//! Web-scenario SLO study: replay the paper's §1 deployment scenarios
//! (recommendation / CDN / ads / e-commerce) against the live coordinator
//! and report latency vs each scenario's SLO, baseline vs speculative.
//!
//!     cargo run --release --example web_scenarios [-- --events 150 --rps 120]

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Instant;

use stride::config::{Cli, ServeConfig};
use stride::data::{dataset_by_name_with_csv, generate_trace, Scenario};
use stride::http::http_request;
use stride::server::Server;
use stride::util::microbench::Table;
use stride::util::stats::quantile;

fn replay(addr: &str, scenario: Scenario, mode: &str, events: usize, rps: f64) -> (Vec<f64>, usize) {
    let trace = generate_trace(scenario, events, rps, 42);
    // Materialize request bodies from the scenario's dataset.
    let bodies: Vec<String> = trace
        .iter()
        .map(|e| {
            let data = dataset_by_name_with_csv(e.dataset).unwrap();
            let ch = e.channel % data.channels();
            let start = 11_000 + (e.channel * 131) % 2_000;
            let hist = data.norm_slice(ch, start, e.history_len);
            let nums: Vec<String> = hist.iter().map(|v| format!("{v:.5}")).collect();
            format!(
                r#"{{"history": [{}], "horizon": {}, "mode": "{mode}", "dataset": "{}"}}"#,
                nums.join(","),
                e.horizon,
                e.dataset
            )
        })
        .collect();

    let bodies = Arc::new(bodies);
    let offsets: Arc<Vec<f64>> = Arc::new(trace.iter().map(|e| e.at_s).collect());
    let next = Arc::new(AtomicUsize::new(0));
    let errors = Arc::new(AtomicUsize::new(0));
    let t0 = Instant::now();
    let handles: Vec<_> = (0..8)
        .map(|_| {
            let bodies = Arc::clone(&bodies);
            let offsets = Arc::clone(&offsets);
            let next = Arc::clone(&next);
            let errors = Arc::clone(&errors);
            let addr = addr.to_string();
            std::thread::spawn(move || {
                let mut lats = Vec::new();
                loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= bodies.len() {
                        return lats;
                    }
                    let due = offsets[i];
                    let now = t0.elapsed().as_secs_f64();
                    if due > now {
                        std::thread::sleep(std::time::Duration::from_secs_f64(due - now));
                    }
                    let ts = Instant::now();
                    match http_request(&addr, "POST", "/forecast", Some(bodies[i].as_bytes())) {
                        Ok(r) if r.status == 200 => lats.push(ts.elapsed().as_secs_f64() * 1e3),
                        _ => {
                            errors.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                }
            })
        })
        .collect();
    let mut lats: Vec<f64> = handles.into_iter().flat_map(|h| h.join().unwrap()).collect();
    lats.sort_by(|a, b| a.partial_cmp(b).unwrap());
    (lats, errors.load(Ordering::Relaxed))
}

fn main() -> anyhow::Result<()> {
    let cli = Cli::from_env()?;
    let events = cli.get_usize("events")?.unwrap_or(150);
    let rps = cli.get_f64("rps")?.unwrap_or(120.0);

    let mut cfg = ServeConfig::default();
    cfg.bind = "127.0.0.1:0".into();
    cfg.backend = cli.get("backend").unwrap_or("xla").to_string();
    cfg.max_batch = 16;
    let server = Server::start(cfg)?;
    let addr = server.addr().to_string();

    let mut table = Table::new(
        "Web scenarios (paper §1): latency vs SLO, baseline vs speculative",
        &["scenario", "SLO ms", "mode", "p50 ms", "p95 ms", "p99 ms", "SLO hit %", "errors"],
    );
    for scenario in [Scenario::Recommendation, Scenario::Cdn, Scenario::Ads, Scenario::Ecommerce] {
        for mode in ["baseline", "sd"] {
            let (lats, errors) = replay(&addr, scenario, mode, events, rps);
            let slo = scenario.slo_ms();
            let hit = lats.iter().filter(|l| **l <= slo).count() as f64 / lats.len() as f64;
            table.row(vec![
                scenario.name().into(),
                format!("{slo:.0}"),
                mode.into(),
                format!("{:.1}", quantile(&lats, 0.50)),
                format!("{:.1}", quantile(&lats, 0.95)),
                format!("{:.1}", quantile(&lats, 0.99)),
                format!("{:.1}", 100.0 * hit),
                format!("{errors}"),
            ]);
        }
    }
    table.print();
    table.write_csv("results/web_scenarios.csv")?;
    println!("wrote results/web_scenarios.csv");
    Ok(())
}
