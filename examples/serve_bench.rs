//! End-to-end serving driver (the DESIGN.md §6 validation run): start the
//! coordinator with XLA artifacts, fire Poisson-arrival forecast traffic
//! through real HTTP from concurrent clients, and report latency percentiles
//! and throughput for baseline-AR vs speculative modes.
//!
//!     cargo run --release --example serve_bench [-- --requests 200 --rps 40 --clients 8]
//!
//! Results are recorded in EXPERIMENTS.md §End-to-end.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Instant;

use stride::config::{Cli, ServeConfig};
use stride::data::Dataset;
use stride::http::http_request;
use stride::server::Server;
use stride::util::json::Json;
use stride::util::microbench::Table;
use stride::util::rng::Rng;
use stride::util::stats::quantile;

struct LoadResult {
    latencies_ms: Vec<f64>,
    wall_s: f64,
    errors: usize,
    patches: usize,
}

/// Fire `n_requests` at ~`rps` (Poisson arrivals) from `clients` threads.
fn run_load(addr: &str, mode: &str, n_requests: usize, rps: f64, clients: usize) -> LoadResult {
    let data = Dataset::by_name("etth1").unwrap();
    // Pre-build request bodies over varied windows/channels/horizons.
    let mut rng = Rng::new(0xBEEF);
    let bodies: Vec<String> = (0..n_requests)
        .map(|i| {
            let ch = i % data.channels();
            let start = 12_000 + (i * 37) % 1_500;
            let hist = data.norm_slice(ch, start, 96);
            let horizon = if i % 5 == 0 { 8 } else { 4 };
            let nums: Vec<String> = hist.iter().map(|v| format!("{v:.5}")).collect();
            format!(
                r#"{{"history": [{}], "horizon": {horizon}, "mode": "{mode}", "dataset": "etth1"}}"#,
                nums.join(",")
            )
        })
        .collect();
    // Poisson arrival offsets.
    let mut offsets_ms = Vec::with_capacity(n_requests);
    let mut t = 0.0f64;
    for _ in 0..n_requests {
        t += rng.exponential(rps) * 1e3;
        offsets_ms.push(t);
    }

    let bodies = Arc::new(bodies);
    let offsets = Arc::new(offsets_ms);
    let next = Arc::new(AtomicUsize::new(0));
    let errors = Arc::new(AtomicUsize::new(0));
    let patches = Arc::new(AtomicUsize::new(0));
    let t0 = Instant::now();
    let handles: Vec<_> = (0..clients)
        .map(|_| {
            let bodies = Arc::clone(&bodies);
            let offsets = Arc::clone(&offsets);
            let next = Arc::clone(&next);
            let errors = Arc::clone(&errors);
            let patches = Arc::clone(&patches);
            let addr = addr.to_string();
            std::thread::spawn(move || {
                let mut lats = Vec::new();
                loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= bodies.len() {
                        return lats;
                    }
                    // Open-loop pacing: wait until this request's arrival time.
                    let due = offsets[i] / 1e3;
                    let now = t0.elapsed().as_secs_f64();
                    if due > now {
                        std::thread::sleep(std::time::Duration::from_secs_f64(due - now));
                    }
                    let ts = Instant::now();
                    match http_request(&addr, "POST", "/forecast", Some(bodies[i].as_bytes())) {
                        Ok(r) if r.status == 200 => {
                            lats.push(ts.elapsed().as_secs_f64() * 1e3);
                            if let Ok(j) = Json::parse(r.body_str()) {
                                if let Some(f) = j.get("forecast").and_then(Json::as_arr) {
                                    patches.fetch_add(f.len() / 24, Ordering::Relaxed);
                                }
                            }
                        }
                        _ => {
                            errors.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                }
            })
        })
        .collect();
    let mut latencies: Vec<f64> = handles.into_iter().flat_map(|h| h.join().unwrap()).collect();
    latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
    LoadResult {
        latencies_ms: latencies,
        wall_s: t0.elapsed().as_secs_f64(),
        errors: errors.load(Ordering::Relaxed),
        patches: patches.load(Ordering::Relaxed),
    }
}

fn main() -> anyhow::Result<()> {
    let cli = Cli::from_env()?;
    let n_requests = cli.get_usize("requests")?.unwrap_or(200);
    let rps = cli.get_f64("rps")?.unwrap_or(40.0);
    let clients = cli.get_usize("clients")?.unwrap_or(8);

    let mut cfg = ServeConfig::default();
    cfg.bind = "127.0.0.1:0".into();
    cfg.backend = cli.get("backend").unwrap_or("xla").to_string();
    cfg.max_batch = cli.get_usize("max-batch")?.unwrap_or(8);
    cfg.max_wait_ms = 2;
    println!(
        "starting server (backend={}, gamma={}, sigma={}, max_batch={})...",
        cfg.backend, cfg.gamma, cfg.sigma, cfg.max_batch
    );
    let server = Server::start(cfg)?;
    let addr = server.addr().to_string();
    println!("server ready on {addr}; load: {n_requests} requests @ {rps} rps, {clients} clients\n");

    let mut table = Table::new(
        "End-to-end serving: baseline AR vs speculative decoding",
        &["mode", "requests", "errors", "p50 ms", "p95 ms", "p99 ms", "mean ms",
          "throughput req/s", "patches/s"],
    );
    for mode in ["baseline", "sd"] {
        let r = run_load(&addr, mode, n_requests, rps, clients);
        let n = r.latencies_ms.len();
        table.row(vec![
            mode.into(),
            format!("{n}"),
            format!("{}", r.errors),
            format!("{:.1}", quantile(&r.latencies_ms, 0.50)),
            format!("{:.1}", quantile(&r.latencies_ms, 0.95)),
            format!("{:.1}", quantile(&r.latencies_ms, 0.99)),
            format!("{:.1}", r.latencies_ms.iter().sum::<f64>() / n as f64),
            format!("{:.1}", n as f64 / r.wall_s),
            format!("{:.0}", r.patches as f64 / r.wall_s),
        ]);
    }
    table.print();
    table.write_csv("results/serve_bench.csv")?;

    // Server-side view.
    let stats = http_request(&addr, "GET", "/stats", None)?;
    println!("server /stats: {}", stats.body_str());
    println!("wrote results/serve_bench.csv");
    Ok(())
}
