//! Capacity planner: the paper's deployment workflow (§3.5) end-to-end.
//!
//! 1. Estimate mean acceptance alpha-hat from a small held-out sample with
//!    the closed-form estimator (Prop. 4 / Remark 5) and its Hoeffding bound.
//! 2. Measure the wall-clock cost ratio c on this hardware.
//! 3. Scan gamma with the analytic predictors, pick gamma* (exact Prop. 3
//!    condition), and *verify* the prediction against a measured run.
//!
//!     cargo run --release --example capacity_planner [-- --sigma 0.6 --dataset weather]

use stride::accept::{estimate_alpha_closed_form, AcceptancePolicy};
use stride::config::Cli;
use stride::repro::{Bench, RowCfg};
use stride::theory;
use stride::util::stats::hoeffding_n;

fn main() -> anyhow::Result<()> {
    let cli = Cli::from_env()?;
    let sigma = cli.get_f64("sigma")?.unwrap_or(0.5);
    let dataset: &'static str = match cli.get("dataset").unwrap_or("etth1") {
        "etth2" => "etth2",
        "ettm2" => "ettm2",
        "weather" => "weather",
        _ => "etth1",
    };

    let bench = Bench::from_env()?;
    let p = bench.manifest.patch;

    // --- Step 1: held-out acceptance estimate.
    let eps = 0.05;
    let n_needed = hoeffding_n(eps, 0.05);
    println!("Hoeffding: N = {n_needed} held-out histories for +-{eps} at 95%");
    let cfg = RowCfg { dataset, sigma, windows: 64, ..Default::default() };
    let windows = bench.windows(&cfg)?;
    let mut heads = Vec::new();
    for w in &windows {
        let n = w.history.len() / p;
        let mp = bench.target.forward(&w.history, n)?;
        let md = bench.draft.forward(&w.history, n)?;
        heads.push((mp[(n - 1) * p..n * p].to_vec(), md[(n - 1) * p..n * p].to_vec()));
    }
    let policy = AcceptancePolicy::new(sigma, 1.0);
    let est = estimate_alpha_closed_form(
        &policy,
        heads.iter().map(|(a, b)| (a.as_slice(), b.as_slice())),
    );
    println!(
        "alpha_hat = {:.4} +- {:.4} (N = {}, dataset = {dataset}, sigma = {sigma})",
        est.alpha_hat, est.eps95, est.n_histories
    );

    // --- Step 2: measured cost ratios on this testbed.
    let c = bench.draft.mean_secs() / bench.target.mean_secs();
    let c_hat = bench.draft.flops(bench.manifest.n_ctx) / bench.target.flops(bench.manifest.n_ctx);
    println!("measured c = {c:.3} (wall-clock), c_hat = {c_hat:.3} (FLOPs)");

    // --- Step 3: gamma scan + pick.
    let g_star = theory::optimal_gamma(est.alpha_hat, c, 16);
    println!("\n gamma   E[L]    S_wall(pred)   OpsFactor");
    for gamma in [1usize, 2, 3, 4, 5, 7, 10] {
        let pr = theory::predict(est.alpha_hat, gamma, c, c_hat);
        println!(
            "  {gamma:>3}   {:>5.2}   {:>9.2}x   {:>8.2}{}",
            pr.expected_l,
            pr.s_wall,
            pr.ops_factor,
            if gamma == g_star { "   <- gamma* (exact Prop. 3)" } else { "" }
        );
    }
    println!(
        "paper's verbatim Prop. 3 rule would pick gamma = {} (conservative; see theory.rs)",
        theory::paper_gamma_rule(est.alpha_hat, c, 16)
    );

    // --- Step 4: verify the chosen gamma against a measured run.
    let cfg = RowCfg { dataset, sigma, gamma: g_star, ..Default::default() };
    let r = bench.run_row(&cfg)?;
    println!(
        "\nverification at gamma* = {g_star}: predicted S_wall {:.2}x, measured {:.2}x ({} windows)",
        theory::wall_speedup(est.alpha_hat, g_star, r.c),
        r.s_wall_meas,
        cfg.windows,
    );
    Ok(())
}
