//! Quickstart: load the AOT artifacts, forecast one window with speculative
//! decoding, and compare against plain target autoregression.
//!
//!     make artifacts && cargo run --release --example quickstart

use stride::data::{eval_windows, Dataset};
use stride::forecast::ar_decode;
use stride::models::XlaBackend;
use stride::runtime::{Engine, Manifest};
use stride::specdec::{sd_generate, SpecConfig};
use stride::util::tensor::mse_mae;

fn main() -> anyhow::Result<()> {
    // 1. Load the compiled artifacts (target + 0.25x distilled draft).
    let manifest = Manifest::load(&stride::artifacts_dir())?;
    let mut engine = Engine::cpu()?;
    let target = XlaBackend::load(&mut engine, &manifest, "target", "fused")?;
    let draft = XlaBackend::load(&mut engine, &manifest, "draft", "fused")?;
    println!(
        "loaded {} ({} params) + {} ({} params) on {}",
        manifest.target.name,
        manifest.target.param_count,
        manifest.draft.name,
        manifest.draft.param_count,
        engine.platform()
    );

    // 2. Take a real eval window: 96-step lookback, 96-step horizon.
    let data = Dataset::by_name("etth1").unwrap();
    let w = &eval_windows(&data, manifest.patch, 4, 4, 96, 1)[0];
    let n_hist = w.history.len() / manifest.patch;

    // 3. Baseline: plain autoregression with the target (4 sequential passes).
    let t0 = std::time::Instant::now();
    let (base, _, calls) = ar_decode(&target, &w.history, n_hist, 4)?;
    let base_wall = t0.elapsed();
    let (base_mse, _) = mse_mae(&base, &w.future);

    // 4. Speculative decoding: draft proposes gamma=3 patches, target
    //    validates all prefixes in one batched pass.
    let cfg = SpecConfig::default(); // gamma=3, sigma=0.5, practical variant
    let t1 = std::time::Instant::now();
    let out = sd_generate(&target, &draft, &w.history, n_hist, 4, &cfg)?;
    let sd_wall = t1.elapsed();
    let (sd_mse, _) = mse_mae(&out.patches, &w.future);

    println!("\nbaseline : {calls} target passes, {:.2}ms, MSE {base_mse:.4}", base_wall.as_secs_f64() * 1e3);
    println!(
        "SD       : {} draft + {} target passes, {:.2}ms, MSE {sd_mse:.4}",
        out.stats.draft_calls,
        out.stats.rounds,
        sd_wall.as_secs_f64() * 1e3
    );
    println!(
        "speedup  : {:.2}x   alpha_hat {:.3}   E[L] {:.2}",
        base_wall.as_secs_f64() / sd_wall.as_secs_f64(),
        out.stats.alpha_hat(),
        out.stats.mean_block_len()
    );
    Ok(())
}
