//! Lossless vs practical variant (paper §3.2-3.3, §A.5, §B.6): measure the
//! exactness/cost trade-off that motivates the paper's fallback-to-p choice.
//!
//! Demonstrates: (i) both variants' forecast quality, (ii) the residual
//! thinning cost exploding as acceptance -> 1 (expected 1/(1-beta) target
//! draws per rejection), and (iii) the §B.6 breakeven rule.
//!
//!     cargo run --release --example lossless_vs_practical

use stride::accept::AcceptancePolicy;
use stride::repro::{Bench, RowCfg};
use stride::theory;
use stride::util::microbench::Table;

fn main() -> anyhow::Result<()> {
    let bench = Bench::from_env()?;
    let mut table = Table::new(
        "Lossless (residual thinning) vs practical (fallback-to-p)",
        &["sigma", "variant", "MSE", "alpha", "residual draws/rejection",
          "S_wall meas", "worthwhile (B.6)?"],
    );

    for &sigma in &[0.3, 0.5, 0.8] {
        for lossless in [false, true] {
            let cfg = RowCfg {
                dataset: "etth1",
                sigma,
                lossless,
                windows: 16,
                ..Default::default()
            };
            let r = bench.run_row(&cfg)?;
            let rejections = r.stats.proposals - r.stats.accepted;
            let draws_per_rej = if rejections > 0 {
                r.stats.residual_draws as f64 / rejections as f64
            } else {
                f64::NAN
            };
            table.row(vec![
                format!("{sigma}"),
                if lossless { "lossless" } else { "practical" }.into(),
                format!("{:.4}", r.mse),
                format!("{:.3}", r.alpha_hat),
                if lossless { format!("{draws_per_rej:.1}") } else { "0 (fallback)".into() },
                format!("{:.2}x", r.s_wall_meas),
                format!("{}", theory::lossless_worthwhile(r.alpha_hat, cfg.gamma)),
            ]);
        }
    }
    table.print();
    table.write_csv("results/lossless_vs_practical.csv")?;

    // Analytic illustration of the 1/(1-beta) cost curve.
    println!("expected residual draws per rejection = 1/(1-beta):");
    let pol = AcceptancePolicy::new(0.5, 1.0);
    for gap in [1.0f32, 0.5, 0.25, 0.1, 0.05] {
        let mu_p = vec![gap; 4];
        let mu_q = vec![0.0f32; 4];
        let beta = pol.mean_acceptance_closed_form(&mu_p, &mu_q);
        println!("  mean gap {gap:<5}: beta = {beta:.3}, expected draws = {:.1}", 1.0 / (1.0 - beta));
    }
    println!("wrote results/lossless_vs_practical.csv");
    Ok(())
}
