//! Failure injection: distribution shift and coordinator resilience
//! (the paper's §7 deployment-risk guidance, tested).

use std::sync::Arc;

use stride::config::ServeConfig;
use stride::data::Dataset;
use stride::http::http_request;
use stride::metrics::AcceptanceMonitor;
use stride::models::AnalyticBackend;
use stride::server::Server;
use stride::specdec::{sd_generate, Emission, SpecConfig, Variant};
use stride::util::json::Json;

fn spec(sigma: f64, seed: u64) -> SpecConfig {
    SpecConfig {
        gamma: 3,
        k: 1,
        policy: stride::accept::AcceptancePolicy::new(sigma, 1.0),
        variant: Variant::Practical,
        seed,
        max_residual_draws: 100,
        emission: Emission::Sampled,
        cache: stride::models::CacheMode::On,
        draft: stride::specdec::DraftConfig::default(),
        adaptive: None,
    }
}

/// Distribution shift: a draft tuned for one regime faces another; the
/// acceptance monitor must flag degradation and recommend gamma = 1
/// (the paper's "adaptive thresholds during anomalous periods").
#[test]
fn monitor_detects_regime_shift_and_downgrades_gamma() {
    let monitor = AcceptanceMonitor::new(64, 0.8);
    let t_normal = AnalyticBackend::new("t", 2, 0.8, 0.0);
    let d_normal = AnalyticBackend::new("d", 2, 0.8, 0.02); // well matched
    // Normal traffic: high acceptance.
    for seed in 0..40 {
        let out = sd_generate(&t_normal, &d_normal, &[0.3, -0.3], 1, 8, &spec(0.5, seed)).unwrap();
        monitor.record(out.stats.alpha_hat());
    }
    assert!(!monitor.degraded(), "normal regime must not alert");
    let g_normal = monitor.recommend_gamma(0.25, 10);
    assert!(g_normal >= 2, "healthy acceptance supports gamma >= 2, got {g_normal}");

    // Shift: the *series* jumps regime (e.g. flash-sale traffic) — modeled
    // by the target adapting (different AR coefficient) while the draft
    // stays stale.
    let t_shifted = AnalyticBackend::new("t2", 2, -0.5, 1.5);
    for seed in 0..80 {
        let out =
            sd_generate(&t_shifted, &d_normal, &[0.3, -0.3], 1, 8, &spec(0.5, 1000 + seed)).unwrap();
        monitor.record(out.stats.alpha_hat());
    }
    assert!(monitor.degraded(), "shifted regime must alert (alpha {:.3})", monitor.alpha_bar());
    assert_eq!(monitor.recommend_gamma(0.25, 10), 1, "conservative gamma under shift");
}

/// The /stats surface reflects degradation end-to-end: drive the server
/// with out-of-distribution histories and watch the monitor flip.
#[test]
fn server_stats_reflect_acceptance_quality() {
    if !stride::artifacts_dir().join("manifest.json").exists() {
        eprintln!("SKIP: run `make artifacts`");
        return;
    }
    let mut cfg = ServeConfig::default();
    cfg.bind = "127.0.0.1:0".into();
    cfg.backend = "native".into();
    cfg.max_batch = 4;
    let server = Server::start(cfg).unwrap();
    let addr = server.addr().to_string();

    // In-distribution traffic.
    let data = Dataset::by_name("etth1").unwrap();
    let hist: Vec<String> =
        data.norm_slice(0, 12_000, 96).iter().map(|v| format!("{v:.5}")).collect();
    let body = format!(r#"{{"history": [{}], "horizon": 4}}"#, hist.join(","));
    for _ in 0..4 {
        let r = http_request(&addr, "POST", "/forecast", Some(body.as_bytes())).unwrap();
        assert_eq!(r.status, 200);
    }
    let j = Json::parse(
        http_request(&addr, "GET", "/stats", None).unwrap().body_str(),
    )
    .unwrap();
    let alpha_in = j.get("alpha_bar_window").unwrap().as_f64().unwrap();

    // Per-draft-source observability: the default model source must show
    // up in both /metrics (stride_draft_model_* gauges) and the /stats
    // "draft" block after serving SD traffic.
    let metrics_text = http_request(&addr, "GET", "/metrics", None).unwrap().body_str().to_string();
    assert!(
        metrics_text.contains("stride_draft_model_decodes"),
        "missing per-source decode counter in /metrics:\n{metrics_text}"
    );
    assert!(
        metrics_text.contains("stride_draft_model_alpha_hat"),
        "missing per-source alpha gauge in /metrics"
    );
    assert!(
        metrics_text.contains("stride_draft_model_c"),
        "missing per-source cost-ratio gauge in /metrics"
    );
    let draft = j.get("draft").expect("/stats must carry a draft block");
    assert_eq!(draft.get("default").unwrap().as_str(), Some("model"));
    let model_src = draft.get("sources").unwrap().get("model").expect("model source served");
    assert!(model_src.get("decodes").unwrap().as_usize().unwrap() > 0);
    assert!(model_src.get("alpha_hat").unwrap().as_f64().is_some());

    // Wild out-of-distribution history (constant extreme level).
    let wild: Vec<String> = (0..96).map(|_| "25.0".to_string()).collect();
    let body = format!(r#"{{"history": [{}], "horizon": 4}}"#, wild.join(","));
    for _ in 0..8 {
        let r = http_request(&addr, "POST", "/forecast", Some(body.as_bytes())).unwrap();
        assert_eq!(r.status, 200, "OOD input must still be served");
    }
    let j = Json::parse(
        http_request(&addr, "GET", "/stats", None).unwrap().body_str(),
    )
    .unwrap();
    let alpha_mixed = j.get("alpha_bar_window").unwrap().as_f64().unwrap();
    eprintln!("alpha in-dist {alpha_in:.3}, after OOD burst {alpha_mixed:.3}");
    // Serving never crashes on OOD; acceptance statistics remain finite.
    assert!(alpha_mixed.is_finite());
}

/// Tree-speculation observability, end to end and artifact-free: a
/// `"k": 4` request routes through the per-job tree executor and must
/// (a) return a deterministic, engine-bit-identical forecast, (b) light
/// up every `stride_tree_*` metric, and (c) fill the `/stats` `"tree"`
/// block (decode/round/branch counters, the k gauge, and the
/// winner-depth histogram).
#[test]
fn tree_metrics_and_stats_block_light_up() {
    use stride::models::NativeBackend;
    use stride::nn::model::tiny_model;
    use stride::server::{ModelShape, ReplicaBuilder, ReplicaStacks};
    use stride::specdec::{make_source, sd_generate_tree_from};

    let mut cfg = ServeConfig::default();
    cfg.bind = "127.0.0.1:0".into();
    cfg.backend = "native".into();
    let shape = ModelShape { patch: 4, n_ctx: 8 };
    let spec_base = cfg.spec_config();
    let gamma = cfg.gamma;
    let builder: ReplicaBuilder = Arc::new(move |_r| {
        Ok(ReplicaStacks {
            target: Box::new(NativeBackend::new(tiny_model(901))),
            draft: Box::new(NativeBackend::new(tiny_model(902))),
        })
    });
    let server = Server::start_with_builder(cfg, shape, builder).unwrap();
    let addr = server.addr().to_string();

    let hist: Vec<f32> = (0..4 * 4).map(|i| (i as f32 * 0.23).sin()).collect();
    let hist_s: Vec<String> = hist.iter().map(|v| format!("{v}")).collect();
    let body = format!(
        r#"{{"history": [{}], "horizon": 6, "k": 4, "seed": 7}}"#,
        hist_s.join(",")
    );
    let r1 = http_request(&addr, "POST", "/forecast", Some(body.as_bytes())).unwrap();
    assert_eq!(r1.status, 200, "{}", r1.body_str());
    let r2 = http_request(&addr, "POST", "/forecast", Some(body.as_bytes())).unwrap();
    assert_eq!(r2.status, 200);

    let forecast_bits = |body: &str| -> Vec<u32> {
        Json::parse(body)
            .unwrap()
            .get("forecast")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|v| (v.as_f64().unwrap() as f32).to_bits())
            .collect()
    };
    let f1 = forecast_bits(r1.body_str());
    assert_eq!(f1, forecast_bits(r2.body_str()), "seed-pinned tree decode must be deterministic");

    // Engine-level replay: the served tree forecast is a pure function
    // of the request — identical bits from a solo sd_generate_tree_from
    // at the same seed (history pre-clamped exactly like the server).
    let t = NativeBackend::new(tiny_model(901));
    let d = NativeBackend::new(tiny_model(902));
    let mut spec = spec_base;
    spec.k = 4;
    spec.seed = 7;
    let keep = (8usize).saturating_sub(gamma + 1).max(1).min(hist.len() / 4);
    let clamped = &hist[(hist.len() / 4 - keep) * 4..];
    let mut src = make_source(&spec.draft, &d).unwrap();
    let solo = sd_generate_tree_from(&t, src.as_mut(), clamped, keep, 6, &spec).unwrap();
    let solo_bits: Vec<u32> = solo.patches.iter().map(|v| v.to_bits()).collect();
    assert_eq!(f1, solo_bits, "served tree forecast diverged from the solo engine");

    // /metrics: every tree series must be present after a k > 1 decode.
    let m = http_request(&addr, "GET", "/metrics", None).unwrap().body_str().to_string();
    for key in [
        "stride_tree_decodes",
        "stride_tree_rounds",
        "stride_tree_branches_verified",
        "stride_tree_k",
        "stride_tree_winner_depth_",
    ] {
        assert!(m.contains(key), "missing {key} in /metrics:\n{m}");
    }

    // /stats: the tree block carries the same story in JSON.
    let j = Json::parse(http_request(&addr, "GET", "/stats", None).unwrap().body_str()).unwrap();
    let tree = j.get("tree").expect("/stats must carry a tree block");
    let decodes = tree.get("decodes").unwrap().as_usize().unwrap();
    let rounds = tree.get("rounds").unwrap().as_usize().unwrap();
    let branches = tree.get("branches_verified").unwrap().as_usize().unwrap();
    assert_eq!(decodes, 2, "two k=4 requests served");
    assert!(rounds >= 1, "at least one speculative round ran");
    assert!(branches > rounds, "k=4 rounds verify more branches than rounds");
    assert_eq!(tree.get("k").unwrap().as_f64(), Some(4.0));
    let depths = tree.get("winner_depth").unwrap().as_arr().unwrap();
    assert_eq!(depths.len(), 9, "depth buckets 0..=8");
    let depth_total: usize = depths.iter().map(|v| v.as_usize().unwrap()).sum();
    assert!(
        depth_total >= 1 && depth_total <= rounds,
        "winner-depth histogram counts tree rounds: {depth_total} vs {rounds}"
    );
}

/// Fault-tolerance observability in steady state: with chaos *disarmed*
/// the supervision counters must still render (pre-registered at zero,
/// so dashboards can alert on "went nonzero" without a first fault),
/// `stride_breaker_state` and `stride_draining` gauges must read 0, and
/// the `/stats` `"faults"` block must report `injection: null`,
/// `draining: false`, and zeroed recovery counters.
#[test]
fn fault_metrics_render_zero_without_chaos() {
    use stride::models::NativeBackend;
    use stride::nn::model::tiny_model;
    use stride::server::{ModelShape, ReplicaBuilder, ReplicaStacks};

    let mut cfg = ServeConfig::default();
    cfg.bind = "127.0.0.1:0".into();
    cfg.backend = "native".into();
    let shape = ModelShape { patch: 4, n_ctx: 8 };
    let builder: ReplicaBuilder = Arc::new(move |_r| {
        Ok(ReplicaStacks {
            target: Box::new(NativeBackend::new(tiny_model(911))),
            draft: Box::new(NativeBackend::new(tiny_model(912))),
        })
    });
    let server = Server::start_with_builder(cfg, shape, builder).unwrap();
    let addr = server.addr().to_string();

    // Serve one request so the stats path is fully exercised.
    let hist: Vec<String> = (0..16).map(|i| format!("{}", (i as f32 * 0.17).cos())).collect();
    let body = format!(r#"{{"history": [{}], "horizon": 4, "seed": 3}}"#, hist.join(","));
    let r = http_request(&addr, "POST", "/forecast", Some(body.as_bytes())).unwrap();
    assert_eq!(r.status, 200, "{}", r.body_str());

    // /metrics: every supervision series is present and zero.
    let m = http_request(&addr, "GET", "/metrics", None).unwrap().body_str().to_string();
    for key in [
        "stride_replica_restarts 0",
        "stride_replica_failures 0",
        "stride_requeues 0",
        "stride_numeric_faults 0",
        "stride_breaker_state 0",
        "stride_draining 0",
    ] {
        assert!(m.contains(key), "missing `{key}` in /metrics:\n{m}");
    }

    // /stats: the faults block tells the same story in JSON.
    let j = Json::parse(http_request(&addr, "GET", "/stats", None).unwrap().body_str()).unwrap();
    let faults = j.get("faults").expect("/stats must carry a faults block");
    assert_eq!(faults.get("injection"), Some(&Json::Null), "chaos disarmed -> injection null");
    assert_eq!(faults.get("replica_restarts").unwrap().as_usize(), Some(0));
    assert_eq!(faults.get("replica_failures").unwrap().as_usize(), Some(0));
    assert_eq!(faults.get("requeues").unwrap().as_usize(), Some(0));
    assert_eq!(faults.get("numeric_faults").unwrap().as_usize(), Some(0));
    assert_eq!(faults.get("draining").unwrap().as_bool(), Some(false));
    // No adaptive controller configured -> no breaker to report.
    assert_eq!(faults.get("breaker"), Some(&Json::Null), "breaker null without adaptive gamma");
}

/// Pin the `/metrics` exposition grammar: every line is exactly
/// `stride_<ident> <finite number>` — one metric per line, no labels,
/// no NaN/inf, no trailing junk. Dashboards parse this by line; a
/// format drift is a silent fleet-wide observability outage.
fn assert_metrics_grammar(text: &str) {
    assert!(!text.is_empty(), "metrics render must not be empty");
    for line in text.lines() {
        let (name, value) = line
            .split_once(' ')
            .unwrap_or_else(|| panic!("metric line must be `name value`: '{line}'"));
        assert!(
            name.strip_prefix("stride_").is_some_and(|rest| {
                !rest.is_empty()
                    && rest.chars().all(|c| c.is_ascii_lowercase() || c.is_ascii_digit() || c == '_')
            }),
            "metric name must be stride_[a-z0-9_]+: '{line}'"
        );
        let v: f64 = value
            .parse()
            .unwrap_or_else(|_| panic!("metric value must parse as a number: '{line}'"));
        assert!(v.is_finite(), "metric value must be finite (torn/NaN line?): '{line}'");
        assert!(!value.contains(' '), "exactly one value per line: '{line}'");
    }
}

/// The render grammar holds on a quiet registry (pre-registered zeros)
/// and after traffic — including the latency histograms the scheduler
/// feeds (`queue_wait`, `draft_compute`, `verify_compute`).
#[test]
fn metrics_render_format_is_pinned() {
    use stride::models::NativeBackend;
    use stride::nn::model::tiny_model;
    use stride::server::{ModelShape, ReplicaBuilder, ReplicaStacks, Server};

    let mut cfg = ServeConfig::default();
    cfg.bind = "127.0.0.1:0".into();
    cfg.backend = "native".into();
    let builder: ReplicaBuilder = Arc::new(move |_r| {
        Ok(ReplicaStacks {
            target: Box::new(NativeBackend::new(tiny_model(921))),
            draft: Box::new(NativeBackend::new(tiny_model(922))),
        })
    });
    let server = Server::start_with_builder(cfg, ModelShape { patch: 4, n_ctx: 8 }, builder).unwrap();
    let addr = server.addr().to_string();

    // Quiet: grammar holds before any request.
    assert_metrics_grammar(http_request(&addr, "GET", "/metrics", None).unwrap().body_str());

    let hist: Vec<String> = (0..16).map(|i| format!("{}", (i as f32 * 0.19).sin())).collect();
    let body = format!(r#"{{"history": [{}], "horizon": 4, "seed": 11}}"#, hist.join(","));
    for _ in 0..3 {
        let r = http_request(&addr, "POST", "/forecast", Some(body.as_bytes())).unwrap();
        assert_eq!(r.status, 200, "{}", r.body_str());
    }

    let m = http_request(&addr, "GET", "/metrics", None).unwrap().body_str().to_string();
    assert_metrics_grammar(&m);
    // The scheduler's stage histograms light up with served traffic.
    for key in ["stride_queue_wait_count", "stride_draft_compute_p95_ms", "stride_verify_compute_p95_ms"]
    {
        assert!(m.contains(key), "missing `{key}` in /metrics after traffic:\n{m}");
    }
}

/// Scrape-under-fire: concurrent `/metrics` readers racing live
/// `/forecast` traffic must always see a complete, grammar-clean
/// exposition — the render locks each family briefly, so a scrape can
/// interleave *between* families but never tear a line or emit NaN.
#[test]
fn concurrent_metrics_scrape_stays_well_formed() {
    use stride::models::NativeBackend;
    use stride::nn::model::tiny_model;
    use stride::server::{ModelShape, ReplicaBuilder, ReplicaStacks, Server};

    let mut cfg = ServeConfig::default();
    cfg.bind = "127.0.0.1:0".into();
    cfg.backend = "native".into();
    let builder: ReplicaBuilder = Arc::new(move |_r| {
        Ok(ReplicaStacks {
            target: Box::new(NativeBackend::new(tiny_model(931))),
            draft: Box::new(NativeBackend::new(tiny_model(932))),
        })
    });
    let server = Server::start_with_builder(cfg, ModelShape { patch: 4, n_ctx: 8 }, builder).unwrap();
    let addr = Arc::new(server.addr().to_string());

    let mut handles = Vec::new();
    // Writers: keep the counters, gauges, and histograms moving.
    for w in 0..2u64 {
        let addr = Arc::clone(&addr);
        handles.push(std::thread::spawn(move || {
            let hist: Vec<String> =
                (0..16).map(|i| format!("{}", (i as f32 * 0.21).cos())).collect();
            for i in 0..8u64 {
                let body = format!(
                    r#"{{"history": [{}], "horizon": 4, "seed": {}}}"#,
                    hist.join(","),
                    w * 100 + i
                );
                let r = http_request(&addr, "POST", "/forecast", Some(body.as_bytes())).unwrap();
                assert_eq!(r.status, 200, "{}", r.body_str());
            }
        }));
    }
    // Scrapers: every observation mid-flight must be grammar-clean.
    for _ in 0..3 {
        let addr = Arc::clone(&addr);
        handles.push(std::thread::spawn(move || {
            for _ in 0..12 {
                let r = http_request(&addr, "GET", "/metrics", None).unwrap();
                assert_eq!(r.status, 200);
                assert_metrics_grammar(r.body_str());
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    // One final settled scrape, still clean.
    assert_metrics_grammar(http_request(&addr, "GET", "/metrics", None).unwrap().body_str());
}

/// Engine-thread resilience: a request that fails validation must not
/// poison the batch it rides in.
#[test]
fn bad_request_does_not_poison_batch() {
    if !stride::artifacts_dir().join("manifest.json").exists() {
        return;
    }
    let mut cfg = ServeConfig::default();
    cfg.bind = "127.0.0.1:0".into();
    cfg.backend = "native".into();
    cfg.max_batch = 8;
    cfg.max_wait_ms = 30; // force co-batching
    let server = Server::start(cfg).unwrap();
    let addr = Arc::new(server.addr().to_string());

    let data = Dataset::by_name("etth1").unwrap();
    let good_hist: Vec<String> =
        data.norm_slice(0, 12_000, 96).iter().map(|v| format!("{v:.5}")).collect();
    let good = Arc::new(format!(r#"{{"history": [{}], "horizon": 4}}"#, good_hist.join(",")));
    // 25 values: not a multiple of patch 24 -> server-side rejection.
    let bad_hist: Vec<String> = (0..25).map(|_| "0.1".into()).collect();
    let bad = Arc::new(format!(r#"{{"history": [{}], "horizon": 4}}"#, bad_hist.join(",")));

    let mut handles = Vec::new();
    for k in 0..6 {
        let addr = Arc::clone(&addr);
        let body = if k % 3 == 0 { Arc::clone(&bad) } else { Arc::clone(&good) };
        let expect_ok = k % 3 != 0;
        handles.push(std::thread::spawn(move || {
            let r = http_request(&addr, "POST", "/forecast", Some(body.as_bytes())).unwrap();
            if expect_ok {
                assert_eq!(r.status, 200, "good request failed: {}", r.body_str());
            } else {
                // Typed error mapping: validation failures are 400s with
                // a machine-readable code (scheduler PR).
                assert_eq!(r.status, 400, "{}", r.body_str());
                assert!(r.body_str().contains("multiple of patch"));
                assert!(r.body_str().contains("\"error_code\":\"invalid\""));
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
}
