//! Equivalence suite for the kernel layer: the packed-weight / scratch-
//! arena / blocked-matmul path must reproduce the pre-kernel-layer
//! reference implementation (string-keyed lookups, per-call allocation,
//! naive matmul) within 1e-5, the parallel paths must be *bitwise*
//! identical to serial for every thread count, and the pool must surface
//! job panics instead of silently shrinking.
//!
//! SIMD-tier walls (SIMD + stacked-GEMM PR): the runtime-dispatched SIMD
//! inner kernel, the cache-blocked tiled path, and the stacked batched
//! GEMM must each be **bitwise** identical to the scalar / flat / looped
//! forms they accelerate — same accumulation order, no FMA contraction —
//! so toggling any of them can never move a decode by one ulp.

use stride::models::{Backend, BatchDecodeSession, DecodeSession, NativeBackend};
use stride::nn::kernel::matmul_stacked;
use stride::nn::{ModelDims, NativeModel};
use stride::util::proptest_lite::{self, Pair, UsizeRange};
use stride::util::rng::Rng;
use stride::util::tensor::{
    matmul, matmul_naive, matmul_parallel, matmul_tiled, set_scalar_kernel, simd_kernel_active,
};
use stride::util::threadpool::ThreadPool;

const TOL: f32 = 1e-5;

fn dims() -> ModelDims {
    ModelDims { patch: 4, n_ctx: 32, d_model: 16, n_layers: 2, n_heads: 2, d_ff: 32 }
}

/// Same seed twice: one kernel-layer backend, one reference backend.
fn pair(seed: u64) -> (NativeBackend, NativeBackend) {
    let packed = NativeBackend::new(NativeModel::random("m", dims(), seed));
    let mut reference = NativeBackend::new(NativeModel::random("m", dims(), seed));
    reference.set_reference_kernel(true);
    (packed, reference)
}

fn tokens(n: usize, seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    (0..n * 4).map(|_| rng.normal() as f32).collect()
}

fn assert_close(a: &[f32], b: &[f32], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length mismatch");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert!((x - y).abs() < TOL, "{what}: [{i}] packed {x} vs reference {y}");
    }
}

#[test]
fn packed_forward_matches_string_keyed_reference() {
    let (packed, reference) = pair(1);
    for seed in 0..4u64 {
        for n in [1usize, 3, 13, 32] {
            let toks = tokens(n, 100 + seed);
            let a = packed.forward(&toks, n).unwrap();
            let b = reference.forward(&toks, n).unwrap();
            assert_close(&a, &b, &format!("forward seed {seed} n {n}"));
        }
    }
}

#[test]
fn arena_cached_matches_allocating_reference() {
    // Session prefill + extend + rollback + re-extend on both kernels.
    let (packed, reference) = pair(2);
    let toks = tokens(14, 7);
    let alt = tokens(4, 8);
    let mut sp = packed.begin_cached(&toks[..6 * 4], 6).unwrap();
    let mut sr = reference.begin_cached(&toks[..6 * 4], 6).unwrap();
    let a = sp.extend(&toks[6 * 4..14 * 4], 8).unwrap();
    let b = sr.extend(&toks[6 * 4..14 * 4], 8).unwrap();
    assert_close(&a, &b, "extend");
    sp.rollback(5).unwrap();
    sr.rollback(5).unwrap();
    let a = sp.extend(&alt, 4).unwrap();
    let b = sr.extend(&alt, 4).unwrap();
    assert_close(&a, &b, "rollback + re-extend");
    assert_close(&sp.tip_mean().unwrap(), &sr.tip_mean().unwrap(), "tip");
}

#[test]
fn prop_packed_equals_reference_over_random_splits() {
    // For random (n_hist, k): prefill n_hist then extend k must agree
    // between the kernel layer and the reference implementation.
    let (packed, reference) = pair(3);
    proptest_lite::check_with(
        proptest_lite::Config { cases: 30, seed: 0x7E57, max_shrink_rounds: 40 },
        &Pair(UsizeRange(1, 12), UsizeRange(1, 8)),
        |&(n_hist, k)| {
            let toks = tokens(n_hist + k, 3000 + (n_hist * 37 + k) as u64);
            let mut sp = packed
                .begin_cached(&toks[..n_hist * 4], n_hist)
                .map_err(|e| e.to_string())?;
            let mut sr = reference
                .begin_cached(&toks[..n_hist * 4], n_hist)
                .map_err(|e| e.to_string())?;
            let a = sp
                .extend(&toks[n_hist * 4..(n_hist + k) * 4], k)
                .map_err(|e| e.to_string())?;
            let b = sr
                .extend(&toks[n_hist * 4..(n_hist + k) * 4], k)
                .map_err(|e| e.to_string())?;
            for (i, (x, y)) in a.iter().zip(&b).enumerate() {
                if (x - y).abs() >= TOL {
                    return Err(format!("[{i}] packed {x} vs reference {y}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn blocked_matmul_matches_naive_within_tolerance() {
    let mut rng = Rng::new(11);
    for &(m, k, n) in &[(1usize, 16usize, 48usize), (7, 33, 12), (64, 128, 96)] {
        let a: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
        let b: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32).collect();
        let mut c0 = vec![0.0; m * n];
        let mut c1 = vec![0.0; m * n];
        matmul_naive(&a, &b, m, k, n, &mut c0);
        matmul(&a, &b, m, k, n, &mut c1);
        for (x, y) in c0.iter().zip(&c1) {
            assert!((x - y).abs() < 1e-4 * x.abs().max(1.0), "naive {x} vs blocked {y}");
        }
    }
}

#[test]
fn parallel_matmul_bit_stable_across_thread_counts() {
    // STRIDE_THREADS ∈ {1, 2, 8}: the row partition must not move a bit.
    let mut rng = Rng::new(12);
    let (m, k, n) = (53, 32, 48);
    let a: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
    let b: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32).collect();
    let mut serial = vec![0.0; m * n];
    matmul(&a, &b, m, k, n, &mut serial);
    for threads in [1usize, 2, 8] {
        let pool = ThreadPool::new(threads);
        let mut par = vec![0.0; m * n];
        matmul_parallel(&pool, &a, &b, m, k, n, &mut par);
        for (i, (x, y)) in serial.iter().zip(&par).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "bit drift at {i} with {threads} threads");
        }
    }
}

#[test]
fn parallel_batched_verify_bit_stable_and_matches_singles() {
    // The batched-verify fan-out must equal per-sequence single sessions
    // exactly — the per-sequence work runs the identical serial kernel on
    // whatever thread picks it up.
    let backend = NativeBackend::new(NativeModel::random("m", dims(), 21));
    let h1 = tokens(3, 31);
    let h2 = tokens(7, 32);
    let h3 = tokens(5, 33);
    let tasks: Vec<(&[f32], usize)> = vec![(&h1, 3), (&h2, 7), (&h3, 5)];
    let mut bs = backend.begin_cached_batch(&tasks).unwrap();
    let fresh = tokens(3, 34);
    let flat = [&fresh[..], &fresh[..], &fresh[..]].concat();
    let rows = bs.extend(&[0, 1, 2], &flat, 3).unwrap();
    for (ai, (h, n)) in [(&h1, 3usize), (&h2, 7), (&h3, 5)].iter().enumerate() {
        let mut solo = backend.begin_cached(h, *n).unwrap();
        let want = solo.extend(&fresh, 3).unwrap();
        let got = &rows[ai * 4 * 4..(ai + 1) * 4 * 4];
        for (i, (x, y)) in want.iter().zip(got).enumerate() {
            assert_eq!(
                x.to_bits(),
                y.to_bits(),
                "sequence {ai} [{i}]: batch {y} vs single {x}"
            );
        }
    }
    // Per-sequence rollback after a parallel extend leaves consistent state.
    bs.rollback(1, 2).unwrap();
    assert_eq!(bs.len(0), 6);
    assert_eq!(bs.len(1), 8);
    assert_eq!(bs.len(2), 8);
}

fn fill(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.normal() as f32).collect()
}

fn assert_bits(a: &[f32], b: &[f32], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length mismatch");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: bit drift at [{i}]: {x} vs {y}");
    }
}

#[test]
fn simd_and_scalar_matmul_bitwise_identical_adversarial_shapes() {
    // Full (m, k, n) cross over shapes chosen to hit every remainder
    // path: 1–3 exercise the k-axis 4x-unroll tail and the n-axis SSE
    // 4-lane tail, 5/7/15/17 straddle chunk boundaries, 8/64 land
    // exactly on them. The SIMD kernel keeps the scalar kernel's exact
    // association — ((a0·b0 + a1·b1) + a2·b2) + a3·b3, no FMA — so the
    // comparison is bitwise, not tolerance-based. On targets without the
    // SIMD tier both runs take the scalar path and the wall is trivially
    // green, which is exactly the portability contract.
    let shapes = [1usize, 2, 3, 5, 7, 8, 15, 17, 64];
    let mut rng = Rng::new(41);
    for &m in &shapes {
        for &k in &shapes {
            for &n in &shapes {
                let a = fill(&mut rng, m * k);
                let b = fill(&mut rng, k * n);
                let mut fast = vec![0.0f32; m * n];
                let mut slow = vec![0.0f32; m * n];
                set_scalar_kernel(false);
                matmul(&a, &b, m, k, n, &mut fast);
                set_scalar_kernel(true);
                matmul(&a, &b, m, k, n, &mut slow);
                set_scalar_kernel(false);
                assert_bits(&fast, &slow, &format!("simd vs scalar ({m},{k},{n})"));
            }
        }
    }
    // The toggle itself must report the restored state.
    let _ = simd_kernel_active(); // platform-dependent value; call is the contract
}

#[test]
fn tiled_matmul_bitwise_equals_flat_dispatch() {
    // Cache-blocked tiling re-orders *loop nests*, never the per-element
    // accumulation: TILE_K is a multiple of the unroll chunk, so every
    // k-block boundary coincides with a chunk boundary and the running
    // sum visits products in the identical order. Shapes exercise
    // multi-tile m, k and n axes plus ragged edges; all sit below the
    // auto-tiling threshold so `matmul` takes the flat path and the
    // comparison is tiled-vs-flat, under both the SIMD and the scalar
    // inner kernel.
    let mut rng = Rng::new(42);
    for &(m, k, n) in
        &[(1usize, 7usize, 5usize), (3, 64, 48), (70, 40, 50), (3, 600, 200), (2, 100, 600), (5, 300, 260)]
    {
        let a = fill(&mut rng, m * k);
        let b = fill(&mut rng, k * n);
        for scalar in [false, true] {
            set_scalar_kernel(scalar);
            let mut flat = vec![0.0f32; m * n];
            let mut tiled = vec![0.0f32; m * n];
            matmul(&a, &b, m, k, n, &mut flat);
            matmul_tiled(&a, &b, m, k, n, &mut tiled);
            set_scalar_kernel(false);
            assert_bits(&flat, &tiled, &format!("tiled vs flat ({m},{k},{n}) scalar={scalar}"));
        }
    }
}

#[test]
fn stacked_matmul_bitwise_equals_looped_singles() {
    // The stacked batched GEMM fuses B same-shape (m, k, n) problems that
    // share one weight matrix into a single (B·m, k, n) call. Rows are
    // independent, so the fused form must equal the per-lane loop bit for
    // bit — including the case where B·m crosses the parallel-dispatch
    // threshold while a single lane's m does not (the row partition is
    // bit-stable, pinned above).
    let mut rng = Rng::new(43);
    for &bsz in &[1usize, 2, 4, 7] {
        for &(m, k, n) in &[(1usize, 3usize, 5usize), (4, 16, 8), (7, 33, 12), (10, 64, 33)] {
            let a = fill(&mut rng, bsz * m * k);
            let b = fill(&mut rng, k * n);
            let mut fused = vec![0.0f32; bsz * m * n];
            matmul_stacked(&a, &b, bsz, m, k, n, &mut fused).unwrap();
            for lane in 0..bsz {
                let mut solo = vec![0.0f32; m * n];
                matmul(&a[lane * m * k..(lane + 1) * m * k], &b, m, k, n, &mut solo);
                assert_bits(
                    &solo,
                    &fused[lane * m * n..(lane + 1) * m * n],
                    &format!("stacked lane {lane} of {bsz} ({m},{k},{n})"),
                );
            }
        }
    }
}

#[test]
fn lockstep_batched_extend_matches_serial_singles_bitwise() {
    // Equal-length sequences route through the stacked lockstep kernel —
    // one fused forward with per-lane KV append — instead of the
    // thread-pool fan-out. The output must still be bitwise what each
    // solo session computes (the fan-out case with unequal lengths is
    // pinned by `parallel_batched_verify_bit_stable_and_matches_singles`).
    let backend = NativeBackend::new(NativeModel::random("m", dims(), 22));
    let h1 = tokens(5, 41);
    let h2 = tokens(5, 42);
    let h3 = tokens(5, 43);
    let tasks: Vec<(&[f32], usize)> = vec![(&h1, 5), (&h2, 5), (&h3, 5)];
    let mut bs = backend.begin_cached_batch(&tasks).unwrap();
    let fresh = tokens(3, 44);
    let flat = [&fresh[..], &fresh[..], &fresh[..]].concat();
    let rows = bs.extend(&[0, 1, 2], &flat, 3).unwrap();
    for (ai, h) in [&h1, &h2, &h3].iter().enumerate() {
        let mut solo = backend.begin_cached(h, 5).unwrap();
        let want = solo.extend(&fresh, 3).unwrap();
        let got = &rows[ai * 4 * 4..(ai + 1) * 4 * 4];
        assert_bits(&want, got, &format!("lockstep sequence {ai}"));
    }
    for i in 0..3 {
        assert_eq!(bs.len(i), 8, "sequence {i} advanced by k");
    }
    // A second lockstep round from the advanced state stays aligned too.
    let rows2 = bs.extend(&[0, 1, 2], &flat, 3).unwrap();
    assert_eq!(rows2.len(), 3 * 4 * 4);
    assert!(rows2.iter().all(|v| v.is_finite()), "second lockstep round non-finite");
}

#[test]
fn prop_simd_and_stacked_identities_hold_on_random_shapes() {
    // Random (m, k) × (n, B): the SIMD kernel equals the scalar kernel
    // and the stacked GEMM equals its per-lane loop, bitwise, for shapes
    // the hand-picked crosses above may have missed.
    proptest_lite::check_with(
        proptest_lite::Config { cases: 60, seed: 0x51D0, max_shrink_rounds: 40 },
        &Pair(Pair(UsizeRange(1, 24), UsizeRange(1, 40)), Pair(UsizeRange(1, 24), UsizeRange(1, 8))),
        |&((m, k), (n, bsz))| {
            let mut rng = Rng::new((m * 1_000_000 + k * 10_000 + n * 100 + bsz) as u64);
            let a = fill(&mut rng, bsz * m * k);
            let b = fill(&mut rng, k * n);
            // SIMD vs scalar on lane 0.
            let mut fast = vec![0.0f32; m * n];
            let mut slow = vec![0.0f32; m * n];
            set_scalar_kernel(false);
            matmul(&a[..m * k], &b, m, k, n, &mut fast);
            set_scalar_kernel(true);
            matmul(&a[..m * k], &b, m, k, n, &mut slow);
            set_scalar_kernel(false);
            for (i, (x, y)) in fast.iter().zip(&slow).enumerate() {
                if x.to_bits() != y.to_bits() {
                    return Err(format!("simd/scalar drift ({m},{k},{n}) [{i}]"));
                }
            }
            // Stacked vs looped over all lanes.
            let mut fused = vec![0.0f32; bsz * m * n];
            matmul_stacked(&a, &b, bsz, m, k, n, &mut fused).map_err(|e| e.to_string())?;
            for lane in 0..bsz {
                let mut solo = vec![0.0f32; m * n];
                matmul(&a[lane * m * k..(lane + 1) * m * k], &b, m, k, n, &mut solo);
                for (i, (x, y)) in solo.iter().zip(&fused[lane * m * n..]).enumerate() {
                    if x.to_bits() != y.to_bits() {
                        return Err(format!("stacked drift lane {lane} ({m},{k},{n}) [{i}]"));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn pool_panic_is_an_error_not_a_hang() {
    let pool = ThreadPool::new(2);
    let err = pool
        .map_wait(3, |i| if i == 1 { panic!("kernel job exploded") } else { i })
        .unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("panicked"), "unexpected error text: {msg}");
    // Pool survives and still computes.
    assert_eq!(pool.map_wait(2, |i| i * 10).unwrap(), vec![0, 10]);
}
