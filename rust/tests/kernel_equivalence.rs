//! Equivalence suite for the kernel layer: the packed-weight / scratch-
//! arena / blocked-matmul path must reproduce the pre-kernel-layer
//! reference implementation (string-keyed lookups, per-call allocation,
//! naive matmul) within 1e-5, the parallel paths must be *bitwise*
//! identical to serial for every thread count, and the pool must surface
//! job panics instead of silently shrinking.

use stride::models::{Backend, BatchDecodeSession, DecodeSession, NativeBackend};
use stride::nn::{ModelDims, NativeModel};
use stride::util::proptest_lite::{self, Pair, UsizeRange};
use stride::util::rng::Rng;
use stride::util::tensor::{matmul, matmul_naive, matmul_parallel};
use stride::util::threadpool::ThreadPool;

const TOL: f32 = 1e-5;

fn dims() -> ModelDims {
    ModelDims { patch: 4, n_ctx: 32, d_model: 16, n_layers: 2, n_heads: 2, d_ff: 32 }
}

/// Same seed twice: one kernel-layer backend, one reference backend.
fn pair(seed: u64) -> (NativeBackend, NativeBackend) {
    let packed = NativeBackend::new(NativeModel::random("m", dims(), seed));
    let mut reference = NativeBackend::new(NativeModel::random("m", dims(), seed));
    reference.set_reference_kernel(true);
    (packed, reference)
}

fn tokens(n: usize, seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    (0..n * 4).map(|_| rng.normal() as f32).collect()
}

fn assert_close(a: &[f32], b: &[f32], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length mismatch");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert!((x - y).abs() < TOL, "{what}: [{i}] packed {x} vs reference {y}");
    }
}

#[test]
fn packed_forward_matches_string_keyed_reference() {
    let (packed, reference) = pair(1);
    for seed in 0..4u64 {
        for n in [1usize, 3, 13, 32] {
            let toks = tokens(n, 100 + seed);
            let a = packed.forward(&toks, n).unwrap();
            let b = reference.forward(&toks, n).unwrap();
            assert_close(&a, &b, &format!("forward seed {seed} n {n}"));
        }
    }
}

#[test]
fn arena_cached_matches_allocating_reference() {
    // Session prefill + extend + rollback + re-extend on both kernels.
    let (packed, reference) = pair(2);
    let toks = tokens(14, 7);
    let alt = tokens(4, 8);
    let mut sp = packed.begin_cached(&toks[..6 * 4], 6).unwrap();
    let mut sr = reference.begin_cached(&toks[..6 * 4], 6).unwrap();
    let a = sp.extend(&toks[6 * 4..14 * 4], 8).unwrap();
    let b = sr.extend(&toks[6 * 4..14 * 4], 8).unwrap();
    assert_close(&a, &b, "extend");
    sp.rollback(5).unwrap();
    sr.rollback(5).unwrap();
    let a = sp.extend(&alt, 4).unwrap();
    let b = sr.extend(&alt, 4).unwrap();
    assert_close(&a, &b, "rollback + re-extend");
    assert_close(&sp.tip_mean().unwrap(), &sr.tip_mean().unwrap(), "tip");
}

#[test]
fn prop_packed_equals_reference_over_random_splits() {
    // For random (n_hist, k): prefill n_hist then extend k must agree
    // between the kernel layer and the reference implementation.
    let (packed, reference) = pair(3);
    proptest_lite::check_with(
        proptest_lite::Config { cases: 30, seed: 0x7E57, max_shrink_rounds: 40 },
        &Pair(UsizeRange(1, 12), UsizeRange(1, 8)),
        |&(n_hist, k)| {
            let toks = tokens(n_hist + k, 3000 + (n_hist * 37 + k) as u64);
            let mut sp = packed
                .begin_cached(&toks[..n_hist * 4], n_hist)
                .map_err(|e| e.to_string())?;
            let mut sr = reference
                .begin_cached(&toks[..n_hist * 4], n_hist)
                .map_err(|e| e.to_string())?;
            let a = sp
                .extend(&toks[n_hist * 4..(n_hist + k) * 4], k)
                .map_err(|e| e.to_string())?;
            let b = sr
                .extend(&toks[n_hist * 4..(n_hist + k) * 4], k)
                .map_err(|e| e.to_string())?;
            for (i, (x, y)) in a.iter().zip(&b).enumerate() {
                if (x - y).abs() >= TOL {
                    return Err(format!("[{i}] packed {x} vs reference {y}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn blocked_matmul_matches_naive_within_tolerance() {
    let mut rng = Rng::new(11);
    for &(m, k, n) in &[(1usize, 16usize, 48usize), (7, 33, 12), (64, 128, 96)] {
        let a: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
        let b: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32).collect();
        let mut c0 = vec![0.0; m * n];
        let mut c1 = vec![0.0; m * n];
        matmul_naive(&a, &b, m, k, n, &mut c0);
        matmul(&a, &b, m, k, n, &mut c1);
        for (x, y) in c0.iter().zip(&c1) {
            assert!((x - y).abs() < 1e-4 * x.abs().max(1.0), "naive {x} vs blocked {y}");
        }
    }
}

#[test]
fn parallel_matmul_bit_stable_across_thread_counts() {
    // STRIDE_THREADS ∈ {1, 2, 8}: the row partition must not move a bit.
    let mut rng = Rng::new(12);
    let (m, k, n) = (53, 32, 48);
    let a: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
    let b: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32).collect();
    let mut serial = vec![0.0; m * n];
    matmul(&a, &b, m, k, n, &mut serial);
    for threads in [1usize, 2, 8] {
        let pool = ThreadPool::new(threads);
        let mut par = vec![0.0; m * n];
        matmul_parallel(&pool, &a, &b, m, k, n, &mut par);
        for (i, (x, y)) in serial.iter().zip(&par).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "bit drift at {i} with {threads} threads");
        }
    }
}

#[test]
fn parallel_batched_verify_bit_stable_and_matches_singles() {
    // The batched-verify fan-out must equal per-sequence single sessions
    // exactly — the per-sequence work runs the identical serial kernel on
    // whatever thread picks it up.
    let backend = NativeBackend::new(NativeModel::random("m", dims(), 21));
    let h1 = tokens(3, 31);
    let h2 = tokens(7, 32);
    let h3 = tokens(5, 33);
    let tasks: Vec<(&[f32], usize)> = vec![(&h1, 3), (&h2, 7), (&h3, 5)];
    let mut bs = backend.begin_cached_batch(&tasks).unwrap();
    let fresh = tokens(3, 34);
    let flat = [&fresh[..], &fresh[..], &fresh[..]].concat();
    let rows = bs.extend(&[0, 1, 2], &flat, 3).unwrap();
    for (ai, (h, n)) in [(&h1, 3usize), (&h2, 7), (&h3, 5)].iter().enumerate() {
        let mut solo = backend.begin_cached(h, *n).unwrap();
        let want = solo.extend(&fresh, 3).unwrap();
        let got = &rows[ai * 4 * 4..(ai + 1) * 4 * 4];
        for (i, (x, y)) in want.iter().zip(got).enumerate() {
            assert_eq!(
                x.to_bits(),
                y.to_bits(),
                "sequence {ai} [{i}]: batch {y} vs single {x}"
            );
        }
    }
    // Per-sequence rollback after a parallel extend leaves consistent state.
    bs.rollback(1, 2).unwrap();
    assert_eq!(bs.len(0), 6);
    assert_eq!(bs.len(1), 8);
    assert_eq!(bs.len(2), 8);
}

#[test]
fn pool_panic_is_an_error_not_a_hang() {
    let pool = ThreadPool::new(2);
    let err = pool
        .map_wait(3, |i| if i == 1 { panic!("kernel job exploded") } else { i })
        .unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("panicked"), "unexpected error text: {msg}");
    // Pool survives and still computes.
    assert_eq!(pool.map_wait(2, |i| i * 10).unwrap(), vec![0, 10]);
}
