//! Tree-speculation equivalence wall + invariants.
//!
//! 1. **The k = 1 equivalence wall.** `sd_generate_tree_from` at k = 1
//!    must reproduce the classic single-trajectory engine *bit for bit*:
//!    same RNG stream positions, same session-operation sequence, same
//!    emitted floats. Pinned across backends (analytic closed-form and
//!    native transformer) × cache on/off × {Practical, Lossless} ×
//!    {Mean, Sampled} × draft-source kinds × seeds × γ — including
//!    horizons that force repeated window slides. The wall is what makes
//!    k a safe knob: everything k > 1 does is pure extension, never a
//!    silent change to an existing decode.
//! 2. **Tree invariants** (proptest_lite):
//!    * `propose_k`'s branch 0 is the classic `propose` at the same
//!      stream position, and every branch is a well-formed γ-block;
//!    * every tree round commits at most γ patches and emits exactly
//!      `accepted + 1`, the decode fills the horizon exactly, and every
//!      proposal round verifies exactly k branches;
//!    * cache on/off bit-identity at any k — the fork-by-rollback used
//!      to share the committed prefix between branches leaves no KV
//!      residue behind.
//! 3. **The stacked-verify wall** (SIMD + stacked-GEMM PR). At k > 1 the
//!    native target can verify all k branch suffixes in ONE stacked
//!    forward against the shared-prefix KV instead of k extend/rollback
//!    round-trips. The sequential path is *retained as the reference*
//!    behind [`set_stacked_verify`]; toggling it must not move a bit —
//!    same patches, same per-round alphas/accepted/residual draws, same
//!    RNG stream positions — across emissions × draft kinds × window
//!    slides.

use stride::accept::AcceptancePolicy;
use stride::models::{AnalyticBackend, CacheMode, NativeBackend};
use stride::nn::model::tiny_model;
use stride::specdec::{
    make_source, sd_generate_from, sd_generate_tree_from, set_stacked_verify,
    stacked_verify_enabled, DraftConfig, DraftKind, Emission, SpecConfig, Variant,
};
use stride::util::proptest_lite::{check_with, Config, Gen};
use stride::util::rng::Rng;

fn cfg(
    gamma: usize,
    k: usize,
    sigma: f64,
    variant: Variant,
    emission: Emission,
    seed: u64,
) -> SpecConfig {
    SpecConfig {
        gamma,
        k,
        policy: AcceptancePolicy::new(sigma, 1.0),
        variant,
        seed,
        max_residual_draws: 10_000,
        emission,
        cache: CacheMode::On,
        draft: DraftConfig::default(),
        adaptive: None,
    }
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// Every (variant, emission) combo the engine accepts.
const COMBOS: &[(Variant, Emission)] = &[
    (Variant::Practical, Emission::Mean),
    (Variant::Practical, Emission::Sampled),
    (Variant::Lossless, Emission::Sampled),
];

/// Run the classic engine and the tree engine (forced through the tree
/// loop, k = 1) on fresh sources and assert bitwise + stats equality.
fn assert_wall(
    target: &dyn stride::models::Backend,
    draft: &dyn stride::models::Backend,
    hist: &[f32],
    n_hist: usize,
    horizon: usize,
    c: &SpecConfig,
    label: &str,
) {
    let mut s1 = make_source(&c.draft, draft).unwrap();
    let classic = sd_generate_from(target, s1.as_mut(), hist, n_hist, horizon, c).unwrap();
    let mut s2 = make_source(&c.draft, draft).unwrap();
    let tree = sd_generate_tree_from(target, s2.as_mut(), hist, n_hist, horizon, c).unwrap();
    assert_eq!(bits(&classic.patches), bits(&tree.patches), "{label}: patches diverged");
    assert_eq!(classic.stats.rounds, tree.stats.rounds, "{label}: rounds");
    assert_eq!(classic.stats.proposals, tree.stats.proposals, "{label}: proposals");
    assert_eq!(classic.stats.accepted, tree.stats.accepted, "{label}: accepted");
    assert_eq!(
        classic.stats.branches_verified, tree.stats.branches_verified,
        "{label}: branches_verified"
    );
    let cg: Vec<usize> = classic.rounds.iter().map(|r| r.gamma).collect();
    let tg: Vec<usize> = tree.rounds.iter().map(|r| r.gamma).collect();
    assert_eq!(cg, tg, "{label}: per-round gammas");
    assert!(
        tree.rounds.iter().all(|r| r.branches == 1),
        "{label}: a k = 1 decode recorded a multi-branch round"
    );
    // The per-round acceptance probabilities are part of the wall too:
    // identical streams must evaluate identical alphas.
    for (i, (rc, rt)) in classic.rounds.iter().zip(&tree.rounds).enumerate() {
        assert_eq!(rc.alphas, rt.alphas, "{label}: round {i} alphas");
        assert_eq!(rc.accepted, rt.accepted, "{label}: round {i} accepted");
        assert_eq!(rc.residual_draws, rt.residual_draws, "{label}: round {i} residual draws");
    }
}

#[test]
fn tree_k1_matches_classic_bitwise_analytic_full_matrix() {
    let t = AnalyticBackend::new("t", 2, 0.8, 0.1);
    let d = AnalyticBackend::new("d", 2, 0.7, 0.15);
    let hist = [0.5f32, -0.5, 0.2, 0.1, -0.3, 0.4];
    for &(variant, emission) in COMBOS {
        for cache in [CacheMode::On, CacheMode::Off] {
            for seed in [1u64, 7, 42] {
                for gamma in [1usize, 2, 3, 5] {
                    let mut c = cfg(gamma, 1, 0.5, variant, emission, seed);
                    c.cache = cache;
                    assert_wall(
                        &t,
                        &d,
                        &hist,
                        3,
                        13,
                        &c,
                        &format!("{variant:?}/{emission:?}/{cache:?} gamma {gamma} seed {seed}"),
                    );
                }
            }
        }
    }
}

#[test]
fn tree_k1_matches_classic_bitwise_across_draft_kinds() {
    let t = AnalyticBackend::new("t", 2, 0.8, 0.1);
    let d = AnalyticBackend::new("d", 2, 0.72, 0.12);
    let hist = [0.5f32, -0.5, 0.2, 0.1];
    for kind in DraftKind::all() {
        for &(variant, emission) in COMBOS {
            for seed in [3u64, 19] {
                let mut c = cfg(3, 1, 0.5, variant, emission, seed);
                c.draft.kind = kind;
                assert_wall(
                    &t,
                    &d,
                    &hist,
                    2,
                    11,
                    &c,
                    &format!("{kind:?}/{variant:?}/{emission:?} seed {seed}"),
                );
            }
        }
    }
}

#[test]
fn tree_k1_matches_classic_bitwise_native_with_window_slides() {
    // Real transformer pair with a tight context window: horizon 17 at
    // γ = 3 forces repeated eviction, so the wall also covers the slide
    // path (evict_to on both sessions mid-decode).
    let t = NativeBackend::new(tiny_model(31));
    let d = NativeBackend::new(tiny_model(32));
    let hist: Vec<f32> = (0..2 * 4).map(|i| (i as f32 * 0.2).sin()).collect();
    for &(variant, emission) in COMBOS {
        for cache in [CacheMode::On, CacheMode::Off] {
            let mut c = cfg(3, 1, 0.4, variant, emission, 11);
            c.cache = cache;
            assert_wall(
                &t,
                &d,
                &hist,
                2,
                17,
                &c,
                &format!("native {variant:?}/{emission:?}/{cache:?}"),
            );
        }
    }
}

// ---------------------------------------------------------------------------
// Tree invariants (proptest_lite).
// ---------------------------------------------------------------------------

/// One generated tree case: source kind, γ, k, horizon, history length,
/// seed, emission flavor.
#[derive(Clone, Debug)]
struct TreeCase {
    kind: usize, // index into DraftKind::all()
    gamma: usize,
    k: usize,
    horizon: usize,
    n_hist: usize,
    seed: u64,
    sampled: bool,
}

struct TreeGen;

impl Gen for TreeGen {
    type Value = TreeCase;
    fn generate(&self, rng: &mut Rng) -> TreeCase {
        TreeCase {
            kind: rng.below(DraftKind::all().len()),
            gamma: 1 + rng.below(4),
            k: 1 + rng.below(5),
            horizon: 1 + rng.below(16),
            n_hist: 1 + rng.below(3),
            seed: rng.next_u64(),
            sampled: rng.bernoulli(0.5),
        }
    }
    fn shrink(&self, v: &TreeCase) -> Vec<TreeCase> {
        let mut out = Vec::new();
        if v.k > 1 {
            out.push(TreeCase { k: v.k - 1, ..v.clone() });
        }
        if v.gamma > 1 {
            out.push(TreeCase { gamma: v.gamma - 1, ..v.clone() });
        }
        if v.horizon > 1 {
            out.push(TreeCase { horizon: v.horizon / 2, ..v.clone() });
        }
        out
    }
}

fn case_cfg(case: &TreeCase) -> SpecConfig {
    let emission = if case.sampled { Emission::Sampled } else { Emission::Mean };
    let mut c = cfg(case.gamma, case.k, 0.5, Variant::Practical, emission, case.seed);
    c.draft.kind = DraftKind::all()[case.kind];
    c
}

/// Invariant: `propose_k`'s branch 0 is the classic `propose` at the
/// same RNG stream position (fresh source, fresh stream), and every
/// branch is a well-formed γ-block of patch-sized rows.
#[test]
fn propose_k_branch0_is_classic_propose() {
    check_with(Config { cases: 200, seed: 0x7EE1, max_shrink_rounds: 100 }, &TreeGen, |case| {
        let p = 2usize;
        let backend = AnalyticBackend::new("d", p, 0.6, 0.2);
        let dcfg = DraftConfig { kind: DraftKind::all()[case.kind], ..DraftConfig::default() };
        let hist: Vec<f32> = (0..case.n_hist * p).map(|i| ((i as f32) * 0.3).sin()).collect();

        let mut s1 = make_source(&dcfg, &backend).map_err(|e| e.to_string())?;
        s1.begin(&hist, case.n_hist, CacheMode::On).map_err(|e| e.to_string())?;
        let mut r1 = Rng::new(case.seed);
        let classic = s1.propose(case.gamma, 0.5, &mut r1).map_err(|e| e.to_string())?;

        let mut s2 = make_source(&dcfg, &backend).map_err(|e| e.to_string())?;
        s2.begin(&hist, case.n_hist, CacheMode::On).map_err(|e| e.to_string())?;
        let mut r2 = Rng::new(case.seed);
        let blocks =
            s2.propose_k(case.gamma, case.k, 0.5, &mut r2).map_err(|e| e.to_string())?;

        if blocks.len() != case.k {
            return Err(format!("{} branches for k {}", blocks.len(), case.k));
        }
        for (j, b) in blocks.iter().enumerate() {
            if b.proposals.len() != case.gamma || b.mu_qs.len() != case.gamma {
                return Err(format!("branch {j}: block lengths != gamma {}", case.gamma));
            }
            if b.proposals.iter().chain(&b.mu_qs).any(|v| v.len() != p) {
                return Err(format!("branch {j}: patch-sized rows violated"));
            }
        }
        let b0 = &blocks[0];
        let same = b0
            .proposals
            .iter()
            .zip(&classic.proposals)
            .chain(b0.mu_qs.iter().zip(&classic.mu_qs))
            .all(|(a, b)| bits(a) == bits(b));
        if !same {
            return Err("branch 0 diverged from the classic propose".into());
        }
        Ok(())
    });
}

/// Invariants: a tree decode fills the horizon exactly; every proposal
/// round commits `accepted <= gamma` and emits `accepted + 1`; every
/// proposal round verifies exactly k branches; all output is finite.
#[test]
fn tree_round_structure_invariants_hold() {
    check_with(Config { cases: 200, seed: 0x7EE2, max_shrink_rounds: 100 }, &TreeGen, |case| {
        let p = 2usize;
        let t = AnalyticBackend::new("t", p, 0.8, 0.1);
        let d = AnalyticBackend::new("d", p, 0.6, 0.25);
        let c = case_cfg(case);
        let hist: Vec<f32> = (0..case.n_hist * p).map(|i| ((i as f32) * 0.3).cos()).collect();
        let mut src = make_source(&c.draft, &d).map_err(|e| e.to_string())?;
        let out = sd_generate_tree_from(&t, src.as_mut(), &hist, case.n_hist, case.horizon, &c)
            .map_err(|e| format!("{e:#}"))?;

        if out.patches.len() != case.horizon * p {
            return Err(format!("patches {} != horizon*p {}", out.patches.len(), case.horizon * p));
        }
        if !out.patches.iter().all(|v| v.is_finite()) {
            return Err("non-finite output".into());
        }
        let mut emitted = 0usize;
        for (i, r) in out.rounds.iter().enumerate() {
            if r.accepted > r.gamma {
                return Err(format!("round {i}: accepted {} > gamma {}", r.accepted, r.gamma));
            }
            if r.gamma == 0 {
                if r.emitted != 1 || r.branches != 1 {
                    return Err(format!("round {i}: malformed tail round"));
                }
            } else {
                if r.emitted != r.accepted + 1 {
                    return Err(format!("round {i}: emitted {} != accepted+1", r.emitted));
                }
                if r.branches != case.k {
                    return Err(format!("round {i}: branches {} != k {}", r.branches, case.k));
                }
                // All k branches scanned: at least one alpha each up to
                // k*gamma total.
                if r.alphas.len() < case.k || r.alphas.len() > case.k * r.gamma {
                    return Err(format!("round {i}: {} alphas for k {}", r.alphas.len(), case.k));
                }
            }
            emitted += r.emitted;
        }
        if emitted < case.horizon {
            return Err(format!("rounds emitted {emitted} < horizon {}", case.horizon));
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// The stacked-verify wall (k > 1).
// ---------------------------------------------------------------------------

/// One tree decode with the stacked-verify toggle forced to `on`,
/// restoring the default afterwards. The toggle is process-global and
/// sibling tests may flip it concurrently; that is *safe by the very
/// invariant under test* — both verify paths are bitwise identical, so
/// whichever path a round takes, the assertion below must hold.
fn tree_run(
    target: &dyn stride::models::Backend,
    draft: &dyn stride::models::Backend,
    hist: &[f32],
    n_hist: usize,
    horizon: usize,
    c: &SpecConfig,
    on: bool,
) -> stride::specdec::DecodeOutput {
    set_stacked_verify(on);
    let mut src = make_source(&c.draft, draft).unwrap();
    let out = sd_generate_tree_from(target, src.as_mut(), hist, n_hist, horizon, c).unwrap();
    set_stacked_verify(true);
    out
}

/// Full-strength comparison: emitted bits, round structure, acceptance
/// probabilities, and residual-draw counts — everything the RNG stream
/// touches — must match between the stacked and the sequential verify.
fn assert_stacked_wall(
    on: &stride::specdec::DecodeOutput,
    off: &stride::specdec::DecodeOutput,
    label: &str,
) {
    assert_eq!(bits(&on.patches), bits(&off.patches), "{label}: patches diverged");
    assert_eq!(on.stats.rounds, off.stats.rounds, "{label}: rounds");
    assert_eq!(on.stats.proposals, off.stats.proposals, "{label}: proposals");
    assert_eq!(on.stats.accepted, off.stats.accepted, "{label}: accepted");
    assert_eq!(
        on.stats.branches_verified, off.stats.branches_verified,
        "{label}: branches_verified"
    );
    for (i, (ra, rb)) in on.rounds.iter().zip(&off.rounds).enumerate() {
        assert_eq!(ra.gamma, rb.gamma, "{label}: round {i} gamma");
        assert_eq!(ra.branches, rb.branches, "{label}: round {i} branches");
        assert_eq!(ra.alphas, rb.alphas, "{label}: round {i} alphas");
        assert_eq!(ra.accepted, rb.accepted, "{label}: round {i} accepted");
        assert_eq!(ra.emitted, rb.emitted, "{label}: round {i} emitted");
        assert_eq!(ra.residual_draws, rb.residual_draws, "{label}: round {i} residual draws");
    }
}

#[test]
fn stacked_verify_bitwise_equals_sequential_native() {
    // Native (kernel-layer) target: the stacked path verifies k branch
    // suffixes in one batched forward against the shared-prefix KV;
    // sequential does k extend/rollback round-trips. Lossless is k = 1
    // only by construction, so the wall matrix is Practical × emissions.
    let t = NativeBackend::new(tiny_model(33));
    let d = NativeBackend::new(tiny_model(34));
    let hist: Vec<f32> = (0..3 * 4).map(|i| (i as f32 * 0.25).sin()).collect();
    for &k in &[2usize, 4] {
        for emission in [Emission::Mean, Emission::Sampled] {
            for seed in [5u64, 23] {
                let c = cfg(2, k, 0.4, Variant::Practical, emission, seed);
                let on = tree_run(&t, &d, &hist, 3, 12, &c, true);
                let off = tree_run(&t, &d, &hist, 3, 12, &c, false);
                assert_stacked_wall(&on, &off, &format!("k {k} {emission:?} seed {seed}"));
                assert!(
                    on.rounds.iter().any(|r| r.branches == k),
                    "k {k}: no multi-branch round was exercised"
                );
            }
        }
    }
}

#[test]
fn stacked_verify_bitwise_equals_sequential_across_draft_kinds() {
    let t = NativeBackend::new(tiny_model(35));
    let d = NativeBackend::new(tiny_model(36));
    let hist: Vec<f32> = (0..2 * 4).map(|i| (i as f32 * 0.3).cos()).collect();
    for kind in DraftKind::all() {
        for emission in [Emission::Mean, Emission::Sampled] {
            let mut c = cfg(3, 2, 0.5, Variant::Practical, emission, 17);
            c.draft.kind = kind;
            let on = tree_run(&t, &d, &hist, 2, 11, &c, true);
            let off = tree_run(&t, &d, &hist, 2, 11, &c, false);
            assert_stacked_wall(&on, &off, &format!("{kind:?}/{emission:?}"));
        }
    }
}

#[test]
fn stacked_verify_bitwise_equals_sequential_with_window_slides() {
    // Tight context + long horizon forces repeated eviction *before* the
    // verify stage (the engine slides to keep γ + 1 of headroom), so the
    // stacked forward must stay bit-identical across evict_to calls too —
    // the lanes rebuild against a moved prefix every slide.
    let t = NativeBackend::new(tiny_model(37));
    let d = NativeBackend::new(tiny_model(38));
    let hist: Vec<f32> = (0..2 * 4).map(|i| (i as f32 * 0.2).sin()).collect();
    for &k in &[2usize, 4] {
        let c = cfg(3, k, 0.4, Variant::Practical, Emission::Sampled, 11);
        let on = tree_run(&t, &d, &hist, 2, 17, &c, true);
        let off = tree_run(&t, &d, &hist, 2, 17, &c, false);
        assert_stacked_wall(&on, &off, &format!("window-slide k {k}"));
    }
}

#[test]
fn stacked_toggle_is_inert_at_k1_and_on_analytic_backends() {
    // k = 1 never enters the stacked branch, and analytic sessions
    // decline `verify_stacked` (default impl) — both must make the
    // toggle a no-op rather than an error.
    let t = AnalyticBackend::new("t", 2, 0.8, 0.1);
    let d = AnalyticBackend::new("d", 2, 0.7, 0.15);
    let hist = [0.5f32, -0.5, 0.2, 0.1];
    for &k in &[1usize, 3] {
        let c = cfg(2, k, 0.5, Variant::Practical, Emission::Sampled, 9);
        let on = tree_run(&t, &d, &hist, 2, 9, &c, true);
        let off = tree_run(&t, &d, &hist, 2, 9, &c, false);
        assert_stacked_wall(&on, &off, &format!("analytic k {k}"));
    }
    // NOTE: no assert on `stacked_verify_enabled()` here — sibling tests
    // flip the process-global toggle transiently in parallel, so its
    // instantaneous value is not observable race-free. Every helper
    // restores `true` on exit; the walls above are what the toggle owes.
    let _ = stacked_verify_enabled();
}

/// Invariant: cache on/off bit-identity at any k. The tree loop forks
/// branches off the shared committed prefix by `rollback(γ)`; if that
/// fork left any KV residue behind, the cached decode would diverge from
/// the stateless re-forward decode.
#[test]
fn tree_cache_on_off_bit_identity_any_k() {
    check_with(Config { cases: 120, seed: 0x7EE3, max_shrink_rounds: 100 }, &TreeGen, |case| {
        let p = 2usize;
        let t = AnalyticBackend::new("t", p, 0.8, 0.1);
        let d = AnalyticBackend::new("d", p, 0.65, 0.2);
        let hist: Vec<f32> = (0..case.n_hist * p).map(|i| ((i as f32) * 0.4).sin()).collect();
        let run = |cache: CacheMode| -> Result<Vec<u32>, String> {
            let mut c = case_cfg(case);
            c.cache = cache;
            let mut src = make_source(&c.draft, &d).map_err(|e| e.to_string())?;
            let out =
                sd_generate_tree_from(&t, src.as_mut(), &hist, case.n_hist, case.horizon, &c)
                    .map_err(|e| format!("{e:#}"))?;
            Ok(bits(&out.patches))
        };
        let on = run(CacheMode::On)?;
        let off = run(CacheMode::Off)?;
        if on != off {
            return Err("cache on/off diverged — branch fork left KV residue".into());
        }
        Ok(())
    });
}
