//! Fuzz-lite: random and adversarial byte inputs must never panic the JSON
//! parser, the HTTP request parser, or the protocol layer (they may only
//! return errors). Seeded, deterministic, shrunk via proptest_lite.

use stride::server::protocol::ForecastRequest;
use stride::util::json::Json;
use stride::util::proptest_lite::{check_with, Config, Gen};
use stride::util::rng::Rng;

/// Random byte soup with JSON-ish characters over-represented.
struct JsonishBytes;

impl Gen for JsonishBytes {
    type Value = Vec<u8>;
    fn generate(&self, rng: &mut Rng) -> Vec<u8> {
        let alphabet: &[u8] = br#"{}[]",:0123456789.eE+-truefalsenull \u00"#;
        let n = rng.below(200);
        (0..n)
            .map(|_| {
                if rng.bernoulli(0.9) {
                    alphabet[rng.below(alphabet.len())]
                } else {
                    rng.below(256) as u8
                }
            })
            .collect()
    }
    fn shrink(&self, v: &Vec<u8>) -> Vec<Vec<u8>> {
        if v.len() <= 1 {
            return vec![];
        }
        vec![v[..v.len() / 2].to_vec(), v[v.len() / 2..].to_vec()]
    }
}

#[test]
fn json_parser_never_panics() {
    check_with(Config { cases: 2000, seed: 0xF00D, max_shrink_rounds: 50 }, &JsonishBytes, |bytes| {
        if let Ok(s) = std::str::from_utf8(bytes) {
            let _ = Json::parse(s); // Ok or Err, never panic
        }
        Ok(())
    });
}

#[test]
fn protocol_never_panics_on_arbitrary_json() {
    // Valid JSON values of arbitrary shape must be rejected gracefully.
    struct ArbJson;
    impl Gen for ArbJson {
        type Value = String;
        fn generate(&self, rng: &mut Rng) -> String {
            fn val(rng: &mut Rng, depth: usize) -> String {
                match if depth > 2 { rng.below(4) } else { rng.below(6) } {
                    0 => "null".into(),
                    1 => format!("{}", rng.normal() * 100.0),
                    2 => format!("{}", rng.bernoulli(0.5)),
                    3 => format!("\"s{}\"", rng.below(100)),
                    4 => {
                        let n = rng.below(4);
                        let items: Vec<String> = (0..n).map(|_| val(rng, depth + 1)).collect();
                        format!("[{}]", items.join(","))
                    }
                    _ => {
                        let n = rng.below(4);
                        let items: Vec<String> = (0..n)
                            .map(|i| {
                                let keys = ["history", "horizon", "mode", "gamma", "sigma", "x"];
                                format!("\"{}\":{}", keys[(i + rng.below(6)) % 6], val(rng, depth + 1))
                            })
                            .collect();
                        format!("{{{}}}", items.join(","))
                    }
                }
            }
            val(rng, 0)
        }
    }
    check_with(Config { cases: 1500, seed: 0xBEE, max_shrink_rounds: 0 }, &ArbJson, |s| {
        if let Ok(j) = Json::parse(s) {
            let _ = ForecastRequest::from_json(&j); // must not panic
        }
        Ok(())
    });
}

#[test]
fn http_request_parser_survives_garbage_connections() {
    use std::io::Write;
    use std::sync::Arc;
    // Start a real server, throw garbage at the socket, then verify it
    // still serves a well-formed request.
    let server = stride::http::HttpServer::start(
        "127.0.0.1:0",
        2,
        Arc::new(|_req| stride::http::Response::text(200, "ok")),
    )
    .unwrap();
    let addr = server.addr.to_string();
    let mut rng = Rng::new(3);
    for _ in 0..30 {
        if let Ok(mut s) = std::net::TcpStream::connect(&addr) {
            let n = rng.below(100);
            let junk: Vec<u8> = (0..n).map(|_| rng.below(256) as u8).collect();
            let _ = s.write_all(&junk);
            // drop: abrupt close mid-request
        }
    }
    // Oversized Content-Length must be rejected without allocation blowup.
    if let Ok(mut s) = std::net::TcpStream::connect(&addr) {
        let _ = s.write_all(b"POST / HTTP/1.1\r\nContent-Length: 999999999999\r\n\r\n");
    }
    let r = stride::http::http_request(&addr, "GET", "/x", None).unwrap();
    assert_eq!(r.status, 200, "server survived garbage");
}
