//! Fuzz-lite: random and adversarial byte inputs must never panic the JSON
//! parser, the HTTP request parser, or the protocol layer (they may only
//! return errors). Seeded, deterministic, shrunk via proptest_lite.
//!
//! SIMD + stacked-GEMM PR: the stacked kernel tier joins the same
//! contract — mis-sized, zero-dim, overflowing, or over-wide stacked
//! requests are typed `Err`s (or graceful fallbacks at the session
//! layer), never UB and never a panic that could escape into the
//! replica supervisor's restart loop.

use stride::server::protocol::ForecastRequest;
use stride::util::json::Json;
use stride::util::proptest_lite::{check_with, Config, Gen};
use stride::util::rng::Rng;

/// Random byte soup with JSON-ish characters over-represented.
struct JsonishBytes;

impl Gen for JsonishBytes {
    type Value = Vec<u8>;
    fn generate(&self, rng: &mut Rng) -> Vec<u8> {
        let alphabet: &[u8] = br#"{}[]",:0123456789.eE+-truefalsenull \u00"#;
        let n = rng.below(200);
        (0..n)
            .map(|_| {
                if rng.bernoulli(0.9) {
                    alphabet[rng.below(alphabet.len())]
                } else {
                    rng.below(256) as u8
                }
            })
            .collect()
    }
    fn shrink(&self, v: &Vec<u8>) -> Vec<Vec<u8>> {
        if v.len() <= 1 {
            return vec![];
        }
        vec![v[..v.len() / 2].to_vec(), v[v.len() / 2..].to_vec()]
    }
}

#[test]
fn json_parser_never_panics() {
    check_with(Config { cases: 2000, seed: 0xF00D, max_shrink_rounds: 50 }, &JsonishBytes, |bytes| {
        if let Ok(s) = std::str::from_utf8(bytes) {
            let _ = Json::parse(s); // Ok or Err, never panic
        }
        Ok(())
    });
}

#[test]
fn protocol_never_panics_on_arbitrary_json() {
    // Valid JSON values of arbitrary shape must be rejected gracefully.
    struct ArbJson;
    impl Gen for ArbJson {
        type Value = String;
        fn generate(&self, rng: &mut Rng) -> String {
            fn val(rng: &mut Rng, depth: usize) -> String {
                match if depth > 2 { rng.below(4) } else { rng.below(6) } {
                    0 => "null".into(),
                    1 => format!("{}", rng.normal() * 100.0),
                    2 => format!("{}", rng.bernoulli(0.5)),
                    3 => format!("\"s{}\"", rng.below(100)),
                    4 => {
                        let n = rng.below(4);
                        let items: Vec<String> = (0..n).map(|_| val(rng, depth + 1)).collect();
                        format!("[{}]", items.join(","))
                    }
                    _ => {
                        let n = rng.below(4);
                        let items: Vec<String> = (0..n)
                            .map(|i| {
                                let keys = ["history", "horizon", "mode", "gamma", "sigma", "x"];
                                format!("\"{}\":{}", keys[(i + rng.below(6)) % 6], val(rng, depth + 1))
                            })
                            .collect();
                        format!("{{{}}}", items.join(","))
                    }
                }
            }
            val(rng, 0)
        }
    }
    check_with(Config { cases: 1500, seed: 0xBEE, max_shrink_rounds: 0 }, &ArbJson, |s| {
        if let Ok(j) = Json::parse(s) {
            let _ = ForecastRequest::from_json(&j); // must not panic
        }
        Ok(())
    });
}

#[test]
fn stacked_kernels_reject_malformed_shapes_with_errors_not_panics() {
    use stride::models::{DecodeSession, NativeBackend};
    use stride::nn::kernel::{matmul_stacked, MAX_STACK_LANES};
    use stride::nn::{ForwardScratch, KvCache, ModelDims, NativeModel, StackedLanes};

    // --- Raw stacked GEMM: every malformed shape is a typed error.
    let a = vec![0.25f32; 2 * 3 * 4];
    let b = vec![0.25f32; 4 * 5];
    let mut c = vec![0.0f32; 2 * 3 * 5];
    assert!(matmul_stacked(&a, &b, 2, 3, 4, 5, &mut c).is_ok(), "well-formed call");
    assert!(matmul_stacked(&a, &b, 0, 3, 4, 5, &mut c).is_err(), "zero batch");
    assert!(matmul_stacked(&a, &b, 2, 0, 4, 5, &mut c).is_err(), "zero m");
    assert!(matmul_stacked(&a, &b, 2, 3, 0, 5, &mut c).is_err(), "zero k");
    assert!(matmul_stacked(&a, &b, 2, 3, 4, 0, &mut c).is_err(), "zero n");
    assert!(matmul_stacked(&a[..1], &b, 2, 3, 4, 5, &mut c).is_err(), "short a");
    assert!(matmul_stacked(&a, &b[..1], 2, 3, 4, 5, &mut c).is_err(), "short b");
    assert!(matmul_stacked(&a, &b, 2, 3, 4, 5, &mut c[..1]).is_err(), "short c");
    assert!(
        matmul_stacked(&a, &b, usize::MAX, usize::MAX, 4, 5, &mut c).is_err(),
        "size overflow must error, not wrap"
    );

    // --- Stacked branch-verify forward: b/k bounds, lane cap, token
    // sizing, and context overflow are all typed errors.
    let dims = ModelDims { patch: 4, n_ctx: 16, d_model: 8, n_layers: 1, n_heads: 2, d_ff: 16 };
    let model = NativeModel::random("m", dims, 9);
    let mut cache = KvCache::new(&dims);
    let hist: Vec<f32> = (0..4 * 4).map(|i| (i as f32 * 0.1).sin()).collect();
    model.forward_cached(&mut cache, &hist, 4).unwrap();
    let mut lanes = StackedLanes::new();
    let toks = vec![0.25f32; 2 * 2 * 4]; // b = 2, k = 2
    assert!(model.forward_cached_stacked(&cache, &mut lanes, &toks, 2, 2).is_ok());
    assert!(model.forward_cached_stacked(&cache, &mut lanes, &toks, 0, 2).is_err(), "b = 0");
    assert!(model.forward_cached_stacked(&cache, &mut lanes, &toks, 2, 0).is_err(), "k = 0");
    let wide = vec![0.25f32; (MAX_STACK_LANES + 1) * 2 * 4];
    assert!(
        model.forward_cached_stacked(&cache, &mut lanes, &wide, MAX_STACK_LANES + 1, 2).is_err(),
        "k > scratch lanes (b over MAX_STACK_LANES)"
    );
    assert!(
        model.forward_cached_stacked(&cache, &mut lanes, &toks[..7], 2, 2).is_err(),
        "mis-sized token buffer"
    );
    let deep = vec![0.25f32; 2 * 13 * 4];
    assert!(
        model.forward_cached_stacked(&cache, &mut lanes, &deep, 2, 13).is_err(),
        "n0 + k past n_ctx"
    );

    // --- Lockstep fused forward: uneven lanes, empty lane sets, zero k,
    // mis-sized tokens, and an under-provisioned scratch all error; a
    // well-formed call still succeeds after every rejection.
    let mut c0 = KvCache::new(&dims);
    let mut c1 = KvCache::new(&dims);
    model.forward_cached(&mut c0, &hist, 4).unwrap();
    model.forward_cached(&mut c1, &hist[..3 * 4], 3).unwrap();
    let mut scratch = ForwardScratch::for_prefill(&dims, 4);
    assert!(
        model.forward_cached_lockstep(&mut [&mut c0, &mut c1], &mut scratch, &toks, 2).is_err(),
        "uneven lane lengths"
    );
    let mut none: Vec<&mut KvCache> = Vec::new();
    assert!(
        model.forward_cached_lockstep(&mut none, &mut scratch, &toks, 2).is_err(),
        "empty lane set"
    );
    model.forward_cached(&mut c1, &hist[3 * 4..4 * 4], 1).unwrap(); // even up
    assert!(
        model.forward_cached_lockstep(&mut [&mut c0, &mut c1], &mut scratch, &toks, 0).is_err(),
        "k = 0"
    );
    assert!(
        model
            .forward_cached_lockstep(&mut [&mut c0, &mut c1], &mut scratch, &toks[..5], 2)
            .is_err(),
        "mis-sized token buffer"
    );
    let mut tiny = ForwardScratch::for_prefill(&dims, 1);
    assert!(
        model.forward_cached_lockstep(&mut [&mut c0, &mut c1], &mut tiny, &toks, 2).is_err(),
        "scratch rows below b * k"
    );
    assert!(
        model.forward_cached_lockstep(&mut [&mut c0, &mut c1], &mut scratch, &toks, 2).is_ok(),
        "recovers after rejections"
    );

    // --- Session layer: mis-sizes are typed errors; requests the stacked
    // tier cannot serve (too many lanes, context overflow) degrade to the
    // sequential fallback (`Ok(false)`) so serving never sees a panic.
    let backend = NativeBackend::new(NativeModel::random("m", dims, 10));
    let mut sess = backend.begin_cached(&hist, 4).unwrap();
    let mut out = Vec::new();
    assert!(sess.verify_stacked(&toks, 0, 2, &mut out).is_err(), "b = 0");
    assert!(sess.verify_stacked(&toks, 2, 0, &mut out).is_err(), "k = 0");
    assert!(sess.verify_stacked(&toks[..5], 2, 2, &mut out).is_err(), "mis-sized branches");
    assert!(
        !sess.verify_stacked(&wide, MAX_STACK_LANES + 1, 2, &mut out).unwrap(),
        "over-wide request must decline, not panic"
    );
    assert!(
        !sess.verify_stacked(&deep, 2, 13, &mut out).unwrap(),
        "context-overflowing request must decline, not panic"
    );
    assert!(sess.verify_stacked(&toks, 2, 2, &mut out).unwrap(), "recovers after declines");
}

#[test]
fn http_request_parser_survives_garbage_connections() {
    use std::io::Write;
    use std::sync::Arc;
    // Start a real server, throw garbage at the socket, then verify it
    // still serves a well-formed request.
    let server = stride::http::HttpServer::start(
        "127.0.0.1:0",
        2,
        Arc::new(|_req| stride::http::Response::text(200, "ok")),
    )
    .unwrap();
    let addr = server.addr.to_string();
    let mut rng = Rng::new(3);
    for _ in 0..30 {
        if let Ok(mut s) = std::net::TcpStream::connect(&addr) {
            let n = rng.below(100);
            let junk: Vec<u8> = (0..n).map(|_| rng.below(256) as u8).collect();
            let _ = s.write_all(&junk);
            // drop: abrupt close mid-request
        }
    }
    // Oversized Content-Length must be rejected without allocation blowup.
    if let Ok(mut s) = std::net::TcpStream::connect(&addr) {
        let _ = s.write_all(b"POST / HTTP/1.1\r\nContent-Length: 999999999999\r\n\r\n");
    }
    let r = stride::http::http_request(&addr, "GET", "/x", None).unwrap();
    assert_eq!(r.status, 200, "server survived garbage");
}
