//! Earliest integration signal: the AOT-exported HLO compiles on the PJRT
//! CPU client and reproduces JAX numerics on the golden window.
//! Requires `make artifacts`. Skips (with a loud message) if absent.

use std::path::Path;

// Offline stub of the external `xla` crate (fails fast at client
// creation); swap for the real dependency to restore PJRT execution.
use stride::xla;

fn read_f32(path: &Path) -> Vec<f32> {
    let bytes = std::fs::read(path).unwrap();
    bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect()
}

#[test]
fn golden_target_forward_matches_jax() {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("target_fwd_b1.hlo.txt").exists() {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        return;
    }
    // Skip (loudly) when PJRT is unavailable — e.g. the offline stub of
    // the `xla` crate is in use; see `stride::xla`.
    let client = match xla::PjRtClient::cpu() {
        Ok(c) => c,
        Err(e) => {
            eprintln!("SKIP golden_target_forward_matches_jax: {e}");
            return;
        }
    };
    let proto =
        xla::HloModuleProto::from_text_file(dir.join("target_fwd_b1.hlo.txt").to_str().unwrap())
            .unwrap();
    let exe = client.compile(&xla::XlaComputation::from_proto(&proto)).unwrap();

    let input = read_f32(&dir.join("golden_input.bin"));
    assert_eq!(input.len(), 32 * 24);
    let lit = xla::Literal::vec1(&input).reshape(&[1, 32, 24]).unwrap();
    let out = exe.execute::<xla::Literal>(&[lit]).unwrap()[0][0]
        .to_literal_sync()
        .unwrap()
        .to_tuple1()
        .unwrap();
    let got = out.to_vec::<f32>().unwrap();
    let want = read_f32(&dir.join("golden_target_means.bin"));
    assert_eq!(got.len(), want.len());
    let max_err = got
        .iter()
        .zip(&want)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    eprintln!("golden forward max_err = {max_err:.3e}");
    assert!(max_err < 1e-4, "max_err {max_err} too large");
}
