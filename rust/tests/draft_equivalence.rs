//! Draft-source subsystem equivalence + invariants.
//!
//! 1. **Bit-identity of the refactor.** The engine now drives a pluggable
//!    `DraftSource`; the default `ModelDraft` must reproduce the
//!    pre-refactor two-session decode loop *bit for bit*. The pre-refactor
//!    loop is preserved verbatim below (`reference::sd_generate` /
//!    `reference::sd_generate_batch`, copied from the engine as it stood
//!    before this PR, fixed-γ path) and compared against the new engine
//!    across backends × cache modes × variants × emissions × seeds —
//!    including horizons that force window slides.
//! 2. **DraftSource invariants** (proptest_lite): a propose returns
//!    exactly γ proposals and γ means; a full round leaves the committed
//!    history untouched (the new context is exactly old context +
//!    committed + final patch — rolled-back proposals never leak); the
//!    adaptive head is deterministic under a fixed seed.

use stride::accept::AcceptancePolicy;
use stride::models::{AnalyticBackend, CacheMode, NativeBackend};
use stride::nn::model::tiny_model;
use stride::specdec::{
    sd_generate, sd_generate_batch, DraftConfig, Emission, SpecConfig, Variant,
};
use stride::util::proptest_lite::{check_with, Config, Gen};
use stride::util::rng::Rng;

fn cfg(gamma: usize, sigma: f64, variant: Variant, emission: Emission, seed: u64) -> SpecConfig {
    SpecConfig {
        gamma,
        k: 1,
        policy: AcceptancePolicy::new(sigma, 1.0),
        variant,
        seed,
        max_residual_draws: 10_000,
        emission,
        cache: CacheMode::On,
        draft: DraftConfig::default(),
        adaptive: None,
    }
}

/// The decode loops exactly as they stood before the draft-source
/// refactor (fixed-γ path), driving the draft as a second decode
/// session. Kept verbatim as the frozen equivalence baseline.
mod reference {
    use anyhow::Result;
    use stride::models::{begin_batch_session, begin_session, Backend};
    use stride::specdec::{Emission, SpecConfig, Variant};
    use stride::util::rng::Rng;

    /// What the equivalence assertions need from a decode.
    pub struct RefOutput {
        pub patches: Vec<f32>,
        pub rounds: usize,
        pub proposals: usize,
        pub accepted: usize,
        pub gammas: Vec<usize>,
    }

    fn emit_from_p(mu: &[f32], sigma: f64, emission: Emission, rng: &mut Rng) -> Vec<f32> {
        match emission {
            Emission::Sampled => {
                let mut buf = vec![0.0f32; mu.len()];
                rng.fill_normal_around(mu, sigma as f32, &mut buf);
                buf
            }
            Emission::Mean => mu.to_vec(),
        }
    }

    pub fn sd_generate(
        target: &dyn Backend,
        draft: &dyn Backend,
        history: &[f32],
        n_hist: usize,
        horizon: usize,
        cfg: &SpecConfig,
    ) -> Result<RefOutput> {
        let p = target.patch();
        let mut rng = Rng::new(cfg.seed);
        let mut t_sess = begin_session(target, cfg.cache, history, n_hist)?;
        let mut d_sess = begin_session(draft, cfg.cache, history, n_hist)?;
        let max_ctx = target.max_ctx().min(draft.max_ctx());
        let mut emitted = 0usize;
        let mut out = RefOutput {
            patches: Vec::with_capacity(horizon * p),
            rounds: 0,
            proposals: 0,
            accepted: 0,
            gammas: Vec::new(),
        };

        while emitted < horizon {
            let remaining = horizon - emitted;
            let gamma = cfg.gamma.min(remaining.saturating_sub(1));
            let policy = cfg.policy;

            let need = gamma + 1;
            let n_ctx_now = t_sess.len();
            if n_ctx_now + need > max_ctx {
                anyhow::ensure!(need < max_ctx, "gamma {gamma} cannot fit in max_ctx {max_ctx}");
                let keep = max_ctx - need;
                t_sess.evict_to(keep)?;
                d_sess.evict_to(keep)?;
            }

            if gamma == 0 {
                let mu_p = t_sess.tip_mean()?;
                let patch = emit_from_p(&mu_p, policy.sigma, cfg.emission, &mut rng);
                t_sess.append(&patch, 1)?;
                d_sess.append(&patch, 1)?;
                out.patches.extend_from_slice(&patch);
                emitted += 1;
                out.rounds += 1;
                out.gammas.push(0);
                continue;
            }

            // Draft proposes gamma patches autoregressively.
            let mut mu_q = d_sess.tip_mean()?;
            let mut proposals: Vec<Vec<f32>> = Vec::with_capacity(gamma);
            let mut mu_qs: Vec<Vec<f32>> = Vec::with_capacity(gamma);
            for i in 0..gamma {
                let mut x = vec![0.0f32; p];
                rng.fill_normal_around(&mu_q, policy.sigma as f32, &mut x);
                proposals.push(x);
                mu_qs.push(mu_q.clone());
                if i + 1 < gamma {
                    let rows = d_sess.extend(proposals.last().unwrap(), 1)?;
                    mu_q = rows[p..].to_vec();
                }
            }

            // One target pass validates all gamma+1 prefix conditionals.
            let mut flat = Vec::with_capacity(gamma * p);
            for x in &proposals {
                flat.extend_from_slice(x);
            }
            let val_rows = t_sess.extend(&flat, gamma)?;
            let mu_p_at = |i: usize| &val_rows[i * p..(i + 1) * p];

            // Acceptance scan.
            let mut accepted = 0usize;
            let mut rejected_at: Option<usize> = None;
            for i in 0..gamma {
                let a = policy.alpha(&proposals[i], mu_p_at(i), &mu_qs[i]);
                if a >= 1.0 || rng.uniform() < a {
                    accepted += 1;
                } else {
                    rejected_at = Some(i);
                    break;
                }
            }

            // Rewind to the accepted prefix, then emit per protocol.
            let keep_d = accepted.min(gamma - 1);
            match cfg.emission {
                Emission::Sampled => {
                    t_sess.rollback(gamma - accepted)?;
                    d_sess.rollback((gamma - 1) - keep_d)?;
                    if accepted > keep_d {
                        d_sess.append(proposals.last().unwrap(), 1)?;
                    }
                    for x in &proposals[..accepted] {
                        out.patches.extend_from_slice(x);
                    }
                }
                Emission::Mean => {
                    t_sess.rollback(gamma)?;
                    d_sess.rollback(gamma - 1)?;
                    let mut emit_flat = Vec::with_capacity(accepted * p);
                    for m in &mu_qs[..accepted] {
                        emit_flat.extend_from_slice(m);
                    }
                    if accepted > 0 {
                        t_sess.append(&emit_flat, accepted)?;
                        d_sess.append(&emit_flat, accepted)?;
                    }
                    out.patches.extend_from_slice(&emit_flat);
                }
            }

            let mut residual_draws = 0usize;
            let final_patch: Vec<f32> = match rejected_at {
                None => {
                    let mu = mu_p_at(gamma);
                    emit_from_p(mu, policy.sigma, cfg.emission, &mut rng)
                }
                Some(i) => {
                    let mu_p = mu_p_at(i);
                    match cfg.variant {
                        Variant::Practical => {
                            emit_from_p(mu_p, policy.sigma, cfg.emission, &mut rng)
                        }
                        Variant::Lossless => {
                            let mu_q = &mu_qs[i];
                            let sigma = policy.sigma;
                            let mut z = vec![0.0f32; p];
                            loop {
                                residual_draws += 1;
                                rng.fill_normal_around(mu_p, sigma as f32, &mut z);
                                let lqp =
                                    stride::gaussian::iso_log_ratio(&z, mu_q, mu_p, sigma);
                                let pi = 1.0 - lqp.min(0.0).exp();
                                if rng.uniform() < pi {
                                    break;
                                }
                                if residual_draws >= cfg.max_residual_draws {
                                    break;
                                }
                            }
                            z
                        }
                    }
                }
            };
            out.patches.extend_from_slice(&final_patch);
            t_sess.append(&final_patch, 1)?;
            d_sess.append(&final_patch, 1)?;
            emitted += accepted + 1;
            out.rounds += 1;
            out.proposals += gamma;
            out.accepted += accepted;
            out.gammas.push(gamma);
        }

        out.patches.truncate(horizon * p);
        Ok(out)
    }

    pub fn sd_generate_batch(
        target: &dyn Backend,
        draft: &dyn Backend,
        tasks: &[(&[f32], usize, usize)],
        cfg: &SpecConfig,
    ) -> Result<Vec<RefOutput>> {
        let p = target.patch();
        let max_ctx = target.max_ctx().min(draft.max_ctx());
        let sess_tasks: Vec<(&[f32], usize)> =
            tasks.iter().map(|(h, n, _)| (*h, *n)).collect();
        let mut t_bs = begin_batch_session(target, cfg.cache, &sess_tasks)?;
        let mut d_bs = begin_batch_session(draft, cfg.cache, &sess_tasks)?;

        struct Seq {
            out: RefOutput,
            horizon: usize,
            emitted: usize,
            rng: Rng,
        }
        let mut seqs: Vec<Seq> = tasks
            .iter()
            .enumerate()
            .map(|(i, (_, _, horizon))| Seq {
                out: RefOutput {
                    patches: Vec::with_capacity(horizon * p),
                    rounds: 0,
                    proposals: 0,
                    accepted: 0,
                    gammas: Vec::new(),
                },
                horizon: *horizon,
                emitted: 0,
                rng: Rng::new(cfg.seed.wrapping_add(i as u64 * 0x9E37_79B9)),
            })
            .collect();

        loop {
            let active: Vec<usize> =
                (0..seqs.len()).filter(|&i| seqs[i].emitted < seqs[i].horizon).collect();
            if active.is_empty() {
                break;
            }
            let a = active.len();
            let desired: Vec<usize> = active
                .iter()
                .map(|&i| {
                    cfg.gamma
                        .min((seqs[i].horizon - seqs[i].emitted).saturating_sub(1))
                })
                .collect();
            let gamma = desired.iter().copied().max().unwrap().max(1);

            for &i in &active {
                let n_now = t_bs.len(i);
                if n_now + gamma + 1 > max_ctx {
                    let keep = max_ctx - (gamma + 1);
                    t_bs.evict_to(i, keep)?;
                    d_bs.evict_to(i, keep)?;
                }
            }

            let mut mu_q = d_bs.tip_means(&active)?;
            let mut proposals: Vec<Vec<Vec<f32>>> = vec![Vec::new(); a];
            let mut mu_qs: Vec<Vec<Vec<f32>>> = vec![Vec::new(); a];
            for step in 0..gamma {
                let mut xs = vec![0.0f32; a * p];
                for (ai, &i) in active.iter().enumerate() {
                    let mq = &mu_q[ai * p..(ai + 1) * p];
                    seqs[i].rng.fill_normal_around(
                        mq,
                        cfg.policy.sigma as f32,
                        &mut xs[ai * p..(ai + 1) * p],
                    );
                    proposals[ai].push(xs[ai * p..(ai + 1) * p].to_vec());
                    mu_qs[ai].push(mq.to_vec());
                }
                if step + 1 < gamma {
                    let rows = d_bs.extend(&active, &xs, 1)?;
                    for ai in 0..a {
                        mu_q[ai * p..(ai + 1) * p]
                            .copy_from_slice(&rows[ai * 2 * p + p..(ai + 1) * 2 * p]);
                    }
                }
            }

            let mut flat = vec![0.0f32; a * gamma * p];
            for ai in 0..a {
                for (k, x) in proposals[ai].iter().enumerate() {
                    flat[ai * gamma * p + k * p..ai * gamma * p + (k + 1) * p]
                        .copy_from_slice(x);
                }
            }
            let val_rows = t_bs.extend(&active, &flat, gamma)?;

            for (ai, &i) in active.iter().enumerate() {
                let base = ai * (gamma + 1) * p;
                let mu_p_at = |k: usize| &val_rows[base + k * p..base + (k + 1) * p];
                let g_i = desired[ai];
                let mut accepted = 0usize;
                let mut rejected_at = None;
                for k in 0..g_i {
                    let alpha = cfg.policy.alpha(&proposals[ai][k], mu_p_at(k), &mu_qs[ai][k]);
                    if alpha >= 1.0 || seqs[i].rng.uniform() < alpha {
                        accepted += 1;
                    } else {
                        rejected_at = Some(k);
                        break;
                    }
                }

                let keep_d = accepted.min(gamma - 1);
                let mut emit: Vec<f32> = Vec::with_capacity((accepted + 1) * p);
                match cfg.emission {
                    Emission::Sampled => {
                        t_bs.rollback(i, gamma - accepted)?;
                        d_bs.rollback(i, (gamma - 1) - keep_d)?;
                        if accepted > keep_d {
                            d_bs.append(i, &proposals[ai][gamma - 1], 1)?;
                        }
                        for x in &proposals[ai][..accepted] {
                            emit.extend_from_slice(x);
                        }
                    }
                    Emission::Mean => {
                        t_bs.rollback(i, gamma)?;
                        d_bs.rollback(i, gamma - 1)?;
                        for m in &mu_qs[ai][..accepted] {
                            emit.extend_from_slice(m);
                        }
                        if accepted > 0 {
                            t_bs.append(i, &emit, accepted)?;
                            d_bs.append(i, &emit, accepted)?;
                        }
                    }
                }

                let mut residual_draws = 0usize;
                let final_mu: Vec<f32> = match rejected_at {
                    None => mu_p_at(g_i).to_vec(),
                    Some(k) => mu_p_at(k).to_vec(),
                };
                let final_patch = match (rejected_at, cfg.variant) {
                    (Some(k), Variant::Lossless) => {
                        let mu_q = &mu_qs[ai][k];
                        let sigma = cfg.policy.sigma;
                        let mut z = vec![0.0f32; p];
                        loop {
                            residual_draws += 1;
                            seqs[i].rng.fill_normal_around(&final_mu, sigma as f32, &mut z);
                            let lqp =
                                stride::gaussian::iso_log_ratio(&z, mu_q, &final_mu, sigma);
                            let pi = 1.0 - lqp.min(0.0).exp();
                            if seqs[i].rng.uniform() < pi
                                || residual_draws >= cfg.max_residual_draws
                            {
                                break;
                            }
                        }
                        z
                    }
                    _ => match cfg.emission {
                        Emission::Sampled => {
                            let mut z = vec![0.0f32; p];
                            seqs[i].rng.fill_normal_around(
                                &final_mu,
                                cfg.policy.sigma as f32,
                                &mut z,
                            );
                            z
                        }
                        Emission::Mean => final_mu,
                    },
                };
                emit.extend_from_slice(&final_patch);
                t_bs.append(i, &final_patch, 1)?;
                d_bs.append(i, &final_patch, 1)?;

                let take = (accepted + 1).min(seqs[i].horizon - seqs[i].emitted);
                seqs[i].out.patches.extend_from_slice(&emit[..take * p]);
                seqs[i].emitted += take;
                seqs[i].out.rounds += 1;
                seqs[i].out.proposals += g_i;
                seqs[i].out.accepted += accepted;
                seqs[i].out.gammas.push(g_i);
            }
        }

        Ok(seqs.into_iter().map(|s| s.out).collect())
    }
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// Every (variant, emission) combo the engine accepts.
const COMBOS: &[(Variant, Emission)] = &[
    (Variant::Practical, Emission::Mean),
    (Variant::Practical, Emission::Sampled),
    (Variant::Lossless, Emission::Sampled),
];

#[test]
fn model_draft_single_is_bit_identical_to_prerefactor_analytic() {
    let t = AnalyticBackend::new("t", 2, 0.8, 0.1);
    let d = AnalyticBackend::new("d", 2, 0.7, 0.15);
    let hist = [0.5f32, -0.5, 0.2, 0.1, -0.3, 0.4];
    for &(variant, emission) in COMBOS {
        for seed in [1u64, 7, 42] {
            for gamma in [1usize, 2, 3, 5] {
                let c = cfg(gamma, 0.5, variant, emission, seed);
                let new = sd_generate(&t, &d, &hist, 3, 13, &c).unwrap();
                let old = reference::sd_generate(&t, &d, &hist, 3, 13, &c).unwrap();
                assert_eq!(
                    bits(&new.patches),
                    bits(&old.patches),
                    "{variant:?}/{emission:?} gamma {gamma} seed {seed}: patches diverged"
                );
                assert_eq!(new.stats.rounds, old.rounds);
                assert_eq!(new.stats.proposals, old.proposals);
                assert_eq!(new.stats.accepted, old.accepted);
                let new_gammas: Vec<usize> = new.rounds.iter().map(|r| r.gamma).collect();
                assert_eq!(new_gammas, old.gammas);
            }
        }
    }
}

#[test]
fn model_draft_single_is_bit_identical_to_prerefactor_native() {
    // Real transformer pair with a tight window (n_ctx forces repeated
    // eviction at horizon 17), cached and uncached.
    let t = NativeBackend::new(tiny_model(31));
    let d = NativeBackend::new(tiny_model(32));
    let hist: Vec<f32> = (0..2 * 4).map(|i| (i as f32 * 0.2).sin()).collect();
    for &(variant, emission) in COMBOS {
        for cache in [CacheMode::On, CacheMode::Off] {
            let mut c = cfg(3, 0.4, variant, emission, 11);
            c.cache = cache;
            let new = sd_generate(&t, &d, &hist, 2, 17, &c).unwrap();
            let old = reference::sd_generate(&t, &d, &hist, 2, 17, &c).unwrap();
            assert_eq!(
                bits(&new.patches),
                bits(&old.patches),
                "{variant:?}/{emission:?}/{cache:?}: native patches diverged"
            );
            assert_eq!(new.stats.accepted, old.accepted);
            assert_eq!(new.stats.rounds, old.rounds);
        }
    }
}

#[test]
fn model_draft_batched_is_bit_identical_to_prerefactor() {
    let t = AnalyticBackend::new("t", 2, 0.8, 0.1);
    let d = AnalyticBackend::new("d", 2, 0.72, 0.12);
    let h1 = vec![0.5f32, -0.5];
    let h2 = vec![1.0f32, 0.0, 0.3, 0.3, -0.2, 0.6];
    let h3 = vec![0.1f32, 0.1];
    let tasks: Vec<(&[f32], usize, usize)> = vec![(&h1, 1, 9), (&h2, 3, 5), (&h3, 1, 1)];
    for &(variant, emission) in COMBOS {
        for seed in [3u64, 19] {
            let c = cfg(3, 0.5, variant, emission, seed);
            let new = sd_generate_batch(&t, &d, &tasks, &c).unwrap();
            let old = reference::sd_generate_batch(&t, &d, &tasks, &c).unwrap();
            assert_eq!(new.len(), old.len());
            for (i, (n, o)) in new.iter().zip(&old).enumerate() {
                assert_eq!(
                    bits(&n.patches),
                    bits(&o.patches),
                    "{variant:?}/{emission:?} seed {seed} seq {i}: patches diverged"
                );
                assert_eq!(n.stats.rounds, o.rounds, "seq {i}");
                assert_eq!(n.stats.proposals, o.proposals, "seq {i}");
                assert_eq!(n.stats.accepted, o.accepted, "seq {i}");
            }
        }
    }
}

#[test]
fn model_draft_batched_native_cached_and_uncached_match_prerefactor() {
    let t = NativeBackend::new(tiny_model(41));
    let d = NativeBackend::new(tiny_model(42));
    let h1: Vec<f32> = (0..2 * 4).map(|i| (i as f32 * 0.2).sin()).collect();
    let h2: Vec<f32> = (0..4 * 4).map(|i| (i as f32 * 0.3).cos()).collect();
    let tasks: Vec<(&[f32], usize, usize)> = vec![(&h1, 2, 11), (&h2, 4, 7)];
    for cache in [CacheMode::On, CacheMode::Off] {
        let mut c = cfg(3, 0.5, Variant::Practical, Emission::Sampled, 9);
        c.cache = cache;
        let new = sd_generate_batch(&t, &d, &tasks, &c).unwrap();
        let old = reference::sd_generate_batch(&t, &d, &tasks, &c).unwrap();
        for (i, (n, o)) in new.iter().zip(&old).enumerate() {
            assert_eq!(
                bits(&n.patches),
                bits(&o.patches),
                "{cache:?} seq {i}: native batched patches diverged"
            );
        }
    }
}

// ---------------------------------------------------------------------------
// DraftSource invariants (proptest_lite).
// ---------------------------------------------------------------------------

use stride::specdec::{make_source, AdaptiveResidualDraft, DraftKind, DraftSource, RoundFeedback};

/// One generated round case: source kind, γ, accepted prefix, history
/// length, seed.
#[derive(Clone, Debug)]
struct RoundCase {
    kind: usize, // 0 = model, 1 = extrap, 2 = adaptive
    gamma: usize,
    accepted: usize,
    n_hist: usize,
    seed: u64,
    sampled: bool,
}

struct RoundGen;

impl Gen for RoundGen {
    type Value = RoundCase;
    fn generate(&self, rng: &mut Rng) -> RoundCase {
        let gamma = 1 + rng.below(5);
        RoundCase {
            kind: rng.below(DraftKind::all().len()),
            gamma,
            accepted: rng.below(gamma + 1),
            n_hist: 1 + rng.below(4),
            seed: rng.next_u64(),
            sampled: rng.bernoulli(0.5),
        }
    }
    fn shrink(&self, v: &RoundCase) -> Vec<RoundCase> {
        let mut out = Vec::new();
        if v.gamma > 1 {
            out.push(RoundCase { gamma: v.gamma - 1, accepted: v.accepted.min(v.gamma - 1), ..v.clone() });
        }
        if v.accepted > 0 {
            out.push(RoundCase { accepted: 0, ..v.clone() });
        }
        out
    }
}

/// Drive one full propose/finish_round cycle on a fresh source and check
/// the structural invariants.
fn run_round_case(case: &RoundCase) -> Result<(), String> {
    let p = 2usize;
    let backend = AnalyticBackend::new("d", p, 0.6, 0.2);
    // Same factory the engine uses, so a future DraftKind automatically
    // joins this property's coverage via DraftKind::all().
    let dcfg = DraftConfig { kind: DraftKind::all()[case.kind], ..DraftConfig::default() };
    let mut boxed = make_source(&dcfg, &backend).map_err(|e| e.to_string())?;
    let src: &mut dyn DraftSource = boxed.as_mut();
    let hist: Vec<f32> = (0..case.n_hist * p).map(|i| ((i as f32) * 0.3).sin()).collect();
    src.begin(&hist, case.n_hist, CacheMode::On).map_err(|e| e.to_string())?;
    let committed_before = src.context().to_vec();
    let mut rng = Rng::new(case.seed);

    let block = src.propose(case.gamma, 0.5, &mut rng).map_err(|e| e.to_string())?;
    // Invariant 1: proposal block length == gamma, means aligned.
    if block.proposals.len() != case.gamma || block.mu_qs.len() != case.gamma {
        return Err(format!(
            "block lengths {}/{} != gamma {}",
            block.proposals.len(),
            block.mu_qs.len(),
            case.gamma
        ));
    }
    if block.proposals.iter().chain(&block.mu_qs).any(|v| v.len() != p) {
        return Err("patch-sized rows violated".into());
    }

    // Simulated verification outcome: accept `accepted`, commit per
    // protocol, one final patch.
    let committed: Vec<f32> = if case.sampled {
        block.proposals[..case.accepted].iter().flatten().copied().collect()
    } else {
        block.mu_qs[..case.accepted].iter().flatten().copied().collect()
    };
    let final_patch = vec![0.25f32; p];
    let target_means = vec![0.1f32; (case.gamma + 1) * p];
    let alphas = vec![0.9f64; case.accepted.min(case.gamma) + 1];
    src.finish_round(&RoundFeedback {
        gamma: case.gamma,
        accepted: case.accepted,
        alphas: &alphas,
        target_means: &target_means,
        committed: &committed,
        final_patch: &final_patch,
        sampled: case.sampled,
    })
    .map_err(|e| e.to_string())?;

    // Invariant 2: committed history is untouched and extended by exactly
    // committed + final — rolled-back proposals never leak into context.
    let ctx = src.context();
    let want_len = committed_before.len() + committed.len() + p;
    if ctx.len() != want_len {
        return Err(format!("context len {} != expected {}", ctx.len(), want_len));
    }
    if ctx[..committed_before.len()] != committed_before[..] {
        return Err("committed history prefix was mutated".into());
    }
    if ctx[committed_before.len()..committed_before.len() + committed.len()] != committed[..] {
        return Err("committed patches not appended verbatim".into());
    }
    if ctx[want_len - p..] != final_patch[..] {
        return Err("final patch not appended".into());
    }
    Ok(())
}

#[test]
fn draft_source_round_invariants_hold() {
    check_with(Config { cases: 300, seed: 0xD0A5, max_shrink_rounds: 100 }, &RoundGen, |case| {
        run_round_case(case)
    });
}

#[test]
fn adaptive_head_is_deterministic_under_fixed_seed() {
    // Two independent sources fed bit-identical streams must produce
    // bit-identical heads, proposals, and update counts — across many
    // random stream shapes.
    check_with(
        Config { cases: 60, seed: 0xD0A6, max_shrink_rounds: 50 },
        &RoundGen,
        |case| {
            let p = 2usize;
            let run = || -> Result<(Vec<u32>, usize, Vec<u32>), String> {
                let mut src = AdaptiveResidualDraft::new(p, 0.5);
                let hist: Vec<f32> =
                    (0..case.n_hist * p).map(|i| ((i as f32) * 0.3).cos()).collect();
                src.begin(&hist, case.n_hist, CacheMode::Off).map_err(|e| e.to_string())?;
                let mut rng = Rng::new(case.seed);
                let mut all_props = Vec::new();
                for _ in 0..4 {
                    let block =
                        src.propose(case.gamma, 0.5, &mut rng).map_err(|e| e.to_string())?;
                    all_props.extend(block.proposals.iter().flatten().map(|v| v.to_bits()));
                    let committed: Vec<f32> =
                        block.proposals[..case.accepted].iter().flatten().copied().collect();
                    src.finish_round(&RoundFeedback {
                        gamma: case.gamma,
                        accepted: case.accepted,
                        alphas: &vec![0.5; case.accepted.min(case.gamma) + 1],
                        target_means: &vec![0.2f32; (case.gamma + 1) * p],
                        committed: &committed,
                        final_patch: &vec![0.3f32; p],
                        sampled: true,
                    })
                    .map_err(|e| e.to_string())?;
                }
                Ok((
                    src.head().iter().map(|v| v.to_bits()).collect(),
                    src.updates(),
                    all_props,
                ))
            };
            let a = run()?;
            let b = run()?;
            if a != b {
                return Err("adaptive head diverged under identical seed/stream".into());
            }
            Ok(())
        },
    );
}
