//! Chaos suite for the fault-tolerance tentpole: seeded fault injection
//! driven through the full HTTP + scheduler + replica stack, proving
//! that every failure shape the [`stride::faultinject`] plan can emit is
//! absorbed with a *typed, terminal* response — no hangs, no served
//! NaNs, bounded recovery — and that with chaos disarmed the serving
//! path is byte-for-byte unchanged.
//!
//! Every test runs artifact-free over synthetic [`NativeBackend`]
//! replicas (`tiny_model`), so the suite exercises supervision, the
//! numeric guards, the speculation circuit breaker, and graceful drain
//! without any model artifacts present.

use std::sync::Arc;
use std::time::Duration;

use stride::config::ServeConfig;
use stride::http::http_request;
use stride::models::NativeBackend;
use stride::nn::model::tiny_model;
use stride::server::{ModelShape, ReplicaBuilder, ReplicaStacks, Server};
use stride::util::json::Json;

const SHAPE: ModelShape = ModelShape { patch: 4, n_ctx: 8 };

/// A replica builder over two synthetic models (same seeds on every
/// replica, so restarts rebind to identical weights).
fn builder(seed_t: u64, seed_d: u64) -> ReplicaBuilder {
    Arc::new(move |_r| {
        Ok(ReplicaStacks {
            target: Box::new(NativeBackend::new(tiny_model(seed_t))),
            draft: Box::new(NativeBackend::new(tiny_model(seed_d))),
        })
    })
}

fn base_cfg() -> ServeConfig {
    let mut cfg = ServeConfig::default();
    cfg.bind = "127.0.0.1:0".into();
    cfg.backend = "native".into();
    cfg
}

fn body(horizon: usize, seed: u64, mode: &str) -> String {
    let hist: Vec<String> = (0..16).map(|i| format!("{}", (i as f32 * 0.23).sin())).collect();
    format!(
        r#"{{"history": [{}], "horizon": {horizon}, "seed": {seed}, "mode": "{mode}"}}"#,
        hist.join(",")
    )
}

fn stats(addr: &str) -> Json {
    Json::parse(http_request(addr, "GET", "/stats", None).unwrap().body_str()).unwrap()
}

fn faults_block(addr: &str) -> Json {
    stats(addr).get("faults").expect("/stats must carry a faults block").clone()
}

/// Forecast values of a 200 response; panics unless every bit is finite.
fn finite_forecast(body: &str) -> Vec<f32> {
    let vals: Vec<f32> = Json::parse(body)
        .unwrap()
        .get("forecast")
        .expect("200 response must carry a forecast")
        .as_arr()
        .unwrap()
        .iter()
        .map(|v| v.as_f64().unwrap() as f32)
        .collect();
    assert!(
        vals.iter().all(|v| v.is_finite()),
        "served forecast carries a non-finite value: {vals:?}"
    );
    vals
}

/// An injected panic inside a speculative decode is invisible to the
/// client: the group goes down the supervisor's requeue-once path, the
/// replica restarts onto fresh stacks, and the retried request is
/// served. Recovery is observable in the supervision counters.
#[test]
fn sd_panic_is_requeued_and_served_after_restart() {
    let mut cfg = base_cfg();
    cfg.fault.enabled = true;
    cfg.fault.seed = 11;
    cfg.fault.p_panic = 1.0;
    cfg.fault.max_faults = 1; // exactly one panic, then quiescent
    let server = Server::start_with_builder(cfg, SHAPE, builder(101, 102)).unwrap();
    let addr = server.addr().to_string();

    let r = http_request(&addr, "POST", "/forecast", Some(body(4, 5, "sd").as_bytes())).unwrap();
    assert_eq!(r.status, 200, "requeue-once must absorb a single panic: {}", r.body_str());
    finite_forecast(r.body_str());

    let f = faults_block(&addr);
    assert_eq!(f.get("replica_restarts").unwrap().as_usize(), Some(1));
    assert_eq!(f.get("requeues").unwrap().as_usize(), Some(1));
    assert_eq!(f.get("replica_failures").unwrap().as_usize(), Some(0));
    let inj = f.get("injection").expect("armed plan must report injection counters");
    assert_eq!(inj.get("panics").unwrap().as_usize(), Some(1));
    assert_eq!(inj.get("exhausted").unwrap().as_bool(), Some(true));
}

/// A panic mid-way through a co-batched group of per-job AR decodes
/// fails exactly the job that owned the faulted forward (typed
/// `replica_failure`, HTTP 500) and requeues its innocent group-mates,
/// which are served after the restart.
#[test]
fn baseline_group_panic_fails_one_job_and_requeues_the_rest() {
    let mut cfg = base_cfg();
    cfg.max_batch = 4;
    cfg.max_wait_ms = 200; // a wide window, so the 4 requests co-batch
    cfg.fault.enabled = true;
    cfg.fault.seed = 12;
    cfg.fault.p_panic = 1.0;
    cfg.fault.max_faults = 1;
    let server = Server::start_with_builder(cfg, SHAPE, builder(103, 104)).unwrap();
    let addr = Arc::new(server.addr().to_string());

    let mut handles = Vec::new();
    for k in 0..4u64 {
        let addr = Arc::clone(&addr);
        handles.push(std::thread::spawn(move || {
            http_request(&addr, "POST", "/forecast", Some(body(3, k, "baseline").as_bytes()))
                .unwrap()
        }));
    }
    let responses: Vec<_> = handles.into_iter().map(|h| h.join().unwrap()).collect();

    let failed: Vec<_> = responses.iter().filter(|r| r.status == 500).collect();
    let served = responses.iter().filter(|r| r.status == 200).count();
    assert_eq!(failed.len(), 1, "exactly the decoding job fails typed");
    assert_eq!(served, 3, "group-mates are requeued and served");
    assert!(
        failed[0].body_str().contains("\"error_code\":\"replica_failure\""),
        "the poisoned job's error must be typed: {}",
        failed[0].body_str()
    );
    for r in &responses {
        if r.status == 200 {
            finite_forecast(r.body_str());
        }
    }
    let f = faults_block(&addr);
    assert_eq!(f.get("replica_restarts").unwrap().as_usize(), Some(1));
    assert_eq!(f.get("replica_failures").unwrap().as_usize(), Some(1));
    assert!(f.get("requeues").unwrap().as_usize().unwrap() >= 1, "group-mates requeued");
}

/// NaN-poisoned model outputs never reach a response: while the fault
/// budget lasts, decodes fail with a typed `internal` error whose
/// message names the non-finite output; once it is exhausted the same
/// request is served clean. No 200 ever carries a non-finite bit.
#[test]
fn nan_faults_become_typed_errors_never_served_values() {
    let mut cfg = base_cfg();
    cfg.fault.enabled = true;
    cfg.fault.seed = 13;
    cfg.fault.p_nan = 1.0;
    cfg.fault.max_faults = 3;
    let server = Server::start_with_builder(cfg, SHAPE, builder(105, 106)).unwrap();
    let addr = server.addr().to_string();

    let mut saw_typed_failure = false;
    for attempt in 0..20u64 {
        let r =
            http_request(&addr, "POST", "/forecast", Some(body(4, attempt, "sd").as_bytes()))
                .unwrap();
        match r.status {
            200 => {
                finite_forecast(r.body_str());
            }
            500 => {
                assert!(
                    r.body_str().contains("non-finite"),
                    "numeric failure must name the guard: {}",
                    r.body_str()
                );
                assert!(r.body_str().contains("\"error_code\":\"internal\""));
                saw_typed_failure = true;
            }
            other => panic!("unexpected status {other}: {}", r.body_str()),
        }
        let inj = faults_block(&addr).get("injection").unwrap().clone();
        if inj.get("exhausted").unwrap().as_bool() == Some(true) {
            break;
        }
    }
    assert!(saw_typed_failure, "the NaN budget must produce at least one typed failure");

    // Bounded recovery: the quiescent tail serves clean.
    let r = http_request(&addr, "POST", "/forecast", Some(body(4, 99, "sd").as_bytes())).unwrap();
    assert_eq!(r.status, 200, "post-exhaustion request must be served: {}", r.body_str());
    finite_forecast(r.body_str());

    let f = faults_block(&addr);
    assert!(f.get("numeric_faults").unwrap().as_usize().unwrap() >= 1);
    let inj = f.get("injection").unwrap();
    assert!(inj.get("nans").unwrap().as_usize().unwrap() >= 1);
    assert_eq!(f.get("replica_restarts").unwrap().as_usize(), Some(0), "NaNs don't restart");
}

/// Stalled forwards are absorbed transparently: the request completes,
/// the forecast is clean, and the injection counters show the stalls
/// actually happened.
#[test]
fn stall_faults_complete_with_clean_forecasts() {
    let mut cfg = base_cfg();
    cfg.fault.enabled = true;
    cfg.fault.seed = 14;
    cfg.fault.p_stall = 1.0;
    cfg.fault.stall_ms = 20;
    cfg.fault.max_faults = 3;
    let server = Server::start_with_builder(cfg, SHAPE, builder(107, 108)).unwrap();
    let addr = server.addr().to_string();

    for seed in 0..2u64 {
        let r =
            http_request(&addr, "POST", "/forecast", Some(body(3, seed, "sd").as_bytes())).unwrap();
        assert_eq!(r.status, 200, "stalls are transparent: {}", r.body_str());
        finite_forecast(r.body_str());
    }
    let inj = faults_block(&addr).get("injection").unwrap().clone();
    let stalls = inj.get("stalls").unwrap().as_usize().unwrap();
    assert!(stalls >= 1, "the plan must actually have stalled forwards");
    assert_eq!(inj.get("injected").unwrap().as_usize(), Some(stalls), "stall-only plan");
}

/// The speculation circuit breaker, end to end: a numeric fault trips
/// it open (speculation disabled, requests served pure-AR on the
/// target), the fallback horizons tick its cool-down into half-open,
/// and one healthy probe decode closes it again. Target and draft share
/// weights here, so probe acceptance is high by construction.
#[test]
fn breaker_trips_to_pure_ar_and_recovers_via_probes() {
    let mut cfg = base_cfg();
    cfg.adaptive = true;
    cfg.adaptive_cfg.breaker = true;
    cfg.adaptive_cfg.breaker_nf_trip = 1; // one numeric fault trips
    cfg.adaptive_cfg.breaker_cooldown = 2; // one fallback horizon reaches half-open
    cfg.adaptive_cfg.breaker_probes = 1; // one healthy probe re-closes
    cfg.fault.enabled = true;
    cfg.fault.seed = 15;
    cfg.fault.p_nan = 1.0;
    cfg.fault.max_faults = 1;
    let server = Server::start_with_builder(cfg, SHAPE, builder(77, 77)).unwrap();
    let addr = server.addr().to_string();

    let breaker = |addr: &str| -> (String, usize) {
        let b = faults_block(addr).get("breaker").expect("adaptive server reports breaker").clone();
        (
            b.get("state").unwrap().as_str().unwrap().to_string(),
            b.get("fallback_decodes").unwrap().as_usize().unwrap(),
        )
    };

    // 1. The poisoned decode fails typed and trips the breaker.
    let r = http_request(&addr, "POST", "/forecast", Some(body(4, 1, "sd").as_bytes())).unwrap();
    assert_eq!(r.status, 500, "poisoned decode fails typed: {}", r.body_str());
    assert!(r.body_str().contains("non-finite"));
    assert_eq!(breaker(&addr).0, "open", "numeric fault must trip the breaker");

    // 2. Open: served pure-AR on the target (no draft calls, alpha
    //    null), which ticks the cool-down past its budget.
    let r = http_request(&addr, "POST", "/forecast", Some(body(4, 2, "sd").as_bytes())).unwrap();
    assert_eq!(r.status, 200, "open breaker still serves: {}", r.body_str());
    finite_forecast(r.body_str());
    let j = Json::parse(r.body_str()).unwrap();
    assert_eq!(j.get("mode").unwrap().as_str(), Some("sd"));
    assert_eq!(j.get("draft_calls").unwrap().as_usize(), Some(0), "pure-AR fallback");
    assert_eq!(j.get("alpha_hat"), Some(&Json::Null), "no acceptance stats without speculation");
    let (state, fallbacks) = breaker(&addr);
    assert_eq!(state, "half_open", "fallback horizons tick the cool-down");
    assert!(fallbacks >= 1);

    // 3. Half-open: a healthy probe decode (shared weights -> alpha = 1)
    //    closes the breaker; speculation is back.
    let r = http_request(&addr, "POST", "/forecast", Some(body(4, 3, "sd").as_bytes())).unwrap();
    assert_eq!(r.status, 200, "{}", r.body_str());
    finite_forecast(r.body_str());
    assert_eq!(breaker(&addr).0, "closed", "healthy probes must re-close the breaker");

    let b = faults_block(&addr).get("breaker").unwrap().clone();
    assert_eq!(b.get("trips").unwrap().as_usize(), Some(1));
    // The gauge tells the same story on the scrape surface.
    let m = http_request(&addr, "GET", "/metrics", None).unwrap().body_str().to_string();
    assert!(m.contains("stride_breaker_state 0"), "closed again at scrape time:\n{m}");
    assert!(m.contains("stride_breaker_trips 1"), "one trip recorded:\n{m}");
}

/// Graceful drain: `begin_drain` flips `/healthz` to a not-ready
/// `"draining"` report, new admissions get a typed 503, queued work is
/// allowed to finish, and `Server::drain` confirms an empty queue
/// within its budget.
#[test]
fn drain_refuses_new_work_and_empties_the_queue() {
    let mut server = Server::start_with_builder(base_cfg(), SHAPE, builder(109, 110)).unwrap();
    let addr = Arc::new(server.addr().to_string());

    // Healthy before the drain.
    let h = http_request(&addr, "GET", "/healthz", None).unwrap();
    assert_eq!(h.status, 200, "{}", h.body_str());

    // A few in-flight requests race the drain; each must end typed —
    // served if admitted before the flip, `draining` after it.
    let mut handles = Vec::new();
    for k in 0..3u64 {
        let addr = Arc::clone(&addr);
        handles.push(std::thread::spawn(move || {
            http_request(&addr, "POST", "/forecast", Some(body(3, k, "sd").as_bytes())).unwrap()
        }));
    }
    std::thread::sleep(Duration::from_millis(30));
    server.handle.begin_drain();

    // New work is refused with the typed drain error...
    let r = http_request(&addr, "POST", "/forecast", Some(body(3, 9, "sd").as_bytes())).unwrap();
    assert_eq!(r.status, 503, "{}", r.body_str());
    assert!(r.body_str().contains("\"error_code\":\"draining\""));
    // ...and /healthz reports the drain.
    let h = http_request(&addr, "GET", "/healthz", None).unwrap();
    assert_eq!(h.status, 503);
    assert!(h.body_str().contains("draining"));
    let f = faults_block(&addr);
    assert_eq!(f.get("draining").unwrap().as_bool(), Some(true));

    for h in handles {
        let r = h.join().unwrap();
        assert!(
            r.status == 200 || (r.status == 503 && r.body_str().contains("draining")),
            "in-flight requests end typed: {} {}",
            r.status,
            r.body_str()
        );
    }
    assert!(
        server.drain(Duration::from_secs(10)),
        "an idle queue must drain inside the budget"
    );
}

/// The chaos gate is absolute: a config that carries fault knobs but
/// `enabled: false` serves bit-identical forecasts to a config with no
/// fault plan at all (same models, same seeds).
#[test]
fn disabled_fault_config_is_bit_identical_to_no_fault_config() {
    let plain = Server::start_with_builder(base_cfg(), SHAPE, builder(31, 32)).unwrap();
    let mut cfg = base_cfg();
    cfg.fault.p_panic = 0.5;
    cfg.fault.p_nan = 0.5;
    cfg.fault.enabled = false; // knobs present, chaos disarmed
    let disarmed = Server::start_with_builder(cfg, SHAPE, builder(31, 32)).unwrap();

    let req = body(5, 42, "sd");
    let bits = |srv: &Server| -> Vec<u32> {
        let addr = srv.addr().to_string();
        let r = http_request(&addr, "POST", "/forecast", Some(req.as_bytes())).unwrap();
        assert_eq!(r.status, 200, "{}", r.body_str());
        finite_forecast(r.body_str()).iter().map(|v| v.to_bits()).collect()
    };
    assert_eq!(bits(&plain), bits(&disarmed), "enabled: false must be byte-for-byte clean");

    // And the disarmed server reports no injection surface at all.
    let f = faults_block(&disarmed.addr().to_string());
    assert_eq!(f.get("injection"), Some(&Json::Null));
}
