//! Cross-layer integration: the same function computed three ways must
//! agree — JAX (golden export), PJRT execution of the HLO artifact, and the
//! native Rust forward over the dumped weights. Also validates the Pallas
//! artifact flavor and the exported acceptance kernel against the Rust
//! acceptance implementation.
//!
//! All tests skip loudly when artifacts are missing (`make artifacts`).

use std::path::PathBuf;

use stride::accept::AcceptancePolicy;
use stride::models::{Backend, NativeBackend, XlaBackend};
use stride::runtime::{Engine, Manifest};

fn artifacts() -> Option<PathBuf> {
    let dir = stride::artifacts_dir();
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: run `make artifacts`");
        None
    }
}

fn read_f32(path: &std::path::Path) -> Vec<f32> {
    std::fs::read(path)
        .unwrap()
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
        .collect()
}

fn max_err(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
}

#[test]
fn three_way_parity_target() {
    let Some(dir) = artifacts() else { return };
    let manifest = Manifest::load(&dir).unwrap();
    let golden_in = read_f32(&dir.join("golden_input.bin"));
    let golden_out = read_f32(&dir.join("golden_target_means.bin"));

    // 1. JAX golden vs PJRT.
    let Ok(mut engine) = Engine::cpu() else {
            eprintln!("SKIP: PJRT unavailable (offline xla stub?)");
            return;
        };
    let xla = XlaBackend::load(&mut engine, &manifest, "target", "fused").unwrap();
    let got_xla = xla.forward(&golden_in, manifest.n_ctx).unwrap();
    let e1 = max_err(&got_xla, &golden_out);
    eprintln!("target XLA vs JAX golden: max_err {e1:.2e}");
    assert!(e1 < 1e-4);

    // 2. Native Rust vs JAX golden.
    let native = NativeBackend::from_entry(&manifest.target).unwrap();
    let got_native = native.forward(&golden_in, manifest.n_ctx).unwrap();
    let e2 = max_err(&got_native, &golden_out);
    eprintln!("target native vs JAX golden: max_err {e2:.2e}");
    assert!(e2 < 5e-4, "native forward drifted: {e2}");
}

#[test]
fn three_way_parity_draft() {
    let Some(dir) = artifacts() else { return };
    let manifest = Manifest::load(&dir).unwrap();
    let golden_in = read_f32(&dir.join("golden_input.bin"));
    let golden_out = read_f32(&dir.join("golden_draft_means.bin"));

    let Ok(mut engine) = Engine::cpu() else {
            eprintln!("SKIP: PJRT unavailable (offline xla stub?)");
            return;
        };
    let xla = XlaBackend::load(&mut engine, &manifest, "draft", "fused").unwrap();
    assert!(max_err(&xla.forward(&golden_in, manifest.n_ctx).unwrap(), &golden_out) < 1e-4);

    let native = NativeBackend::from_entry(&manifest.draft).unwrap();
    assert!(max_err(&native.forward(&golden_in, manifest.n_ctx).unwrap(), &golden_out) < 5e-4);
}

#[test]
fn pallas_artifact_matches_fused() {
    // The L1 kernel lowered through interpret-mode Pallas must compute the
    // same function as the fused XLA attention.
    let Some(dir) = artifacts() else { return };
    let manifest = Manifest::load(&dir).unwrap();
    let Ok(mut engine) = Engine::cpu() else {
            eprintln!("SKIP: PJRT unavailable (offline xla stub?)");
            return;
        };
    let fused = XlaBackend::load(&mut engine, &manifest, "target", "fused").unwrap();
    let pallas = XlaBackend::load(&mut engine, &manifest, "target", "pallas").unwrap();
    let input = read_f32(&dir.join("golden_input.bin"));
    let a = fused.forward(&input, manifest.n_ctx).unwrap();
    let b = pallas.forward(&input, manifest.n_ctx).unwrap();
    let e = max_err(&a, &b);
    eprintln!("pallas vs fused: max_err {e:.2e}");
    assert!(e < 1e-3, "pallas kernel drifted from fused attention: {e}");
}

#[test]
fn batch_variant_consistency() {
    // b=8/b=32 artifacts must agree with b=1 on shared rows.
    let Some(dir) = artifacts() else { return };
    let manifest = Manifest::load(&dir).unwrap();
    let Ok(mut engine) = Engine::cpu() else {
            eprintln!("SKIP: PJRT unavailable (offline xla stub?)");
            return;
        };
    let xla = XlaBackend::load(&mut engine, &manifest, "draft", "fused").unwrap();
    let p = manifest.patch;
    let n = manifest.n_ctx;
    let one: Vec<f32> = (0..n * p).map(|i| (i as f32 * 0.013).sin()).collect();
    let single = xla.forward(&one, n).unwrap();
    // Duplicate the row 5 times; batched result rows must equal the single.
    let mut batch = Vec::new();
    for _ in 0..5 {
        batch.extend_from_slice(&one);
    }
    let out = xla.forward_batch(&batch, 5, n).unwrap();
    for r in 0..5 {
        let row = &out[r * n * p..(r + 1) * n * p];
        let e = max_err(row, &single);
        assert!(e < 1e-4, "batch row {r} differs from single: {e}");
    }
}

#[test]
fn accept_kernel_artifact_matches_rust() {
    // The exported Pallas acceptance kernel vs the native Rust hot-path
    // implementation of Eq. 7/8.
    let Some(dir) = artifacts() else { return };
    let x = read_f32(&dir.join("golden_accept_x.bin"));
    let mu_p = read_f32(&dir.join("golden_accept_mu_p.bin"));
    let mu_q = read_f32(&dir.join("golden_accept_mu_q.bin"));
    let want_alpha = read_f32(&dir.join("golden_accept_alpha.bin"));
    let (b, d) = (32usize, 24usize);
    let policy = AcceptancePolicy::new(0.5, 1.0);
    for i in 0..b {
        let s = i * d..(i + 1) * d;
        let a = policy.alpha(&x[s.clone()], &mu_p[s.clone()], &mu_q[s.clone()]) as f32;
        assert!(
            (a - want_alpha[i]).abs() < 1e-4,
            "row {i}: rust alpha {a} vs pallas-golden {}",
            want_alpha[i]
        );
    }
}

#[test]
fn sd_decode_runs_end_to_end_on_xla() {
    // Full SD decode over the production backend on a real window.
    let Some(dir) = artifacts() else { return };
    let manifest = Manifest::load(&dir).unwrap();
    let Ok(mut engine) = Engine::cpu() else {
            eprintln!("SKIP: PJRT unavailable (offline xla stub?)");
            return;
        };
    let target = XlaBackend::load(&mut engine, &manifest, "target", "fused").unwrap();
    let draft = XlaBackend::load(&mut engine, &manifest, "draft", "fused").unwrap();

    let data = stride::data::Dataset::by_name("etth1").unwrap();
    let ws = stride::data::eval_windows(&data, manifest.patch, 4, 4, 96, 3);
    let cfg = stride::specdec::SpecConfig::default();
    for w in &ws {
        let out = stride::specdec::sd_generate(&target, &draft, &w.history, 4, 4, &cfg).unwrap();
        assert_eq!(out.patches.len(), 4 * manifest.patch);
        assert!(out.patches.iter().all(|v| v.is_finite()));
        assert!(out.stats.alpha_hat() > 0.0, "some acceptance expected");
    }
}
