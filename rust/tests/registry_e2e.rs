//! End-to-end registry + live-swap tests: the content-addressed model
//! registry over real HTTP (push → pull bit-identity, typed corruption
//! rejection) and the replica pool's live weight swap (zero dropped
//! requests under concurrent load; post-swap responses bit-identical to
//! a cold start on the new manifest).
//!
//! Everything here runs artifact-free: model pairs are `tiny_model`
//! synthetics published into throwaway registries under the system temp
//! dir, registry-hosting servers come up via `Server::start_with_builder`
//! (no artifacts manifest on disk), and registry-*booted* servers use
//! `ServeConfig::registry_model` to serve a published pair directly.

use std::sync::Arc;

use stride::config::ServeConfig;
use stride::faultinject::{FaultConfig, FaultPlan};
use stride::http::{http_request, RetryPolicy};
use stride::models::NativeBackend;
use stride::nn::model::tiny_model;
use stride::registry::{
    load_pair, publish_pair, pull_model, push_model, sha256_hex, Registry, RegistryError,
};
use stride::server::{ModelShape, ReplicaBuilder, ReplicaStacks, Server};
use stride::util::json::Json;
use stride::util::tensor::Tensor;

fn fresh_registry(tag: &str) -> Registry {
    let root = std::env::temp_dir().join(format!("stride_registry_e2e_{tag}"));
    let _ = std::fs::remove_dir_all(&root);
    Registry::open(&root).unwrap()
}

fn tiny_shape() -> ModelShape {
    ModelShape { patch: 4, n_ctx: 8 }
}

fn tiny_builder() -> ReplicaBuilder {
    Arc::new(move |_r| {
        Ok(ReplicaStacks {
            target: Box::new(NativeBackend::new(tiny_model(901))),
            draft: Box::new(NativeBackend::new(tiny_model(902))),
        })
    })
}

fn base_cfg() -> ServeConfig {
    let mut cfg = ServeConfig::default();
    cfg.bind = "127.0.0.1:0".into();
    cfg.backend = "native".into();
    cfg.replicas = 2;
    cfg.http_workers = 16;
    cfg.max_batch = 4;
    cfg.max_wait_ms = 5;
    cfg
}

/// A synthetic-model server that also hosts a registry under `tag`'s
/// temp dir: the push/pull/route tests need registry routes, not a
/// registry-loaded model.
fn registry_host(tag: &str) -> (Server, Registry) {
    let reg = fresh_registry(tag);
    let mut cfg = base_cfg();
    cfg.registry_dir = Some(reg.root().to_path_buf());
    let server =
        Server::start_with_builder(cfg, tiny_shape(), tiny_builder()).expect("registry host");
    (server, reg)
}

/// A server booted *from* the registry: `reference` is resolved,
/// verified, zero-copy-loaded, and served under its manifest digest.
fn registry_booted(reg: &Registry, reference: &str) -> Server {
    let mut cfg = base_cfg();
    cfg.registry_dir = Some(reg.root().to_path_buf());
    cfg.registry_model = Some(reference.to_string());
    Server::start(cfg).expect("registry-booted server")
}

fn hist_json() -> String {
    let h: Vec<String> = (0..16).map(|i| format!("{}", ((i as f32) * 0.23).sin())).collect();
    format!("[{}]", h.join(","))
}

fn forecast_bits(addr: &str, seed: u64) -> Vec<u32> {
    let body = format!(
        r#"{{"history": {}, "horizon": 8, "gamma": 2, "seed": {seed}}}"#,
        hist_json()
    );
    let r = http_request(addr, "POST", "/forecast", Some(body.as_bytes())).unwrap();
    assert_eq!(r.status, 200, "{}", r.body_str());
    let j = Json::parse(r.body_str()).unwrap();
    j.get("forecast")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|v| (v.as_f64().unwrap() as f32).to_bits())
        .collect()
}

#[test]
fn push_pull_roundtrip_is_bit_identical_over_http() {
    let source = fresh_registry("push_src");
    let digest = publish_pair(&source, "m", "v1", &tiny_model(801), &tiny_model(802)).unwrap();

    let (server, _host_reg) = registry_host("push_srv");
    let addr = server.addr().to_string();
    let policy = RetryPolicy::default();

    let pushed = push_model(&addr, &source, "m:v1", &policy).unwrap();
    assert_eq!(pushed, digest, "server must acknowledge the same content address");

    // The tag listing and the content address both resolve over HTTP;
    // the served manifest bytes are canonical (they re-hash to the
    // address they were fetched by).
    let r = http_request(&addr, "GET", "/v1/models", None).unwrap();
    assert_eq!(r.status, 200, "{}", r.body_str());
    assert!(r.body_str().contains("m:v1"), "{}", r.body_str());
    let r = http_request(&addr, "GET", &format!("/v1/models/sha256/{digest}"), None).unwrap();
    assert_eq!(r.status, 200, "{}", r.body_str());
    assert_eq!(sha256_hex(&r.body), digest, "manifest body must be the canonical form");

    // Pull into a third registry and compare every byte.
    let dest = fresh_registry("push_dst");
    let pulled = pull_model(&addr, &dest, "m:v1", &policy, None).unwrap();
    assert_eq!(pulled, digest);
    let (m, _) = dest.get_manifest("m:v1").unwrap();
    for spec in [&m.target, &m.draft] {
        let a = source.blobs().read_verified(&spec.sha256).unwrap();
        let b = dest.blobs().read_verified(&spec.sha256).unwrap();
        assert_eq!(a, b, "blob sha256:{} must round-trip bit-identically", spec.sha256);
    }

    // The pulled pair zero-copy-loads and forwards exactly like the
    // model it was packed from: [B=1, N=2, P=4] within tiny n_ctx.
    let pair = load_pair(&dest, "m:v1").unwrap();
    let src_model = tiny_model(801);
    let tokens =
        Tensor::from_vec(&[1, 2, 4], (0..8).map(|i| (i as f32 * 0.37).sin()).collect());
    let want: Vec<u32> =
        src_model.forward(&tokens).unwrap().data.iter().map(|v| v.to_bits()).collect();
    let got: Vec<u32> = pair
        .target
        .model()
        .forward(&tokens)
        .unwrap()
        .data
        .iter()
        .map(|v| v.to_bits())
        .collect();
    assert_eq!(want, got, "mapped registry load must be bitwise-invisible");
}

#[test]
fn corrupted_pull_is_a_typed_rejection_not_a_poisoned_cache() {
    let source = fresh_registry("chaos_src");
    publish_pair(&source, "m", "v1", &tiny_model(811), &tiny_model(812)).unwrap();
    let (server, _host_reg) = registry_host("chaos_srv");
    let addr = server.addr().to_string();
    let policy = RetryPolicy::default();
    push_model(&addr, &source, "m:v1", &policy).unwrap();

    // Chaos at the transfer boundary: every pulled blob gets a byte
    // flipped before verification.
    let mut fc = FaultConfig::default();
    fc.enabled = true;
    fc.seed = 7;
    fc.p_blob_corrupt = 1.0;
    let plan = FaultPlan::new(fc).unwrap();

    let dest = fresh_registry("chaos_dst");
    match pull_model(&addr, &dest, "m:v1", &policy, Some(plan.as_ref())) {
        Err(RegistryError::DigestMismatch { expected, actual }) => {
            assert_ne!(expected, actual);
        }
        other => panic!("corrupt transfer must be DigestMismatch, got {:?}", other.err()),
    }
    // Nothing poisoned: the cache holds no blob under the expected
    // digest, no manifest landed, and a clean retry into the same dir
    // succeeds.
    let (m, _) = source.get_manifest("m:v1").unwrap();
    assert!(!dest.blobs().has(&m.target.sha256));
    assert!(dest.get_manifest("m:v1").is_err(), "manifest must not land before its blobs");
    pull_model(&addr, &dest, "m:v1", &policy, None).unwrap();
    assert!(dest.blobs().read_verified(&m.target.sha256).is_ok());
}

#[test]
fn blob_and_manifest_routes_reject_bad_input_with_typed_errors() {
    let (server, _reg) = registry_host("routes");
    let addr = server.addr().to_string();

    // Wrong-content upload: hash-before-store answers 422 and caches
    // nothing under either digest.
    let fake = "a".repeat(64);
    let r = http_request(&addr, "PUT", &format!("/v1/blobs/{fake}"), Some(b"junk")).unwrap();
    assert_eq!(r.status, 422, "{}", r.body_str());
    assert!(r.body_str().contains("\"error_code\":\"digest_mismatch\""), "{}", r.body_str());
    let r = http_request(&addr, "GET", &format!("/v1/blobs/{fake}"), None).unwrap();
    assert_eq!(r.status, 404, "{}", r.body_str());

    // Malformed digests never touch the filesystem: typed 400.
    let r = http_request(&addr, "GET", "/v1/blobs/not-a-digest", None).unwrap();
    assert_eq!(r.status, 400, "{}", r.body_str());

    // A manifest PUT whose name/version disagree with the path is a 400.
    let source = fresh_registry("routes_src");
    publish_pair(&source, "m", "v1", &tiny_model(821), &tiny_model(822)).unwrap();
    let (m, _) = source.get_manifest("m:v1").unwrap();
    let body = m.to_json().to_string();
    let r = http_request(&addr, "PUT", "/v1/models/other/v1", Some(body.as_bytes())).unwrap();
    assert_eq!(r.status, 400, "{}", r.body_str());

    // Blobs-first protocol over the wire: the manifest alone is refused
    // (its blobs were never pushed).
    let r = http_request(&addr, "PUT", "/v1/models/m/v1", Some(body.as_bytes())).unwrap();
    assert_eq!(r.status, 404, "{}", r.body_str());
    assert!(r.body_str().contains("\"error_code\":\"not_found\""), "{}", r.body_str());
}

#[test]
fn live_swap_drops_zero_requests_and_matches_a_cold_start() {
    // Two versions, same geometry, different weights, one registry.
    let reg = fresh_registry("swap_live");
    let d1 = publish_pair(&reg, "m", "v1", &tiny_model(901), &tiny_model(902)).unwrap();
    let d2 = publish_pair(&reg, "m", "v2", &tiny_model(911), &tiny_model(912)).unwrap();
    assert_ne!(d1, d2);

    let server = registry_booted(&reg, "m:v1");
    let addr = Arc::new(server.addr().to_string());

    let h = http_request(&addr, "GET", "/healthz", None).unwrap();
    let j = Json::parse(h.body_str()).unwrap();
    assert_eq!(j.get("model_digest").unwrap().as_str(), Some(d1.as_str()));
    assert_eq!(j.get("model_generation").unwrap().as_usize(), Some(0));

    // Concurrent seeded load across the swap: every request must be
    // served (200) — the swap is not allowed to drop or error any.
    let stop_load = Arc::new(std::sync::atomic::AtomicBool::new(false));
    let clients: Vec<_> = (0..6)
        .map(|c| {
            let addr = Arc::clone(&addr);
            let stop_load = Arc::clone(&stop_load);
            std::thread::spawn(move || {
                let mut served = 0u64;
                while !stop_load.load(std::sync::atomic::Ordering::Relaxed) {
                    let body = format!(
                        r#"{{"history": {}, "horizon": 16, "seed": {}}}"#,
                        hist_json(),
                        1000 + c
                    );
                    let r = http_request(&addr, "POST", "/forecast", Some(body.as_bytes()))
                        .expect("request across swap must not fail at the transport");
                    assert_eq!(r.status, 200, "dropped during swap: {}", r.body_str());
                    served += 1;
                }
                served
            })
        })
        .collect();
    std::thread::sleep(std::time::Duration::from_millis(50));

    // Swap mid-load.
    let r = http_request(&addr, "POST", "/admin/swap", Some(br#"{"model": "m:v2"}"#)).unwrap();
    assert_eq!(r.status, 200, "{}", r.body_str());
    let rep = Json::parse(r.body_str()).unwrap();
    assert_eq!(rep.get("digest").unwrap().as_str(), Some(d2.as_str()));
    assert_eq!(rep.get("complete").unwrap().as_bool(), Some(true));
    assert_eq!(rep.get("generation").unwrap().as_usize(), Some(1));
    assert_eq!(rep.get("rebound").unwrap().as_usize(), Some(2));
    assert_eq!(rep.get("heads").unwrap().as_str(), Some("reset"));

    std::thread::sleep(std::time::Duration::from_millis(50));
    stop_load.store(true, std::sync::atomic::Ordering::Relaxed);
    let total: u64 = clients.into_iter().map(|h| h.join().unwrap()).sum();
    assert!(total > 0, "load loop never issued a request");

    // Identity flipped everywhere it is reported.
    let h = http_request(&addr, "GET", "/healthz", None).unwrap();
    let j = Json::parse(h.body_str()).unwrap();
    assert_eq!(j.get("model_digest").unwrap().as_str(), Some(d2.as_str()));
    assert_eq!(j.get("model_generation").unwrap().as_usize(), Some(1));
    let s = Json::parse(http_request(&addr, "GET", "/stats", None).unwrap().body_str()).unwrap();
    let model = s.get("model").expect("stats model block");
    assert_eq!(model.get("digest").unwrap().as_str(), Some(d2.as_str()));
    assert_eq!(model.get("label").unwrap().as_str(), Some("m:v2"));
    assert_eq!(model.get("swaps").unwrap().as_usize(), Some(1));
    assert_eq!(model.get("swap_failures").unwrap().as_usize(), Some(0));
    assert!(model.get("rebinds").unwrap().as_usize().unwrap() >= 2);
    assert_eq!(model.get("rebind_failures").unwrap().as_usize(), Some(0));

    // Post-swap responses are bit-identical to a cold start on v2: the
    // swap left no residue in the serving numerics.
    let hot = forecast_bits(&addr, 424242);
    let cold = registry_booted(&reg, "m:v2");
    let cold_bits = forecast_bits(&cold.addr().to_string(), 424242);
    assert_eq!(hot, cold_bits, "post-swap decode must equal a cold start on the new manifest");
}

#[test]
fn swap_failures_are_typed_and_leave_the_pool_serving() {
    let (server, reg) = registry_host("swap_fail");
    let addr = server.addr().to_string();

    // Unknown reference: 404.
    let r = http_request(&addr, "POST", "/admin/swap", Some(br#"{"model": "ghost:v9"}"#)).unwrap();
    assert_eq!(r.status, 404, "{}", r.body_str());
    assert!(r.body_str().contains("\"error_code\":\"not_found\""), "{}", r.body_str());

    // Body without a model reference: 400.
    let r = http_request(&addr, "POST", "/admin/swap", Some(br#"{"nope": 1}"#)).unwrap();
    assert_eq!(r.status, 400, "{}", r.body_str());

    // Geometry mismatch: a published pair with different dims is
    // refused — a live swap cannot change model shape.
    use stride::nn::{ModelDims, NativeModel};
    let dims = ModelDims { patch: 2, n_ctx: 8, d_model: 8, n_layers: 1, n_heads: 2, d_ff: 16 };
    let t = NativeModel::random("t", dims, 31);
    let d = NativeModel::random("d", dims, 32);
    publish_pair(&reg, "thin", "v1", &t, &d).unwrap();
    let r = http_request(&addr, "POST", "/admin/swap", Some(br#"{"model": "thin:v1"}"#)).unwrap();
    assert_eq!(r.status, 400, "{}", r.body_str());
    assert!(r.body_str().contains("geometry"), "{}", r.body_str());

    // Every failed swap was counted, none advanced the pool: it still
    // answers on its boot weights under the builtin identity.
    let s = Json::parse(http_request(&addr, "GET", "/stats", None).unwrap().body_str()).unwrap();
    let model = s.get("model").expect("stats model block");
    assert_eq!(model.get("swaps").unwrap().as_usize(), Some(0));
    assert_eq!(model.get("swap_failures").unwrap().as_usize(), Some(2));
    let h = http_request(&addr, "GET", "/healthz", None).unwrap();
    let j = Json::parse(h.body_str()).unwrap();
    assert_eq!(j.get("model_digest").unwrap().as_str(), Some("unregistered"));
    assert_eq!(j.get("model_generation").unwrap().as_usize(), Some(0));
    let bits = forecast_bits(&addr, 7);
    assert!(!bits.is_empty());
}
