//! Equivalence suite for the KV-cached decode sessions: cached forwards
//! must reproduce the stateless `forward()` path within 1e-5 for single
//! and batched decode, including after mid-sequence `rollback()`, and
//! ring-buffer eviction at max_ctx must match the stateless
//! sliding-window rule. Property tests (proptest_lite) pin the session
//! invariants: extend-then-rollback is an identity, eviction equals the
//! window rule.

use stride::models::{
    begin_batch_session, begin_session, Backend, CacheMode, NativeBackend,
};
use stride::nn::{ModelDims, NativeModel};
use stride::util::proptest_lite::{self, Pair, UsizeRange};
use stride::util::rng::Rng;

const TOL: f32 = 1e-5;

fn dims(n_ctx: usize) -> ModelDims {
    ModelDims { patch: 4, n_ctx, d_model: 16, n_layers: 2, n_heads: 2, d_ff: 32 }
}

fn model(n_ctx: usize, seed: u64) -> NativeBackend {
    NativeBackend::new(NativeModel::random("m", dims(n_ctx), seed))
}

fn tokens(n: usize, seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    (0..n * 4).map(|_| rng.normal() as f32).collect()
}

fn assert_close(a: &[f32], b: &[f32], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length mismatch");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert!((x - y).abs() < TOL, "{what}: [{i}] cached {x} vs stateless {y}");
    }
}

#[test]
fn cached_extend_matches_stateless_forward() {
    // For several (n_hist, k) splits, session prefill + extend must equal
    // one stateless forward over the concatenated sequence.
    let b = model(32, 1);
    for seed in 0..5u64 {
        let toks = tokens(12, 100 + seed);
        for (n_hist, k) in [(1usize, 1usize), (1, 11), (4, 3), (8, 4), (11, 1)] {
            let full = b.forward(&toks[..(n_hist + k) * 4], n_hist + k).unwrap();
            let mut sess =
                begin_session(&b, CacheMode::On, &toks[..n_hist * 4], n_hist).unwrap();
            let rows = sess.extend(&toks[n_hist * 4..(n_hist + k) * 4], k).unwrap();
            // rows = outputs at positions n_hist-1 ..= n_hist+k-1.
            assert_close(
                &rows,
                &full[(n_hist - 1) * 4..(n_hist + k) * 4],
                &format!("seed {seed} n_hist {n_hist} k {k}"),
            );
        }
    }
}

#[test]
fn cached_rollback_midsequence_matches_stateless() {
    // extend a, rollback r, extend different patches: outputs must equal a
    // stateless forward over the spliced sequence — the exact state a
    // speculative rejection leaves behind.
    let b = model(32, 2);
    let toks = tokens(10, 7);
    let alt = tokens(6, 8);
    let mut sess = begin_session(&b, CacheMode::On, &toks[..4 * 4], 4).unwrap();
    let _ = sess.extend(&toks[4 * 4..10 * 4], 6).unwrap();
    sess.rollback(4).unwrap(); // keep 6 patches
    let rows = sess.extend(&alt[..3 * 4], 3).unwrap();

    let mut spliced = toks[..6 * 4].to_vec();
    spliced.extend_from_slice(&alt[..3 * 4]);
    let full = b.forward(&spliced, 9).unwrap();
    assert_close(&rows, &full[5 * 4..9 * 4], "rollback+reextend");
    let tip = sess.tip_mean().unwrap();
    assert_close(&tip, &full[8 * 4..9 * 4], "tip after rollback+reextend");
}

#[test]
fn cached_batch_matches_stateless_per_sequence() {
    let b = model(32, 3);
    let h1 = tokens(3, 11);
    let h2 = tokens(7, 12);
    let tasks: Vec<(&[f32], usize)> = vec![(&h1, 3), (&h2, 7)];
    let mut bs = begin_batch_session(&b, CacheMode::On, &tasks).unwrap();
    let fresh = tokens(2, 13);
    let rows = bs.extend(&[0, 1], &[&fresh[..2 * 4], &fresh[..2 * 4]].concat(), 2).unwrap();

    let cases: [(usize, &[f32], usize); 2] = [(0, &h1, 3), (1, &h2, 7)];
    for (ai, hist, n_hist) in cases {
        let mut seq = hist[..n_hist * 4].to_vec();
        seq.extend_from_slice(&fresh[..2 * 4]);
        let full = b.forward(&seq, n_hist + 2).unwrap();
        let per_seq = &rows[ai * 3 * 4..(ai + 1) * 3 * 4];
        assert_close(per_seq, &full[(n_hist - 1) * 4..(n_hist + 2) * 4], "batched row");
    }
}

#[test]
fn batched_per_sequence_rollback_independent() {
    // Rolling back one sequence must not disturb the other's state.
    let b = model(32, 4);
    let h1 = tokens(4, 21);
    let h2 = tokens(4, 22);
    let tasks: Vec<(&[f32], usize)> = vec![(&h1, 4), (&h2, 4)];
    let mut bs = begin_batch_session(&b, CacheMode::On, &tasks).unwrap();
    let ext = tokens(3, 23);
    let _ = bs.extend(&[0, 1], &[&ext[..3 * 4], &ext[..3 * 4]].concat(), 3).unwrap();
    bs.rollback(0, 2).unwrap();
    assert_eq!(bs.len(0), 5);
    assert_eq!(bs.len(1), 7);
    // Sequence 1's tip must still equal the stateless forward of its full
    // 7-patch context.
    let mut seq2 = h2[..4 * 4].to_vec();
    seq2.extend_from_slice(&ext[..3 * 4]);
    let full = b.forward(&seq2, 7).unwrap();
    let tips = bs.tip_means(&[1]).unwrap();
    assert_close(&tips, &full[6 * 4..7 * 4], "untouched sequence tip");
}

#[test]
fn eviction_at_max_ctx_matches_sliding_window() {
    // Push a session far past max_ctx one patch at a time; at every step
    // the tip must equal a stateless forward over the trailing window —
    // for both cache modes.
    let n_ctx = 8;
    let b = model(n_ctx, 5);
    let toks = tokens(20, 31);
    for mode in [CacheMode::On, CacheMode::Off] {
        let mut sess = begin_session(&b, mode, &toks[..4 * 4], 4).unwrap();
        for t in 4..20 {
            let tip = sess.tip_mean().unwrap();
            let n = sess.len();
            let start = t - n;
            let full = b.forward(&toks[start * 4..t * 4], n).unwrap();
            assert_close(&tip, &full[(n - 1) * 4..n * 4], &format!("{mode:?} step {t}"));
            sess.append(&toks[t * 4..(t + 1) * 4], 1).unwrap();
            assert!(sess.len() <= n_ctx, "window exceeded max_ctx");
        }
    }
}

#[test]
fn cache_modes_agree_after_eviction() {
    // Same drive sequence in both modes: lengths and tips must agree at
    // every step (the ring-buffer eviction rule IS the sliding-window
    // rule).
    let b = model(8, 6);
    let toks = tokens(26, 41);
    let mut on = begin_session(&b, CacheMode::On, &toks[..2 * 4], 2).unwrap();
    let mut off = begin_session(&b, CacheMode::Off, &toks[..2 * 4], 2).unwrap();
    for t in 2..26 {
        assert_eq!(on.len(), off.len(), "lengths diverged at step {t}");
        assert_close(
            &on.tip_mean().unwrap(),
            &off.tip_mean().unwrap(),
            &format!("tip at step {t}"),
        );
        on.append(&toks[t * 4..(t + 1) * 4], 1).unwrap();
        off.append(&toks[t * 4..(t + 1) * 4], 1).unwrap();
    }
}

// ---------------------------------------------------------------------------
// Property tests (proptest_lite): session invariants over random shapes.
// ---------------------------------------------------------------------------

#[test]
fn prop_extend_then_rollback_is_identity() {
    // For random (n_hist, k): extend(k) then rollback(k) restores len,
    // context, and tip mean exactly.
    let b = model(32, 9);
    proptest_lite::check_with(
        proptest_lite::Config { cases: 40, seed: 0xCAFE, max_shrink_rounds: 50 },
        &Pair(UsizeRange(1, 12), UsizeRange(1, 8)),
        |&(n_hist, k)| {
            let toks = tokens(n_hist + k, 1000 + (n_hist * 31 + k) as u64);
            let mut sess = begin_session(&b, CacheMode::On, &toks[..n_hist * 4], n_hist)
                .map_err(|e| e.to_string())?;
            let tip0 = sess.tip_mean().map_err(|e| e.to_string())?;
            let ctx0 = sess.context().to_vec();
            let _ = sess
                .extend(&toks[n_hist * 4..(n_hist + k) * 4], k)
                .map_err(|e| e.to_string())?;
            sess.rollback(k).map_err(|e| e.to_string())?;
            if sess.len() != n_hist {
                return Err(format!("len {} != {}", sess.len(), n_hist));
            }
            if sess.context() != ctx0.as_slice() {
                return Err("context changed".into());
            }
            let tip1 = sess.tip_mean().map_err(|e| e.to_string())?;
            if tip0 != tip1 {
                return Err(format!("tip changed: {tip0:?} vs {tip1:?}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_eviction_matches_stateless_window() {
    // For random total lengths past max_ctx, the cached session's tip
    // equals a stateless forward over the trailing max_ctx window.
    let n_ctx = 8;
    let b = model(n_ctx, 10);
    proptest_lite::check_with(
        proptest_lite::Config { cases: 30, seed: 0xBEEF, max_shrink_rounds: 50 },
        &UsizeRange(9, 24),
        |&total| {
            let toks = tokens(total, 2000 + total as u64);
            let mut sess = begin_session(&b, CacheMode::On, &toks[..4 * 4], 4)
                .map_err(|e| e.to_string())?;
            for t in 4..total {
                sess.append(&toks[t * 4..(t + 1) * 4], 1).map_err(|e| e.to_string())?;
            }
            let n = sess.len();
            if n > n_ctx {
                return Err(format!("len {n} exceeds max_ctx {n_ctx}"));
            }
            let start = total - n;
            let full = b.forward(&toks[start * 4..total * 4], n).map_err(|e| e.to_string())?;
            let tip = sess.tip_mean().map_err(|e| e.to_string())?;
            for (x, y) in tip.iter().zip(&full[(n - 1) * 4..n * 4]) {
                if (x - y).abs() >= TOL {
                    return Err(format!("tip {x} vs window {y}"));
                }
            }
            Ok(())
        },
    );
}
