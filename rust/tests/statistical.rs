//! Statistical validation of the paper's laws on analytic heads:
//! * capped-geometric block-length law (Eqs. 2-4),
//! * measured E[L] within the dependence bounds (Prop. 1),
//! * practical-variant TV deviation <= alpha-bar (Cor. 1),
//! * Hoeffding concentration of the alpha-hat estimator (Prop. 8).
//!
//! These run on `AnalyticBackend` (no artifacts needed) and are the
//! "coordinator invariants" property suite the testing policy asks for.

use stride::accept::{estimate_alpha, AcceptancePolicy};
use stride::models::{AnalyticBackend, Backend};
use stride::specdec::{sd_generate, SpecConfig, Variant};
use stride::theory;
use stride::util::rng::Rng;
use stride::util::stats::Summary;

fn spec(gamma: usize, sigma: f64, variant: Variant, seed: u64) -> SpecConfig {
    SpecConfig {
        gamma,
        k: 1,
        policy: AcceptancePolicy::new(sigma, 1.0),
        variant,
        seed,
        max_residual_draws: 10_000,
        emission: stride::specdec::Emission::Sampled,
        cache: stride::models::CacheMode::On,
        draft: stride::specdec::DraftConfig::default(),
        adaptive: None,
    }
}

/// Heads with a constant mean gap g have constant per-step acceptance
/// beta = 2 Phi(-g / (2 sigma)) — the i.i.d. regime of Eq. 2-4.
fn constant_gap_models(patch: usize, gap_per_dim: f32) -> (AnalyticBackend, AnalyticBackend) {
    let t = AnalyticBackend::new("t", patch, 0.0, 0.0); // mean always 0
    let d = AnalyticBackend::new("d", patch, 0.0, gap_per_dim); // mean always gap
    (t, d)
}

#[test]
fn block_length_law_matches_capped_geometric() {
    let patch = 4;
    let gap = 0.45f32;
    let sigma = 0.5;
    let (t, d) = constant_gap_models(patch, gap);
    let delta = (patch as f64).sqrt() * gap as f64 / sigma;
    let alpha = stride::util::stats::gaussian_overlap(delta);
    let gamma = 3;

    // Collect first-round block lengths over many independent decodes.
    let mut counts = vec![0usize; gamma + 1];
    let n = 6000;
    let hist = vec![0.0f32; patch];
    for seed in 0..n {
        let out = sd_generate(&t, &d, &hist, 1, gamma + 1, &spec(gamma, sigma, Variant::Practical, seed)).unwrap();
        let l = out.rounds[0].emitted;
        counts[l - 1] += 1;
    }
    let pmf = theory::block_length_pmf(alpha, gamma);
    for (l, want) in pmf.iter().enumerate() {
        let got = counts[l] as f64 / n as f64;
        // Binomial SE ~ sqrt(p(1-p)/n) < 0.007; allow 4 SE.
        assert!(
            (got - want).abs() < 0.03,
            "P(L={}) measured {:.4} vs theory {:.4} (alpha={:.3})",
            l + 1,
            got,
            want,
            alpha
        );
    }
    // And the mean matches Eq. 4.
    let mean_l: f64 =
        counts.iter().enumerate().map(|(l, c)| (l + 1) as f64 * *c as f64).sum::<f64>() / n as f64;
    let want_l = theory::expected_block_length(alpha, gamma);
    assert!((mean_l - want_l).abs() < 0.06, "E[L] {mean_l:.3} vs {want_l:.3}");
}

#[test]
fn lossless_multi_step_matches_target_chain() {
    // Theorem 2: iterating blocks recovers the exact AR(1) target chain.
    // Check mean/std of patch index 2 (three-step composition).
    let a = 0.7f32;
    let b = 0.1f32;
    let t = AnalyticBackend::new("t", 1, a, b);
    let d = AnalyticBackend::new("d", 1, 0.4, -0.2); // bad draft, exactness must hold anyway
    let sigma = 0.4;
    let x0 = 0.8f32;

    // Target chain: x1 ~ N(a x0 + b, s2), x2 | x1 ~ N(a x1 + b, s2), ...
    // Marginal of x3: mean = a^3 x0 + b(1 + a + a^2), var = s2(1 + a^2 + a^4).
    let want_mean = (a as f64).powi(3) * x0 as f64
        + b as f64 * (1.0 + a as f64 + (a as f64).powi(2));
    let want_var = sigma * sigma * (1.0 + (a as f64).powi(2) + (a as f64).powi(4));

    let mut s = Summary::new();
    for seed in 0..6000 {
        let out = sd_generate(&t, &d, &[x0], 1, 3, &spec(2, sigma, Variant::Lossless, seed)).unwrap();
        s.push(out.patches[2] as f64);
    }
    assert!(
        (s.mean() - want_mean).abs() < 0.03,
        "x3 mean {:.4} vs target chain {:.4}",
        s.mean(),
        want_mean
    );
    assert!(
        (s.var() - want_var).abs() < 0.05,
        "x3 var {:.4} vs target chain {:.4}",
        s.var(),
        want_var
    );
}

#[test]
fn practical_tv_deviation_bounded_by_alpha_bar() {
    // Cor. 1: ||g - p||_TV <= alpha-bar. Estimate the TV distance of the
    // first emitted patch empirically via histogram comparison in 1-D.
    let t = AnalyticBackend::new("t", 1, 0.0, 0.5); // p = N(0.5, s2)
    let d = AnalyticBackend::new("d", 1, 0.0, 0.0); // q = N(0.0, s2)
    let sigma = 0.5;
    let alpha_bar = stride::util::stats::gaussian_overlap(0.5 / sigma);

    let nbins = 40;
    let (lo, hi) = (-2.0f64, 3.0f64);
    let mut h_sd = vec![0f64; nbins];
    let mut h_p = vec![0f64; nbins];
    let n = 30_000;
    let mut rng = Rng::new(99);
    for seed in 0..n {
        let out =
            sd_generate(&t, &d, &[0.0], 1, 1, &spec(1, sigma, Variant::Practical, seed)).unwrap();
        let x = out.patches[0] as f64;
        let bin = (((x - lo) / (hi - lo) * nbins as f64) as isize).clamp(0, nbins as isize - 1);
        h_sd[bin as usize] += 1.0 / n as f64;
        // Reference: exact p samples.
        let y = 0.5 + sigma * rng.normal();
        let bin = (((y - lo) / (hi - lo) * nbins as f64) as isize).clamp(0, nbins as isize - 1);
        h_p[bin as usize] += 1.0 / n as f64;
    }
    let tv: f64 = h_sd.iter().zip(&h_p).map(|(a, b)| (a - b).abs()).sum::<f64>() / 2.0;
    // Histogram TV underestimates true TV, so the bound must hold with
    // slack for sampling noise.
    assert!(
        tv <= alpha_bar + 0.03,
        "empirical TV {tv:.4} exceeds bound alpha_bar {alpha_bar:.4}"
    );
    // And the deviation is *real* (draft shifted left => SD mean < p mean).
    let mean_sd: f64 = h_sd
        .iter()
        .enumerate()
        .map(|(i, p)| p * (lo + (i as f64 + 0.5) * (hi - lo) / nbins as f64))
        .sum();
    assert!(mean_sd < 0.5, "practical variant should be biased toward the draft");
}

#[test]
fn alpha_estimator_concentrates() {
    // Prop. 8: two-stage estimator within Hoeffding eps of closed form.
    let policy = AcceptancePolicy::new(0.6, 1.0);
    let patch = 8;
    let mut rng = Rng::new(5);
    let mut heads: Vec<(Vec<f32>, Vec<f32>)> = Vec::new();
    for _ in 0..50 {
        let mu_p: Vec<f32> = (0..patch).map(|_| rng.normal() as f32 * 0.3).collect();
        let mu_q: Vec<f32> = mu_p.iter().map(|v| v + 0.1 * rng.normal() as f32).collect();
        heads.push((mu_p, mu_q));
    }
    let mc = estimate_alpha(
        &policy,
        heads.iter().map(|(a, b)| (a.as_slice(), b.as_slice())),
        200,
        1,
    );
    let cf = stride::accept::estimate_alpha_closed_form(
        &policy,
        heads.iter().map(|(a, b)| (a.as_slice(), b.as_slice())),
    );
    assert!(
        (mc.alpha_hat - cf.alpha_hat).abs() < 0.02,
        "MC {:.4} vs closed-form {:.4}",
        mc.alpha_hat,
        cf.alpha_hat
    );
    assert!(mc.eps95 < 0.02, "10k samples should give tight eps: {}", mc.eps95);
}

#[test]
fn measured_speedup_components_track_theory() {
    // With constant-gap heads, measured E[L] and the call pattern must
    // match the capped-geometric predictions across gammas.
    let patch = 4;
    let sigma = 0.5;
    let (t, d) = constant_gap_models(patch, 0.2);
    let delta = (patch as f64).sqrt() * 0.2 / sigma;
    let alpha = stride::util::stats::gaussian_overlap(delta);
    let hist = vec![0.0f32; patch];
    for gamma in [1usize, 2, 3, 5] {
        let mut total_emitted = 0usize;
        let mut total_rounds = 0usize;
        for seed in 0..800 {
            let out =
                sd_generate(&t, &d, &hist, 1, 40, &spec(gamma, sigma, Variant::Practical, seed))
                    .unwrap();
            total_emitted += 40;
            total_rounds += out.stats.rounds;
        }
        let mean_l = total_emitted as f64 / total_rounds as f64;
        let want = theory::expected_block_length(alpha, gamma);
        // Horizon-end gamma capping slightly depresses the mean; 8% slack.
        assert!(
            (mean_l - want).abs() / want < 0.08,
            "gamma={gamma}: measured E[L] {mean_l:.3} vs theory {want:.3} (alpha {alpha:.3})"
        );
    }
}

// ---------------------------------------------------------------------------
// Cache regression suite: the KV-cached decode path must not move a single
// statistic. Cached and uncached runs share the RNG stream (the engine
// consumes randomness identically in both modes), and the native backend's
// incremental forward reproduces the stateless op order, so acceptance
// decisions — not just rates — must match decode-for-decode.
// ---------------------------------------------------------------------------

fn tiny_native_pair() -> (stride::models::NativeBackend, stride::models::NativeBackend) {
    use stride::models::NativeBackend;
    use stride::nn::{ModelDims, NativeModel};
    let dims = ModelDims { patch: 4, n_ctx: 24, d_model: 16, n_layers: 2, n_heads: 2, d_ff: 32 };
    let draft_dims =
        ModelDims { patch: 4, n_ctx: 24, d_model: 8, n_layers: 1, n_heads: 2, d_ff: 16 };
    (
        NativeBackend::new(NativeModel::random("t", dims, 101)),
        NativeBackend::new(NativeModel::random("d", draft_dims, 202)),
    )
}

/// Run many decodes in both cache modes; assert acceptance rate, per-round
/// accepted-patch histogram, alpha-hat, and MSE against a fixed reference
/// are *identical* (same RNG stream, same decisions).
fn assert_cache_modes_agree(variant: Variant, emission: stride::specdec::Emission) {
    use stride::models::CacheMode;
    use stride::util::tensor::mse_mae;
    let (t, d) = tiny_native_pair();
    let hist: Vec<f32> = (0..4 * 4).map(|i| (i as f32 * 0.23).sin()).collect();
    let reference: Vec<f32> = (0..10 * 4).map(|i| (i as f32 * 0.23 + 0.4).sin()).collect();
    let gamma = 3;

    // Per-round accepted-count histogram (0..=gamma) across all decodes.
    let mut hist_on = vec![0usize; gamma + 1];
    let mut hist_off = vec![0usize; gamma + 1];
    let (mut rate_on, mut rate_off) = ((0usize, 0usize), (0usize, 0usize));
    let (mut alpha_on, mut alpha_off) = ((0.0f64, 0usize), (0.0f64, 0usize));
    let (mut mse_on, mut mse_off) = (0.0f64, 0.0f64);

    for seed in 0..60u64 {
        let mut on = spec(gamma, 0.5, variant, seed);
        on.emission = emission;
        on.cache = CacheMode::On;
        let mut off = on;
        off.cache = CacheMode::Off;
        let a = sd_generate(&t, &d, &hist, 4, 10, &on).unwrap();
        let b = sd_generate(&t, &d, &hist, 4, 10, &off).unwrap();

        assert_eq!(a.rounds.len(), b.rounds.len(), "seed {seed}: round count drifted");
        for (ra, rb) in a.rounds.iter().zip(&b.rounds) {
            assert_eq!(ra.accepted, rb.accepted, "seed {seed}: accepted-run drifted");
            assert_eq!(ra.gamma, rb.gamma);
            if ra.gamma > 0 {
                hist_on[ra.accepted] += 1;
                hist_off[rb.accepted] += 1;
            }
            for (x, y) in ra.alphas.iter().zip(&rb.alphas) {
                assert!((x - y).abs() < 1e-9, "seed {seed}: alpha drifted {x} vs {y}");
            }
        }
        rate_on = (rate_on.0 + a.stats.accepted, rate_on.1 + a.stats.proposals);
        rate_off = (rate_off.0 + b.stats.accepted, rate_off.1 + b.stats.proposals);
        alpha_on = (alpha_on.0 + a.stats.sum_alpha, alpha_on.1 + a.stats.alpha_count);
        alpha_off = (alpha_off.0 + b.stats.sum_alpha, alpha_off.1 + b.stats.alpha_count);
        mse_on += mse_mae(&a.patches, &reference).0;
        mse_off += mse_mae(&b.patches, &reference).0;
    }

    assert_eq!(hist_on, hist_off, "accepted-patch histograms drifted");
    assert_eq!(rate_on, rate_off, "acceptance rate drifted");
    assert_eq!(alpha_on.1, alpha_off.1);
    assert!((alpha_on.0 - alpha_off.0).abs() < 1e-6, "alpha-hat drifted");
    // MSE delta vs the fixed reference: identical emissions => identical
    // (within f32 accumulation) error.
    assert!(
        (mse_on - mse_off).abs() < 1e-6,
        "MSE drifted: cached {mse_on} vs uncached {mse_off}"
    );
    // Sanity: the suite exercised both acceptances and rejections — an
    // all-accept (or all-reject) run would make the comparison vacuous.
    assert!(rate_on.0 > 0, "no acceptances — test has no power");
    assert!(rate_on.0 < rate_on.1, "no rejections — test has no power");
}

#[test]
fn cached_specdec_statistics_identical_practical() {
    assert_cache_modes_agree(Variant::Practical, stride::specdec::Emission::Sampled);
}

#[test]
fn cached_specdec_statistics_identical_practical_mean_emission() {
    assert_cache_modes_agree(Variant::Practical, stride::specdec::Emission::Mean);
}

#[test]
fn cached_specdec_statistics_identical_lossless() {
    assert_cache_modes_agree(Variant::Lossless, stride::specdec::Emission::Sampled);
}

#[test]
fn cached_batched_specdec_statistics_identical() {
    use stride::models::CacheMode;
    use stride::specdec::sd_generate_batch;
    let (t, d) = tiny_native_pair();
    let h1: Vec<f32> = (0..3 * 4).map(|i| (i as f32 * 0.31).sin()).collect();
    let h2: Vec<f32> = (0..5 * 4).map(|i| (i as f32 * 0.19).cos()).collect();
    let tasks: Vec<(&[f32], usize, usize)> = vec![(&h1, 3, 9), (&h2, 5, 6)];
    let mut on = spec(3, 0.5, Variant::Practical, 77);
    on.cache = CacheMode::On;
    let mut off = on;
    off.cache = CacheMode::Off;
    let a = sd_generate_batch(&t, &d, &tasks, &on).unwrap();
    let b = sd_generate_batch(&t, &d, &tasks, &off).unwrap();
    for (x, y) in a.iter().zip(&b) {
        assert_eq!(x.stats.accepted, y.stats.accepted);
        assert_eq!(x.stats.proposals, y.stats.proposals);
        assert_eq!(x.stats.rounds, y.stats.rounds);
        assert_eq!(x.stats.sum_block_len, y.stats.sum_block_len);
        for (u, v) in x.patches.iter().zip(&y.patches) {
            assert!((u - v).abs() < 1e-5);
        }
    }
}

// ---------------------------------------------------------------------------
// Adaptive-controller regression: adaptation changes *when* we draft, never
// *what* is emitted. For the lossless variant this is the exactness
// statement — each round is exact for any γ (Theorems 1-2 are per-round),
// so a γ sequence chosen online must reproduce bit-for-bit when replayed
// as per-round fixed choices.
// ---------------------------------------------------------------------------

#[test]
fn adaptive_lossless_bit_identical_to_fixed_gamma_replay() {
    use stride::specdec::{sd_generate_scheduled, AdaptiveConfig};
    let (t, d) = tiny_native_pair();
    let hist: Vec<f32> = (0..4 * 4).map(|i| (i as f32 * 0.23).sin()).collect();
    for seed in 0..12u64 {
        let mut live_cfg = spec(3, 0.5, Variant::Lossless, seed);
        live_cfg.adaptive = Some(AdaptiveConfig {
            warmup: 1,
            dwell: 1,
            halflife: 4.0,
            c_override: 0.1,
            ..AdaptiveConfig::default()
        });
        let live = sd_generate(&t, &d, &hist, 4, 20, &live_cfg).unwrap();
        let schedule: Vec<usize> = live.rounds.iter().map(|r| r.gamma).collect();
        let mut replay_cfg = live_cfg;
        replay_cfg.adaptive = None;
        let replay = sd_generate_scheduled(&t, &d, &hist, 4, 20, &replay_cfg, &schedule).unwrap();
        assert_eq!(
            live.patches, replay.patches,
            "seed {seed}: adaptive lossless output drifted from its own gamma schedule"
        );
        assert_eq!(live.stats.accepted, replay.stats.accepted, "seed {seed}");
        assert_eq!(live.stats.proposals, replay.stats.proposals, "seed {seed}");
        assert_eq!(live.stats.residual_draws, replay.stats.residual_draws, "seed {seed}");
        for (a, b) in live.rounds.iter().zip(&replay.rounds) {
            assert_eq!(a.gamma, b.gamma, "seed {seed}: replay used a different gamma");
            assert_eq!(a.accepted, b.accepted, "seed {seed}");
        }
    }
}

#[test]
fn adaptive_lossless_still_matches_target_law() {
    // The stronger statistical statement: with the controller moving γ
    // online, the lossless chain still reproduces the exact target
    // marginal (Theorem 2) — adaptation is invisible in distribution.
    use stride::specdec::AdaptiveConfig;
    let a = 0.7f32;
    let b = 0.1f32;
    let t = AnalyticBackend::new("t", 1, a, b);
    let d = AnalyticBackend::new("d", 1, 0.4, -0.2); // bad draft
    let sigma = 0.4;
    let x0 = 0.8f32;
    let want_mean = (a as f64).powi(3) * x0 as f64
        + b as f64 * (1.0 + a as f64 + (a as f64).powi(2));
    let want_var = sigma * sigma * (1.0 + (a as f64).powi(2) + (a as f64).powi(4));

    let mut s = Summary::new();
    for seed in 0..6000 {
        let mut cfg = spec(2, sigma, Variant::Lossless, seed);
        cfg.adaptive = Some(AdaptiveConfig {
            warmup: 1,
            dwell: 1,
            halflife: 4.0,
            c_override: 0.1,
            ..AdaptiveConfig::default()
        });
        let out = sd_generate(&t, &d, &[x0], 1, 3, &cfg).unwrap();
        s.push(out.patches[2] as f64);
    }
    assert!(
        (s.mean() - want_mean).abs() < 0.03,
        "adaptive lossless x3 mean {:.4} vs target chain {:.4}",
        s.mean(),
        want_mean
    );
    assert!(
        (s.var() - want_var).abs() < 0.05,
        "adaptive lossless x3 var {:.4} vs target chain {:.4}",
        s.var(),
        want_var
    );
}

// ---------------------------------------------------------------------------
// Tree-speculation statistics: the k = 1 tree path must inherit every
// distributional guarantee of the classic engine (it is bit-identical —
// tests/tree_equivalence.rs — so this is a belt-and-braces check through
// the statistical lens), and k must buy accepted-run length at the rate
// the max-of-k generalization of Eq. 4 predicts.
// ---------------------------------------------------------------------------

#[test]
fn tree_k1_lossless_matches_target_chain() {
    // Theorem 2 through the tree loop: at k = 1 the lossless tree decode
    // reproduces the exact AR(1) target marginal, bad draft and all.
    use stride::specdec::sd_generate_tree;
    let a = 0.7f32;
    let b = 0.1f32;
    let t = AnalyticBackend::new("t", 1, a, b);
    let d = AnalyticBackend::new("d", 1, 0.4, -0.2); // bad draft, exactness must hold anyway
    let sigma = 0.4;
    let x0 = 0.8f32;
    let want_mean = (a as f64).powi(3) * x0 as f64
        + b as f64 * (1.0 + a as f64 + (a as f64).powi(2));
    let want_var = sigma * sigma * (1.0 + (a as f64).powi(2) + (a as f64).powi(4));

    let mut s = Summary::new();
    for seed in 0..6000 {
        let out =
            sd_generate_tree(&t, &d, &[x0], 1, 3, &spec(2, sigma, Variant::Lossless, seed))
                .unwrap();
        s.push(out.patches[2] as f64);
    }
    assert!(
        (s.mean() - want_mean).abs() < 0.03,
        "tree k=1 lossless x3 mean {:.4} vs target chain {:.4}",
        s.mean(),
        want_mean
    );
    assert!(
        (s.var() - want_var).abs() < 0.05,
        "tree k=1 lossless x3 var {:.4} vs target chain {:.4}",
        s.var(),
        want_var
    );
}

#[test]
fn tree_accepted_run_is_monotone_in_k_and_tracks_theory() {
    // Constant-gap heads give i.i.d. per-step acceptance α, and the k
    // branches draw independent proposals and uniforms, so the winning
    // run is the max of k independent capped geometrics:
    //   E[acc_k] = Σ_{i=1..γ} (1 − (1 − αⁱ)^k)
    // — exactly `theory::expected_block_length_tree(α, γ, k) − 1`. The
    // measured first-round mean must track it per k and rise strictly
    // with k.
    let patch = 4;
    let sigma = 0.5;
    let gap = 0.2f32;
    let (t, d) = constant_gap_models(patch, gap);
    let delta = (patch as f64).sqrt() * gap as f64 / sigma;
    let alpha = stride::util::stats::gaussian_overlap(delta);
    let gamma = 4;
    let hist = vec![0.0f32; patch];
    let n = 2000u64;

    let mut means = Vec::new();
    for k in [1usize, 2, 4] {
        let mut total = 0usize;
        for seed in 0..n {
            let mut c = spec(gamma, sigma, Variant::Practical, seed);
            c.k = k;
            let out =
                stride::specdec::sd_generate_tree(&t, &d, &hist, 1, gamma + 1, &c).unwrap();
            total += out.rounds[0].accepted;
        }
        let mean = total as f64 / n as f64;
        let want = theory::expected_block_length_tree(alpha, gamma, k) - 1.0;
        // SE of a mean of [0, γ]-bounded draws over 2000 trials < 0.03;
        // allow ~4 SE.
        assert!(
            (mean - want).abs() < 0.12,
            "k={k}: measured mean accepted {mean:.3} vs theory {want:.3} (alpha {alpha:.3})"
        );
        means.push(mean);
    }
    assert!(
        means[0] + 0.2 < means[1] && means[1] + 0.2 < means[2],
        "accepted run must rise strictly with k: {means:?}"
    );
}

#[test]
fn draft_cost_ratio_is_meaningful() {
    // c measured on the analytic backends is ~1 (same trivial compute);
    // the ratio plumbing itself must produce finite positive numbers once
    // both backends have been timed.
    let (t, d) = constant_gap_models(2, 0.1);
    let _ = t.forward(&[0.0, 0.0], 1).unwrap();
    let _ = d.forward(&[0.0, 0.0], 1).unwrap();
    let _ = sd_generate(&t, &d, &[0.0, 0.0], 1, 8, &spec(3, 0.5, Variant::Practical, 1)).unwrap();
    let c_hat = d.flops(8) / t.flops(8);
    assert!(c_hat > 0.0 && c_hat.is_finite());
}
