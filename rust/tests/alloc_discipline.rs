//! Counting-allocator proof of the kernel layer's zero-allocation claim:
//! a steady-state `NativeModel::forward_cached` performs **zero** heap
//! allocations (packed weights, cache-owned arena, slice return), and a
//! steady-state `NativeSession::extend` allocates only the trait-mandated
//! return `Vec`.
//!
//! This file contains exactly one `#[test]` on purpose: the counter is a
//! process-wide global, and a sibling test allocating concurrently would
//! make the measurement meaningless.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use stride::models::{DecodeSession, NativeBackend};
use stride::nn::{KvCache, ModelDims, NativeModel};

static ALLOCS: AtomicU64 = AtomicU64::new(0);

struct CountingAlloc;

// SAFETY: delegates straight to `System`; the counter uses a lock-free
// atomic and never allocates.
unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }
    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static A: CountingAlloc = CountingAlloc;

fn allocs() -> u64 {
    ALLOCS.load(Ordering::Relaxed)
}

#[test]
fn steady_state_decode_does_not_allocate() {
    let dims = ModelDims { patch: 4, n_ctx: 32, d_model: 16, n_layers: 2, n_heads: 2, d_ff: 32 };
    let model = NativeModel::random("m", dims, 1);
    let toks: Vec<f32> = (0..32 * 4).map(|i| (i as f32 * 0.17).sin()).collect();

    // --- Kernel layer: forward_cached over a prefilled cache, k = 1.
    let mut cache = KvCache::new(&dims);
    let _ = model.forward_cached(&mut cache, &toks, 16).unwrap(); // prefill (allocs OK)
    // Warm one steady-state step so any lazy one-time init is done.
    let _ = model.forward_cached(&mut cache, &toks[16 * 4..17 * 4], 1).unwrap();
    cache.truncate(16);

    let before = allocs();
    for step in 0..8 {
        let _ = model
            .forward_cached(&mut cache, &toks[(16 + step) * 4..(17 + step) * 4], 1)
            .unwrap();
        cache.truncate(16);
    }
    let kernel_allocs = allocs() - before;
    assert_eq!(
        kernel_allocs, 0,
        "forward_cached must be allocation-free in steady state \
         (packed weights + cache-owned arena); counted {kernel_allocs} over 8 steps"
    );

    // γ-sized extends (k up to MAX_DECODE_ROWS) are steady state too: the
    // owned arena covers them and matmul_auto must stay serial (the pool
    // path allocates). k = 16 was exactly the old PAR_MIN_ROWS, so this
    // guards the threshold regression.
    let before = allocs();
    for _ in 0..4 {
        let _ = model.forward_cached(&mut cache, &toks[16 * 4..32 * 4], 16).unwrap();
        cache.truncate(16);
    }
    let gamma_allocs = allocs() - before;
    assert_eq!(
        gamma_allocs, 0,
        "gamma-sized forward_cached (k = 16) must also be allocation-free; \
         counted {gamma_allocs} over 4 steps"
    );

    // --- Session layer: extend/rollback. The DecodeSession contract
    // returns a Vec, so the only permitted allocation per extend is that
    // return value (1 per call; <= 2 leaves room for allocator-internal
    // bookkeeping on some platforms, still far below the dozens a
    // format!-keyed or per-layer-allocating forward would show).
    let backend = NativeBackend::new(model);
    let mut sess = backend.begin_cached(&toks, 16).unwrap();
    // Warm-up: settle Vec capacities and the timing summary.
    for step in 0..4 {
        let _ = sess.extend(&toks[(16 + step) * 4..(17 + step) * 4], 1).unwrap();
        sess.rollback(1).unwrap();
    }
    let before = allocs();
    let rounds = 8u64;
    for step in 0..rounds as usize {
        let _ = sess.extend(&toks[(16 + step) * 4..(17 + step) * 4], 1).unwrap();
        sess.rollback(1).unwrap();
    }
    let per_round = (allocs() - before) as f64 / rounds as f64;
    assert!(
        per_round <= 2.0,
        "steady-state extend should allocate only its return Vec; \
         measured {per_round} allocations per extend+rollback round"
    );
}
