//! Counting-allocator proof of the kernel layer's zero-allocation claim:
//! a steady-state `NativeModel::forward_cached` performs **zero** heap
//! allocations (packed weights, cache-owned arena, slice return), and a
//! steady-state `NativeSession::extend` allocates only the trait-mandated
//! return `Vec`.
//!
//! SIMD + stacked-GEMM PR: the stacked verify tier obeys the same
//! discipline — `forward_cached_stacked` (k > 1 tree verify) and
//! `forward_cached_lockstep` (equal-length batched rounds) are **zero**
//! allocation in steady state after their lane/scratch arenas' one-time
//! high-water allocation, and the session-layer `verify_stacked` with a
//! caller-reused out buffer stays at amortized-zero.
//!
//! Flight-recorder PR: the trace ring obeys the same discipline — a
//! warmed [`stride::trace::TraceSink`] records events (including full
//! per-round spans with their inline alpha array) with **zero** heap
//! allocations, so tracing enabled costs the hot path a sharded mutex
//! and a slab write, never an allocation or an unbounded queue.
//!
//! This file contains exactly one `#[test]` on purpose: the counter is a
//! process-wide global, and a sibling test allocating concurrently would
//! make the measurement meaningless.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use stride::models::{BatchDecodeSession, DecodeSession, NativeBackend};
use stride::nn::{ForwardScratch, KvCache, ModelDims, NativeModel, StackedLanes};

static ALLOCS: AtomicU64 = AtomicU64::new(0);

struct CountingAlloc;

// SAFETY: delegates straight to `System`; the counter uses a lock-free
// atomic and never allocates.
unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }
    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static A: CountingAlloc = CountingAlloc;

fn allocs() -> u64 {
    ALLOCS.load(Ordering::Relaxed)
}

#[test]
fn steady_state_decode_does_not_allocate() {
    let dims = ModelDims { patch: 4, n_ctx: 32, d_model: 16, n_layers: 2, n_heads: 2, d_ff: 32 };
    let model = NativeModel::random("m", dims, 1);
    let toks: Vec<f32> = (0..32 * 4).map(|i| (i as f32 * 0.17).sin()).collect();

    // --- Kernel layer: forward_cached over a prefilled cache, k = 1.
    let mut cache = KvCache::new(&dims);
    let _ = model.forward_cached(&mut cache, &toks, 16).unwrap(); // prefill (allocs OK)
    // Warm one steady-state step so any lazy one-time init is done.
    let _ = model.forward_cached(&mut cache, &toks[16 * 4..17 * 4], 1).unwrap();
    cache.truncate(16);

    let before = allocs();
    for step in 0..8 {
        let _ = model
            .forward_cached(&mut cache, &toks[(16 + step) * 4..(17 + step) * 4], 1)
            .unwrap();
        cache.truncate(16);
    }
    let kernel_allocs = allocs() - before;
    assert_eq!(
        kernel_allocs, 0,
        "forward_cached must be allocation-free in steady state \
         (packed weights + cache-owned arena); counted {kernel_allocs} over 8 steps"
    );

    // γ-sized extends (k up to MAX_DECODE_ROWS) are steady state too: the
    // owned arena covers them and matmul_auto must stay serial (the pool
    // path allocates). k = 16 was exactly the old PAR_MIN_ROWS, so this
    // guards the threshold regression.
    let before = allocs();
    for _ in 0..4 {
        let _ = model.forward_cached(&mut cache, &toks[16 * 4..32 * 4], 16).unwrap();
        cache.truncate(16);
    }
    let gamma_allocs = allocs() - before;
    assert_eq!(
        gamma_allocs, 0,
        "gamma-sized forward_cached (k = 16) must also be allocation-free; \
         counted {gamma_allocs} over 4 steps"
    );

    // --- Stacked tree verify, kernel layer: `forward_cached_stacked`
    // reads the shared prefix from the (immutably borrowed) cache and
    // writes per-branch K/V into the lane arena. After the arena's
    // one-time high-water allocation a verify round must be strictly
    // allocation-free — this is what makes k > 1 tree verify a steady-
    // state serving operation rather than k heap-churning extends.
    let mut lanes = StackedLanes::new();
    let branches = &toks[..3 * 2 * 4]; // b = 3 lanes, k = 2 rows each
    let _ = model.forward_cached_stacked(&cache, &mut lanes, branches, 3, 2).unwrap(); // warm
    let before = allocs();
    for _ in 0..8 {
        let _ = model.forward_cached_stacked(&cache, &mut lanes, branches, 3, 2).unwrap();
    }
    let stacked_allocs = allocs() - before;
    assert_eq!(
        stacked_allocs, 0,
        "forward_cached_stacked must be allocation-free in steady state \
         (lane arena + shared-prefix reads); counted {stacked_allocs} over 8 rounds"
    );

    // --- Lockstep batched rounds, kernel layer: `forward_cached_lockstep`
    // fuses B equal-length decode steps into one forward, appending into
    // each lane's own cache. With an externally owned scratch it is
    // likewise strictly allocation-free in steady state.
    let mut c0 = KvCache::new(&dims);
    let mut c1 = KvCache::new(&dims);
    let _ = model.forward_cached(&mut c0, &toks[..16 * 4], 16).unwrap();
    let _ = model.forward_cached(&mut c1, &toks[..16 * 4], 16).unwrap();
    let mut scratch = ForwardScratch::for_prefill(&dims, 2 * 2);
    let lock_toks = &toks[16 * 4..20 * 4]; // b = 2, k = 2 -> 4 rows
    let _ = model.forward_cached_lockstep(&mut [&mut c0, &mut c1], &mut scratch, lock_toks, 2).unwrap();
    c0.truncate(16);
    c1.truncate(16);
    let before = allocs();
    for _ in 0..8 {
        let _ = model
            .forward_cached_lockstep(&mut [&mut c0, &mut c1], &mut scratch, lock_toks, 2)
            .unwrap();
        c0.truncate(16);
        c1.truncate(16);
    }
    let lockstep_allocs = allocs() - before;
    assert_eq!(
        lockstep_allocs, 0,
        "forward_cached_lockstep must be allocation-free in steady state \
         (external scratch + preallocated caches); counted {lockstep_allocs} over 8 rounds"
    );

    // --- Session layer: extend/rollback. The DecodeSession contract
    // returns a Vec, so the only permitted allocation per extend is that
    // return value (1 per call; <= 2 leaves room for allocator-internal
    // bookkeeping on some platforms, still far below the dozens a
    // format!-keyed or per-layer-allocating forward would show).
    let backend = NativeBackend::new(model);
    let mut sess = backend.begin_cached(&toks, 16).unwrap();
    // Warm-up: settle Vec capacities and the timing summary.
    for step in 0..4 {
        let _ = sess.extend(&toks[(16 + step) * 4..(17 + step) * 4], 1).unwrap();
        sess.rollback(1).unwrap();
    }
    let before = allocs();
    let rounds = 8u64;
    for step in 0..rounds as usize {
        let _ = sess.extend(&toks[(16 + step) * 4..(17 + step) * 4], 1).unwrap();
        sess.rollback(1).unwrap();
    }
    let per_round = (allocs() - before) as f64 / rounds as f64;
    assert!(
        per_round <= 2.0,
        "steady-state extend should allocate only its return Vec; \
         measured {per_round} allocations per extend+rollback round"
    );

    // --- Session layer: `verify_stacked` with a caller-reused out
    // buffer. The kernel work is pinned to zero above; at the session
    // layer the only permitted growth is amortized telemetry (the
    // timing ring doubles rarely), so the per-round average must stay
    // at (near-)zero — far below the b extends a sequential verify
    // would cost in return Vecs alone.
    let vbranches: Vec<f32> = toks[..3 * 2 * 4].to_vec();
    let mut vout: Vec<f32> = Vec::new();
    for _ in 0..4 {
        let used = sess.verify_stacked(&vbranches, 3, 2, &mut vout).unwrap();
        assert!(used, "native session must take the stacked verify path");
    }
    let before = allocs();
    let rounds = 8u64;
    for _ in 0..rounds {
        let used = sess.verify_stacked(&vbranches, 3, 2, &mut vout).unwrap();
        assert!(used, "stacked verify fell back mid-measurement");
    }
    let per_round = (allocs() - before) as f64 / rounds as f64;
    assert!(
        per_round <= 1.0,
        "steady-state verify_stacked with a reused out buffer should be \
         amortized allocation-free; measured {per_round} per round"
    );
    assert_eq!(vout.len(), 3 * (2 + 1) * 4, "verify rows: b * (k+1) * patch");

    // --- Session layer: lockstep batched extend. Equal-length sequences
    // take the fused stacked forward; the per-round budget is the
    // trait-mandated return Vec (plus its growth), the cache-ref gather,
    // and amortized telemetry — a small constant, independent of B,
    // where the fan-out path would pay per-sequence task allocations.
    let h = &toks[..5 * 4];
    let tasks: Vec<(&[f32], usize)> = vec![(h, 5), (h, 5), (h, 5)];
    let mut bs = backend.begin_cached_batch(&tasks).unwrap();
    let fresh = &toks[5 * 4..7 * 4]; // k = 2 rows
    let flat = [fresh, fresh, fresh].concat();
    for _ in 0..4 {
        let _ = bs.extend(&[0, 1, 2], &flat, 2).unwrap();
        for i in 0..3 {
            bs.rollback(i, 2).unwrap();
        }
    }
    let before = allocs();
    for _ in 0..rounds {
        let _ = bs.extend(&[0, 1, 2], &flat, 2).unwrap();
        for i in 0..3 {
            bs.rollback(i, 2).unwrap();
        }
    }
    let per_round = (allocs() - before) as f64 / rounds as f64;
    assert!(
        per_round <= 6.0,
        "steady-state lockstep batched extend should allocate only the \
         return Vec, its growth, and the cache-ref gather; measured \
         {per_round} allocations per round"
    );

    // --- Flight recorder: event recording is strictly allocation-free.
    // The ring's slabs are preallocated at construction and every
    // `EventKind` is `Copy` with inline storage (fixed-size alpha
    // array), so a record is a mutex + slab write — even past wrap,
    // where overflow must be a counted drop, never an allocation.
    use std::time::Duration;
    use stride::trace::{EventKind, TraceSink, MAX_TRACE_ALPHAS};
    let sink = TraceSink::new(64); // small: the loop below wraps it
    let round = EventKind::Round {
        round: 1,
        gamma: 4,
        k: 2,
        draft: 0,
        proposed: 8,
        accepted: 6,
        rollback: 2,
        residual: 1,
        draft_ns: 1_000,
        target_ns: 9_000,
        n_alphas: MAX_TRACE_ALPHAS as u8,
        alphas: [0.9; MAX_TRACE_ALPHAS],
    };
    sink.record(1, EventKind::Requeued); // warm: settle any one-time init
    let before = allocs();
    for i in 0..1_000u64 {
        sink.record(i.max(1), round);
        sink.record_span_ending_now(
            i.max(1),
            Duration::from_micros(10),
            EventKind::Replied { ok: true, status: 200, rounds: 3 },
        );
    }
    let trace_allocs = allocs() - before;
    assert_eq!(
        trace_allocs, 0,
        "TraceSink::record must be allocation-free after construction \
         (preallocated slabs, Copy events, counted-drop overflow); \
         counted {trace_allocs} over 2000 records"
    );
    assert_eq!(sink.recorded(), 2_001, "every record lands in the ledger");
    assert!(sink.dropped() > 0, "the loop must actually have wrapped the ring");
}
