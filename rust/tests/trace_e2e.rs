//! Flight-recorder tracing, end to end: request ids assigned and
//! echoed, per-round speculation spans retrievable by id, Chrome-trace
//! export valid under concurrent load, exact drop accounting on wrap,
//! and — the hard constraint — tracing disabled is bit-identical to
//! tracing enabled at the same seed.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::sync::Arc;

use stride::config::ServeConfig;
use stride::http::http_request;
use stride::models::NativeBackend;
use stride::nn::model::tiny_model;
use stride::server::{ModelShape, ReplicaBuilder, ReplicaStacks, Server};
use stride::trace::{parse_request_id, EventKind, TraceSink};
use stride::util::json::Json;

/// A tiny artifact-free server; `trace_capacity` 0 disables tracing.
fn start(trace_capacity: usize, model_seed: u64) -> Server {
    let mut cfg = ServeConfig::default();
    cfg.bind = "127.0.0.1:0".into();
    cfg.backend = "native".into();
    cfg.trace_capacity = trace_capacity;
    let shape = ModelShape { patch: 4, n_ctx: 8 };
    let builder: ReplicaBuilder = Arc::new(move |_r| {
        Ok(ReplicaStacks {
            target: Box::new(NativeBackend::new(tiny_model(model_seed))),
            draft: Box::new(NativeBackend::new(tiny_model(model_seed + 1))),
        })
    });
    Server::start_with_builder(cfg, shape, builder).unwrap()
}

fn body(seed: u64, request_id: Option<&str>) -> String {
    let hist: Vec<String> = (0..16).map(|i| format!("{}", (i as f32 * 0.17).cos())).collect();
    let rid = request_id.map(|r| format!(r#", "request_id": "{r}""#)).unwrap_or_default();
    format!(r#"{{"history": [{}], "horizon": 4, "seed": {seed}{rid}}}"#, hist.join(","))
}

/// `http_request` with one extra request header (the shared client
/// helper deliberately has no header hook).
fn post_with_header(addr: &str, path: &str, body: &str, header: (&str, &str)) -> (u16, Vec<(String, String)>, String) {
    let mut stream = TcpStream::connect(addr).unwrap();
    let head = format!(
        "POST {path} HTTP/1.1\r\nHost: {addr}\r\nContent-Length: {}\r\n{}: {}\r\nConnection: close\r\n\r\n",
        body.len(),
        header.0,
        header.1
    );
    stream.write_all(head.as_bytes()).unwrap();
    stream.write_all(body.as_bytes()).unwrap();
    let mut reader = BufReader::new(stream);
    let mut status_line = String::new();
    reader.read_line(&mut status_line).unwrap();
    let status: u16 = status_line.split_whitespace().nth(1).unwrap().parse().unwrap();
    let mut headers = Vec::new();
    loop {
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let line = line.trim_end();
        if line.is_empty() {
            break;
        }
        if let Some((k, v)) = line.split_once(':') {
            headers.push((k.trim().to_lowercase(), v.trim().to_string()));
        }
    }
    let mut rest = String::new();
    reader.read_to_string(&mut rest).unwrap();
    // Strip chunked framing if present; body is the JSON object line.
    let body = rest.lines().find(|l| l.starts_with('{')).unwrap_or("").to_string();
    (status, headers, body)
}

fn header<'a>(headers: &'a [(String, String)], name: &str) -> Option<&'a str> {
    headers.iter().find(|(k, _)| k == name).map(|(_, v)| v.as_str())
}

/// The full per-request story: a client-supplied id is echoed in body
/// and header, and `/debug/requests/<id>` returns a timeline whose
/// round count matches the response's `rounds` and whose root span
/// reports the same outcome.
#[test]
fn timeline_by_request_id_matches_response() {
    let server = start(4096, 931);
    let addr = server.addr().to_string();

    let r = http_request(&addr, "POST", "/forecast", Some(body(5, Some("deadbeef")).as_bytes()))
        .unwrap();
    assert_eq!(r.status, 200, "{}", r.body_str());
    let j = Json::parse(r.body_str()).unwrap();
    assert_eq!(j.get("request_id").unwrap().as_str(), Some("00000000deadbeef"));
    assert_eq!(
        header(
            &r.headers.iter().map(|(k, v)| (k.to_lowercase(), v.clone())).collect::<Vec<_>>(),
            "x-request-id"
        ),
        Some("00000000deadbeef"),
        "success replies must echo X-Request-Id"
    );
    let rounds = j.get("rounds").unwrap().as_usize().unwrap();
    assert!(rounds >= 1, "SD decode must run at least one round");

    let t = http_request(&addr, "GET", "/debug/requests/deadbeef", None).unwrap();
    assert_eq!(t.status, 200, "{}", t.body_str());
    let tl = Json::parse(t.body_str()).unwrap();
    assert_eq!(tl.get("request_id").unwrap().as_str(), Some("00000000deadbeef"));
    let events = tl.get("events").unwrap().as_arr().unwrap();
    assert_eq!(tl.get("found").unwrap().as_usize(), Some(events.len()));
    let names: Vec<&str> = events.iter().filter_map(|e| e.get("name").unwrap().as_str()).collect();
    for expected in ["admitted", "queue_wait", "round", "request"] {
        assert!(names.contains(&expected), "timeline missing `{expected}`: {names:?}");
    }
    let traced_rounds = names.iter().filter(|n| **n == "round").count();
    assert_eq!(
        traced_rounds, rounds,
        "recorded round spans must match the response's round count"
    );
    // The root span agrees with the HTTP outcome.
    let root = events.iter().find(|e| e.get("name").unwrap().as_str() == Some("request")).unwrap();
    let args = root.get("args").unwrap();
    assert_eq!(args.get("ok").unwrap().as_bool(), Some(true));
    assert_eq!(args.get("status").unwrap().as_usize(), Some(200));
    assert_eq!(args.get("rounds").unwrap().as_usize(), Some(rounds));
    // Round spans carry the speculation telemetry the paper's ledger
    // needs: gamma, acceptance, and the draft/verify time split.
    let round = events.iter().find(|e| e.get("name").unwrap().as_str() == Some("round")).unwrap();
    let args = round.get("args").unwrap();
    for key in ["gamma", "k", "draft", "proposed", "accepted", "rollback", "draft_ns", "target_ns", "alphas"] {
        assert!(args.get(key).is_some(), "round span missing `{key}`: {args:?}");
    }

    // An unknown (but well-formed) id is found: 0, not an error.
    let miss = http_request(&addr, "GET", "/debug/requests/abc123", None).unwrap();
    assert_eq!(miss.status, 200);
    let tl = Json::parse(miss.body_str()).unwrap();
    assert_eq!(tl.get("found").unwrap().as_usize(), Some(0));
    // A malformed id is a 400, and id 0 is reserved.
    assert_eq!(http_request(&addr, "GET", "/debug/requests/zz", None).unwrap().status, 400);
    assert_eq!(http_request(&addr, "GET", "/debug/requests/0", None).unwrap().status, 400);
}

/// Id assignment and override precedence: no id -> the scheduler
/// assigns a nonzero 16-hex id; `X-Request-Id` header -> honored; both
/// header and body -> the body wins; malformed header -> 400.
#[test]
fn request_id_assignment_and_header_override() {
    let server = start(1024, 941);
    let addr = server.addr().to_string();

    // No id supplied: the server assigns one (16 lowercase hex, nonzero).
    let r = http_request(&addr, "POST", "/forecast", Some(body(1, None).as_bytes())).unwrap();
    assert_eq!(r.status, 200, "{}", r.body_str());
    let assigned =
        Json::parse(r.body_str()).unwrap().get("request_id").unwrap().as_str().unwrap().to_string();
    assert_eq!(assigned.len(), 16, "wire ids are zero-padded 16-hex, got '{assigned}'");
    let rid = parse_request_id(&assigned).expect("assigned id must round-trip");
    assert!(rid != 0, "id 0 is reserved for the control plane");

    // Header override: the reply and the timeline use the client's id.
    let (status, headers, resp_body) =
        post_with_header(&addr, "/forecast", &body(2, None), ("X-Request-Id", "00aa"));
    assert_eq!(status, 200, "{resp_body}");
    assert_eq!(header(&headers, "x-request-id"), Some("00000000000000aa"));
    assert_eq!(
        Json::parse(&resp_body).unwrap().get("request_id").unwrap().as_str(),
        Some("00000000000000aa")
    );

    // Body beats header when both are present.
    let (status, headers, _) =
        post_with_header(&addr, "/forecast", &body(3, Some("bb")), ("X-Request-Id", "cc"));
    assert_eq!(status, 200);
    assert_eq!(header(&headers, "x-request-id"), Some("00000000000000bb"));

    // A malformed header is rejected up front.
    let (status, _, _) =
        post_with_header(&addr, "/forecast", &body(4, None), ("X-Request-Id", "not-hex"));
    assert_eq!(status, 400, "malformed X-Request-Id must be a 400");
    let (status, _, _) =
        post_with_header(&addr, "/forecast", &body(4, None), ("X-Request-Id", "0"));
    assert_eq!(status, 400, "X-Request-Id 0 is reserved");
}

/// `/debug/trace` stays valid Chrome trace-event JSON while requests
/// are in flight, and the smoke artifact for CI is written from a
/// concurrently-scraped snapshot.
#[test]
fn chrome_trace_valid_under_concurrent_load() {
    let server = start(8192, 951);
    let addr = Arc::new(server.addr().to_string());

    let mut handles = Vec::new();
    for w in 0..4u64 {
        let addr = Arc::clone(&addr);
        handles.push(std::thread::spawn(move || {
            for i in 0..6u64 {
                let r = http_request(&addr, "POST", "/forecast", Some(body(w * 100 + i, None).as_bytes()))
                    .unwrap();
                assert_eq!(r.status, 200, "{}", r.body_str());
                // Scrape mid-flight: the export must always parse.
                let t = http_request(&addr, "GET", "/debug/trace", None).unwrap();
                assert_eq!(t.status, 200);
                let parsed = Json::parse(t.body_str()).unwrap_or_else(|e| {
                    panic!("/debug/trace must stay valid JSON under load: {e:#}")
                });
                for e in parsed.as_arr().unwrap() {
                    assert_eq!(e.get("ph").unwrap().as_str(), Some("X"));
                    assert_eq!(e.get("pid").unwrap().as_usize(), Some(1));
                    assert!(e.get("ts").unwrap().as_usize().is_some());
                    assert!(e.get("dur").unwrap().as_usize().is_some());
                }
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }

    // /stats carries the recorder's ledger.
    let j = Json::parse(http_request(&addr, "GET", "/stats", None).unwrap().body_str()).unwrap();
    let trace = j.get("trace").expect("/stats must carry a trace block");
    assert_eq!(trace.get("enabled").unwrap().as_bool(), Some(true));
    assert!(trace.get("recorded").unwrap().as_usize().unwrap() > 0);

    // Persist the export for ci.sh's JSON validation step.
    let out = http_request(&addr, "GET", "/debug/trace", None).unwrap();
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("results");
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("trace_smoke.json"), out.body_str()).unwrap();
}

/// Exact drop accounting on wrap: a deliberately tiny ring keeps
/// serving, every overflow is a counted drop (never a block), and
/// `recorded - dropped` equals what the snapshot can actually return.
#[test]
fn ring_wrap_drops_are_counted_exactly() {
    // Library-level, deterministic: hammer one sink far past capacity.
    let sink = TraceSink::new(64);
    for i in 0..10_000u64 {
        sink.record(i.max(1), EventKind::Requeued);
    }
    assert_eq!(sink.recorded(), 10_000);
    let live = sink.snapshot().len() as u64;
    assert_eq!(
        sink.recorded() - sink.dropped(),
        live,
        "every recorded event is either live in the ring or a counted drop"
    );
    assert!(live <= sink.capacity() as u64);

    // End to end: a tiny server-side ring under real traffic obeys the
    // same invariant, visible through /stats.
    let server = start(16, 961);
    let addr = server.addr().to_string();
    for i in 0..12u64 {
        let r = http_request(&addr, "POST", "/forecast", Some(body(i, None).as_bytes())).unwrap();
        assert_eq!(r.status, 200);
    }
    let j = Json::parse(http_request(&addr, "GET", "/stats", None).unwrap().body_str()).unwrap();
    let trace = j.get("trace").unwrap();
    let recorded = trace.get("recorded").unwrap().as_usize().unwrap() as u64;
    let dropped = trace.get("dropped").unwrap().as_usize().unwrap() as u64;
    let t = http_request(&addr, "GET", "/debug/trace", None).unwrap();
    let live = Json::parse(t.body_str()).unwrap().as_arr().unwrap().len() as u64;
    // The scrape races ongoing control-plane events, so allow the
    // ledger to have advanced past the snapshot — never the reverse.
    assert!(recorded >= live, "recorded {recorded} >= live {live}");
    assert!(dropped <= recorded);
    assert!(recorded - dropped >= live.min(16), "drop ledger lost events");
}

/// The hard constraint: tracing disabled is not observably different
/// from enabled — same seed, bit-identical forecasts — and the debug
/// surface degrades to typed 404s instead of half-working.
#[test]
fn disabled_tracing_is_bit_identical_and_typed_off() {
    let off = start(0, 971);
    let on = start(4096, 971);
    let b = body(9, Some("feed"));

    let bits = |server: &Server| -> Vec<u32> {
        let r = http_request(&server.addr().to_string(), "POST", "/forecast", Some(b.as_bytes()))
            .unwrap();
        assert_eq!(r.status, 200, "{}", r.body_str());
        Json::parse(r.body_str())
            .unwrap()
            .get("forecast")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|v| (v.as_f64().unwrap() as f32).to_bits())
            .collect()
    };
    assert_eq!(bits(&off), bits(&on), "tracing must not perturb decoding");

    // Disabled: the debug surface is a typed 404, /stats says so.
    let addr = off.addr().to_string();
    let r = http_request(&addr, "GET", "/debug/trace", None).unwrap();
    assert_eq!(r.status, 404);
    assert!(r.body_str().contains("trace-capacity"), "{}", r.body_str());
    assert_eq!(http_request(&addr, "GET", "/debug/requests/feed", None).unwrap().status, 404);
    let j = Json::parse(http_request(&addr, "GET", "/stats", None).unwrap().body_str()).unwrap();
    let trace = j.get("trace").unwrap();
    assert_eq!(trace.get("enabled").unwrap().as_bool(), Some(false));
    assert_eq!(trace.get("recorded").unwrap().as_usize(), Some(0));

    // Enabled: the same request is fully reconstructible.
    let addr = on.addr().to_string();
    let t = http_request(&addr, "GET", "/debug/requests/feed", None).unwrap();
    assert_eq!(t.status, 200);
    assert!(Json::parse(t.body_str()).unwrap().get("found").unwrap().as_usize().unwrap() >= 1);
}
