//! End-to-end serving test: start the coordinator on an ephemeral port with
//! the native backend (fast, PJRT-free) and exercise the full HTTP surface,
//! including batched concurrent load and error paths.
//!
//! Two substrates:
//! * artifact-backed tests (skip without `make artifacts`) over the real
//!   manifest models, as before;
//! * artifact-free scheduler tests over `Server::start_with_builder` +
//!   `tiny_model` replicas — shedding, deadline expiry, priority
//!   inversion, replica-count invariance, and `/healthz` readiness run
//!   everywhere.

use std::sync::Arc;

use stride::config::ServeConfig;
use stride::data::Dataset;
use stride::http::http_request;
use stride::models::NativeBackend;
use stride::nn::model::tiny_model;
use stride::server::{ModelShape, ReplicaBuilder, ReplicaStacks, Server};
use stride::util::json::Json;

fn start_server() -> Option<Server> {
    if !stride::artifacts_dir().join("manifest.json").exists() {
        eprintln!("SKIP: run `make artifacts`");
        return None;
    }
    let mut cfg = ServeConfig::default();
    cfg.bind = "127.0.0.1:0".into();
    cfg.backend = "native".into(); // keep the e2e test PJRT-free and fast
    cfg.max_batch = 4;
    cfg.max_wait_ms = 5;
    Some(Server::start(cfg).expect("server start"))
}

fn history_json(n_points: usize) -> String {
    let data = Dataset::by_name("etth1").unwrap();
    let vals = data.norm_slice(0, 12_000, n_points);
    let nums: Vec<String> = vals.iter().map(|v| format!("{v}")).collect();
    format!("[{}]", nums.join(","))
}

#[test]
fn healthz_metrics_stats() {
    let Some(server) = start_server() else { return };
    let addr = server.addr().to_string();
    let r = http_request(&addr, "GET", "/healthz", None).unwrap();
    assert_eq!(r.status, 200);
    assert!(r.body_str().contains("ok"));

    let r = http_request(&addr, "GET", "/metrics", None).unwrap();
    assert_eq!(r.status, 200);
    assert!(r.body_str().contains("stride_requests_total"));

    let r = http_request(&addr, "GET", "/stats", None).unwrap();
    assert_eq!(r.status, 200);
    let j = Json::parse(r.body_str()).unwrap();
    assert!(j.get("requests").is_some());

    let r = http_request(&addr, "GET", "/nope", None).unwrap();
    assert_eq!(r.status, 404);
}

#[test]
fn forecast_sd_and_baseline() {
    let Some(server) = start_server() else { return };
    let addr = server.addr().to_string();
    let hist = history_json(96);

    for mode in ["sd", "baseline", "draft"] {
        let body = format!(r#"{{"history": {hist}, "horizon": 4, "mode": "{mode}"}}"#);
        let r = http_request(&addr, "POST", "/forecast", Some(body.as_bytes())).unwrap();
        assert_eq!(r.status, 200, "mode {mode}: {}", r.body_str());
        let j = Json::parse(r.body_str()).unwrap();
        assert_eq!(j.get("mode").unwrap().as_str(), Some(mode));
        let forecast = j.get("forecast").unwrap().as_arr().unwrap();
        assert_eq!(forecast.len(), 4 * 24, "mode {mode}");
        assert!(j.get("latency_ms").unwrap().as_f64().unwrap() > 0.0);
        if mode == "sd" {
            assert!(j.get("alpha_hat").unwrap().as_f64().unwrap() > 0.0);
            assert!(j.get("draft_calls").unwrap().as_usize().unwrap() > 0);
        }
    }
}

#[test]
fn per_request_overrides() {
    let Some(server) = start_server() else { return };
    let addr = server.addr().to_string();
    let hist = history_json(96);
    let body = format!(r#"{{"history": {hist}, "horizon": 3, "gamma": 2, "sigma": 0.9}}"#);
    let r = http_request(&addr, "POST", "/forecast", Some(body.as_bytes())).unwrap();
    assert_eq!(r.status, 200, "{}", r.body_str());
    let j = Json::parse(r.body_str()).unwrap();
    assert_eq!(j.get("forecast").unwrap().as_arr().unwrap().len(), 3 * 24);
}

#[test]
fn rejects_invalid_requests() {
    let Some(server) = start_server() else { return };
    let addr = server.addr().to_string();
    // Bad JSON.
    let r = http_request(&addr, "POST", "/forecast", Some(b"{nope")).unwrap();
    assert_eq!(r.status, 400);
    // Missing horizon.
    let r = http_request(&addr, "POST", "/forecast", Some(br#"{"history":[1.0]}"#)).unwrap();
    assert_eq!(r.status, 400);
    // History not a multiple of the patch size (server-side validation):
    // a typed 400 with a machine-readable code since the scheduler PR.
    let r = http_request(
        &addr,
        "POST",
        "/forecast",
        Some(br#"{"history":[1.0,2.0,3.0], "horizon": 2}"#),
    )
    .unwrap();
    assert_eq!(r.status, 400, "{}", r.body_str());
    assert!(r.body_str().contains("multiple of patch"));
    assert!(r.body_str().contains("\"error_code\":\"invalid\""));
}

#[test]
fn concurrent_load_is_batched_and_correct() {
    let Some(server) = start_server() else { return };
    let addr = Arc::new(server.addr().to_string());
    let hist = Arc::new(history_json(96));
    let n_clients = 12;
    let handles: Vec<_> = (0..n_clients)
        .map(|_| {
            let addr = Arc::clone(&addr);
            let hist = Arc::clone(&hist);
            std::thread::spawn(move || {
                let body = format!(r#"{{"history": {hist}, "horizon": 4}}"#);
                let r = http_request(&addr, "POST", "/forecast", Some(body.as_bytes())).unwrap();
                assert_eq!(r.status, 200);
                let j = Json::parse(r.body_str()).unwrap();
                assert_eq!(j.get("forecast").unwrap().as_arr().unwrap().len(), 96);
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    // Batching must have happened: fewer batches than jobs.
    let m = http_request(&addr, "GET", "/metrics", None).unwrap();
    let text = m.body_str().to_string();
    let get = |k: &str| -> u64 {
        text.lines()
            .find(|l| l.starts_with(k))
            .and_then(|l| l.split_whitespace().nth(1))
            .and_then(|v| v.parse().ok())
            .unwrap_or(0)
    };
    assert_eq!(get("stride_requests_total"), n_clients as u64);
    let batches = get("stride_batches");
    assert!(batches >= 1 && batches <= n_clients as u64);
    eprintln!("{} requests served in {} batches", n_clients, batches);
}

// ---------------------------------------------------------------------------
// Artifact-free scheduler tests: full HTTP + admission + EDF + replica pool
// over synthetic tiny models (Server::start_with_builder). These run in
// every environment.
// ---------------------------------------------------------------------------

fn tiny_shape() -> ModelShape {
    ModelShape { patch: 4, n_ctx: 8 }
}

fn tiny_builder() -> ReplicaBuilder {
    Arc::new(move |_r| {
        Ok(ReplicaStacks {
            target: Box::new(NativeBackend::new(tiny_model(901))),
            draft: Box::new(NativeBackend::new(tiny_model(902))),
        })
    })
}

fn sched_cfg(replicas: usize) -> ServeConfig {
    let mut cfg = ServeConfig::default();
    cfg.bind = "127.0.0.1:0".into();
    cfg.backend = "native".into();
    cfg.replicas = replicas;
    cfg.http_workers = 32;
    cfg
}

fn start_tiny(cfg: ServeConfig) -> Server {
    Server::start_with_builder(cfg, tiny_shape(), tiny_builder()).expect("builder server start")
}

fn tiny_hist() -> Vec<f32> {
    (0..4 * 4).map(|i| (i as f32 * 0.23).sin()).collect()
}

fn hist_json(h: &[f32]) -> String {
    let nums: Vec<String> = h.iter().map(|v| format!("{v}")).collect();
    format!("[{}]", nums.join(","))
}

fn metric(addr: &str, key: &str) -> u64 {
    let text = http_request(addr, "GET", "/metrics", None).unwrap().body_str().to_string();
    text.lines()
        .find(|l| l.starts_with(key) && l.split_whitespace().next() == Some(key))
        .and_then(|l| l.split_whitespace().nth(1))
        .and_then(|v| v.parse().ok())
        .unwrap_or(0)
}

/// Criterion (c) of the scheduler PR, at the HTTP level: scheduled
/// responses are bit-identical to the unscheduled `sd_generate_from`
/// engine at the same request + seed, for every replica count — with
/// mixed-group concurrent traffic forcing nontrivial batch compositions.
#[test]
fn scheduled_responses_match_unscheduled_engine_for_any_replica_count() {
    use stride::specdec::{make_source, sd_generate_from, DraftKind};
    let hist = tiny_hist();
    // (gamma, sigma, draft kind, seed, horizon) — two compatibility
    // groups per kind.
    let combos: Vec<(usize, f64, &str, u64, usize)> = vec![
        (2, 0.5, "model", 11, 6),
        (3, 0.8, "model", 22, 5),
        (2, 0.5, "extrap", 33, 7),
        (3, 0.6, "extrap", 44, 4),
        (2, 0.5, "model", 55, 6),
        (2, 0.5, "extrap", 66, 6),
    ];
    // Unscheduled references straight off the decode engine.
    let t = NativeBackend::new(tiny_model(901));
    let d = NativeBackend::new(tiny_model(902));
    let mut refs: Vec<Vec<u32>> = Vec::new();
    for &(g, s, kind, seed, hz) in &combos {
        let mut spec = sched_cfg(1).spec_config();
        spec.gamma = g;
        spec.policy.sigma = s;
        spec.seed = seed;
        spec.draft.kind = DraftKind::parse(kind).unwrap();
        let mut src = make_source(&spec.draft, &d).unwrap();
        let out = sd_generate_from(&t, src.as_mut(), &hist, 4, hz, &spec).unwrap();
        refs.push(out.patches.iter().map(|v| v.to_bits()).collect());
    }
    let hist_s = Arc::new(hist_json(&hist));
    for replicas in [1usize, 2, 3] {
        let server = start_tiny(sched_cfg(replicas));
        let addr = Arc::new(server.addr().to_string());
        let handles: Vec<_> = combos
            .iter()
            .map(|&(g, s, kind, seed, hz)| {
                let addr = Arc::clone(&addr);
                let hist_s = Arc::clone(&hist_s);
                std::thread::spawn(move || {
                    let body = format!(
                        r#"{{"history": {hist_s}, "horizon": {hz}, "gamma": {g},
                            "sigma": {s}, "draft": "{kind}", "seed": {seed}}}"#
                    );
                    let r =
                        http_request(&addr, "POST", "/forecast", Some(body.as_bytes())).unwrap();
                    assert_eq!(r.status, 200, "{}", r.body_str());
                    let j = Json::parse(r.body_str()).unwrap();
                    let bits: Vec<u32> = j
                        .get("forecast")
                        .unwrap()
                        .as_arr()
                        .unwrap()
                        .iter()
                        .map(|v| (v.as_f64().unwrap() as f32).to_bits())
                        .collect();
                    bits
                })
            })
            .collect();
        for (i, h) in handles.into_iter().enumerate() {
            let got = h.join().unwrap();
            assert_eq!(
                got, refs[i],
                "replicas={replicas}: combo {i} diverged from the unscheduled engine"
            );
        }
    }
}

#[test]
fn saturation_sheds_with_retry_after() {
    let mut cfg = sched_cfg(1);
    cfg.queue_cap = 1;
    cfg.max_batch = 1;
    cfg.retry_after_ms = 1500;
    let server = start_tiny(cfg);
    let addr = Arc::new(server.addr().to_string());
    let hist = Arc::new(hist_json(&tiny_hist()));
    let handles: Vec<_> = (0..24)
        .map(|_| {
            let addr = Arc::clone(&addr);
            let hist = Arc::clone(&hist);
            std::thread::spawn(move || {
                let body = format!(r#"{{"history": {hist}, "horizon": 1024}}"#);
                http_request(&addr, "POST", "/forecast", Some(body.as_bytes())).unwrap()
            })
        })
        .collect();
    let mut ok = 0;
    let mut shed = 0;
    for h in handles {
        let r = h.join().unwrap();
        match r.status {
            200 => ok += 1,
            429 => {
                shed += 1;
                assert!(r.body_str().contains("\"error_code\":\"shed\""), "{}", r.body_str());
                let retry = r
                    .headers
                    .iter()
                    .find(|(k, _)| k.eq_ignore_ascii_case("retry-after"))
                    .map(|(_, v)| v.clone());
                assert_eq!(retry.as_deref(), Some("2"), "1500 ms rounds up to 2 s");
            }
            other => panic!("unexpected status {other}: {}", r.body_str()),
        }
    }
    assert!(ok >= 1, "at least one request must be served");
    assert!(shed >= 1, "a queue cap of 1 under a 24-way burst must shed");
    assert!(metric(&addr, "stride_sheds_total") >= shed as u64);
}

#[test]
fn expired_deadline_fails_fast_with_504() {
    let mut cfg = sched_cfg(1);
    cfg.max_batch = 1;
    let server = start_tiny(cfg);
    let addr = Arc::new(server.addr().to_string());
    let hist = Arc::new(hist_json(&tiny_hist()));
    // Occupy the single replica with a high-priority flood; EDF keeps it
    // ahead of the low-priority probe below.
    let flood: Vec<_> = (0..16)
        .map(|_| {
            let addr = Arc::clone(&addr);
            let hist = Arc::clone(&hist);
            std::thread::spawn(move || {
                let body =
                    format!(r#"{{"history": {hist}, "horizon": 1024, "priority": "high"}}"#);
                let r = http_request(&addr, "POST", "/forecast", Some(body.as_bytes())).unwrap();
                assert_eq!(r.status, 200, "{}", r.body_str());
            })
        })
        .collect();
    std::thread::sleep(std::time::Duration::from_millis(20));
    // A low-priority request with a tight deadline sits behind the flood
    // and must be failed fast — decoded never, answered 504.
    let body = format!(
        r#"{{"history": {hist}, "horizon": 4, "priority": "low", "deadline_ms": 25}}"#
    );
    let r = http_request(&addr, "POST", "/forecast", Some(body.as_bytes())).unwrap();
    assert_eq!(r.status, 504, "{}", r.body_str());
    assert!(r.body_str().contains("\"error_code\":\"deadline_expired\""));
    for h in flood {
        h.join().unwrap();
    }
    assert!(metric(&addr, "stride_expired_total") >= 1);
}

#[test]
fn high_priority_is_not_starved_by_low_flood() {
    let mut cfg = sched_cfg(1);
    cfg.max_batch = 2;
    let server = start_tiny(cfg);
    let addr = Arc::new(server.addr().to_string());
    let hist = Arc::new(hist_json(&tiny_hist()));
    let t0 = std::time::Instant::now();
    let lows: Vec<_> = (0..12)
        .map(|_| {
            let addr = Arc::clone(&addr);
            let hist = Arc::clone(&hist);
            std::thread::spawn(move || {
                let body =
                    format!(r#"{{"history": {hist}, "horizon": 1024, "priority": "low"}}"#);
                let r = http_request(&addr, "POST", "/forecast", Some(body.as_bytes())).unwrap();
                assert_eq!(r.status, 200, "{}", r.body_str());
                t0.elapsed()
            })
        })
        .collect();
    std::thread::sleep(std::time::Duration::from_millis(20));
    let body = format!(r#"{{"history": {hist}, "horizon": 32, "priority": "high"}}"#);
    let r = http_request(&addr, "POST", "/forecast", Some(body.as_bytes())).unwrap();
    let high_done = t0.elapsed();
    assert_eq!(r.status, 200, "{}", r.body_str());
    let j = Json::parse(r.body_str()).unwrap();
    assert_eq!(j.get("priority").unwrap().as_str(), Some("high"));
    let low_finish: Vec<_> = lows.into_iter().map(|h| h.join().unwrap()).collect();
    let last_low = low_finish.iter().max().unwrap();
    assert!(
        high_done < *last_low,
        "high-priority request ({high_done:?}) starved behind the low flood (last low {last_low:?})"
    );
}

#[test]
fn healthz_readiness_flips_under_saturation() {
    let mut cfg = sched_cfg(1);
    cfg.queue_cap = 1;
    cfg.max_batch = 1;
    let server = start_tiny(cfg);
    let addr = Arc::new(server.addr().to_string());
    // Fresh server: ready.
    let r = http_request(&addr, "GET", "/healthz", None).unwrap();
    assert_eq!(r.status, 200);
    let j = Json::parse(r.body_str()).unwrap();
    assert_eq!(j.get("ready").unwrap().as_bool(), Some(true));
    // Saturate: one decode in flight + one queued hits the cap of 1.
    let hist = Arc::new(hist_json(&tiny_hist()));
    let flood: Vec<_> = (0..16)
        .map(|_| {
            let addr = Arc::clone(&addr);
            let hist = Arc::clone(&hist);
            std::thread::spawn(move || {
                let body = format!(r#"{{"history": {hist}, "horizon": 1024}}"#);
                let _ = http_request(&addr, "POST", "/forecast", Some(body.as_bytes()));
            })
        })
        .collect();
    let mut saw_unready = false;
    for _ in 0..600 {
        let r = http_request(&addr, "GET", "/healthz", None).unwrap();
        if r.status == 503 {
            let j = Json::parse(r.body_str()).unwrap();
            assert_eq!(j.get("ready").unwrap().as_bool(), Some(false));
            saw_unready = true;
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(5));
    }
    for h in flood {
        h.join().unwrap();
    }
    assert!(saw_unready, "healthz never reported saturation under a 16-way burst at cap 1");
    // Drained: ready again.
    let mut ready_again = false;
    for _ in 0..600 {
        let r = http_request(&addr, "GET", "/healthz", None).unwrap();
        if r.status == 200 {
            ready_again = true;
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(5));
    }
    assert!(ready_again, "healthz stuck unready after the queue drained");
}

#[test]
fn stats_scheduler_block_is_present() {
    let server = start_tiny(sched_cfg(2));
    let addr = server.addr().to_string();
    let hist = hist_json(&tiny_hist());
    let body = format!(
        r#"{{"history": {hist}, "horizon": 4, "priority": "high", "deadline_ms": 60000}}"#
    );
    let r = http_request(&addr, "POST", "/forecast", Some(body.as_bytes())).unwrap();
    assert_eq!(r.status, 200, "{}", r.body_str());
    let j = Json::parse(http_request(&addr, "GET", "/stats", None).unwrap().body_str()).unwrap();
    let sched = j.get("scheduler").expect("scheduler block");
    assert_eq!(sched.get("policy").unwrap().as_str(), Some("edf"));
    assert_eq!(sched.get("replicas").unwrap().as_usize(), Some(2));
    assert!(sched.get("queue_cap").unwrap().as_usize().unwrap() >= 1);
    let prio = sched.get("priorities").unwrap().get("high").expect("high priority block");
    // The generous-deadline request above must have met its SLO.
    assert_eq!(prio.get("slo_attainment").unwrap().as_f64(), Some(1.0));
}

#[test]
fn acceptance_monitor_populates() {
    let Some(server) = start_server() else { return };
    let addr = server.addr().to_string();
    let hist = history_json(96);
    for _ in 0..3 {
        let body = format!(r#"{{"history": {hist}, "horizon": 4}}"#);
        let _ = http_request(&addr, "POST", "/forecast", Some(body.as_bytes())).unwrap();
    }
    let r = http_request(&addr, "GET", "/stats", None).unwrap();
    let j = Json::parse(r.body_str()).unwrap();
    let alpha = j.get("alpha_bar_window").unwrap();
    assert!(alpha.as_f64().is_some(), "monitor should have samples: {alpha:?}");
}
