//! End-to-end serving test: start the coordinator on an ephemeral port with
//! the native backend (fast, PJRT-free) and exercise the full HTTP surface,
//! including batched concurrent load and error paths.

use std::sync::Arc;

use stride::config::ServeConfig;
use stride::data::Dataset;
use stride::http::http_request;
use stride::server::Server;
use stride::util::json::Json;

fn start_server() -> Option<Server> {
    if !stride::artifacts_dir().join("manifest.json").exists() {
        eprintln!("SKIP: run `make artifacts`");
        return None;
    }
    let mut cfg = ServeConfig::default();
    cfg.bind = "127.0.0.1:0".into();
    cfg.backend = "native".into(); // keep the e2e test PJRT-free and fast
    cfg.max_batch = 4;
    cfg.max_wait_ms = 5;
    Some(Server::start(cfg).expect("server start"))
}

fn history_json(n_points: usize) -> String {
    let data = Dataset::by_name("etth1").unwrap();
    let vals = data.norm_slice(0, 12_000, n_points);
    let nums: Vec<String> = vals.iter().map(|v| format!("{v}")).collect();
    format!("[{}]", nums.join(","))
}

#[test]
fn healthz_metrics_stats() {
    let Some(server) = start_server() else { return };
    let addr = server.addr().to_string();
    let r = http_request(&addr, "GET", "/healthz", None).unwrap();
    assert_eq!(r.status, 200);
    assert!(r.body_str().contains("ok"));

    let r = http_request(&addr, "GET", "/metrics", None).unwrap();
    assert_eq!(r.status, 200);
    assert!(r.body_str().contains("stride_requests_total"));

    let r = http_request(&addr, "GET", "/stats", None).unwrap();
    assert_eq!(r.status, 200);
    let j = Json::parse(r.body_str()).unwrap();
    assert!(j.get("requests").is_some());

    let r = http_request(&addr, "GET", "/nope", None).unwrap();
    assert_eq!(r.status, 404);
}

#[test]
fn forecast_sd_and_baseline() {
    let Some(server) = start_server() else { return };
    let addr = server.addr().to_string();
    let hist = history_json(96);

    for mode in ["sd", "baseline", "draft"] {
        let body = format!(r#"{{"history": {hist}, "horizon": 4, "mode": "{mode}"}}"#);
        let r = http_request(&addr, "POST", "/forecast", Some(body.as_bytes())).unwrap();
        assert_eq!(r.status, 200, "mode {mode}: {}", r.body_str());
        let j = Json::parse(r.body_str()).unwrap();
        assert_eq!(j.get("mode").unwrap().as_str(), Some(mode));
        let forecast = j.get("forecast").unwrap().as_arr().unwrap();
        assert_eq!(forecast.len(), 4 * 24, "mode {mode}");
        assert!(j.get("latency_ms").unwrap().as_f64().unwrap() > 0.0);
        if mode == "sd" {
            assert!(j.get("alpha_hat").unwrap().as_f64().unwrap() > 0.0);
            assert!(j.get("draft_calls").unwrap().as_usize().unwrap() > 0);
        }
    }
}

#[test]
fn per_request_overrides() {
    let Some(server) = start_server() else { return };
    let addr = server.addr().to_string();
    let hist = history_json(96);
    let body = format!(r#"{{"history": {hist}, "horizon": 3, "gamma": 2, "sigma": 0.9}}"#);
    let r = http_request(&addr, "POST", "/forecast", Some(body.as_bytes())).unwrap();
    assert_eq!(r.status, 200, "{}", r.body_str());
    let j = Json::parse(r.body_str()).unwrap();
    assert_eq!(j.get("forecast").unwrap().as_arr().unwrap().len(), 3 * 24);
}

#[test]
fn rejects_invalid_requests() {
    let Some(server) = start_server() else { return };
    let addr = server.addr().to_string();
    // Bad JSON.
    let r = http_request(&addr, "POST", "/forecast", Some(b"{nope")).unwrap();
    assert_eq!(r.status, 400);
    // Missing horizon.
    let r = http_request(&addr, "POST", "/forecast", Some(br#"{"history":[1.0]}"#)).unwrap();
    assert_eq!(r.status, 400);
    // History not a multiple of the patch size (server-side validation).
    let r = http_request(
        &addr,
        "POST",
        "/forecast",
        Some(br#"{"history":[1.0,2.0,3.0], "horizon": 2}"#),
    )
    .unwrap();
    assert_eq!(r.status, 500, "{}", r.body_str());
    assert!(r.body_str().contains("multiple of patch"));
}

#[test]
fn concurrent_load_is_batched_and_correct() {
    let Some(server) = start_server() else { return };
    let addr = Arc::new(server.addr().to_string());
    let hist = Arc::new(history_json(96));
    let n_clients = 12;
    let handles: Vec<_> = (0..n_clients)
        .map(|_| {
            let addr = Arc::clone(&addr);
            let hist = Arc::clone(&hist);
            std::thread::spawn(move || {
                let body = format!(r#"{{"history": {hist}, "horizon": 4}}"#);
                let r = http_request(&addr, "POST", "/forecast", Some(body.as_bytes())).unwrap();
                assert_eq!(r.status, 200);
                let j = Json::parse(r.body_str()).unwrap();
                assert_eq!(j.get("forecast").unwrap().as_arr().unwrap().len(), 96);
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    // Batching must have happened: fewer batches than jobs.
    let m = http_request(&addr, "GET", "/metrics", None).unwrap();
    let text = m.body_str().to_string();
    let get = |k: &str| -> u64 {
        text.lines()
            .find(|l| l.starts_with(k))
            .and_then(|l| l.split_whitespace().nth(1))
            .and_then(|v| v.parse().ok())
            .unwrap_or(0)
    };
    assert_eq!(get("stride_requests_total"), n_clients as u64);
    let batches = get("stride_batches");
    assert!(batches >= 1 && batches <= n_clients as u64);
    eprintln!("{} requests served in {} batches", n_clients, batches);
}

#[test]
fn acceptance_monitor_populates() {
    let Some(server) = start_server() else { return };
    let addr = server.addr().to_string();
    let hist = history_json(96);
    for _ in 0..3 {
        let body = format!(r#"{{"history": {hist}, "horizon": 4}}"#);
        let _ = http_request(&addr, "POST", "/forecast", Some(body.as_bytes())).unwrap();
    }
    let r = http_request(&addr, "GET", "/stats", None).unwrap();
    let j = Json::parse(r.body_str()).unwrap();
    let alpha = j.get("alpha_bar_window").unwrap();
    assert!(alpha.as_f64().is_some(), "monitor should have samples: {alpha:?}");
}
