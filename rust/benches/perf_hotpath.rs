//! L3 hot-path microbenchmarks (the §Perf profile targets): acceptance
//! math, Gaussian sampling, literal marshalling (PJRT boundary), JSON
//! parse/serialize of the wire protocol, and end-to-end forward costs per
//! backend. These are the numbers the performance pass iterates on.

use stride::accept::AcceptancePolicy;
use stride::util::microbench::{bencher_from_env, Table};
use stride::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let b = bencher_from_env();
    let mut table = Table::new(
        "Perf: L3 hot-path microbenchmarks",
        &["op", "mean", "p50", "p99", "unit/iter"],
    );
    let fmt = |r: &stride::util::microbench::BenchResult, unit: &str| {
        vec![
            r.name.clone(),
            format!("{:.2}us", r.mean_ns / 1e3),
            format!("{:.2}us", r.p50_ns / 1e3),
            format!("{:.2}us", r.p99_ns / 1e3),
            unit.to_string(),
        ]
    };

    // Acceptance alpha over a 24-dim patch (the per-proposal cost).
    let policy = AcceptancePolicy::new(0.5, 1.0);
    let mut rng = Rng::new(1);
    let x: Vec<f32> = (0..24).map(|_| rng.normal() as f32).collect();
    let mu_p: Vec<f32> = (0..24).map(|_| rng.normal() as f32).collect();
    let mu_q: Vec<f32> = (0..24).map(|_| rng.normal() as f32).collect();
    let mut acc = 0.0;
    let r = b.run("accept_alpha_d24", || {
        acc += policy.alpha(&x, &mu_p, &mu_q);
    });
    table.row(fmt(&r, "1 alpha"));
    std::hint::black_box(acc);

    // Patch sampling (draft proposal emission).
    let mut out = vec![0.0f32; 24];
    let r = b.run("sample_patch_d24", || {
        rng.fill_normal_around(&mu_q, 0.5, &mut out);
    });
    table.row(fmt(&r, "1 patch"));

    // Wire protocol: parse + serialize a forecast request/response.
    let hist: Vec<String> = (0..96).map(|i| format!("{:.4}", (i as f32 * 0.1).sin())).collect();
    let req_body = format!(r#"{{"history": [{}], "horizon": 4}}"#, hist.join(","));
    let r = b.run("json_parse_request", || {
        let j = stride::util::json::Json::parse(&req_body).unwrap();
        std::hint::black_box(stride::server::ForecastRequest::from_json(&j).unwrap());
    });
    table.row(fmt(&r, "1 req"));

    let resp = stride::server::ForecastResponse {
        forecast: (0..96).map(|i| i as f32).collect(),
        mode: "sd".into(),
        latency_ms: 1.0,
        alpha_hat: 0.97,
        mean_block_len: 3.4,
        rounds: 2,
        draft_calls: 6,
        target_calls: 2,
    };
    let r = b.run("json_serialize_response", || {
        std::hint::black_box(resp.to_json().to_string());
    });
    table.row(fmt(&r, "1 resp"));

    // Backend forwards (the dominant cost; includes the PJRT literal
    // marshalling boundary for the XLA rows).
    if stride::artifacts_dir().join("manifest.json").exists() {
        let bench = stride::repro::Bench::xla()?;
        let n = bench.manifest.n_ctx;
        let p = bench.manifest.patch;
        let input = vec![0.1f32; n * p];
        let _ = bench.target.forward(&input, n); // warm
        let _ = bench.draft.forward(&input, n);
        let r = b.run("xla_target_fwd_b1", || {
            std::hint::black_box(bench.target.forward(&input, n).unwrap());
        });
        table.row(fmt(&r, "1 fwd"));
        let r = b.run("xla_draft_fwd_b1", || {
            std::hint::black_box(bench.draft.forward(&input, n).unwrap());
        });
        table.row(fmt(&r, "1 fwd"));
        let batch_in = vec![0.1f32; 32 * n * p];
        let _ = bench.target.forward_batch(&batch_in, 32, n);
        let r = b.run("xla_target_fwd_b32", || {
            std::hint::black_box(bench.target.forward_batch(&batch_in, 32, n).unwrap());
        });
        table.row(fmt(&r, "32 fwd"));

        let native = stride::repro::Bench::native()?;
        let r = b.run("native_target_fwd_b1", || {
            std::hint::black_box(native.target.forward(&input, n).unwrap());
        });
        table.row(fmt(&r, "1 fwd"));

        // Full SD decode end-to-end (4-patch horizon, XLA).
        let data = stride::data::Dataset::by_name("etth1").unwrap();
        let ws = stride::data::eval_windows(&data, p, 4, 4, 96, 1);
        let spec = stride::specdec::SpecConfig::default();
        let r = b.run("sd_decode_h4_xla", || {
            std::hint::black_box(
                stride::specdec::sd_generate(
                    bench.target.as_ref(),
                    bench.draft.as_ref(),
                    &ws[0].history,
                    4,
                    4,
                    &spec,
                )
                .unwrap(),
            );
        });
        table.row(fmt(&r, "1 decode"));
    } else {
        eprintln!("(artifacts missing: XLA rows skipped)");
    }

    table.print();
    table.write_csv("results/perf_hotpath.csv")?;
    println!("wrote results/perf_hotpath.csv");
    Ok(())
}
