//! L3 hot-path microbenchmarks (the §Perf profile targets): acceptance
//! math, Gaussian sampling, literal marshalling (PJRT boundary), JSON
//! parse/serialize of the wire protocol, end-to-end forward costs per
//! backend, the KV-cache sweep (cached vs uncached decode cost vs
//! context length — the fig-style table behind the decode-session PR),
//! and the kernel-layer comparison (packed/arena/blocked vs the
//! pre-kernel-layer naive kernel, serial vs row-parallel matmul) emitted
//! machine-readably to `results/BENCH_perf_hotpath.json` for CI.
//! These are the numbers the performance pass iterates on.
//!
//! SIMD + stacked-GEMM PR additions: the runtime-dispatched SIMD inner
//! kernel vs the forced-scalar fallback, the cache-blocked tiled matmul,
//! and the stacked tree-verify (one batched target forward) vs the
//! retained sequential extend/rollback reference — each pair's bit
//! identity is asserted **in-bench** before timing, and the JSON record
//! carries a `criteria_met` verdict that scripts/ci.sh gates on.
//!
//! Flight-recorder PR addition: `trace_overhead` — the same SD decode
//! untraced vs under an installed round observer feeding a live
//! `TraceSink`. The decode must be bit-identical either way (tracing
//! can observe, never perturb) and the traced mean must stay within 5%
//! of untraced; both verdicts fold into `criteria_met`.

use std::sync::Arc;
use std::time::Duration;

use stride::accept::AcceptancePolicy;
use stride::forecast::ar_decode_with;
use stride::models::{Backend, CacheMode, DecodeSession, NativeBackend};
use stride::nn::{ModelDims, NativeModel};
use stride::specdec::{sd_generate, with_round_observer, RoundObserver, RoundStats, SpecConfig};
use stride::trace::{EventKind, TraceSink, MAX_TRACE_ALPHAS};
use stride::util::microbench::{bencher_from_env, Bencher, Table};
use stride::util::rng::Rng;
use stride::util::tensor::{matmul, matmul_parallel, matmul_tiled, set_scalar_kernel};
use stride::util::threadpool::global_pool;

fn main() -> anyhow::Result<()> {
    let b = bencher_from_env();
    let mut table = Table::new(
        "Perf: L3 hot-path microbenchmarks",
        &["op", "mean", "p50", "p99", "unit/iter"],
    );
    let fmt = |r: &stride::util::microbench::BenchResult, unit: &str| {
        vec![
            r.name.clone(),
            format!("{:.2}us", r.mean_ns / 1e3),
            format!("{:.2}us", r.p50_ns / 1e3),
            format!("{:.2}us", r.p99_ns / 1e3),
            unit.to_string(),
        ]
    };

    // Acceptance alpha over a 24-dim patch (the per-proposal cost).
    let policy = AcceptancePolicy::new(0.5, 1.0);
    let mut rng = Rng::new(1);
    let x: Vec<f32> = (0..24).map(|_| rng.normal() as f32).collect();
    let mu_p: Vec<f32> = (0..24).map(|_| rng.normal() as f32).collect();
    let mu_q: Vec<f32> = (0..24).map(|_| rng.normal() as f32).collect();
    let mut acc = 0.0;
    let r = b.run("accept_alpha_d24", || {
        acc += policy.alpha(&x, &mu_p, &mu_q);
    });
    table.row(fmt(&r, "1 alpha"));
    std::hint::black_box(acc);

    // Patch sampling (draft proposal emission).
    let mut out = vec![0.0f32; 24];
    let r = b.run("sample_patch_d24", || {
        rng.fill_normal_around(&mu_q, 0.5, &mut out);
    });
    table.row(fmt(&r, "1 patch"));

    // Wire protocol: parse + serialize a forecast request/response.
    let hist: Vec<String> = (0..96).map(|i| format!("{:.4}", (i as f32 * 0.1).sin())).collect();
    let req_body = format!(r#"{{"history": [{}], "horizon": 4}}"#, hist.join(","));
    let r = b.run("json_parse_request", || {
        let j = stride::util::json::Json::parse(&req_body).unwrap();
        std::hint::black_box(stride::server::ForecastRequest::from_json(&j).unwrap());
    });
    table.row(fmt(&r, "1 req"));

    let resp = stride::server::ForecastResponse {
        forecast: (0..96).map(|i| i as f32).collect(),
        mode: "sd".into(),
        draft: "model".into(),
        priority: "normal".into(),
        replica: 0,
        seed: 42,
        request_id: 0xc0ffee,
        latency_ms: 1.0,
        alpha_hat: 0.97,
        mean_block_len: 3.4,
        rounds: 2,
        draft_calls: 6,
        target_calls: 2,
    };
    let r = b.run("json_serialize_response", || {
        std::hint::black_box(resp.to_json().to_string());
    });
    table.row(fmt(&r, "1 resp"));

    // --- KV-cache sweep: cached vs uncached decode over context length.
    // Runs on seeded random native models so it needs no artifacts; the
    // acceptance bar for the decode-session PR is cached strictly faster
    // than uncached from n_ctx >= 256.
    {
        let dims =
            ModelDims { patch: 8, n_ctx: 512, d_model: 32, n_layers: 2, n_heads: 4, d_ff: 64 };
        let draft_dims =
            ModelDims { patch: 8, n_ctx: 512, d_model: 16, n_layers: 1, n_heads: 2, d_ff: 32 };
        let target = NativeBackend::new(NativeModel::random("t", dims, 1));
        let draft = NativeBackend::new(NativeModel::random("d", draft_dims, 2));
        let mut rng = Rng::new(3);
        let hist: Vec<f32> = (0..dims.n_ctx * dims.patch).map(|_| rng.normal() as f32).collect();

        // Decode wall-clock dominates the iteration, so a light bencher
        // keeps the sweep tractable; STRIDE_BENCH_QUICK trims it further.
        let quick = std::env::var("STRIDE_BENCH_QUICK").as_deref() == Ok("1");
        let sweep_b = Bencher {
            warmup: Duration::from_millis(if quick { 10 } else { 50 }),
            measure: Duration::from_millis(if quick { 100 } else { 500 }),
            min_iters: 3,
            max_iters: if quick { 10 } else { 100 },
        };
        let horizon = 16;
        let mut sweep = Table::new(
            "Perf: KV-cache sweep (AR + SD decode, horizon 16)",
            &["n_ctx", "mode", "uncached", "cached", "speedup"],
        );
        for n_hist in [64usize, 256, 480] {
            // Greedy AR baseline (one sequential read per patch).
            let r_off = sweep_b.run(&format!("ar_off_n{n_hist}"), || {
                std::hint::black_box(
                    ar_decode_with(&target, &hist, n_hist, horizon, CacheMode::Off).unwrap(),
                );
            });
            let r_on = sweep_b.run(&format!("ar_on_n{n_hist}"), || {
                std::hint::black_box(
                    ar_decode_with(&target, &hist, n_hist, horizon, CacheMode::On).unwrap(),
                );
            });
            sweep.row(vec![
                format!("{n_hist}"),
                "ar".into(),
                format!("{:.2}ms", r_off.mean_ms()),
                format!("{:.2}ms", r_on.mean_ms()),
                format!("{:.2}x", r_off.mean_ns / r_on.mean_ns),
            ]);

            // Speculative decode, gamma 3.
            let mut spec = SpecConfig::default();
            spec.cache = CacheMode::Off;
            let s_off = sweep_b.run(&format!("sd_off_n{n_hist}"), || {
                std::hint::black_box(
                    sd_generate(&target, &draft, &hist, n_hist, horizon, &spec).unwrap(),
                );
            });
            spec.cache = CacheMode::On;
            let s_on = sweep_b.run(&format!("sd_on_n{n_hist}"), || {
                std::hint::black_box(
                    sd_generate(&target, &draft, &hist, n_hist, horizon, &spec).unwrap(),
                );
            });
            sweep.row(vec![
                format!("{n_hist}"),
                "sd_g3".into(),
                format!("{:.2}ms", s_off.mean_ms()),
                format!("{:.2}ms", s_on.mean_ms()),
                format!("{:.2}x", s_off.mean_ns / s_on.mean_ns),
            ]);
        }
        // Single-step anatomy: full re-forward vs one incremental row.
        for n in [256usize, 512] {
            let r_full = sweep_b.run(&format!("fwd_full_n{n}"), || {
                std::hint::black_box(target.forward(&hist, n).unwrap());
            });
            let mut sess = target.begin_cached(&hist, n - 1).unwrap();
            let step = hist[(n - 1) * dims.patch..n * dims.patch].to_vec();
            let r_inc = sweep_b.run(&format!("fwd_inc_n{n}"), || {
                std::hint::black_box(sess.extend(&step, 1).unwrap());
                sess.rollback(1).unwrap();
            });
            sweep.row(vec![
                format!("{n}"),
                "1 fwd".into(),
                format!("{:.3}ms", r_full.mean_ms()),
                format!("{:.3}ms", r_inc.mean_ms()),
                format!("{:.2}x", r_full.mean_ns / r_inc.mean_ns),
            ]);
        }
        sweep.print();
        sweep.write_csv("results/perf_hotpath_cached.csv")?;
        println!("wrote results/perf_hotpath_cached.csv");
    }

    // --- Kernel layer: packed weights + scratch arena + blocked matmul
    // ("after") vs the pre-kernel-layer reference kernel behind the flag
    // ("before" = string-keyed lookups, per-call allocation, naive ikj
    // matmul), plus serial vs row-parallel matmul at prefill shape. The
    // perf trajectory for this layer is tracked machine-readably in
    // results/BENCH_perf_hotpath.json; scripts/ci.sh fails on NaN or
    // empty output.
    {
        let dims =
            ModelDims { patch: 8, n_ctx: 256, d_model: 128, n_layers: 2, n_heads: 4, d_ff: 256 };
        let draft_dims =
            ModelDims { patch: 8, n_ctx: 256, d_model: 64, n_layers: 1, n_heads: 2, d_ff: 128 };
        let target = NativeBackend::new(NativeModel::random("kt", dims, 5));
        let draft = NativeBackend::new(NativeModel::random("kd", draft_dims, 6));
        let mut target_ref = NativeBackend::new(NativeModel::random("kt", dims, 5));
        target_ref.set_reference_kernel(true);
        let mut draft_ref = NativeBackend::new(NativeModel::random("kd", draft_dims, 6));
        draft_ref.set_reference_kernel(true);
        let mut rng = Rng::new(7);
        let hist: Vec<f32> =
            (0..dims.n_ctx * dims.patch).map(|_| rng.normal() as f32).collect();
        let quick = std::env::var("STRIDE_BENCH_QUICK").as_deref() == Ok("1");
        let kb = Bencher {
            warmup: Duration::from_millis(if quick { 20 } else { 100 }),
            measure: Duration::from_millis(if quick { 150 } else { 800 }),
            min_iters: 3,
            max_iters: if quick { 20 } else { 200 },
        };
        let p = dims.patch;
        let n = dims.n_ctx;

        // Prefill: one stateless forward over the full context.
        let r_pre = kb.run("kernel_prefill_packed", || {
            std::hint::black_box(target.forward(&hist, n).unwrap());
        });
        let r_pre_ref = kb.run("kernel_prefill_naive", || {
            std::hint::black_box(target_ref.forward(&hist, n).unwrap());
        });

        // AR step: one incremental extend at full context (+ rollback so
        // the session state is identical every iteration).
        let step = hist[(n - 1) * p..n * p].to_vec();
        let mut sess = target.begin_cached(&hist, n - 1).unwrap();
        let r_ar = kb.run("kernel_ar_step_packed", || {
            std::hint::black_box(sess.extend(&step, 1).unwrap());
            sess.rollback(1).unwrap();
        });
        let mut sess_ref = target_ref.begin_cached(&hist, n - 1).unwrap();
        let r_ar_ref = kb.run("kernel_ar_step_naive", || {
            std::hint::black_box(sess_ref.extend(&step, 1).unwrap());
            sess_ref.rollback(1).unwrap();
        });

        // SD round: a full speculative decode (horizon 16, γ 3, cache on)
        // normalized per round. Both kernel flavors decode identically
        // (same acceptance decisions within fp tolerance), so ns/round is
        // the like-for-like verify-path cost.
        let n_hist = 128;
        let spec = SpecConfig::default();
        let rounds = sd_generate(&target, &draft, &hist, n_hist, 16, &spec)
            .unwrap()
            .stats
            .rounds
            .max(1) as f64;
        let r_sd = kb.run("kernel_sd_decode_packed", || {
            std::hint::black_box(
                sd_generate(&target, &draft, &hist, n_hist, 16, &spec).unwrap(),
            );
        });
        let rounds_ref = sd_generate(&target_ref, &draft_ref, &hist, n_hist, 16, &spec)
            .unwrap()
            .stats
            .rounds
            .max(1) as f64;
        let r_sd_ref = kb.run("kernel_sd_decode_naive", || {
            std::hint::black_box(
                sd_generate(&target_ref, &draft_ref, &hist, n_hist, 16, &spec).unwrap(),
            );
        });
        let sd_round = r_sd.mean_ns / rounds;
        let sd_round_ref = r_sd_ref.mean_ns / rounds_ref;

        // Matmul at prefill shape: serial blocked kernel vs the
        // row-partitioned pool path (bitwise identical results).
        let (mm, mk, mn) = (n, dims.d_model, 3 * dims.d_model);
        let a: Vec<f32> = (0..mm * mk).map(|_| rng.normal() as f32).collect();
        let b2: Vec<f32> = (0..mk * mn).map(|_| rng.normal() as f32).collect();
        let mut c = vec![0.0f32; mm * mn];
        let r_mm = kb.run("kernel_matmul_serial", || {
            matmul(&a, &b2, mm, mk, mn, &mut c);
            std::hint::black_box(&c);
        });
        let pool = global_pool();
        let r_mmp = kb.run("kernel_matmul_parallel", || {
            matmul_parallel(pool, &a, &b2, mm, mk, mn, &mut c);
            std::hint::black_box(&c);
        });

        // --- SIMD tier before/after: the runtime-dispatched 4-lane inner
        // kernel vs the forced-scalar fallback, plus the cache-blocked
        // tiled path, all at the prefill matmul shape. The exhaustive
        // fence lives in tests/kernel_equivalence.rs; here each pair's
        // bit identity is re-asserted on the benched buffers so the perf
        // record can never describe a kernel that drifted.
        let mut c_scalar = vec![0.0f32; mm * mn];
        set_scalar_kernel(true);
        let r_mm_scalar = kb.run("kernel_matmul_scalar", || {
            matmul(&a, &b2, mm, mk, mn, &mut c_scalar);
            std::hint::black_box(&c_scalar);
        });
        set_scalar_kernel(false);
        let r_mm_simd = kb.run("kernel_matmul_simd", || {
            matmul(&a, &b2, mm, mk, mn, &mut c);
            std::hint::black_box(&c);
        });
        let simd_identical =
            c.iter().zip(&c_scalar).all(|(x, y)| x.to_bits() == y.to_bits());
        anyhow::ensure!(simd_identical, "SIMD matmul drifted from the scalar kernel's bits");
        let mut c_tiled = vec![0.0f32; mm * mn];
        let r_mm_tiled = kb.run("kernel_matmul_tiled", || {
            matmul_tiled(&a, &b2, mm, mk, mn, &mut c_tiled);
            std::hint::black_box(&c_tiled);
        });
        let tiled_identical =
            c.iter().zip(&c_tiled).all(|(x, y)| x.to_bits() == y.to_bits());
        anyhow::ensure!(tiled_identical, "tiled matmul drifted from the flat kernel's bits");

        // --- Stacked tree verify: k branch suffixes against the shared
        // prefix as ONE batched target forward ("after") vs the retained
        // sequential extend/rollback reference ("before"). Row bit
        // identity is asserted before timing.
        let k_branches = 4usize;
        let gamma = 3usize;
        let n_hist2 = 192usize;
        let mut vs = target.begin_cached(&hist[..n_hist2 * p], n_hist2).unwrap();
        let mut vrng = Rng::new(9);
        let branches: Vec<f32> =
            (0..k_branches * gamma * p).map(|_| vrng.normal() as f32).collect();
        let mut srows: Vec<f32> = Vec::new();
        anyhow::ensure!(
            vs.verify_stacked(&branches, k_branches, gamma, &mut srows)?,
            "native session refused the stacked verify path"
        );
        let mut seq_rows: Vec<f32> = Vec::with_capacity(srows.len());
        for j in 0..k_branches {
            let rows = vs.extend(&branches[j * gamma * p..(j + 1) * gamma * p], gamma)?;
            seq_rows.extend_from_slice(&rows);
            vs.rollback(gamma)?;
        }
        let stacked_identical = srows.len() == seq_rows.len()
            && srows.iter().zip(&seq_rows).all(|(x, y)| x.to_bits() == y.to_bits());
        anyhow::ensure!(
            stacked_identical,
            "stacked verify rows drifted from the sequential extend/rollback reference"
        );
        let r_vseq = kb.run("tree_verify_sequential_k4_g3", || {
            for j in 0..k_branches {
                std::hint::black_box(
                    vs.extend(&branches[j * gamma * p..(j + 1) * gamma * p], gamma).unwrap(),
                );
                vs.rollback(gamma).unwrap();
            }
        });
        let r_vstack = kb.run("tree_verify_stacked_k4_g3", || {
            std::hint::black_box(
                vs.verify_stacked(&branches, k_branches, gamma, &mut srows).unwrap(),
            );
        });

        // --- Flight-recorder overhead: the tracing PR's contract. With
        // no observer installed the engines pay one TLS None-check per
        // round; with an observer feeding a live TraceSink the decode
        // must (a) stay bit-identical — tracing observes, never
        // perturbs — and (b) cost < 5% wall-clock on a full SD decode.
        struct SinkObserver {
            sink: Arc<TraceSink>,
        }
        impl RoundObserver for SinkObserver {
            fn on_round(&self, seq: usize, r: &RoundStats) {
                let fan = r.branches.max(1);
                let n_alphas = r.alphas.len().min(MAX_TRACE_ALPHAS);
                let mut alphas = [0.0f32; MAX_TRACE_ALPHAS];
                for (dst, src) in alphas.iter_mut().zip(r.alphas.iter()) {
                    *dst = *src as f32;
                }
                self.sink.record_span_ending_now(
                    seq as u64 + 1,
                    r.draft_time + r.target_time,
                    EventKind::Round {
                        round: 0,
                        gamma: r.gamma.min(u8::MAX as usize) as u8,
                        k: fan.min(u8::MAX as usize) as u8,
                        draft: 0,
                        proposed: (r.gamma * fan).min(u16::MAX as usize) as u16,
                        accepted: r.accepted.min(u16::MAX as usize) as u16,
                        rollback: r.gamma.saturating_sub(r.accepted).min(u16::MAX as usize) as u16,
                        residual: r.residual_draws.min(u16::MAX as usize) as u16,
                        draft_ns: r.draft_time.as_nanos() as u64,
                        target_ns: r.target_time.as_nanos() as u64,
                        n_alphas: n_alphas as u8,
                        alphas,
                    },
                );
            }
        }
        let sink = Arc::new(TraceSink::new(4096));
        let obs: Arc<dyn RoundObserver> = Arc::new(SinkObserver { sink: Arc::clone(&sink) });
        let out_plain = sd_generate(&target, &draft, &hist, n_hist, 16, &spec)?;
        let out_traced = with_round_observer(Arc::clone(&obs), || {
            sd_generate(&target, &draft, &hist, n_hist, 16, &spec)
        })?;
        let trace_identical = out_plain.patches.len() == out_traced.patches.len()
            && out_plain
                .patches
                .iter()
                .zip(&out_traced.patches)
                .all(|(x, y)| x.to_bits() == y.to_bits());
        anyhow::ensure!(trace_identical, "decode under a round observer drifted bitwise");
        anyhow::ensure!(sink.recorded() > 0, "the observer never reached the sink");
        let r_untraced = kb.run("sd_decode_untraced", || {
            std::hint::black_box(sd_generate(&target, &draft, &hist, n_hist, 16, &spec).unwrap());
        });
        let r_traced = kb.run("sd_decode_traced", || {
            with_round_observer(Arc::clone(&obs), || {
                std::hint::black_box(
                    sd_generate(&target, &draft, &hist, n_hist, 16, &spec).unwrap(),
                );
            });
        });
        let trace_overhead = (r_traced.mean_ns - r_untraced.mean_ns) / r_untraced.mean_ns;
        let trace_overhead_ok = trace_overhead < 0.05;

        let mut ktab = Table::new(
            "Perf: kernel layer (packed/arena/blocked vs naive reference)",
            &["op", "naive", "packed", "speedup"],
        );
        let ms = |ns: f64| format!("{:.3}ms", ns / 1e6);
        ktab.row(vec![
            "prefill fwd n256".into(),
            ms(r_pre_ref.mean_ns),
            ms(r_pre.mean_ns),
            format!("{:.2}x", r_pre_ref.mean_ns / r_pre.mean_ns),
        ]);
        ktab.row(vec![
            "AR step n256".into(),
            ms(r_ar_ref.mean_ns),
            ms(r_ar.mean_ns),
            format!("{:.2}x", r_ar_ref.mean_ns / r_ar.mean_ns),
        ]);
        ktab.row(vec![
            "SD round g3".into(),
            ms(sd_round_ref),
            ms(sd_round),
            format!("{:.2}x", sd_round_ref / sd_round),
        ]);
        ktab.row(vec![
            format!("matmul {mm}x{mk}x{mn} (serial->par)"),
            ms(r_mm.mean_ns),
            ms(r_mmp.mean_ns),
            format!("{:.2}x", r_mm.mean_ns / r_mmp.mean_ns),
        ]);
        ktab.row(vec![
            format!("matmul {mm}x{mk}x{mn} (scalar->simd)"),
            ms(r_mm_scalar.mean_ns),
            ms(r_mm_simd.mean_ns),
            format!("{:.2}x", r_mm_scalar.mean_ns / r_mm_simd.mean_ns),
        ]);
        ktab.row(vec![
            format!("matmul {mm}x{mk}x{mn} (flat->tiled)"),
            ms(r_mm_simd.mean_ns),
            ms(r_mm_tiled.mean_ns),
            format!("{:.2}x", r_mm_simd.mean_ns / r_mm_tiled.mean_ns),
        ]);
        ktab.row(vec![
            "tree verify k4 g3 (seq->stacked)".into(),
            ms(r_vseq.mean_ns),
            ms(r_vstack.mean_ns),
            format!("{:.2}x", r_vseq.mean_ns / r_vstack.mean_ns),
        ]);
        ktab.row(vec![
            "SD decode (untraced->traced)".into(),
            ms(r_untraced.mean_ns),
            ms(r_traced.mean_ns),
            format!("{:+.2}%", trace_overhead * 100.0),
        ]);
        ktab.print();

        // Machine-readable record for CI and the perf trajectory. Every
        // value is checked finite before writing so a NaN can never slip
        // into the file silently (ci.sh also greps).
        let vals = [
            r_pre.mean_ns,
            r_pre_ref.mean_ns,
            r_ar.mean_ns,
            r_ar_ref.mean_ns,
            sd_round,
            sd_round_ref,
            r_mm.mean_ns,
            r_mmp.mean_ns,
            r_mm_scalar.mean_ns,
            r_mm_simd.mean_ns,
            r_mm_tiled.mean_ns,
            r_vseq.mean_ns,
            r_vstack.mean_ns,
            r_untraced.mean_ns,
            r_traced.mean_ns,
        ];
        let all_finite = vals.iter().all(|v| v.is_finite() && *v > 0.0);
        anyhow::ensure!(all_finite, "kernel bench produced non-finite timings: {vals:?}");
        // `criteria_met` is the CI gate (scripts/ci.sh greps for it):
        // every before/after pair in this record is bitwise identical,
        // every timing is finite, and the flight recorder's observed
        // decode is both bit-identical and within its 5% overhead
        // budget. The speedups themselves are informative (they vary
        // with the host); the identity is the contract.
        let criteria_met = all_finite
            && simd_identical
            && tiled_identical
            && stacked_identical
            && trace_identical
            && trace_overhead_ok;
        let json = format!(
            concat!(
                "{{\n",
                "  \"bench\": \"perf_hotpath_kernel\",\n",
                "  \"threads\": {threads},\n",
                "  \"quick\": {quick},\n",
                "  \"dims\": {{\"patch\": {p}, \"n_ctx\": {n}, \"d_model\": {d}, ",
                "\"n_layers\": {l}, \"n_heads\": {h}, \"d_ff\": {f}}},\n",
                "  \"prefill_ns\": {{\"naive\": {pre_ref:.0}, \"packed\": {pre:.0}, \"speedup\": {pre_s:.3}}},\n",
                "  \"ar_step_ns\": {{\"naive\": {ar_ref:.0}, \"packed\": {ar:.0}, \"speedup\": {ar_s:.3}}},\n",
                "  \"sd_round_ns\": {{\"naive\": {sd_ref:.0}, \"packed\": {sd:.0}, \"speedup\": {sd_s:.3}}},\n",
                "  \"matmul_ns\": {{\"serial\": {mm_s_ns:.0}, \"parallel\": {mm_p_ns:.0}, \"speedup\": {mm_sp:.3}}},\n",
                "  \"simd_matmul_ns\": {{\"scalar\": {sc_ns:.0}, \"simd\": {si_ns:.0}, ",
                "\"tiled\": {ti_ns:.0}, \"speedup\": {si_sp:.3}}},\n",
                "  \"stacked_verify_ns\": {{\"sequential\": {vq_ns:.0}, \"stacked\": {vk_ns:.0}, ",
                "\"speedup\": {vk_sp:.3}, \"k\": {kb_k}, \"gamma\": {kb_g}}},\n",
                "  \"trace_overhead\": {{\"untraced_ns\": {tr_u:.0}, \"traced_ns\": {tr_t:.0}, ",
                "\"overhead_frac\": {tr_f:.4}, \"events_recorded\": {tr_n}}},\n",
                "  \"criteria\": {{\"all_finite\": {fin}, \"simd_bitwise_identical\": {sid}, ",
                "\"tiled_bitwise_identical\": {tid}, \"stacked_bitwise_identical\": {std_}, ",
                "\"trace_bitwise_identical\": {trid}, \"trace_overhead_ok\": {trok}, ",
                "\"criteria_met\":{met}}}\n",
                "}}\n"
            ),
            threads = pool.size(),
            quick = quick,
            p = p,
            n = n,
            d = dims.d_model,
            l = dims.n_layers,
            h = dims.n_heads,
            f = dims.d_ff,
            pre_ref = r_pre_ref.mean_ns,
            pre = r_pre.mean_ns,
            pre_s = r_pre_ref.mean_ns / r_pre.mean_ns,
            ar_ref = r_ar_ref.mean_ns,
            ar = r_ar.mean_ns,
            ar_s = r_ar_ref.mean_ns / r_ar.mean_ns,
            sd_ref = sd_round_ref,
            sd = sd_round,
            sd_s = sd_round_ref / sd_round,
            mm_s_ns = r_mm.mean_ns,
            mm_p_ns = r_mmp.mean_ns,
            mm_sp = r_mm.mean_ns / r_mmp.mean_ns,
            sc_ns = r_mm_scalar.mean_ns,
            si_ns = r_mm_simd.mean_ns,
            ti_ns = r_mm_tiled.mean_ns,
            si_sp = r_mm_scalar.mean_ns / r_mm_simd.mean_ns,
            vq_ns = r_vseq.mean_ns,
            vk_ns = r_vstack.mean_ns,
            vk_sp = r_vseq.mean_ns / r_vstack.mean_ns,
            kb_k = k_branches,
            kb_g = gamma,
            tr_u = r_untraced.mean_ns,
            tr_t = r_traced.mean_ns,
            tr_f = trace_overhead,
            tr_n = sink.recorded(),
            fin = all_finite,
            sid = simd_identical,
            tid = tiled_identical,
            std_ = stacked_identical,
            trid = trace_identical,
            trok = trace_overhead_ok,
            met = criteria_met,
        );
        std::fs::create_dir_all("results")?;
        std::fs::write("results/BENCH_perf_hotpath.json", &json)?;
        println!("wrote results/BENCH_perf_hotpath.json");
    }

    // Backend forwards (the dominant cost; includes the PJRT literal
    // marshalling boundary for the XLA rows).
    if stride::artifacts_dir().join("manifest.json").exists() {
        let bench = stride::repro::Bench::xla()?;
        let n = bench.manifest.n_ctx;
        let p = bench.manifest.patch;
        let input = vec![0.1f32; n * p];
        let _ = bench.target.forward(&input, n); // warm
        let _ = bench.draft.forward(&input, n);
        let r = b.run("xla_target_fwd_b1", || {
            std::hint::black_box(bench.target.forward(&input, n).unwrap());
        });
        table.row(fmt(&r, "1 fwd"));
        let r = b.run("xla_draft_fwd_b1", || {
            std::hint::black_box(bench.draft.forward(&input, n).unwrap());
        });
        table.row(fmt(&r, "1 fwd"));
        let batch_in = vec![0.1f32; 32 * n * p];
        let _ = bench.target.forward_batch(&batch_in, 32, n);
        let r = b.run("xla_target_fwd_b32", || {
            std::hint::black_box(bench.target.forward_batch(&batch_in, 32, n).unwrap());
        });
        table.row(fmt(&r, "32 fwd"));

        let native = stride::repro::Bench::native()?;
        let r = b.run("native_target_fwd_b1", || {
            std::hint::black_box(native.target.forward(&input, n).unwrap());
        });
        table.row(fmt(&r, "1 fwd"));

        // Full SD decode end-to-end (4-patch horizon, XLA).
        let data = stride::data::Dataset::by_name("etth1").unwrap();
        let ws = stride::data::eval_windows(&data, p, 4, 4, 96, 1);
        let spec = stride::specdec::SpecConfig::default();
        let r = b.run("sd_decode_h4_xla", || {
            std::hint::black_box(
                stride::specdec::sd_generate(
                    bench.target.as_ref(),
                    bench.draft.as_ref(),
                    &ws[0].history,
                    4,
                    4,
                    &spec,
                )
                .unwrap(),
            );
        });
        table.row(fmt(&r, "1 decode"));
    } else {
        eprintln!("(artifacts missing: XLA rows skipped)");
    }

    table.print();
    table.write_csv("results/perf_hotpath.csv")?;
    println!("wrote results/perf_hotpath.csv");
    Ok(())
}
