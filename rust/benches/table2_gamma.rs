//! Table 2 — Ablations on gamma for the Weather dataset (sigma = 0.8),
//! extended past the paper's {3, 4} to show the saturation tail.

use stride::repro::{quick, Bench, RowCfg};
use stride::util::microbench::Table;

fn main() -> anyhow::Result<()> {
    let bench = Bench::from_env()?;
    let mut table = Table::new(
        "Table 2: Ablations on gamma (Weather, sigma=0.8)",
        &["gamma", "alpha", "E[L] (meas)", "S_wall (pred)", "S_wall (meas)"],
    );
    let gammas: &[usize] = if quick() { &[3, 4] } else { &[1, 2, 3, 4, 5, 7, 10] };
    for &gamma in gammas {
        let cfg = RowCfg { dataset: "weather", sigma: 0.8, gamma, ..Default::default() };
        let r = bench.run_row(&cfg)?;
        table.row(vec![
            format!("{gamma}"),
            format!("{:.3}", r.alpha_hat),
            format!("{:.2}", r.mean_block_len),
            format!("{:.2}x", r.s_wall_pred),
            format!("{:.2}x", r.s_wall_meas),
        ]);
    }
    table.print();
    table.write_csv("results/table2_gamma.csv")?;
    println!("wrote results/table2_gamma.csv");
    Ok(())
}
