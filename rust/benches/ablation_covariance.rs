//! Ablation — covariance parameterization (paper Remark 1 / §4.3):
//! isotropic vs diagonal Gaussian heads.
//!
//! The paper restricts to isotropic heads for efficiency and *predicts*
//! that diagonal covariance "may increase alpha-bar by better matching the
//! target, but raises per-step cost". We quantify both halves:
//! * acceptance: alpha-hat under iso vs diagonal acceptance on the same
//!   (target, draft) head pairs, with per-dim sigmas fitted from validation
//!   residuals;
//! * cost: ns per acceptance evaluation for each parameterization.

use stride::gaussian::{diag_log_ratio, DiagGaussian};
use stride::models::Backend;
use stride::repro::{Bench, RowCfg};
use stride::util::microbench::{bencher_from_env, Table};
use stride::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let bench = Bench::from_env()?;
    let p = bench.manifest.patch;

    // Fit per-dim residual std of the *target* on validation windows: the
    // natural diagonal head (per-position-in-patch error profile).
    let cfg = RowCfg { dataset: "etth1", windows: 48, ..Default::default() };
    let windows = bench.windows(&cfg)?;
    let mut sq = vec![0.0f64; p];
    let mut heads: Vec<(Vec<f32>, Vec<f32>)> = Vec::new();
    for w in &windows {
        let n = w.history.len() / p;
        let mp = bench.target.forward(&w.history, n)?;
        let md = bench.draft.forward(&w.history, n)?;
        let mu_p = &mp[(n - 1) * p..n * p];
        for (i, (m, t)) in mu_p.iter().zip(&w.future[..p]).enumerate() {
            sq[i] += ((m - t) as f64).powi(2);
        }
        heads.push((mu_p.to_vec(), md[(n - 1) * p..n * p].to_vec()));
    }
    let diag_sigmas: Vec<f32> =
        sq.iter().map(|s| ((s / windows.len() as f64).sqrt() as f32).max(0.05)).collect();
    let mean_sigma =
        (diag_sigmas.iter().map(|s| (s * s) as f64).sum::<f64>() / p as f64).sqrt();

    let mut table = Table::new(
        "Ablation: covariance parameterization (Remark 1), ETTh1 heads",
        &["head", "alpha_hat (MC)", "ns / alpha eval", "notes"],
    );

    // Monte-Carlo alpha under both rules on identical samples.
    let mut rng = Rng::new(17);
    let m = 400;
    let (mut a_iso, mut a_diag) = (0.0f64, 0.0f64);
    for (mu_p, mu_q) in &heads {
        let q_diag = DiagGaussian::new(mu_q.clone(), diag_sigmas.clone());
        let p_diag = DiagGaussian::new(mu_p.clone(), diag_sigmas.clone());
        let pol = stride::accept::AcceptancePolicy::new(mean_sigma, 1.0);
        for _ in 0..m {
            // Sample from the diagonal draft (the more faithful model).
            let x = q_diag.sample(&mut rng);
            a_iso += pol.alpha(&x, mu_p, mu_q);
            a_diag += diag_log_ratio(&x, &p_diag, &q_diag).min(0.0).exp();
        }
    }
    let n_mc = (heads.len() * m) as f64;

    // Cost of one acceptance evaluation each way.
    let b = bencher_from_env();
    let (mu_p, mu_q) = &heads[0];
    let x: Vec<f32> = mu_q.iter().map(|v| v + 0.1).collect();
    let pol = stride::accept::AcceptancePolicy::new(mean_sigma, 1.0);
    let r_iso = b.run("iso", || {
        std::hint::black_box(pol.alpha(&x, mu_p, mu_q));
    });
    let pd = DiagGaussian::new(mu_p.clone(), diag_sigmas.clone());
    let qd = DiagGaussian::new(mu_q.clone(), diag_sigmas.clone());
    let r_diag = b.run("diag", || {
        std::hint::black_box(diag_log_ratio(&x, &pd, &qd).min(0.0).exp());
    });

    table.row(vec![
        "isotropic".into(),
        format!("{:.4}", a_iso / n_mc),
        format!("{:.0}", r_iso.mean_ns),
        format!("sigma = {mean_sigma:.3} (RMS of fitted diag)"),
    ]);
    table.row(vec![
        "diagonal".into(),
        format!("{:.4}", a_diag / n_mc),
        format!("{:.0}", r_diag.mean_ns),
        format!(
            "per-dim sigma in [{:.2}, {:.2}]",
            diag_sigmas.iter().cloned().fold(f32::INFINITY, f32::min),
            diag_sigmas.iter().cloned().fold(0.0, f32::max)
        ),
    ]);
    table.print();
    table.write_csv("results/ablation_covariance.csv")?;
    println!("wrote results/ablation_covariance.csv");
    Ok(())
}
