//! Table 3 — Ablations on sigma for ETTh1 (gamma = 3).

use stride::repro::{quick, Bench, RowCfg};
use stride::util::microbench::Table;

fn main() -> anyhow::Result<()> {
    let bench = Bench::from_env()?;
    let mut table = Table::new(
        "Table 3: Ablations on sigma (ETTh1, gamma=3)",
        &["sigma", "alpha", "S_wall (meas)", "MSE", "dMSE vs baseline"],
    );
    let sigmas: &[f64] = if quick() { &[0.5] } else { &[0.35, 0.40, 0.45, 0.50, 0.55, 0.60] };
    for &sigma in sigmas {
        let cfg = RowCfg { dataset: "etth1", sigma, ..Default::default() };
        let r = bench.run_row(&cfg)?;
        table.row(vec![
            format!("{sigma:.2}"),
            format!("{:.3}", r.alpha_hat),
            format!("{:.2}x", r.s_wall_meas),
            format!("{:.4}", r.mse),
            format!("{:+.1}%", 100.0 * (r.mse - r.baseline_mse) / r.baseline_mse),
        ]);
    }
    table.print();
    table.write_csv("results/table3_sigma_etth1.csv")?;
    println!("wrote results/table3_sigma_etth1.csv");
    Ok(())
}
