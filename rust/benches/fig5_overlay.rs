//! Figure 5 — Forecast overlay on a representative series segment: the SD
//! forecast vs the target-only baseline vs ground truth. Emits a CSV with
//! one row per time step (plot with any tool).

use stride::forecast::ar_decode;
use stride::repro::{Bench, RowCfg};
use stride::specdec::sd_generate;
use stride::util::microbench::Table;

fn main() -> anyhow::Result<()> {
    let bench = Bench::from_env()?;
    let cfg = RowCfg { dataset: "etth1", sigma: 0.5, ..Default::default() };
    let windows = bench.windows(&cfg)?;
    let w = &windows[0];
    let p = bench.manifest.patch;
    let n_hist = w.history.len() / p;

    let (base, _, _) = ar_decode(bench.target.as_ref(), &w.history, n_hist, cfg.horizon)?;
    let spec = {
        let mut s = stride::specdec::SpecConfig::default();
        s.policy.sigma = cfg.sigma;
        s
    };
    let sd = sd_generate(bench.target.as_ref(), bench.draft.as_ref(), &w.history, n_hist, cfg.horizon, &spec)?;

    let mut table = Table::new(
        "Figure 5: forecast overlay (ETTh1 segment, normalized values)",
        &["t", "truth", "target_only", "speculative"],
    );
    for t in 0..cfg.horizon * p {
        table.row(vec![
            format!("{t}"),
            format!("{:.4}", w.future[t]),
            format!("{:.4}", base[t]),
            format!("{:.4}", sd.patches[t]),
        ]);
    }
    table.write_csv("results/fig5_overlay.csv")?;
    // Print summary only (480 rows would flood the terminal).
    let mse_base: f64 = base.iter().zip(&w.future).map(|(a, b)| ((a - b) as f64).powi(2)).sum::<f64>() / base.len() as f64;
    let mse_sd: f64 = sd.patches.iter().zip(&w.future).map(|(a, b)| ((a - b) as f64).powi(2)).sum::<f64>() / base.len() as f64;
    println!("Figure 5 overlay written to results/fig5_overlay.csv");
    println!("segment MSE: target-only {mse_base:.4}, speculative {mse_sd:.4} (near-overlap expected)");
    Ok(())
}
