//! Figure 7 — Measured and predicted wall-clock speedup vs block size
//! gamma; the curve saturates past gamma ~ 3 (capped-geometric analysis).

use stride::repro::{quick, Bench, RowCfg};
use stride::util::microbench::Table;

fn main() -> anyhow::Result<()> {
    let bench = Bench::from_env()?;
    let mut table = Table::new(
        "Figure 7: S_wall vs gamma (ETTh1, sigma=0.6)",
        &["gamma", "alpha", "E[L]", "c", "S_wall pred", "S_wall meas"],
    );
    let gammas: &[usize] = if quick() { &[1, 3] } else { &[1, 2, 3, 4, 5, 6, 7, 8, 10] };
    for &gamma in gammas {
        // Long horizon (pred-len 336 = 14 patches) so gamma up to 10 is
        // exercised rather than capped at horizon-1.
        let cfg = RowCfg {
            dataset: "etth1",
            sigma: 0.6,
            gamma,
            horizon: 14,
            windows: if quick() { 4 } else { 14 },
            ..Default::default()
        };
        let r = bench.run_row(&cfg)?;
        table.row(vec![
            format!("{gamma}"),
            format!("{:.3}", r.alpha_hat),
            format!("{:.2}", r.mean_block_len),
            format!("{:.3}", r.c),
            format!("{:.2}x", r.s_wall_pred),
            format!("{:.2}x", r.s_wall_meas),
        ]);
    }
    table.print();
    table.write_csv("results/fig7_speedup_vs_gamma.csv")?;
    println!("wrote results/fig7_speedup_vs_gamma.csv");
    Ok(())
}
