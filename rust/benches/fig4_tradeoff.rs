//! Figure 4 — Accuracy vs. speed trade-off frontier: target baseline,
//! draft-only decoding, and SD at gamma in {3, 7, 10}.

use stride::forecast::eval_ar;
use stride::repro::{quick, Bench, RowCfg};
use stride::util::microbench::Table;

fn main() -> anyhow::Result<()> {
    let bench = Bench::from_env()?;
    let mut table = Table::new(
        "Figure 4: accuracy vs speed trade-off (ETTh1)",
        &["Point", "MSE", "relative cost", "speedup"],
    );

    let base_cfg = RowCfg { dataset: "etth1", sigma: 0.5, ..Default::default() };
    let windows = bench.windows(&base_cfg)?;
    let p = bench.manifest.patch;

    // Target baseline.
    let base = eval_ar(bench.target.as_ref(), &windows, p)?;
    table.row(vec![
        "target-only".into(),
        format!("{:.4}", base.mse),
        "1.00".into(),
        "1.00x".into(),
    ]);

    // Draft-only decoding (circle marker in the paper: fast but inaccurate).
    let draft_only = eval_ar(bench.draft.as_ref(), &windows, p)?;
    table.row(vec![
        "draft-only".into(),
        format!("{:.4}", draft_only.mse),
        format!("{:.2}", draft_only.wall.as_secs_f64() / base.wall.as_secs_f64()),
        format!("{:.2}x", base.wall.as_secs_f64() / draft_only.wall.as_secs_f64()),
    ]);

    // SD at increasing gamma (square/diamond/pentagon markers).
    let gammas: &[usize] = if quick() { &[3] } else { &[3, 7, 10] };
    for &gamma in gammas {
        let cfg = RowCfg { gamma, ..base_cfg.clone() };
        let r = bench.run_row(&cfg)?;
        table.row(vec![
            format!("SD gamma={gamma}"),
            format!("{:.4}", r.mse),
            format!("{:.2}", 1.0 / r.s_wall_meas),
            format!("{:.2}x", r.s_wall_meas),
        ]);
    }

    table.print();
    table.write_csv("results/fig4_tradeoff.csv")?;
    println!("wrote results/fig4_tradeoff.csv");
    Ok(())
}
