//! Live weight-swap bench: open-loop Poisson load against a
//! registry-booted engine with a hot swap fired mid-soak.
//!
//! Three self-judging criteria (asserted in-bench and recorded in
//! `results/BENCH_model_swap.json`; schema in `benches/README.md`):
//!
//! 1. **Zero drops** — every request issued across the soak (before,
//!    during, and after the swap) completes successfully; the swap is
//!    not allowed to shed, error, or lose a single one.
//! 2. **Bounded disturbance** — p99 latency of requests issued inside
//!    the swap window is <= 2x the steady-state p99 (plus a small
//!    absolute floor for timer jitter at tiny-model ms latencies).
//! 3. **Identity lands** — the swap report is complete (every replica
//!    rebound) and the serving digest equals the new manifest's content
//!    address.
//!
//! No artifacts needed: both model versions are seeded synthetics
//! published into a throwaway registry under the system temp dir, and
//! the engine boots from `ServeConfig::registry_model`.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use stride::config::ServeConfig;
use stride::metrics::{AcceptanceMonitor, Metrics};
use stride::nn::{ModelDims, NativeModel};
use stride::registry::{publish_pair, Registry};
use stride::server::protocol::{ForecastRequest, Mode, Priority};
use stride::server::{start_engine, BatcherHandle};
use stride::util::json::Json;
use stride::util::rng::Rng;
use stride::util::stats::quantile;

const PATCH: usize = 4;
const N_CTX: usize = 32;
const N_HIST: usize = 8;
const HORIZON: usize = 16;

fn target_model(seed: u64) -> NativeModel {
    let dims =
        ModelDims { patch: PATCH, n_ctx: N_CTX, d_model: 32, n_layers: 2, n_heads: 4, d_ff: 64 };
    NativeModel::random("swap-target", dims, seed)
}

fn draft_model(seed: u64) -> NativeModel {
    let dims =
        ModelDims { patch: PATCH, n_ctx: N_CTX, d_model: 16, n_layers: 1, n_heads: 2, d_ff: 32 };
    NativeModel::random("swap-draft", dims, seed)
}

struct Engine {
    handle: BatcherHandle,
    threads: Vec<std::thread::JoinHandle<()>>,
}

fn start(registry_root: &std::path::Path, reference: &str) -> anyhow::Result<Engine> {
    let mut cfg = ServeConfig::default();
    cfg.backend = "native".into();
    cfg.replicas = 2;
    cfg.max_batch = 8;
    cfg.max_wait_ms = 1;
    cfg.queue_cap = 1024;
    // Replica behavior is the thing under test; keep kernel-layer
    // parallelism fixed so latencies attribute to the serving layer.
    cfg.threads = 1;
    cfg.registry_dir = Some(registry_root.to_path_buf());
    cfg.registry_model = Some(reference.to_string());
    let metrics = Arc::new(Metrics::new());
    let monitor = Arc::new(AcceptanceMonitor::new(256, 0.8));
    let stop = Arc::new(AtomicBool::new(false));
    let (handle, threads) = start_engine(cfg, metrics, monitor, stop)?;
    Ok(Engine { handle, threads })
}

impl Engine {
    fn stop(self) {
        self.handle.shutdown();
        for t in self.threads {
            let _ = t.join();
        }
    }
}

fn history(seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    (0..N_HIST * PATCH).map(|_| (rng.normal() as f32) * 0.5).collect()
}

fn request(i: usize) -> ForecastRequest {
    ForecastRequest {
        history: history(1000 + (i % 8) as u64),
        horizon: HORIZON,
        mode: Mode::Sd,
        gamma: Some(2 + (i % 2)),
        k: None,
        sigma: Some(0.5),
        cache: None,
        adaptive: None,
        draft: None,
        dataset: None,
        priority: Priority::Normal,
        deadline_ms: None,
        seed: Some(0x5A17_0000 + i as u64),
        request_id: None,
    }
}

/// Short closed-loop warmup to size the open-loop rate: the soak runs at
/// ~60% of measured capacity so the queue stays shallow and the swap is
/// the only disturbance.
fn measure_capacity(handle: &BatcherHandle, n_req: usize) -> anyhow::Result<f64> {
    let next = Arc::new(AtomicUsize::new(0));
    let t0 = Instant::now();
    let workers: Vec<_> = (0..8)
        .map(|_| {
            let h = handle.clone();
            let next = Arc::clone(&next);
            std::thread::spawn(move || loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n_req {
                    return;
                }
                h.forecast(request(i)).expect("warmup request failed");
            })
        })
        .collect();
    for w in workers {
        w.join().unwrap();
    }
    Ok(n_req as f64 / t0.elapsed().as_secs_f64())
}

/// One completed soak request: seconds-from-start at issue, latency in
/// ms, and whether it succeeded.
#[derive(Clone, Copy)]
struct Sample {
    issued_at_s: f64,
    latency_ms: f64,
    ok: bool,
}

fn p99(samples: &[&Sample]) -> f64 {
    let mut l: Vec<f64> = samples.iter().map(|s| s.latency_ms).collect();
    l.sort_by(|a, b| a.partial_cmp(b).unwrap());
    if l.is_empty() {
        0.0
    } else {
        quantile(&l, 0.99)
    }
}

fn main() -> anyhow::Result<()> {
    let quick = std::env::var("STRIDE_BENCH_QUICK").as_deref() == Ok("1");
    let n_req = if quick { 300 } else { 900 };
    let n_warm = if quick { 48 } else { 96 };
    println!("model_swap: quick={quick}, soak {n_req} requests, horizon {HORIZON}, patch {PATCH}");

    // Publish both versions (same geometry, different weights) into a
    // throwaway registry.
    let root = std::env::temp_dir().join("stride_bench_model_swap");
    let _ = std::fs::remove_dir_all(&root);
    let reg = Registry::open(&root)?;
    let d1 = publish_pair(&reg, "bench", "v1", &target_model(0xA11CE), &draft_model(0xB0B))?;
    let d2 = publish_pair(&reg, "bench", "v2", &target_model(0xCAFE), &draft_model(0xD00D))?;
    anyhow::ensure!(d1 != d2, "versions must differ");

    let engine = start(&root, "bench:v1")?;
    anyhow::ensure!(engine.handle.model_digest() == d1, "engine must boot on v1");

    let capacity = measure_capacity(&engine.handle, n_warm)?;
    let rate = (0.6 * capacity).max(20.0);
    println!("capacity ~{capacity:.1} req/s -> open-loop soak at {rate:.1} req/s");

    // Pre-computed Poisson arrival schedule (seeded: the arrival pattern
    // is part of the workload definition).
    let mut rng = Rng::new(0x5A17_BEEF);
    let mut offsets = Vec::with_capacity(n_req);
    let mut t_acc = 0.0f64;
    for _ in 0..n_req {
        t_acc += rng.exponential(rate);
        offsets.push(t_acc);
    }
    let offsets = Arc::new(offsets);
    let next = Arc::new(AtomicUsize::new(0));
    let issued = Arc::new(AtomicUsize::new(0));
    let samples: Arc<Mutex<Vec<Sample>>> = Arc::new(Mutex::new(Vec::new()));
    let t0 = Instant::now();
    let workers: Vec<_> = (0..32)
        .map(|_| {
            let h = engine.handle.clone();
            let next = Arc::clone(&next);
            let issued = Arc::clone(&issued);
            let offsets = Arc::clone(&offsets);
            let samples = Arc::clone(&samples);
            std::thread::spawn(move || loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= offsets.len() {
                    return;
                }
                let due = offsets[i];
                let now = t0.elapsed().as_secs_f64();
                if due > now {
                    std::thread::sleep(std::time::Duration::from_secs_f64(due - now));
                }
                let issued_at_s = t0.elapsed().as_secs_f64();
                issued.fetch_add(1, Ordering::Relaxed);
                let t = Instant::now();
                let ok = h.forecast(request(i)).is_ok();
                let latency_ms = t.elapsed().as_secs_f64() * 1e3;
                samples.lock().unwrap().push(Sample { issued_at_s, latency_ms, ok });
            })
        })
        .collect();

    // Fire the hot swap once half the soak has been issued.
    while issued.load(Ordering::Relaxed) < n_req / 2 {
        std::thread::sleep(std::time::Duration::from_millis(1));
    }
    let swap_start_s = t0.elapsed().as_secs_f64();
    let report = engine.handle.swap_model("bench:v2").map_err(|e| anyhow::anyhow!("{e}"))?;
    let swap_end_s = t0.elapsed().as_secs_f64();
    println!(
        "swap: {} -> generation {} in {} ms (rebound {}/{}, complete {})",
        report.label, report.generation, report.duration_ms, report.rebound, report.replicas,
        report.complete
    );

    for w in workers {
        w.join().unwrap();
    }
    let samples = samples.lock().unwrap().clone();
    anyhow::ensure!(samples.len() == n_req, "soak lost samples: {}", samples.len());

    // Partition by issue time. The swap window gets a margin past the
    // barrier so requests admitted onto just-rebound replicas count as
    // "during"; if the window caught too few samples for a p99 (a fast
    // swap on an idle instant), widen it symmetrically.
    let mut window = (swap_start_s, swap_end_s + 0.1);
    let in_window = |w: (f64, f64), s: &&Sample| s.issued_at_s >= w.0 && s.issued_at_s <= w.1;
    if samples.iter().filter(|s| in_window(window, s)).count() < 5 {
        window = (swap_start_s - 0.25, swap_end_s + 0.35);
    }
    let steady: Vec<&Sample> = samples.iter().filter(|s| s.issued_at_s < window.0).collect();
    let during: Vec<&Sample> = samples.iter().filter(|s| in_window(window, s)).collect();
    let after: Vec<&Sample> = samples.iter().filter(|s| s.issued_at_s > window.1).collect();
    let errors = samples.iter().filter(|s| !s.ok).count();
    let p99_steady = p99(&steady);
    let p99_during = p99(&during);
    let p99_after = p99(&after);
    println!(
        "p99 ms: steady {p99_steady:.2} ({} req), during swap {p99_during:.2} ({} req), \
         after {p99_after:.2} ({} req); errors {errors}",
        steady.len(),
        during.len(),
        after.len()
    );

    // Criteria. The +5 ms absolute floor keeps the 2x ratio meaningful
    // at tiny-model latencies, where a single timer tick is a large
    // relative error.
    let zero_drops = errors == 0;
    let bounded = p99_during <= 2.0 * p99_steady + 5.0;
    let identity = report.complete
        && report.digest == d2
        && engine.handle.model_digest() == d2
        && report.rebound == report.replicas;
    let criteria_met = zero_drops && bounded && identity;

    let vals = [p99_steady, p99_during, p99_after, capacity, rate];
    anyhow::ensure!(vals.iter().all(|v| v.is_finite()), "non-finite bench value: {vals:?}");
    let phase_json = |label: &str, s: &[&Sample], p: f64| {
        Json::obj(vec![
            ("label", Json::from(label)),
            ("requests", Json::from(s.len())),
            ("latency_p99_ms", Json::Num(p)),
        ])
    };
    let j = Json::obj(vec![
        ("bench", Json::from("model_swap")),
        ("quick", Json::from(quick)),
        (
            "config",
            Json::obj(vec![
                ("patch", Json::from(PATCH)),
                ("n_ctx", Json::from(N_CTX)),
                ("horizon_patches", Json::from(HORIZON)),
                ("replicas", Json::from(2usize)),
                ("soak_requests", Json::from(n_req)),
                ("capacity_req_per_s", Json::Num(capacity)),
                ("soak_rate_req_per_s", Json::Num(rate)),
            ]),
        ),
        (
            "swap",
            Json::obj(vec![
                ("from_digest", Json::from(d1)),
                ("to_digest", Json::from(report.digest.clone())),
                ("generation", Json::from(report.generation as usize)),
                ("duration_ms", Json::from(report.duration_ms as usize)),
                ("rebound", Json::from(report.rebound)),
                ("replicas", Json::from(report.replicas)),
                ("complete", Json::from(report.complete)),
                ("heads", Json::from(report.heads)),
            ]),
        ),
        (
            "phases",
            Json::Arr(vec![
                phase_json("steady", &steady, p99_steady),
                phase_json("during_swap", &during, p99_during),
                phase_json("after_swap", &after, p99_after),
            ]),
        ),
        (
            "criteria",
            Json::obj(vec![
                ("zero_dropped_or_errored", Json::from(zero_drops)),
                ("swap_p99_le_2x_steady", Json::from(bounded)),
                ("post_swap_digest_matches", Json::from(identity)),
                ("criteria_met", Json::from(criteria_met)),
            ]),
        ),
    ]);
    std::fs::create_dir_all("results")?;
    std::fs::write("results/BENCH_model_swap.json", format!("{j}\n"))?;
    println!("wrote results/BENCH_model_swap.json");
    engine.stop();

    anyhow::ensure!(
        criteria_met,
        "model_swap criteria failed: zero_drops={zero_drops} bounded={bounded} \
         identity={identity}"
    );
    println!(
        "criteria met: zero requests dropped across the swap; swap-window p99 bounded; \
         serving identity landed on the new digest"
    );
    Ok(())
}
