//! Figure 6 — Accuracy-speed trade-off labeled by sigma for ETTh1 and
//! ETTh2: dMSE (%) vs measured speedup as sigma sweeps 0.30 -> 0.70.

use stride::repro::{quick, Bench, RowCfg};
use stride::util::microbench::Table;

fn main() -> anyhow::Result<()> {
    let bench = Bench::from_env()?;
    let mut table = Table::new(
        "Figure 6: dMSE vs speedup, labeled by sigma",
        &["dataset", "sigma", "alpha", "S_wall (meas)", "dMSE %"],
    );
    let sigmas: &[f64] =
        if quick() { &[0.5] } else { &[0.30, 0.35, 0.40, 0.45, 0.50, 0.55, 0.60, 0.65, 0.70] };
    for dataset in ["etth1", "etth2"] {
        for &sigma in sigmas {
            let cfg = RowCfg { dataset: if dataset == "etth1" { "etth1" } else { "etth2" }, sigma, ..Default::default() };
            let r = bench.run_row(&cfg)?;
            table.row(vec![
                dataset.into(),
                format!("{sigma:.2}"),
                format!("{:.3}", r.alpha_hat),
                format!("{:.2}", r.s_wall_meas),
                format!("{:.1}", 100.0 * (r.mse - r.baseline_mse) / r.baseline_mse),
            ]);
        }
    }
    table.print();
    table.write_csv("results/fig6_sigma_tradeoff.csv")?;
    println!("wrote results/fig6_sigma_tradeoff.csv");
    Ok(())
}
