//! Serving-scheduler load bench: open-loop Poisson traffic over a mixed
//! scenario workload against the full scheduler stack (bounded
//! admission, EDF dispatch, engine replica pool) — no artifacts needed
//! (synthetic native models, `start_engine_with_builder`).
//!
//! Three phases, three self-judging criteria (asserted in-bench and
//! recorded in `results/BENCH_serving_load.json`; schema in
//! `benches/README.md`):
//!
//! 1. **Determinism** — scheduled responses are **bit-identical** to the
//!    unscheduled `sd_generate_from` engine for the same request + seed,
//!    at every replica count, under concurrent mixed-group traffic
//!    (non-learning draft kinds; the online-learned `adaptive` kind is
//!    deliberately order-dependent and excluded here).
//! 2. **Throughput scales with replicas** — saturation throughput over
//!    the mixed workload is monotone non-decreasing in replica count
//!    (within a noise slack), and the largest pool beats one replica
//!    outright when the host has >= 2 cores.
//! 3. **Priority SLO under overload** — at 2x the measured single-replica
//!    capacity (open-loop Poisson arrivals), high-priority deadline
//!    attainment under the EDF scheduler with the full pool is >= the
//!    single-replica FIFO baseline (the pre-scheduler serving shape).

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use stride::config::{SchedPolicy, ServeConfig};
use stride::metrics::{AcceptanceMonitor, Metrics};
use stride::models::NativeBackend;
use stride::nn::{ModelDims, NativeModel};
use stride::server::protocol::{ForecastRequest, Mode, Priority};
use stride::server::{
    start_engine_with_builder, BatcherHandle, ModelShape, ReplicaBuilder, ReplicaStacks,
};
use stride::specdec::{make_source, sd_generate_from, DraftKind};
use stride::util::json::Json;
use stride::util::rng::Rng;
use stride::util::stats::quantile;

const PATCH: usize = 4;
const N_CTX: usize = 32;
const N_HIST: usize = 8;
const HORIZON: usize = 16;

fn target_model() -> NativeModel {
    let dims =
        ModelDims { patch: PATCH, n_ctx: N_CTX, d_model: 32, n_layers: 2, n_heads: 4, d_ff: 64 };
    NativeModel::random("bench-target", dims, 0xA11CE)
}

fn draft_model() -> NativeModel {
    let dims =
        ModelDims { patch: PATCH, n_ctx: N_CTX, d_model: 16, n_layers: 1, n_heads: 2, d_ff: 32 };
    NativeModel::random("bench-draft", dims, 0xB0B)
}

/// Replicas share the base models' `Arc`-packed weights via
/// `NativeBackend::replicate` — the bench exercises the same zero-copy
/// replication path the server uses.
fn builder() -> ReplicaBuilder {
    let base_t = NativeBackend::new(target_model());
    let base_d = NativeBackend::new(draft_model());
    Arc::new(move |_r| {
        Ok(ReplicaStacks {
            target: Box::new(base_t.replicate()?),
            draft: Box::new(base_d.replicate()?),
        })
    })
}

fn shape() -> ModelShape {
    ModelShape { patch: PATCH, n_ctx: N_CTX }
}

fn base_cfg(replicas: usize, sched: SchedPolicy, queue_cap: usize) -> ServeConfig {
    let mut cfg = ServeConfig::default();
    cfg.backend = "native".into();
    cfg.replicas = replicas;
    cfg.sched = sched;
    cfg.queue_cap = queue_cap;
    cfg.max_batch = 8;
    cfg.max_wait_ms = 1;
    // Keep kernel-layer parallelism out of the picture: replica scaling
    // is the thing under test, and results must not depend on the
    // worker-pool size (they are bitwise invariant anyway; this is about
    // wall-clock attribution).
    cfg.threads = 1;
    cfg
}

struct Engine {
    handle: BatcherHandle,
    threads: Vec<std::thread::JoinHandle<()>>,
    metrics: Arc<Metrics>,
}

fn start(cfg: ServeConfig) -> anyhow::Result<Engine> {
    let metrics = Arc::new(Metrics::new());
    let monitor = Arc::new(AcceptanceMonitor::new(256, 0.8));
    let stop = Arc::new(AtomicBool::new(false));
    let (handle, threads) = start_engine_with_builder(
        cfg,
        shape(),
        builder(),
        metrics.clone(),
        monitor,
        stop,
    )?;
    Ok(Engine { handle, threads, metrics })
}

impl Engine {
    fn stop(self) {
        self.handle.shutdown();
        for t in self.threads {
            let _ = t.join();
        }
    }
}

fn history(seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    (0..N_HIST * PATCH).map(|_| (rng.normal() as f32) * 0.5).collect()
}

/// One mixed-scenario request: γ/σ/draft-kind/priority/deadline vary by
/// index, seeds pin determinism.
fn request(i: usize, with_deadline: bool) -> ForecastRequest {
    let kinds = [DraftKind::Model, DraftKind::Extrap];
    let priority = match i % 4 {
        0 => Priority::High,
        1 => Priority::Low,
        _ => Priority::Normal,
    };
    ForecastRequest {
        history: history(1000 + (i % 8) as u64),
        horizon: HORIZON,
        mode: Mode::Sd,
        gamma: Some(2 + (i % 2)),
        k: None,
        sigma: Some(if i % 3 == 0 { 0.8 } else { 0.5 }),
        cache: None,
        adaptive: None,
        draft: Some(kinds[i % kinds.len()]),
        dataset: None,
        priority,
        deadline_ms: if with_deadline {
            Some(match priority {
                Priority::High => 250,
                Priority::Normal => 1000,
                Priority::Low => 2000,
            })
        } else {
            None
        },
        seed: Some(0x5EED_0000 + i as u64),
        request_id: None,
    }
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// Phase 1: bit-identity of the scheduled path vs the bare engine.
fn run_identity(replica_counts: &[usize]) -> anyhow::Result<bool> {
    // Unscheduled references.
    let t = NativeBackend::new(target_model());
    let d = NativeBackend::new(draft_model());
    let n_req = 24;
    let mut refs: Vec<Vec<u32>> = Vec::new();
    for i in 0..n_req {
        let r = request(i, false);
        let mut spec = base_cfg(1, SchedPolicy::Edf, 256).spec_config();
        spec.gamma = r.gamma.unwrap();
        spec.policy.sigma = r.sigma.unwrap();
        spec.seed = r.seed.unwrap();
        spec.draft.kind = r.draft.unwrap();
        let mut src = make_source(&spec.draft, &d)?;
        let out = sd_generate_from(&t, src.as_mut(), &r.history, N_HIST, r.horizon, &spec)?;
        refs.push(bits(&out.patches));
    }
    let mut all_equal = true;
    for &replicas in replica_counts {
        let engine = start(base_cfg(replicas, SchedPolicy::Edf, 256))?;
        let handle = engine.handle.clone();
        let handles: Vec<_> = (0..n_req)
            .map(|i| {
                let h = handle.clone();
                std::thread::spawn(move || h.forecast(request(i, false)))
            })
            .collect();
        for (i, th) in handles.into_iter().enumerate() {
            let resp = th.join().unwrap().map_err(|e| anyhow::anyhow!("{e}"))?;
            if bits(&resp.forecast) != refs[i] {
                eprintln!("MISMATCH: replicas={replicas} request {i}");
                all_equal = false;
            }
        }
        engine.stop();
    }
    Ok(all_equal)
}

struct ThroughputPoint {
    replicas: usize,
    req_per_s: f64,
    p50_ms: f64,
    p99_ms: f64,
}

/// Phase 2: closed-loop saturation throughput per replica count.
fn run_throughput(replica_counts: &[usize], n_req: usize) -> anyhow::Result<Vec<ThroughputPoint>> {
    let mut points = Vec::new();
    for &replicas in replica_counts {
        let engine = start(base_cfg(replicas, SchedPolicy::Edf, 1024))?;
        let next = Arc::new(AtomicUsize::new(0));
        let lats: Arc<Mutex<Vec<f64>>> = Arc::new(Mutex::new(Vec::new()));
        let t0 = Instant::now();
        let workers: Vec<_> = (0..16)
            .map(|_| {
                let h = engine.handle.clone();
                let next = Arc::clone(&next);
                let lats = Arc::clone(&lats);
                std::thread::spawn(move || loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n_req {
                        return;
                    }
                    let t = Instant::now();
                    if let Ok(resp) = h.forecast(request(i, false)) {
                        assert_eq!(resp.forecast.len(), HORIZON * PATCH);
                        lats.lock().unwrap().push(t.elapsed().as_secs_f64() * 1e3);
                    }
                })
            })
            .collect();
        for w in workers {
            w.join().unwrap();
        }
        let wall = t0.elapsed().as_secs_f64();
        let mut l = lats.lock().unwrap().clone();
        l.sort_by(|a, b| a.partial_cmp(b).unwrap());
        anyhow::ensure!(l.len() == n_req, "throughput phase lost requests");
        let point = ThroughputPoint {
            replicas,
            req_per_s: n_req as f64 / wall,
            p50_ms: quantile(&l, 0.5),
            p99_ms: quantile(&l, 0.99),
        };
        println!(
            "throughput: replicas={} -> {:.1} req/s (p50 {:.2} ms, p99 {:.2} ms)",
            point.replicas, point.req_per_s, point.p50_ms, point.p99_ms
        );
        engine.stop();
        points.push(point);
    }
    Ok(points)
}

struct OverloadResult {
    label: &'static str,
    sent_high: usize,
    met_high: usize,
    shed: u64,
    expired: u64,
    high_p99_ms: f64,
}

/// Phase 3: open-loop Poisson arrivals at `rate_per_s` for `n_req`
/// requests with per-priority deadlines; returns high-priority deadline
/// attainment. Open loop: arrival times are fixed by the schedule, not
/// by completions — the queue genuinely backs up at 2x capacity.
fn run_overload(
    label: &'static str,
    cfg: ServeConfig,
    rate_per_s: f64,
    n_req: usize,
) -> anyhow::Result<OverloadResult> {
    let engine = start(cfg)?;
    // Pre-computed Poisson schedule (seeded: the arrival pattern is part
    // of the workload definition).
    let mut rng = Rng::new(0x09E4_100B);
    let mut offsets = Vec::with_capacity(n_req);
    let mut t_acc = 0.0f64;
    for _ in 0..n_req {
        t_acc += rng.exponential(rate_per_s);
        offsets.push(t_acc);
    }
    let offsets = Arc::new(offsets);
    let next = Arc::new(AtomicUsize::new(0));
    // (priority_is_high, met_deadline, latency_ms) per completed request.
    let outcomes: Arc<Mutex<Vec<(bool, bool, f64)>>> = Arc::new(Mutex::new(Vec::new()));
    let t0 = Instant::now();
    let workers: Vec<_> = (0..64)
        .map(|_| {
            let h = engine.handle.clone();
            let next = Arc::clone(&next);
            let offsets = Arc::clone(&offsets);
            let outcomes = Arc::clone(&outcomes);
            std::thread::spawn(move || loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= offsets.len() {
                    return;
                }
                let due = offsets[i];
                let now = t0.elapsed().as_secs_f64();
                if due > now {
                    std::thread::sleep(Duration::from_secs_f64(due - now));
                }
                let req = request(i, true);
                let is_high = req.priority == Priority::High;
                let deadline_ms = req.deadline_ms.unwrap();
                let t = Instant::now();
                let res = h.forecast(req);
                let lat_ms = t.elapsed().as_secs_f64() * 1e3;
                let met = res.is_ok() && lat_ms <= deadline_ms as f64;
                outcomes.lock().unwrap().push((is_high, met, lat_ms));
            })
        })
        .collect();
    for w in workers {
        w.join().unwrap();
    }
    let outcomes = outcomes.lock().unwrap().clone();
    let highs: Vec<&(bool, bool, f64)> = outcomes.iter().filter(|o| o.0).collect();
    let met_high = highs.iter().filter(|o| o.1).count();
    let mut high_lat: Vec<f64> = highs.iter().map(|o| o.2).collect();
    high_lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let shed = engine.metrics.sheds_total.load(Ordering::Relaxed);
    let expired = engine.metrics.expired_total.load(Ordering::Relaxed);
    let result = OverloadResult {
        label,
        sent_high: highs.len(),
        met_high,
        shed,
        expired,
        high_p99_ms: if high_lat.is_empty() { 0.0 } else { quantile(&high_lat, 0.99) },
    };
    println!(
        "overload[{label}]: high attainment {}/{} ({:.1}%), shed {}, expired {}, high p99 {:.1} ms",
        result.met_high,
        result.sent_high,
        100.0 * result.met_high as f64 / result.sent_high.max(1) as f64,
        result.shed,
        result.expired,
        result.high_p99_ms
    );
    engine.stop();
    Ok(result)
}

fn main() -> anyhow::Result<()> {
    let quick = std::env::var("STRIDE_BENCH_QUICK").as_deref() == Ok("1");
    let replica_counts: Vec<usize> = if quick { vec![1, 2] } else { vec![1, 2, 4] };
    let n_throughput = if quick { 96 } else { 240 };
    let n_overload = if quick { 160 } else { 400 };
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    println!(
        "serving_load: quick={quick}, replicas {replica_counts:?}, {cores} cores, \
         horizon {HORIZON}, patch {PATCH}"
    );

    // --- Phase 1: determinism.
    let bitwise_identical = run_identity(&replica_counts)?;
    println!("identity: scheduled == unscheduled engine at every replica count: {bitwise_identical}");

    // --- Phase 2: throughput scaling.
    let points = run_throughput(&replica_counts, n_throughput)?;
    let mut monotone = true;
    for w in points.windows(2) {
        // 8% slack absorbs scheduler/timing noise; a real regression
        // (replica count up, throughput down) still trips it.
        monotone &= w[1].req_per_s >= w[0].req_per_s * 0.92;
    }
    // Strict speedup needs real parallel hardware.
    let scales_up = if cores >= 2 {
        points.last().unwrap().req_per_s >= points[0].req_per_s * 1.15
    } else {
        println!("single-core host: skipping the strict speedup criterion");
        true
    };
    let throughput_ok = monotone && scales_up;

    // --- Phase 3: overload SLO. 2x the measured single-replica
    // capacity, FIFO/1-replica baseline vs EDF/full pool.
    let capacity = points[0].req_per_s;
    let rate = 2.0 * capacity;
    let fifo = run_overload(
        "fifo_1_replica",
        base_cfg(1, SchedPolicy::Fifo, 32),
        rate,
        n_overload,
    )?;
    let edf = run_overload(
        "edf_pool",
        base_cfg(*replica_counts.last().unwrap(), SchedPolicy::Edf, 32),
        rate,
        n_overload,
    )?;
    let att = |r: &OverloadResult| r.met_high as f64 / r.sent_high.max(1) as f64;
    let slo_ok = att(&edf) >= att(&fifo);

    // --- Record.
    let sweep = Json::Arr(
        points
            .iter()
            .map(|p| {
                Json::obj(vec![
                    ("replicas", Json::from(p.replicas)),
                    ("throughput_req_per_s", Json::Num(p.req_per_s)),
                    ("latency_p50_ms", Json::Num(p.p50_ms)),
                    ("latency_p99_ms", Json::Num(p.p99_ms)),
                ])
            })
            .collect(),
    );
    let overload_json = |r: &OverloadResult| {
        Json::obj(vec![
            ("label", Json::from(r.label)),
            ("high_sent", Json::from(r.sent_high)),
            ("high_met_deadline", Json::from(r.met_high)),
            ("high_attainment_frac", Json::Num(att(r))),
            ("high_latency_p99_ms", Json::Num(r.high_p99_ms)),
            ("shed_total", Json::from(r.shed as usize)),
            ("expired_total", Json::from(r.expired as usize)),
        ])
    };
    let vals = [
        points.iter().map(|p| p.req_per_s).collect::<Vec<_>>(),
        vec![att(&fifo), att(&edf), fifo.high_p99_ms, edf.high_p99_ms],
    ]
    .concat();
    anyhow::ensure!(
        vals.iter().all(|v| v.is_finite()),
        "non-finite value in bench results: {vals:?}"
    );
    let criteria_met = bitwise_identical && throughput_ok && slo_ok;
    let j = Json::obj(vec![
        ("bench", Json::from("serving_load")),
        ("quick", Json::from(quick)),
        (
            "config",
            Json::obj(vec![
                ("patch", Json::from(PATCH)),
                ("n_ctx", Json::from(N_CTX)),
                ("horizon_patches", Json::from(HORIZON)),
                ("cores", Json::from(cores)),
                ("throughput_requests", Json::from(n_throughput)),
                ("overload_requests", Json::from(n_overload)),
                ("overload_rate_req_per_s", Json::Num(rate)),
                (
                    "deadlines_ms",
                    Json::obj(vec![
                        ("high", Json::from(250usize)),
                        ("normal", Json::from(1000usize)),
                        ("low", Json::from(2000usize)),
                    ]),
                ),
            ]),
        ),
        ("replica_sweep", sweep),
        (
            "overload",
            Json::obj(vec![
                ("fifo_baseline", overload_json(&fifo)),
                ("edf_sched", overload_json(&edf)),
            ]),
        ),
        (
            "criteria",
            Json::obj(vec![
                ("bitwise_identical_to_unscheduled", Json::from(bitwise_identical)),
                ("throughput_monotone_in_replicas", Json::from(monotone)),
                ("throughput_scales_up", Json::from(scales_up)),
                ("high_priority_slo_ge_fifo_baseline", Json::from(slo_ok)),
                ("criteria_met", Json::from(criteria_met)),
            ]),
        ),
    ]);
    std::fs::create_dir_all("results")?;
    std::fs::write("results/BENCH_serving_load.json", format!("{j}\n"))?;
    println!("wrote results/BENCH_serving_load.json");

    anyhow::ensure!(
        criteria_met,
        "serving_load criteria failed: bitwise={bitwise_identical} monotone={monotone} \
         scales_up={scales_up} slo_ok={slo_ok}"
    );
    println!(
        "criteria met: deterministic at every replica count; throughput scales with \
         replicas; EDF keeps high-priority SLOs under 2x overload"
    );
    Ok(())
}
