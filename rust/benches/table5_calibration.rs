//! Table 5 — Acceptance estimation and predictor calibration: the
//! closed-form alpha-hat estimator (Prop. 4 / Remark 5) and the theory
//! predictors (Eqs. 4-5) vs measured values, including bias rows.
//!
//! Also reports the paper's verbatim Prop. 3 gamma rule next to the exact
//! rule (the paper's inequality drops an alpha factor — see theory.rs).

use stride::accept::{estimate_alpha_closed_form, AcceptancePolicy};
use stride::repro::{quick, Bench, RowCfg};
use stride::theory;
use stride::util::microbench::Table;

fn main() -> anyhow::Result<()> {
    let bench = Bench::from_env()?;
    let mut table = Table::new(
        "Table 5: Acceptance estimation and predictor calibration",
        &["Config", "alpha (est)", "alpha (meas)", "E[L] pred", "E[L] meas",
          "S_wall pred", "S_wall meas"],
    );

    let rows: Vec<(&str, f64, f64)> = if quick() {
        vec![("etth1", 0.5, 1.0)]
    } else {
        vec![
            ("etth1", 0.3, 1.25),
            ("etth1", 0.3, 1.5),
            ("etth1", 0.3, 3.0),
            ("etth1", 0.6, 1.0),
            ("etth2", 0.25, 1.0),
            ("etth2", 0.3, 1.0),
            ("etth2", 0.4, 1.0),
            ("etth2", 0.5, 1.0),
            ("etth2", 0.6, 1.0),
            ("ettm2", 0.7, 1.5),
        ]
    };

    for (dataset, sigma, bias) in rows {
        let cfg = RowCfg { dataset, sigma, bias, ..Default::default() };
        // Held-out alpha estimate from last-position heads (Prop. 4):
        // closed form is exact for bias=1; for bias != 1 it still reports
        // the canonical overlap (what the paper's estimator computes).
        let windows = bench.windows(&cfg)?;
        let p = bench.manifest.patch;
        let mut heads = Vec::new();
        for w in &windows {
            let n = w.history.len() / p;
            let mp = bench.target.forward(&w.history, n)?;
            let md = bench.draft.forward(&w.history, n)?;
            heads.push((
                mp[(n - 1) * p..n * p].to_vec(),
                md[(n - 1) * p..n * p].to_vec(),
            ));
        }
        let policy = AcceptancePolicy::new(sigma, 1.0);
        let est = estimate_alpha_closed_form(
            &policy,
            heads.iter().map(|(a, b)| (a.as_slice(), b.as_slice())),
        );
        let r = bench.run_row(&cfg)?;
        let el_pred = theory::expected_block_length(est.alpha_hat, cfg.gamma);
        let s_pred = theory::wall_speedup(est.alpha_hat, cfg.gamma, r.c);
        table.row(vec![
            format!("{dataset} (s={sigma}, bias={bias})"),
            format!("{:.4}", est.alpha_hat),
            format!("{:.4}", r.alpha_hat),
            format!("{:.2}", el_pred),
            format!("{:.2}", r.mean_block_len),
            format!("{:.2}x", s_pred),
            format!("{:.2}x", r.s_wall_meas),
        ]);
    }
    table.print();
    table.write_csv("results/table5_calibration.csv")?;

    // Gamma-rule comparison (paper discrepancy note).
    let mut rule = Table::new(
        "Prop. 3 gamma rule: paper's verbatim inequality vs exact condition",
        &["alpha", "c", "gamma* (paper rule)", "gamma* (exact)", "argmax scan"],
    );
    for (alpha, c) in [(0.9, 0.25), (0.97, 0.25), (0.99, 0.1), (0.999, 0.05)] {
        let scan = (1..=64)
            .max_by(|&a, &b| {
                theory::wall_speedup(alpha, a, c)
                    .partial_cmp(&theory::wall_speedup(alpha, b, c))
                    .unwrap()
            })
            .unwrap();
        rule.row(vec![
            format!("{alpha}"),
            format!("{c}"),
            format!("{}", theory::paper_gamma_rule(alpha, c, 64)),
            format!("{}", theory::optimal_gamma(alpha, c, 64)),
            format!("{scan}"),
        ]);
    }
    rule.print();
    rule.write_csv("results/table5_gamma_rule.csv")?;
    println!("wrote results/table5_calibration.csv, results/table5_gamma_rule.csv");
    Ok(())
}
