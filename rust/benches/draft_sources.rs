//! Draft-source comparison on the drifting-acceptance workload: the three
//! `specdec::draft` sources × {Practical, Lossless} over the same
//! regime-switching schedule the `adaptive_gamma` bench uses.
//!
//! Workload: per regime the *target* is an analytic AR(1) head whose
//! intercept drifts (a regime switch in the series' level response); the
//! classic model draft is **frozen** at the pre-drift target (the
//! distilled-draft-goes-stale scenario of Online Speculative Decoding),
//! so its acceptance collapses when the regime moves. Histories are drawn
//! from the synthetic datasets' regime windows, exactly as in
//! `adaptive_gamma.rs`. Each source runs the identical schedule with one
//! *persistent* source instance (the adaptive head carries its learned
//! state across windows — that is the whole point).
//!
//! Self-judging criteria (asserted in-bench, recorded in
//! `results/BENCH_draft_sources.json` — schema in `benches/README.md`):
//! * **Adaptation closes the drift gap**: `AdaptiveResidualDraft`'s α̂ on
//!   the post-drift regimes strictly exceeds the frozen `ModelDraft`'s,
//!   for both variants (the learned head re-fits the moved target from
//!   verification feedback alone — zero extra target passes).
//! * **Draft-free is cheapest**: `ExtrapolationDraft` achieves the lowest
//!   measured wall-clock cost ratio c of the three sources (the Eq. 5
//!   best case).
//! * All recorded numbers are finite.

use std::collections::BTreeMap;

use stride::data::Dataset;
use stride::models::AnalyticBackend;
use stride::specdec::{
    make_source, sd_generate_from, DecodeStats, DraftConfig, DraftKind, DraftSource, SpecConfig,
    Variant,
};
use stride::util::json::Json;
use stride::util::stats::gaussian_overlap;

const PATCH: usize = 4;
const SIGMA: f64 = 0.5;
const HORIZON: usize = 12;
const GAMMA: usize = 3;
/// Shared AR coefficient of target and (frozen) model draft.
const A_T: f32 = 0.3;
/// History length in patches fed to every window.
const N_HIST: usize = 4;

/// One acceptance regime: the target's intercept (the regime level the
/// frozen draft does not know about) and a synthetic-dataset segment the
/// histories are drawn from.
struct Regime {
    name: &'static str,
    /// Target intercept; the frozen model draft keeps b = 0, so the
    /// per-dimension draft-target mean gap equals `target_b`.
    target_b: f32,
    dataset: &'static str,
    t0: usize,
}

const REGIMES: &[Regime] = &[
    Regime { name: "pre", target_b: 0.0, dataset: "weather", t0: 2_000 },
    Regime { name: "drift_mid", target_b: 0.5, dataset: "etth1", t0: 6_000 },
    Regime { name: "drift_far", target_b: 1.0, dataset: "etth2", t0: 10_000 },
];

/// The switching schedule (revisits included: the adaptive head must
/// re-adapt, not converge once).
const SCHEDULE: &[usize] = &[0, 1, 2, 1, 2];

/// Frozen model draft's theoretical ᾱ in a regime (constant mean gap).
fn frozen_alpha(r: &Regime) -> f64 {
    gaussian_overlap((PATCH as f64).sqrt() * r.target_b as f64 / SIGMA)
}

struct SourceRun {
    per_regime: BTreeMap<&'static str, DecodeStats>,
    total: DecodeStats,
}

/// Run one persistent source over the whole schedule.
fn run_source(
    source: &mut dyn DraftSource,
    targets: &[AnalyticBackend],
    histories: &[Vec<Vec<f32>>],
    windows: usize,
    spec: &SpecConfig,
) -> anyhow::Result<SourceRun> {
    let mut per_regime: BTreeMap<&'static str, DecodeStats> = BTreeMap::new();
    let mut total = DecodeStats::default();
    let mut window_seq = 0u64;
    for (seg, &ri) in SCHEDULE.iter().enumerate() {
        let regime = &REGIMES[ri];
        for w in 0..windows {
            let hist = &histories[ri][(seg * windows + w) % histories[ri].len()];
            let mut cfg = *spec;
            cfg.seed = 0xD4A7_0000u64.wrapping_add(window_seq.wrapping_mul(0x9E37_79B9));
            window_seq += 1;
            let out =
                sd_generate_from(&targets[ri], source, hist, N_HIST, HORIZON, &cfg)?;
            per_regime.entry(regime.name).or_default().merge(&out.stats);
            total.merge(&out.stats);
        }
    }
    Ok(SourceRun { per_regime, total })
}

fn main() -> anyhow::Result<()> {
    let quick = std::env::var("STRIDE_BENCH_QUICK").as_deref() == Ok("1");
    let windows = if quick { 12 } else { 24 };

    // Histories from the synthetic datasets' regime segments (window
    // shapes tied to the corpora; the analytic heads make acceptance a
    // function of the draft gap alone).
    let mut histories: Vec<Vec<Vec<f32>>> = Vec::new();
    for r in REGIMES {
        let data = Dataset::by_name(r.dataset).expect("known dataset");
        let hists: Vec<Vec<f32>> = (0..windows * 2)
            .map(|w| {
                let ch = w % data.channels();
                data.norm_slice(ch, r.t0 + w * HORIZON * PATCH, N_HIST * PATCH)
            })
            .collect();
        histories.push(hists);
    }

    // Per-regime drifted targets; one frozen draft (the pre-drift target).
    let targets: Vec<AnalyticBackend> = REGIMES
        .iter()
        .map(|r| AnalyticBackend::new("t", PATCH, A_T, r.target_b))
        .collect();
    let frozen_draft = AnalyticBackend::new("d", PATCH, A_T, 0.0);

    let mut spec = SpecConfig::default();
    spec.gamma = GAMMA;
    spec.policy = stride::accept::AcceptancePolicy::new(SIGMA, 1.0);
    spec.max_residual_draws = 1000;

    let variants = [
        (Variant::Practical, stride::specdec::Emission::Sampled, "practical"),
        (Variant::Lossless, stride::specdec::Emission::Sampled, "lossless"),
    ];

    // (kind, variant) -> run results; per-kind merged stats for c.
    let mut runs: BTreeMap<(DraftKind, &'static str), SourceRun> = BTreeMap::new();
    let mut per_kind: BTreeMap<DraftKind, DecodeStats> = BTreeMap::new();
    for &(variant, emission, vname) in &variants {
        let mut s = spec;
        s.variant = variant;
        s.emission = emission;
        for kind in DraftKind::all() {
            // Persistent source per (kind, variant) run — the factory the
            // engine itself uses (defaults: linear extrap, eta 0.5).
            let dcfg = DraftConfig { kind, ..DraftConfig::default() };
            let mut src = make_source(&dcfg, &frozen_draft)?;
            let run = run_source(src.as_mut(), &targets, &histories, windows, &s)?;
            per_kind.entry(kind).or_default().merge(&run.total);
            runs.insert((kind, vname), run);
        }
    }

    // Post-drift α̂ per (kind, variant): merged over the b > 0 regimes.
    let post_alpha = |kind: DraftKind, vname: &'static str| -> f64 {
        let run = &runs[&(kind, vname)];
        let mut m = DecodeStats::default();
        for r in REGIMES.iter().filter(|r| r.target_b > 0.0) {
            if let Some(s) = run.per_regime.get(r.name) {
                m.merge(s);
            }
        }
        m.alpha_hat()
    };
    // Measured wall-clock cost ratio per kind, merged over both variants.
    let c_of = |kind: DraftKind| per_kind[&kind].cost_ratio();

    println!(
        "draft_sources: {windows} windows/segment, horizon {HORIZON}, gamma {GAMMA}, sigma {SIGMA}"
    );
    println!(
        "{:<10} {:<10} {:>10} {:>10} {:>12} {:>10}",
        "source", "variant", "alpha_all", "alpha_post", "E[L]", "updates"
    );
    let mut source_rows = Vec::new();
    for &(_, _, vname) in &variants {
        for kind in DraftKind::all() {
            let run = &runs[&(kind, vname)];
            let a_post = post_alpha(kind, vname);
            println!(
                "{:<10} {:<10} {:>10.3} {:>10.3} {:>12.2} {:>10}",
                kind.as_str(),
                vname,
                run.total.alpha_hat(),
                a_post,
                run.total.mean_block_len(),
                run.total.draft_updates,
            );
            let regime_alphas = Json::obj(
                REGIMES
                    .iter()
                    .map(|r| {
                        (
                            r.name,
                            Json::Num(
                                run.per_regime
                                    .get(r.name)
                                    .map(DecodeStats::alpha_hat)
                                    .unwrap_or(f64::NAN),
                            ),
                        )
                    })
                    .collect(),
            );
            source_rows.push(Json::obj(vec![
                ("kind", Json::from(kind.as_str())),
                ("variant", Json::from(vname)),
                ("alpha_hat_overall", Json::Num(run.total.alpha_hat())),
                ("alpha_hat_post_drift", Json::Num(a_post)),
                ("alpha_hat_per_regime", regime_alphas),
                ("mean_block_len", Json::Num(run.total.mean_block_len())),
                ("updates", Json::from(run.total.draft_updates)),
                ("rounds", Json::from(run.total.rounds)),
            ]));
        }
    }
    for kind in DraftKind::all() {
        println!("measured c ({}) = {:.5}", kind.as_str(), c_of(kind));
    }

    // --- Criteria.
    let mut adaptive_beats_frozen = true;
    for &(_, _, vname) in &variants {
        let a_ad = post_alpha(DraftKind::Adaptive, vname);
        let a_mo = post_alpha(DraftKind::Model, vname);
        println!(
            "post-drift alpha ({vname}): adaptive {a_ad:.3} vs frozen model {a_mo:.3} \
             (frozen theory: mid {:.3}, far {:.3})",
            frozen_alpha(&REGIMES[1]),
            frozen_alpha(&REGIMES[2]),
        );
        adaptive_beats_frozen &= a_ad > a_mo;
    }
    let (c_model, c_extrap, c_adaptive) =
        (c_of(DraftKind::Model), c_of(DraftKind::Extrap), c_of(DraftKind::Adaptive));
    let extrap_cheapest = c_extrap <= c_model && c_extrap <= c_adaptive;

    // Finiteness invariant (benches/README.md): no NaN/inf may reach the
    // results file.
    let mut all_vals = vec![c_model, c_extrap, c_adaptive];
    for &(_, _, vname) in &variants {
        for kind in DraftKind::all() {
            all_vals.push(runs[&(kind, vname)].total.alpha_hat());
            all_vals.push(post_alpha(kind, vname));
            all_vals.push(runs[&(kind, vname)].total.mean_block_len());
        }
    }
    anyhow::ensure!(
        all_vals.iter().all(|v| v.is_finite()),
        "non-finite value in bench results: {all_vals:?}"
    );

    let criteria_met = adaptive_beats_frozen && extrap_cheapest;
    let j = Json::obj(vec![
        ("bench", Json::from("draft_sources")),
        ("quick", Json::from(quick)),
        (
            "config",
            Json::obj(vec![
                ("patch", Json::from(PATCH)),
                ("sigma", Json::Num(SIGMA)),
                ("horizon_patches", Json::from(HORIZON)),
                ("gamma", Json::from(GAMMA)),
                ("windows_per_segment", Json::from(windows)),
                ("target_a", Json::Num(A_T as f64)),
                (
                    "regime_target_b",
                    Json::obj(
                        REGIMES
                            .iter()
                            .map(|r| (r.name, Json::Num(r.target_b as f64)))
                            .collect(),
                    ),
                ),
                ("adaptive_eta", Json::Num(0.5)),
            ]),
        ),
        ("sources", Json::Arr(source_rows)),
        (
            "measured_c",
            Json::obj(vec![
                ("model", Json::Num(c_model)),
                ("extrap", Json::Num(c_extrap)),
                ("adaptive", Json::Num(c_adaptive)),
            ]),
        ),
        (
            "criteria",
            Json::obj(vec![
                ("adaptive_alpha_beats_frozen_model_post_drift", Json::from(adaptive_beats_frozen)),
                ("extrap_lowest_measured_c", Json::from(extrap_cheapest)),
                ("criteria_met", Json::from(criteria_met)),
            ]),
        ),
    ]);
    std::fs::create_dir_all("results")?;
    std::fs::write("results/BENCH_draft_sources.json", format!("{j}\n"))?;
    println!("wrote results/BENCH_draft_sources.json");

    anyhow::ensure!(
        criteria_met,
        "draft-source criteria failed: adaptive beats frozen post-drift = \
         {adaptive_beats_frozen}, extrap lowest c = {extrap_cheapest} \
         (c: model {c_model:.5}, extrap {c_extrap:.5}, adaptive {c_adaptive:.5})"
    );
    println!("criteria met: online-adapted draft out-accepts the frozen model after drift; draft-free source is cheapest");
    Ok(())
}
