//! Chaos soak: open-loop Poisson traffic against the full scheduler
//! stack while a seeded [`stride::faultinject`] plan injects panics,
//! stalls, and NaN-poisoned forwards — the fault-tolerance tentpole's
//! endurance proof (no artifacts needed; synthetic native models over
//! `start_engine_with_builder`, replicas sharing `Arc`-packed weights so
//! restarts rebind without reloading floats).
//!
//! Self-judging criteria (asserted in-bench and recorded in
//! `results/BENCH_chaos_soak.json`; schema in `benches/README.md`):
//!
//! 1. **No hangs** — every request in the soak returns a terminal
//!    outcome (a forecast or a typed [`ServeError`]); nothing is lost.
//! 2. **No served NaNs** — every 200-equivalent response is finite in
//!    every bit, despite NaN injection at the model boundary.
//! 3. **Faults actually happened** — the plan's injection counters are
//!    nonzero and the finite budget is exhausted by the end.
//! 4. **Bounded recovery** — after the budget is exhausted, a tail of
//!    clean requests is served error-free.
//! 5. **Supervised restarts** — replica restarts equal injected panics
//!    (each panic costs one group, one restart, never the thread).

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use stride::config::ServeConfig;
use stride::metrics::{AcceptanceMonitor, Metrics};
use stride::models::NativeBackend;
use stride::nn::{ModelDims, NativeModel};
use stride::server::protocol::{ForecastRequest, Mode, Priority};
use stride::server::{
    start_engine_with_builder, BatcherHandle, ModelShape, ReplicaBuilder, ReplicaStacks,
};
use stride::specdec::DraftKind;
use stride::util::json::Json;
use stride::util::rng::Rng;

const PATCH: usize = 4;
const N_CTX: usize = 32;
const N_HIST: usize = 8;
const HORIZON: usize = 8;

fn builder() -> ReplicaBuilder {
    let t_dims =
        ModelDims { patch: PATCH, n_ctx: N_CTX, d_model: 32, n_layers: 2, n_heads: 4, d_ff: 64 };
    let d_dims =
        ModelDims { patch: PATCH, n_ctx: N_CTX, d_model: 16, n_layers: 1, n_heads: 2, d_ff: 32 };
    let base_t = NativeBackend::new(NativeModel::random("soak-target", t_dims, 0xCAFE));
    let base_d = NativeBackend::new(NativeModel::random("soak-draft", d_dims, 0xD00D));
    Arc::new(move |_r| {
        Ok(ReplicaStacks {
            target: Box::new(base_t.replicate()?),
            draft: Box::new(base_d.replicate()?),
        })
    })
}

struct Engine {
    handle: BatcherHandle,
    threads: Vec<std::thread::JoinHandle<()>>,
    metrics: Arc<Metrics>,
}

fn start(cfg: ServeConfig) -> anyhow::Result<Engine> {
    let metrics = Arc::new(Metrics::new());
    let monitor = Arc::new(AcceptanceMonitor::new(256, 0.8));
    let stop = Arc::new(AtomicBool::new(false));
    let (handle, threads) = start_engine_with_builder(
        cfg,
        ModelShape { patch: PATCH, n_ctx: N_CTX },
        builder(),
        metrics.clone(),
        monitor,
        stop,
    )?;
    Ok(Engine { handle, threads, metrics })
}

impl Engine {
    fn stop(self) {
        self.handle.shutdown();
        for t in self.threads {
            let _ = t.join();
        }
    }
}

fn history(seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    (0..N_HIST * PATCH).map(|_| (rng.normal() as f32) * 0.5).collect()
}

/// A mixed soak request: SD and baseline modes, both non-learning draft
/// kinds, varying γ/σ, pinned seeds.
fn request(i: usize) -> ForecastRequest {
    let kinds = [DraftKind::Model, DraftKind::Extrap];
    ForecastRequest {
        history: history(2000 + (i % 8) as u64),
        horizon: HORIZON,
        mode: if i % 5 == 4 { Mode::Baseline } else { Mode::Sd },
        gamma: Some(2 + (i % 2)),
        k: None,
        sigma: Some(if i % 3 == 0 { 0.8 } else { 0.5 }),
        cache: None,
        adaptive: None,
        draft: Some(kinds[i % kinds.len()]),
        dataset: None,
        priority: Priority::Normal,
        deadline_ms: None,
        seed: Some(0x50AC_0000 + i as u64),
        request_id: None,
    }
}

/// Outcome tally of one traffic phase.
#[derive(Default, Clone)]
struct Tally {
    ok: usize,
    /// Ok responses carrying a non-finite bit (must stay zero).
    poisoned_served: usize,
    errors: BTreeMap<String, usize>,
}

impl Tally {
    fn errors_total(&self) -> usize {
        self.errors.values().sum()
    }
    fn total(&self) -> usize {
        self.ok + self.errors_total()
    }
}

fn record(tally: &Mutex<Tally>, res: Result<Vec<f32>, &'static str>) {
    let mut t = tally.lock().unwrap();
    match res {
        Ok(forecast) => {
            if forecast.iter().any(|v| !v.is_finite()) {
                t.poisoned_served += 1;
            }
            t.ok += 1;
        }
        Err(code) => *t.errors.entry(code.to_string()).or_insert(0) += 1,
    }
}

/// Open-loop Poisson phase: seeded arrival schedule, every request ends
/// in the tally (the no-hang criterion is `tally.total() == n`).
fn run_phase(
    engine: &Engine,
    first: usize,
    n: usize,
    rate_per_s: f64,
) -> anyhow::Result<Tally> {
    let mut rng = Rng::new(0x0A05_EED + first as u64);
    let mut offsets = Vec::with_capacity(n);
    let mut t_acc = 0.0f64;
    for _ in 0..n {
        t_acc += rng.exponential(rate_per_s);
        offsets.push(t_acc);
    }
    let offsets = Arc::new(offsets);
    let next = Arc::new(AtomicUsize::new(0));
    let tally = Arc::new(Mutex::new(Tally::default()));
    let t0 = Instant::now();
    let workers: Vec<_> = (0..32)
        .map(|_| {
            let h = engine.handle.clone();
            let next = Arc::clone(&next);
            let offsets = Arc::clone(&offsets);
            let tally = Arc::clone(&tally);
            std::thread::spawn(move || loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= offsets.len() {
                    return;
                }
                let due = offsets[i];
                let now = t0.elapsed().as_secs_f64();
                if due > now {
                    std::thread::sleep(Duration::from_secs_f64(due - now));
                }
                let res = h
                    .forecast(request(first + i))
                    .map(|resp| resp.forecast)
                    .map_err(|e| e.code());
                record(&tally, res);
            })
        })
        .collect();
    for w in workers {
        w.join().unwrap();
    }
    let tally = tally.lock().unwrap().clone();
    anyhow::ensure!(tally.total() == n, "phase lost requests: {} of {n}", tally.total());
    Ok(tally)
}

fn main() -> anyhow::Result<()> {
    let quick = std::env::var("STRIDE_BENCH_QUICK").as_deref() == Ok("1");
    let n_soak = if quick { 120 } else { 400 };
    let n_tail = if quick { 24 } else { 60 };
    let rate = if quick { 60.0 } else { 80.0 };
    let max_faults = if quick { 16u64 } else { 40 };

    let mut cfg = ServeConfig::default();
    cfg.backend = "native".into();
    cfg.replicas = 2;
    cfg.max_batch = 8;
    cfg.max_wait_ms = 1;
    cfg.queue_cap = 1024;
    cfg.threads = 1;
    // The fixed fault schedule: all three failure shapes, a finite
    // budget so the soak has a guaranteed-quiescent tail.
    cfg.fault.enabled = true;
    cfg.fault.seed = 0xBAD_5EED;
    cfg.fault.p_panic = 0.002;
    cfg.fault.p_stall = 0.004;
    cfg.fault.stall_ms = 10;
    cfg.fault.p_nan = 0.002;
    cfg.fault.max_faults = max_faults;
    cfg.validate()?;

    println!(
        "chaos_soak: quick={quick}, {n_soak} soak + {n_tail} tail requests at {rate}/s, \
         fault budget {max_faults}"
    );
    let t0 = Instant::now();
    let engine = start(cfg.clone())?;
    let plan = engine.handle.fault.clone().expect("soak must run with an armed plan");

    // --- Phase 1: soak under injection.
    let soak = run_phase(&engine, 0, n_soak, rate)?;
    println!(
        "soak: {} ok, {} typed errors ({:?}), injected {} (panics {}, stalls {}, nans {})",
        soak.ok,
        soak.errors_total(),
        soak.errors,
        plan.injected(),
        plan.panics(),
        plan.stalls(),
        plan.nans()
    );

    // --- Drain any injection budget the soak left unspent, so the tail
    // is measured against a quiescent plan (the budget is finite by
    // construction; burn it with throwaway traffic if needed).
    let mut burn = 0usize;
    while !plan.exhausted() && burn < 1200 {
        let _ = engine.handle.forecast(request(n_soak + burn));
        burn += 1;
    }
    let exhausted = plan.exhausted();

    // --- Phase 2: recovery tail. The plan is spent; every request must
    // be served clean.
    let tail_first = n_soak + burn;
    let tail = run_phase(&engine, tail_first, n_tail, rate)?;
    println!(
        "tail: {} ok, {} errors (recovery after {} burned requests, exhausted={exhausted})",
        tail.ok,
        tail.errors_total(),
        burn
    );

    let restarts = engine.metrics.counter("replica_restarts");
    let failures = engine.metrics.counter("replica_failures");
    let requeues = engine.metrics.counter("requeues");
    let numeric = engine.metrics.counter("numeric_faults");
    let wall = t0.elapsed().as_secs_f64();
    engine.stop();

    // --- Criteria.
    let no_hangs = soak.total() == n_soak && tail.total() == n_tail;
    let no_nonfinite = soak.poisoned_served == 0 && tail.poisoned_served == 0;
    let faults_injected = plan.injected() > 0 && exhausted;
    let recovered_clean = tail.errors_total() == 0;
    let restarts_match_panics = restarts == plan.panics();
    let criteria_met =
        no_hangs && no_nonfinite && faults_injected && recovered_clean && restarts_match_panics;

    // Key names deliberately avoid `nan`/`inf` substrings — scripts/ci.sh
    // rejects those tokens anywhere in a bench record (the finiteness
    // invariant in benches/README.md), so the NaN knob serializes as
    // `p_poison` and the counters as `poison*`.
    let tally_json = |t: &Tally| {
        Json::obj(vec![
            ("ok", Json::from(t.ok)),
            ("poisoned_served", Json::from(t.poisoned_served)),
            (
                "errors",
                Json::obj(
                    t.errors
                        .iter()
                        .map(|(k, v)| (k.as_str(), Json::from(*v)))
                        .collect::<Vec<_>>(),
                ),
            ),
        ])
    };
    let j = Json::obj(vec![
        ("bench", Json::from("chaos_soak")),
        ("quick", Json::from(quick)),
        (
            "config",
            Json::obj(vec![
                ("patch", Json::from(PATCH)),
                ("n_ctx", Json::from(N_CTX)),
                ("horizon_patches", Json::from(HORIZON)),
                ("replicas", Json::from(2usize)),
                ("soak_requests", Json::from(n_soak)),
                ("tail_requests", Json::from(n_tail)),
                ("rate_req_per_s", Json::Num(rate)),
                (
                    "fault",
                    Json::obj(vec![
                        ("seed", Json::from(cfg.fault.seed as usize)),
                        ("p_panic", Json::Num(cfg.fault.p_panic)),
                        ("p_stall", Json::Num(cfg.fault.p_stall)),
                        ("stall_ms", Json::from(cfg.fault.stall_ms as usize)),
                        ("p_poison", Json::Num(cfg.fault.p_nan)),
                        ("max_faults", Json::from(cfg.fault.max_faults as usize)),
                    ]),
                ),
            ]),
        ),
        ("soak", tally_json(&soak)),
        ("tail", tally_json(&tail)),
        ("burned_to_exhaust", Json::from(burn)),
        (
            "injection",
            Json::obj(vec![
                ("injected", Json::from(plan.injected() as usize)),
                ("panics", Json::from(plan.panics() as usize)),
                ("stalls", Json::from(plan.stalls() as usize)),
                ("poisons", Json::from(plan.nans() as usize)),
                ("exhausted", Json::from(exhausted)),
            ]),
        ),
        (
            "supervision",
            Json::obj(vec![
                ("replica_restarts", Json::from(restarts as usize)),
                ("replica_failures", Json::from(failures as usize)),
                ("requeues", Json::from(requeues as usize)),
                ("numeric_faults", Json::from(numeric as usize)),
            ]),
        ),
        ("wall_s", Json::Num(wall)),
        (
            "criteria",
            Json::obj(vec![
                ("no_hangs", Json::from(no_hangs)),
                ("no_poisoned_bits_served", Json::from(no_nonfinite)),
                ("faults_injected_and_exhausted", Json::from(faults_injected)),
                ("recovery_tail_error_free", Json::from(recovered_clean)),
                ("restarts_match_panics", Json::from(restarts_match_panics)),
                ("criteria_met", Json::from(criteria_met)),
            ]),
        ),
    ]);
    std::fs::create_dir_all("results")?;
    std::fs::write("results/BENCH_chaos_soak.json", format!("{j}\n"))?;
    println!("wrote results/BENCH_chaos_soak.json");

    anyhow::ensure!(
        criteria_met,
        "chaos_soak criteria failed: no_hangs={no_hangs} no_nonfinite={no_nonfinite} \
         injected={faults_injected} recovered={recovered_clean} \
         restarts_match_panics={restarts_match_panics}"
    );
    println!(
        "criteria met: every request terminal, no served non-finite bits, faults injected \
         and absorbed, clean recovery tail, restarts == injected panics"
    );
    Ok(())
}
