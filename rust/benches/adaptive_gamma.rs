//! Adaptive-γ controller vs fixed-γ sweep on drifting-α synthetic
//! workloads.
//!
//! The paper picks γ offline; this bench measures what that costs when the
//! acceptance rate drifts. Three regimes with very different ᾱ (constant
//! mean-gap analytic heads — the i.i.d. setting of Eqs. 2–4, so the
//! theoretical ᾱ and γ* are known in closed form) are visited in a
//! switching schedule, with forecast histories drawn from the synthetic
//! datasets' regime windows (`data/synthetic.rs`). Every fixed γ is run
//! over the identical workload, then the adaptive controller
//! (`specdec::controller`) runs it once with a single long-lived
//! [`GammaController`] carried across all windows.
//!
//! Cost model: analytic heads have no meaningful wall clock, so rounds are
//! priced by the paper's own unit — a round with draft length γ costs
//! `c·γ + 1` target-forward equivalents (Eq. 5's denominator) with a fixed
//! `c`; the same `c` is given to the controller via `c_override`, making
//! the whole bench deterministic. Throughput = emitted patches per
//! target-unit.
//!
//! Acceptance criteria (asserted in-bench, recorded in
//! `results/BENCH_adaptive_gamma.json` — schema in `benches/README.md`):
//! the controller reaches ≥ 90% of the best fixed-γ throughput *on every
//! regime*, beats the worst fixed-γ on every regime and overall, and all
//! recorded numbers are finite.

use std::collections::BTreeMap;

use stride::data::Dataset;
use stride::models::AnalyticBackend;
use stride::specdec::{
    sd_generate, sd_generate_with_controller, AdaptiveConfig, GammaController, SpecConfig,
};
use stride::util::json::Json;
use stride::util::stats::gaussian_overlap;

const PATCH: usize = 4;
const SIGMA: f64 = 0.5;
/// Simulated draft/target cost ratio (a 4x-smaller draft is well below
/// this; 0.08 keeps the optimal-γ spread wide across the regimes).
const COST_C: f64 = 0.08;
const HORIZON: usize = 12;
const GAMMA0: usize = 3;
const FIXED_GAMMAS: &[usize] = &[1, 2, 3, 4, 6, 8];

/// One acceptance regime: a draft whose constant mean gap to the target
/// sets ᾱ, and a synthetic dataset segment the histories are drawn from.
struct Regime {
    name: &'static str,
    /// Per-dimension draft-target mean gap (drives ᾱ = 2Φ(-√p·gap/2σ)).
    gap: f32,
    dataset: &'static str,
    /// Window start offset into the dataset (regime segment).
    t0: usize,
}

const REGIMES: &[Regime] = &[
    Regime { name: "calm", gap: 0.05, dataset: "weather", t0: 2_000 },
    Regime { name: "mixed", gap: 0.25, dataset: "etth1", t0: 6_000 },
    Regime { name: "shift", gap: 0.9, dataset: "etth2", t0: 10_000 },
];

/// The switching schedule: indices into REGIMES (revisits included so the
/// controller must re-adapt, not just converge once).
const SCHEDULE: &[usize] = &[0, 1, 2, 0, 2, 1];

fn regime_alpha(r: &Regime) -> f64 {
    gaussian_overlap((PATCH as f64).sqrt() * r.gap as f64 / SIGMA)
}

/// Per-regime and overall (emitted, cost) accumulator.
#[derive(Default)]
struct Tally {
    per_regime: BTreeMap<&'static str, (f64, f64)>,
}

impl Tally {
    fn add(&mut self, regime: &'static str, emitted: f64, cost: f64) {
        let e = self.per_regime.entry(regime).or_insert((0.0, 0.0));
        e.0 += emitted;
        e.1 += cost;
    }
    fn throughput(&self, regime: &str) -> f64 {
        let (e, c) = self.per_regime[regime];
        e / c
    }
    fn overall(&self) -> f64 {
        let (e, c) = self
            .per_regime
            .values()
            .fold((0.0, 0.0), |acc, v| (acc.0 + v.0, acc.1 + v.1));
        e / c
    }
}

/// Decode every window of the schedule under one policy. `ctrl` carries
/// across windows for the adaptive policy; `None` uses `spec.gamma`
/// verbatim.
fn run_policy(
    target: &AnalyticBackend,
    drafts: &[AnalyticBackend],
    histories: &[Vec<Vec<f32>>],
    windows: usize,
    spec: &SpecConfig,
    mut ctrl: Option<&mut GammaController>,
) -> anyhow::Result<(Tally, f64)> {
    let mut tally = Tally::default();
    let mut gamma_sum = 0.0;
    let mut rounds_total = 0.0;
    let mut window_seq = 0u64;
    for (seg, &ri) in SCHEDULE.iter().enumerate() {
        let regime = &REGIMES[ri];
        for w in 0..windows {
            let hist = &histories[ri][(seg * windows + w) % histories[ri].len()];
            let mut cfg = *spec;
            cfg.seed = 0xADA9_0000u64.wrapping_add(window_seq * 0x9E37_79B9);
            window_seq += 1;
            let out = match ctrl.as_deref_mut() {
                Some(c) => sd_generate_with_controller(
                    target,
                    &drafts[ri],
                    hist,
                    hist.len() / PATCH,
                    HORIZON,
                    &cfg,
                    c,
                )?,
                None => sd_generate(target, &drafts[ri], hist, hist.len() / PATCH, HORIZON, &cfg)?,
            };
            let cost: f64 =
                out.rounds.iter().map(|r| COST_C * r.gamma as f64 + 1.0).sum();
            gamma_sum += out.rounds.iter().map(|r| r.gamma as f64).sum::<f64>();
            rounds_total += out.rounds.len() as f64;
            tally.add(regime.name, HORIZON as f64, cost);
        }
    }
    Ok((tally, gamma_sum / rounds_total.max(1.0)))
}

fn main() -> anyhow::Result<()> {
    let quick = std::env::var("STRIDE_BENCH_QUICK").as_deref() == Ok("1");
    let windows = if quick { 20 } else { 40 };

    // Histories from the synthetic datasets' regime segments. The
    // constant-gap analytic heads make alpha independent of the history
    // values, so the workload's alpha drift is controlled purely by the
    // regime's draft gap — the histories tie window shapes to the
    // datasets' regime windows.
    let mut histories: Vec<Vec<Vec<f32>>> = Vec::new();
    for r in REGIMES {
        let data = Dataset::by_name(r.dataset).expect("known dataset");
        let hists: Vec<Vec<f32>> = (0..windows * 2)
            .map(|w| {
                let ch = w % data.channels();
                data.norm_slice(ch, r.t0 + w * HORIZON * PATCH, 4 * PATCH)
            })
            .collect();
        histories.push(hists);
    }

    let target = AnalyticBackend::new("t", PATCH, 0.0, 0.0);
    let drafts: Vec<AnalyticBackend> =
        REGIMES.iter().map(|r| AnalyticBackend::new("d", PATCH, 0.0, r.gap)).collect();

    let mut spec = SpecConfig::default();
    spec.gamma = GAMMA0;
    spec.policy = stride::accept::AcceptancePolicy::new(SIGMA, 1.0);

    // --- Fixed-γ sweep over the identical workload.
    let mut fixed: BTreeMap<usize, Tally> = BTreeMap::new();
    for &g in FIXED_GAMMAS {
        let mut s = spec;
        s.gamma = g;
        let (tally, _) = run_policy(&target, &drafts, &histories, windows, &s, None)?;
        fixed.insert(g, tally);
    }

    // --- Adaptive: one long-lived controller across the whole stream.
    let acfg = AdaptiveConfig {
        max_gamma: 12,
        halflife: 8.0,
        warmup: 2,
        dwell: 2,
        hysteresis: 0.02,
        c_override: COST_C,
        ..AdaptiveConfig::default()
    };
    let mut ctrl = GammaController::new(acfg, GAMMA0, SIGMA);
    let mut aspec = spec;
    aspec.adaptive = Some(acfg);
    let (adaptive, mean_gamma) =
        run_policy(&target, &drafts, &histories, windows, &aspec, Some(&mut ctrl))?;
    let cstate = ctrl.state();

    // --- Report + criteria.
    println!(
        "adaptive_gamma: {} windows/segment, horizon {HORIZON}, c = {COST_C}, sigma = {SIGMA}",
        windows
    );
    println!(
        "{:<8} {:>10} {:>10} {:>10} {:>10}",
        "policy", "overall", "calm", "mixed", "shift"
    );
    for (&g, t) in &fixed {
        println!(
            "gamma={:<2} {:>10.3} {:>10.3} {:>10.3} {:>10.3}",
            g,
            t.overall(),
            t.throughput("calm"),
            t.throughput("mixed"),
            t.throughput("shift")
        );
    }
    println!(
        "{:<8} {:>10.3} {:>10.3} {:>10.3} {:>10.3}   (mean gamma {:.2}, {} changes)",
        "adaptive",
        adaptive.overall(),
        adaptive.throughput("calm"),
        adaptive.throughput("mixed"),
        adaptive.throughput("shift"),
        mean_gamma,
        cstate.gamma_changes,
    );

    let mut regime_rows = Vec::new();
    let mut min_ratio = f64::INFINITY;
    let mut beats_worst_everywhere = true;
    for r in REGIMES {
        let best = fixed
            .values()
            .map(|t| t.throughput(r.name))
            .fold(f64::MIN, f64::max);
        let worst = fixed
            .values()
            .map(|t| t.throughput(r.name))
            .fold(f64::MAX, f64::min);
        let thr = adaptive.throughput(r.name);
        let ratio = thr / best;
        min_ratio = min_ratio.min(ratio);
        beats_worst_everywhere &= thr > worst;
        println!(
            "  {}: adaptive/best = {:.3} (best fixed {:.3}, worst fixed {:.3})",
            r.name, ratio, best, worst
        );
        regime_rows.push(Json::obj(vec![
            ("name", Json::from(r.name)),
            ("dataset", Json::from(r.dataset)),
            ("alpha_theory", Json::Num(regime_alpha(r))),
            (
                "gamma_star",
                Json::from(stride::theory::optimal_gamma(regime_alpha(r), COST_C, 12)),
            ),
            ("adaptive_throughput", Json::Num(thr)),
            ("best_fixed_throughput", Json::Num(best)),
            ("worst_fixed_throughput", Json::Num(worst)),
            ("ratio_to_best", Json::Num(ratio)),
        ]));
    }
    let worst_overall = fixed.values().map(Tally::overall).fold(f64::MAX, f64::min);
    let beats_worst_overall = adaptive.overall() > worst_overall;

    // Finiteness invariant (benches/README.md): no NaN/inf may reach the
    // results file.
    let mut all_vals: Vec<f64> = vec![adaptive.overall(), mean_gamma, min_ratio];
    for t in fixed.values() {
        all_vals.push(t.overall());
        for r in REGIMES {
            all_vals.push(t.throughput(r.name));
        }
    }
    anyhow::ensure!(
        all_vals.iter().all(|v| v.is_finite()),
        "non-finite throughput in bench results: {all_vals:?}"
    );

    let fixed_rows: Vec<Json> = fixed
        .iter()
        .map(|(&g, t)| {
            Json::obj(vec![
                ("gamma", Json::from(g)),
                ("overall_throughput", Json::Num(t.overall())),
                (
                    "per_regime",
                    Json::obj(
                        REGIMES
                            .iter()
                            .map(|r| (r.name, Json::Num(t.throughput(r.name))))
                            .collect(),
                    ),
                ),
            ])
        })
        .collect();

    let criteria_met = min_ratio >= 0.9 && beats_worst_everywhere && beats_worst_overall;
    let j = Json::obj(vec![
        ("bench", Json::from("adaptive_gamma")),
        ("quick", Json::from(quick)),
        (
            "config",
            Json::obj(vec![
                ("patch", Json::from(PATCH)),
                ("sigma", Json::Num(SIGMA)),
                ("cost_ratio_c", Json::Num(COST_C)),
                ("horizon_patches", Json::from(HORIZON)),
                ("windows_per_segment", Json::from(windows)),
                ("gamma0", Json::from(GAMMA0)),
                ("max_gamma", Json::from(acfg.max_gamma)),
                ("halflife", Json::Num(acfg.halflife)),
                ("dwell", Json::from(acfg.dwell)),
                ("hysteresis", Json::Num(acfg.hysteresis)),
            ]),
        ),
        ("regimes", Json::Arr(regime_rows)),
        ("fixed", Json::Arr(fixed_rows)),
        (
            "adaptive",
            Json::obj(vec![
                ("overall_throughput", Json::Num(adaptive.overall())),
                (
                    "per_regime",
                    Json::obj(
                        REGIMES
                            .iter()
                            .map(|r| (r.name, Json::Num(adaptive.throughput(r.name))))
                            .collect(),
                    ),
                ),
                ("mean_gamma", Json::Num(mean_gamma)),
                ("gamma_changes", Json::from(cstate.gamma_changes)),
                ("final_gamma", Json::from(cstate.gamma)),
                ("final_alpha_hat", Json::Num(cstate.alpha_hat)),
            ]),
        ),
        (
            "criteria",
            Json::obj(vec![
                ("min_ratio_to_best_fixed", Json::Num(min_ratio)),
                ("required_ratio", Json::Num(0.9)),
                ("beats_worst_fixed_per_regime", Json::from(beats_worst_everywhere)),
                ("beats_worst_fixed_overall", Json::from(beats_worst_overall)),
                ("criteria_met", Json::from(criteria_met)),
            ]),
        ),
    ]);
    std::fs::create_dir_all("results")?;
    std::fs::write("results/BENCH_adaptive_gamma.json", format!("{j}\n"))?;
    println!("wrote results/BENCH_adaptive_gamma.json");

    anyhow::ensure!(
        criteria_met,
        "adaptive controller failed its acceptance criteria: \
         min ratio to best fixed {min_ratio:.3} (need >= 0.9), \
         beats worst per-regime: {beats_worst_everywhere}, \
         beats worst overall: {beats_worst_overall}"
    );
    println!("criteria met: controller within {:.1}% of best fixed gamma everywhere", {
        100.0 * min_ratio
    });
    Ok(())
}
