//! Table 1 — Main results across datasets and models.
//!
//! For each dataset: the Timer-base baseline row, then 0.25x-draft SD rows
//! sweeping sigma (and batch for ETTh1, bias + pred-len for ETTm2), printing
//! MSE / MAE / alpha-hat / E[L] / gamma / c / S_wall (pred & meas).
//!
//! Run: `cargo bench --bench table1_main` (STRIDE_BENCH_QUICK=1 for CI).

use stride::repro::{fmt_row, quick, Bench, RowCfg};
use stride::util::microbench::Table;

fn main() -> anyhow::Result<()> {
    let bench = Bench::from_env()?;
    let mut table = Table::new(
        "Table 1: Main results across datasets and models",
        &["Dataset", "Model", "MSE", "MAE", "alpha", "E[L]", "g", "c", "S_wall (pred/meas)"],
    );

    let mut rows: Vec<RowCfg> = Vec::new();
    let sig_etth1: &[f64] = if quick() { &[0.5] } else { &[0.35, 0.4, 0.45, 0.5, 0.55, 0.6, 0.65, 0.7] };
    for &sigma in sig_etth1 {
        rows.push(RowCfg { dataset: "etth1", sigma, ..Default::default() });
    }
    // Batch sweep at sigma=0.6 (the paper's batch=64/128 rows; our artifact
    // variants cap at 32).
    for &batch in if quick() { &[8][..] } else { &[8, 32][..] } {
        rows.push(RowCfg { dataset: "etth1", sigma: 0.6, batch, windows: 32, ..Default::default() });
    }
    let sig_etth2: &[f64] = if quick() { &[0.5] } else { &[0.3, 0.35, 0.4, 0.45, 0.5, 0.55, 0.6, 0.65] };
    for &sigma in sig_etth2 {
        rows.push(RowCfg { dataset: "etth2", sigma, ..Default::default() });
    }
    // ETTm2: pred-len 336 (14 patches) and 96, with bias=1.5 rows.
    if !quick() {
        rows.push(RowCfg { dataset: "ettm2", sigma: 0.7, bias: 1.5, horizon: 14, windows: 14, ..Default::default() });
        rows.push(RowCfg { dataset: "ettm2", sigma: 0.7, bias: 1.5, ..Default::default() });
        rows.push(RowCfg { dataset: "ettm2", sigma: 0.7, bias: 1.5, gamma: 2, ..Default::default() });
        rows.push(RowCfg { dataset: "ettm2", sigma: 0.8, bias: 1.5, gamma: 2, ..Default::default() });
    } else {
        rows.push(RowCfg { dataset: "ettm2", sigma: 0.7, bias: 1.5, ..Default::default() });
    }
    // Weather: gamma 3/4 at sigma 0.8, gamma 2 at 0.6/0.7.
    let weather: &[(f64, usize)] =
        if quick() { &[(0.8, 3)] } else { &[(0.8, 3), (0.8, 4), (0.6, 2), (0.7, 2)] };
    for &(sigma, gamma) in weather {
        rows.push(RowCfg { dataset: "weather", sigma, gamma, ..Default::default() });
    }

    let mut last_dataset = "";
    for cfg in &rows {
        let r = bench.run_row(cfg)?;
        if cfg.dataset != last_dataset {
            table.row(vec![
                cfg.dataset.into(),
                "Timer-base (baseline)".into(),
                format!("{:.4}", r.baseline_mse),
                format!("{:.4}", r.baseline_mae),
                "-".into(),
                "-".into(),
                "-".into(),
                "-".into(),
                "- / 1.00x".into(),
            ]);
            last_dataset = cfg.dataset;
        }
        table.row(fmt_row(&r));
    }

    table.print();
    table.write_csv("results/table1_main.csv")?;
    println!("wrote results/table1_main.csv");
    Ok(())
}
