//! Tree speculation: accepted-run length and Eq. 5 speedup vs k.
//!
//! The tentpole question: does verifying k candidate branches per round
//! actually lengthen the accepted run at the rate the max-of-k
//! generalization of Eq. 4 predicts, and when does that gain survive the
//! k-multiplied draft cost in Eq. 5's denominator? Same drifting-α
//! workload design as `adaptive_gamma`: three constant-gap regimes
//! (known closed-form ᾱ) visited in a switching schedule, histories from
//! the synthetic datasets' regime windows. Every k ∈ {1, 2, 4} decodes
//! the identical workload with identical per-window seeds.
//!
//! Cost model: a round with k branches of draft length γ costs
//! `c·k·γ + 1` target-forward equivalents (the tree Eq. 5 denominator);
//! γ = 0 tail rounds cost 1. Throughput = emitted patches per
//! target-unit.
//!
//! Acceptance criteria (asserted in-bench, recorded in
//! `results/BENCH_tree_speculation.json` — schema in
//! `benches/README.md`): the mean accepted run at k = 4 is strictly
//! longer than at k = 1 overall *and in every regime*, measured
//! full-γ accepted runs track the independent-branch theory
//! `E[L_k] − 1 = Σ(1 − (1 − αⁱ)^k)`, and every recorded number is
//! finite.
//!
//! SIMD + stacked-GEMM PR addition: a native (kernel-layer) decode pair
//! re-runs the same tree workload with the stacked verify toggled on and
//! off — one batched target forward per round vs the retained sequential
//! extend/rollback reference. Bit identity of the emitted patches is
//! asserted in-bench, the per-decode times land in the JSON record, and
//! the identity folds into `criteria_met`.

use std::collections::BTreeMap;
use std::time::Instant;

use stride::data::Dataset;
use stride::models::{AnalyticBackend, NativeBackend};
use stride::nn::{ModelDims, NativeModel};
use stride::specdec::{sd_generate_tree, set_stacked_verify, SpecConfig};
use stride::theory;
use stride::util::json::Json;
use stride::util::stats::gaussian_overlap;

const PATCH: usize = 4;
const SIGMA: f64 = 0.5;
/// Simulated draft/target cost ratio. Cheap drafts are where the tree
/// pays: Eq. 5's tree denominator charges c per *branch* step.
const COST_C: f64 = 0.02;
const HORIZON: usize = 12;
const GAMMA: usize = 4;
const KS: &[usize] = &[1, 2, 4];

/// One acceptance regime: constant per-dimension draft-target mean gap
/// (drives ᾱ = 2Φ(-√p·gap/2σ)) plus the dataset segment histories are
/// drawn from.
struct Regime {
    name: &'static str,
    gap: f32,
    dataset: &'static str,
    t0: usize,
}

const REGIMES: &[Regime] = &[
    Regime { name: "calm", gap: 0.05, dataset: "weather", t0: 2_000 },
    Regime { name: "mixed", gap: 0.25, dataset: "etth1", t0: 6_000 },
    Regime { name: "shift", gap: 0.9, dataset: "etth2", t0: 10_000 },
];

/// The switching schedule (revisits included — the drift is the point).
const SCHEDULE: &[usize] = &[0, 1, 2, 0, 2, 1];

fn regime_alpha(r: &Regime) -> f64 {
    gaussian_overlap((PATCH as f64).sqrt() * r.gap as f64 / SIGMA)
}

/// Per-regime accumulator: proposal-round accepted counts, full-γ round
/// accepted counts, emitted patches, and priced cost.
#[derive(Default, Clone)]
struct Tally {
    accepted: f64,
    prop_rounds: f64,
    full_accepted: f64,
    full_rounds: f64,
    emitted: f64,
    cost: f64,
}

impl Tally {
    fn mean_accepted(&self) -> f64 {
        self.accepted / self.prop_rounds.max(1.0)
    }
    fn full_gamma_mean_accepted(&self) -> f64 {
        self.full_accepted / self.full_rounds.max(1.0)
    }
    fn throughput(&self) -> f64 {
        self.emitted / self.cost.max(1e-12)
    }
    fn merge(&mut self, o: &Tally) {
        self.accepted += o.accepted;
        self.prop_rounds += o.prop_rounds;
        self.full_accepted += o.full_accepted;
        self.full_rounds += o.full_rounds;
        self.emitted += o.emitted;
        self.cost += o.cost;
    }
}

fn main() -> anyhow::Result<()> {
    let quick = std::env::var("STRIDE_BENCH_QUICK").as_deref() == Ok("1");
    let windows = if quick { 30 } else { 120 };

    let mut histories: Vec<Vec<Vec<f32>>> = Vec::new();
    for r in REGIMES {
        let data = Dataset::by_name(r.dataset).expect("known dataset");
        // Wrap window starts inside the series (length 14_400): full
        // mode walks past the end otherwise, and the acceptance regime
        // is set by the head gap, not the history content.
        let span = data.len() - 4 * PATCH;
        let hists: Vec<Vec<f32>> = (0..windows * 2)
            .map(|w| {
                let ch = w % data.channels();
                data.norm_slice(ch, (r.t0 + w * HORIZON * PATCH) % span, 4 * PATCH)
            })
            .collect();
        histories.push(hists);
    }

    let target = AnalyticBackend::new("t", PATCH, 0.0, 0.0);
    let drafts: Vec<AnalyticBackend> =
        REGIMES.iter().map(|r| AnalyticBackend::new("d", PATCH, 0.0, r.gap)).collect();

    let mut spec = SpecConfig::default();
    spec.gamma = GAMMA;
    spec.policy = stride::accept::AcceptancePolicy::new(SIGMA, 1.0);

    // --- k sweep over the identical workload (identical per-window
    // seeds: at a given window, the k = 1 decode and the k = 4 decode
    // face the same histories — only the branch count differs).
    let mut per_k: BTreeMap<usize, BTreeMap<&'static str, Tally>> = BTreeMap::new();
    for &k in KS {
        let mut regime_tallies: BTreeMap<&'static str, Tally> = BTreeMap::new();
        let mut window_seq = 0u64;
        for (seg, &ri) in SCHEDULE.iter().enumerate() {
            let regime = &REGIMES[ri];
            for w in 0..windows {
                let hist = &histories[ri][(seg * windows + w) % histories[ri].len()];
                let mut cfg = spec;
                cfg.k = k;
                cfg.seed = 0x7EE5_0000u64.wrapping_add(window_seq * 0x9E37_79B9);
                window_seq += 1;
                let out = sd_generate_tree(
                    &target,
                    &drafts[ri],
                    hist,
                    hist.len() / PATCH,
                    HORIZON,
                    &cfg,
                )?;
                let t = regime_tallies.entry(regime.name).or_default();
                for r in &out.rounds {
                    // Priced cost: c per branch-step + 1 target unit.
                    // Tail rounds (γ = 0, branches = 1) price to exactly 1.
                    t.cost += COST_C * (r.branches * r.gamma) as f64 + 1.0;
                    if r.gamma > 0 {
                        t.accepted += r.accepted as f64;
                        t.prop_rounds += 1.0;
                    }
                    if r.gamma == GAMMA {
                        t.full_accepted += r.accepted as f64;
                        t.full_rounds += 1.0;
                    }
                }
                t.emitted += HORIZON as f64;
            }
        }
        per_k.insert(k, regime_tallies);
    }

    let overall = |k: usize| -> Tally {
        let mut t = Tally::default();
        for v in per_k[&k].values() {
            t.merge(v);
        }
        t
    };

    // --- Report.
    println!(
        "tree_speculation: {windows} windows/segment, horizon {HORIZON}, gamma {GAMMA}, \
         c = {COST_C}, sigma = {SIGMA}"
    );
    println!(
        "{:<6} {:>14} {:>14} {:>12}",
        "k", "mean_accepted", "full-g accept", "throughput"
    );
    for &k in KS {
        let t = overall(k);
        println!(
            "k={:<4} {:>14.3} {:>14.3} {:>12.3}",
            k,
            t.mean_accepted(),
            t.full_gamma_mean_accepted(),
            t.throughput()
        );
    }

    // --- Theory tracking on full-γ rounds (known closed-form ᾱ per
    // regime; the independent-branch law is exact in this i.i.d.
    // setting).
    let mut max_theory_err = 0.0f64;
    let mut regime_rows = Vec::new();
    for r in REGIMES {
        let alpha = regime_alpha(r);
        let mut k_rows = Vec::new();
        for &k in KS {
            let t = &per_k[&k][r.name];
            let measured = t.full_gamma_mean_accepted();
            let want = theory::expected_block_length_tree(alpha, GAMMA, k) - 1.0;
            let err = (measured - want).abs();
            max_theory_err = max_theory_err.max(err);
            k_rows.push(Json::obj(vec![
                ("k", Json::from(k)),
                ("mean_accepted", Json::Num(t.mean_accepted())),
                ("full_gamma_mean_accepted", Json::Num(measured)),
                ("theory_mean_accepted", Json::Num(want)),
                ("abs_error", Json::Num(err)),
                ("throughput", Json::Num(t.throughput())),
                (
                    "speedup_eq5_theory",
                    Json::Num(theory::tree_wall_speedup(alpha, GAMMA, k, COST_C)),
                ),
            ]));
        }
        println!(
            "  {}: alpha {:.3}, full-g accepted k1 {:.3} / k4 {:.3} (theory {:.3} / {:.3})",
            r.name,
            alpha,
            per_k[&1][r.name].full_gamma_mean_accepted(),
            per_k[&4][r.name].full_gamma_mean_accepted(),
            theory::expected_block_length_tree(alpha, GAMMA, 1) - 1.0,
            theory::expected_block_length_tree(alpha, GAMMA, 4) - 1.0,
        );
        regime_rows.push(Json::obj(vec![
            ("name", Json::from(r.name)),
            ("dataset", Json::from(r.dataset)),
            ("gap", Json::Num(r.gap as f64)),
            ("alpha_theory", Json::Num(alpha)),
            ("per_k", Json::Arr(k_rows)),
        ]));
    }

    // --- Stacked verify on a native (kernel-layer) pair: the same tree
    // workload, toggled between the stacked batched verify and the
    // retained sequential reference. The emitted bits must match decode
    // for decode (the tests/tree_equivalence.rs wall, re-asserted on the
    // benched workload); the times record what the fusion buys here.
    let ndims = ModelDims { patch: PATCH, n_ctx: 64, d_model: 32, n_layers: 2, n_heads: 4, d_ff: 64 };
    let ddims = ModelDims { patch: PATCH, n_ctx: 64, d_model: 16, n_layers: 1, n_heads: 2, d_ff: 32 };
    let nt = NativeBackend::new(NativeModel::random("nt", ndims, 51));
    let nd = NativeBackend::new(NativeModel::random("nd", ddims, 52));
    let n_decodes = if quick { 4usize } else { 12 };
    let mut stacked_ns = 0.0f64;
    let mut seq_ns = 0.0f64;
    let mut stacked_identical = true;
    {
        let mut ncfg = spec;
        ncfg.gamma = GAMMA;
        ncfg.k = 4;
        for w in 0..n_decodes {
            let hist = &histories[w % REGIMES.len()][w % histories[0].len()];
            ncfg.seed = 0x57AC_0000u64.wrapping_add(w as u64 * 0x9E37_79B9);
            set_stacked_verify(true);
            let t0 = Instant::now();
            let on = sd_generate_tree(&nt, &nd, hist, hist.len() / PATCH, HORIZON, &ncfg)?;
            stacked_ns += t0.elapsed().as_nanos() as f64;
            set_stacked_verify(false);
            let t1 = Instant::now();
            let off = sd_generate_tree(&nt, &nd, hist, hist.len() / PATCH, HORIZON, &ncfg)?;
            seq_ns += t1.elapsed().as_nanos() as f64;
            set_stacked_verify(true);
            stacked_identical &= on.patches.len() == off.patches.len()
                && on.patches.iter().zip(&off.patches).all(|(x, y)| x.to_bits() == y.to_bits());
        }
    }
    let stacked_per = stacked_ns / n_decodes as f64;
    let seq_per = seq_ns / n_decodes as f64;
    anyhow::ensure!(
        stacked_identical,
        "stacked verify diverged from the sequential reference on the benched workload"
    );
    println!(
        "stacked verify (native, k=4, g={GAMMA}): {:.3}ms/decode vs sequential {:.3}ms/decode \
         ({:.2}x), bits identical",
        stacked_per / 1e6,
        seq_per / 1e6,
        seq_per / stacked_per.max(1e-9),
    );

    // --- Criteria.
    let k1 = overall(1);
    let k4 = overall(4);
    let k4_longer_overall = k4.mean_accepted() > k1.mean_accepted();
    let k4_longer_everywhere = REGIMES
        .iter()
        .all(|r| per_k[&4][r.name].mean_accepted() > per_k[&1][r.name].mean_accepted());
    // Theory tolerance: full-γ samples per regime scale with the window
    // count, so the quick trim gets the wider gate (4σ of a
    // [0, γ]-bounded mean over ~60 decodes vs ~240).
    let theory_tol = if quick { 0.2 } else { 0.15 };
    let theory_tracks = max_theory_err < theory_tol;

    let mut all_vals: Vec<f64> = vec![max_theory_err, stacked_per, seq_per];
    for &k in KS {
        let t = overall(k);
        all_vals.extend([t.mean_accepted(), t.full_gamma_mean_accepted(), t.throughput()]);
        for r in REGIMES {
            all_vals.push(per_k[&k][r.name].throughput());
        }
    }
    anyhow::ensure!(
        all_vals.iter().all(|v| v.is_finite()),
        "non-finite value in bench results: {all_vals:?}"
    );

    let k_rows: Vec<Json> = KS
        .iter()
        .map(|&k| {
            let t = overall(k);
            Json::obj(vec![
                ("k", Json::from(k)),
                ("mean_accepted", Json::Num(t.mean_accepted())),
                ("full_gamma_mean_accepted", Json::Num(t.full_gamma_mean_accepted())),
                ("throughput", Json::Num(t.throughput())),
                ("proposal_rounds", Json::Num(t.prop_rounds)),
                (
                    "per_regime",
                    Json::obj(
                        REGIMES
                            .iter()
                            .map(|r| (r.name, Json::Num(per_k[&k][r.name].mean_accepted())))
                            .collect(),
                    ),
                ),
            ])
        })
        .collect();

    let criteria_met =
        k4_longer_overall && k4_longer_everywhere && theory_tracks && stacked_identical;
    let j = Json::obj(vec![
        ("bench", Json::from("tree_speculation")),
        ("quick", Json::from(quick)),
        (
            "stacked_verify",
            Json::obj(vec![
                ("decodes", Json::from(n_decodes)),
                ("k", Json::from(4usize)),
                ("gamma", Json::from(GAMMA)),
                ("stacked_ns_per_decode", Json::Num(stacked_per)),
                ("sequential_ns_per_decode", Json::Num(seq_per)),
                ("speedup", Json::Num(seq_per / stacked_per.max(1e-9))),
                ("bitwise_identical", Json::from(stacked_identical)),
            ]),
        ),
        (
            "config",
            Json::obj(vec![
                ("patch", Json::from(PATCH)),
                ("sigma", Json::Num(SIGMA)),
                ("cost_ratio_c", Json::Num(COST_C)),
                ("horizon_patches", Json::from(HORIZON)),
                ("windows_per_segment", Json::from(windows)),
                ("gamma", Json::from(GAMMA)),
                ("ks", Json::Arr(KS.iter().map(|&k| Json::from(k)).collect())),
            ]),
        ),
        ("regimes", Json::Arr(regime_rows)),
        ("ks", Json::Arr(k_rows)),
        (
            "criteria",
            Json::obj(vec![
                ("k1_mean_accepted", Json::Num(k1.mean_accepted())),
                ("k4_mean_accepted", Json::Num(k4.mean_accepted())),
                ("k4_longer_overall", Json::from(k4_longer_overall)),
                ("k4_longer_every_regime", Json::from(k4_longer_everywhere)),
                ("max_theory_abs_error", Json::Num(max_theory_err)),
                ("theory_tolerance", Json::Num(theory_tol)),
                ("stacked_verify_bitwise_identical", Json::from(stacked_identical)),
                ("criteria_met", Json::from(criteria_met)),
            ]),
        ),
    ]);
    std::fs::create_dir_all("results")?;
    std::fs::write("results/BENCH_tree_speculation.json", format!("{j}\n"))?;
    println!("wrote results/BENCH_tree_speculation.json");

    anyhow::ensure!(
        criteria_met,
        "tree speculation failed its acceptance criteria: k4 > k1 overall: \
         {k4_longer_overall}, per-regime: {k4_longer_everywhere}, \
         max theory error {max_theory_err:.3} (need < {theory_tol}), \
         stacked verify bitwise identical: {stacked_identical}"
    );
    println!(
        "criteria met: k=4 accepted run {:.3} vs k=1 {:.3}, theory tracked within {:.3}",
        k4.mean_accepted(),
        k1.mean_accepted(),
        max_theory_err
    );
    Ok(())
}
