//! Closed-form performance theory (paper §3.4–§3.5, Props. 1 & 3).
//!
//! Everything here is pure math over the mean acceptance ᾱ, the wall-clock
//! cost ratio c and the FLOPs ratio ĉ; the calibration bench (Table 5)
//! compares these predictors against measured values, and the server's
//! auto-γ controller calls [`optimal_gamma`] online.

/// Capped-geometric block-length law (Eqs. 2–3):
/// P(L = l) = (1-ᾱ) ᾱ^{l-1} for 1 <= l <= γ, P(L = γ+1) = ᾱ^γ.
pub fn block_length_pmf(alpha: f64, gamma: usize) -> Vec<f64> {
    assert!((0.0..=1.0).contains(&alpha), "alpha in [0,1]");
    let mut pmf = Vec::with_capacity(gamma + 1);
    for l in 1..=gamma {
        pmf.push((1.0 - alpha) * alpha.powi(l as i32 - 1));
    }
    pmf.push(alpha.powi(gamma as i32));
    pmf
}

/// E\[L\] = (1 - ᾱ^{γ+1}) / (1 - ᾱ) (Eq. 4), with the ᾱ→1 limit γ+1.
pub fn expected_block_length(alpha: f64, gamma: usize) -> f64 {
    assert!((0.0..=1.0).contains(&alpha));
    if (1.0 - alpha).abs() < 1e-12 {
        return (gamma + 1) as f64;
    }
    (1.0 - alpha.powi(gamma as i32 + 1)) / (1.0 - alpha)
}

/// Wall-clock speedup S_wall(γ) = E\[L\] / (cγ + 1) (Eq. 5);
/// c is the measured draft/target wall-clock ratio.
pub fn wall_speedup(alpha: f64, gamma: usize, c: f64) -> f64 {
    expected_block_length(alpha, gamma) / (c * gamma as f64 + 1.0)
}

/// Tree-speculation extension of Eq. 4: expected committed block length
/// when **k independent** draft trajectories of length γ are verified in
/// one target pass and the longest accepted branch is committed.
///
/// Each branch's accepted run length follows the capped-geometric law of
/// Eqs. 2–3; the winner is the max of k i.i.d. run lengths, so
///
/// ```text
/// E[L_k] = 1 + Σ_{i=1..γ} P(max run >= i)
///        = 1 + Σ_{i=1..γ} (1 − (1 − ᾱ^i)^k)
/// ```
///
/// (the leading 1 is the bonus/fallback patch every round emits). At
/// k = 1 this telescopes back to Eq. 4 exactly — pinned by
/// `tree_expected_l_reduces_to_eq4`.
pub fn expected_block_length_tree(alpha: f64, gamma: usize, k: usize) -> f64 {
    assert!((0.0..=1.0).contains(&alpha), "alpha in [0,1]");
    assert!(k >= 1, "k >= 1");
    let mut e = 1.0;
    for i in 1..=gamma {
        e += 1.0 - (1.0 - alpha.powi(i as i32)).powi(k as i32);
    }
    e
}

/// Tree-speculation extension of Eq. 5: the draft now proposes k·γ
/// patches per round (k branches of length γ), so the round cost is
/// `c·k·γ + 1` target-equivalents and
///
/// ```text
/// S_tree(γ, k) = E[L_k] / (c·k·γ + 1)
/// ```
///
/// At k = 1 this is [`wall_speedup`] verbatim. The batched verify is
/// modeled as one target pass (the branches share the prefix KV cache and
/// ride one `extend`), matching the engine's target-call accounting.
pub fn tree_wall_speedup(alpha: f64, gamma: usize, k: usize, c: f64) -> f64 {
    expected_block_length_tree(alpha, gamma, k) / (c * (k * gamma) as f64 + 1.0)
}

/// Joint (γ*, k*) maximizing [`tree_wall_speedup`] over
/// `γ ∈ [1, gamma_cap] × k ∈ [1, k_cap]` by exhaustive scan — the space
/// is tiny (≤ 64×16) and the curve is not unimodal in the pair, so a
/// scan is both simplest and exact. Ties break toward smaller k, then
/// smaller γ (prefer the cheaper configuration at equal predicted
/// speedup; in particular plain k = 1 speculation wins all ties).
pub fn optimal_gamma_k(alpha: f64, c: f64, gamma_cap: usize, k_cap: usize) -> (usize, usize) {
    let (mut best, mut best_s) = ((1usize, 1usize), f64::MIN);
    for k in 1..=k_cap.max(1) {
        for g in 1..=gamma_cap.max(1) {
            let s = tree_wall_speedup(alpha, g, k, c);
            if s > best_s {
                best_s = s;
                best = (g, k);
            }
        }
    }
    best
}

/// OpsFactor = (γ ĉ + γ + 1) / E\[L\] (Eq. 6): extra compute per emitted
/// patch relative to pure target autoregression (>1 means SD burns more
/// FLOPs — the price paid for latency).
pub fn ops_factor(alpha: f64, gamma: usize, c_hat: f64) -> f64 {
    (gamma as f64 * c_hat + gamma as f64 + 1.0) / expected_block_length(alpha, gamma)
}

/// Exact increment condition: S_wall(γ+1) >= S_wall(γ) iff
///   ᾱ^{γ+1} · [ (1 + c(γ+1)) − ᾱ(1 + cγ) ] >= c.
///
/// Derivation: cross-multiply Eq. 5 at γ and γ+1 —
///   (1-ᾱ^{γ+2})(cγ+1) >= (1-ᾱ^{γ+1})(c(γ+1)+1)
/// and collect the ᾱ^{γ+1} terms.
///
/// NOTE — paper discrepancy (recorded in EXPERIMENTS.md): the paper's
/// Prop. 3 states the condition as ᾱ^{γ+1} >= (1+cγ)/(1+c(γ+1)), which
/// drops an ᾱ factor in the expansion (their Eq. 27→28 treats
/// ᾱ^{γ+2}(cγ+1) as ᾱ^{γ+1}(cγ+1)). The stated rule is *conservative*
/// (understates the optimal γ at high ᾱ); our property test
/// `optimal_gamma_matches_exhaustive_scan` rejects it, so [`optimal_gamma`]
/// uses the exact condition and [`paper_gamma_rule`] preserves the paper's
/// verbatim rule for Table 5 comparisons.
pub fn speedup_increases_at(alpha: f64, gamma: usize, c: f64) -> bool {
    let g = gamma as f64;
    alpha.powi(gamma as i32 + 1) * ((1.0 + c * (g + 1.0)) - alpha * (1.0 + c * g)) >= c
}

/// Near-optimal integer γ*: scan up from 1 while the speedup keeps
/// increasing (exact condition above).
pub fn optimal_gamma(alpha: f64, c: f64, cap: usize) -> usize {
    let mut g = 1usize;
    while g < cap && speedup_increases_at(alpha, g, c) {
        g += 1;
    }
    g
}

/// The paper's Prop. 3 rule, verbatim: largest γ with
/// ᾱ^{γ+1} >= (1+cγ)/(1+c(γ+1)). Kept for predictor-calibration
/// comparisons; conservative at high ᾱ (see [`speedup_increases_at`]).
pub fn paper_gamma_rule(alpha: f64, c: f64, cap: usize) -> usize {
    let mut g = 1usize;
    while g < cap
        && alpha.powi(g as i32 + 1)
            >= (1.0 + c * g as f64) / (1.0 + c * (g as f64 + 1.0))
    {
        g += 1;
    }
    g
}

/// Prop. 1 dependence bounds on E\[L\] when per-step conditional acceptance
/// lies in [alpha_lo, alpha_hi].
pub fn block_length_bounds(alpha_lo: f64, alpha_hi: f64, gamma: usize) -> (f64, f64) {
    assert!(alpha_lo <= alpha_hi);
    (
        expected_block_length(alpha_lo, gamma),
        expected_block_length(alpha_hi, gamma),
    )
}

/// Plug-in predictor bundle for a measured (α̂, c, ĉ) triple — what the
/// capacity planner and Table 5 report.
#[derive(Clone, Copy, Debug)]
pub struct Predictors {
    /// Mean acceptance ᾱ the predictions are evaluated at.
    pub alpha: f64,
    /// Draft block length γ.
    pub gamma: usize,
    /// Predicted mean block length E\[L\] (Eq. 4).
    pub expected_l: f64,
    /// Predicted wall-clock speedup (Eq. 5).
    pub s_wall: f64,
    /// Predicted compute overhead factor (Eq. 6).
    pub ops_factor: f64,
}

/// Evaluate all closed-form predictors at one (ᾱ, γ, c, ĉ) point.
pub fn predict(alpha: f64, gamma: usize, c: f64, c_hat: f64) -> Predictors {
    Predictors {
        alpha,
        gamma,
        expected_l: expected_block_length(alpha, gamma),
        s_wall: wall_speedup(alpha, gamma, c),
        ops_factor: ops_factor(alpha, gamma, c_hat),
    }
}

/// Breakeven heuristic for the lossless variant (§B.6): residual sampling
/// is only competitive when 1 - ᾱ ≳ 1/γ (expected residual cost per block
/// does not exceed the block's expected output).
pub fn lossless_worthwhile(alpha: f64, gamma: usize) -> bool {
    (1.0 - alpha) >= 1.0 / gamma as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest_lite::{check, F64Range, Pair, UsizeRange};

    #[test]
    fn pmf_sums_to_one() {
        check(
            &Pair(F64Range(0.0, 1.0), UsizeRange(1, 20)),
            |(alpha, gamma)| {
                let s: f64 = block_length_pmf(*alpha, *gamma).iter().sum();
                if (s - 1.0).abs() < 1e-9 {
                    Ok(())
                } else {
                    Err(format!("pmf sums to {s}"))
                }
            },
        );
    }

    #[test]
    fn expected_l_matches_pmf_mean() {
        check(
            &Pair(F64Range(0.0, 0.999), UsizeRange(1, 15)),
            |(alpha, gamma)| {
                let pmf = block_length_pmf(*alpha, *gamma);
                let mean: f64 = pmf.iter().enumerate().map(|(i, p)| (i + 1) as f64 * p).sum();
                let closed = expected_block_length(*alpha, *gamma);
                if (mean - closed).abs() < 1e-9 {
                    Ok(())
                } else {
                    Err(format!("pmf mean {mean} vs closed form {closed}"))
                }
            },
        );
    }

    #[test]
    fn expected_l_limits() {
        assert!((expected_block_length(0.0, 5) - 1.0).abs() < 1e-12, "always reject -> 1");
        assert!((expected_block_length(1.0, 5) - 6.0).abs() < 1e-12, "always accept -> gamma+1");
        // Monotone increasing in alpha and in gamma.
        assert!(expected_block_length(0.9, 5) > expected_block_length(0.5, 5));
        assert!(expected_block_length(0.9, 7) > expected_block_length(0.9, 5));
    }

    #[test]
    fn saturation_in_gamma() {
        // The paper's headline qualitative claim (Fig. 7): E[L] saturates
        // once gamma greatly exceeds the 1/(1-alpha) scale.
        let a = 0.7; // scale 1/(1-a) ~ 3.3
        let g5 = expected_block_length(a, 5);
        let g10 = expected_block_length(a, 10);
        let g20 = expected_block_length(a, 20);
        assert!((g10 - g5) > (g20 - g10), "increments shrink");
        assert!((g20 - 1.0 / (1.0 - a)).abs() < 0.01, "limit is 1/(1-alpha)");
        // And S_wall itself saturates: past the optimum it *decreases*.
        let c = 0.2;
        let g_star = optimal_gamma(a, c, 64);
        assert!(wall_speedup(a, g_star + 5, c) < wall_speedup(a, g_star, c));
    }

    #[test]
    fn speedup_known_value() {
        // alpha=1, c=0.25, gamma=3: S = 4 / (0.75 + 1) = 2.2857...
        let s = wall_speedup(1.0, 3, 0.25);
        assert!((s - 4.0 / 1.75).abs() < 1e-12, "{s}");
    }

    #[test]
    fn ops_factor_at_least_cost_of_validation() {
        // With perfect acceptance OpsFactor = (γĉ + γ + 1)/(γ+1) > 1 when ĉ>0.
        let f = ops_factor(1.0, 3, 0.25);
        assert!((f - (3.0 * 0.25 + 4.0) / 4.0).abs() < 1e-12);
        assert!(f > 1.0);
    }

    #[test]
    fn optimal_gamma_matches_exhaustive_scan() {
        check(
            &Pair(F64Range(0.05, 0.999), F64Range(0.02, 0.9)),
            |(alpha, c)| {
                let cap = 32;
                let g_rule = optimal_gamma(*alpha, *c, cap);
                // Exhaustive argmax of S_wall over [1, cap].
                let (mut best_g, mut best_s) = (1, f64::MIN);
                for g in 1..=cap {
                    let s = wall_speedup(*alpha, g, *c);
                    if s > best_s {
                        best_s = s;
                        best_g = g;
                    }
                }
                // Prop. 3 is *near*-optimal: the rule's S_wall must be
                // within 2% of the exhaustive optimum.
                let s_rule = wall_speedup(*alpha, g_rule, *c);
                if s_rule >= 0.98 * best_s {
                    Ok(())
                } else {
                    Err(format!(
                        "rule gamma={g_rule} (S={s_rule:.4}) vs scan gamma={best_g} (S={best_s:.4})"
                    ))
                }
            },
        );
    }

    #[test]
    fn high_alpha_low_c_wants_large_gamma() {
        assert!(optimal_gamma(0.99, 0.05, 64) > 8);
        assert!(optimal_gamma(0.5, 0.5, 64) <= 2);
        // The paper's verbatim rule is conservative at high alpha:
        assert!(paper_gamma_rule(0.99, 0.05, 64) <= optimal_gamma(0.99, 0.05, 64));
    }

    #[test]
    fn dependence_bounds_bracket_iid() {
        let (lo, hi) = block_length_bounds(0.7, 0.9, 5);
        let iid = expected_block_length(0.8, 5);
        assert!(lo <= iid && iid <= hi);
    }

    #[test]
    fn lossless_breakeven() {
        assert!(lossless_worthwhile(0.5, 4)); // 0.5 >= 0.25
        assert!(!lossless_worthwhile(0.95, 4)); // 0.05 < 0.25
    }

    #[test]
    fn tree_expected_l_reduces_to_eq4() {
        // k = 1 must reproduce Eq. 4 exactly across the whole (alpha, gamma)
        // plane: 1 + sum alpha^i is the telescoped geometric sum.
        check(
            &Pair(F64Range(0.0, 0.999), UsizeRange(1, 20)),
            |(alpha, gamma)| {
                let tree = expected_block_length_tree(*alpha, *gamma, 1);
                let eq4 = expected_block_length(*alpha, *gamma);
                if (tree - eq4).abs() < 1e-9 {
                    Ok(())
                } else {
                    Err(format!("tree k=1 {tree} vs Eq.4 {eq4}"))
                }
            },
        );
        // And the speedup wrapper reduces to Eq. 5.
        check(
            &Pair(F64Range(0.05, 0.99), F64Range(0.02, 0.9)),
            |(alpha, c)| {
                let t = tree_wall_speedup(*alpha, 4, 1, *c);
                let w = wall_speedup(*alpha, 4, *c);
                if (t - w).abs() < 1e-12 {
                    Ok(())
                } else {
                    Err(format!("tree k=1 speedup {t} vs Eq.5 {w}"))
                }
            },
        );
    }

    #[test]
    fn tree_expected_l_monotone_in_k_and_bounded() {
        check(
            &Pair(F64Range(0.01, 0.99), UsizeRange(1, 12)),
            |(alpha, gamma)| {
                let mut prev = f64::MIN;
                for k in 1..=8 {
                    let e = expected_block_length_tree(*alpha, *gamma, k);
                    if e < prev - 1e-12 {
                        return Err(format!("E[L_k] decreased at k={k}: {e} < {prev}"));
                    }
                    if !(1.0 - 1e-12..=(*gamma + 1) as f64 + 1e-12).contains(&e) {
                        return Err(format!("E[L_k]={e} outside [1, gamma+1]"));
                    }
                    prev = e;
                }
                Ok(())
            },
        );
    }

    #[test]
    fn tree_expected_l_matches_max_of_runs_simulation_values() {
        // Hand-checked point: alpha = 0.5, gamma = 2, k = 2.
        // P(run >= 1) = 1 - 0.5^2 = 0.75; P(run >= 2) = 1 - 0.75^2 = 0.4375.
        let e = expected_block_length_tree(0.5, 2, 2);
        assert!((e - (1.0 + 0.75 + 0.4375)).abs() < 1e-12, "{e}");
        // Degenerate edges: alpha 0 -> always 1 bonus patch; alpha 1 -> gamma+1.
        assert!((expected_block_length_tree(0.0, 5, 4) - 1.0).abs() < 1e-12);
        assert!((expected_block_length_tree(1.0, 5, 4) - 6.0).abs() < 1e-12);
    }

    #[test]
    fn tree_speedup_tradeoff_and_joint_optimum() {
        // Branches help E[L] but multiply draft cost: at c = 0 more
        // branches can only help; at large c they must eventually hurt.
        assert!(tree_wall_speedup(0.7, 4, 4, 0.0) > tree_wall_speedup(0.7, 4, 1, 0.0));
        assert!(tree_wall_speedup(0.7, 4, 4, 0.5) < tree_wall_speedup(0.7, 4, 1, 0.5));
        // Joint optimum: free drafts want the largest tree; expensive
        // drafts collapse to classic k = 1.
        let (g_free, k_free) = optimal_gamma_k(0.8, 0.001, 16, 8);
        assert!(k_free > 1, "near-free draft should branch (got k={k_free})");
        assert!(g_free >= 4);
        let (_, k_dear) = optimal_gamma_k(0.5, 0.8, 16, 8);
        assert_eq!(k_dear, 1, "expensive draft must not branch");
        // The scan beats (or ties) every config it considered.
        check(
            &Pair(F64Range(0.05, 0.99), F64Range(0.01, 0.6)),
            |(alpha, c)| {
                let (g, k) = optimal_gamma_k(*alpha, *c, 12, 6);
                let best = tree_wall_speedup(*alpha, g, k, *c);
                for kk in 1..=6 {
                    for gg in 1..=12 {
                        if tree_wall_speedup(*alpha, gg, kk, *c) > best + 1e-12 {
                            return Err(format!("scan missed ({gg},{kk}) > ({g},{k})"));
                        }
                    }
                }
                Ok(())
            },
        );
    }
}
