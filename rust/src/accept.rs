//! Acceptance rule and the mean-acceptance estimator (paper §3.5–§3.6,
//! Props. 4 & 8).
//!
//! The rule is computed in the log domain (Eq. 7) with an optional
//! tolerance/bias λ multiplying the ratio (the "bias" knob of Tables 1/5):
//! accept x with probability min{1, λ p(x)/q(x)}. The deviation bounds of
//! §3.3 hold for any measurable α, so λ trades a larger bias bound ᾱ for
//! higher throughput.

use crate::gaussian::{iso_log_ratio, IsoGaussian};
use crate::util::rng::Rng;
use crate::util::stats::{gaussian_overlap, hoeffding_eps};

/// Acceptance policy shared by the engine and the estimator.
#[derive(Clone, Copy, Debug)]
pub struct AcceptancePolicy {
    /// Shared head sigma (the paper's noise knob).
    pub sigma: f64,
    /// Tolerance λ >= 0; 1.0 is the canonical rule.
    pub bias: f64,
}

impl Default for AcceptancePolicy {
    fn default() -> Self {
        AcceptancePolicy { sigma: 0.5, bias: 1.0 }
    }
}

impl AcceptancePolicy {
    /// Policy with the given (positive) sigma and bias λ.
    pub fn new(sigma: f64, bias: f64) -> Self {
        assert!(sigma > 0.0 && bias > 0.0);
        AcceptancePolicy { sigma, bias }
    }

    /// α(x) = min{1, λ p(x)/q(x)} for equal-sigma isotropic heads,
    /// evaluated in log space.
    #[inline]
    pub fn alpha(&self, x: &[f32], mu_p: &[f32], mu_q: &[f32]) -> f64 {
        let lr = iso_log_ratio(x, mu_p, mu_q, self.sigma) + self.bias.ln();
        lr.min(0.0).exp()
    }

    /// One acceptance coin flip.
    #[inline]
    pub fn accept(&self, x: &[f32], mu_p: &[f32], mu_q: &[f32], rng: &mut Rng) -> bool {
        let a = self.alpha(x, mu_p, mu_q);
        a >= 1.0 || rng.uniform() < a
    }

    /// Closed-form per-history mean acceptance for the canonical rule
    /// (λ = 1): β(h) = 2 Φ(-Δ/2) with Δ the Mahalanobis mean gap
    /// (Remark 5). For λ != 1 there is no closed form; use Monte Carlo.
    pub fn mean_acceptance_closed_form(&self, mu_p: &[f32], mu_q: &[f32]) -> f64 {
        let gap_sq: f64 = mu_p
            .iter()
            .zip(mu_q)
            .map(|(a, b)| {
                let d = (*a - *b) as f64;
                d * d
            })
            .sum();
        gaussian_overlap(gap_sq.sqrt() / self.sigma)
    }
}

/// Two-stage mean-acceptance estimator (Prop. 8): for each history draw m
/// proposals from q and average α; average over N histories. Hoeffding over
/// the N·m bounded terms gives P(|α̂ - ᾱ| >= ε) <= 2 exp(-2 N m ε²).
#[derive(Clone, Debug)]
pub struct AcceptanceEstimate {
    /// Estimated mean acceptance ᾱ.
    pub alpha_hat: f64,
    /// Held-out histories averaged over.
    pub n_histories: usize,
    /// Monte-Carlo proposals per history (0 for the closed form).
    pub m_per_history: usize,
    /// 95% Hoeffding half-width.
    pub eps95: f64,
}

/// Estimate ᾱ from per-history head pairs via Monte Carlo (works for any
/// bias λ). `heads` yields (mu_p, mu_q) per held-out history.
pub fn estimate_alpha<'a, I>(
    policy: &AcceptancePolicy,
    heads: I,
    m_per_history: usize,
    seed: u64,
) -> AcceptanceEstimate
where
    I: IntoIterator<Item = (&'a [f32], &'a [f32])>,
{
    let mut rng = Rng::new(seed);
    let mut total = 0.0f64;
    let mut n = 0usize;
    for (mu_p, mu_q) in heads {
        let q = IsoGaussian::new(mu_q.to_vec(), policy.sigma);
        let mut acc = 0.0;
        for _ in 0..m_per_history {
            let x = q.sample(&mut rng);
            acc += policy.alpha(&x, mu_p, mu_q);
        }
        total += acc / m_per_history as f64;
        n += 1;
    }
    assert!(n > 0, "need at least one history");
    AcceptanceEstimate {
        alpha_hat: total / n as f64,
        n_histories: n,
        m_per_history,
        eps95: hoeffding_eps(n * m_per_history, 0.05),
    }
}

/// Closed-form estimator (canonical rule only): averages 2Φ(-Δ/2) over
/// histories — the exact inner integral, so concentration is over N alone.
pub fn estimate_alpha_closed_form<'a, I>(policy: &AcceptancePolicy, heads: I) -> AcceptanceEstimate
where
    I: IntoIterator<Item = (&'a [f32], &'a [f32])>,
{
    let mut total = 0.0f64;
    let mut n = 0usize;
    for (mu_p, mu_q) in heads {
        total += policy.mean_acceptance_closed_form(mu_p, mu_q);
        n += 1;
    }
    assert!(n > 0);
    AcceptanceEstimate {
        alpha_hat: total / n as f64,
        n_histories: n,
        m_per_history: 0,
        eps95: hoeffding_eps(n, 0.05),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alpha_is_one_when_target_likes_x_more() {
        let pol = AcceptancePolicy::new(0.5, 1.0);
        let x = [0.0f32, 0.0];
        // mu_p == x, mu_q far: p(x) > q(x) => alpha = 1.
        assert_eq!(pol.alpha(&x, &[0.0, 0.0], &[2.0, 2.0]), 1.0);
        // Reverse: alpha < 1.
        assert!(pol.alpha(&x, &[2.0, 2.0], &[0.0, 0.0]) < 1e-6);
    }

    #[test]
    fn bias_inflates_acceptance() {
        let x = [0.1f32, -0.2];
        let mu_p = [0.5f32, 0.5];
        let mu_q = [0.0f32, 0.0];
        let a1 = AcceptancePolicy::new(0.5, 1.0).alpha(&x, &mu_p, &mu_q);
        let a2 = AcceptancePolicy::new(0.5, 2.0).alpha(&x, &mu_p, &mu_q);
        assert!(a2 >= a1);
        assert!(a2 <= 1.0);
    }

    #[test]
    fn no_overflow_for_huge_log_ratio() {
        let pol = AcceptancePolicy::new(0.01, 1.0);
        // Extremely peaked heads: |log ratio| is enormous; alpha must stay
        // finite and in [0, 1].
        let a = pol.alpha(&[100.0, 100.0], &[100.0, 100.0], &[-100.0, -100.0]);
        assert!(a.is_finite() && (0.0..=1.0).contains(&a));
        assert_eq!(a, 1.0);
    }

    #[test]
    fn mc_estimator_matches_closed_form() {
        // Single history: alpha_bar = 2 Phi(-gap / (2 sigma)).
        let pol = AcceptancePolicy::new(0.6, 1.0);
        let mu_p = vec![0.3f32; 8];
        let mu_q = vec![0.0f32; 8];
        let mc = estimate_alpha(
            &pol,
            std::iter::once((mu_p.as_slice(), mu_q.as_slice())),
            40_000,
            5,
        );
        let cf = pol.mean_acceptance_closed_form(&mu_p, &mu_q);
        assert!(
            (mc.alpha_hat - cf).abs() < 0.01,
            "MC {:.4} vs closed form {cf:.4}",
            mc.alpha_hat
        );
    }

    #[test]
    fn estimator_concentrates_with_n() {
        let e1 = AcceptanceEstimate { alpha_hat: 0.9, n_histories: 10, m_per_history: 10, eps95: hoeffding_eps(100, 0.05) };
        let e2 = AcceptanceEstimate { alpha_hat: 0.9, n_histories: 1000, m_per_history: 10, eps95: hoeffding_eps(10_000, 0.05) };
        assert!(e2.eps95 < e1.eps95 / 5.0);
    }

    #[test]
    fn closed_form_estimator_averages() {
        let pol = AcceptancePolicy::new(0.5, 1.0);
        let a = vec![0.0f32; 4];
        let b = vec![10.0f32; 4]; // essentially zero overlap
        let est = estimate_alpha_closed_form(
            &pol,
            vec![(a.as_slice(), a.as_slice()), (a.as_slice(), b.as_slice())],
        );
        assert!((est.alpha_hat - 0.5).abs() < 1e-6, "{}", est.alpha_hat);
    }
}
