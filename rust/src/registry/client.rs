//! Registry transfer client: push/pull a model pair between a local
//! registry directory and a serving node's registry API.
//!
//! Transfers ride the existing HTTP substrate: [`http_request_retry`]
//! with the shared [`RetryPolicy`] (seeded backoff; retries only 429/503
//! and transport faults), so a briefly-draining server does not fail a
//! pull. Every pulled byte is verified before it is committed:
//! manifests are re-digested after parsing, blobs go through
//! [`BlobStore::put_expected`] — a corrupted transfer is a typed
//! [`RegistryError::DigestMismatch`], never a poisoned cache entry. The
//! chaos hook ([`FaultPlan::corrupt_blob`]) injects bit flips exactly at
//! the network boundary to prove that property under test.

use crate::faultinject::FaultPlan;
use crate::http::{http_request_retry, HttpResponse, RetryError, RetryPolicy};
use crate::registry::error::RegistryError;
use crate::registry::manifest::{parse_ref, ModelRef, RegistryManifest};
use crate::registry::Registry;
use crate::util::json::Json;

/// URL path for a manifest reference. `sha256` is a reserved name, so
/// `/v1/models/sha256/<hex>` (content address) and
/// `/v1/models/<name>/<version>` (tag) share one route shape.
pub fn manifest_path(reference: &str) -> Result<String, RegistryError> {
    Ok(match parse_ref(reference)? {
        ModelRef::Tag { name, version } => format!("/v1/models/{name}/{version}"),
        ModelRef::Digest(d) => format!("/v1/models/sha256/{d}"),
    })
}

/// Push a locally-registered model pair to `addr`. Blobs first, then the
/// manifest (the server refuses manifests whose blobs are absent, so the
/// ordering is load-bearing). Returns the manifest digest.
pub fn push_model(
    addr: &str,
    registry: &Registry,
    reference: &str,
    policy: &RetryPolicy,
) -> Result<String, RegistryError> {
    let (manifest, digest) = registry.get_manifest(reference)?;
    for spec in [&manifest.target, &manifest.draft] {
        let bytes = registry.blobs().read_verified(&spec.sha256)?;
        let resp = request(addr, "PUT", &format!("/v1/blobs/{}", spec.sha256), Some(&bytes), policy)?;
        expect_2xx(&resp, &format!("pushing blob sha256:{}", spec.sha256))?;
    }
    let body = manifest.to_json().to_string();
    let resp = request(
        addr,
        "PUT",
        &format!("/v1/models/{}/{}", manifest.name, manifest.version),
        Some(body.as_bytes()),
        policy,
    )?;
    expect_2xx(&resp, "pushing manifest")?;
    let remote_digest = Json::parse(resp.body_str())
        .ok()
        .and_then(|j| j.get("digest").and_then(Json::as_str).map(str::to_string))
        .unwrap_or_default();
    if remote_digest != digest {
        return Err(RegistryError::Invalid(format!(
            "server acknowledged digest {remote_digest:?}, local manifest is sha256:{digest}"
        )));
    }
    Ok(digest)
}

/// Pull `reference` from `addr` into the local registry. Blobs already
/// present locally are not re-fetched (the cache is keyed by digest, so
/// "present" implies "verified content"). Returns the manifest digest.
///
/// `fault` is the chaos boundary: when armed with `p_blob_corrupt > 0`
/// it flips a byte in the received blob *before* verification, modeling
/// a corrupt transfer or bad disk on the far side.
pub fn pull_model(
    addr: &str,
    registry: &Registry,
    reference: &str,
    policy: &RetryPolicy,
    fault: Option<&FaultPlan>,
) -> Result<String, RegistryError> {
    let resp = request(addr, "GET", &manifest_path(reference)?, None, policy)?;
    expect_2xx(&resp, &format!("pulling manifest {reference}"))?;
    let j = Json::parse(resp.body_str())
        .map_err(|e| RegistryError::Invalid(format!("manifest from {addr} unparseable: {e}")))?;
    let manifest = RegistryManifest::from_json(&j)?;
    if let ModelRef::Digest(expected) = parse_ref(reference)? {
        let actual = manifest.digest();
        if actual != expected {
            return Err(RegistryError::DigestMismatch { expected, actual });
        }
    }
    for spec in [&manifest.target, &manifest.draft] {
        if registry.blobs().has(&spec.sha256) {
            continue;
        }
        let resp = request(addr, "GET", &format!("/v1/blobs/{}", spec.sha256), None, policy)?;
        expect_2xx(&resp, &format!("pulling blob sha256:{}", spec.sha256))?;
        let mut bytes = resp.body;
        if let Some(plan) = fault {
            plan.corrupt_blob(&mut bytes);
        }
        registry.blobs().put_expected(&spec.sha256, &bytes)?;
    }
    registry.put_manifest(&manifest)
}

fn request(
    addr: &str,
    method: &str,
    path: &str,
    body: Option<&[u8]>,
    policy: &RetryPolicy,
) -> Result<HttpResponse, RegistryError> {
    http_request_retry(addr, method, path, body, policy).map_err(|e| {
        let msg = format!("{method} {path}: {e}");
        match e {
            RetryError::Io { last, .. } => {
                RegistryError::Io(std::io::Error::new(last.kind(), msg))
            }
            RetryError::Exhausted { .. } => {
                RegistryError::Io(std::io::Error::new(std::io::ErrorKind::TimedOut, msg))
            }
        }
    })
}

/// Map a non-2xx registry API response back into the typed error space
/// (the server emits `ServeError::to_json` bodies; we reconstruct the
/// matching `RegistryError` so client callers see the same sum type as
/// local callers).
fn expect_2xx(resp: &HttpResponse, what: &str) -> Result<(), RegistryError> {
    if (200..300).contains(&resp.status) {
        return Ok(());
    }
    let j = Json::parse(resp.body_str()).ok();
    let msg = j
        .as_ref()
        .and_then(|j| j.get("error").and_then(Json::as_str))
        .unwrap_or("")
        .to_string();
    Err(match resp.status {
        404 => RegistryError::NotFound(format!("{what}: {msg}")),
        422 => {
            let field = |k: &str| {
                j.as_ref()
                    .and_then(|j| j.get(k).and_then(Json::as_str))
                    .unwrap_or("?")
                    .to_string()
            };
            RegistryError::DigestMismatch { expected: field("expected"), actual: field("actual") }
        }
        400 | 413 => RegistryError::Invalid(format!("{what}: http {}: {msg}", resp.status)),
        s => RegistryError::Io(std::io::Error::new(
            std::io::ErrorKind::Other,
            format!("{what}: http {s}: {msg}"),
        )),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_paths() {
        assert_eq!(manifest_path("demo:v1").unwrap(), "/v1/models/demo/v1");
        let d = "cd".repeat(32);
        assert_eq!(manifest_path(&format!("sha256:{d}")).unwrap(), format!("/v1/models/sha256/{d}"));
        assert!(manifest_path("no-colon").is_err());
    }

    #[test]
    fn error_bodies_map_back_to_typed_errors() {
        let resp = |status: u16, body: &str| HttpResponse {
            status,
            headers: vec![],
            body: body.as_bytes().to_vec(),
        };
        assert!(matches!(
            expect_2xx(&resp(404, r#"{"error":"no such model"}"#), "x"),
            Err(RegistryError::NotFound(_))
        ));
        match expect_2xx(&resp(422, r#"{"error":"bad","expected":"aa","actual":"bb"}"#), "x") {
            Err(RegistryError::DigestMismatch { expected, actual }) => {
                assert_eq!((expected.as_str(), actual.as_str()), ("aa", "bb"));
            }
            other => panic!("{other:?}"),
        }
        assert!(matches!(
            expect_2xx(&resp(400, r#"{"error":"bad ref"}"#), "x"),
            Err(RegistryError::Invalid(_))
        ));
        assert!(expect_2xx(&resp(201, r#"{"digest":"aa"}"#), "x").is_ok());
    }
}
