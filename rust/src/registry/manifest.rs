//! Versioned registry manifests: the unit of model identity.
//!
//! A manifest names a (target, draft) model pair — architecture tag,
//! serving shape (`patch`/`n_ctx`), per-role dims, and for each role the
//! SHA-256 of its weight blob plus the tensor index that binds names and
//! shapes to float offsets inside it. Manifests serialize through
//! [`crate::util::json::Json`], whose object keys are a `BTreeMap` — the
//! canonical form is therefore deterministic, and the manifest digest is
//! simply the SHA-256 of that canonical text. Two manifests with the same
//! digest are the same model pair, bit for bit.

use crate::nn::ModelDims;
use crate::registry::digest::{is_hex_digest, sha256_hex};
use crate::registry::error::RegistryError;
use crate::util::json::Json;

/// The only architecture this registry accepts; manifests carrying any
/// other tag are rejected at parse time (forward-compat hinge).
pub const ARCH: &str = "stride-native-v1";

/// One role (target or draft) inside a manifest.
#[derive(Clone, Debug)]
pub struct RoleSpec {
    /// Model name handed to the backend (shows up in traces/metrics).
    pub model_name: String,
    /// Full architecture dims for this role.
    pub dims: ModelDims,
    /// SHA-256 (lowercase hex) of the role's weight blob.
    pub sha256: String,
    /// Blob size in bytes (cheap pre-check before hashing on pull).
    pub size_bytes: usize,
    /// Float count (sanity cross-check against the index).
    pub param_count: usize,
    /// `[{name, shape, offset}]` with offsets in floats — the same index
    /// format `runtime::manifest` uses, so both loaders share a parser.
    pub tensor_index: Json,
}

/// A named, versioned (target, draft) model pair.
#[derive(Clone, Debug)]
pub struct RegistryManifest {
    /// Model family name (path-safe, see [`valid_ref_component`]).
    pub name: String,
    /// Version label (path-safe).
    pub version: String,
    /// Shared patch length both roles must agree on.
    pub patch: usize,
    /// Shared context length both roles must agree on.
    pub n_ctx: usize,
    /// The verification model.
    pub target: RoleSpec,
    /// The speculation model.
    pub draft: RoleSpec,
}

impl RegistryManifest {
    /// Canonical JSON form (sorted keys; `Display` of this value is the
    /// byte sequence the manifest digest is computed over).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("arch", Json::from(ARCH)),
            ("name", Json::from(self.name.clone())),
            ("version", Json::from(self.version.clone())),
            ("patch", Json::from(self.patch)),
            ("n_ctx", Json::from(self.n_ctx)),
            (
                "models",
                Json::obj(vec![
                    ("target", role_to_json(&self.target)),
                    ("draft", role_to_json(&self.draft)),
                ]),
            ),
        ])
    }

    /// SHA-256 of the canonical serialization — the manifest's content
    /// address (`sha256:<this>` resolves it).
    pub fn digest(&self) -> String {
        sha256_hex(self.to_json().to_string().as_bytes())
    }

    /// Parse and validate. Every structural failure is a typed
    /// [`RegistryError::Invalid`]; digests are shape-checked here so
    /// nothing malformed ever reaches a blob path.
    pub fn from_json(j: &Json) -> Result<RegistryManifest, RegistryError> {
        let arch = req_str(j, "arch")?;
        if arch != ARCH {
            return Err(RegistryError::Invalid(format!(
                "unsupported arch {arch:?} (this registry serves {ARCH:?})"
            )));
        }
        let name = req_str(j, "name")?.to_string();
        let version = req_str(j, "version")?.to_string();
        valid_ref_component("name", &name)?;
        valid_ref_component("version", &version)?;
        let patch = req_usize(j, "patch")?;
        let n_ctx = req_usize(j, "n_ctx")?;
        let models = j
            .get("models")
            .ok_or_else(|| RegistryError::Invalid("manifest missing models".into()))?;
        let target = role_from_json(models, "target")?;
        let draft = role_from_json(models, "draft")?;
        let m = RegistryManifest { name, version, patch, n_ctx, target, draft };
        m.validate()?;
        Ok(m)
    }

    /// Cross-field invariants: both roles must share the manifest's
    /// serving shape (the scheduler batches by `(patch, n_ctx)`; a pair
    /// that disagrees cannot speculate against itself).
    pub fn validate(&self) -> Result<(), RegistryError> {
        for (role, spec) in [("target", &self.target), ("draft", &self.draft)] {
            if spec.dims.patch != self.patch || spec.dims.n_ctx != self.n_ctx {
                return Err(RegistryError::Invalid(format!(
                    "{role} dims (patch={}, n_ctx={}) disagree with manifest shape (patch={}, n_ctx={})",
                    spec.dims.patch, spec.dims.n_ctx, self.patch, self.n_ctx
                )));
            }
            if spec.dims.d_model == 0
                || spec.dims.n_layers == 0
                || spec.dims.n_heads == 0
                || spec.dims.d_model % spec.dims.n_heads != 0
            {
                return Err(RegistryError::Invalid(format!("{role} dims are degenerate")));
            }
            if spec.size_bytes != spec.param_count * 4 {
                return Err(RegistryError::Invalid(format!(
                    "{role} size_bytes {} != 4 * param_count {}",
                    spec.size_bytes, spec.param_count
                )));
            }
        }
        Ok(())
    }
}

fn role_to_json(r: &RoleSpec) -> Json {
    Json::obj(vec![
        ("name", Json::from(r.model_name.clone())),
        ("patch", Json::from(r.dims.patch)),
        ("n_ctx", Json::from(r.dims.n_ctx)),
        ("d_model", Json::from(r.dims.d_model)),
        ("n_layers", Json::from(r.dims.n_layers)),
        ("n_heads", Json::from(r.dims.n_heads)),
        ("d_ff", Json::from(r.dims.d_ff)),
        ("sha256", Json::from(r.sha256.clone())),
        ("size_bytes", Json::from(r.size_bytes)),
        ("param_count", Json::from(r.param_count)),
        ("tensors", r.tensor_index.clone()),
    ])
}

fn role_from_json(models: &Json, role: &str) -> Result<RoleSpec, RegistryError> {
    let j = models
        .get(role)
        .ok_or_else(|| RegistryError::Invalid(format!("manifest missing models.{role}")))?;
    let sha256 = req_str(j, "sha256")?.to_string();
    if !is_hex_digest(&sha256) {
        return Err(RegistryError::Invalid(format!("{role} sha256 is not a hex digest")));
    }
    let tensor_index = j
        .get("tensors")
        .ok_or_else(|| RegistryError::Invalid(format!("{role} missing tensors index")))?;
    if tensor_index.as_arr().is_none() {
        return Err(RegistryError::Invalid(format!("{role} tensors index must be an array")));
    }
    Ok(RoleSpec {
        model_name: req_str(j, "name")?.to_string(),
        dims: ModelDims {
            patch: req_usize(j, "patch")?,
            n_ctx: req_usize(j, "n_ctx")?,
            d_model: req_usize(j, "d_model")?,
            n_layers: req_usize(j, "n_layers")?,
            n_heads: req_usize(j, "n_heads")?,
            d_ff: req_usize(j, "d_ff")?,
        },
        sha256,
        size_bytes: req_usize(j, "size_bytes")?,
        param_count: req_usize(j, "param_count")?,
        tensor_index: tensor_index.clone(),
    })
}

fn req_str<'a>(j: &'a Json, key: &str) -> Result<&'a str, RegistryError> {
    j.get(key)
        .and_then(Json::as_str)
        .ok_or_else(|| RegistryError::Invalid(format!("manifest field {key:?} missing or not a string")))
}

fn req_usize(j: &Json, key: &str) -> Result<usize, RegistryError> {
    j.get(key)
        .and_then(Json::as_usize)
        .ok_or_else(|| RegistryError::Invalid(format!("manifest field {key:?} missing or not a number")))
}

/// A parsed model reference: either `name:version` or `sha256:<hex>`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ModelRef {
    /// Mutable tag — resolves through the tag file to whatever manifest
    /// was last pushed under it.
    Tag {
        /// Model family name.
        name: String,
        /// Version label.
        version: String,
    },
    /// Immutable content address of a manifest.
    Digest(String),
}

/// Parse `"name:version"` or `"sha256:<hex>"`. Anything else — missing
/// colon, unsafe path characters, malformed digest — is a typed
/// [`RegistryError::Invalid`].
pub fn parse_ref(s: &str) -> Result<ModelRef, RegistryError> {
    let (head, tail) = s
        .split_once(':')
        .ok_or_else(|| RegistryError::Invalid(format!("model ref {s:?} must be name:version or sha256:<hex>")))?;
    if head == "sha256" {
        if !is_hex_digest(tail) {
            return Err(RegistryError::Invalid(format!("malformed manifest digest in ref {s:?}")));
        }
        return Ok(ModelRef::Digest(tail.to_string()));
    }
    valid_ref_component("name", head)?;
    valid_ref_component("version", tail)?;
    Ok(ModelRef::Tag { name: head.to_string(), version: tail.to_string() })
}

/// Path-safety gate for manifest names and versions: nonempty, ≤64
/// chars, `[A-Za-z0-9._-]` only, no leading dot, and `name` may not be
/// the reserved word `sha256` (it would make refs ambiguous).
pub fn valid_ref_component(what: &str, s: &str) -> Result<(), RegistryError> {
    let ok = !s.is_empty()
        && s.len() <= 64
        && !s.starts_with('.')
        && s != "sha256"
        && s.bytes().all(|b| b.is_ascii_alphanumeric() || b == b'.' || b == b'_' || b == b'-');
    if ok {
        Ok(())
    } else {
        Err(RegistryError::Invalid(format!("unsafe {what} {s:?}")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dims() -> ModelDims {
        ModelDims { patch: 4, n_ctx: 8, d_model: 8, n_layers: 2, n_heads: 2, d_ff: 16 }
    }

    fn role(name: &str) -> RoleSpec {
        RoleSpec {
            model_name: name.to_string(),
            dims: dims(),
            sha256: "ab".repeat(32),
            size_bytes: 40,
            param_count: 10,
            tensor_index: Json::parse(r#"[{"name":"a","shape":[10],"offset":0}]"#).unwrap(),
        }
    }

    fn manifest() -> RegistryManifest {
        RegistryManifest {
            name: "demo".into(),
            version: "v1".into(),
            patch: 4,
            n_ctx: 8,
            target: role("t"),
            draft: role("d"),
        }
    }

    #[test]
    fn roundtrip_preserves_digest() {
        let m = manifest();
        let j = m.to_json();
        let m2 = RegistryManifest::from_json(&Json::parse(&j.to_string()).unwrap()).unwrap();
        assert_eq!(m.digest(), m2.digest());
        assert_eq!(m2.name, "demo");
        assert_eq!(m2.target.dims.d_ff, 16);
    }

    #[test]
    fn digest_is_content_sensitive_and_key_order_insensitive() {
        let m = manifest();
        let mut m2 = manifest();
        assert_eq!(m.digest(), m2.digest());
        m2.version = "v2".into();
        assert_ne!(m.digest(), m2.digest());
        // Key order in the source text does not matter: Json objects are
        // BTreeMaps, so parsing a shuffled doc re-canonicalizes it.
        let shuffled = r#"{"version":"v1","name":"demo","arch":"stride-native-v1","patch":4,"n_ctx":8,"models":{"target":null,"draft":null}}"#;
        let canonical = Json::parse(shuffled).unwrap().to_string();
        assert!(canonical.starts_with(r#"{"arch""#));
    }

    #[test]
    fn rejects_wrong_arch_and_bad_fields() {
        let mut j = manifest().to_json();
        if let Json::Obj(m) = &mut j {
            m.insert("arch".into(), Json::from("pytorch-v9"));
        }
        assert!(matches!(
            RegistryManifest::from_json(&j),
            Err(RegistryError::Invalid(_))
        ));

        let mut m = manifest();
        m.draft.dims.patch = 5; // disagrees with manifest shape
        assert!(m.validate().is_err());

        let mut m = manifest();
        m.target.size_bytes = 41;
        assert!(m.validate().is_err());
    }

    #[test]
    fn ref_parsing() {
        assert_eq!(
            parse_ref("demo:v1").unwrap(),
            ModelRef::Tag { name: "demo".into(), version: "v1".into() }
        );
        let d = "ab".repeat(32);
        assert_eq!(parse_ref(&format!("sha256:{d}")).unwrap(), ModelRef::Digest(d));
        for bad in ["demo", "sha256:xyz", "../x:v1", "a:b:c", ":v1", "demo:", "sha256:"] {
            assert!(parse_ref(bad).is_err(), "{bad} should be rejected");
        }
    }
}
