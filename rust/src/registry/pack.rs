//! Weight serialization: `Weights` → (flat little-endian f32 blob,
//! `{name, shape, offset}` tensor index).
//!
//! Tensors are packed in sorted-name order, so the blob — and therefore
//! its digest — is a pure function of the weight contents. Publishing the
//! same model twice yields the same blob digest and the same manifest
//! digest, which is what makes "post-swap outputs == cold-start outputs"
//! a testable bit-level claim.

use crate::nn::{NativeModel, Weights};
use crate::registry::error::RegistryError;
use crate::registry::manifest::{RegistryManifest, RoleSpec};
use crate::registry::Registry;
use crate::util::json::Json;

/// Serialize a weight store. Returns the raw blob and the tensor index
/// whose offsets (in floats) describe it — the same index format
/// [`Weights::load`] and [`Weights::from_mapped`] consume.
pub fn pack_weights(w: &Weights) -> Result<(Vec<u8>, Json), RegistryError> {
    let mut blob: Vec<u8> = Vec::with_capacity(w.total_params() * 4);
    let mut index: Vec<Json> = Vec::with_capacity(w.len());
    let mut offset = 0usize; // in floats
    for name in w.names() {
        let t = w
            .get(&name)
            .map_err(|e| RegistryError::Invalid(format!("packing {name}: {e}")))?;
        let shape = Json::Arr(t.shape.iter().map(|&d| Json::from(d)).collect());
        index.push(Json::obj(vec![
            ("name", Json::from(name.clone())),
            ("shape", shape),
            ("offset", Json::from(offset)),
        ]));
        for v in t.data.iter() {
            blob.extend_from_slice(&v.to_le_bytes());
        }
        offset += t.numel();
    }
    Ok((blob, Json::Arr(index)))
}

/// Pack one model's weights into the blob store and describe it as a
/// manifest role.
pub fn role_spec(model: &NativeModel, registry: &Registry) -> Result<RoleSpec, RegistryError> {
    let (blob, tensor_index) = pack_weights(model.weights())?;
    let sha256 = registry.blobs().put(&blob)?;
    Ok(RoleSpec {
        model_name: model.name.clone(),
        dims: model.dims,
        sha256,
        size_bytes: blob.len(),
        param_count: blob.len() / 4,
        tensor_index,
    })
}

/// Publish a (target, draft) pair under `name:version`: pack both weight
/// blobs into the store, then write the manifest. Returns the manifest
/// digest. This is how a model pair enters a registry in the first place
/// (tests, benches, and the push CLI all bottom out here).
pub fn publish_pair(
    registry: &Registry,
    name: &str,
    version: &str,
    target: &NativeModel,
    draft: &NativeModel,
) -> Result<String, RegistryError> {
    let manifest = RegistryManifest {
        name: name.to_string(),
        version: version.to_string(),
        patch: target.dims.patch,
        n_ctx: target.dims.n_ctx,
        target: role_spec(target, registry)?,
        draft: role_spec(draft, registry)?,
    };
    registry.put_manifest(&manifest)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::model::tiny_model;
    use crate::nn::ModelDims;

    #[test]
    fn packing_is_deterministic_and_loadable() {
        let m = tiny_model(7);
        let (blob1, idx1) = pack_weights(m.weights()).unwrap();
        let (blob2, idx2) = pack_weights(m.weights()).unwrap();
        assert_eq!(blob1, blob2);
        assert_eq!(idx1.to_string(), idx2.to_string());
        assert_eq!(blob1.len(), m.weights().total_params() * 4);

        // Heap-load the packed blob back and compare bit-for-bit.
        let dir = std::env::temp_dir().join("stride_pack_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("w.bin");
        std::fs::write(&path, &blob1).unwrap();
        let loaded = Weights::load(&path, &idx1).unwrap();
        assert_eq!(loaded.names(), m.weights().names());
        for name in loaded.names() {
            let a = m.weights().get(&name).unwrap();
            let b = loaded.get(&name).unwrap();
            assert_eq!(a.shape, b.shape);
            let ab: Vec<u32> = a.data.iter().map(|v| v.to_bits()).collect();
            let bb: Vec<u32> = b.data.iter().map(|v| v.to_bits()).collect();
            assert_eq!(ab, bb, "tensor {name}");
        }
    }

    #[test]
    fn publish_then_resolve_roundtrips() {
        let root = std::env::temp_dir().join("stride_publish_test");
        let _ = std::fs::remove_dir_all(&root);
        let registry = Registry::open(&root).unwrap();
        let dims = ModelDims { patch: 4, n_ctx: 8, d_model: 8, n_layers: 2, n_heads: 2, d_ff: 16 };
        let target = NativeModel::random("t", dims, 11);
        let draft = NativeModel::random("d", dims, 22);
        let digest = publish_pair(&registry, "demo", "v1", &target, &draft).unwrap();

        let (by_tag, d1) = registry.get_manifest("demo:v1").unwrap();
        assert_eq!(d1, digest);
        let (by_digest, d2) = registry.get_manifest(&format!("sha256:{digest}")).unwrap();
        assert_eq!(d2, digest);
        assert_eq!(by_tag.digest(), by_digest.digest());
        assert!(registry.blobs().has(&by_tag.target.sha256));
        assert!(registry.blobs().has(&by_tag.draft.sha256));

        // Re-publish of identical content is a no-op digest-wise.
        assert_eq!(publish_pair(&registry, "demo", "v1", &target, &draft).unwrap(), digest);
    }
}
