//! Content-addressed model registry: versioned manifests, digest-verified
//! blobs, zero-copy loading, and the substrate for live weight swap.
//!
//! Layout on disk (everything under one root, default
//! `<artifacts>/registry`):
//!
//! ```text
//! <root>/blobs/sha256/<hex>              raw weight blobs, named by content
//! <root>/manifests/sha256/<hex>.json     manifests, named by content
//! <root>/manifests/tags/<name>/<ver>.json  mutable tag -> same canonical bytes
//! ```
//!
//! Identity is content: a blob's name is the SHA-256 of its bytes, a
//! manifest's address is the SHA-256 of its canonical (sorted-key) JSON.
//! Every read re-verifies — [`BlobStore::open_verified`] hashes the
//! mapped bytes before any tensor binds to them, and manifest reads by
//! digest re-hash the file. Corruption anywhere on the path is a typed
//! [`RegistryError::DigestMismatch`], never a panic and never a model
//! that silently serves garbage.
//!
//! The module splits as: [`digest`] (hand-rolled SHA-256), [`blob`]
//! (content-addressed file store), [`manifest`] (versioned model-pair
//! descriptions + reference parsing), [`pack`] (weights → blob + index),
//! [`loader`] (verify-then-bind → ready backends, zero float copies),
//! and [`client`] (push/pull over the serving HTTP substrate).

pub mod blob;
pub mod client;
pub mod digest;
pub mod error;
pub mod loader;
pub mod manifest;
pub mod pack;

use std::fs;
use std::path::{Path, PathBuf};

pub use blob::BlobStore;
pub use client::{manifest_path, pull_model, push_model};
pub use digest::{sha256, sha256_hex, Sha256};
pub use error::RegistryError;
pub use loader::{load_pair, LoadedPair};
pub use manifest::{parse_ref, ModelRef, RegistryManifest, RoleSpec, ARCH};
pub use pack::{pack_weights, publish_pair};

use crate::registry::manifest::valid_ref_component;
use crate::util::json::Json;

/// A registry rooted at one directory: blob store + manifest store.
#[derive(Clone, Debug)]
pub struct Registry {
    root: PathBuf,
    blobs: BlobStore,
}

impl Registry {
    /// Open (creating directories as needed) a registry at `root`.
    pub fn open(root: &Path) -> Result<Registry, RegistryError> {
        fs::create_dir_all(root.join("manifests").join("sha256"))?;
        fs::create_dir_all(root.join("manifests").join("tags"))?;
        let blobs = BlobStore::open(root)?;
        Ok(Registry { root: root.to_path_buf(), blobs })
    }

    /// The registry's root directory.
    pub fn root(&self) -> &Path {
        &self.root
    }

    /// The blob store under this root.
    pub fn blobs(&self) -> &BlobStore {
        &self.blobs
    }

    fn digest_path(&self, digest: &str) -> PathBuf {
        self.root.join("manifests").join("sha256").join(format!("{digest}.json"))
    }

    fn tag_path(&self, name: &str, version: &str) -> PathBuf {
        self.root.join("manifests").join("tags").join(name).join(format!("{version}.json"))
    }

    /// Store a manifest under both its content address and its
    /// `name:version` tag. Refuses manifests whose blobs are not already
    /// present (push protocol: blobs first, then the manifest — a
    /// manifest in the store is a promise every referenced byte is too).
    /// Returns the manifest digest.
    pub fn put_manifest(&self, m: &RegistryManifest) -> Result<String, RegistryError> {
        valid_ref_component("name", &m.name)?;
        valid_ref_component("version", &m.version)?;
        m.validate()?;
        for (role, spec) in [("target", &m.target), ("draft", &m.draft)] {
            if !self.blobs.has(&spec.sha256) {
                return Err(RegistryError::NotFound(format!(
                    "blob sha256:{} referenced by {role} (push blobs before the manifest)",
                    spec.sha256
                )));
            }
        }
        let text = m.to_json().to_string();
        let digest = sha256_hex(text.as_bytes());
        write_atomic(&self.digest_path(&digest), text.as_bytes())?;
        let tag = self.tag_path(&m.name, &m.version);
        if let Some(parent) = tag.parent() {
            fs::create_dir_all(parent)?;
        }
        write_atomic(&tag, text.as_bytes())?;
        Ok(digest)
    }

    /// Resolve a reference (`name:version` or `sha256:<hex>`) to a parsed
    /// manifest and its digest. Digest lookups re-hash the stored bytes —
    /// a tampered manifest file is a [`RegistryError::DigestMismatch`].
    pub fn get_manifest(
        &self,
        reference: &str,
    ) -> Result<(RegistryManifest, String), RegistryError> {
        let (path, expected) = match parse_ref(reference)? {
            ModelRef::Digest(d) => (self.digest_path(&d), Some(d)),
            ModelRef::Tag { name, version } => (self.tag_path(&name, &version), None),
        };
        let bytes = fs::read(&path).map_err(|e| {
            if e.kind() == std::io::ErrorKind::NotFound {
                RegistryError::NotFound(format!("manifest {reference}"))
            } else {
                RegistryError::Io(e)
            }
        })?;
        if let Some(expected) = &expected {
            let actual = sha256_hex(&bytes);
            if &actual != expected {
                return Err(RegistryError::DigestMismatch {
                    expected: expected.clone(),
                    actual,
                });
            }
        }
        let text = String::from_utf8(bytes)
            .map_err(|_| RegistryError::Invalid(format!("manifest {reference} is not UTF-8")))?;
        let j = Json::parse(&text)
            .map_err(|e| RegistryError::Invalid(format!("manifest {reference}: {e}")))?;
        let m = RegistryManifest::from_json(&j)?;
        let digest = expected.unwrap_or_else(|| m.digest());
        Ok((m, digest))
    }

    /// Tags present in the store, as `name:version` strings in sorted
    /// order (the `/v1/models` listing).
    pub fn list_tags(&self) -> Result<Vec<String>, RegistryError> {
        let tags_dir = self.root.join("manifests").join("tags");
        let mut out = Vec::new();
        for name_entry in fs::read_dir(&tags_dir)? {
            let name_entry = name_entry?;
            if !name_entry.path().is_dir() {
                continue;
            }
            let name = name_entry.file_name().to_string_lossy().into_owned();
            for ver_entry in fs::read_dir(name_entry.path())? {
                let file = ver_entry?.file_name().to_string_lossy().into_owned();
                if let Some(version) = file.strip_suffix(".json") {
                    out.push(format!("{name}:{version}"));
                }
            }
        }
        out.sort();
        Ok(out)
    }
}

/// Temp-file + rename write (same crash-safety contract as blob writes).
fn write_atomic(path: &Path, bytes: &[u8]) -> Result<(), RegistryError> {
    let tmp = path.with_extension(format!("tmp.{}", std::process::id()));
    fs::write(&tmp, bytes)?;
    fs::rename(&tmp, path)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::model::tiny_model;

    fn fresh(tag: &str) -> Registry {
        let root = std::env::temp_dir().join(format!("stride_registry_mod_{tag}"));
        let _ = std::fs::remove_dir_all(&root);
        Registry::open(&root).unwrap()
    }

    #[test]
    fn manifest_requires_blobs_first() {
        let reg = fresh("blobs_first");
        let m = {
            // Build a manifest whose blobs were never pushed.
            let other = fresh("blobs_first_side");
            let t = tiny_model(1);
            let d = tiny_model(2);
            publish_pair(&other, "m", "v1", &t, &d).unwrap();
            other.get_manifest("m:v1").unwrap().0
        };
        assert!(matches!(reg.put_manifest(&m), Err(RegistryError::NotFound(_))));
    }

    #[test]
    fn tampered_manifest_by_digest_is_rejected() {
        let reg = fresh("tamper");
        let t = tiny_model(3);
        let d = tiny_model(4);
        let digest = publish_pair(&reg, "m", "v1", &t, &d).unwrap();
        let path = reg.digest_path(&digest);
        let mut text = std::fs::read_to_string(&path).unwrap();
        text = text.replace("\"v1\"", "\"v2\"");
        std::fs::write(&path, text).unwrap();
        assert!(matches!(
            reg.get_manifest(&format!("sha256:{digest}")),
            Err(RegistryError::DigestMismatch { .. })
        ));
        // The tag file is untouched; tag resolution still works and now
        // reports the *tag file's* digest.
        assert!(reg.get_manifest("m:v1").is_ok());
    }

    #[test]
    fn tags_list_and_retarget() {
        let reg = fresh("tags");
        let t = tiny_model(5);
        let d = tiny_model(6);
        let d1 = publish_pair(&reg, "m", "v1", &t, &d).unwrap();
        let t2 = tiny_model(7);
        let d2m = tiny_model(8);
        let d2 = publish_pair(&reg, "m", "v2", &t2, &d2m).unwrap();
        assert_ne!(d1, d2);
        assert_eq!(reg.list_tags().unwrap(), vec!["m:v1".to_string(), "m:v2".to_string()]);
        // Re-pushing v1 with different content retargets the tag: v1 now
        // references the same blobs as v2 (manifest digests still differ
        // because the version field differs).
        let d1b = publish_pair(&reg, "m", "v1", &t2, &d2m).unwrap();
        assert_ne!(d1b, d1);
        assert_ne!(d1b, d2);
        assert_eq!(
            reg.get_manifest("m:v1").unwrap().0.target.sha256,
            reg.get_manifest("m:v2").unwrap().0.target.sha256
        );
    }
}
