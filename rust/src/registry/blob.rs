//! Content-addressed blob store: `<root>/blobs/sha256/<hex>`.
//!
//! A blob's name *is* its SHA-256, so the store is immutable and
//! idempotent by construction — `put` of bytes that already exist is a
//! no-op, and two registries that hold the same model hold bit-identical
//! files under the same paths. Writes go through a temp file + atomic
//! rename so a crashed push never leaves a half-written blob under a
//! valid digest. Reads re-verify: [`BlobStore::open_verified`] hashes the
//! mapped bytes and refuses to hand out a mapping whose content no longer
//! matches its address.

use std::fs;
use std::path::{Path, PathBuf};
use std::sync::Arc;

use crate::registry::digest::{is_hex_digest, sha256_hex};
use crate::registry::error::RegistryError;
use crate::util::mmap::MappedFile;

/// Handle to an on-disk blob directory (cheap to clone; no state beyond
/// the root path — the filesystem is the source of truth).
#[derive(Clone, Debug)]
pub struct BlobStore {
    dir: PathBuf,
}

impl BlobStore {
    /// Open (creating if absent) the blob directory under a registry root.
    pub fn open(registry_root: &Path) -> Result<BlobStore, RegistryError> {
        let dir = registry_root.join("blobs").join("sha256");
        fs::create_dir_all(&dir)?;
        Ok(BlobStore { dir })
    }

    /// The path a digest would live at. Errors on anything that is not a
    /// well-formed lowercase hex digest — this is the traversal guard for
    /// every externally supplied digest.
    pub fn path_for(&self, digest: &str) -> Result<PathBuf, RegistryError> {
        if !is_hex_digest(digest) {
            return Err(RegistryError::Invalid(format!("malformed blob digest {digest:?}")));
        }
        Ok(self.dir.join(digest))
    }

    /// Whether a blob with this digest is present (malformed digests are
    /// simply absent).
    pub fn has(&self, digest: &str) -> bool {
        self.path_for(digest).map(|p| p.is_file()).unwrap_or(false)
    }

    /// Store `bytes` under their own digest and return it. Idempotent;
    /// atomic via temp file + rename.
    pub fn put(&self, bytes: &[u8]) -> Result<String, RegistryError> {
        let digest = sha256_hex(bytes);
        let dst = self.dir.join(&digest);
        if dst.is_file() {
            return Ok(digest);
        }
        // Temp name is unique per (digest, pid) — concurrent writers of
        // the *same* content race benignly: both temp files hold the
        // same bytes and rename is atomic.
        let tmp = self.dir.join(format!(".tmp.{}.{}", digest, std::process::id()));
        fs::write(&tmp, bytes)?;
        fs::rename(&tmp, &dst)?;
        Ok(digest)
    }

    /// Store bytes that the caller claims have `expected` digest; verify
    /// before committing. This is the pull path's corruption gate: a
    /// truncated or bit-flipped transfer is rejected with a typed
    /// [`RegistryError::DigestMismatch`] and nothing is written.
    pub fn put_expected(&self, expected: &str, bytes: &[u8]) -> Result<String, RegistryError> {
        if !is_hex_digest(expected) {
            return Err(RegistryError::Invalid(format!("malformed blob digest {expected:?}")));
        }
        let actual = sha256_hex(bytes);
        if actual != expected {
            return Err(RegistryError::DigestMismatch {
                expected: expected.to_string(),
                actual,
            });
        }
        self.put(bytes)
    }

    /// Read a blob fully into memory, verifying its digest.
    pub fn read_verified(&self, digest: &str) -> Result<Vec<u8>, RegistryError> {
        let path = self.path_for(digest)?;
        let bytes = fs::read(&path).map_err(|e| {
            if e.kind() == std::io::ErrorKind::NotFound {
                RegistryError::NotFound(format!("blob sha256:{digest}"))
            } else {
                RegistryError::Io(e)
            }
        })?;
        let actual = sha256_hex(&bytes);
        if actual != digest {
            return Err(RegistryError::DigestMismatch {
                expected: digest.to_string(),
                actual,
            });
        }
        Ok(bytes)
    }

    /// Map a blob read-only (heap fallback where mmap is unsupported),
    /// verify the mapped bytes hash to `digest`, and return the mapping.
    /// This is the zero-copy load path: the returned `Arc<MappedFile>` is
    /// what weight tensors bind into — the digest check reads every byte
    /// once, but no float is ever copied.
    pub fn open_verified(&self, digest: &str) -> Result<Arc<MappedFile>, RegistryError> {
        let path = self.path_for(digest)?;
        if !path.is_file() {
            return Err(RegistryError::NotFound(format!("blob sha256:{digest}")));
        }
        let file = Arc::new(MappedFile::open(&path)?);
        let actual = sha256_hex(file.bytes());
        if actual != digest {
            return Err(RegistryError::DigestMismatch {
                expected: digest.to_string(),
                actual,
            });
        }
        Ok(file)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn store(tag: &str) -> BlobStore {
        let root = std::env::temp_dir().join(format!("stride_blobstore_{tag}"));
        let _ = fs::remove_dir_all(&root);
        BlobStore::open(&root).unwrap()
    }

    #[test]
    fn put_get_roundtrip_is_bit_identical() {
        let s = store("roundtrip");
        let data = b"hello registry".to_vec();
        let d = s.put(&data).unwrap();
        assert!(s.has(&d));
        assert_eq!(s.read_verified(&d).unwrap(), data);
        let mapped = s.open_verified(&d).unwrap();
        assert_eq!(mapped.bytes(), &data[..]);
        // Idempotent re-put.
        assert_eq!(s.put(&data).unwrap(), d);
    }

    #[test]
    fn corruption_is_a_typed_rejection_not_a_panic() {
        let s = store("corrupt");
        let d = s.put(b"good bytes").unwrap();
        // Flip a byte on disk behind the store's back.
        let path = s.path_for(&d).unwrap();
        let mut bytes = fs::read(&path).unwrap();
        bytes[0] ^= 0xff;
        fs::write(&path, &bytes).unwrap();
        match s.open_verified(&d) {
            Err(RegistryError::DigestMismatch { expected, actual }) => {
                assert_eq!(expected, d);
                assert_ne!(actual, d);
            }
            other => panic!("want DigestMismatch, got {other:?}"),
        }
        assert!(matches!(
            s.read_verified(&d),
            Err(RegistryError::DigestMismatch { .. })
        ));
    }

    #[test]
    fn put_expected_rejects_wrong_content() {
        let s = store("expected");
        let good = b"payload".to_vec();
        let d = crate::registry::digest::sha256_hex(&good);
        assert_eq!(s.put_expected(&d, &good).unwrap(), d);
        let err = s.put_expected(&d, b"tampered").unwrap_err();
        assert!(matches!(err, RegistryError::DigestMismatch { .. }));
        // Nothing extra written: the tampered bytes' digest is absent.
        assert!(!s.has(&crate::registry::digest::sha256_hex(b"tampered")));
    }

    #[test]
    fn malformed_digests_never_touch_the_filesystem() {
        let s = store("traversal");
        for bad in ["../../etc/passwd", "ABCDEF", "", "zz"] {
            assert!(matches!(s.path_for(bad), Err(RegistryError::Invalid(_))));
            assert!(!s.has(bad));
        }
        assert!(matches!(
            s.read_verified(&"0".repeat(64)),
            Err(RegistryError::NotFound(_))
        ));
    }
}
