//! Verify-then-bind model loading: manifest reference → ready backends.
//!
//! The load path never trusts bytes it has not hashed. Each role's blob
//! is mapped read-only, hashed against the manifest's digest (one
//! sequential pass — the only full read on the path), and only then do
//! weight tensors bind into the mapping via [`Weights::from_mapped`]:
//! zero floats are copied between disk and the kernel layer's packed
//! handles. A corrupt or truncated blob surfaces as a typed
//! [`RegistryError::DigestMismatch`] before any model object exists.

use crate::models::NativeBackend;
use crate::nn::{NativeModel, Weights};
use crate::registry::error::RegistryError;
use crate::registry::manifest::{RegistryManifest, RoleSpec};
use crate::registry::Registry;

/// A fully verified, ready-to-serve model pair.
pub struct LoadedPair {
    /// The manifest the pair was loaded from.
    pub manifest: RegistryManifest,
    /// The manifest's content address — this is what `/healthz` and
    /// `/stats` report as the serving model identity.
    pub manifest_digest: String,
    /// Verification backend.
    pub target: NativeBackend,
    /// Speculation backend.
    pub draft: NativeBackend,
}

/// Resolve `reference` (`name:version` or `sha256:<hex>`) and load both
/// roles with digest verification.
pub fn load_pair(registry: &Registry, reference: &str) -> Result<LoadedPair, RegistryError> {
    let (manifest, manifest_digest) = registry.get_manifest(reference)?;
    let target = load_role(registry, &manifest.target)?;
    let draft = load_role(registry, &manifest.draft)?;
    Ok(LoadedPair { manifest, manifest_digest, target, draft })
}

/// Load one role: verified mapping → tensor binding → packed backend.
pub fn load_role(registry: &Registry, spec: &RoleSpec) -> Result<NativeBackend, RegistryError> {
    let file = registry.blobs().open_verified(&spec.sha256)?;
    if file.len() != spec.size_bytes {
        return Err(RegistryError::Invalid(format!(
            "blob sha256:{} is {} bytes, manifest says {}",
            spec.sha256,
            file.len(),
            spec.size_bytes
        )));
    }
    let weights = Weights::from_mapped(file, &spec.tensor_index)
        .map_err(|e| RegistryError::Invalid(format!("binding tensors for {}: {e:#}", spec.model_name)))?;
    if weights.total_params() != spec.param_count {
        return Err(RegistryError::Invalid(format!(
            "{} indexes {} params, manifest says {}",
            spec.model_name,
            weights.total_params(),
            spec.param_count
        )));
    }
    let model = NativeModel::new(&spec.model_name, spec.dims, weights)
        .map_err(|e| RegistryError::Invalid(format!("packing {}: {e:#}", spec.model_name)))?;
    Ok(NativeBackend::new(model))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::model::tiny_model;
    use crate::registry::pack::publish_pair;
    use crate::util::tensor::Tensor;

    fn fresh_registry(tag: &str) -> Registry {
        let root = std::env::temp_dir().join(format!("stride_loader_test_{tag}"));
        let _ = std::fs::remove_dir_all(&root);
        Registry::open(&root).unwrap()
    }

    #[test]
    fn loaded_pair_forwards_identically_to_the_source_models() {
        let registry = fresh_registry("fwd");
        let target = tiny_model(31);
        let draft = tiny_model(32);
        let digest = publish_pair(&registry, "m", "v1", &target, &draft).unwrap();

        let pair = load_pair(&registry, "m:v1").unwrap();
        assert_eq!(pair.manifest_digest, digest);

        // Same input through source model and registry-loaded (mapped)
        // model must agree bit-for-bit: the whole zero-copy path is only
        // admissible because it is invisible to the numerics.
        let dims = target.dims;
        let tokens = Tensor::from_vec(
            &[1, 2, dims.patch],
            (0..2 * dims.patch).map(|i| (i as f32 * 0.37).sin()).collect(),
        );
        let want = target.forward(&tokens).unwrap();
        let got = pair.target.model().forward(&tokens).unwrap();
        let wb: Vec<u32> = want.data.iter().map(|v| v.to_bits()).collect();
        let gb: Vec<u32> = got.data.iter().map(|v| v.to_bits()).collect();
        assert_eq!(wb, gb);
    }

    #[test]
    fn corrupt_blob_is_rejected_with_digest_mismatch() {
        let registry = fresh_registry("corrupt");
        let target = tiny_model(41);
        let draft = tiny_model(42);
        publish_pair(&registry, "m", "v1", &target, &draft).unwrap();
        let (manifest, _) = registry.get_manifest("m:v1").unwrap();

        // Truncate the target blob in place.
        let path = registry.blobs().path_for(&manifest.target.sha256).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() - 4]).unwrap();

        match load_pair(&registry, "m:v1") {
            Err(RegistryError::DigestMismatch { expected, .. }) => {
                assert_eq!(expected, manifest.target.sha256);
            }
            other => panic!("want DigestMismatch, got {:?}", other.err()),
        }
    }

    #[test]
    fn missing_blob_is_not_found() {
        let registry = fresh_registry("missing");
        let target = tiny_model(51);
        let draft = tiny_model(52);
        publish_pair(&registry, "m", "v1", &target, &draft).unwrap();
        let (manifest, _) = registry.get_manifest("m:v1").unwrap();
        std::fs::remove_file(registry.blobs().path_for(&manifest.draft.sha256).unwrap()).unwrap();
        assert!(matches!(
            load_pair(&registry, "m:v1"),
            Err(RegistryError::NotFound(_))
        ));
    }
}
