//! Typed registry failures, mapped onto the serving wire protocol.
//!
//! The store layer stays independent of the server (`registry` must not
//! import `server::protocol`), so it defines its own error sum and the
//! conversion lives here as a `From` impl — handlers bubble
//! `RegistryError` with `?` straight into a [`ServeError`] response.

use std::fmt;

use crate::server::protocol::ServeError;

/// Why a registry operation failed. Every variant is a typed, reportable
/// condition — corruption and absence are expected runtime events, never
/// panics.
#[derive(Debug)]
pub enum RegistryError {
    /// Content did not hash to the digest it was addressed by (corrupt or
    /// truncated blob, tampered manifest).
    DigestMismatch {
        /// The digest the content was addressed by.
        expected: String,
        /// What the content actually hashed to.
        actual: String,
    },
    /// No blob or manifest under that reference.
    NotFound(String),
    /// Structurally invalid input: bad reference syntax, malformed
    /// manifest JSON, wrong architecture tag, unsafe name.
    Invalid(String),
    /// Underlying filesystem failure.
    Io(std::io::Error),
}

impl fmt::Display for RegistryError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            RegistryError::DigestMismatch { expected, actual } => {
                write!(f, "digest mismatch: expected sha256:{expected}, got sha256:{actual}")
            }
            RegistryError::NotFound(what) => write!(f, "not found: {what}"),
            RegistryError::Invalid(why) => write!(f, "invalid: {why}"),
            RegistryError::Io(e) => write!(f, "registry io: {e}"),
        }
    }
}

impl std::error::Error for RegistryError {}

impl From<std::io::Error> for RegistryError {
    fn from(e: std::io::Error) -> Self {
        RegistryError::Io(e)
    }
}

impl From<RegistryError> for ServeError {
    fn from(e: RegistryError) -> Self {
        match e {
            RegistryError::DigestMismatch { expected, actual } => {
                ServeError::DigestMismatch { expected, actual }
            }
            RegistryError::NotFound(what) => ServeError::NotFound(what),
            RegistryError::Invalid(why) => ServeError::Invalid(why),
            RegistryError::Io(e) => ServeError::Internal(format!("registry io: {e}")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn maps_onto_wire_protocol() {
        let e: ServeError = RegistryError::DigestMismatch {
            expected: "aa".into(),
            actual: "bb".into(),
        }
        .into();
        assert_eq!(e.http_status(), 422);
        assert_eq!(e.code(), "digest_mismatch");

        let e: ServeError = RegistryError::NotFound("model demo:v9".into()).into();
        assert_eq!(e.http_status(), 404);

        let e: ServeError = RegistryError::Invalid("bad ref".into()).into();
        assert_eq!(e.http_status(), 400);

        let e: ServeError =
            RegistryError::from(std::io::Error::new(std::io::ErrorKind::Other, "disk")).into();
        assert_eq!(e.http_status(), 500);
    }
}
