//! Synthetic ETT-like / Weather-like dataset generator.
//!
//! Line-for-line mirror of `python/compile/datagen.py` (same counter-based
//! SplitMix64 stream, same AR recursion, same split/normalization), so the
//! serving side evaluates on exactly the corpus the models were trained on.
//! The cross-language contract is pinned by golden vectors exported by
//! `aot.py` (see `golden_matches_python_export` below).

use crate::util::rng::{std_normal, uniform01};

/// Parameters of one synthetic dataset (mirror of datagen.DatasetSpec).
#[derive(Clone, Debug, PartialEq)]
pub struct DatasetSpec {
    /// Dataset name ("etth1", "weather", ...).
    pub name: &'static str,
    /// Base seed for all of the dataset's RNG sub-streams.
    pub seed: u64,
    /// Number of channels (independent series).
    pub channels: usize,
    /// Series length in time steps.
    pub length: usize,
    /// Seasonal component periods, in time steps.
    pub periods: Vec<usize>,
    /// Base amplitude per seasonal component.
    pub amps: Vec<f64>,
    /// AR(1) noise coefficient.
    pub ar_phi: f64,
    /// AR(1) innovation standard deviation.
    pub noise_std: f64,
    /// Linear trend magnitude per 1000 steps.
    pub trend_per_k: f64,
    /// Number of random level shifts (regime switches).
    pub n_shifts: usize,
    /// Level-shift magnitude standard deviation.
    pub shift_std: f64,
}

/// The four benchmark stand-ins (mirror of datagen.SPECS; see DESIGN.md §3
/// for why the parameterization preserves the paper's dataset ordering).
pub fn specs() -> Vec<DatasetSpec> {
    vec![
        DatasetSpec {
            name: "etth1", seed: 101, channels: 7, length: 14400,
            periods: vec![24, 168], amps: vec![1.0, 0.45],
            ar_phi: 0.72, noise_std: 0.32, trend_per_k: 0.04,
            n_shifts: 6, shift_std: 0.5,
        },
        DatasetSpec {
            name: "etth2", seed: 202, channels: 7, length: 14400,
            periods: vec![24, 168], amps: vec![0.9, 0.35],
            ar_phi: 0.65, noise_std: 0.52, trend_per_k: 0.06,
            n_shifts: 10, shift_std: 0.8,
        },
        DatasetSpec {
            name: "ettm2", seed: 303, channels: 7, length: 28800,
            periods: vec![96, 672], amps: vec![1.0, 0.40],
            ar_phi: 0.80, noise_std: 0.28, trend_per_k: 0.02,
            n_shifts: 6, shift_std: 0.4,
        },
        DatasetSpec {
            name: "weather", seed: 404, channels: 21, length: 14400,
            periods: vec![144, 1008], amps: vec![1.1, 0.50],
            ar_phi: 0.85, noise_std: 0.14, trend_per_k: 0.03,
            n_shifts: 3, shift_std: 0.3,
        },
    ]
}

/// The spec of a benchmark dataset by name, if known.
pub fn spec_by_name(name: &str) -> Option<DatasetSpec> {
    specs().into_iter().find(|s| s.name == name)
}

// Sub-stream tags (keep in sync with datagen.py).
const TAG_PHASE: u64 = 1;
const TAG_AMP: u64 = 2;
const TAG_NOISE: u64 = 3;
const TAG_TREND: u64 = 4;
const TAG_SHIFT_POS: u64 = 5;
const TAG_SHIFT_MAG: u64 = 6;

fn chan_seed(spec: &DatasetSpec, tag: u64, channel: usize) -> u64 {
    spec.seed
        .wrapping_mul(1_000_003)
        .wrapping_add(tag.wrapping_mul(10_007))
        .wrapping_add(channel as u64)
}

/// A generated dataset: raw series plus train-split normalization stats.
#[derive(Clone, Debug)]
pub struct Dataset {
    /// The generating parameters.
    pub spec: DatasetSpec,
    /// Raw series, row-major `[channels][length]`.
    pub raw: Vec<Vec<f64>>,
    /// Per-channel train mean/std (population std, matching numpy).
    pub mean: Vec<f64>,
    /// Per-channel train standard deviation (floored at 1e-8).
    pub std: Vec<f64>,
}

/// Generate one channel (mirror of the datagen.generate inner loop).
fn generate_channel(spec: &DatasetSpec, c: usize) -> Vec<f64> {
    let n = spec.length;
    let mut y = vec![0.0f64; n];
    let nk = spec.periods.len();
    let phases: Vec<f64> =
        (0..nk).map(|k| uniform01(chan_seed(spec, TAG_PHASE, c), k as u64)).collect();
    let ampj: Vec<f64> =
        (0..nk).map(|k| uniform01(chan_seed(spec, TAG_AMP, c), k as u64)).collect();
    for k in 0..nk {
        let a = spec.amps[k] * (0.75 + 0.5 * ampj[k]);
        let period = spec.periods[k] as f64;
        for (t, yt) in y.iter_mut().enumerate() {
            *yt += a * (2.0 * std::f64::consts::PI * (t as f64 / period + phases[k])).sin();
        }
    }
    // AR(1) noise.
    let noise_seed = chan_seed(spec, TAG_NOISE, c);
    let mut prev = 0.0f64;
    for (t, yt) in y.iter_mut().enumerate() {
        prev = spec.ar_phi * prev + spec.noise_std * std_normal(noise_seed, t as u64);
        *yt += prev;
    }
    // Slow linear trend.
    let tr = uniform01(chan_seed(spec, TAG_TREND, c), 0) - 0.5;
    let slope = 2.0 * tr * spec.trend_per_k / 1000.0;
    for (t, yt) in y.iter_mut().enumerate() {
        *yt += slope * t as f64;
    }
    // Rare level shifts.
    let pos_seed = chan_seed(spec, TAG_SHIFT_POS, c);
    let mag_seed = chan_seed(spec, TAG_SHIFT_MAG, c);
    for s in 0..spec.n_shifts {
        let start = (uniform01(pos_seed, s as u64) * n as f64) as usize;
        let mag = spec.shift_std * std_normal(mag_seed, s as u64);
        for yt in y.iter_mut().skip(start) {
            *yt += mag;
        }
    }
    y
}

/// (train_end, val_end): 70/10/20 split (mirror of datagen).
pub fn split_points(length: usize) -> (usize, usize) {
    ((length as f64 * 0.7) as usize, (length as f64 * 0.8) as usize)
}

impl Dataset {
    /// Generate the full dataset for a spec (deterministic).
    pub fn generate(spec: &DatasetSpec) -> Dataset {
        let raw: Vec<Vec<f64>> =
            (0..spec.channels).map(|c| generate_channel(spec, c)).collect();
        let (train_end, _) = split_points(spec.length);
        let mut mean = Vec::with_capacity(spec.channels);
        let mut std = Vec::with_capacity(spec.channels);
        for ch in &raw {
            let m = ch[..train_end].iter().sum::<f64>() / train_end as f64;
            let v = ch[..train_end].iter().map(|x| (x - m) * (x - m)).sum::<f64>()
                / train_end as f64;
            mean.push(m);
            std.push(v.sqrt().max(1e-8));
        }
        Dataset { spec: spec.clone(), raw, mean, std }
    }

    /// Generate a benchmark dataset by name, if known.
    pub fn by_name(name: &str) -> Option<Dataset> {
        spec_by_name(name).map(|s| Dataset::generate(&s))
    }

    /// Normalized value at (channel, t).
    #[inline]
    pub fn norm(&self, channel: usize, t: usize) -> f32 {
        ((self.raw[channel][t] - self.mean[channel]) / self.std[channel]) as f32
    }

    /// Normalized slice [t0, t0+len) of a channel as f32.
    pub fn norm_slice(&self, channel: usize, t0: usize, len: usize) -> Vec<f32> {
        (t0..t0 + len).map(|t| self.norm(channel, t)).collect()
    }

    /// Number of channels.
    pub fn channels(&self) -> usize {
        self.spec.channels
    }

    /// Series length in time steps.
    pub fn len(&self) -> usize {
        self.spec.length
    }

    /// Whether the series is empty (never true for the benchmark specs).
    pub fn is_empty(&self) -> bool {
        self.spec.length == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let spec = &specs()[0];
        let a = Dataset::generate(spec);
        let b = Dataset::generate(spec);
        assert_eq!(a.raw[0][..100], b.raw[0][..100]);
    }

    #[test]
    fn channels_differ() {
        let d = Dataset::by_name("etth1").unwrap();
        assert_ne!(d.raw[0][..50], d.raw[1][..50]);
    }

    #[test]
    fn normalized_train_split_is_standard() {
        let d = Dataset::by_name("etth2").unwrap();
        let (train_end, _) = split_points(d.len());
        for c in 0..d.channels() {
            let vals: Vec<f64> = (0..train_end).map(|t| d.norm(c, t) as f64).collect();
            let m = vals.iter().sum::<f64>() / vals.len() as f64;
            let v = vals.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / vals.len() as f64;
            assert!(m.abs() < 1e-3, "mean {m}");
            assert!((v - 1.0).abs() < 1e-3, "var {v}");
        }
    }

    #[test]
    fn datasets_have_expected_roughness_ordering() {
        // Weather is smoothest; ETTh2 noisier than ETTh1 (paper's dataset
        // behaviour ordering, DESIGN.md §3). Roughness = mean |x_t - x_{t-1}|
        // of the normalized series.
        let rough = |name: &str| {
            let d = Dataset::by_name(name).unwrap();
            let mut acc = 0.0f64;
            let mut n = 0usize;
            for c in 0..d.channels() {
                for t in 1..2000 {
                    acc += (d.norm(c, t) - d.norm(c, t - 1)).abs() as f64;
                    n += 1;
                }
            }
            acc / n as f64
        };
        let (w, e1, e2) = (rough("weather"), rough("etth1"), rough("etth2"));
        assert!(w < e1, "weather {w} vs etth1 {e1}");
        assert!(e1 < e2, "etth1 {e1} vs etth2 {e2}");
    }

    /// Cross-language contract: when artifacts are present, the first 64 raw
    /// samples of channel 0 must match the Python export bit-for-bit (up to
    /// libm ulp differences — tol 1e-9).
    #[test]
    fn golden_matches_python_export() {
        let dir = crate::artifacts_dir();
        let mut checked = 0;
        for spec in specs() {
            let path = dir.join(format!("golden_data_{}.bin", spec.name));
            if !path.exists() {
                continue;
            }
            let bytes = std::fs::read(&path).unwrap();
            let want: Vec<f64> = bytes
                .chunks_exact(8)
                .map(|c| f64::from_le_bytes(c.try_into().unwrap()))
                .collect();
            let d = Dataset::generate(&spec);
            for (t, w) in want.iter().enumerate() {
                let got = d.raw[0][t];
                assert!(
                    (got - w).abs() < 1e-9,
                    "{} t={t}: rust {got} vs python {w}",
                    spec.name
                );
            }
            checked += 1;
        }
        if checked == 0 {
            eprintln!("SKIP golden_matches_python_export: run `make artifacts`");
        }
    }
}
