//! Evaluation windowing: channel-independent sliding windows over the test
//! split (standard long-horizon forecasting protocol: lookback L, horizon H,
//! per-channel z-scored by train statistics).

use super::synthetic::{split_points, Dataset};

/// One forecasting task instance (normalized values).
#[derive(Clone, Debug)]
pub struct Window {
    /// Source channel index within the dataset.
    pub channel: usize,
    /// Window start (time step) within the channel.
    pub start: usize,
    /// Lookback, length = lookback patches * patch.
    pub history: Vec<f32>,
    /// Ground truth, length = horizon patches * patch.
    pub future: Vec<f32>,
}

/// Deterministic sliding eval windows from the test split.
///
/// `stride` is in time steps; the standard protocol strides by the horizon
/// so windows do not overlap in their forecast region.
pub fn eval_windows(
    data: &Dataset,
    patch: usize,
    lookback_patches: usize,
    horizon_patches: usize,
    stride: usize,
    max_windows: usize,
) -> Vec<Window> {
    let (_, val_end) = split_points(data.len());
    let lb = lookback_patches * patch;
    let hz = horizon_patches * patch;
    let mut out = Vec::new();
    'outer: for channel in 0..data.channels() {
        let mut start = val_end;
        while start + lb + hz <= data.len() {
            out.push(Window {
                channel,
                start,
                history: data.norm_slice(channel, start, lb),
                future: data.norm_slice(channel, start + lb, hz),
            });
            if out.len() >= max_windows {
                break 'outer;
            }
            start += stride;
        }
    }
    out
}

/// Round-robin interleave across channels so a truncated window budget still
/// covers every channel (used when batching across heterogeneous requests).
pub fn eval_windows_balanced(
    data: &Dataset,
    patch: usize,
    lookback_patches: usize,
    horizon_patches: usize,
    stride: usize,
    max_windows: usize,
) -> Vec<Window> {
    let per_chan = eval_windows(data, patch, lookback_patches, horizon_patches, stride, usize::MAX);
    let mut by_chan: Vec<Vec<Window>> = vec![Vec::new(); data.channels()];
    for w in per_chan {
        by_chan[w.channel].push(w);
    }
    let mut out = Vec::new();
    let mut i = 0;
    while out.len() < max_windows {
        let mut any = false;
        for ch in by_chan.iter() {
            if let Some(w) = ch.get(i) {
                out.push(w.clone());
                any = true;
                if out.len() >= max_windows {
                    break;
                }
            }
        }
        if !any {
            break;
        }
        i += 1;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::Dataset;

    #[test]
    fn windows_are_in_test_split_and_consistent() {
        let d = Dataset::by_name("etth1").unwrap();
        let ws = eval_windows(&d, 24, 4, 4, 96, 50);
        assert!(!ws.is_empty());
        let (_, val_end) = split_points(d.len());
        for w in &ws {
            assert!(w.start >= val_end);
            assert_eq!(w.history.len(), 96);
            assert_eq!(w.future.len(), 96);
            // History + future must be contiguous in the underlying series.
            let direct = d.norm_slice(w.channel, w.start, 192);
            assert_eq!(&direct[..96], w.history.as_slice());
            assert_eq!(&direct[96..], w.future.as_slice());
        }
    }

    #[test]
    fn stride_and_budget_respected() {
        let d = Dataset::by_name("etth1").unwrap();
        let ws = eval_windows(&d, 24, 4, 4, 48, 10);
        assert_eq!(ws.len(), 10);
        assert_eq!(ws[1].start - ws[0].start, 48);
    }

    #[test]
    fn balanced_covers_channels() {
        let d = Dataset::by_name("etth1").unwrap();
        let ws = eval_windows_balanced(&d, 24, 4, 4, 96, 14);
        let chans: std::collections::BTreeSet<usize> = ws.iter().map(|w| w.channel).collect();
        assert_eq!(chans.len(), 7, "all 7 channels covered: {chans:?}");
    }

    #[test]
    fn long_horizon_windows() {
        let d = Dataset::by_name("ettm2").unwrap();
        let ws = eval_windows(&d, 24, 4, 14, 336, 20); // pred-len 336
        assert!(!ws.is_empty());
        assert_eq!(ws[0].future.len(), 336);
    }
}
