//! Data pipeline: synthetic benchmark datasets (mirroring the Python
//! training corpus) and evaluation windowing.

pub mod csv;
pub mod synthetic;
pub mod windows;
pub mod workload;

pub use csv::{dataset_by_name_with_csv, load_csv_dataset};
pub use synthetic::{spec_by_name, specs, Dataset, DatasetSpec};
pub use windows::{eval_windows, eval_windows_balanced, Window};
pub use workload::{generate_trace, Scenario, TraceEvent};
