//! Web-workload trace generator — the paper's §1 deployment scenarios as
//! reproducible request traces for the load generator and capacity tests.
//!
//! Each scenario produces a deterministic sequence of (arrival offset,
//! forecast request shape) events with the arrival-process character the
//! intro describes: steady Poisson for recommendation ranking, diurnal
//! modulation for CDN traffic, bursty flash-crowds for ads/e-commerce.

use crate::util::rng::Rng;

/// One request event in a trace.
#[derive(Clone, Debug)]
pub struct TraceEvent {
    /// Arrival offset from trace start, seconds.
    pub at_s: f64,
    /// Dataset the history is drawn from.
    pub dataset: &'static str,
    /// Channel index (modulo the dataset's channels).
    pub channel: usize,
    /// History length in time steps.
    pub history_len: usize,
    /// Forecast horizon in patches.
    pub horizon: usize,
}

/// Scenario presets from the paper's introduction.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Scenario {
    /// §1(1): real-time content recommendation — steady high-rate Poisson,
    /// short horizons, tight latency budget (10-50 ms).
    Recommendation,
    /// §1(2): CDN/traffic optimization — diurnally modulated rate,
    /// minute-granularity forecasts, longer horizons.
    Cdn,
    /// §1(3): computational advertising — bursty arrivals (flash crowds on
    /// top of a base rate), very short horizons, <20 ms budget.
    Ads,
    /// §1(4): e-commerce demand — moderate rate, mixed horizons including
    /// long-range (pred-336) forecasts.
    Ecommerce,
}

impl Scenario {
    /// Lower-case scenario name (trace/report label).
    pub fn name(&self) -> &'static str {
        match self {
            Scenario::Recommendation => "recommendation",
            Scenario::Cdn => "cdn",
            Scenario::Ads => "ads",
            Scenario::Ecommerce => "ecommerce",
        }
    }

    /// Latency SLO the scenario motivates (paper §1), milliseconds.
    pub fn slo_ms(&self) -> f64 {
        match self {
            Scenario::Recommendation => 50.0,
            Scenario::Cdn => 200.0,
            Scenario::Ads => 20.0,
            Scenario::Ecommerce => 100.0,
        }
    }
}

/// Generate a deterministic trace of `n` events at mean rate `rps`.
pub fn generate_trace(scenario: Scenario, n: usize, rps: f64, seed: u64) -> Vec<TraceEvent> {
    let mut rng = Rng::new(seed ^ 0x7124_CE00);
    let mut t = 0.0f64;
    let mut out = Vec::with_capacity(n);
    for i in 0..n {
        // Arrival process.
        let rate = match scenario {
            Scenario::Recommendation => rps,
            // Diurnal modulation: +-60% sinusoid over a simulated day
            // compressed into the trace span.
            Scenario::Cdn => rps * (1.0 + 0.6 * (i as f64 / n as f64 * std::f64::consts::TAU).sin()),
            // Bursts: 10x rate with probability 5%.
            Scenario::Ads => {
                if rng.bernoulli(0.05) {
                    rps * 10.0
                } else {
                    rps
                }
            }
            Scenario::Ecommerce => rps,
        };
        t += rng.exponential(rate.max(1e-6));
        let (dataset, horizon) = match scenario {
            Scenario::Recommendation => ("etth1", 4),
            Scenario::Cdn => ("ettm2", if rng.bernoulli(0.3) { 14 } else { 4 }),
            Scenario::Ads => ("etth2", if rng.bernoulli(0.5) { 2 } else { 4 }),
            Scenario::Ecommerce => ("weather", if rng.bernoulli(0.2) { 14 } else { 8 }),
        };
        out.push(TraceEvent {
            at_s: t,
            dataset,
            channel: rng.below(32),
            history_len: 96,
            horizon,
        });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn traces_are_deterministic_and_ordered() {
        let a = generate_trace(Scenario::Recommendation, 100, 50.0, 1);
        let b = generate_trace(Scenario::Recommendation, 100, 50.0, 1);
        assert_eq!(a.len(), 100);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.at_s, y.at_s);
        }
        assert!(a.windows(2).all(|w| w[0].at_s <= w[1].at_s), "arrivals ordered");
    }

    #[test]
    fn mean_rate_approximates_target() {
        let tr = generate_trace(Scenario::Recommendation, 2000, 100.0, 2);
        let span = tr.last().unwrap().at_s;
        let rate = 2000.0 / span;
        assert!((rate - 100.0).abs() / 100.0 < 0.1, "rate {rate}");
    }

    #[test]
    fn ads_trace_is_burstier_than_recommendation() {
        // Squared coefficient of variation of inter-arrivals: bursty > Poisson.
        let cv2 = |tr: &[TraceEvent]| {
            let gaps: Vec<f64> = tr.windows(2).map(|w| w[1].at_s - w[0].at_s).collect();
            let m = gaps.iter().sum::<f64>() / gaps.len() as f64;
            let v = gaps.iter().map(|g| (g - m) * (g - m)).sum::<f64>() / gaps.len() as f64;
            v / (m * m)
        };
        let ads = generate_trace(Scenario::Ads, 3000, 100.0, 3);
        let rec = generate_trace(Scenario::Recommendation, 3000, 100.0, 3);
        assert!(cv2(&ads) > cv2(&rec), "ads {:.2} vs rec {:.2}", cv2(&ads), cv2(&rec));
    }

    #[test]
    fn scenario_request_shapes() {
        for s in [Scenario::Recommendation, Scenario::Cdn, Scenario::Ads, Scenario::Ecommerce] {
            let tr = generate_trace(s, 200, 50.0, 4);
            assert!(tr.iter().all(|e| e.history_len == 96));
            assert!(tr.iter().all(|e| e.horizon >= 1 && e.horizon <= 14));
            assert!(s.slo_ms() > 0.0);
        }
        // CDN mixes long horizons.
        let cdn = generate_trace(Scenario::Cdn, 500, 50.0, 5);
        assert!(cdn.iter().any(|e| e.horizon == 14));
    }
}
