//! CSV dataset loader: drop-in support for the *real* ETT/Weather CSVs.
//!
//! The paper's benchmarks are CSVs with a `date` column followed by value
//! columns (ETTh1.csv: date,HUFL,HULL,MUFL,MULL,LUFL,LULL,OT). This
//! environment has no network access so the synthetic generators stand in
//! (DESIGN.md §3), but when a user supplies the originals under
//! `$STRIDE_DATA/<name>.csv` the loader below produces a [`Dataset`] with
//! identical downstream semantics (train-split z-scoring, eval windowing),
//! making the substitution reversible.

use std::path::Path;

use anyhow::{bail, Context, Result};

use super::synthetic::{split_points, Dataset, DatasetSpec};

/// Parse a numeric CSV with a header row; the first column (timestamp) is
/// skipped. Returns column-major series `[channels][rows]`.
pub fn parse_csv(text: &str) -> Result<(Vec<String>, Vec<Vec<f64>>)> {
    let mut lines = text.lines().filter(|l| !l.trim().is_empty());
    let header = lines.next().context("empty CSV")?;
    let names: Vec<String> = header.split(',').skip(1).map(|s| s.trim().to_string()).collect();
    if names.is_empty() {
        bail!("CSV must have at least one value column after the date column");
    }
    let mut cols: Vec<Vec<f64>> = vec![Vec::new(); names.len()];
    for (lineno, line) in lines.enumerate() {
        let mut fields = line.split(',');
        let _date = fields.next();
        let mut count = 0;
        for (c, field) in fields.enumerate() {
            if c >= cols.len() {
                bail!("row {}: too many columns", lineno + 2);
            }
            let v: f64 = field
                .trim()
                .parse()
                .with_context(|| format!("row {}, column {}: bad number '{field}'", lineno + 2, c + 2))?;
            cols[c].push(v);
            count += 1;
        }
        if count != cols.len() {
            bail!("row {}: expected {} value columns, got {count}", lineno + 2, cols.len());
        }
    }
    if cols[0].is_empty() {
        bail!("CSV has no data rows");
    }
    Ok((names, cols))
}

/// Load `<dir>/<name>.csv` as a [`Dataset`] (train-split z-scoring, same
/// protocol as the synthetic path).
pub fn load_csv_dataset(dir: &Path, name: &str) -> Result<Dataset> {
    let path = dir.join(format!("{name}.csv"));
    let text = std::fs::read_to_string(&path)
        .with_context(|| format!("reading {}", path.display()))?;
    let (_names, raw) = parse_csv(&text)?;
    let length = raw[0].len();
    let channels = raw.len();
    let spec = DatasetSpec {
        name: Box::leak(name.to_string().into_boxed_str()),
        seed: 0,
        channels,
        length,
        periods: vec![],
        amps: vec![],
        ar_phi: 0.0,
        noise_std: 0.0,
        trend_per_k: 0.0,
        n_shifts: 0,
        shift_std: 0.0,
    };
    let (train_end, _) = split_points(length);
    let mut mean = Vec::with_capacity(channels);
    let mut std = Vec::with_capacity(channels);
    for ch in &raw {
        let m = ch[..train_end].iter().sum::<f64>() / train_end as f64;
        let v = ch[..train_end].iter().map(|x| (x - m) * (x - m)).sum::<f64>() / train_end as f64;
        mean.push(m);
        std.push(v.sqrt().max(1e-8));
    }
    Ok(Dataset { spec, raw, mean, std })
}

/// Resolve a dataset by name: real CSV (if `STRIDE_DATA` is set and the
/// file exists) takes precedence over the synthetic generator.
pub fn dataset_by_name_with_csv(name: &str) -> Option<Dataset> {
    if let Ok(dir) = std::env::var("STRIDE_DATA") {
        let dir = Path::new(&dir);
        if dir.join(format!("{name}.csv")).exists() {
            match load_csv_dataset(dir, name) {
                Ok(d) => return Some(d),
                Err(e) => log::warn!("CSV load failed for {name}: {e:#}; using synthetic"),
            }
        }
    }
    Dataset::by_name(name)
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
date,HUFL,OT
2016-07-01 00:00:00,5.827,30.531
2016-07-01 01:00:00,5.693,27.787
2016-07-01 02:00:00,5.157,27.787
2016-07-01 03:00:00,5.090,25.044
";

    #[test]
    fn parses_ett_shaped_csv() {
        let (names, cols) = parse_csv(SAMPLE).unwrap();
        assert_eq!(names, vec!["HUFL", "OT"]);
        assert_eq!(cols.len(), 2);
        assert_eq!(cols[0].len(), 4);
        assert!((cols[0][0] - 5.827).abs() < 1e-9);
        assert!((cols[1][3] - 25.044).abs() < 1e-9);
    }

    #[test]
    fn rejects_malformed() {
        assert!(parse_csv("").is_err());
        assert!(parse_csv("date,a\n2016,1.0,2.0\n").is_err()); // too many cols
        assert!(parse_csv("date,a,b\n2016,1.0\n").is_err()); // too few
        assert!(parse_csv("date,a\n2016,xyz\n").is_err()); // non-numeric
        assert!(parse_csv("date,a\n").is_err()); // header only
    }

    #[test]
    fn csv_dataset_roundtrip() {
        let dir = std::env::temp_dir().join("stride_csv_test");
        std::fs::create_dir_all(&dir).unwrap();
        // 100 rows so split_points produces a usable train split.
        let mut body = String::from("date,a,b\n");
        for i in 0..100 {
            body.push_str(&format!("t{i},{},{}\n", i as f64 * 0.1, (i as f64 * 0.2).sin()));
        }
        std::fs::write(dir.join("mini.csv"), body).unwrap();
        let d = load_csv_dataset(&dir, "mini").unwrap();
        assert_eq!(d.channels(), 2);
        assert_eq!(d.len(), 100);
        // Normalized train split has ~zero mean.
        let (train_end, _) = split_points(d.len());
        let m: f64 = (0..train_end).map(|t| d.norm(0, t) as f64).sum::<f64>() / train_end as f64;
        assert!(m.abs() < 1e-6);
    }

    #[test]
    fn env_fallback_to_synthetic() {
        // Without STRIDE_DATA the loader must serve synthetic datasets.
        let d = dataset_by_name_with_csv("etth1").unwrap();
        assert_eq!(d.channels(), 7);
        assert!(dataset_by_name_with_csv("nope").is_none());
    }
}
