//! Weight loading for the native backend: flat f32 LE blobs indexed by the
//! manifest's tensor table (written by `aot.dump_weights`).
//!
//! Tensors are stored behind `Arc` so the kernel layer's
//! [`crate::nn::kernel::PackedWeights`] can hold direct handles to the same
//! storage the string-keyed map owns — packing copies pointers, not floats,
//! and the map stays available for the reference (string-keyed) forward
//! path and for introspection.

use std::collections::HashMap;
use std::path::Path;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::util::json::Json;
use crate::util::mmap::MappedFile;
use crate::util::tensor::{Storage, Tensor};

/// Named tensor store.
///
/// Cloning is cheap: tensors live behind `Arc`, so a clone copies pointers
/// only — the replica pool uses this to give every serving replica its own
/// model stack over one shared float storage.
#[derive(Clone, Debug, Default)]
pub struct Weights {
    map: HashMap<String, Arc<Tensor>>,
}

impl Weights {
    /// Load from `blob_path` using the manifest's per-model `tensors` index
    /// (array of {name, shape, offset} with offsets in floats).
    pub fn load(blob_path: &Path, tensor_index: &Json) -> Result<Weights> {
        let bytes = std::fs::read(blob_path)
            .with_context(|| format!("reading weights blob {}", blob_path.display()))?;
        if bytes.len() % 4 != 0 {
            bail!("weights blob size {} not a multiple of 4", bytes.len());
        }
        let floats: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        let mut map = HashMap::new();
        for entry in parse_index(tensor_index, floats.len())? {
            let IndexEntry { name, shape, offset, numel } = entry;
            map.insert(
                name,
                Arc::new(Tensor::from_vec(&shape, floats[offset..offset + numel].to_vec())),
            );
        }
        Ok(Weights { map })
    }

    /// Load from `blob_path` without copying a float: the blob is mapped
    /// read-only (heap fallback on unsupported platforms, see
    /// [`crate::util::mmap::MMAP_SUPPORTED`]) and every tensor becomes a
    /// view into the shared mapping. Contents are bit-identical to
    /// [`Weights::load`] on the same inputs — the heap loader stays the
    /// reference the equivalence tests compare against.
    pub fn load_mapped(blob_path: &Path, tensor_index: &Json) -> Result<Weights> {
        let file = Arc::new(
            MappedFile::open(blob_path)
                .with_context(|| format!("mapping weights blob {}", blob_path.display()))?,
        );
        Weights::from_mapped(file, tensor_index)
    }

    /// Build a store over an already-opened mapping (the registry loader
    /// hashes the mapped bytes for digest verification first, then binds
    /// tensors to the same mapping — one open, zero float copies).
    pub fn from_mapped(file: Arc<MappedFile>, tensor_index: &Json) -> Result<Weights> {
        if file.len() % 4 != 0 {
            bail!("weights blob size {} not a multiple of 4", file.len());
        }
        let total_floats = file.len() / 4;
        let mut map = HashMap::new();
        for entry in parse_index(tensor_index, total_floats)? {
            let IndexEntry { name, shape, offset, numel } = entry;
            let byte_off = offset.checked_mul(4).context("tensor offset overflows")?;
            let storage = Storage::mapped(file.clone(), byte_off, numel)
                .map_err(anyhow::Error::msg)
                .with_context(|| format!("mapping tensor {name}"))?;
            let t = Tensor::from_storage(&shape, storage)
                .map_err(anyhow::Error::msg)
                .with_context(|| format!("shaping tensor {name}"))?;
            map.insert(name, Arc::new(t));
        }
        Ok(Weights { map })
    }

    /// Borrow a tensor by name (error when missing).
    pub fn get(&self, name: &str) -> Result<&Tensor> {
        self.map
            .get(name)
            .map(|t| t.as_ref())
            .with_context(|| format!("missing tensor {name}"))
    }

    /// Shared handle to a tensor (the kernel layer packs these once at
    /// model construction; no float is copied).
    pub fn get_arc(&self, name: &str) -> Result<Arc<Tensor>> {
        self.map
            .get(name)
            .cloned()
            .with_context(|| format!("missing tensor {name}"))
    }

    /// Number of named tensors.
    pub fn len(&self) -> usize {
        self.map.len()
    }

    /// Whether the store holds no tensors.
    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Total float count across all tensors.
    pub fn total_params(&self) -> usize {
        self.map.values().map(|t| t.numel()).sum()
    }

    /// Insert (for tests / synthetic weights).
    pub fn insert(&mut self, name: &str, t: Tensor) {
        self.map.insert(name.to_string(), Arc::new(t));
    }

    /// Tensor names in sorted order (deterministic iteration for packing
    /// and serialization).
    pub fn names(&self) -> Vec<String> {
        let mut names: Vec<String> = self.map.keys().cloned().collect();
        names.sort();
        names
    }
}

/// One parsed row of the manifest's tensor index.
struct IndexEntry {
    name: String,
    shape: Vec<usize>,
    /// Offset into the blob, in floats.
    offset: usize,
    numel: usize,
}

/// Parse and bounds-check the `{name, shape, offset}` index against a blob
/// of `total_floats` floats.
fn parse_index(tensor_index: &Json, total_floats: usize) -> Result<Vec<IndexEntry>> {
    let entries = tensor_index.as_arr().context("tensor index must be an array")?;
    let mut out = Vec::with_capacity(entries.len());
    for e in entries {
        let name = e.get("name").and_then(Json::as_str).context("tensor name")?;
        let offset = e.get("offset").and_then(Json::as_usize).context("tensor offset")?;
        let shape: Vec<usize> = e
            .get("shape")
            .and_then(Json::as_arr)
            .context("tensor shape")?
            .iter()
            .map(|v| v.as_usize().unwrap_or(0))
            .collect();
        let numel: usize = shape.iter().product();
        let end = offset.checked_add(numel).context("tensor extent overflows")?;
        if end > total_floats {
            bail!("tensor {name} [{offset}, {end}) exceeds blob len {total_floats}");
        }
        out.push(IndexEntry { name: name.to_string(), shape, offset, numel });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn load_roundtrip() {
        let dir = std::env::temp_dir().join("stride_weights_test");
        std::fs::create_dir_all(&dir).unwrap();
        let blob = dir.join("w.bin");
        let floats: Vec<f32> = (0..10).map(|i| i as f32).collect();
        let bytes: Vec<u8> = floats.iter().flat_map(|f| f.to_le_bytes()).collect();
        std::fs::write(&blob, bytes).unwrap();
        let index = Json::parse(
            r#"[{"name":"a","shape":[2,3],"offset":0},{"name":"b","shape":[4],"offset":6}]"#,
        )
        .unwrap();
        let w = Weights::load(&blob, &index).unwrap();
        assert_eq!(w.len(), 2);
        assert_eq!(w.get("a").unwrap().shape, vec![2, 3]);
        assert_eq!(w.get("a").unwrap().data, vec![0.0, 1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(w.get("b").unwrap().data, vec![6.0, 7.0, 8.0, 9.0]);
        assert_eq!(w.total_params(), 10);
        assert!(w.get("nope").is_err());
    }

    #[test]
    fn mapped_load_is_bitwise_identical_to_heap_load() {
        let dir = std::env::temp_dir().join("stride_weights_test_mapped");
        std::fs::create_dir_all(&dir).unwrap();
        let blob = dir.join("w.bin");
        // Include awkward bit patterns: negatives, subnormal, -0.0.
        let floats: Vec<f32> = vec![0.0, -0.0, 1.5, -2.25, 1.0e-40, 3.14159, -1.0, 42.0];
        let bytes: Vec<u8> = floats.iter().flat_map(|f| f.to_le_bytes()).collect();
        std::fs::write(&blob, bytes).unwrap();
        let index = Json::parse(
            r#"[{"name":"a","shape":[2,2],"offset":0},{"name":"b","shape":[4],"offset":4}]"#,
        )
        .unwrap();
        let heap = Weights::load(&blob, &index).unwrap();
        let mapped = Weights::load_mapped(&blob, &index).unwrap();
        assert_eq!(heap.names(), mapped.names());
        for name in heap.names() {
            let h = heap.get(&name).unwrap();
            let m = mapped.get(&name).unwrap();
            assert_eq!(h.shape, m.shape);
            let hb: Vec<u32> = h.data.iter().map(|v| v.to_bits()).collect();
            let mb: Vec<u32> = m.data.iter().map(|v| v.to_bits()).collect();
            assert_eq!(hb, mb, "tensor {name} differs between heap and mapped load");
            assert_eq!(m.data.is_mapped(), crate::util::mmap::MMAP_SUPPORTED);
        }
    }

    #[test]
    fn mapped_load_rejects_out_of_bounds() {
        let dir = std::env::temp_dir().join("stride_weights_test_mapped_oob");
        std::fs::create_dir_all(&dir).unwrap();
        let blob = dir.join("w.bin");
        std::fs::write(&blob, [0u8; 8]).unwrap();
        let index = Json::parse(r#"[{"name":"a","shape":[4],"offset":0}]"#).unwrap();
        assert!(Weights::load_mapped(&blob, &index).is_err());
    }

    #[test]
    fn rejects_out_of_bounds() {
        let dir = std::env::temp_dir().join("stride_weights_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let blob = dir.join("w.bin");
        std::fs::write(&blob, [0u8; 8]).unwrap();
        let index =
            Json::parse(r#"[{"name":"a","shape":[4],"offset":0}]"#).unwrap();
        assert!(Weights::load(&blob, &index).is_err());
    }
}
