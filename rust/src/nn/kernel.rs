//! The native backend's kernel layer: packed weights, a reusable forward
//! arena, and the per-block compute kernels the Timer-style forward is
//! assembled from.
//!
//! Three ideas (see `models/README.md` § kernel layer):
//!
//! * **Packed weights** — [`PackedWeights::pack`] resolves every
//!   string-keyed tensor lookup (`format!("layers.{li}.wqkv")` + hashmap
//!   probe, 24 sites per forward before this layer existed) exactly once
//!   at model construction into direct `Arc<Tensor>` handles: one
//!   [`LayerWeights`] per decoder layer plus the embed/pos/head tensors.
//!   The hot loop indexes a `Vec`, never a map.
//! * **Scratch arena** — [`ForwardScratch`] owns every intermediate buffer
//!   one forward needs (activations, qkv, attention scores, MLP gate/up/
//!   down, output rows), sized once. The KV-cached decode path stores the
//!   arena inside the cache, so a steady-state `extend` performs **zero
//!   heap allocations** (pinned by `tests/alloc_discipline.rs`).
//! * **Slice kernels** — the block functions below ([`embed_tokens`],
//!   [`qkv_rows`], [`append_kv`], [`attn_rows`] and its split-store twin
//!   [`attn_rows_split`], [`proj_residual_rows`], [`mlp_rows`],
//!   [`head_rows`], plus the batched [`matmul_stacked`] entry that folds B
//!   same-shape blocks into one GEMM) operate on flat `&[f32]` row buffers
//!   and
//!   are shared verbatim by the stateless batched forward and the
//!   incremental cached forward, which is what keeps the two paths equal
//!   row-for-row (the cache-equivalence invariant from the decode-session
//!   PR). Matmuls dispatch through [`crate::util::tensor::matmul_auto`]:
//!   serial for decode-sized row counts, row-partitioned across the shared
//!   pool for prefill-sized ones — bitwise identical either way.
//!
//! To add a new kernel: take `rows` plus flat slices, assert lengths,
//! write only into caller-provided scratch, and keep per-row arithmetic
//! independent of `rows` so cached/stateless equality and thread-count
//! determinism hold by construction.

use std::sync::Arc;

use anyhow::{ensure, Result};

use super::model::ModelDims;
use super::weights::Weights;
use crate::util::tensor::{matmul_auto, rmsnorm, silu, softmax_row, Tensor};

/// RMSNorm epsilon (matches the JAX side; re-exported via `model`).
pub(crate) const RMS_EPS: f32 = 1e-6;

/// Direct handles to one decoder layer's tensors.
#[derive(Clone)]
pub struct LayerWeights {
    /// Pre-attention RMSNorm scale, `[d_model]`.
    pub ln1: Arc<Tensor>,
    /// Fused QKV projection, `[d_model, 3*d_model]`.
    pub wqkv: Arc<Tensor>,
    /// Attention output projection, `[d_model, d_model]`.
    pub wo: Arc<Tensor>,
    /// Pre-MLP RMSNorm scale, `[d_model]`.
    pub ln2: Arc<Tensor>,
    /// SwiGLU gate projection, `[d_model, d_ff]`.
    pub wg: Arc<Tensor>,
    /// SwiGLU up projection, `[d_model, d_ff]`.
    pub wu: Arc<Tensor>,
    /// SwiGLU down projection, `[d_ff, d_model]`.
    pub wd: Arc<Tensor>,
}

/// All weight handles a forward needs, resolved once at construction.
#[derive(Clone)]
pub struct PackedWeights {
    /// Patch embedding, `[patch, d_model]`.
    pub embed_w: Arc<Tensor>,
    /// Patch embedding bias, `[d_model]`.
    pub embed_b: Arc<Tensor>,
    /// Learned absolute position table, `[n_ctx, d_model]`.
    pub pos: Arc<Tensor>,
    /// Per-layer handles, in order.
    pub layers: Vec<LayerWeights>,
    /// Final RMSNorm scale, `[d_model]`.
    pub final_norm: Arc<Tensor>,
    /// Output head, `[d_model, patch]`.
    pub head_w: Arc<Tensor>,
    /// Output head bias, `[patch]`.
    pub head_b: Arc<Tensor>,
}

impl PackedWeights {
    /// Resolve and shape-check every tensor against `dims`. Fails early
    /// (at load time) on a missing or mis-shaped tensor instead of deep in
    /// a decode loop.
    pub fn pack(dims: &ModelDims, w: &Weights) -> Result<PackedWeights> {
        let (p, d, f) = (dims.patch, dims.d_model, dims.d_ff);
        let want = |t: &Arc<Tensor>, shape: &[usize], name: &str| -> Result<()> {
            ensure!(
                t.shape == shape,
                "tensor {name}: shape {:?} != expected {:?}",
                t.shape,
                shape
            );
            Ok(())
        };
        let embed_w = w.get_arc("embed_w")?;
        want(&embed_w, &[p, d], "embed_w")?;
        let embed_b = w.get_arc("embed_b")?;
        want(&embed_b, &[d], "embed_b")?;
        let pos = w.get_arc("pos")?;
        want(&pos, &[dims.n_ctx, d], "pos")?;
        let mut layers = Vec::with_capacity(dims.n_layers);
        for li in 0..dims.n_layers {
            let lw = LayerWeights {
                ln1: w.get_arc(&format!("layers.{li}.ln1"))?,
                wqkv: w.get_arc(&format!("layers.{li}.wqkv"))?,
                wo: w.get_arc(&format!("layers.{li}.wo"))?,
                ln2: w.get_arc(&format!("layers.{li}.ln2"))?,
                wg: w.get_arc(&format!("layers.{li}.wg"))?,
                wu: w.get_arc(&format!("layers.{li}.wu"))?,
                wd: w.get_arc(&format!("layers.{li}.wd"))?,
            };
            want(&lw.ln1, &[d], "ln1")?;
            want(&lw.wqkv, &[d, 3 * d], "wqkv")?;
            want(&lw.wo, &[d, d], "wo")?;
            want(&lw.ln2, &[d], "ln2")?;
            want(&lw.wg, &[d, f], "wg")?;
            want(&lw.wu, &[d, f], "wu")?;
            want(&lw.wd, &[f, d], "wd")?;
            layers.push(lw);
        }
        let final_norm = w.get_arc("final_norm")?;
        want(&final_norm, &[d], "final_norm")?;
        let head_w = w.get_arc("head_w")?;
        want(&head_w, &[d, p], "head_w")?;
        let head_b = w.get_arc("head_b")?;
        want(&head_b, &[p], "head_b")?;
        Ok(PackedWeights { embed_w, embed_b, pos, layers, final_norm, head_w, head_b })
    }
}

/// Reusable per-forward buffers, sized once for up to `rows` activation
/// rows. The KV-cached path owns one inside the `KvCache` (rows = n_ctx,
/// the prefill worst case) so steady-state decode never allocates; the
/// stateless path builds one per call (rows = b·n).
pub struct ForwardScratch {
    /// Row capacity this arena was sized for.
    rows: usize,
    /// Activations `[rows, d]` — the residual stream.
    pub(crate) x: Vec<f32>,
    /// Pre-norm copy `[rows, d]` (attn and MLP reuse it in turn).
    pub(crate) normed: Vec<f32>,
    /// QKV projection `[rows, 3d]`.
    pub(crate) qkv: Vec<f32>,
    /// Attention head concat `[rows, d]`.
    pub(crate) concat: Vec<f32>,
    /// Output projection `[rows, d]`.
    pub(crate) proj: Vec<f32>,
    /// MLP gate / up `[rows, d_ff]`, down `[rows, d]`.
    pub(crate) gate: Vec<f32>,
    pub(crate) up: Vec<f32>,
    pub(crate) down: Vec<f32>,
    /// One attention score row `[n_ctx]`.
    pub(crate) scores: Vec<f32>,
    /// Per-sequence K/V gather for the *stateless* path `[n_ctx, d]`
    /// (the cached path reads the KvCache ring buffers instead).
    pub(crate) kbuf: Vec<f32>,
    pub(crate) vbuf: Vec<f32>,
    /// Model output `[rows, patch]`.
    pub(crate) out: Vec<f32>,
}

/// Most stacked lanes (tree branches / lockstep sequences) one
/// `forward_cached_stacked` call can carry. Matches `specdec`'s
/// `MAX_TREE_K` so every admissible tree round fits; requests beyond it
/// get a typed error from [`matmul_stacked`]/the stacked forward (pinned
/// by `tests/fuzz_lite.rs`), never UB or a panic.
pub const MAX_STACK_LANES: usize = 16;

/// Stacked batched GEMM: treat `batch` contiguous `[m, k]` blocks of A as
/// one `[batch*m, k] x [k, n]` call — the enabler for verifying k tree
/// branches (or B lockstep sequences) in ONE target forward instead of
/// B narrow ones. Because every GEMM output row depends only on its own A
/// row and all of B, the stacked result is **bitwise identical** to
/// looping `matmul` over the blocks (pinned by
/// `tests/kernel_equivalence.rs`), and large stacks still ride
/// `matmul_auto`'s row-parallel + tiled dispatch.
///
/// Unlike the asserting [`crate::util::tensor::matmul`], shape mismatches
/// here return typed errors: the stacked entry sits on the serving path
/// (tree verify under the PR 7 replica supervisor), where a fuzzable
/// mis-size must surface as `Err`, not a panic.
pub fn matmul_stacked(
    a: &[f32],
    b: &[f32],
    batch: usize,
    m: usize,
    k: usize,
    n: usize,
    c: &mut [f32],
) -> Result<()> {
    ensure!(batch >= 1, "matmul_stacked: batch must be >= 1");
    ensure!(m >= 1 && k >= 1 && n >= 1, "matmul_stacked: zero-dim shape ({m}, {k}, {n})");
    let rows = batch
        .checked_mul(m)
        .filter(|r| r.checked_mul(k).is_some() && r.checked_mul(n).is_some())
        .ok_or_else(|| anyhow::anyhow!("matmul_stacked: batch*m overflows ({batch} x {m})"))?;
    ensure!(
        a.len() == rows * k,
        "matmul_stacked: A has {} elems, want batch*m*k = {}",
        a.len(),
        rows * k
    );
    ensure!(b.len() == k * n, "matmul_stacked: B has {} elems, want k*n = {}", b.len(), k * n);
    ensure!(
        c.len() == rows * n,
        "matmul_stacked: C has {} elems, want batch*m*n = {}",
        c.len(),
        rows * n
    );
    matmul_auto(a, b, rows, k, n, c);
    Ok(())
}

/// Largest `k` a steady-state decode read can carry: `SpecConfig::gamma`
/// is capped at 64 (`config::ServeConfig::validate`), so a session sees
/// extends of at most γ proposals and appends of at most γ+1 emitted
/// patches. The cache-owned arena is sized for this, not for a
/// full-context prefill — prefill-sized calls borrow a temporary arena
/// instead (they are allowed to allocate; only steady state is pinned
/// allocation-free).
pub const MAX_DECODE_ROWS: usize = 64;

impl ForwardScratch {
    /// Arena for the *stateless* path: includes the per-sequence K/V
    /// gather buffers its attention reads.
    pub fn new(dims: &ModelDims, rows: usize) -> ForwardScratch {
        Self::build(dims, rows, rows, true)
    }

    /// Persistent arena for the *cached* path (owned by the `KvCache`).
    /// Intermediates are sized for [`MAX_DECODE_ROWS`] (the steady-state
    /// worst case), not `n_ctx` — at production dims full-context
    /// `gate`/`up`/`qkv` rows would dwarf the K/V cache itself and sit
    /// ~n_ctx/γ× oversized after the one prefill. The `out` buffer alone
    /// is `n_ctx` rows (patch-sized, tiny) so prefill results written via
    /// a temporary arena can still be returned from cache-owned storage.
    /// The stateless K/V gather buffers are not allocated at all —
    /// attention reads the cache's ring buffers.
    pub fn for_cached(dims: &ModelDims) -> ForwardScratch {
        Self::build(dims, MAX_DECODE_ROWS.min(dims.n_ctx), dims.n_ctx, false)
    }

    /// Temporary arena for a prefill-sized cached call
    /// (`k > capacity_rows()` of the persistent arena).
    pub fn for_prefill(dims: &ModelDims, rows: usize) -> ForwardScratch {
        Self::build(dims, rows, rows, false)
    }

    fn build(dims: &ModelDims, rows: usize, out_rows: usize, kv_gather: bool) -> ForwardScratch {
        let d = dims.d_model;
        let f = dims.d_ff;
        let kv = if kv_gather { dims.n_ctx * d } else { 0 };
        ForwardScratch {
            rows,
            x: vec![0.0; rows * d],
            normed: vec![0.0; rows * d],
            qkv: vec![0.0; rows * 3 * d],
            concat: vec![0.0; rows * d],
            proj: vec![0.0; rows * d],
            gate: vec![0.0; rows * f],
            up: vec![0.0; rows * f],
            down: vec![0.0; rows * d],
            scores: vec![0.0; dims.n_ctx],
            kbuf: vec![0.0; kv],
            vbuf: vec![0.0; kv],
            out: vec![0.0; out_rows * dims.patch],
        }
    }

    /// Row capacity (callers assert their `rows <= capacity`).
    pub fn capacity_rows(&self) -> usize {
        self.rows
    }
}

// ---------------------------------------------------------------------------
// Block kernels. All operate on flat row buffers, allocate nothing, and
// keep per-row arithmetic independent of how many rows are processed
// together (the cached/stateless equality invariant).
// ---------------------------------------------------------------------------

/// Patch embedding: `tokens [rows, p] x embed_w [p, d] + embed_b -> x`.
pub fn embed_tokens(pw: &PackedWeights, tokens: &[f32], rows: usize, p: usize, d: usize, x: &mut [f32]) {
    matmul_auto(&tokens[..rows * p], &pw.embed_w.data, rows, p, d, &mut x[..rows * d]);
    let bias = &pw.embed_b.data;
    for r in 0..rows {
        for (v, bv) in x[r * d..(r + 1) * d].iter_mut().zip(bias) {
            *v += bv;
        }
    }
}

/// Add learned absolute positions `n0..n0+rows` to `rows` activation rows.
pub fn add_pos(pw: &PackedWeights, d: usize, n0: usize, rows: usize, x: &mut [f32]) {
    let pos = &pw.pos.data;
    for t in 0..rows {
        let row = &mut x[t * d..(t + 1) * d];
        for (v, pv) in row.iter_mut().zip(&pos[(n0 + t) * d..(n0 + t + 1) * d]) {
            *v += pv;
        }
    }
}

/// Pre-norm + QKV projection: `normed = rmsnorm(x, ln1)`, `qkv = normed x
/// wqkv` (`[rows, 3d]`, per-token layout `[q | k | v]`, heads contiguous).
pub fn qkv_rows(lw: &LayerWeights, x: &[f32], rows: usize, d: usize, normed: &mut [f32], qkv: &mut [f32]) {
    normed[..rows * d].copy_from_slice(&x[..rows * d]);
    rmsnorm(&mut normed[..rows * d], &lw.ln1.data, RMS_EPS);
    matmul_auto(&normed[..rows * d], &lw.wqkv.data, rows, d, 3 * d, &mut qkv[..rows * 3 * d]);
}

/// Append the K/V parts of `rows` qkv rows into `[n, d]` row buffers at
/// absolute positions `n0..n0+rows` (heads contiguous, the cache layout).
pub fn append_kv(qkv: &[f32], rows: usize, d: usize, n0: usize, kbuf: &mut [f32], vbuf: &mut [f32]) {
    for t in 0..rows {
        let base = t * 3 * d;
        kbuf[(n0 + t) * d..(n0 + t + 1) * d].copy_from_slice(&qkv[base + d..base + 2 * d]);
        vbuf[(n0 + t) * d..(n0 + t + 1) * d].copy_from_slice(&qkv[base + 2 * d..base + 3 * d]);
    }
}

/// Causal attention for `rows` new rows at absolute positions
/// `n0..n0+rows` over K/V row buffers that already contain positions
/// `0..n0+rows` (call [`append_kv`] first so a row can see itself). Writes
/// head-concatenated outputs into `concat [rows, d]`; `scores` is one
/// reusable `[>= n0+rows]` row.
#[allow(clippy::too_many_arguments)]
pub fn attn_rows(
    qkv: &[f32],
    kbuf: &[f32],
    vbuf: &[f32],
    n0: usize,
    rows: usize,
    h: usize,
    dh: usize,
    scale: f32,
    scores: &mut [f32],
    concat: &mut [f32],
) {
    let d = h * dh;
    for t in 0..rows {
        let g = n0 + t;
        for hi in 0..h {
            let q = &qkv[t * 3 * d + hi * dh..t * 3 * d + hi * dh + dh];
            let srow = &mut scores[..=g];
            for (j, sv) in srow.iter_mut().enumerate() {
                let krow = &kbuf[j * d + hi * dh..j * d + hi * dh + dh];
                *sv = q.iter().zip(krow).map(|(a, c)| a * c).sum::<f32>() * scale;
            }
            softmax_row(srow);
            let orow = &mut concat[t * d + hi * dh..t * d + hi * dh + dh];
            orow.fill(0.0);
            for (j, &wj) in srow.iter().enumerate() {
                let vrow = &vbuf[j * d + hi * dh..j * d + hi * dh + dh];
                for (o, vv) in orow.iter_mut().zip(vrow) {
                    *o += wj * vv;
                }
            }
        }
    }
}

/// [`attn_rows`] over a **split** K/V store: positions `0..n0` read the
/// shared-prefix cache rows (`kpre`/`vpre`, untouched — they come in
/// behind `&`), positions `n0..n0+rows` read a per-lane scratch buffer
/// (`klane`/`vlane`, rows `0..rows`). This is how one stacked forward
/// verifies k branch suffixes against ONE committed prefix without
/// copying or mutating the cache: each branch appends its K/V to its own
/// disjoint lane. Per (row, head, j) the arithmetic — dot, scale,
/// softmax, weighted-V accumulation in ascending j — is line-for-line
/// [`attn_rows`] with the row source switched at `n0`, so the output is
/// bitwise identical to having appended the lane rows into the cache
/// (the sequential verify path).
#[allow(clippy::too_many_arguments)]
pub fn attn_rows_split(
    qkv: &[f32],
    kpre: &[f32],
    vpre: &[f32],
    klane: &[f32],
    vlane: &[f32],
    n0: usize,
    rows: usize,
    h: usize,
    dh: usize,
    scale: f32,
    scores: &mut [f32],
    concat: &mut [f32],
) {
    let d = h * dh;
    for t in 0..rows {
        let g = n0 + t;
        for hi in 0..h {
            let q = &qkv[t * 3 * d + hi * dh..t * 3 * d + hi * dh + dh];
            let srow = &mut scores[..=g];
            for (j, sv) in srow.iter_mut().enumerate() {
                let krow = if j < n0 {
                    &kpre[j * d + hi * dh..j * d + hi * dh + dh]
                } else {
                    let jl = j - n0;
                    &klane[jl * d + hi * dh..jl * d + hi * dh + dh]
                };
                *sv = q.iter().zip(krow).map(|(a, c)| a * c).sum::<f32>() * scale;
            }
            softmax_row(srow);
            let orow = &mut concat[t * d + hi * dh..t * d + hi * dh + dh];
            orow.fill(0.0);
            for (j, &wj) in srow.iter().enumerate() {
                let vrow = if j < n0 {
                    &vpre[j * d + hi * dh..j * d + hi * dh + dh]
                } else {
                    let jl = j - n0;
                    &vlane[jl * d + hi * dh..jl * d + hi * dh + dh]
                };
                for (o, vv) in orow.iter_mut().zip(vrow) {
                    *o += wj * vv;
                }
            }
        }
    }
}

/// Attention output projection plus residual: `x += concat x wo`.
pub fn proj_residual_rows(
    lw: &LayerWeights,
    concat: &[f32],
    rows: usize,
    d: usize,
    proj: &mut [f32],
    x: &mut [f32],
) {
    matmul_auto(&concat[..rows * d], &lw.wo.data, rows, d, d, &mut proj[..rows * d]);
    for (xv, pv) in x[..rows * d].iter_mut().zip(&proj[..rows * d]) {
        *xv += pv;
    }
}

/// Gated MLP block with residual: `x += silu(norm x wg) * (norm x wu) x wd`.
#[allow(clippy::too_many_arguments)]
pub fn mlp_rows(
    lw: &LayerWeights,
    x: &mut [f32],
    rows: usize,
    d: usize,
    f: usize,
    normed: &mut [f32],
    gate: &mut [f32],
    up: &mut [f32],
    down: &mut [f32],
) {
    normed[..rows * d].copy_from_slice(&x[..rows * d]);
    rmsnorm(&mut normed[..rows * d], &lw.ln2.data, RMS_EPS);
    matmul_auto(&normed[..rows * d], &lw.wg.data, rows, d, f, &mut gate[..rows * f]);
    matmul_auto(&normed[..rows * d], &lw.wu.data, rows, d, f, &mut up[..rows * f]);
    for (gv, uv) in gate[..rows * f].iter_mut().zip(&up[..rows * f]) {
        *gv = silu(*gv) * uv;
    }
    matmul_auto(&gate[..rows * f], &lw.wd.data, rows, f, d, &mut down[..rows * d]);
    for (xv, dv) in x[..rows * d].iter_mut().zip(&down[..rows * d]) {
        *xv += dv;
    }
}

/// Final norm + output head: `out = rmsnorm(x, final_norm) x head_w +
/// head_b` (`[rows, p]`). Mutates `x` in place (last use in a forward).
pub fn head_rows(pw: &PackedWeights, x: &mut [f32], rows: usize, d: usize, p: usize, out: &mut [f32]) {
    rmsnorm(&mut x[..rows * d], &pw.final_norm.data, RMS_EPS);
    matmul_auto(&x[..rows * d], &pw.head_w.data, rows, d, p, &mut out[..rows * p]);
    let bias = &pw.head_b.data;
    for r in 0..rows {
        for (v, bv) in out[r * p..(r + 1) * p].iter_mut().zip(bias) {
            *v += bv;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parallel_threshold_excludes_steady_state_decode() {
        // matmul_auto must never take the (allocating) pool path for a
        // decode-sized row count, or forward_cached's zero-allocation
        // guarantee breaks for large γ.
        assert!(crate::util::tensor::PAR_MIN_ROWS > MAX_DECODE_ROWS);
    }

    #[test]
    fn pack_rejects_missing_and_misshaped() {
        let dims = ModelDims { patch: 2, n_ctx: 4, d_model: 4, n_layers: 1, n_heads: 2, d_ff: 8 };
        let mut w = Weights::default();
        assert!(PackedWeights::pack(&dims, &w).is_err(), "empty weights must not pack");
        w.insert("embed_w", Tensor::zeros(&[2, 5])); // wrong d
        assert!(PackedWeights::pack(&dims, &w).is_err());
    }

    #[test]
    fn scratch_sized_for_rows() {
        let dims = ModelDims { patch: 3, n_ctx: 8, d_model: 4, n_layers: 2, n_heads: 2, d_ff: 6 };
        let s = ForwardScratch::new(&dims, 5);
        assert_eq!(s.capacity_rows(), 5);
        assert_eq!(s.x.len(), 5 * 4);
        assert_eq!(s.qkv.len(), 5 * 12);
        assert_eq!(s.gate.len(), 5 * 6);
        assert_eq!(s.scores.len(), 8);
        assert_eq!(s.kbuf.len(), 8 * 4);
        assert_eq!(s.out.len(), 5 * 3);
    }

    #[test]
    fn cached_scratch_skips_stateless_gather_buffers() {
        let dims = ModelDims { patch: 3, n_ctx: 8, d_model: 4, n_layers: 2, n_heads: 2, d_ff: 6 };
        let s = ForwardScratch::for_cached(&dims);
        assert_eq!(s.capacity_rows(), 8, "capped at n_ctx when n_ctx < MAX_DECODE_ROWS");
        assert_eq!(s.kbuf.len(), 0, "cached path reads the KvCache ring buffers");
        assert_eq!(s.vbuf.len(), 0);
        assert_eq!(s.x.len(), 8 * 4);
    }

    #[test]
    fn stacked_matmul_matches_looped_and_types_errors() {
        let mut rng = crate::util::rng::Rng::new(41);
        let (batch, m, k, n) = (3usize, 2usize, 5usize, 4usize);
        let a: Vec<f32> = (0..batch * m * k).map(|_| rng.normal() as f32).collect();
        let b: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32).collect();
        let mut stacked = vec![0.0; batch * m * n];
        matmul_stacked(&a, &b, batch, m, k, n, &mut stacked).unwrap();
        for bi in 0..batch {
            let mut single = vec![0.0; m * n];
            crate::util::tensor::matmul(&a[bi * m * k..(bi + 1) * m * k], &b, m, k, n, &mut single);
            for (i, (x, y)) in single.iter().zip(&stacked[bi * m * n..(bi + 1) * m * n]).enumerate() {
                assert_eq!(x.to_bits(), y.to_bits(), "stacked drift at block {bi} elem {i}");
            }
        }
        // Typed errors, not panics.
        assert!(matmul_stacked(&a, &b, 0, m, k, n, &mut stacked).is_err(), "batch 0");
        assert!(matmul_stacked(&a, &b, batch, 0, k, n, &mut stacked).is_err(), "zero dim");
        assert!(matmul_stacked(&a[1..], &b, batch, m, k, n, &mut stacked).is_err(), "short A");
        assert!(matmul_stacked(&a, &b[1..], batch, m, k, n, &mut stacked).is_err(), "short B");
        assert!(matmul_stacked(&a, &b, batch, m, k, n, &mut stacked[1..]).is_err(), "short C");
        assert!(matmul_stacked(&a, &b, usize::MAX, 2, k, n, &mut stacked).is_err(), "overflow");
    }

    #[test]
    fn split_attention_bitwise_equals_contiguous() {
        // attn_rows over [prefix | lane] appended contiguously must equal
        // attn_rows_split reading the two stores separately.
        let mut rng = crate::util::rng::Rng::new(43);
        let (h, dh, n0, rows) = (2usize, 3usize, 4usize, 3usize);
        let d = h * dh;
        let qkv: Vec<f32> = (0..rows * 3 * d).map(|_| rng.normal() as f32).collect();
        let kall: Vec<f32> = (0..(n0 + rows) * d).map(|_| rng.normal() as f32).collect();
        let vall: Vec<f32> = (0..(n0 + rows) * d).map(|_| rng.normal() as f32).collect();
        let mut scores = vec![0.0; n0 + rows];
        let mut c0 = vec![0.0; rows * d];
        let mut c1 = vec![0.0; rows * d];
        attn_rows(&qkv, &kall, &vall, n0, rows, h, dh, 0.5, &mut scores, &mut c0);
        attn_rows_split(
            &qkv,
            &kall[..n0 * d],
            &vall[..n0 * d],
            &kall[n0 * d..],
            &vall[n0 * d..],
            n0,
            rows,
            h,
            dh,
            0.5,
            &mut scores,
            &mut c1,
        );
        for (i, (x, y)) in c0.iter().zip(&c1).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "split attention drift at {i}");
        }
    }

    #[test]
    fn cached_scratch_sized_for_decode_not_prefill() {
        // Long contexts: intermediates stop at MAX_DECODE_ROWS; only the
        // (patch-sized) out buffer spans n_ctx so prefill results can be
        // returned from cache-owned storage.
        let dims =
            ModelDims { patch: 3, n_ctx: 256, d_model: 4, n_layers: 2, n_heads: 2, d_ff: 16 };
        let s = ForwardScratch::for_cached(&dims);
        assert_eq!(s.capacity_rows(), MAX_DECODE_ROWS);
        assert_eq!(s.gate.len(), MAX_DECODE_ROWS * 16);
        assert_eq!(s.out.len(), 256 * 3, "out must hold a full prefill's rows");
        let t = ForwardScratch::for_prefill(&dims, 200);
        assert_eq!(t.capacity_rows(), 200);
        assert_eq!(t.kbuf.len(), 0);
    }
}
