//! Native (pure-Rust) neural network substrate: weight loading, the
//! kernel layer (packed weights + scratch arena + block kernels), and the
//! Timer-style decoder forward, mirroring `python/compile/model.py`.

pub mod kernel;
pub mod model;
pub mod weights;

pub use kernel::{ForwardScratch, LayerWeights, PackedWeights};
pub use model::{KvCache, ModelDims, NativeModel, StackedLanes};
pub use weights::Weights;
