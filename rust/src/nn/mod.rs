//! Native (pure-Rust) neural network substrate: weight loading and the
//! Timer-style decoder forward, mirroring `python/compile/model.py`.

pub mod model;
pub mod weights;

pub use model::{KvCache, ModelDims, NativeModel};
pub use weights::Weights;
