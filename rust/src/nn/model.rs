//! Native Rust forward pass of the Timer-style decoder — an exact mirror of
//! `python/compile/model.py::forward` (fused-attention variant).
//!
//! Role in the system (DESIGN.md §4): (i) the CPU reference comparator the
//! paper baselines against, (ii) a PJRT-free backend for tests/benches, and
//! (iii) the parity check proving the HLO artifacts compute the same
//! function (`rust/tests/xla_integration.rs` asserts native == XLA == JAX
//! golden within fp tolerance).
//!
//! Two forward paths share the same math:
//! * [`NativeModel::forward`] — stateless, recomputes attention over the
//!   whole context (O(n²·d) per call).
//! * [`NativeModel::forward_cached`] — incremental over a [`KvCache`]:
//!   only the appended rows are computed (O(k·n·d) per call), which is what
//!   turns a speculative round from O(n²·d) into O(γ·n·d). The op order is
//!   identical row-for-row, so the two paths agree to float equality
//!   (pinned by `rust/tests/cache_equivalence.rs`).

use anyhow::Result;

use super::weights::Weights;
use crate::util::rng::Rng;
use crate::util::tensor::{linear, matmul, rmsnorm, silu, softmax_row, Tensor};

/// Architecture dims (mirror of model.ModelConfig; parsed from the manifest).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ModelDims {
    pub patch: usize,
    pub n_ctx: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
}

impl ModelDims {
    pub fn d_head(&self) -> usize {
        self.d_model / self.n_heads
    }
}

const RMS_EPS: f32 = 1e-6;

/// A loaded native model.
pub struct NativeModel {
    pub dims: ModelDims,
    pub name: String,
    w: Weights,
}

impl NativeModel {
    pub fn new(name: &str, dims: ModelDims, weights: Weights) -> NativeModel {
        NativeModel { dims, name: name.to_string(), w: weights }
    }

    /// Seeded random-weight model (no artifacts needed): the substrate for
    /// the cache-equivalence test suite and the `perf_hotpath` cached sweep,
    /// where analytic heads would be too trivial to exercise attention.
    /// Projections are scaled by 1/sqrt(fan_in) so activations stay sane at
    /// bench-sized dims.
    pub fn random(name: &str, dims: ModelDims, seed: u64) -> NativeModel {
        let mut w = Weights::default();
        let mut rng = Rng::new(seed);
        let mut t = |shape: &[usize], scale: f32| {
            let n: usize = shape.iter().product();
            Tensor::from_vec(shape, (0..n).map(|_| scale * rng.normal() as f32).collect())
        };
        let (p, d, f) = (dims.patch, dims.d_model, dims.d_ff);
        let s_p = 0.5 / (p as f32).sqrt();
        let s_d = 0.5 / (d as f32).sqrt();
        let s_f = 0.5 / (f as f32).sqrt();
        w.insert("embed_w", t(&[p, d], s_p));
        w.insert("embed_b", Tensor::zeros(&[d]));
        w.insert("pos", t(&[dims.n_ctx, d], 0.1));
        for li in 0..dims.n_layers {
            w.insert(&format!("layers.{li}.ln1"), Tensor::from_vec(&[d], vec![1.0; d]));
            w.insert(&format!("layers.{li}.wqkv"), t(&[d, 3 * d], s_d));
            w.insert(&format!("layers.{li}.wo"), t(&[d, d], s_d));
            w.insert(&format!("layers.{li}.ln2"), Tensor::from_vec(&[d], vec![1.0; d]));
            w.insert(&format!("layers.{li}.wg"), t(&[d, f], s_d));
            w.insert(&format!("layers.{li}.wu"), t(&[d, f], s_d));
            w.insert(&format!("layers.{li}.wd"), t(&[f, d], s_f));
        }
        w.insert("final_norm", Tensor::from_vec(&[d], vec![1.0; d]));
        w.insert("head_w", t(&[d, p], s_d));
        w.insert("head_b", Tensor::zeros(&[p]));
        NativeModel::new(name, dims, w)
    }

    /// tokens [B, N, P] -> next-patch means [B, N, P]; N <= n_ctx.
    pub fn forward(&self, tokens: &Tensor) -> Result<Tensor> {
        let (b, n, p) = (tokens.shape[0], tokens.shape[1], tokens.shape[2]);
        anyhow::ensure!(p == self.dims.patch, "patch dim {p} != {}", self.dims.patch);
        anyhow::ensure!(n <= self.dims.n_ctx, "N {n} > n_ctx {}", self.dims.n_ctx);
        let d = self.dims.d_model;

        // Patch embedding + learned positions.
        let mut x = linear(tokens, self.w.get("embed_w")?, Some(&self.w.get("embed_b")?.data));
        let pos = self.w.get("pos")?;
        for bi in 0..b {
            for t in 0..n {
                let row = &mut x.data[(bi * n + t) * d..(bi * n + t + 1) * d];
                for (v, pv) in row.iter_mut().zip(&pos.data[t * d..(t + 1) * d]) {
                    *v += pv;
                }
            }
        }

        let mut scratch = Scratch::new(&self.dims, b, n);
        for li in 0..self.dims.n_layers {
            self.attn_block(li, &mut x, b, n, &mut scratch)?;
            self.mlp_block(li, &mut x, b, n)?;
        }

        rmsnorm(&mut x.data, &self.w.get("final_norm")?.data, RMS_EPS);
        Ok(linear(&x, self.w.get("head_w")?, Some(&self.w.get("head_b")?.data)))
    }

    /// Convenience: single-sequence forward returning the mean at `pos`.
    pub fn mean_at(&self, patches: &[f32], n: usize, pos: usize) -> Result<Vec<f32>> {
        let p = self.dims.patch;
        let t = Tensor::from_vec(&[1, n, p], patches[..n * p].to_vec());
        let out = self.forward(&t)?;
        Ok(out.data[pos * p..(pos + 1) * p].to_vec())
    }

    fn attn_block(&self, li: usize, x: &mut Tensor, b: usize, n: usize, s: &mut Scratch) -> Result<()> {
        let d = self.dims.d_model;
        let h = self.dims.n_heads;
        let dh = self.dims.d_head();
        let scale = 1.0 / (dh as f32).sqrt();

        // Pre-norm into scratch.
        s.normed.data.copy_from_slice(&x.data);
        rmsnorm(&mut s.normed.data, &self.w.get(&format!("layers.{li}.ln1"))?.data, RMS_EPS);
        // QKV projection: [B*N, 3D]; layout per token = [3, H, Dh].
        let wqkv = self.w.get(&format!("layers.{li}.wqkv"))?;
        matmul(&s.normed.data, &wqkv.data, b * n, d, 3 * d, &mut s.qkv.data);

        // Attention per (batch, head): scores in scratch, online over rows.
        for bi in 0..b {
            for hi in 0..h {
                // Gather q, k, v rows for this (b, h): stride-3D layout.
                for t in 0..n {
                    let base = (bi * n + t) * 3 * d;
                    let qoff = base + hi * dh;
                    let koff = base + d + hi * dh;
                    let voff = base + 2 * d + hi * dh;
                    s.q[t * dh..(t + 1) * dh].copy_from_slice(&s.qkv.data[qoff..qoff + dh]);
                    s.k[t * dh..(t + 1) * dh].copy_from_slice(&s.qkv.data[koff..koff + dh]);
                    s.v[t * dh..(t + 1) * dh].copy_from_slice(&s.qkv.data[voff..voff + dh]);
                }
                for t in 0..n {
                    let qrow = &s.q[t * dh..(t + 1) * dh];
                    let srow = &mut s.scores[..=t];
                    for (j, sv) in srow.iter_mut().enumerate() {
                        let krow = &s.k[j * dh..(j + 1) * dh];
                        *sv = qrow.iter().zip(krow).map(|(a, c)| a * c).sum::<f32>() * scale;
                    }
                    softmax_row(srow);
                    let orow = &mut s.attn_out[(t * dh)..(t + 1) * dh];
                    orow.fill(0.0);
                    for (j, &w) in srow.iter().enumerate() {
                        let vrow = &s.v[j * dh..(j + 1) * dh];
                        for (o, vv) in orow.iter_mut().zip(vrow) {
                            *o += w * vv;
                        }
                    }
                }
                // Scatter head output back into s.concat [B*N, D].
                for t in 0..n {
                    let dst = (bi * n + t) * d + hi * dh;
                    s.concat.data[dst..dst + dh]
                        .copy_from_slice(&s.attn_out[t * dh..(t + 1) * dh]);
                }
            }
        }
        // Output projection + residual.
        let wo = self.w.get(&format!("layers.{li}.wo"))?;
        matmul(&s.concat.data, &wo.data, b * n, d, d, &mut s.proj.data);
        for (xv, pv) in x.data.iter_mut().zip(&s.proj.data) {
            *xv += pv;
        }
        Ok(())
    }

    fn mlp_block(&self, li: usize, x: &mut Tensor, b: usize, n: usize) -> Result<()> {
        let d = self.dims.d_model;
        let f = self.dims.d_ff;
        let mut normed = x.clone();
        rmsnorm(&mut normed.data, &self.w.get(&format!("layers.{li}.ln2"))?.data, RMS_EPS);
        let wg = self.w.get(&format!("layers.{li}.wg"))?;
        let wu = self.w.get(&format!("layers.{li}.wu"))?;
        let wd = self.w.get(&format!("layers.{li}.wd"))?;
        let mut g = vec![0.0f32; b * n * f];
        let mut u = vec![0.0f32; b * n * f];
        matmul(&normed.data, &wg.data, b * n, d, f, &mut g);
        matmul(&normed.data, &wu.data, b * n, d, f, &mut u);
        for (gv, uv) in g.iter_mut().zip(&u) {
            *gv = silu(*gv) * uv;
        }
        let mut down = vec![0.0f32; b * n * d];
        matmul(&g, &wd.data, b * n, f, d, &mut down);
        for (xv, dv) in x.data.iter_mut().zip(&down) {
            *xv += dv;
        }
        Ok(())
    }

    /// Incremental forward: consume `k` new patches (flat `[k, patch]`)
    /// given `cache` holding per-layer K/V for the first `cache.n` patches
    /// of the sequence. Appends `k` rows per layer and returns the outputs
    /// at the `k` new positions (flat `[k, patch]`).
    ///
    /// The appended rows attend over the cached rows plus themselves with
    /// exactly the op order of [`NativeModel::forward`], so outputs match
    /// the corresponding rows of a full stateless forward to float
    /// equality. Cost is O(k·n·d) vs the stateless O(n²·d).
    pub fn forward_cached(&self, cache: &mut KvCache, new_tokens: &[f32], k: usize) -> Result<Vec<f32>> {
        let p = self.dims.patch;
        let d = self.dims.d_model;
        let h = self.dims.n_heads;
        let dh = self.dims.d_head();
        anyhow::ensure!(cache.dims == self.dims, "KV cache built for different dims");
        anyhow::ensure!(k >= 1, "forward_cached needs k >= 1");
        anyhow::ensure!(new_tokens.len() >= k * p, "token buffer too short");
        let n0 = cache.n;
        anyhow::ensure!(
            n0 + k <= self.dims.n_ctx,
            "KV cache overflow: {n0} + {k} > n_ctx {}",
            self.dims.n_ctx
        );

        // Embed + learned positions for the new rows only. Positions are
        // absolute (n0..n0+k), which is why window slides cannot rotate the
        // cache in place — see `KvCache` docs.
        let t_in = Tensor::from_vec(&[k, p], new_tokens[..k * p].to_vec());
        let mut x = linear(&t_in, self.w.get("embed_w")?, Some(&self.w.get("embed_b")?.data));
        let pos = self.w.get("pos")?;
        for t in 0..k {
            let row = &mut x.data[t * d..(t + 1) * d];
            for (v, pv) in row.iter_mut().zip(&pos.data[(n0 + t) * d..(n0 + t + 1) * d]) {
                *v += pv;
            }
        }

        let scale = 1.0 / (dh as f32).sqrt();
        let mut normed = vec![0.0f32; k * d];
        let mut qkv = vec![0.0f32; k * 3 * d];
        let mut concat = vec![0.0f32; k * d];
        let mut proj = vec![0.0f32; k * d];
        let mut scores = vec![0.0f32; n0 + k];

        for li in 0..self.dims.n_layers {
            normed.copy_from_slice(&x.data);
            rmsnorm(&mut normed, &self.w.get(&format!("layers.{li}.ln1"))?.data, RMS_EPS);
            let wqkv = self.w.get(&format!("layers.{li}.wqkv"))?;
            matmul(&normed, &wqkv.data, k, d, 3 * d, &mut qkv);

            // Append the new K/V rows (heads contiguous, as in the qkv
            // layout) before attending so a row can see itself.
            let kbuf = &mut cache.k[li];
            let vbuf = &mut cache.v[li];
            for t in 0..k {
                let base = t * 3 * d;
                kbuf[(n0 + t) * d..(n0 + t + 1) * d].copy_from_slice(&qkv[base + d..base + 2 * d]);
                vbuf[(n0 + t) * d..(n0 + t + 1) * d]
                    .copy_from_slice(&qkv[base + 2 * d..base + 3 * d]);
            }
            // Causal attention: new row at absolute position g attends over
            // cached rows 0..=g.
            for t in 0..k {
                let g = n0 + t;
                for hi in 0..h {
                    let q = &qkv[t * 3 * d + hi * dh..t * 3 * d + hi * dh + dh];
                    let srow = &mut scores[..=g];
                    for (j, sv) in srow.iter_mut().enumerate() {
                        let krow = &kbuf[j * d + hi * dh..j * d + hi * dh + dh];
                        *sv = q.iter().zip(krow).map(|(a, c)| a * c).sum::<f32>() * scale;
                    }
                    softmax_row(srow);
                    let orow = &mut concat[t * d + hi * dh..t * d + hi * dh + dh];
                    orow.fill(0.0);
                    for (j, &wj) in srow.iter().enumerate() {
                        let vrow = &vbuf[j * d + hi * dh..j * d + hi * dh + dh];
                        for (o, vv) in orow.iter_mut().zip(vrow) {
                            *o += wj * vv;
                        }
                    }
                }
            }
            let wo = self.w.get(&format!("layers.{li}.wo"))?;
            matmul(&concat, &wo.data, k, d, d, &mut proj);
            for (xv, pv) in x.data.iter_mut().zip(&proj) {
                *xv += pv;
            }
            self.mlp_block(li, &mut x, 1, k)?;
        }

        cache.n = n0 + k;
        rmsnorm(&mut x.data, &self.w.get("final_norm")?.data, RMS_EPS);
        Ok(linear(&x, self.w.get("head_w")?, Some(&self.w.get("head_b")?.data)).data)
    }
}

/// Per-layer K/V ring buffers for incremental decoding.
///
/// Rows live at absolute positions `0..n` in fixed `[n_ctx * d_model]`
/// allocations (one K and one V buffer per layer, heads contiguous).
/// Rollback of rejected speculation is `truncate` (drop suffix rows —
/// the prefix stays valid because attention is causal). Window *slides*
/// are different: the learned absolute position embeddings make every
/// cached row position-dependent, so eviction from the front cannot
/// rotate rows in place — the session layer truncates and re-prefills
/// the kept suffix instead (see `models::NativeSession::evict_to`).
/// The speculative engine evicts once per round (freeing γ+1 slots), so
/// the re-prefill amortizes over the whole emitted block; a *saturated*
/// plain-AR decode slides one patch per step and therefore degenerates
/// to stateless cost at the window boundary — the price of keeping
/// eviction bit-equal to the stateless sliding-window rule.
pub struct KvCache {
    dims: ModelDims,
    /// Valid rows (patches) currently cached.
    n: usize,
    k: Vec<Vec<f32>>,
    v: Vec<Vec<f32>>,
}

impl KvCache {
    pub fn new(dims: &ModelDims) -> KvCache {
        let cap = dims.n_ctx * dims.d_model;
        KvCache {
            dims: *dims,
            n: 0,
            k: (0..dims.n_layers).map(|_| vec![0.0; cap]).collect(),
            v: (0..dims.n_layers).map(|_| vec![0.0; cap]).collect(),
        }
    }

    /// Valid rows (patches) currently cached.
    pub fn len(&self) -> usize {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// Maximum rows (the model's n_ctx).
    pub fn capacity(&self) -> usize {
        self.dims.n_ctx
    }

    /// Forget everything (prelude to a re-prefill after a window slide).
    pub fn reset(&mut self) {
        self.n = 0;
    }

    /// Drop cached rows beyond `n` — the rollback of rejected speculation.
    pub fn truncate(&mut self, n: usize) {
        assert!(n <= self.n, "KvCache::truncate beyond cached rows");
        self.n = n;
    }
}

/// Reusable per-forward scratch buffers (hot-path allocation hygiene).
struct Scratch {
    normed: Tensor,
    qkv: Tensor,
    concat: Tensor,
    proj: Tensor,
    q: Vec<f32>,
    k: Vec<f32>,
    v: Vec<f32>,
    scores: Vec<f32>,
    attn_out: Vec<f32>,
}

impl Scratch {
    fn new(dims: &ModelDims, b: usize, n: usize) -> Scratch {
        let d = dims.d_model;
        let dh = dims.d_head();
        Scratch {
            normed: Tensor::zeros(&[b * n, d]),
            qkv: Tensor::zeros(&[b * n, 3 * d]),
            concat: Tensor::zeros(&[b * n, d]),
            proj: Tensor::zeros(&[b * n, d]),
            q: vec![0.0; n * dh],
            k: vec![0.0; n * dh],
            v: vec![0.0; n * dh],
            scores: vec![0.0; n],
            attn_out: vec![0.0; n * dh],
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// Tiny random model for structural tests (no artifacts needed).
    pub fn tiny_model(seed: u64) -> NativeModel {
        let dims = ModelDims { patch: 4, n_ctx: 8, d_model: 8, n_layers: 2, n_heads: 2, d_ff: 16 };
        let mut w = Weights::default();
        let mut rng = Rng::new(seed);
        let mut t = |shape: &[usize], scale: f32| {
            let n: usize = shape.iter().product();
            Tensor::from_vec(shape, (0..n).map(|_| scale * rng.normal() as f32).collect())
        };
        w.insert("embed_w", t(&[4, 8], 0.3));
        w.insert("embed_b", Tensor::zeros(&[8]));
        w.insert("pos", t(&[8, 8], 0.1));
        for li in 0..2 {
            w.insert(&format!("layers.{li}.ln1"), Tensor::from_vec(&[8], vec![1.0; 8]));
            w.insert(&format!("layers.{li}.wqkv"), t(&[8, 24], 0.3));
            w.insert(&format!("layers.{li}.wo"), t(&[8, 8], 0.2));
            w.insert(&format!("layers.{li}.ln2"), Tensor::from_vec(&[8], vec![1.0; 8]));
            w.insert(&format!("layers.{li}.wg"), t(&[8, 16], 0.3));
            w.insert(&format!("layers.{li}.wu"), t(&[8, 16], 0.3));
            w.insert(&format!("layers.{li}.wd"), t(&[16, 8], 0.2));
        }
        w.insert("final_norm", Tensor::from_vec(&[8], vec![1.0; 8]));
        w.insert("head_w", t(&[8, 4], 0.3));
        w.insert("head_b", Tensor::zeros(&[4]));
        NativeModel::new("tiny", dims, w)
    }

    #[test]
    fn forward_shapes() {
        let m = tiny_model(1);
        let x = Tensor::zeros(&[2, 8, 4]);
        let y = m.forward(&x).unwrap();
        assert_eq!(y.shape, vec![2, 8, 4]);
        assert!(y.data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn causality() {
        // Changing patch t must not change outputs at positions < t.
        let m = tiny_model(2);
        let mut rng = Rng::new(3);
        let base: Vec<f32> = (0..8 * 4).map(|_| rng.normal() as f32).collect();
        let y0 = m.forward(&Tensor::from_vec(&[1, 8, 4], base.clone())).unwrap();
        let mut perturbed = base.clone();
        for v in &mut perturbed[5 * 4..] {
            *v += 1.0;
        }
        let y1 = m.forward(&Tensor::from_vec(&[1, 8, 4], perturbed)).unwrap();
        for t in 0..5 {
            for i in 0..4 {
                let a = y0.data[t * 4 + i];
                let b = y1.data[t * 4 + i];
                assert!(
                    (a - b).abs() < 1e-6,
                    "position {t} changed by future perturbation: {a} vs {b}"
                );
            }
        }
        // ...and *must* change positions >= 5 (sanity that the test bites).
        let mut any = false;
        for t in 5..8 {
            for i in 0..4 {
                if (y0.data[t * 4 + i] - y1.data[t * 4 + i]).abs() > 1e-4 {
                    any = true;
                }
            }
        }
        assert!(any, "future positions unaffected — attention is broken");
    }

    #[test]
    fn batch_equals_loop() {
        // forward([a; b]) == [forward(a); forward(b)].
        let m = tiny_model(4);
        let mut rng = Rng::new(9);
        let a: Vec<f32> = (0..8 * 4).map(|_| rng.normal() as f32).collect();
        let b: Vec<f32> = (0..8 * 4).map(|_| rng.normal() as f32).collect();
        let mut ab = a.clone();
        ab.extend_from_slice(&b);
        let batched = m.forward(&Tensor::from_vec(&[2, 8, 4], ab)).unwrap();
        let ya = m.forward(&Tensor::from_vec(&[1, 8, 4], a)).unwrap();
        let yb = m.forward(&Tensor::from_vec(&[1, 8, 4], b)).unwrap();
        for i in 0..8 * 4 {
            assert!((batched.data[i] - ya.data[i]).abs() < 1e-5);
            assert!((batched.data[8 * 4 + i] - yb.data[i]).abs() < 1e-5);
        }
    }

    #[test]
    fn shorter_context_allowed() {
        let m = tiny_model(5);
        let x = Tensor::zeros(&[1, 3, 4]);
        let y = m.forward(&x).unwrap();
        assert_eq!(y.shape, vec![1, 3, 4]);
    }

    #[test]
    fn wrong_patch_dim_rejected() {
        let m = tiny_model(6);
        assert!(m.forward(&Tensor::zeros(&[1, 8, 5])).is_err());
        assert!(m.forward(&Tensor::zeros(&[1, 9, 4])).is_err());
    }

    #[test]
    fn cached_forward_matches_full() {
        // prefill 5 rows + incremental 3 rows == one stateless forward.
        let m = tiny_model(11);
        let mut rng = Rng::new(21);
        let toks: Vec<f32> = (0..8 * 4).map(|_| rng.normal() as f32).collect();
        let full = m.forward(&Tensor::from_vec(&[1, 8, 4], toks.clone())).unwrap();
        let mut cache = KvCache::new(&m.dims);
        let head = m.forward_cached(&mut cache, &toks[..5 * 4], 5).unwrap();
        let tail = m.forward_cached(&mut cache, &toks[5 * 4..], 3).unwrap();
        assert_eq!(cache.len(), 8);
        for (i, v) in head.iter().chain(tail.iter()).enumerate() {
            assert!(
                (v - full.data[i]).abs() < 1e-5,
                "row {} diverged: cached {v} vs full {}",
                i / 4,
                full.data[i]
            );
        }
    }

    #[test]
    fn cached_truncate_then_reextend_matches_full() {
        // Rollback (truncate) must leave the prefix usable: re-extending
        // with different patches equals a stateless forward of the spliced
        // sequence.
        let m = tiny_model(12);
        let mut rng = Rng::new(22);
        let toks: Vec<f32> = (0..8 * 4).map(|_| rng.normal() as f32).collect();
        let mut cache = KvCache::new(&m.dims);
        let _ = m.forward_cached(&mut cache, &toks, 8).unwrap();
        cache.truncate(4);
        let replacement: Vec<f32> = (0..2 * 4).map(|_| rng.normal() as f32).collect();
        let rows = m.forward_cached(&mut cache, &replacement, 2).unwrap();
        let mut spliced = toks[..4 * 4].to_vec();
        spliced.extend_from_slice(&replacement);
        let full = m.forward(&Tensor::from_vec(&[1, 6, 4], spliced)).unwrap();
        for i in 0..2 * 4 {
            assert!((rows[i] - full.data[4 * 4 + i]).abs() < 1e-5);
        }
    }

    #[test]
    fn cached_overflow_rejected() {
        let m = tiny_model(13);
        let mut cache = KvCache::new(&m.dims);
        let toks = vec![0.1f32; 8 * 4];
        let _ = m.forward_cached(&mut cache, &toks, 8).unwrap();
        assert!(m.forward_cached(&mut cache, &toks[..4], 1).is_err());
    }

    #[test]
    fn random_model_forward_is_finite() {
        let dims =
            ModelDims { patch: 4, n_ctx: 32, d_model: 16, n_layers: 3, n_heads: 4, d_ff: 32 };
        let m = NativeModel::random("rnd", dims, 7);
        let mut rng = Rng::new(8);
        let toks: Vec<f32> = (0..32 * 4).map(|_| rng.normal() as f32).collect();
        let y = m.forward(&Tensor::from_vec(&[1, 32, 4], toks)).unwrap();
        assert!(y.data.iter().all(|v| v.is_finite()));
    }
}

#[cfg(test)]
pub use tests::tiny_model;
