//! Native Rust forward pass of the Timer-style decoder — an exact mirror of
//! `python/compile/model.py::forward` (fused-attention variant).
//!
//! Role in the system (DESIGN.md §4): (i) the CPU reference comparator the
//! paper baselines against, (ii) a PJRT-free backend for tests/benches, and
//! (iii) the parity check proving the HLO artifacts compute the same
//! function (`rust/tests/xla_integration.rs` asserts native == XLA == JAX
//! golden within fp tolerance).
//!
//! Since the kernel-layer PR the compute itself lives in [`super::kernel`]:
//! weights are resolved once at construction into [`PackedWeights`] (no
//! string-keyed lookups in the hot loop), intermediates live in a
//! [`ForwardScratch`] arena (the cached path's arena is owned by the
//! [`KvCache`], so steady-state decode does zero heap allocation), and
//! matmuls dispatch serial-or-row-parallel via `matmul_auto`. The
//! pre-kernel-layer implementation (string-keyed, allocating, naive
//! matmul) is retained behind [`NativeModel::set_reference`] as the
//! equivalence baseline and the `perf_hotpath` "before" kernel.
//!
//! Two forward paths share the same math:
//! * [`NativeModel::forward`] — stateless, recomputes attention over the
//!   whole context (O(n²·d) per call).
//! * [`NativeModel::forward_cached`] — incremental over a [`KvCache`]:
//!   only the appended rows are computed (O(k·n·d) per call), which is what
//!   turns a speculative round from O(n²·d) into O(γ·n·d). Both paths are
//!   assembled from the *same* slice kernels, so they agree row-for-row to
//!   float equality (pinned by `rust/tests/cache_equivalence.rs`).

use anyhow::Result;

use super::kernel::{self, ForwardScratch, PackedWeights, RMS_EPS};
use super::weights::Weights;
use crate::util::rng::Rng;
use crate::util::tensor::{linear_naive, matmul_naive, rmsnorm, silu, softmax_row, Tensor};

/// Architecture dims (mirror of model.ModelConfig; parsed from the manifest).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ModelDims {
    /// Values per patch token.
    pub patch: usize,
    /// Maximum context length in patches.
    pub n_ctx: usize,
    /// Residual stream width.
    pub d_model: usize,
    /// Decoder layers.
    pub n_layers: usize,
    /// Attention heads per layer.
    pub n_heads: usize,
    /// MLP hidden width.
    pub d_ff: usize,
}

impl ModelDims {
    /// Per-head dimension (`d_model / n_heads`).
    pub fn d_head(&self) -> usize {
        self.d_model / self.n_heads
    }
}

/// A loaded native model.
pub struct NativeModel {
    /// Architecture dimensions.
    pub dims: ModelDims,
    /// Model name (manifest name or a synthetic label).
    pub name: String,
    /// String-keyed store (reference path + introspection); shares tensor
    /// storage with `pw` via `Arc`, so keeping both costs pointers only.
    w: Weights,
    /// Kernel-layer weight handles, resolved once here.
    pw: PackedWeights,
    /// Route forwards through the pre-kernel-layer reference
    /// implementation (equivalence tests, perf "before" flag).
    use_reference: bool,
}

impl NativeModel {
    /// Pack the weight map into direct kernel handles; fails early on a
    /// missing or mis-shaped tensor.
    pub fn new(name: &str, dims: ModelDims, weights: Weights) -> Result<NativeModel> {
        let pw = PackedWeights::pack(&dims, &weights)?;
        Ok(NativeModel {
            dims,
            name: name.to_string(),
            w: weights,
            pw,
            use_reference: false,
        })
    }

    /// An independent model instance over the *same* `Arc`-shared float
    /// storage: tensors are not copied, only handles. Each serving replica
    /// gets its own `NativeModel` (and thus its own packed handles and
    /// timing summary) this way — N replicas cost N sets of pointers, one
    /// set of floats. The reference-kernel flag resets to the default.
    pub fn replicate(&self) -> Result<NativeModel> {
        NativeModel::new(&self.name, self.dims, self.w.clone())
    }

    /// Toggle the pre-kernel-layer (string-keyed, allocating, naive-matmul)
    /// implementation for both forward paths. The kernel equivalence suite
    /// pins `packed == reference` within 1e-5.
    pub fn set_reference(&mut self, on: bool) {
        self.use_reference = on;
    }

    /// Whether the reference kernel is active.
    pub fn reference_kernel(&self) -> bool {
        self.use_reference
    }

    /// Borrow the string-keyed weight map (the registry packer serializes
    /// tensors from here; no float is copied by the borrow).
    pub fn weights(&self) -> &Weights {
        &self.w
    }

    /// Seeded random-weight model (no artifacts needed): the substrate for
    /// the cache-equivalence test suite and the `perf_hotpath` cached sweep,
    /// where analytic heads would be too trivial to exercise attention.
    /// Projections are scaled by 1/sqrt(fan_in) so activations stay sane at
    /// bench-sized dims.
    pub fn random(name: &str, dims: ModelDims, seed: u64) -> NativeModel {
        let mut w = Weights::default();
        let mut rng = Rng::new(seed);
        let mut t = |shape: &[usize], scale: f32| {
            let n: usize = shape.iter().product();
            Tensor::from_vec(shape, (0..n).map(|_| scale * rng.normal() as f32).collect())
        };
        let (p, d, f) = (dims.patch, dims.d_model, dims.d_ff);
        let s_p = 0.5 / (p as f32).sqrt();
        let s_d = 0.5 / (d as f32).sqrt();
        let s_f = 0.5 / (f as f32).sqrt();
        w.insert("embed_w", t(&[p, d], s_p));
        w.insert("embed_b", Tensor::zeros(&[d]));
        w.insert("pos", t(&[dims.n_ctx, d], 0.1));
        for li in 0..dims.n_layers {
            w.insert(&format!("layers.{li}.ln1"), Tensor::from_vec(&[d], vec![1.0; d]));
            w.insert(&format!("layers.{li}.wqkv"), t(&[d, 3 * d], s_d));
            w.insert(&format!("layers.{li}.wo"), t(&[d, d], s_d));
            w.insert(&format!("layers.{li}.ln2"), Tensor::from_vec(&[d], vec![1.0; d]));
            w.insert(&format!("layers.{li}.wg"), t(&[d, f], s_d));
            w.insert(&format!("layers.{li}.wu"), t(&[d, f], s_d));
            w.insert(&format!("layers.{li}.wd"), t(&[f, d], s_f));
        }
        w.insert("final_norm", Tensor::from_vec(&[d], vec![1.0; d]));
        w.insert("head_w", t(&[d, p], s_d));
        w.insert("head_b", Tensor::zeros(&[p]));
        NativeModel::new(name, dims, w).expect("random weights are complete")
    }

    /// tokens [B, N, P] -> next-patch means [B, N, P]; N <= n_ctx.
    pub fn forward(&self, tokens: &Tensor) -> Result<Tensor> {
        let (b, n, p) = (tokens.shape[0], tokens.shape[1], tokens.shape[2]);
        anyhow::ensure!(p == self.dims.patch, "patch dim {p} != {}", self.dims.patch);
        anyhow::ensure!(n <= self.dims.n_ctx, "N {n} > n_ctx {}", self.dims.n_ctx);
        if self.use_reference {
            return self.forward_reference(tokens, b, n);
        }
        let d = self.dims.d_model;
        let h = self.dims.n_heads;
        let dh = self.dims.d_head();
        let f = self.dims.d_ff;
        let scale = 1.0 / (dh as f32).sqrt();
        let rows = b * n;

        // One arena per call (the stateless path is the re-forward cost
        // model; only the cached path must be allocation-free).
        let mut s = ForwardScratch::new(&self.dims, rows);
        kernel::embed_tokens(&self.pw, &tokens.data, rows, p, d, &mut s.x);
        for bi in 0..b {
            kernel::add_pos(&self.pw, d, 0, n, &mut s.x[bi * n * d..(bi + 1) * n * d]);
        }
        for lw in &self.pw.layers {
            kernel::qkv_rows(lw, &s.x, rows, d, &mut s.normed, &mut s.qkv);
            for bi in 0..b {
                let q = &s.qkv[bi * n * 3 * d..(bi + 1) * n * 3 * d];
                kernel::append_kv(q, n, d, 0, &mut s.kbuf, &mut s.vbuf);
                kernel::attn_rows(
                    q,
                    &s.kbuf,
                    &s.vbuf,
                    0,
                    n,
                    h,
                    dh,
                    scale,
                    &mut s.scores,
                    &mut s.concat[bi * n * d..(bi + 1) * n * d],
                );
            }
            kernel::proj_residual_rows(lw, &s.concat, rows, d, &mut s.proj, &mut s.x);
            kernel::mlp_rows(lw, &mut s.x, rows, d, f, &mut s.normed, &mut s.gate, &mut s.up, &mut s.down);
        }
        kernel::head_rows(&self.pw, &mut s.x, rows, d, p, &mut s.out);
        Ok(Tensor::from_vec(&[b, n, p], s.out))
    }

    /// Convenience: single-sequence forward returning the mean at `pos`.
    pub fn mean_at(&self, patches: &[f32], n: usize, pos: usize) -> Result<Vec<f32>> {
        let p = self.dims.patch;
        let t = Tensor::from_vec(&[1, n, p], patches[..n * p].to_vec());
        let out = self.forward(&t)?;
        Ok(out.data[pos * p..(pos + 1) * p].to_vec())
    }

    /// Incremental forward: consume `k` new patches (flat `[k, patch]`)
    /// given `cache` holding per-layer K/V for the first `cache.n` patches
    /// of the sequence. Appends `k` rows per layer and returns the outputs
    /// at the `k` new positions (flat `[k, patch]`), borrowed from the
    /// cache-owned scratch arena — **zero heap allocations** on this path
    /// (pinned by `tests/alloc_discipline.rs`).
    ///
    /// The appended rows attend over the cached rows plus themselves with
    /// exactly the op order of [`NativeModel::forward`] (same slice
    /// kernels), so outputs match the corresponding rows of a full
    /// stateless forward to float equality. Cost is O(k·n·d) vs the
    /// stateless O(n²·d).
    pub fn forward_cached<'c>(
        &self,
        cache: &'c mut KvCache,
        new_tokens: &[f32],
        k: usize,
    ) -> Result<&'c [f32]> {
        let p = self.dims.patch;
        anyhow::ensure!(cache.dims == self.dims, "KV cache built for different dims");
        anyhow::ensure!(k >= 1, "forward_cached needs k >= 1");
        anyhow::ensure!(new_tokens.len() >= k * p, "token buffer too short");
        let n0 = cache.n;
        anyhow::ensure!(
            n0 + k <= self.dims.n_ctx,
            "KV cache overflow: {n0} + {k} > n_ctx {}",
            self.dims.n_ctx
        );

        if self.use_reference {
            let v = self.forward_cached_reference(cache, new_tokens, k)?;
            cache.scratch.out[..k * p].copy_from_slice(&v);
            return Ok(&cache.scratch.out[..k * p]);
        }

        {
            // Split the cache borrow: K/V ring buffers and the scratch
            // arena are disjoint fields.
            let KvCache { k: ref mut kc, v: ref mut vc, scratch: ref mut owned, .. } = *cache;
            if k <= owned.capacity_rows() {
                // Steady state (k <= MAX_DECODE_ROWS): the cache-owned
                // arena, zero allocations.
                self.cached_kernels(owned, kc, vc, new_tokens, n0, k);
            } else {
                // Prefill / evict re-prefill: larger than the persistent
                // arena — borrow a temporary one (allocation is fine off
                // the steady-state path) and land the output rows in the
                // cache-owned `out` (sized n_ctx rows) so the returned
                // slice always borrows from the cache.
                let mut temp = ForwardScratch::for_prefill(&self.dims, k);
                self.cached_kernels(&mut temp, kc, vc, new_tokens, n0, k);
                owned.out[..k * p].copy_from_slice(&temp.out[..k * p]);
            }
        }
        cache.n = n0 + k;
        Ok(&cache.scratch.out[..k * p])
    }

    /// The cached forward's kernel sequence over an arbitrary arena
    /// (cache-owned in steady state, temporary for prefill-sized `k`).
    fn cached_kernels(
        &self,
        s: &mut ForwardScratch,
        kc: &mut [Vec<f32>],
        vc: &mut [Vec<f32>],
        new_tokens: &[f32],
        n0: usize,
        k: usize,
    ) {
        let p = self.dims.patch;
        let d = self.dims.d_model;
        let h = self.dims.n_heads;
        let dh = self.dims.d_head();
        let f = self.dims.d_ff;
        let scale = 1.0 / (dh as f32).sqrt();
        // Embed + learned positions for the new rows only. Positions are
        // absolute (n0..n0+k), which is why window slides cannot rotate
        // the cache in place — see `KvCache` docs.
        kernel::embed_tokens(&self.pw, new_tokens, k, p, d, &mut s.x);
        kernel::add_pos(&self.pw, d, n0, k, &mut s.x);
        for (li, lw) in self.pw.layers.iter().enumerate() {
            kernel::qkv_rows(lw, &s.x, k, d, &mut s.normed, &mut s.qkv);
            // Append the new K/V rows before attending so a row can see
            // itself.
            kernel::append_kv(&s.qkv, k, d, n0, &mut kc[li], &mut vc[li]);
            kernel::attn_rows(
                &s.qkv,
                &kc[li],
                &vc[li],
                n0,
                k,
                h,
                dh,
                scale,
                &mut s.scores,
                &mut s.concat,
            );
            kernel::proj_residual_rows(lw, &s.concat, k, d, &mut s.proj, &mut s.x);
            kernel::mlp_rows(lw, &mut s.x, k, d, f, &mut s.normed, &mut s.gate, &mut s.up, &mut s.down);
        }
        kernel::head_rows(&self.pw, &mut s.x, k, d, p, &mut s.out);
    }

    /// Stacked incremental forward: run `b` same-length suffixes of `k`
    /// patches each (flat `[b, k, patch]`, lane-major) against ONE shared
    /// committed prefix, in ONE pass of stacked GEMMs. The cache comes in
    /// behind `&` — the type-level guarantee that the prefix is never
    /// mutated — and each lane's K/V rows land in its own disjoint slice
    /// of the [`StackedLanes`] arena, with attention reading prefix rows
    /// from the cache and suffix rows from the lane
    /// ([`kernel::attn_rows_split`]).
    ///
    /// Returns the `b*k` output rows (flat `[b, k, patch]`, lane-major),
    /// borrowed from the lane arena. Every GEMM row and every attention
    /// row depends only on its own lane's inputs plus the shared prefix,
    /// so lane `j`'s rows are **bitwise identical** to a sequential
    /// [`NativeModel::forward_cached`] of that lane's patches over the
    /// same prefix (pinned by `tests/tree_equivalence.rs`'s stacked wall).
    /// This is the "verify k draft branches in one wide target forward"
    /// kernel from the paper's parallel-verification claim.
    ///
    /// Steady state is **zero heap allocations**: the arena grows to a
    /// high-water mark on first use and is reused afterwards (pinned by
    /// `tests/alloc_discipline.rs`). Shape violations — zero dims,
    /// mis-sized token buffers, more lanes than
    /// [`kernel::MAX_STACK_LANES`], overflowing the context window —
    /// return typed errors, never UB or a panic (`tests/fuzz_lite.rs`).
    /// Always runs the kernel layer, regardless of
    /// [`NativeModel::set_reference`] (the reference wall compares against
    /// the sequential path instead).
    pub fn forward_cached_stacked<'s>(
        &self,
        cache: &KvCache,
        lanes: &'s mut StackedLanes,
        new_tokens: &[f32],
        b: usize,
        k: usize,
    ) -> Result<&'s [f32]> {
        let p = self.dims.patch;
        let d = self.dims.d_model;
        let h = self.dims.n_heads;
        let dh = self.dims.d_head();
        let f = self.dims.d_ff;
        anyhow::ensure!(cache.dims == self.dims, "KV cache built for different dims");
        anyhow::ensure!(b >= 1 && k >= 1, "forward_cached_stacked needs b >= 1 and k >= 1");
        anyhow::ensure!(
            b <= kernel::MAX_STACK_LANES,
            "forward_cached_stacked: {b} lanes > MAX_STACK_LANES {}",
            kernel::MAX_STACK_LANES
        );
        anyhow::ensure!(
            new_tokens.len() == b * k * p,
            "forward_cached_stacked: token buffer has {} values, want b*k*p = {}",
            new_tokens.len(),
            b * k * p
        );
        let n0 = cache.n;
        anyhow::ensure!(
            n0 + k <= self.dims.n_ctx,
            "KV cache overflow: {n0} + {k} > n_ctx {}",
            self.dims.n_ctx
        );
        lanes.ensure(&self.dims, b, k);
        let rows = b * k;
        let scale = 1.0 / (dh as f32).sqrt();
        // Split the lane-arena borrow: per-layer lane K/V and the scratch
        // are disjoint fields.
        let StackedLanes {
            k: ref mut lk,
            v: ref mut lv,
            scratch: ref mut sc,
            rows: cap_rows,
            ..
        } = *lanes;
        let stride = cap_rows * d;
        let s = sc.as_mut().expect("ensure() populated the stacked scratch");
        kernel::embed_tokens(&self.pw, new_tokens, rows, p, d, &mut s.x);
        for lane in 0..b {
            // Every lane sits at the same absolute positions n0..n0+k.
            kernel::add_pos(&self.pw, d, n0, k, &mut s.x[lane * k * d..(lane + 1) * k * d]);
        }
        for (li, lw) in self.pw.layers.iter().enumerate() {
            kernel::qkv_rows(lw, &s.x, rows, d, &mut s.normed, &mut s.qkv);
            let kc = &cache.k[li];
            let vc = &cache.v[li];
            for lane in 0..b {
                let q = &s.qkv[lane * k * 3 * d..(lane + 1) * k * 3 * d];
                {
                    let klane = &mut lk[li][lane * stride..lane * stride + k * d];
                    let vlane = &mut lv[li][lane * stride..lane * stride + k * d];
                    kernel::append_kv(q, k, d, 0, klane, vlane);
                }
                kernel::attn_rows_split(
                    q,
                    &kc[..n0 * d],
                    &vc[..n0 * d],
                    &lk[li][lane * stride..lane * stride + k * d],
                    &lv[li][lane * stride..lane * stride + k * d],
                    n0,
                    k,
                    h,
                    dh,
                    scale,
                    &mut s.scores,
                    &mut s.concat[lane * k * d..(lane + 1) * k * d],
                );
            }
            kernel::proj_residual_rows(lw, &s.concat, rows, d, &mut s.proj, &mut s.x);
            kernel::mlp_rows(lw, &mut s.x, rows, d, f, &mut s.normed, &mut s.gate, &mut s.up, &mut s.down);
        }
        kernel::head_rows(&self.pw, &mut s.x, rows, d, p, &mut s.out);
        Ok(&s.out[..rows * p])
    }

    /// Lockstep incremental forward: advance `b` *independent* cached
    /// sequences — all sitting at the same length `n0` — by the same `k`
    /// patches each (flat `[b, k, patch]`, lane-major), with every GEMM in
    /// the round stacked into one `[b*k, ·]` call. Unlike
    /// [`NativeModel::forward_cached_stacked`] (k branches over ONE shared
    /// prefix, cache immutable) this is the batched decoder's commit path:
    /// each lane's K/V rows are appended into *its own* cache and each
    /// cache advances to `n0 + k`.
    ///
    /// Attention stays per-lane (each lane reads only its own cache), and
    /// every stacked GEMM row depends only on its own lane's activations,
    /// so lane `j`'s output rows are **bitwise identical** to a serial
    /// [`NativeModel::forward_cached`] on cache `j` (pinned by
    /// `tests/kernel_equivalence.rs`). `scratch` must have capacity for
    /// `b*k` rows; the caller owns and reuses it so steady-state lockstep
    /// rounds allocate nothing. Always runs the kernel layer — callers
    /// gate on [`NativeModel::reference_kernel`].
    pub fn forward_cached_lockstep<'s>(
        &self,
        caches: &mut [&mut KvCache],
        scratch: &'s mut ForwardScratch,
        new_tokens: &[f32],
        k: usize,
    ) -> Result<&'s [f32]> {
        let p = self.dims.patch;
        let d = self.dims.d_model;
        let h = self.dims.n_heads;
        let dh = self.dims.d_head();
        let f = self.dims.d_ff;
        let b = caches.len();
        anyhow::ensure!(b >= 1 && k >= 1, "forward_cached_lockstep needs b >= 1 and k >= 1");
        let n0 = caches[0].n;
        for c in caches.iter() {
            anyhow::ensure!(c.dims == self.dims, "KV cache built for different dims");
            anyhow::ensure!(
                c.n == n0,
                "lockstep caches must share a length: {} vs {n0}",
                c.n
            );
        }
        anyhow::ensure!(
            n0 + k <= self.dims.n_ctx,
            "KV cache overflow: {n0} + {k} > n_ctx {}",
            self.dims.n_ctx
        );
        anyhow::ensure!(
            new_tokens.len() == b * k * p,
            "forward_cached_lockstep: token buffer has {} values, want b*k*p = {}",
            new_tokens.len(),
            b * k * p
        );
        let rows = b * k;
        anyhow::ensure!(
            rows <= scratch.capacity_rows(),
            "lockstep scratch sized for {} rows, need {rows}",
            scratch.capacity_rows()
        );
        let scale = 1.0 / (dh as f32).sqrt();
        let s = scratch;
        kernel::embed_tokens(&self.pw, new_tokens, rows, p, d, &mut s.x);
        for lane in 0..b {
            // All lanes sit at the same absolute positions n0..n0+k.
            kernel::add_pos(&self.pw, d, n0, k, &mut s.x[lane * k * d..(lane + 1) * k * d]);
        }
        for (li, lw) in self.pw.layers.iter().enumerate() {
            kernel::qkv_rows(lw, &s.x, rows, d, &mut s.normed, &mut s.qkv);
            for (lane, cache) in caches.iter_mut().enumerate() {
                let q = &s.qkv[lane * k * 3 * d..(lane + 1) * k * 3 * d];
                kernel::append_kv(q, k, d, n0, &mut cache.k[li], &mut cache.v[li]);
                kernel::attn_rows(
                    q,
                    &cache.k[li],
                    &cache.v[li],
                    n0,
                    k,
                    h,
                    dh,
                    scale,
                    &mut s.scores,
                    &mut s.concat[lane * k * d..(lane + 1) * k * d],
                );
            }
            kernel::proj_residual_rows(lw, &s.concat, rows, d, &mut s.proj, &mut s.x);
            kernel::mlp_rows(lw, &mut s.x, rows, d, f, &mut s.normed, &mut s.gate, &mut s.up, &mut s.down);
        }
        kernel::head_rows(&self.pw, &mut s.x, rows, d, p, &mut s.out);
        for cache in caches.iter_mut() {
            cache.n = n0 + k;
        }
        Ok(&s.out[..rows * p])
    }

    // -----------------------------------------------------------------------
    // Reference (pre-kernel-layer) implementation: string-keyed weight
    // lookups, per-call allocation, naive matmul. The "before" side of the
    // kernel equivalence tests and the perf_hotpath naive flag.
    // -----------------------------------------------------------------------

    fn forward_reference(&self, tokens: &Tensor, b: usize, n: usize) -> Result<Tensor> {
        let d = self.dims.d_model;

        // Patch embedding + learned positions.
        let mut x =
            linear_naive(tokens, self.w.get("embed_w")?, Some(&self.w.get("embed_b")?.data[..]));
        let pos = self.w.get("pos")?;
        for bi in 0..b {
            for t in 0..n {
                let row = &mut x.data[(bi * n + t) * d..(bi * n + t + 1) * d];
                for (v, pv) in row.iter_mut().zip(&pos.data[t * d..(t + 1) * d]) {
                    *v += pv;
                }
            }
        }

        let mut scratch = RefScratch::new(&self.dims, b, n);
        for li in 0..self.dims.n_layers {
            self.attn_block_reference(li, &mut x, b, n, &mut scratch)?;
            self.mlp_block_reference(li, &mut x, b, n)?;
        }

        rmsnorm(&mut x.data, &self.w.get("final_norm")?.data, RMS_EPS);
        Ok(linear_naive(&x, self.w.get("head_w")?, Some(&self.w.get("head_b")?.data[..])))
    }

    fn attn_block_reference(
        &self,
        li: usize,
        x: &mut Tensor,
        b: usize,
        n: usize,
        s: &mut RefScratch,
    ) -> Result<()> {
        let d = self.dims.d_model;
        let h = self.dims.n_heads;
        let dh = self.dims.d_head();
        let scale = 1.0 / (dh as f32).sqrt();

        // Pre-norm into scratch.
        s.normed.data.copy_from_slice(&x.data);
        rmsnorm(&mut s.normed.data, &self.w.get(&format!("layers.{li}.ln1"))?.data, RMS_EPS);
        // QKV projection: [B*N, 3D]; layout per token = [3, H, Dh].
        let wqkv = self.w.get(&format!("layers.{li}.wqkv"))?;
        matmul_naive(&s.normed.data, &wqkv.data, b * n, d, 3 * d, &mut s.qkv.data);

        // Attention per (batch, head): scores in scratch, online over rows.
        for bi in 0..b {
            for hi in 0..h {
                // Gather q, k, v rows for this (b, h): stride-3D layout.
                for t in 0..n {
                    let base = (bi * n + t) * 3 * d;
                    let qoff = base + hi * dh;
                    let koff = base + d + hi * dh;
                    let voff = base + 2 * d + hi * dh;
                    s.q[t * dh..(t + 1) * dh].copy_from_slice(&s.qkv.data[qoff..qoff + dh]);
                    s.k[t * dh..(t + 1) * dh].copy_from_slice(&s.qkv.data[koff..koff + dh]);
                    s.v[t * dh..(t + 1) * dh].copy_from_slice(&s.qkv.data[voff..voff + dh]);
                }
                for t in 0..n {
                    let qrow = &s.q[t * dh..(t + 1) * dh];
                    let srow = &mut s.scores[..=t];
                    for (j, sv) in srow.iter_mut().enumerate() {
                        let krow = &s.k[j * dh..(j + 1) * dh];
                        *sv = qrow.iter().zip(krow).map(|(a, c)| a * c).sum::<f32>() * scale;
                    }
                    softmax_row(srow);
                    let orow = &mut s.attn_out[(t * dh)..(t + 1) * dh];
                    orow.fill(0.0);
                    for (j, &w) in srow.iter().enumerate() {
                        let vrow = &s.v[j * dh..(j + 1) * dh];
                        for (o, vv) in orow.iter_mut().zip(vrow) {
                            *o += w * vv;
                        }
                    }
                }
                // Scatter head output back into s.concat [B*N, D].
                for t in 0..n {
                    let dst = (bi * n + t) * d + hi * dh;
                    s.concat.data[dst..dst + dh]
                        .copy_from_slice(&s.attn_out[t * dh..(t + 1) * dh]);
                }
            }
        }
        // Output projection + residual.
        let wo = self.w.get(&format!("layers.{li}.wo"))?;
        matmul_naive(&s.concat.data, &wo.data, b * n, d, d, &mut s.proj.data);
        for (xv, pv) in x.data.iter_mut().zip(&s.proj.data) {
            *xv += pv;
        }
        Ok(())
    }

    fn mlp_block_reference(&self, li: usize, x: &mut Tensor, b: usize, n: usize) -> Result<()> {
        let d = self.dims.d_model;
        let f = self.dims.d_ff;
        let mut normed = x.clone();
        rmsnorm(&mut normed.data, &self.w.get(&format!("layers.{li}.ln2"))?.data, RMS_EPS);
        let wg = self.w.get(&format!("layers.{li}.wg"))?;
        let wu = self.w.get(&format!("layers.{li}.wu"))?;
        let wd = self.w.get(&format!("layers.{li}.wd"))?;
        let mut g = vec![0.0f32; b * n * f];
        let mut u = vec![0.0f32; b * n * f];
        matmul_naive(&normed.data, &wg.data, b * n, d, f, &mut g);
        matmul_naive(&normed.data, &wu.data, b * n, d, f, &mut u);
        for (gv, uv) in g.iter_mut().zip(&u) {
            *gv = silu(*gv) * uv;
        }
        let mut down = vec![0.0f32; b * n * d];
        matmul_naive(&g, &wd.data, b * n, f, d, &mut down);
        for (xv, dv) in x.data.iter_mut().zip(&down) {
            *xv += dv;
        }
        Ok(())
    }

    fn forward_cached_reference(
        &self,
        cache: &mut KvCache,
        new_tokens: &[f32],
        k: usize,
    ) -> Result<Vec<f32>> {
        let p = self.dims.patch;
        let d = self.dims.d_model;
        let h = self.dims.n_heads;
        let dh = self.dims.d_head();
        let n0 = cache.n;

        // Embed + learned positions for the new rows only.
        let t_in = Tensor::from_vec(&[k, p], new_tokens[..k * p].to_vec());
        let mut x =
            linear_naive(&t_in, self.w.get("embed_w")?, Some(&self.w.get("embed_b")?.data[..]));
        let pos = self.w.get("pos")?;
        for t in 0..k {
            let row = &mut x.data[t * d..(t + 1) * d];
            for (v, pv) in row.iter_mut().zip(&pos.data[(n0 + t) * d..(n0 + t + 1) * d]) {
                *v += pv;
            }
        }

        let scale = 1.0 / (dh as f32).sqrt();
        let mut normed = vec![0.0f32; k * d];
        let mut qkv = vec![0.0f32; k * 3 * d];
        let mut concat = vec![0.0f32; k * d];
        let mut proj = vec![0.0f32; k * d];
        let mut scores = vec![0.0f32; n0 + k];

        for li in 0..self.dims.n_layers {
            normed.copy_from_slice(&x.data);
            rmsnorm(&mut normed, &self.w.get(&format!("layers.{li}.ln1"))?.data, RMS_EPS);
            let wqkv = self.w.get(&format!("layers.{li}.wqkv"))?;
            matmul_naive(&normed, &wqkv.data, k, d, 3 * d, &mut qkv);

            // Append the new K/V rows (heads contiguous, as in the qkv
            // layout) before attending so a row can see itself.
            let kbuf = &mut cache.k[li];
            let vbuf = &mut cache.v[li];
            for t in 0..k {
                let base = t * 3 * d;
                kbuf[(n0 + t) * d..(n0 + t + 1) * d].copy_from_slice(&qkv[base + d..base + 2 * d]);
                vbuf[(n0 + t) * d..(n0 + t + 1) * d]
                    .copy_from_slice(&qkv[base + 2 * d..base + 3 * d]);
            }
            // Causal attention: new row at absolute position g attends over
            // cached rows 0..=g.
            for t in 0..k {
                let g = n0 + t;
                for hi in 0..h {
                    let q = &qkv[t * 3 * d + hi * dh..t * 3 * d + hi * dh + dh];
                    let srow = &mut scores[..=g];
                    for (j, sv) in srow.iter_mut().enumerate() {
                        let krow = &kbuf[j * d + hi * dh..j * d + hi * dh + dh];
                        *sv = q.iter().zip(krow).map(|(a, c)| a * c).sum::<f32>() * scale;
                    }
                    softmax_row(srow);
                    let orow = &mut concat[t * d + hi * dh..t * d + hi * dh + dh];
                    orow.fill(0.0);
                    for (j, &wj) in srow.iter().enumerate() {
                        let vrow = &vbuf[j * d + hi * dh..j * d + hi * dh + dh];
                        for (o, vv) in orow.iter_mut().zip(vrow) {
                            *o += wj * vv;
                        }
                    }
                }
            }
            let wo = self.w.get(&format!("layers.{li}.wo"))?;
            matmul_naive(&concat, &wo.data, k, d, d, &mut proj);
            for (xv, pv) in x.data.iter_mut().zip(&proj) {
                *xv += pv;
            }
            self.mlp_block_reference(li, &mut x, 1, k)?;
        }

        cache.n = n0 + k;
        rmsnorm(&mut x.data, &self.w.get("final_norm")?.data, RMS_EPS);
        Ok(linear_naive(&x, self.w.get("head_w")?, Some(&self.w.get("head_b")?.data[..]))
            .data
            .into_vec())
    }
}

/// Per-layer K/V ring buffers for incremental decoding, plus the owned
/// [`ForwardScratch`] arena (sized once for the steady-state worst case,
/// [`kernel::MAX_DECODE_ROWS`] rows, so every decode-sized
/// `forward_cached` is allocation-free; prefill-sized calls borrow a
/// temporary arena and may allocate).
///
/// Rows live at absolute positions `0..n` in fixed `[n_ctx * d_model]`
/// allocations (one K and one V buffer per layer, heads contiguous).
/// Rollback of rejected speculation is `truncate` (drop suffix rows —
/// the prefix stays valid because attention is causal). Window *slides*
/// are different: the learned absolute position embeddings make every
/// cached row position-dependent, so eviction from the front cannot
/// rotate rows in place — the session layer truncates and re-prefills
/// the kept suffix instead (see `models::NativeSession::evict_to`).
/// The speculative engine evicts once per round (freeing γ+1 slots), so
/// the re-prefill amortizes over the whole emitted block; a *saturated*
/// plain-AR decode slides one patch per step and therefore degenerates
/// to stateless cost at the window boundary — the price of keeping
/// eviction bit-equal to the stateless sliding-window rule.
pub struct KvCache {
    pub(crate) dims: ModelDims,
    /// Valid rows (patches) currently cached.
    pub(crate) n: usize,
    pub(crate) k: Vec<Vec<f32>>,
    pub(crate) v: Vec<Vec<f32>>,
    pub(crate) scratch: ForwardScratch,
}

impl KvCache {
    /// Empty cache with full-capacity K/V buffers and the owned scratch
    /// arena pre-sized for `dims`.
    pub fn new(dims: &ModelDims) -> KvCache {
        let cap = dims.n_ctx * dims.d_model;
        KvCache {
            dims: *dims,
            n: 0,
            k: (0..dims.n_layers).map(|_| vec![0.0; cap]).collect(),
            v: (0..dims.n_layers).map(|_| vec![0.0; cap]).collect(),
            scratch: ForwardScratch::for_cached(dims),
        }
    }

    /// Valid rows (patches) currently cached.
    pub fn len(&self) -> usize {
        self.n
    }

    /// Whether no rows are cached.
    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// Maximum rows (the model's n_ctx).
    pub fn capacity(&self) -> usize {
        self.dims.n_ctx
    }

    /// Forget everything (prelude to a re-prefill after a window slide).
    pub fn reset(&mut self) {
        self.n = 0;
    }

    /// Drop cached rows beyond `n` — the rollback of rejected speculation.
    pub fn truncate(&mut self, n: usize) {
        assert!(n <= self.n, "KvCache::truncate beyond cached rows");
        self.n = n;
    }
}

/// Per-branch scratch lanes for [`NativeModel::forward_cached_stacked`]:
/// each of up to [`kernel::MAX_STACK_LANES`] lanes gets a disjoint K/V
/// slice per layer (the branch suffix rows; the shared prefix stays in
/// the immutable [`KvCache`]) plus a slice of one stacked
/// [`ForwardScratch`] arena. Construction allocates nothing; buffers grow
/// lazily to a (lanes, rows, dims) high-water mark on first use and are
/// reused bit-for-bit afterwards, so steady-state stacked verify rounds
/// are zero-allocation (pinned by `tests/alloc_discipline.rs`).
#[derive(Default)]
pub struct StackedLanes {
    /// Dims the buffers were last sized for (resized on change).
    dims: Option<ModelDims>,
    /// Lane capacity (branches) currently allocated.
    lanes: usize,
    /// Row capacity per lane; the lane stride in `k`/`v` is `rows * d`.
    rows: usize,
    /// Per-layer branch K rows, `[lanes * rows * d_model]`, lane-major.
    k: Vec<Vec<f32>>,
    /// Per-layer branch V rows, same layout.
    v: Vec<Vec<f32>>,
    /// Stacked activation arena (`lanes * rows` rows), built on first use.
    scratch: Option<ForwardScratch>,
}

impl StackedLanes {
    /// Empty lane set; no buffers are allocated until the first stacked
    /// forward declares its (lanes, rows) shape.
    pub fn new() -> StackedLanes {
        StackedLanes::default()
    }

    /// Grow buffers to cover (`lanes`, `rows`) under `dims`; a no-op (and
    /// allocation-free) whenever the high-water mark already covers the
    /// request, which is every steady-state call.
    fn ensure(&mut self, dims: &ModelDims, lanes: usize, rows: usize) {
        let covered = self.dims.as_ref() == Some(dims)
            && lanes <= self.lanes
            && rows <= self.rows
            && self.scratch.is_some();
        if covered {
            return;
        }
        if self.dims.as_ref() != Some(dims) {
            // Dims changed: previous high-water marks are meaningless.
            self.lanes = 0;
            self.rows = 0;
        }
        self.dims = Some(*dims);
        self.lanes = self.lanes.max(lanes);
        self.rows = self.rows.max(rows);
        let cap = self.lanes * self.rows * dims.d_model;
        self.k = (0..dims.n_layers).map(|_| vec![0.0; cap]).collect();
        self.v = (0..dims.n_layers).map(|_| vec![0.0; cap]).collect();
        self.scratch = Some(ForwardScratch::for_prefill(dims, self.lanes * self.rows));
    }
}

/// Reusable per-forward scratch for the *reference* stateless path (the
/// kernel-layer path uses [`ForwardScratch`]).
struct RefScratch {
    normed: Tensor,
    qkv: Tensor,
    concat: Tensor,
    proj: Tensor,
    q: Vec<f32>,
    k: Vec<f32>,
    v: Vec<f32>,
    scores: Vec<f32>,
    attn_out: Vec<f32>,
}

impl RefScratch {
    fn new(dims: &ModelDims, b: usize, n: usize) -> RefScratch {
        let d = dims.d_model;
        let dh = dims.d_head();
        RefScratch {
            normed: Tensor::zeros(&[b * n, d]),
            qkv: Tensor::zeros(&[b * n, 3 * d]),
            concat: Tensor::zeros(&[b * n, d]),
            proj: Tensor::zeros(&[b * n, d]),
            q: vec![0.0; n * dh],
            k: vec![0.0; n * dh],
            v: vec![0.0; n * dh],
            scores: vec![0.0; n],
            attn_out: vec![0.0; n * dh],
        }
    }
}

/// Tiny random model for structural tests and serving benches (no
/// artifacts needed): patch 4, context 8, two layers. Exported at module
/// level (not under `cfg(test)`) because integration tests and benches
/// compile the library without the test cfg and need the same substrate.
pub fn tiny_model(seed: u64) -> NativeModel {
    let dims = ModelDims { patch: 4, n_ctx: 8, d_model: 8, n_layers: 2, n_heads: 2, d_ff: 16 };
    let mut w = Weights::default();
    let mut rng = Rng::new(seed);
    let mut t = |shape: &[usize], scale: f32| {
        let n: usize = shape.iter().product();
        Tensor::from_vec(shape, (0..n).map(|_| scale * rng.normal() as f32).collect())
    };
    w.insert("embed_w", t(&[4, 8], 0.3));
    w.insert("embed_b", Tensor::zeros(&[8]));
    w.insert("pos", t(&[8, 8], 0.1));
    for li in 0..2 {
        w.insert(&format!("layers.{li}.ln1"), Tensor::from_vec(&[8], vec![1.0; 8]));
        w.insert(&format!("layers.{li}.wqkv"), t(&[8, 24], 0.3));
        w.insert(&format!("layers.{li}.wo"), t(&[8, 8], 0.2));
        w.insert(&format!("layers.{li}.ln2"), Tensor::from_vec(&[8], vec![1.0; 8]));
        w.insert(&format!("layers.{li}.wg"), t(&[8, 16], 0.3));
        w.insert(&format!("layers.{li}.wu"), t(&[8, 16], 0.3));
        w.insert(&format!("layers.{li}.wd"), t(&[16, 8], 0.2));
    }
    w.insert("final_norm", Tensor::from_vec(&[8], vec![1.0; 8]));
    w.insert("head_w", t(&[8, 4], 0.3));
    w.insert("head_b", Tensor::zeros(&[4]));
    NativeModel::new("tiny", dims, w).unwrap()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn forward_shapes() {
        let m = tiny_model(1);
        let x = Tensor::zeros(&[2, 8, 4]);
        let y = m.forward(&x).unwrap();
        assert_eq!(y.shape, vec![2, 8, 4]);
        assert!(y.data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn packed_forward_matches_reference() {
        // The kernel layer (packed weights, arena, blocked matmul) must
        // reproduce the pre-kernel-layer implementation within fp
        // reassociation tolerance.
        let m = tiny_model(7);
        let mut r = tiny_model(7);
        r.set_reference(true);
        let mut rng = Rng::new(70);
        let toks: Vec<f32> = (0..2 * 8 * 4).map(|_| rng.normal() as f32).collect();
        let t = Tensor::from_vec(&[2, 8, 4], toks);
        let a = m.forward(&t).unwrap();
        let b = r.forward(&t).unwrap();
        for (i, (x, y)) in a.data.iter().zip(&b.data).enumerate() {
            assert!((x - y).abs() < 1e-5, "[{i}] packed {x} vs reference {y}");
        }
    }

    #[test]
    fn packed_cached_matches_reference_cached() {
        let m = tiny_model(8);
        let mut r = tiny_model(8);
        r.set_reference(true);
        let mut rng = Rng::new(80);
        let toks: Vec<f32> = (0..8 * 4).map(|_| rng.normal() as f32).collect();
        let mut c_m = KvCache::new(&m.dims);
        let mut c_r = KvCache::new(&r.dims);
        let a = m.forward_cached(&mut c_m, &toks[..5 * 4], 5).unwrap().to_vec();
        let b = r.forward_cached(&mut c_r, &toks[..5 * 4], 5).unwrap().to_vec();
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 1e-5, "prefill: packed {x} vs reference {y}");
        }
        let a = m.forward_cached(&mut c_m, &toks[5 * 4..], 3).unwrap().to_vec();
        let b = r.forward_cached(&mut c_r, &toks[5 * 4..], 3).unwrap().to_vec();
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 1e-5, "extend: packed {x} vs reference {y}");
        }
    }

    #[test]
    fn causality() {
        // Changing patch t must not change outputs at positions < t.
        let m = tiny_model(2);
        let mut rng = Rng::new(3);
        let base: Vec<f32> = (0..8 * 4).map(|_| rng.normal() as f32).collect();
        let y0 = m.forward(&Tensor::from_vec(&[1, 8, 4], base.clone())).unwrap();
        let mut perturbed = base.clone();
        for v in &mut perturbed[5 * 4..] {
            *v += 1.0;
        }
        let y1 = m.forward(&Tensor::from_vec(&[1, 8, 4], perturbed)).unwrap();
        for t in 0..5 {
            for i in 0..4 {
                let a = y0.data[t * 4 + i];
                let b = y1.data[t * 4 + i];
                assert!(
                    (a - b).abs() < 1e-6,
                    "position {t} changed by future perturbation: {a} vs {b}"
                );
            }
        }
        // ...and *must* change positions >= 5 (sanity that the test bites).
        let mut any = false;
        for t in 5..8 {
            for i in 0..4 {
                if (y0.data[t * 4 + i] - y1.data[t * 4 + i]).abs() > 1e-4 {
                    any = true;
                }
            }
        }
        assert!(any, "future positions unaffected — attention is broken");
    }

    #[test]
    fn batch_equals_loop() {
        // forward([a; b]) == [forward(a); forward(b)].
        let m = tiny_model(4);
        let mut rng = Rng::new(9);
        let a: Vec<f32> = (0..8 * 4).map(|_| rng.normal() as f32).collect();
        let b: Vec<f32> = (0..8 * 4).map(|_| rng.normal() as f32).collect();
        let mut ab = a.clone();
        ab.extend_from_slice(&b);
        let batched = m.forward(&Tensor::from_vec(&[2, 8, 4], ab)).unwrap();
        let ya = m.forward(&Tensor::from_vec(&[1, 8, 4], a)).unwrap();
        let yb = m.forward(&Tensor::from_vec(&[1, 8, 4], b)).unwrap();
        for i in 0..8 * 4 {
            assert!((batched.data[i] - ya.data[i]).abs() < 1e-5);
            assert!((batched.data[8 * 4 + i] - yb.data[i]).abs() < 1e-5);
        }
    }

    #[test]
    fn shorter_context_allowed() {
        let m = tiny_model(5);
        let x = Tensor::zeros(&[1, 3, 4]);
        let y = m.forward(&x).unwrap();
        assert_eq!(y.shape, vec![1, 3, 4]);
    }

    #[test]
    fn wrong_patch_dim_rejected() {
        let m = tiny_model(6);
        assert!(m.forward(&Tensor::zeros(&[1, 8, 5])).is_err());
        assert!(m.forward(&Tensor::zeros(&[1, 9, 4])).is_err());
    }

    #[test]
    fn cached_forward_matches_full() {
        // prefill 5 rows + incremental 3 rows == one stateless forward.
        let m = tiny_model(11);
        let mut rng = Rng::new(21);
        let toks: Vec<f32> = (0..8 * 4).map(|_| rng.normal() as f32).collect();
        let full = m.forward(&Tensor::from_vec(&[1, 8, 4], toks.clone())).unwrap();
        let mut cache = KvCache::new(&m.dims);
        let head = m.forward_cached(&mut cache, &toks[..5 * 4], 5).unwrap().to_vec();
        let tail = m.forward_cached(&mut cache, &toks[5 * 4..], 3).unwrap().to_vec();
        assert_eq!(cache.len(), 8);
        for (i, v) in head.iter().chain(tail.iter()).enumerate() {
            assert!(
                (v - full.data[i]).abs() < 1e-5,
                "row {} diverged: cached {v} vs full {}",
                i / 4,
                full.data[i]
            );
        }
    }

    #[test]
    fn cached_truncate_then_reextend_matches_full() {
        // Rollback (truncate) must leave the prefix usable: re-extending
        // with different patches equals a stateless forward of the spliced
        // sequence.
        let m = tiny_model(12);
        let mut rng = Rng::new(22);
        let toks: Vec<f32> = (0..8 * 4).map(|_| rng.normal() as f32).collect();
        let mut cache = KvCache::new(&m.dims);
        let _ = m.forward_cached(&mut cache, &toks, 8).unwrap();
        cache.truncate(4);
        let replacement: Vec<f32> = (0..2 * 4).map(|_| rng.normal() as f32).collect();
        let rows = m.forward_cached(&mut cache, &replacement, 2).unwrap().to_vec();
        let mut spliced = toks[..4 * 4].to_vec();
        spliced.extend_from_slice(&replacement);
        let full = m.forward(&Tensor::from_vec(&[1, 6, 4], spliced)).unwrap();
        for i in 0..2 * 4 {
            assert!((rows[i] - full.data[4 * 4 + i]).abs() < 1e-5);
        }
    }

    #[test]
    fn cached_overflow_rejected() {
        let m = tiny_model(13);
        let mut cache = KvCache::new(&m.dims);
        let toks = vec![0.1f32; 8 * 4];
        let _ = m.forward_cached(&mut cache, &toks, 8).unwrap();
        assert!(m.forward_cached(&mut cache, &toks[..4], 1).is_err());
    }

    #[test]
    fn prefill_beyond_arena_capacity_matches_stateless() {
        // n_ctx > MAX_DECODE_ROWS: the prefill takes the temporary-arena
        // path (k > capacity_rows) and must still equal the stateless
        // forward row-for-row; a subsequent small extend goes back through
        // the owned arena against the same cache.
        let dims =
            ModelDims { patch: 4, n_ctx: 96, d_model: 8, n_layers: 2, n_heads: 2, d_ff: 16 };
        assert!(dims.n_ctx > crate::nn::kernel::MAX_DECODE_ROWS);
        let m = NativeModel::random("long", dims, 31);
        let mut rng = Rng::new(32);
        let toks: Vec<f32> = (0..90 * 4).map(|_| rng.normal() as f32).collect();
        let full = m.forward(&Tensor::from_vec(&[1, 90, 4], toks.clone())).unwrap();
        let mut cache = KvCache::new(&dims);
        let head = m.forward_cached(&mut cache, &toks[..80 * 4], 80).unwrap().to_vec();
        for (i, v) in head.iter().enumerate() {
            assert!((v - full.data[i]).abs() < 1e-5, "prefill row {} diverged", i / 4);
        }
        let tail = m.forward_cached(&mut cache, &toks[80 * 4..], 10).unwrap().to_vec();
        for (i, v) in tail.iter().enumerate() {
            assert!(
                (v - full.data[80 * 4 + i]).abs() < 1e-5,
                "post-prefill extend row {} diverged",
                i / 4
            );
        }
    }

    #[test]
    fn stacked_forward_bitwise_equals_sequential_branches() {
        // k branch suffixes through ONE stacked forward against a shared
        // immutable prefix == k sequential forward_cached + truncate
        // passes, bit for bit.
        let m = tiny_model(17);
        let mut rng = Rng::new(27);
        let p = m.dims.patch;
        let prefix: Vec<f32> = (0..3 * p).map(|_| rng.normal() as f32).collect();
        let (b, k) = (3usize, 2usize);
        let branches: Vec<f32> = (0..b * k * p).map(|_| rng.normal() as f32).collect();
        let mut cache = KvCache::new(&m.dims);
        let _ = m.forward_cached(&mut cache, &prefix, 3).unwrap();
        let mut lanes = StackedLanes::new();
        let stacked =
            m.forward_cached_stacked(&cache, &mut lanes, &branches, b, k).unwrap().to_vec();
        assert_eq!(cache.len(), 3, "stacked verify must not grow the cache");
        for lane in 0..b {
            let rows = m
                .forward_cached(&mut cache, &branches[lane * k * p..(lane + 1) * k * p], k)
                .unwrap()
                .to_vec();
            cache.truncate(3);
            for (i, (x, y)) in rows.iter().zip(&stacked[lane * k * p..(lane + 1) * k * p]).enumerate()
            {
                assert_eq!(
                    x.to_bits(),
                    y.to_bits(),
                    "lane {lane} row {} diverged: sequential {x} vs stacked {y}",
                    i / p
                );
            }
        }
    }

    #[test]
    fn lockstep_forward_bitwise_equals_serial_caches() {
        // b independent sequences advanced k patches in one stacked round
        // == b serial forward_cached calls, bit for bit, with every cache
        // advanced.
        let m = tiny_model(19);
        let mut rng = Rng::new(29);
        let p = m.dims.patch;
        let (b, k) = (3usize, 2usize);
        let prefixes: Vec<Vec<f32>> = (0..b)
            .map(|_| (0..3 * p).map(|_| rng.normal() as f32).collect())
            .collect();
        let steps: Vec<f32> = (0..b * k * p).map(|_| rng.normal() as f32).collect();
        let mut serial_rows = Vec::new();
        for lane in 0..b {
            let mut c = KvCache::new(&m.dims);
            let _ = m.forward_cached(&mut c, &prefixes[lane], 3).unwrap();
            serial_rows.push(
                m.forward_cached(&mut c, &steps[lane * k * p..(lane + 1) * k * p], k)
                    .unwrap()
                    .to_vec(),
            );
        }
        let mut caches: Vec<KvCache> = (0..b).map(|_| KvCache::new(&m.dims)).collect();
        for lane in 0..b {
            let _ = m.forward_cached(&mut caches[lane], &prefixes[lane], 3).unwrap();
        }
        let mut refs: Vec<&mut KvCache> = caches.iter_mut().collect();
        let mut scratch = ForwardScratch::for_prefill(&m.dims, b * k);
        let rows = m.forward_cached_lockstep(&mut refs, &mut scratch, &steps, k).unwrap().to_vec();
        for lane in 0..b {
            assert_eq!(caches[lane].len(), 5, "lane {lane} cache did not advance");
            for (i, (x, y)) in
                serial_rows[lane].iter().zip(&rows[lane * k * p..(lane + 1) * k * p]).enumerate()
            {
                assert_eq!(x.to_bits(), y.to_bits(), "lane {lane} elem {i} diverged");
            }
        }
        // Mismatched lengths are a typed error, not a panic.
        let mut c_short = KvCache::new(&m.dims);
        let _ = m.forward_cached(&mut c_short, &prefixes[0][..2 * p], 2).unwrap();
        let mut c_ok = KvCache::new(&m.dims);
        let _ = m.forward_cached(&mut c_ok, &prefixes[1], 3).unwrap();
        let mut uneven: Vec<&mut KvCache> = vec![&mut c_short, &mut c_ok];
        assert!(m.forward_cached_lockstep(&mut uneven, &mut scratch, &steps[..2 * k * p], k).is_err());
    }

    #[test]
    fn stacked_forward_types_errors_not_panics() {
        let m = tiny_model(18);
        let p = m.dims.patch;
        let mut cache = KvCache::new(&m.dims);
        let _ = m.forward_cached(&mut cache, &vec![0.1; 3 * p], 3).unwrap();
        let mut lanes = StackedLanes::new();
        let toks = vec![0.1f32; 2 * 2 * p];
        assert!(m.forward_cached_stacked(&cache, &mut lanes, &toks, 0, 2).is_err(), "b = 0");
        assert!(m.forward_cached_stacked(&cache, &mut lanes, &toks, 2, 0).is_err(), "k = 0");
        assert!(m.forward_cached_stacked(&cache, &mut lanes, &toks[1..], 2, 2).is_err(), "short");
        assert!(
            m.forward_cached_stacked(&cache, &mut lanes, &toks, 17, 2).is_err(),
            "lanes beyond MAX_STACK_LANES"
        );
        assert!(
            m.forward_cached_stacked(&cache, &mut lanes, &vec![0.1; 2 * 6 * p], 2, 6).is_err(),
            "n0 + k beyond n_ctx"
        );
    }

    #[test]
    fn random_model_forward_is_finite() {
        let dims =
            ModelDims { patch: 4, n_ctx: 32, d_model: 16, n_layers: 3, n_heads: 4, d_ff: 32 };
        let m = NativeModel::random("rnd", dims, 7);
        let mut rng = Rng::new(8);
        let toks: Vec<f32> = (0..32 * 4).map(|_| rng.normal() as f32).collect();
        let y = m.forward(&Tensor::from_vec(&[1, 32, 4], toks)).unwrap();
        assert!(y.data.iter().all(|v| v.is_finite()));
    }
}
