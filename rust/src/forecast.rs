//! Forecast decoding modes and accuracy evaluation — the paper's baselines
//! (§4.1.3): (i) target-only autoregression, (ii) draft-only decoding,
//! (iii) speculative decoding, plus MSE/MAE evaluation over eval windows.
//!
//! All AR decoders drive [`crate::models::DecodeSession`]s: with the KV
//! cache on (the default), a step costs one incremental forward instead of
//! a full-context re-forward; `ar_decode_with` exposes the toggle so the
//! benches can report cached-vs-uncached baselines.

use std::time::{Duration, Instant};

use anyhow::Result;

use crate::data::Window;
use crate::models::{begin_batch_session, begin_session, Backend, CacheMode};
use crate::specdec::{sd_generate, DecodeStats, SpecConfig};
use crate::util::rng::Rng;
use crate::util::tensor::mse_mae;

/// Plain autoregressive decode with a single model: one sequential model
/// read per emitted patch, greedy (mean) emission — the paper's target
/// baseline protocol. KV-cached when the backend supports it.
pub fn ar_decode(
    model: &dyn Backend,
    history: &[f32],
    n_hist: usize,
    horizon: usize,
) -> Result<(Vec<f32>, Duration, usize)> {
    ar_decode_with(model, history, n_hist, horizon, CacheMode::On)
}

/// [`ar_decode`] with an explicit cache toggle (the A/B hook for the
/// `perf_hotpath` cached sweep). Returned `calls` counts sequential decode
/// steps (one model read per emitted patch), identical across modes.
pub fn ar_decode_with(
    model: &dyn Backend,
    history: &[f32],
    n_hist: usize,
    horizon: usize,
    cache: CacheMode,
) -> Result<(Vec<f32>, Duration, usize)> {
    let p = model.patch();
    let t0 = Instant::now();
    let mut sess = begin_session(model, cache, history, n_hist)?;
    let mut out = Vec::with_capacity(horizon * p);
    let mut calls = 0usize;
    for _ in 0..horizon {
        let mu = sess.tip_mean()?;
        crate::specdec::ensure_finite(&mu, "AR tip mean")?;
        calls += 1;
        out.extend_from_slice(&mu);
        // Sessions slide their window internally at max_ctx, matching the
        // old drain-from-front rule.
        sess.append(&mu, 1)?;
    }
    Ok((out, t0.elapsed(), calls))
}

/// Stochastic AR decode (samples N(mu, sigma^2 I) each step) — the
/// like-for-like baseline for SD's generative protocol.
pub fn ar_decode_stochastic(
    model: &dyn Backend,
    history: &[f32],
    n_hist: usize,
    horizon: usize,
    sigma: f64,
    seed: u64,
) -> Result<(Vec<f32>, Duration)> {
    let p = model.patch();
    let mut rng = Rng::new(seed);
    let t0 = Instant::now();
    let mut sess = begin_session(model, CacheMode::On, history, n_hist)?;
    let mut out = Vec::with_capacity(horizon * p);
    for _ in 0..horizon {
        let mu = sess.tip_mean()?;
        crate::specdec::ensure_finite(&mu, "AR tip mean")?;
        let mut x = vec![0.0f32; p];
        rng.fill_normal_around(&mu, sigma as f32, &mut x);
        out.extend_from_slice(&x);
        sess.append(&x, 1)?;
    }
    Ok((out, t0.elapsed()))
}

/// Batched greedy AR decode: all sequences advance one patch per round
/// over a [`crate::models::BatchDecodeSession`] (one batched read per
/// step; per-sequence KV caches when the backend supports them). The
/// baseline for the paper's batch>1 rows. Sequences may differ in history
/// length; horizons must match.
pub fn ar_decode_batch(
    model: &dyn Backend,
    tasks: &[(&[f32], usize, usize)],
    // (history, n_hist, horizon)
) -> Result<(Vec<Vec<f32>>, Duration)> {
    let p = model.patch();
    anyhow::ensure!(!tasks.is_empty());
    let horizon = tasks[0].2;
    anyhow::ensure!(tasks.iter().all(|t| t.2 == horizon), "batched AR needs equal horizons");
    let t0 = Instant::now();
    let sess_tasks: Vec<(&[f32], usize)> = tasks.iter().map(|(h, n, _)| (*h, *n)).collect();
    let mut bs = begin_batch_session(model, CacheMode::On, &sess_tasks)?;
    let idx: Vec<usize> = (0..tasks.len()).collect();
    let mut outs: Vec<Vec<f32>> = vec![Vec::with_capacity(horizon * p); tasks.len()];
    for _ in 0..horizon {
        let mus = bs.tip_means(&idx)?;
        crate::specdec::ensure_finite(&mus, "batched AR tip means")?;
        for (ai, &i) in idx.iter().enumerate() {
            let mu = &mus[ai * p..(ai + 1) * p];
            outs[i].extend_from_slice(mu);
            bs.append(i, mu, 1)?;
        }
    }
    Ok((outs, t0.elapsed()))
}

/// Accuracy + efficiency over a set of eval windows for one decoding mode.
#[derive(Clone, Debug, Default)]
pub struct EvalResult {
    /// Windows evaluated.
    pub windows: usize,
    /// Mean squared error over all windows.
    pub mse: f64,
    /// Mean absolute error over all windows.
    pub mae: f64,
    /// Total decode wall-clock.
    pub wall: Duration,
    /// Emitted patches (throughput numerator).
    pub patches: usize,
    /// SD-only: aggregated decode stats.
    pub sd: DecodeStats,
}

impl EvalResult {
    /// Decode throughput in patches per second.
    pub fn throughput_patches_per_s(&self) -> f64 {
        self.patches as f64 / self.wall.as_secs_f64()
    }
}

/// Evaluate target-only AR (greedy) over windows.
pub fn eval_ar(model: &dyn Backend, windows: &[Window], patch: usize) -> Result<EvalResult> {
    let mut r = EvalResult::default();
    let (mut se, mut ae) = (0.0, 0.0);
    for w in windows {
        let n_hist = w.history.len() / patch;
        let horizon = w.future.len() / patch;
        let (pred, wall, _calls) = ar_decode(model, &w.history, n_hist, horizon)?;
        let (mse, mae) = mse_mae(&pred, &w.future);
        se += mse;
        ae += mae;
        r.wall += wall;
        r.patches += horizon;
        r.windows += 1;
    }
    r.mse = se / r.windows as f64;
    r.mae = ae / r.windows as f64;
    Ok(r)
}

/// Evaluate speculative decoding over windows.
pub fn eval_sd(
    target: &dyn Backend,
    draft: &dyn Backend,
    windows: &[Window],
    patch: usize,
    cfg: &SpecConfig,
) -> Result<EvalResult> {
    let mut r = EvalResult::default();
    let (mut se, mut ae) = (0.0, 0.0);
    for (i, w) in windows.iter().enumerate() {
        let n_hist = w.history.len() / patch;
        let horizon = w.future.len() / patch;
        let mut c = *cfg;
        c.seed = cfg.seed.wrapping_add(i as u64 * 0x9E37);
        let t0 = Instant::now();
        let out = sd_generate(target, draft, &w.history, n_hist, horizon, &c)?;
        r.wall += t0.elapsed();
        let (mse, mae) = mse_mae(&out.patches, &w.future);
        se += mse;
        ae += mae;
        r.patches += horizon;
        r.windows += 1;
        r.sd.merge(&out.stats);
    }
    r.mse = se / r.windows as f64;
    r.mae = ae / r.windows as f64;
    Ok(r)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::AnalyticBackend;

    fn window(patch: usize, n_hist: usize, horizon: usize) -> Window {
        Window {
            channel: 0,
            start: 0,
            history: (0..n_hist * patch).map(|i| (i as f32 * 0.3).sin()).collect(),
            future: (0..horizon * patch).map(|i| (i as f32 * 0.3).cos()).collect(),
        }
    }

    #[test]
    fn ar_decode_emits_horizon() {
        let m = AnalyticBackend::new("t", 3, 0.9, 0.0);
        let w = window(3, 4, 5);
        let (pred, _, calls) = ar_decode(&m, &w.history, 4, 5).unwrap();
        assert_eq!(pred.len(), 15);
        assert_eq!(calls, 5);
    }

    #[test]
    fn eval_ar_and_sd_shapes() {
        let t = AnalyticBackend::new("t", 2, 0.8, 0.1);
        let d = AnalyticBackend::new("d", 2, 0.78, 0.1);
        let ws: Vec<Window> = (0..4).map(|_| window(2, 3, 6)).collect();
        let ar = eval_ar(&t, &ws, 2).unwrap();
        assert_eq!(ar.windows, 4);
        assert_eq!(ar.patches, 24);
        assert!(ar.mse.is_finite() && ar.mae.is_finite());

        let sd = eval_sd(&t, &d, &ws, 2, &SpecConfig::default()).unwrap();
        assert_eq!(sd.windows, 4);
        assert!(sd.sd.rounds > 0);
        assert!(sd.sd.alpha_hat() > 0.0);
        assert!(sd.throughput_patches_per_s() > 0.0);
    }

    #[test]
    fn ar_decode_cache_toggle_identical() {
        // Cached AR must emit the same forecast as the uncached baseline,
        // including once the window starts sliding.
        use crate::models::NativeBackend;
        use crate::nn::model::tiny_model;
        let m = NativeBackend::new(tiny_model(17));
        let hist: Vec<f32> = (0..3 * 4).map(|i| (i as f32 * 0.21).sin()).collect();
        let (on, _, calls_on) = ar_decode_with(&m, &hist, 3, 12, CacheMode::On).unwrap();
        let (off, _, calls_off) = ar_decode_with(&m, &hist, 3, 12, CacheMode::Off).unwrap();
        assert_eq!(calls_on, calls_off);
        assert_eq!(on.len(), off.len());
        for (a, b) in on.iter().zip(&off) {
            assert!((a - b).abs() < 1e-5, "cached {a} vs uncached {b}");
        }
    }

    #[test]
    fn greedy_ar_beats_stochastic_on_mse() {
        // Adding sigma-noise to emissions must not *reduce* error on
        // average — the sigma/MSE mechanism behind the paper's Fig. 6.
        let t = AnalyticBackend::new("t", 2, 0.8, 0.1);
        let ws: Vec<Window> = (0..6).map(|_| window(2, 3, 8)).collect();
        let greedy = eval_ar(&t, &ws, 2).unwrap();
        let mut se = 0.0;
        for (i, w) in ws.iter().enumerate() {
            let (pred, _) =
                ar_decode_stochastic(&t, &w.history, 3, 8, 0.8, 7 + i as u64).unwrap();
            se += mse_mae(&pred, &w.future).0;
        }
        let stoch_mse = se / ws.len() as f64;
        assert!(stoch_mse > greedy.mse, "stochastic {stoch_mse} vs greedy {}", greedy.mse);
    }
}
