//! Forecast decoding modes and accuracy evaluation — the paper's baselines
//! (§4.1.3): (i) target-only autoregression, (ii) draft-only decoding,
//! (iii) speculative decoding, plus MSE/MAE evaluation over eval windows.

use std::time::{Duration, Instant};

use anyhow::Result;

use crate::data::Window;
use crate::models::Backend;
use crate::specdec::{sd_generate, DecodeStats, SpecConfig};
use crate::util::rng::Rng;
use crate::util::tensor::mse_mae;

/// Plain autoregressive decode with a single model: one forward per emitted
/// patch, greedy (mean) emission — the paper's target baseline protocol.
pub fn ar_decode(
    model: &dyn Backend,
    history: &[f32],
    n_hist: usize,
    horizon: usize,
) -> Result<(Vec<f32>, Duration, usize)> {
    let p = model.patch();
    let mut ctx: Vec<f32> = history[..n_hist * p].to_vec();
    let mut out = Vec::with_capacity(horizon * p);
    let t0 = Instant::now();
    let mut calls = 0usize;
    for _ in 0..horizon {
        let n = (ctx.len() / p).min(model.max_ctx());
        if ctx.len() / p > model.max_ctx() {
            let drop = ctx.len() / p - model.max_ctx();
            ctx.drain(..drop * p);
        }
        let means = model.forward(&ctx, n)?;
        calls += 1;
        let mu = &means[(n - 1) * p..n * p];
        out.extend_from_slice(mu);
        ctx.extend_from_slice(mu);
    }
    Ok((out, t0.elapsed(), calls))
}

/// Stochastic AR decode (samples N(mu, sigma^2 I) each step) — the
/// like-for-like baseline for SD's generative protocol.
pub fn ar_decode_stochastic(
    model: &dyn Backend,
    history: &[f32],
    n_hist: usize,
    horizon: usize,
    sigma: f64,
    seed: u64,
) -> Result<(Vec<f32>, Duration)> {
    let p = model.patch();
    let mut rng = Rng::new(seed);
    let mut ctx: Vec<f32> = history[..n_hist * p].to_vec();
    let mut out = Vec::with_capacity(horizon * p);
    let t0 = Instant::now();
    for _ in 0..horizon {
        if ctx.len() / p > model.max_ctx() {
            let drop = ctx.len() / p - model.max_ctx();
            ctx.drain(..drop * p);
        }
        let n = ctx.len() / p;
        let means = model.forward(&ctx, n)?;
        let mu = &means[(n - 1) * p..n * p];
        let mut x = vec![0.0f32; p];
        rng.fill_normal_around(mu, sigma as f32, &mut x);
        out.extend_from_slice(&x);
        ctx.extend_from_slice(&x);
    }
    Ok((out, t0.elapsed()))
}

/// Batched greedy AR decode: all sequences advance one patch per round via
/// one batched forward (the baseline for the paper's batch>1 rows).
/// Sequences may differ in history length; horizons must match.
pub fn ar_decode_batch(
    model: &dyn Backend,
    tasks: &[(&[f32], usize, usize)],
    // (history, n_hist, horizon)
) -> Result<(Vec<Vec<f32>>, Duration)> {
    let p = model.patch();
    anyhow::ensure!(!tasks.is_empty());
    let horizon = tasks[0].2;
    anyhow::ensure!(tasks.iter().all(|t| t.2 == horizon), "batched AR needs equal horizons");
    let mut ctxs: Vec<Vec<f32>> = tasks.iter().map(|(h, n, _)| h[..n * p].to_vec()).collect();
    let mut outs: Vec<Vec<f32>> = vec![Vec::with_capacity(horizon * p); tasks.len()];
    let t0 = Instant::now();
    for _ in 0..horizon {
        for ctx in ctxs.iter_mut() {
            if ctx.len() / p > model.max_ctx() {
                let drop = ctx.len() / p - model.max_ctx();
                ctx.drain(..drop * p);
            }
        }
        let n_max = ctxs.iter().map(|c| c.len() / p).max().unwrap();
        let mut buf = vec![0.0f32; tasks.len() * n_max * p];
        for (i, ctx) in ctxs.iter().enumerate() {
            buf[i * n_max * p..i * n_max * p + ctx.len()].copy_from_slice(ctx);
        }
        let means = model.forward_batch(&buf, tasks.len(), n_max)?;
        for (i, ctx) in ctxs.iter_mut().enumerate() {
            let n_i = ctx.len() / p;
            let off = i * n_max * p + (n_i - 1) * p;
            let mu = &means[off..off + p];
            outs[i].extend_from_slice(mu);
            ctx.extend_from_slice(mu);
        }
    }
    Ok((outs, t0.elapsed()))
}

/// Accuracy + efficiency over a set of eval windows for one decoding mode.
#[derive(Clone, Debug, Default)]
pub struct EvalResult {
    pub windows: usize,
    pub mse: f64,
    pub mae: f64,
    /// Total decode wall-clock.
    pub wall: Duration,
    /// Emitted patches (throughput numerator).
    pub patches: usize,
    /// SD-only: aggregated decode stats.
    pub sd: DecodeStats,
}

impl EvalResult {
    pub fn throughput_patches_per_s(&self) -> f64 {
        self.patches as f64 / self.wall.as_secs_f64()
    }
}

/// Evaluate target-only AR (greedy) over windows.
pub fn eval_ar(model: &dyn Backend, windows: &[Window], patch: usize) -> Result<EvalResult> {
    let mut r = EvalResult::default();
    let (mut se, mut ae) = (0.0, 0.0);
    for w in windows {
        let n_hist = w.history.len() / patch;
        let horizon = w.future.len() / patch;
        let (pred, wall, _calls) = ar_decode(model, &w.history, n_hist, horizon)?;
        let (mse, mae) = mse_mae(&pred, &w.future);
        se += mse;
        ae += mae;
        r.wall += wall;
        r.patches += horizon;
        r.windows += 1;
    }
    r.mse = se / r.windows as f64;
    r.mae = ae / r.windows as f64;
    Ok(r)
}

/// Evaluate speculative decoding over windows.
pub fn eval_sd(
    target: &dyn Backend,
    draft: &dyn Backend,
    windows: &[Window],
    patch: usize,
    cfg: &SpecConfig,
) -> Result<EvalResult> {
    let mut r = EvalResult::default();
    let (mut se, mut ae) = (0.0, 0.0);
    for (i, w) in windows.iter().enumerate() {
        let n_hist = w.history.len() / patch;
        let horizon = w.future.len() / patch;
        let mut c = *cfg;
        c.seed = cfg.seed.wrapping_add(i as u64 * 0x9E37);
        let t0 = Instant::now();
        let out = sd_generate(target, draft, &w.history, n_hist, horizon, &c)?;
        r.wall += t0.elapsed();
        let (mse, mae) = mse_mae(&out.patches, &w.future);
        se += mse;
        ae += mae;
        r.patches += horizon;
        r.windows += 1;
        r.sd.merge(&out.stats);
    }
    r.mse = se / r.windows as f64;
    r.mae = ae / r.windows as f64;
    Ok(r)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::AnalyticBackend;

    fn window(patch: usize, n_hist: usize, horizon: usize) -> Window {
        Window {
            channel: 0,
            start: 0,
            history: (0..n_hist * patch).map(|i| (i as f32 * 0.3).sin()).collect(),
            future: (0..horizon * patch).map(|i| (i as f32 * 0.3).cos()).collect(),
        }
    }

    #[test]
    fn ar_decode_emits_horizon() {
        let m = AnalyticBackend::new("t", 3, 0.9, 0.0);
        let w = window(3, 4, 5);
        let (pred, _, calls) = ar_decode(&m, &w.history, 4, 5).unwrap();
        assert_eq!(pred.len(), 15);
        assert_eq!(calls, 5);
    }

    #[test]
    fn eval_ar_and_sd_shapes() {
        let t = AnalyticBackend::new("t", 2, 0.8, 0.1);
        let d = AnalyticBackend::new("d", 2, 0.78, 0.1);
        let ws: Vec<Window> = (0..4).map(|_| window(2, 3, 6)).collect();
        let ar = eval_ar(&t, &ws, 2).unwrap();
        assert_eq!(ar.windows, 4);
        assert_eq!(ar.patches, 24);
        assert!(ar.mse.is_finite() && ar.mae.is_finite());

        let sd = eval_sd(&t, &d, &ws, 2, &SpecConfig::default()).unwrap();
        assert_eq!(sd.windows, 4);
        assert!(sd.sd.rounds > 0);
        assert!(sd.sd.alpha_hat() > 0.0);
        assert!(sd.throughput_patches_per_s() > 0.0);
    }

    #[test]
    fn greedy_ar_beats_stochastic_on_mse() {
        // Adding sigma-noise to emissions must not *reduce* error on
        // average — the sigma/MSE mechanism behind the paper's Fig. 6.
        let t = AnalyticBackend::new("t", 2, 0.8, 0.1);
        let ws: Vec<Window> = (0..6).map(|_| window(2, 3, 8)).collect();
        let greedy = eval_ar(&t, &ws, 2).unwrap();
        let mut se = 0.0;
        for (i, w) in ws.iter().enumerate() {
            let (pred, _) =
                ar_decode_stochastic(&t, &w.history, 3, 8, 0.8, 7 + i as u64).unwrap();
            se += mse_mae(&pred, &w.future).0;
        }
        let stoch_mse = se / ws.len() as f64;
        assert!(stoch_mse > greedy.mse, "stochastic {stoch_mse} vs greedy {}", greedy.mse);
    }
}
