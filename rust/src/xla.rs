//! Offline stub of the `xla` (PJRT) crate's API surface.
//!
//! The runtime layer was written against the external `xla` crate
//! (PJRT CPU client over AOT HLO artifacts), which is not available in
//! the offline build environment. This module mirrors exactly the API
//! the repo touches — [`PjRtClient`], [`PjRtLoadedExecutable`],
//! [`Literal`], [`HloModuleProto`], [`XlaComputation`] — so everything
//! compiles and all non-XLA paths (native backend, analytic heads, the
//! whole specdec/serving stack) work unchanged. Any attempt to actually
//! *use* PJRT fails fast at [`PjRtClient::cpu`] with a clear message.
//!
//! Restoring real PJRT execution is a two-line change: add the `xla`
//! dependency to `Cargo.toml` and delete the `use crate::xla;` aliases
//! in `runtime::engine` and `tests/smoke_hlo.rs` (plus this module).
//! Every call site is API-compatible by construction.

use std::fmt;

/// Error type mirroring `xla::Error` closely enough for `anyhow` context
/// chaining.
#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla (stub): {}", self.0)
    }
}

impl std::error::Error for Error {}

/// Stub result type.
pub type Result<T> = std::result::Result<T, Error>;

const UNAVAILABLE: &str = "PJRT/XLA is unavailable in this build (the `xla` crate is not \
     vendored offline); use --backend native, or add the `xla` dependency \
     to Cargo.toml to restore this path";

/// A host tensor literal (stub: carries no data).
#[derive(Debug, Clone)]
pub struct Literal;

impl Literal {
    /// Build a rank-1 literal from a float slice.
    pub fn vec1(_data: &[f32]) -> Literal {
        Literal
    }

    /// Reshape to the given dimensions.
    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(Error(UNAVAILABLE.into()))
    }

    /// Unwrap a 1-tuple result literal.
    pub fn to_tuple1(self) -> Result<Literal> {
        Err(Error(UNAVAILABLE.into()))
    }

    /// Copy out as a typed vector.
    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(Error(UNAVAILABLE.into()))
    }

    /// Synchronous device-to-host transfer.
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error(UNAVAILABLE.into()))
    }
}

/// Parsed HLO module (stub: never constructible at runtime).
#[derive(Debug)]
pub struct HloModuleProto;

impl HloModuleProto {
    /// Parse an HLO-text file.
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(Error(UNAVAILABLE.into()))
    }
}

/// An XLA computation wrapping an HLO module.
#[derive(Debug)]
pub struct XlaComputation;

impl XlaComputation {
    /// Wrap a parsed HLO module.
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// A compiled executable (stub: never constructible at runtime).
#[derive(Debug)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    /// Execute on device buffers; indexed `[device][output]`.
    pub fn execute<T>(&self, _args: &[Literal]) -> Result<Vec<Vec<Literal>>> {
        Err(Error(UNAVAILABLE.into()))
    }
}

/// The PJRT client (stub: construction always fails with a clear message).
#[derive(Debug)]
pub struct PjRtClient;

impl PjRtClient {
    /// Create a CPU client. Always fails in the stub build.
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error(UNAVAILABLE.into()))
    }

    /// Platform name ("cpu" in the stub).
    pub fn platform_name(&self) -> String {
        "cpu-stub".to_string()
    }

    /// Compile a computation.
    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error(UNAVAILABLE.into()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_fails_fast_with_clear_message() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.to_string().contains("unavailable"), "{err}");
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
    }

    #[test]
    fn stub_error_chains_through_anyhow() {
        use anyhow::Context;
        let r: anyhow::Result<PjRtClient> =
            PjRtClient::cpu().context("creating PJRT CPU client");
        let msg = format!("{:#}", r.unwrap_err());
        assert!(msg.contains("creating PJRT CPU client"));
        assert!(msg.contains("unavailable"));
    }
}
