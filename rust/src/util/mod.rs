//! Hand-rolled substrates (no third-party crates are available offline
//! beyond the `xla` dependency chain — see DESIGN.md §4).

pub mod json;
pub mod microbench;
pub mod mmap;
pub mod proptest_lite;
pub mod rng;
pub mod stats;
pub mod tensor;
pub mod threadpool;
