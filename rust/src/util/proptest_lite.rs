//! Property-testing substrate (proptest is unavailable offline).
//!
//! A seeded generator + shrinking-lite runner: on failure, retries with
//! "smaller" inputs produced by the generator's `shrink` hook and reports
//! the smallest failing case. Used for coordinator invariants (routing,
//! batching, specdec state) per the repo testing policy.

use super::rng::Rng;

/// A value generator with an optional shrinker.
pub trait Gen {
    /// The generated value type.
    type Value: std::fmt::Debug + Clone;
    /// Draw one value from the generator's distribution.
    fn generate(&self, rng: &mut Rng) -> Self::Value;
    /// Candidate smaller values; default none.
    fn shrink(&self, _v: &Self::Value) -> Vec<Self::Value> {
        Vec::new()
    }
}

/// Runner configuration.
pub struct Config {
    /// Generated inputs per property.
    pub cases: usize,
    /// RNG seed (reported on failure for reproduction).
    pub seed: u64,
    /// Cap on shrink attempts after a failure.
    pub max_shrink_rounds: usize,
}

impl Default for Config {
    fn default() -> Self {
        Config { cases: 200, seed: 0x5712DE, max_shrink_rounds: 200 }
    }
}

/// Run `prop` over `cases` generated inputs; panic with the smallest
/// failing input found.
pub fn check<G: Gen, F: Fn(&G::Value) -> Result<(), String>>(gen: &G, prop: F) {
    check_with(Config::default(), gen, prop)
}

/// [`check`] with an explicit [`Config`] (case count, seed, shrink cap).
pub fn check_with<G: Gen, F: Fn(&G::Value) -> Result<(), String>>(
    cfg: Config,
    gen: &G,
    prop: F,
) {
    let mut rng = Rng::new(cfg.seed);
    for case in 0..cfg.cases {
        let v = gen.generate(&mut rng);
        if let Err(msg) = prop(&v) {
            // Shrink.
            let mut best = v.clone();
            let mut best_msg = msg;
            let mut rounds = 0;
            'outer: loop {
                for cand in gen.shrink(&best) {
                    rounds += 1;
                    if rounds > cfg.max_shrink_rounds {
                        break 'outer;
                    }
                    if let Err(m) = prop(&cand) {
                        best = cand;
                        best_msg = m;
                        continue 'outer;
                    }
                }
                break;
            }
            panic!(
                "property failed (case {case}, seed {:#x}):\n  input: {:?}\n  error: {}",
                cfg.seed, best, best_msg
            );
        }
    }
}

// ---------------------------------------------------------------------------
// Common generators.
// ---------------------------------------------------------------------------

/// Uniform f64 in [lo, hi]; shrinks toward lo.
pub struct F64Range(pub f64, pub f64);

impl Gen for F64Range {
    type Value = f64;
    fn generate(&self, rng: &mut Rng) -> f64 {
        rng.range(self.0, self.1)
    }
    fn shrink(&self, v: &f64) -> Vec<f64> {
        let mut out = Vec::new();
        if *v > self.0 {
            out.push(self.0);
            out.push(self.0 + (*v - self.0) / 2.0);
        }
        out
    }
}

/// Uniform usize in [lo, hi]; shrinks toward lo.
pub struct UsizeRange(pub usize, pub usize);

impl Gen for UsizeRange {
    type Value = usize;
    fn generate(&self, rng: &mut Rng) -> usize {
        self.0 + rng.below(self.1 - self.0 + 1)
    }
    fn shrink(&self, v: &usize) -> Vec<usize> {
        let mut out = Vec::new();
        if *v > self.0 {
            out.push(self.0);
            out.push(self.0 + (*v - self.0) / 2);
        }
        out
    }
}

/// Vec of f32 drawn from N(0, scale); shrinks by halving length.
pub struct NormalVec {
    /// Length range of the generated vector.
    pub len: UsizeRange,
    /// Standard deviation of the elements.
    pub scale: f32,
}

impl Gen for NormalVec {
    type Value = Vec<f32>;
    fn generate(&self, rng: &mut Rng) -> Vec<f32> {
        let n = self.len.generate(rng);
        (0..n).map(|_| self.scale * rng.normal() as f32).collect()
    }
    fn shrink(&self, v: &Vec<f32>) -> Vec<Vec<f32>> {
        if v.len() <= self.len.0 {
            return Vec::new();
        }
        let half = self.len.0.max(v.len() / 2);
        vec![v[..half].to_vec()]
    }
}

/// Tuple combinator.
pub struct Pair<A, B>(pub A, pub B);

impl<A: Gen, B: Gen> Gen for Pair<A, B> {
    type Value = (A::Value, B::Value);
    fn generate(&self, rng: &mut Rng) -> Self::Value {
        (self.0.generate(rng), self.1.generate(rng))
    }
    fn shrink(&self, v: &Self::Value) -> Vec<Self::Value> {
        let mut out: Vec<Self::Value> = self
            .0
            .shrink(&v.0)
            .into_iter()
            .map(|a| (a, v.1.clone()))
            .collect();
        out.extend(self.1.shrink(&v.1).into_iter().map(|b| (v.0.clone(), b)));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check(&F64Range(0.0, 1.0), |v| {
            if (0.0..=1.0).contains(v) {
                Ok(())
            } else {
                Err(format!("{v} out of range"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_shrunk_input() {
        check(&UsizeRange(0, 1000), |v| {
            if *v < 500 {
                Ok(())
            } else {
                Err("too big".into())
            }
        });
    }

    #[test]
    fn shrink_finds_small_case() {
        // Verify the shrinker drives toward the boundary: catch the panic
        // and check the reported input is well below the original draws.
        let res = std::panic::catch_unwind(|| {
            check(&UsizeRange(0, 1_000_000), |v| {
                if *v < 10 {
                    Ok(())
                } else {
                    Err("boom".into())
                }
            })
        });
        let msg = *res.unwrap_err().downcast::<String>().unwrap();
        // Binary shrinking from anywhere in [0, 1e6] should land < 100.
        let input: usize = msg
            .split("input: ")
            .nth(1)
            .unwrap()
            .split_whitespace()
            .next()
            .unwrap()
            .parse()
            .unwrap();
        assert!(input < 100, "shrunk input {input} (msg: {msg})");
    }

    #[test]
    fn pair_generates_both() {
        check(&Pair(UsizeRange(1, 4), F64Range(-1.0, 1.0)), |(n, x)| {
            if (1..=4).contains(n) && (-1.0..=1.0).contains(x) {
                Ok(())
            } else {
                Err("bounds".into())
            }
        });
    }
}
