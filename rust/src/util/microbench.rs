//! Micro-benchmark substrate (criterion is unavailable offline).
//!
//! Provides warmup + timed iterations with mean / p50 / p99 / MAD reporting,
//! a table printer for the paper-reproduction benches, and CSV output into
//! `results/`. All `cargo bench` targets (`harness = false`) use this.

use std::io::Write;
use std::path::Path;
use std::time::{Duration, Instant};

use super::stats::quantile;

/// Timing statistics of one benched closure.
#[derive(Clone, Debug)]
pub struct BenchResult {
    /// Bench label (as passed to [`Bencher::run`]).
    pub name: String,
    /// Timed iterations contributing to the statistics.
    pub iters: usize,
    /// Mean per-iteration time, nanoseconds.
    pub mean_ns: f64,
    /// Median per-iteration time, nanoseconds.
    pub p50_ns: f64,
    /// 99th-percentile per-iteration time, nanoseconds.
    pub p99_ns: f64,
    /// Median absolute deviation from the median, nanoseconds.
    pub mad_ns: f64,
    /// Fastest iteration, nanoseconds.
    pub min_ns: f64,
}

impl BenchResult {
    /// Mean per-iteration time in milliseconds.
    pub fn mean_ms(&self) -> f64 {
        self.mean_ns / 1e6
    }
    /// Items per second given `items_per_iter` work units per iteration.
    pub fn throughput_per_s(&self, items_per_iter: f64) -> f64 {
        items_per_iter / (self.mean_ns / 1e9)
    }
}

/// Warmup-then-measure micro-bench runner.
#[derive(Clone, Debug)]
pub struct Bencher {
    /// Untimed warmup budget.
    pub warmup: Duration,
    /// Timed measurement budget.
    pub measure: Duration,
    /// Lower bound on timed iterations (overrides the budget).
    pub min_iters: usize,
    /// Upper bound on timed iterations (caps the budget).
    pub max_iters: usize,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            warmup: Duration::from_millis(300),
            measure: Duration::from_secs(2),
            min_iters: 10,
            max_iters: 10_000,
        }
    }
}

impl Bencher {
    /// CI-scale budgets (tens of milliseconds instead of seconds).
    pub fn quick() -> Self {
        Bencher {
            warmup: Duration::from_millis(50),
            measure: Duration::from_millis(300),
            min_iters: 5,
            max_iters: 1000,
        }
    }

    /// Benchmark `f`, returning robust timing statistics.
    pub fn run<F: FnMut()>(&self, name: &str, mut f: F) -> BenchResult {
        // Warmup.
        let t0 = Instant::now();
        while t0.elapsed() < self.warmup {
            f();
        }
        // Measure.
        let mut samples_ns: Vec<f64> = Vec::new();
        let t1 = Instant::now();
        while (t1.elapsed() < self.measure || samples_ns.len() < self.min_iters)
            && samples_ns.len() < self.max_iters
        {
            let s = Instant::now();
            f();
            samples_ns.push(s.elapsed().as_nanos() as f64);
        }
        samples_ns.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mean = samples_ns.iter().sum::<f64>() / samples_ns.len() as f64;
        let p50 = quantile(&samples_ns, 0.5);
        let mut devs: Vec<f64> = samples_ns.iter().map(|x| (x - p50).abs()).collect();
        devs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        BenchResult {
            name: name.to_string(),
            iters: samples_ns.len(),
            mean_ns: mean,
            p50_ns: p50,
            p99_ns: quantile(&samples_ns, 0.99),
            mad_ns: quantile(&devs, 0.5),
            min_ns: samples_ns[0],
        }
    }
}

/// Fixed-width table printer for paper-style output.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
    title: String,
}

impl Table {
    /// Empty table with a title row and column headers.
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append one row (must match the header width).
    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row width mismatch");
        self.rows.push(cells);
    }

    /// Print the table to stdout with auto-sized columns.
    pub fn print(&self) {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (w, c) in widths.iter_mut().zip(row) {
                *w = (*w).max(c.len());
            }
        }
        let total: usize = widths.iter().sum::<usize>() + 3 * widths.len() + 1;
        println!("\n{}", self.title);
        println!("{}", "=".repeat(total.min(120)));
        let fmt_row = |cells: &[String]| {
            let mut line = String::from("|");
            for (c, w) in cells.iter().zip(&widths) {
                line.push_str(&format!(" {:<w$} |", c, w = w));
            }
            line
        };
        println!("{}", fmt_row(&self.headers));
        println!("{}", "-".repeat(total.min(120)));
        for row in &self.rows {
            println!("{}", fmt_row(row));
        }
        println!();
    }

    /// Write the table as CSV under results/ (created if missing).
    pub fn write_csv(&self, path: &str) -> std::io::Result<()> {
        if let Some(parent) = Path::new(path).parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut f = std::fs::File::create(path)?;
        writeln!(f, "{}", self.headers.join(","))?;
        for row in &self.rows {
            writeln!(f, "{}", row.join(","))?;
        }
        Ok(())
    }
}

/// Format helper: "1.23x".
pub fn fx(v: f64) -> String {
    format!("{v:.2}x")
}

/// Format helper: fixed 4 decimals.
pub fn f4(v: f64) -> String {
    format!("{v:.4}")
}

/// Honor `STRIDE_BENCH_QUICK=1` so CI can run every bench cheaply.
pub fn bencher_from_env() -> Bencher {
    if std::env::var("STRIDE_BENCH_QUICK").as_deref() == Ok("1") {
        Bencher::quick()
    } else {
        Bencher::default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_sleep() {
        let b = Bencher {
            warmup: Duration::from_millis(5),
            measure: Duration::from_millis(100),
            min_iters: 5,
            max_iters: 100,
        };
        let r = b.run("sleep1ms", || std::thread::sleep(Duration::from_millis(1)));
        assert!(r.mean_ns > 0.9e6, "mean {:.0}ns", r.mean_ns);
        assert!(r.iters >= 5);
        assert!(r.p99_ns >= r.p50_ns);
        assert!(r.min_ns <= r.mean_ns);
    }

    #[test]
    fn table_roundtrip_csv() {
        let dir = std::env::temp_dir().join("stride_tbl_test");
        let path = dir.join("t.csv");
        let mut t = Table::new("T", &["a", "b"]);
        t.row(vec!["1".into(), "2".into()]);
        t.write_csv(path.to_str().unwrap()).unwrap();
        let body = std::fs::read_to_string(&path).unwrap();
        assert_eq!(body, "a,b\n1,2\n");
    }
}
