//! Minimal JSON substrate (no serde offline): recursive-descent parser and
//! writer covering the full JSON grammar, used for artifact manifests, the
//! config system, and the HTTP API.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value (the usual six-variant sum type).
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any JSON number (always stored as `f64`).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object (sorted keys — output is deterministic).
    Obj(BTreeMap<String, Json>),
}

/// Parse error with the byte offset where parsing failed.
#[derive(Debug)]
pub struct JsonError {
    /// Byte offset into the input where the error was detected.
    pub pos: usize,
    /// Human-readable description of what went wrong.
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // ---- accessors -------------------------------------------------------
    /// Object field lookup (`None` for non-objects / missing keys).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }
    /// Array element lookup (`None` for non-arrays / out of range).
    pub fn at(&self, idx: usize) -> Option<&Json> {
        match self {
            Json::Arr(v) => v.get(idx),
            _ => None,
        }
    }
    /// The number value, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    /// The number value truncated to `usize`, if this is a number.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }
    /// The string value, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    /// The boolean value, if this is a bool.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    /// The elements, if this is an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }
    /// The key→value map, if this is an object.
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Path accessor: `j.path(&["models", "target", "d_model"])`.
    pub fn path(&self, keys: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for k in keys {
            cur = cur.get(k)?;
        }
        Some(cur)
    }

    // ---- constructors ----------------------------------------------------
    /// Build an object from `(key, value)` pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
    /// Build a number array from an `f64` slice.
    pub fn arr_f64(v: &[f64]) -> Json {
        Json::Arr(v.iter().map(|&x| Json::Num(x)).collect())
    }
    /// Build a number array from an `f32` slice (widened to `f64`).
    pub fn arr_f32(v: &[f32]) -> Json {
        Json::Arr(v.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    /// Parse a complete JSON document (trailing data is an error).
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let b = s.as_bytes();
        let mut p = Parser { b, pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }
}

impl From<f64> for Json {
    fn from(v: f64) -> Self {
        Json::Num(v)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Self {
        Json::Num(v as f64)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Self {
        Json::Str(v.to_string())
    }
}
impl From<String> for Json {
    fn from(v: String) -> Self {
        Json::Str(v)
    }
}
impl From<bool> for Json {
    fn from(v: bool) -> Self {
        Json::Bool(v)
    }
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }
    fn skip_ws(&mut self) {
        while self.pos < self.b.len() && matches!(self.b[self.pos], b' ' | b'\t' | b'\n' | b'\r') {
            self.pos += 1;
        }
    }
    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }
    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }
    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            out.insert(key, self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'u') => {
                            let hex = self
                                .b
                                .get(self.pos + 1..self.pos + 5)
                                .ok_or_else(|| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex).map_err(|_| self.err("bad \\u"))?,
                                16,
                            )
                            .map_err(|_| self.err("bad \\u"))?;
                            self.pos += 4;
                            // Surrogate pairs: only handle BMP + paired surrogates.
                            if (0xD800..0xDC00).contains(&code) {
                                if self.b.get(self.pos + 1..self.pos + 3) == Some(b"\\u") {
                                    let hex2 = self
                                        .b
                                        .get(self.pos + 3..self.pos + 7)
                                        .ok_or_else(|| self.err("bad surrogate"))?;
                                    let low = u32::from_str_radix(
                                        std::str::from_utf8(hex2)
                                            .map_err(|_| self.err("bad surrogate"))?,
                                        16,
                                    )
                                    .map_err(|_| self.err("bad surrogate"))?;
                                    let c = 0x10000 + ((code - 0xD800) << 10) + (low - 0xDC00);
                                    out.push(
                                        char::from_u32(c).ok_or_else(|| self.err("bad pair"))?,
                                    );
                                    self.pos += 6;
                                } else {
                                    return Err(self.err("lone surrogate"));
                                }
                            } else {
                                out.push(
                                    char::from_u32(code).ok_or_else(|| self.err("bad codepoint"))?,
                                );
                            }
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let s = &self.b[self.pos..];
                    let ch_len = utf8_len(s[0]);
                    let chunk = s.get(..ch_len).ok_or_else(|| self.err("bad utf8"))?;
                    out.push_str(
                        std::str::from_utf8(chunk).map_err(|_| self.err("bad utf8"))?,
                    );
                    self.pos += ch_len;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

fn utf8_len(b0: u8) -> usize {
    match b0 {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.is_finite() {
                    if *n == n.trunc() && n.abs() < 1e15 {
                        write!(f, "{}", *n as i64)
                    } else {
                        write!(f, "{n}")
                    }
                } else {
                    write!(f, "null") // JSON has no Inf/NaN
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" -1.5e3 ").unwrap(), Json::Num(-1500.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parses_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(j.path(&["a"]).unwrap().at(2).unwrap().get("b").unwrap().as_str(), Some("x"));
        assert_eq!(j.get("c"), Some(&Json::Null));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"\\q\"").is_err());
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(Json::parse(r#""é""#).unwrap(), Json::Str("é".into()));
        assert_eq!(Json::parse(r#""😀""#).unwrap(), Json::Str("😀".into()));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,-3],"b":false,"nested":{"s":"hi\n\"there\""},"z":null}"#;
        let j = Json::parse(src).unwrap();
        let printed = j.to_string();
        assert_eq!(Json::parse(&printed).unwrap(), j);
    }

    #[test]
    fn real_manifest_shape() {
        let j = Json::parse(
            r#"{"models":{"target":{"d_model":128,"tensors":[{"name":"embed_w","shape":[24,128],"offset":0}]}}}"#,
        )
        .unwrap();
        let t = j.path(&["models", "target"]).unwrap();
        assert_eq!(t.get("d_model").unwrap().as_usize(), Some(128));
        let tensor = t.get("tensors").unwrap().at(0).unwrap();
        assert_eq!(tensor.get("shape").unwrap().at(1).unwrap().as_usize(), Some(128));
    }
}
