//! Deterministic RNG substrate: counter-based SplitMix64 + a sequential
//! Xoshiro256++ stream, plus Gaussian sampling.
//!
//! The counter-based SplitMix64 is mirrored bit-for-bit by
//! `python/compile/datagen.py` so Python (training) and Rust (serving/eval)
//! can generate the *same* synthetic datasets; golden vectors exported by
//! `aot.py` pin the equivalence (`data::synthetic` tests).
//!
//! No `rand` crate is available offline; everything here is hand-rolled and
//! unit-tested against reference values.

const GOLDEN: u64 = 0x9E37_79B9_7F4A_7C15;
const MIX1: u64 = 0xBF58_476D_1CE4_E5B9;
const MIX2: u64 = 0x94D0_49BB_1331_11EB;

/// Hash (seed, idx) -> u64. Stateless; identical to datagen.splitmix64.
#[inline]
pub fn splitmix64(seed: u64, idx: u64) -> u64 {
    let mut z = seed.wrapping_add(idx.wrapping_add(1).wrapping_mul(GOLDEN));
    z = (z ^ (z >> 30)).wrapping_mul(MIX1);
    z = (z ^ (z >> 27)).wrapping_mul(MIX2);
    z ^ (z >> 31)
}

/// Uniform in [0, 1) with 53-bit mantissa (matches datagen.uniform01).
#[inline]
pub fn uniform01(seed: u64, idx: u64) -> f64 {
    (splitmix64(seed, idx) >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
}

/// Standard normal via Box-Muller over the (2i, 2i+1) uniform pair,
/// cos branch only (matches datagen.std_normal).
#[inline]
pub fn std_normal(seed: u64, idx: u64) -> f64 {
    let u1 = uniform01(seed, 2 * idx);
    let u2 = uniform01(seed, 2 * idx + 1);
    (-2.0 * (-u1).ln_1p()).sqrt() * (2.0 * core::f64::consts::PI * u2).cos()
}

/// Sequential PRNG for the serving hot loop (acceptance coin flips, fallback
/// sampling): Xoshiro256++, seeded via SplitMix64 expansion.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second Box-Muller branch.
    spare: Option<f64>,
}

impl Rng {
    /// New stream; equal seeds yield identical streams.
    pub fn new(seed: u64) -> Self {
        let mut s = [0u64; 4];
        for (i, slot) in s.iter_mut().enumerate() {
            *slot = splitmix64(seed, i as u64);
        }
        Rng { s, spare: None }
    }

    /// Next raw 64-bit output of the Xoshiro256++ stream.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire-style rejection-free for our (non-crypto) purposes.
        ((self.next_u64() >> 11) as u128 * n as u128 >> 53) as usize
    }

    /// Standard normal (Box-Muller, both branches used).
    #[inline]
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare.take() {
            return z;
        }
        let u1 = self.uniform();
        let u2 = self.uniform();
        let r = (-2.0 * (1.0 - u1).ln()).sqrt();
        let (s, c) = (2.0 * core::f64::consts::PI * u2).sin_cos();
        self.spare = Some(r * s);
        r * c
    }

    /// N(mu, sigma^2).
    #[inline]
    pub fn normal_scaled(&mut self, mu: f64, sigma: f64) -> f64 {
        mu + sigma * self.normal()
    }

    /// Fill `out` with `x[i] ~ N(mu[i], sigma^2)` — the draft/fallback patch
    /// sampler on the hot path.
    pub fn fill_normal_around(&mut self, mu: &[f32], sigma: f32, out: &mut [f32]) {
        debug_assert_eq!(mu.len(), out.len());
        for (o, m) in out.iter_mut().zip(mu) {
            *o = *m + sigma * self.normal() as f32;
        }
    }

    /// Exponential with rate `lambda` (Poisson-process inter-arrivals for
    /// the load generator).
    #[inline]
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        -(1.0 - self.uniform()).ln() / lambda
    }

    /// Bernoulli(p).
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.uniform() < p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_reference_values() {
        // Distinct, deterministic, stable across runs.
        assert_eq!(splitmix64(0, 0), splitmix64(0, 0));
        assert_ne!(splitmix64(0, 0), splitmix64(0, 1));
        assert_ne!(splitmix64(0, 0), splitmix64(1, 0));
        // Pinned golden values computed from python/compile/datagen.py —
        // this is the cross-language equivalence contract.
        assert_eq!(splitmix64(42, 0), 0xbdd7_3226_2feb_6e95);
        assert_eq!(splitmix64(0, 0), 0xe220_a839_7b1d_cdaf);
        assert!((uniform01(42, 0) - 0.7415648787718233).abs() < 1e-15);
        assert!((std_normal(3, 3) - 0.4124328000730101).abs() < 1e-12);
    }

    #[test]
    fn uniform_in_range_and_spread() {
        let mut mn = 1.0f64;
        let mut mx = 0.0f64;
        for i in 0..10_000 {
            let u = uniform01(7, i);
            assert!((0.0..1.0).contains(&u));
            mn = mn.min(u);
            mx = mx.max(u);
        }
        assert!(mn < 0.01 && mx > 0.99, "poor spread: [{mn}, {mx}]");
    }

    #[test]
    fn counter_normal_moments() {
        let n = 50_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for i in 0..n {
            let z = std_normal(3, i);
            s += z;
            s2 += z * z;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn xoshiro_normal_moments_and_determinism() {
        let mut rng = Rng::new(9);
        let mut rng2 = Rng::new(9);
        assert_eq!(rng.next_u64(), rng2.next_u64());
        let n = 50_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let z = rng.normal();
            s += z;
            s2 += z * z;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn below_is_uniform_enough() {
        let mut rng = Rng::new(1);
        let mut counts = [0usize; 10];
        for _ in 0..100_000 {
            counts[rng.below(10)] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "bucket count {c}");
        }
    }

    #[test]
    fn exponential_mean() {
        let mut rng = Rng::new(5);
        let lambda = 4.0;
        let n = 50_000;
        let s: f64 = (0..n).map(|_| rng.exponential(lambda)).sum();
        let mean = s / n as f64;
        assert!((mean - 1.0 / lambda).abs() < 0.01, "mean {mean}");
    }
}
