//! Row-major f32 tensor substrate for the native backend and data pipeline.
//!
//! Deliberately minimal: shapes are `Vec<usize>`, storage is `Vec<f32>`,
//! and only the ops the Timer-style forward needs are implemented (matmul,
//! softmax, rmsnorm, transpose-free attention helpers). The PJRT path does
//! not use this type on the wire — `runtime::literal` marshals flat slices.

use std::fmt;

#[derive(Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl fmt::Debug for Tensor {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Tensor{:?}(n={})", self.shape, self.data.len())
    }
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Tensor {
        let n = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: vec![0.0; n] }
    }

    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        Tensor { shape: shape.to_vec(), data }
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    /// Last-axis length.
    pub fn dim(&self, i: usize) -> usize {
        self.shape[i]
    }

    pub fn reshape(mut self, shape: &[usize]) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), self.data.len());
        self.shape = shape.to_vec();
        self
    }

    /// Row `r` of a 2-D tensor.
    pub fn row(&self, r: usize) -> &[f32] {
        assert_eq!(self.rank(), 2);
        let c = self.shape[1];
        &self.data[r * c..(r + 1) * c]
    }

    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        assert_eq!(self.rank(), 2);
        let c = self.shape[1];
        &mut self.data[r * c..(r + 1) * c]
    }
}

/// C = A[m,k] x B[k,n]; the native-backend hot matmul.
/// Simple ikj loop order with the inner j loop auto-vectorizing.
pub fn matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, c: &mut [f32]) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    assert_eq!(c.len(), m * n);
    c.fill(0.0);
    for i in 0..m {
        let crow = &mut c[i * n..(i + 1) * n];
        for kk in 0..k {
            let aik = a[i * k + kk];
            if aik == 0.0 {
                continue;
            }
            let brow = &b[kk * n..(kk + 1) * n];
            for (cj, bj) in crow.iter_mut().zip(brow) {
                *cj += aik * bj;
            }
        }
    }
}

/// y = x[m,k] x W[k,n] + b (b optional), allocating variant.
pub fn linear(x: &Tensor, w: &Tensor, b: Option<&[f32]>) -> Tensor {
    let (m, k) = (x.numel() / x.shape[x.rank() - 1], *x.shape.last().unwrap());
    assert_eq!(w.rank(), 2);
    assert_eq!(w.shape[0], k, "linear: in-dim mismatch");
    let n = w.shape[1];
    let mut out_shape = x.shape.clone();
    *out_shape.last_mut().unwrap() = n;
    let mut out = Tensor::zeros(&out_shape);
    matmul(&x.data, &w.data, m, k, n, &mut out.data);
    if let Some(bias) = b {
        assert_eq!(bias.len(), n);
        for r in 0..m {
            for j in 0..n {
                out.data[r * n + j] += bias[j];
            }
        }
    }
    out
}

/// In-place numerically-stable softmax over the last axis of a row slice.
pub fn softmax_row(row: &mut [f32]) {
    let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let mut sum = 0.0f32;
    for v in row.iter_mut() {
        *v = (*v - mx).exp();
        sum += *v;
    }
    let inv = 1.0 / sum;
    for v in row.iter_mut() {
        *v *= inv;
    }
}

/// RMSNorm over the last axis (eps matches the JAX side).
pub fn rmsnorm(x: &mut [f32], w: &[f32], eps: f32) {
    let d = w.len();
    assert_eq!(x.len() % d, 0);
    for row in x.chunks_exact_mut(d) {
        let ms: f32 = row.iter().map(|v| v * v).sum::<f32>() / d as f32;
        let inv = 1.0 / (ms + eps).sqrt();
        for (v, wi) in row.iter_mut().zip(w) {
            *v = *v * inv * wi;
        }
    }
}

/// SiLU activation.
#[inline]
pub fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

/// MSE and MAE between two equal-length slices.
pub fn mse_mae(a: &[f32], b: &[f32]) -> (f64, f64) {
    assert_eq!(a.len(), b.len());
    assert!(!a.is_empty());
    let (mut se, mut ae) = (0.0f64, 0.0f64);
    for (x, y) in a.iter().zip(b) {
        let d = (*x - *y) as f64;
        se += d * d;
        ae += d.abs();
    }
    (se / a.len() as f64, ae / a.len() as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_identity() {
        let a = vec![1.0, 2.0, 3.0, 4.0];
        let eye = vec![1.0, 0.0, 0.0, 1.0];
        let mut c = vec![0.0; 4];
        matmul(&a, &eye, 2, 2, 2, &mut c);
        assert_eq!(c, a);
    }

    #[test]
    fn matmul_known() {
        // [[1,2],[3,4]] x [[5,6],[7,8]] = [[19,22],[43,50]]
        let a = vec![1.0, 2.0, 3.0, 4.0];
        let b = vec![5.0, 6.0, 7.0, 8.0];
        let mut c = vec![0.0; 4];
        matmul(&a, &b, 2, 2, 2, &mut c);
        assert_eq!(c, vec![19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn matmul_rectangular() {
        // [1x3] x [3x2]
        let a = vec![1.0, 2.0, 3.0];
        let b = vec![1.0, 0.0, 0.0, 1.0, 1.0, 1.0];
        let mut c = vec![0.0; 2];
        matmul(&a, &b, 1, 3, 2, &mut c);
        assert_eq!(c, vec![4.0, 5.0]);
    }

    #[test]
    fn linear_bias() {
        let x = Tensor::from_vec(&[1, 2], vec![1.0, 1.0]);
        let w = Tensor::from_vec(&[2, 3], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let out = linear(&x, &w, Some(&[10.0, 20.0, 30.0]));
        assert_eq!(out.data, vec![15.0, 27.0, 39.0]);
        assert_eq!(out.shape, vec![1, 3]);
    }

    #[test]
    fn softmax_sums_to_one_and_is_stable() {
        let mut row = vec![1000.0, 1001.0, 1002.0];
        softmax_row(&mut row);
        let s: f32 = row.iter().sum();
        assert!((s - 1.0).abs() < 1e-6);
        assert!(row[2] > row[1] && row[1] > row[0]);
        assert!(row.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn rmsnorm_unit_rows() {
        let mut x = vec![3.0, 4.0];
        rmsnorm(&mut x, &[1.0, 1.0], 0.0);
        let rms: f32 = (x.iter().map(|v| v * v).sum::<f32>() / 2.0).sqrt();
        assert!((rms - 1.0).abs() < 1e-6);
    }

    #[test]
    fn mse_mae_basics() {
        let (mse, mae) = mse_mae(&[1.0, 2.0], &[2.0, 4.0]);
        assert!((mse - 2.5).abs() < 1e-12);
        assert!((mae - 1.5).abs() < 1e-12);
    }
}
