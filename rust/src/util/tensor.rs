//! Row-major f32 tensor substrate for the native backend and data pipeline.
//!
//! Deliberately minimal: shapes are `Vec<usize>`, storage is `Vec<f32>`,
//! and only the ops the Timer-style forward needs are implemented (matmul,
//! softmax, rmsnorm, transpose-free attention helpers). The PJRT path does
//! not use this type on the wire — `runtime::literal` marshals flat slices.

use std::fmt;
use std::ops::{Deref, DerefMut};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use crate::util::mmap::MappedFile;

/// Flat f32 storage behind a [`Tensor`]: either an owned heap `Vec` (every
/// computed tensor) or a window into a shared read-only [`MappedFile`]
/// (registry-loaded weights — the zero-copy path binds the blob's bytes
/// straight into the weight store; no float is copied at load time).
///
/// `Deref<Target = [f32]>` makes the two cases indistinguishable to the
/// kernel layer. Mutation (`DerefMut`) promotes a mapped window to a heap
/// copy first — weights are never mutated in practice, so the promotion
/// path exists for safety, not for the hot loop.
pub struct Storage(Repr);

enum Repr {
    Heap(Vec<f32>),
    Mapped {
        file: Arc<MappedFile>,
        /// Byte offset into the file (4-aligned, checked at construction).
        off: usize,
        /// Element count.
        len: usize,
    },
}

impl Storage {
    /// Owned heap storage.
    pub fn from_vec(v: Vec<f32>) -> Storage {
        Storage(Repr::Heap(v))
    }

    /// A `len`-float window at byte offset `off` of a shared mapping.
    /// Fails (typed, never a panic — this sits on the model-load path) on
    /// a misaligned offset or an out-of-bounds window. Only valid on
    /// little-endian hosts, where the blob's LE f32 bytes *are* the
    /// in-memory representation; [`crate::util::mmap::MMAP_SUPPORTED`]
    /// gates callers on other targets.
    pub fn mapped(file: Arc<MappedFile>, off: usize, len: usize) -> Result<Storage, String> {
        if !cfg!(target_endian = "little") {
            return Err("mapped storage requires a little-endian host".to_string());
        }
        if off % 4 != 0 {
            return Err(format!("mapped tensor byte offset {off} is not 4-aligned"));
        }
        let end = off
            .checked_add(len.checked_mul(4).ok_or("mapped tensor length overflows")?)
            .ok_or("mapped tensor window overflows")?;
        if end > file.len() {
            return Err(format!(
                "mapped tensor window [{off}, {end}) exceeds blob length {}",
                file.len()
            ));
        }
        Ok(Storage(Repr::Mapped { file, off, len }))
    }

    /// True when backed by a mapped file window (no heap copy was made).
    pub fn is_mapped(&self) -> bool {
        matches!(self.0, Repr::Mapped { .. })
    }

    /// Consume into an owned `Vec` (copy only if mapped).
    pub fn into_vec(self) -> Vec<f32> {
        match self.0 {
            Repr::Heap(v) => v,
            Repr::Mapped { .. } => self.to_vec(),
        }
    }
}

impl Deref for Storage {
    type Target = [f32];
    fn deref(&self) -> &[f32] {
        match &self.0 {
            Repr::Heap(v) => v,
            Repr::Mapped { file, off, len } => {
                let bytes = &file.bytes()[*off..*off + *len * 4];
                // SAFETY: the window is bounds- and 4-alignment-checked at
                // construction, the mapping is immutable for its lifetime
                // (PROT_READ), every u32 bit pattern is a valid f32, and
                // mmap regions are page-aligned so off % 4 == 0 implies
                // f32 alignment.
                unsafe { std::slice::from_raw_parts(bytes.as_ptr() as *const f32, *len) }
            }
        }
    }
}

impl DerefMut for Storage {
    fn deref_mut(&mut self) -> &mut [f32] {
        if self.is_mapped() {
            // Promote to heap on first mutation (never taken for weights).
            self.0 = Repr::Heap(self.to_vec());
        }
        match &mut self.0 {
            Repr::Heap(v) => v,
            Repr::Mapped { .. } => unreachable!("promoted above"),
        }
    }
}

impl Clone for Storage {
    fn clone(&self) -> Storage {
        match &self.0 {
            Repr::Heap(v) => Storage(Repr::Heap(v.clone())),
            // Cloning a mapped window copies pointers, not floats — the
            // replica pool's cheap-clone contract extends to mapped
            // weights.
            Repr::Mapped { file, off, len } => {
                Storage(Repr::Mapped { file: Arc::clone(file), off: *off, len: *len })
            }
        }
    }
}

impl PartialEq for Storage {
    fn eq(&self, other: &Storage) -> bool {
        self[..] == other[..]
    }
}

impl PartialEq<Vec<f32>> for Storage {
    fn eq(&self, other: &Vec<f32>) -> bool {
        self[..] == other[..]
    }
}

impl PartialEq<[f32]> for Storage {
    fn eq(&self, other: &[f32]) -> bool {
        &self[..] == other
    }
}

impl fmt::Debug for Storage {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Storage(n={}, mapped={})", self.len(), self.is_mapped())
    }
}

impl From<Vec<f32>> for Storage {
    fn from(v: Vec<f32>) -> Storage {
        Storage::from_vec(v)
    }
}

impl<'a> IntoIterator for &'a Storage {
    type Item = &'a f32;
    type IntoIter = std::slice::Iter<'a, f32>;
    fn into_iter(self) -> Self::IntoIter {
        self.iter()
    }
}

/// Row-major f32 tensor: a shape vector over flat storage.
#[derive(Clone, PartialEq)]
pub struct Tensor {
    /// Dimension sizes, outermost first.
    pub shape: Vec<usize>,
    /// Flat row-major storage (`shape.iter().product()` elements) —
    /// heap-owned or a zero-copy mapped window, see [`Storage`].
    pub data: Storage,
}

impl fmt::Debug for Tensor {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Tensor{:?}(n={})", self.shape, self.data.len())
    }
}

impl Tensor {
    /// Zero-filled tensor of the given shape.
    pub fn zeros(shape: &[usize]) -> Tensor {
        let n = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: Storage::from_vec(vec![0.0; n]) }
    }

    /// Wrap existing flat data in a shape (lengths must agree).
    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        Tensor { shape: shape.to_vec(), data: Storage::from_vec(data) }
    }

    /// Wrap pre-built storage (heap or mapped) in a shape. Typed error on
    /// a length mismatch — this sits on the registry load path, where a
    /// truncated blob must surface as `Err`, not a panic.
    pub fn from_storage(shape: &[usize], data: Storage) -> Result<Tensor, String> {
        let want: usize = shape.iter().product();
        if want != data.len() {
            return Err(format!("shape {shape:?} wants {want} floats, storage has {}", data.len()));
        }
        Ok(Tensor { shape: shape.to_vec(), data })
    }

    /// Total element count.
    pub fn numel(&self) -> usize {
        self.data.len()
    }

    /// Number of dimensions.
    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    /// Size of dimension `i`.
    pub fn dim(&self, i: usize) -> usize {
        self.shape[i]
    }

    /// Reinterpret under a new shape of equal element count.
    pub fn reshape(mut self, shape: &[usize]) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), self.data.len());
        self.shape = shape.to_vec();
        self
    }

    /// Row `r` of a 2-D tensor.
    pub fn row(&self, r: usize) -> &[f32] {
        assert_eq!(self.rank(), 2);
        let c = self.shape[1];
        &self.data[r * c..(r + 1) * c]
    }

    /// Mutable row `r` of a 2-D tensor.
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        assert_eq!(self.rank(), 2);
        let c = self.shape[1];
        &mut self.data[r * c..(r + 1) * c]
    }
}

/// C = A[m,k] x B[k,n]; the native-backend hot matmul.
///
/// Register-blocked micro-kernel: the k loop is 4x-unrolled so the inner j
/// loop carries four multiply-adds per C element per pass (one load of
/// `crow[j]`, four B streams). On x86_64 the inner loop runs explicit
/// 4-lane SSE (separate mul + add, never FMA — see [`set_scalar_kernel`]);
/// everywhere else, a scalar fallback with the identical association
/// order, so the two paths are **bitwise identical**. Shapes whose B
/// panel outgrows L2 additionally go through [`matmul_tiled`]'s
/// cache blocking, also bitwise identical.
/// There is deliberately *no* `a[i,k] == 0.0` skip: on dense activations
/// the branch mispredicts, and skipping silently dropped NaN/Inf
/// propagation (`0.0 * NaN` never added), diverging from the XLA/JAX
/// reference semantics. Results are bit-deterministic for fixed shapes —
/// each output row depends only on its own A row and all of B — which is
/// what lets [`matmul_parallel`] partition rows across threads without
/// changing a single bit.
pub fn matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, c: &mut [f32]) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    assert_eq!(c.len(), m * n);
    matmul_rows(a, b, m, k, n, c);
}

/// Force the scalar 4-lane micro-kernel even where SIMD lanes are
/// available. Test hook for the dispatch-equality wall in
/// `tests/kernel_equivalence.rs` (the forced-fallback path must be bitwise
/// identical to the SIMD path); also the escape hatch if an exotic target
/// miscompiles the intrinsics. Process-global, like
/// `NativeBackend::set_reference_kernel`.
pub fn set_scalar_kernel(on: bool) {
    FORCE_SCALAR.store(on, Ordering::SeqCst);
}

/// Whether the next [`matmul`] call will run the explicit-SIMD micro-kernel
/// (true on x86_64 unless [`set_scalar_kernel`]`(true)` is in effect; the
/// scalar 4-lane fallback runs everywhere else).
pub fn simd_kernel_active() -> bool {
    cfg!(target_arch = "x86_64") && !FORCE_SCALAR.load(Ordering::Relaxed)
}

static FORCE_SCALAR: AtomicBool = AtomicBool::new(false);

/// Cache-blocked tile sizes for [`matmul_tiled`]. `TILE_K` MUST stay a
/// multiple of 4: k-blocks then begin on the same 4-aligned boundaries the
/// flat kernel's unroll visits, so every output element consumes the k
/// dimension in the exact same 4-chunk groups (ascending) and the tiled
/// result is bitwise identical to the flat kernel. Tiling over i and j is
/// order-irrelevant (each C element is an independent accumulation chain).
const TILE_M: usize = 64;
const TILE_K: usize = 256;
const TILE_N: usize = 256;
/// Flat-vs-tiled switch inside [`matmul`]: once B no longer fits in L2
/// (k·n floats), the streaming passes thrash and blocking wins.
const TILE_MIN_KN: usize = 128 * 1024;

/// Accumulate `crow[j0..j1] += arow[k0..k1] · B[k0..k1, j0..j1]` with the
/// canonical association order: k in 4-chunks from `k0` (then singles),
/// each chunk contributing `((a0·b0 + a1·b1) + a2·b2) + a3·b3` to the
/// running `crow[j]`. Both micro-kernels below implement exactly this
/// order; callers must pass a 4-aligned `k0` for chunk boundaries to line
/// up with the flat kernel's.
#[inline]
fn accum_span(
    arow: &[f32],
    b: &[f32],
    n: usize,
    k0: usize,
    k1: usize,
    j0: usize,
    j1: usize,
    crow: &mut [f32],
    simd: bool,
) {
    #[cfg(target_arch = "x86_64")]
    if simd {
        // SAFETY: slice bounds checked by the callers' debug asserts and
        // the loop conditions below; SSE is part of the x86_64 baseline.
        unsafe {
            return accum_span_sse(arow, b, n, k0, k1, j0, j1, crow);
        }
    }
    let _ = simd;
    accum_span_scalar(arow, b, n, k0, k1, j0, j1, crow);
}

/// Scalar reference micro-kernel: the PR 2 4x unroll verbatim, generalized
/// to a (k, j) sub-range. With `k0 = j0 = 0`, `k1 = k`, `j1 = n` this is
/// line-for-line the old `matmul_rows` inner loop.
fn accum_span_scalar(
    arow: &[f32],
    b: &[f32],
    n: usize,
    k0: usize,
    k1: usize,
    j0: usize,
    j1: usize,
    crow: &mut [f32],
) {
    let mut kk = k0;
    while kk + 4 <= k1 {
        let a0 = arow[kk];
        let a1 = arow[kk + 1];
        let a2 = arow[kk + 2];
        let a3 = arow[kk + 3];
        let b0 = &b[kk * n..(kk + 1) * n];
        let b1 = &b[(kk + 1) * n..(kk + 2) * n];
        let b2 = &b[(kk + 2) * n..(kk + 3) * n];
        let b3 = &b[(kk + 3) * n..(kk + 4) * n];
        for j in j0..j1 {
            crow[j] += a0 * b0[j] + a1 * b1[j] + a2 * b2[j] + a3 * b3[j];
        }
        kk += 4;
    }
    while kk < k1 {
        let aik = arow[kk];
        let brow = &b[kk * n..(kk + 1) * n];
        for j in j0..j1 {
            crow[j] += aik * brow[j];
        }
        kk += 1;
    }
}

/// 4-lane SSE micro-kernel, bitwise identical to [`accum_span_scalar`]:
/// separate `_mm_mul_ps` + `_mm_add_ps` (never a fused multiply-add — FMA
/// would skip the intermediate rounding and change bits) applied in the
/// scalar kernel's exact association order, with the j remainder handled
/// by the same scalar expression. IEEE-754 ops are deterministic per lane,
/// so vectorizing over j preserves every bit.
#[cfg(target_arch = "x86_64")]
unsafe fn accum_span_sse(
    arow: &[f32],
    b: &[f32],
    n: usize,
    k0: usize,
    k1: usize,
    j0: usize,
    j1: usize,
    crow: &mut [f32],
) {
    use std::arch::x86_64::{_mm_add_ps, _mm_loadu_ps, _mm_mul_ps, _mm_set1_ps, _mm_storeu_ps};
    let bp = b.as_ptr();
    let cp = crow.as_mut_ptr();
    let mut kk = k0;
    while kk + 4 <= k1 {
        let a0 = arow[kk];
        let a1 = arow[kk + 1];
        let a2 = arow[kk + 2];
        let a3 = arow[kk + 3];
        let va0 = _mm_set1_ps(a0);
        let va1 = _mm_set1_ps(a1);
        let va2 = _mm_set1_ps(a2);
        let va3 = _mm_set1_ps(a3);
        let b0 = bp.add(kk * n);
        let b1 = bp.add((kk + 1) * n);
        let b2 = bp.add((kk + 2) * n);
        let b3 = bp.add((kk + 3) * n);
        let mut j = j0;
        while j + 4 <= j1 {
            // ((a0*b0 + a1*b1) + a2*b2) + a3*b3, then += into C — the
            // scalar expression's left-to-right association, per lane.
            let t01 = _mm_add_ps(
                _mm_mul_ps(va0, _mm_loadu_ps(b0.add(j))),
                _mm_mul_ps(va1, _mm_loadu_ps(b1.add(j))),
            );
            let t012 = _mm_add_ps(t01, _mm_mul_ps(va2, _mm_loadu_ps(b2.add(j))));
            let t = _mm_add_ps(t012, _mm_mul_ps(va3, _mm_loadu_ps(b3.add(j))));
            _mm_storeu_ps(cp.add(j), _mm_add_ps(_mm_loadu_ps(cp.add(j)), t));
            j += 4;
        }
        while j < j1 {
            crow[j] += a0 * *b0.add(j) + a1 * *b1.add(j) + a2 * *b2.add(j) + a3 * *b3.add(j);
            j += 1;
        }
        kk += 4;
    }
    while kk < k1 {
        let aik = arow[kk];
        let va = _mm_set1_ps(aik);
        let brow = bp.add(kk * n);
        let mut j = j0;
        while j + 4 <= j1 {
            let t = _mm_mul_ps(va, _mm_loadu_ps(brow.add(j)));
            _mm_storeu_ps(cp.add(j), _mm_add_ps(_mm_loadu_ps(cp.add(j)), t));
            j += 4;
        }
        while j < j1 {
            crow[j] += aik * *brow.add(j);
            j += 1;
        }
        kk += 1;
    }
}

/// Row-range worker for [`matmul`]/[`matmul_parallel`]: computes `rows`
/// output rows from `rows` A rows against the full B. No allocation.
/// Dispatches to the SIMD or scalar micro-kernel (bitwise identical to
/// each other) and to the cache-blocked tiling once B outgrows L2
/// (bitwise identical to the flat sweep; see `TILE_K`).
fn matmul_rows(a_rows: &[f32], b: &[f32], rows: usize, k: usize, n: usize, c_rows: &mut [f32]) {
    debug_assert_eq!(a_rows.len(), rows * k);
    debug_assert_eq!(c_rows.len(), rows * n);
    let simd = simd_kernel_active();
    if k * n >= TILE_MIN_KN {
        return matmul_rows_tiled(a_rows, b, rows, k, n, c_rows, simd);
    }
    c_rows.fill(0.0);
    for i in 0..rows {
        let arow = &a_rows[i * k..(i + 1) * k];
        let crow = &mut c_rows[i * n..(i + 1) * n];
        accum_span(arow, b, n, 0, k, 0, n, crow, simd);
    }
}

/// Cache-blocked [`matmul`] for prefill-sized shapes: i/k/j tiled so each
/// pass streams a `TILE_K x TILE_N` block of B against `TILE_M` A rows.
/// Bitwise identical to the flat kernel — `TILE_K` is a multiple of 4, so
/// per output element the k dimension is consumed in the identical
/// ascending 4-chunk sequence (the k%4 singles land at the same final
/// offset), and i/j tiling only reorders independent elements. Exposed for
/// the equivalence tests and the `perf_hotpath` before/after; [`matmul`]
/// engages it automatically past `TILE_MIN_KN`.
pub fn matmul_tiled(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, c: &mut [f32]) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    assert_eq!(c.len(), m * n);
    matmul_rows_tiled(a, b, m, k, n, c, simd_kernel_active());
}

fn matmul_rows_tiled(
    a_rows: &[f32],
    b: &[f32],
    rows: usize,
    k: usize,
    n: usize,
    c_rows: &mut [f32],
    simd: bool,
) {
    c_rows.fill(0.0);
    let mut i0 = 0;
    while i0 < rows {
        let i1 = (i0 + TILE_M).min(rows);
        let mut k0 = 0;
        while k0 < k {
            let k1 = (k0 + TILE_K).min(k);
            let mut j0 = 0;
            while j0 < n {
                let j1 = (j0 + TILE_N).min(n);
                for i in i0..i1 {
                    let arow = &a_rows[i * k..(i + 1) * k];
                    let crow = &mut c_rows[i * n..(i + 1) * n];
                    accum_span(arow, b, n, k0, k1, j0, j1, crow, simd);
                }
                j0 = j1;
            }
            k0 = k1;
        }
        i0 = i1;
    }
}

/// The pre-kernel-layer reference matmul (plain ikj, one k per pass).
/// Kept as the "before" side of the kernel equivalence tests and the
/// `perf_hotpath` naive-kernel flag; same semantics as [`matmul`] up to
/// float reassociation (results agree within ~1e-6 relative).
pub fn matmul_naive(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, c: &mut [f32]) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    assert_eq!(c.len(), m * n);
    c.fill(0.0);
    for i in 0..m {
        let crow = &mut c[i * n..(i + 1) * n];
        for kk in 0..k {
            let aik = a[i * k + kk];
            let brow = &b[kk * n..(kk + 1) * n];
            for (cj, bj) in crow.iter_mut().zip(brow) {
                *cj += aik * bj;
            }
        }
    }
}

/// Row-partitioned parallel [`matmul`] over a worker pool. Each worker
/// computes a contiguous block of output rows with the same serial
/// micro-kernel, so the result is **bitwise identical** to the serial call
/// for any worker count (pinned by `tests/kernel_equivalence.rs` across
/// pool sizes {1, 2, 8}).
///
/// Must not be called from a worker of the same pool (nested `map_wait`
/// deadlocks); use [`matmul_auto`], which checks.
pub fn matmul_parallel(
    pool: &crate::util::threadpool::ThreadPool,
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    c: &mut [f32],
) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    assert_eq!(c.len(), m * n);
    let jobs = pool.size().min(m).max(1);
    if jobs == 1 {
        return matmul_rows(a, b, m, k, n, c);
    }
    let rows_per = (m + jobs - 1) / jobs;
    // Smuggle the borrows as addresses: the Job type is 'static but
    // map_wait joins every job before returning, so `a`, `b`, and `c`
    // strictly outlive all worker accesses, and each job writes a disjoint
    // row range of `c`.
    let a_addr = a.as_ptr() as usize;
    let b_addr = b.as_ptr() as usize;
    let c_addr = c.as_mut_ptr() as usize;
    pool.map_wait(jobs, move |j| {
        let lo = j * rows_per;
        let hi = ((j + 1) * rows_per).min(m);
        if lo >= hi {
            return;
        }
        // SAFETY: see above — shared &[f32] views plus a &mut slice of
        // rows [lo, hi) that no other job touches, all joined before the
        // caller's borrows end.
        let (a_rows, b, c_rows) = unsafe {
            (
                std::slice::from_raw_parts((a_addr as *const f32).add(lo * k), (hi - lo) * k),
                std::slice::from_raw_parts(b_addr as *const f32, k * n),
                std::slice::from_raw_parts_mut((c_addr as *mut f32).add(lo * n), (hi - lo) * n),
            )
        };
        matmul_rows(a_rows, b, hi - lo, k, n, c_rows);
    })
    .expect("parallel matmul job panicked");
}

/// Rows below this run serially. Set strictly above
/// `nn::kernel::MAX_DECODE_ROWS` (= 64, the γ cap) so every steady-state
/// cached forward — whose matmuls have m = k ≤ 64 — stays on the serial,
/// allocation-free path (the zero-allocation guarantee of
/// `forward_cached` must hold for *all* valid γ, and `map_wait`
/// allocates); prefill-sized m still parallelizes. Cross-checked by a
/// test in `nn::kernel`.
pub const PAR_MIN_ROWS: usize = 65;
/// Minimum per-row work (k·n mults) for the parallel path to win.
pub const PAR_MIN_ROW_FLOPS: usize = 2048;

/// [`matmul`] that routes prefill-sized calls through the shared pool and
/// everything else (small m, small per-row work, or already running on a
/// pool worker) through the serial kernel. Bitwise identical either way.
pub fn matmul_auto(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, c: &mut [f32]) {
    use crate::util::threadpool::{global_pool, in_worker};
    if m >= PAR_MIN_ROWS && k * n >= PAR_MIN_ROW_FLOPS && !in_worker() {
        let pool = global_pool();
        if pool.size() > 1 {
            return matmul_parallel(pool, a, b, m, k, n, c);
        }
    }
    matmul(a, b, m, k, n, c)
}

/// y = x[m,k] x W[k,n] + b (b optional), allocating variant over
/// [`matmul_naive`]: the embed/head of the reference (pre-kernel-layer)
/// forward, kept so the "before" flag measures the old kernel end to end.
/// The kernel layer itself writes into caller scratch via
/// `nn::kernel::embed_tokens` / `head_rows` instead.
pub fn linear_naive(x: &Tensor, w: &Tensor, b: Option<&[f32]>) -> Tensor {
    let (m, k) = (x.numel() / x.shape[x.rank() - 1], *x.shape.last().unwrap());
    assert_eq!(w.rank(), 2);
    assert_eq!(w.shape[0], k, "linear: in-dim mismatch");
    let n = w.shape[1];
    let mut out_shape = x.shape.clone();
    *out_shape.last_mut().unwrap() = n;
    let mut out = Tensor::zeros(&out_shape);
    matmul_naive(&x.data, &w.data, m, k, n, &mut out.data);
    if let Some(bias) = b {
        assert_eq!(bias.len(), n);
        for r in 0..m {
            for j in 0..n {
                out.data[r * n + j] += bias[j];
            }
        }
    }
    out
}

/// In-place numerically-stable softmax over the last axis of a row slice.
pub fn softmax_row(row: &mut [f32]) {
    let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let mut sum = 0.0f32;
    for v in row.iter_mut() {
        *v = (*v - mx).exp();
        sum += *v;
    }
    let inv = 1.0 / sum;
    for v in row.iter_mut() {
        *v *= inv;
    }
}

/// RMSNorm over the last axis (eps matches the JAX side).
pub fn rmsnorm(x: &mut [f32], w: &[f32], eps: f32) {
    let d = w.len();
    assert_eq!(x.len() % d, 0);
    for row in x.chunks_exact_mut(d) {
        let ms: f32 = row.iter().map(|v| v * v).sum::<f32>() / d as f32;
        let inv = 1.0 / (ms + eps).sqrt();
        for (v, wi) in row.iter_mut().zip(w) {
            *v = *v * inv * wi;
        }
    }
}

/// SiLU activation.
#[inline]
pub fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

/// MSE and MAE between two equal-length slices.
pub fn mse_mae(a: &[f32], b: &[f32]) -> (f64, f64) {
    assert_eq!(a.len(), b.len());
    assert!(!a.is_empty());
    let (mut se, mut ae) = (0.0f64, 0.0f64);
    for (x, y) in a.iter().zip(b) {
        let d = (*x - *y) as f64;
        se += d * d;
        ae += d.abs();
    }
    (se / a.len() as f64, ae / a.len() as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_identity() {
        let a = vec![1.0, 2.0, 3.0, 4.0];
        let eye = vec![1.0, 0.0, 0.0, 1.0];
        let mut c = vec![0.0; 4];
        matmul(&a, &eye, 2, 2, 2, &mut c);
        assert_eq!(c, a);
    }

    #[test]
    fn matmul_known() {
        // [[1,2],[3,4]] x [[5,6],[7,8]] = [[19,22],[43,50]]
        let a = vec![1.0, 2.0, 3.0, 4.0];
        let b = vec![5.0, 6.0, 7.0, 8.0];
        let mut c = vec![0.0; 4];
        matmul(&a, &b, 2, 2, 2, &mut c);
        assert_eq!(c, vec![19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn matmul_rectangular() {
        // [1x3] x [3x2]
        let a = vec![1.0, 2.0, 3.0];
        let b = vec![1.0, 0.0, 0.0, 1.0, 1.0, 1.0];
        let mut c = vec![0.0; 2];
        matmul(&a, &b, 1, 3, 2, &mut c);
        assert_eq!(c, vec![4.0, 5.0]);
    }

    #[test]
    fn matmul_matches_naive_on_odd_shapes() {
        // Exercise the unrolled-by-4 path plus the remainder loop.
        let mut rng = crate::util::rng::Rng::new(7);
        for &(m, k, n) in &[(1usize, 1usize, 1usize), (3, 5, 2), (4, 7, 9), (8, 16, 3), (5, 13, 17)] {
            let a: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
            let b: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32).collect();
            let mut c0 = vec![0.0; m * n];
            let mut c1 = vec![0.0; m * n];
            matmul_naive(&a, &b, m, k, n, &mut c0);
            matmul(&a, &b, m, k, n, &mut c1);
            for (x, y) in c0.iter().zip(&c1) {
                assert!((x - y).abs() < 1e-5, "blocked {y} vs naive {x} at ({m},{k},{n})");
            }
        }
    }

    #[test]
    fn matmul_propagates_nan_through_zero_rows() {
        // A zero in A must not skip a NaN in B: 0.0 * NaN = NaN (the old
        // zero-skip branch silently dropped it).
        let a = vec![0.0f32, 1.0];
        let b = vec![f32::NAN, 2.0, 3.0, 4.0];
        let mut c = vec![0.0; 2];
        matmul(&a, &b, 1, 2, 2, &mut c);
        assert!(c[0].is_nan(), "NaN dropped by the kernel: {c:?}");
        let mut c = vec![0.0; 2];
        matmul_naive(&a, &b, 1, 2, 2, &mut c);
        assert!(c[0].is_nan(), "NaN dropped by the naive kernel: {c:?}");
    }

    #[test]
    fn simd_and_scalar_kernels_bitwise_identical() {
        let mut rng = crate::util::rng::Rng::new(23);
        for &(m, k, n) in &[(1usize, 1usize, 1usize), (2, 3, 5), (7, 9, 6), (5, 8, 4), (3, 17, 11)] {
            let a: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
            let b: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32).collect();
            let mut fast = vec![0.0; m * n];
            let mut slow = vec![0.0; m * n];
            matmul(&a, &b, m, k, n, &mut fast);
            set_scalar_kernel(true);
            matmul(&a, &b, m, k, n, &mut slow);
            set_scalar_kernel(false);
            for (i, (x, y)) in fast.iter().zip(&slow).enumerate() {
                assert_eq!(x.to_bits(), y.to_bits(), "dispatch drift at {i} for ({m},{k},{n})");
            }
        }
    }

    #[test]
    fn tiled_matmul_bitwise_equals_flat() {
        let mut rng = crate::util::rng::Rng::new(29);
        // Shapes straddling every tile boundary: < one tile, exactly one
        // tile on each axis, and a ragged multi-tile (k % 4 != 0 so the
        // singles remainder lands inside the final k-block).
        for &(m, k, n) in &[
            (3usize, 5usize, 7usize),
            (TILE_M, TILE_K, 8),
            (5, TILE_K + 6, TILE_N + 3),
            (TILE_M + 1, 13, TILE_N),
        ] {
            let a: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
            let b: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32).collect();
            let mut flat = vec![0.0; m * n];
            let mut tiled = vec![0.0; m * n];
            // Flat reference: the scalar full-range span (never auto-tiled).
            flat.fill(0.0);
            for i in 0..m {
                accum_span_scalar(&a[i * k..(i + 1) * k], &b, n, 0, k, 0, n, &mut flat[i * n..(i + 1) * n]);
            }
            matmul_tiled(&a, &b, m, k, n, &mut tiled);
            for (i, (x, y)) in flat.iter().zip(&tiled).enumerate() {
                assert_eq!(x.to_bits(), y.to_bits(), "tiling drift at {i} for ({m},{k},{n})");
            }
        }
    }

    #[test]
    fn matmul_parallel_bitwise_equals_serial() {
        use crate::util::threadpool::ThreadPool;
        let mut rng = crate::util::rng::Rng::new(11);
        let (m, k, n) = (37, 24, 19);
        let a: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
        let b: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32).collect();
        let mut serial = vec![0.0; m * n];
        matmul(&a, &b, m, k, n, &mut serial);
        for threads in [1usize, 2, 8] {
            let pool = ThreadPool::new(threads);
            let mut par = vec![0.0; m * n];
            matmul_parallel(&pool, &a, &b, m, k, n, &mut par);
            for (i, (x, y)) in serial.iter().zip(&par).enumerate() {
                assert_eq!(
                    x.to_bits(),
                    y.to_bits(),
                    "bit drift at {i} with {threads} threads: {x} vs {y}"
                );
            }
        }
    }

    #[test]
    fn linear_naive_bias() {
        let x = Tensor::from_vec(&[1, 2], vec![1.0, 1.0]);
        let w = Tensor::from_vec(&[2, 3], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let out = linear_naive(&x, &w, Some(&[10.0, 20.0, 30.0]));
        assert_eq!(out.data, vec![15.0, 27.0, 39.0]);
        assert_eq!(out.shape, vec![1, 3]);
    }

    #[test]
    fn softmax_sums_to_one_and_is_stable() {
        let mut row = vec![1000.0, 1001.0, 1002.0];
        softmax_row(&mut row);
        let s: f32 = row.iter().sum();
        assert!((s - 1.0).abs() < 1e-6);
        assert!(row[2] > row[1] && row[1] > row[0]);
        assert!(row.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn rmsnorm_unit_rows() {
        let mut x = vec![3.0, 4.0];
        rmsnorm(&mut x, &[1.0, 1.0], 0.0);
        let rms: f32 = (x.iter().map(|v| v * v).sum::<f32>() / 2.0).sqrt();
        assert!((rms - 1.0).abs() < 1e-6);
    }

    #[test]
    fn mse_mae_basics() {
        let (mse, mae) = mse_mae(&[1.0, 2.0], &[2.0, 4.0]);
        assert!((mse - 2.5).abs() < 1e-12);
        assert!((mae - 1.5).abs() < 1e-12);
    }
}
