//! Read-only file mapping for zero-copy weight loading.
//!
//! [`MappedFile`] binds a file's bytes into the address space via `mmap(2)`
//! on 64-bit little-endian unix targets — the configuration where a raw
//! little-endian f32 blob can be reinterpreted in place — and falls back to
//! a plain heap read everywhere else. Callers never branch on which path
//! was taken: [`MappedFile::bytes`] is the one accessor, and
//! [`MappedFile::is_mapped`] only feeds metrics/tests.
//!
//! No external crate is used: the two syscalls are declared directly
//! against the platform libc that `std` already links. The mapping is
//! `PROT_READ` + `MAP_PRIVATE`, so the kernel shares clean pages across
//! processes and a serving replica can never scribble on the weight file.

use std::fs::File;
use std::io;
use std::path::Path;

/// Whether this build can take the true `mmap` path (64-bit little-endian
/// unix). Elsewhere the type silently degrades to a heap read with the
/// identical API and bit-identical contents.
pub const MMAP_SUPPORTED: bool =
    cfg!(all(unix, target_pointer_width = "64", target_endian = "little"));

#[cfg(all(unix, target_pointer_width = "64", target_endian = "little"))]
mod sys {
    use std::ffi::c_void;
    use std::os::raw::c_int;

    pub const PROT_READ: c_int = 1;
    pub const MAP_PRIVATE: c_int = 2;

    extern "C" {
        pub fn mmap(
            addr: *mut c_void,
            len: usize,
            prot: c_int,
            flags: c_int,
            fd: c_int,
            offset: i64,
        ) -> *mut c_void;
        pub fn munmap(addr: *mut c_void, len: usize) -> c_int;
    }

    pub const MAP_FAILED: *mut c_void = usize::MAX as *mut c_void;
}

enum Backing {
    /// Live `mmap` region (freed with `munmap` on drop).
    #[cfg(all(unix, target_pointer_width = "64", target_endian = "little"))]
    Map { ptr: *const u8, len: usize },
    /// Heap fallback (non-unix / big-endian / empty file / mmap failure).
    Heap(Vec<u8>),
}

/// A file's bytes, mapped read-only when the platform allows it and read
/// to the heap otherwise. Immutable for its whole lifetime, so sharing
/// `&[u8]` views across threads is sound.
pub struct MappedFile {
    backing: Backing,
}

// SAFETY: the region is PROT_READ/MAP_PRIVATE and never handed out
// mutably; concurrent reads of immutable memory are data-race free.
unsafe impl Send for MappedFile {}
unsafe impl Sync for MappedFile {}

impl MappedFile {
    /// Map (or read) `path`. Zero-length files take the heap path — a
    /// zero-length `mmap` is an error by spec, not an empty mapping.
    pub fn open(path: &Path) -> io::Result<MappedFile> {
        let file = File::open(path)?;
        let len = file.metadata()?.len();
        #[cfg(all(unix, target_pointer_width = "64", target_endian = "little"))]
        {
            use std::os::unix::io::AsRawFd;
            if len > 0 && len <= usize::MAX as u64 {
                let len = len as usize;
                // SAFETY: fd is open for reading; len matches the file
                // size read above; a MAP_FAILED return is checked before
                // the pointer is ever used.
                let ptr = unsafe {
                    sys::mmap(
                        std::ptr::null_mut(),
                        len,
                        sys::PROT_READ,
                        sys::MAP_PRIVATE,
                        file.as_raw_fd(),
                        0,
                    )
                };
                if ptr != sys::MAP_FAILED {
                    return Ok(MappedFile { backing: Backing::Map { ptr: ptr as *const u8, len } });
                }
                // Fall through to the heap read on mmap failure (e.g. a
                // filesystem that refuses mappings) — degraded, not fatal.
            }
        }
        let bytes = std::fs::read(path)?;
        let _ = len;
        Ok(MappedFile { backing: Backing::Heap(bytes) })
    }

    /// The file's bytes (identical contents on either backing).
    pub fn bytes(&self) -> &[u8] {
        match &self.backing {
            #[cfg(all(unix, target_pointer_width = "64", target_endian = "little"))]
            // SAFETY: ptr/len came from a successful mmap of exactly `len`
            // bytes, live until Drop.
            Backing::Map { ptr, len } => unsafe { std::slice::from_raw_parts(*ptr, *len) },
            Backing::Heap(v) => v,
        }
    }

    /// Byte length.
    pub fn len(&self) -> usize {
        self.bytes().len()
    }

    /// True when the file is empty.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// True when the bytes are a live `mmap` region (vs the heap
    /// fallback). Observability only — contents are identical either way.
    pub fn is_mapped(&self) -> bool {
        match &self.backing {
            #[cfg(all(unix, target_pointer_width = "64", target_endian = "little"))]
            Backing::Map { .. } => true,
            Backing::Heap(_) => false,
        }
    }
}

impl Drop for MappedFile {
    fn drop(&mut self) {
        #[cfg(all(unix, target_pointer_width = "64", target_endian = "little"))]
        if let Backing::Map { ptr, len } = self.backing {
            // SAFETY: exactly the region mmap returned; unmapped once.
            unsafe {
                sys::munmap(ptr as *mut std::ffi::c_void, len);
            }
        }
    }
}

impl std::fmt::Debug for MappedFile {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "MappedFile(len={}, mapped={})", self.len(), self.is_mapped())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn maps_and_reads_identical_bytes() {
        let dir = std::env::temp_dir().join("stride_mmap_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("blob.bin");
        let want: Vec<u8> = (0..=255u8).cycle().take(10_000).collect();
        std::fs::write(&path, &want).unwrap();
        let m = MappedFile::open(&path).unwrap();
        assert_eq!(m.len(), want.len());
        assert_eq!(m.bytes(), &want[..]);
        assert_eq!(m.is_mapped(), MMAP_SUPPORTED);
    }

    #[test]
    fn empty_file_takes_heap_path() {
        let dir = std::env::temp_dir().join("stride_mmap_test_empty");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("empty.bin");
        std::fs::write(&path, []).unwrap();
        let m = MappedFile::open(&path).unwrap();
        assert!(m.is_empty());
        assert!(!m.is_mapped());
    }

    #[test]
    fn missing_file_is_an_error() {
        assert!(MappedFile::open(Path::new("/nonexistent/stride/blob")).is_err());
    }
}
