//! Fixed-size worker pool over std::sync primitives (no tokio offline).
//! Backs the HTTP server's connection handling and the load generator.

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

pub struct ThreadPool {
    workers: Vec<thread::JoinHandle<()>>,
    tx: Option<mpsc::Sender<Job>>,
}

impl ThreadPool {
    pub fn new(size: usize) -> ThreadPool {
        assert!(size > 0);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..size)
            .map(|i| {
                let rx = Arc::clone(&rx);
                thread::Builder::new()
                    .name(format!("stride-worker-{i}"))
                    .spawn(move || loop {
                        let job = {
                            let guard = rx.lock().unwrap();
                            guard.recv()
                        };
                        match job {
                            Ok(job) => job(),
                            Err(_) => break, // sender dropped: shutdown
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { workers, tx: Some(tx) }
    }

    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.tx
            .as_ref()
            .expect("pool shut down")
            .send(Box::new(f))
            .expect("workers alive");
    }

    /// Run `f` over 0..n from the pool and wait for all results (scoped join).
    pub fn map_wait<T, F>(&self, n: usize, f: F) -> Vec<T>
    where
        T: Send + 'static,
        F: Fn(usize) -> T + Send + Sync + 'static,
    {
        let f = Arc::new(f);
        let (tx, rx) = mpsc::channel();
        for i in 0..n {
            let f = Arc::clone(&f);
            let tx = tx.clone();
            self.execute(move || {
                let _ = tx.send((i, f(i)));
            });
        }
        drop(tx);
        let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
        for (i, v) in rx {
            out[i] = Some(v);
        }
        out.into_iter().map(|v| v.expect("worker panicked")).collect()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn executes_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        let (tx, rx) = mpsc::channel();
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            let tx = tx.clone();
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
                let _ = tx.send(());
            });
        }
        for _ in 0..100 {
            rx.recv_timeout(std::time::Duration::from_secs(5)).unwrap();
        }
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn map_wait_ordered() {
        let pool = ThreadPool::new(3);
        let out = pool.map_wait(10, |i| i * i);
        assert_eq!(out, (0..10).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn drop_joins_workers() {
        let pool = ThreadPool::new(2);
        pool.execute(|| std::thread::sleep(std::time::Duration::from_millis(10)));
        drop(pool); // must not hang or panic
    }
}
