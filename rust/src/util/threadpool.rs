//! Fixed-size worker pool over std::sync primitives (no tokio offline).
//! Backs the HTTP server's connection handling, the load generator, and —
//! since the kernel-layer PR — the native backend's row-parallel matmul
//! and the batched verify fan-out.
//!
//! Panic safety: a panicking job is caught in the worker loop (the worker
//! thread survives and keeps draining the queue), and [`ThreadPool::map_wait`]
//! surfaces the panic as an `Err` instead of poisoning the pool. Before
//! this, one bad job silently shrank the pool and a later `map_wait` died
//! on a missing result.
//!
//! A process-wide shared pool for compute kernels lives behind
//! [`global_pool`]; its size comes from `STRIDE_THREADS` (or available
//! parallelism, capped at 8) and can be fixed programmatically once via
//! [`init_global_pool`] before first use. The kernel pool's workers are
//! named `stride-kernel-*` (other pools default to `stride-worker-*`);
//! [`in_worker`] lets nested code detect that it is already running on
//! the *kernel* pool and fall back to the serial path instead of
//! deadlocking on a recursive `map_wait`.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc;
use std::sync::{Arc, Mutex, OnceLock};
use std::thread;

use anyhow::{anyhow, Result};

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Fixed-size worker pool: fire-and-forget [`ThreadPool::execute`] plus a
/// scoped-join [`ThreadPool::map_wait`] for compute fan-outs.
pub struct ThreadPool {
    workers: Vec<thread::JoinHandle<()>>,
    tx: Option<mpsc::Sender<Job>>,
}

impl ThreadPool {
    /// Pool of `size` workers with the default `stride-worker` name prefix.
    pub fn new(size: usize) -> ThreadPool {
        Self::with_name(size, "stride-worker")
    }

    /// Pool with a custom worker-name prefix. The global compute pool uses
    /// `stride-kernel` so [`in_worker`] identifies *its* workers
    /// specifically — the HTTP connection pool's `stride-worker` threads
    /// must not trip the serial-fallback guard.
    pub fn with_name(size: usize, prefix: &str) -> ThreadPool {
        assert!(size > 0);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..size)
            .map(|i| {
                let rx = Arc::clone(&rx);
                thread::Builder::new()
                    .name(format!("{prefix}-{i}"))
                    .spawn(move || loop {
                        let job = {
                            let guard = rx.lock().unwrap();
                            guard.recv()
                        };
                        match job {
                            // A panicking job must not kill the worker: the
                            // pool would silently shrink and a later
                            // map_wait would hang on the missing slot.
                            Ok(job) => {
                                let _ = catch_unwind(AssertUnwindSafe(job));
                            }
                            Err(_) => break, // sender dropped: shutdown
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { workers, tx: Some(tx) }
    }

    /// Worker thread count.
    pub fn size(&self) -> usize {
        self.workers.len()
    }

    /// Enqueue a fire-and-forget job (no result, no join).
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.tx
            .as_ref()
            .expect("pool shut down")
            .send(Box::new(f))
            .expect("workers alive");
    }

    /// Run `f` over 0..n from the pool and wait for all results (scoped
    /// join: every job has completed by the time this returns). A panic in
    /// any `f(i)` is caught and surfaced as an `Err` naming the first
    /// panicked index — the pool itself stays usable.
    ///
    /// Must not be called from a worker of the same pool: the caller's job
    /// would block waiting for queue slots behind itself (see [`in_worker`]).
    pub fn map_wait<T, F>(&self, n: usize, f: F) -> Result<Vec<T>>
    where
        T: Send + 'static,
        F: Fn(usize) -> T + Send + Sync + 'static,
    {
        let f = Arc::new(f);
        let (tx, rx) = mpsc::channel();
        for i in 0..n {
            let f = Arc::clone(&f);
            let tx = tx.clone();
            self.execute(move || {
                // Catch here (not just in the worker loop) so the slot is
                // always filled and the panic is attributable to its index.
                let r = catch_unwind(AssertUnwindSafe(|| f(i)));
                let _ = tx.send((i, r));
            });
        }
        drop(tx);
        let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
        let mut panicked: Vec<(usize, String)> = Vec::new();
        for (i, r) in rx {
            match r {
                Ok(v) => out[i] = Some(v),
                Err(payload) => panicked.push((i, panic_message(&payload))),
            }
        }
        if let Some((i, msg)) = panicked.into_iter().min_by_key(|(i, _)| *i) {
            return Err(anyhow!("map_wait job {i} panicked: {msg}"));
        }
        out.into_iter()
            .map(|v| v.ok_or_else(|| anyhow!("map_wait job lost (worker died)")))
            .collect()
    }
}

fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

// ---------------------------------------------------------------------------
// Process-wide compute pool (kernel layer).
// ---------------------------------------------------------------------------

static GLOBAL: OnceLock<ThreadPool> = OnceLock::new();

/// Thread count the global pool would be built with: `STRIDE_THREADS` if
/// set (>= 1), else available parallelism capped at 8 (the compute kernels
/// stop scaling before the HTTP worker count does).
pub fn default_threads() -> usize {
    if let Ok(v) = std::env::var("STRIDE_THREADS") {
        if let Ok(n) = v.trim().parse::<usize>() {
            return n.max(1);
        }
    }
    thread::available_parallelism().map(|n| n.get()).unwrap_or(1).min(8)
}

const KERNEL_POOL_NAME: &str = "stride-kernel";

/// The shared compute pool, built on first use with [`default_threads`].
pub fn global_pool() -> &'static ThreadPool {
    GLOBAL.get_or_init(|| ThreadPool::with_name(default_threads(), KERNEL_POOL_NAME))
}

/// Fix the global pool size before first use (server startup). Returns the
/// actual size — an earlier initialization wins (the constructor only runs
/// if the pool does not exist yet; no threads are spawned and thrown away).
pub fn init_global_pool(threads: usize) -> usize {
    GLOBAL
        .get_or_init(|| ThreadPool::with_name(threads.max(1), KERNEL_POOL_NAME))
        .size()
}

/// True when the current thread is a *global compute pool* worker. Kernel
/// code uses this to run serially instead of issuing a nested
/// (deadlocking) `map_wait`. Other pools (the HTTP connection pool) keep
/// the `stride-worker` prefix and do not trip this guard.
pub fn in_worker() -> bool {
    thread::current().name().map_or(false, |n| n.starts_with(KERNEL_POOL_NAME))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn executes_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        let (tx, rx) = mpsc::channel();
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            let tx = tx.clone();
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
                let _ = tx.send(());
            });
        }
        for _ in 0..100 {
            rx.recv_timeout(std::time::Duration::from_secs(5)).unwrap();
        }
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn map_wait_ordered() {
        let pool = ThreadPool::new(3);
        let out = pool.map_wait(10, |i| i * i).unwrap();
        assert_eq!(out, (0..10).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn map_wait_surfaces_panics_and_pool_survives() {
        let pool = ThreadPool::new(2);
        let err = pool
            .map_wait(4, |i| {
                if i == 2 {
                    panic!("boom at {i}");
                }
                i
            })
            .unwrap_err();
        assert!(err.to_string().contains("panicked"), "{err}");
        assert!(err.to_string().contains("boom"), "{err}");
        // The workers caught the unwind: the pool still runs jobs.
        let out = pool.map_wait(6, |i| i + 1).unwrap();
        assert_eq!(out, vec![1, 2, 3, 4, 5, 6]);
    }

    #[test]
    fn execute_panic_does_not_shrink_pool() {
        let pool = ThreadPool::new(1);
        pool.execute(|| panic!("job dies, worker must not"));
        // Single worker: if the panic killed it, this would hang/err.
        let out = pool.map_wait(3, |i| i).unwrap();
        assert_eq!(out, vec![0, 1, 2]);
    }

    #[test]
    fn drop_joins_workers() {
        let pool = ThreadPool::new(2);
        pool.execute(|| std::thread::sleep(std::time::Duration::from_millis(10)));
        drop(pool); // must not hang or panic
    }

    #[test]
    fn worker_detection_is_kernel_pool_specific() {
        assert!(!in_worker());
        // Only kernel-named workers trip the guard...
        let kernel = ThreadPool::with_name(2, KERNEL_POOL_NAME);
        let flags = kernel.map_wait(4, |_| in_worker()).unwrap();
        assert!(flags.iter().all(|&f| f));
        // ...a default-named pool (e.g. HTTP connections) must not.
        let http = ThreadPool::new(2);
        let flags = http.map_wait(4, |_| in_worker()).unwrap();
        assert!(flags.iter().all(|&f| !f), "non-kernel pool misdetected as kernel worker");
    }

    #[test]
    fn global_pool_has_workers() {
        assert!(global_pool().size() >= 1);
    }
}
