//! Statistics substrate: normal CDF/erf, summary stats, quantiles, and a
//! log-bucketed latency histogram (HDR-style) for the serving metrics.

/// erf via Abramowitz & Stegun 7.1.26 refined: max abs error < 1.2e-7,
/// plenty for acceptance/overlap math (we also cross-check against series).
pub fn erf(x: f64) -> f64 {
    let sign = if x < 0.0 { -1.0 } else { 1.0 };
    let x = x.abs();
    let t = 1.0 / (1.0 + 0.3275911 * x);
    let y = 1.0
        - (((((1.061405429 * t - 1.453152027) * t) + 1.421413741) * t - 0.284496736) * t
            + 0.254829592)
            * t
            * (-x * x).exp();
    sign * y
}

/// Standard normal CDF.
#[inline]
pub fn phi(x: f64) -> f64 {
    0.5 * (1.0 + erf(x / std::f64::consts::SQRT_2))
}

/// Closed-form overlap of two equal-covariance isotropic Gaussians
/// (paper Remark 5): beta = 2 * Phi(-delta / 2), delta = ||mu_p - mu_q|| / sigma.
#[inline]
pub fn gaussian_overlap(mahalanobis_gap: f64) -> f64 {
    2.0 * phi(-mahalanobis_gap / 2.0)
}

/// Hoeffding sample size: N such that P(|a_hat - a| >= eps) <= delta
/// (paper §3.5: P <= 2 exp(-2 N eps^2)).
pub fn hoeffding_n(eps: f64, delta: f64) -> usize {
    ((2.0f64 / delta).ln() / (2.0 * eps * eps)).ceil() as usize
}

/// Hoeffding deviation bound for given N: eps such that the failure
/// probability is `delta`.
pub fn hoeffding_eps(n: usize, delta: f64) -> f64 {
    ((2.0f64 / delta).ln() / (2.0 * n as f64)).sqrt()
}

/// Running summary statistics (Welford).
#[derive(Clone, Debug, Default)]
pub struct Summary {
    /// Samples pushed so far.
    pub n: u64,
    mean: f64,
    m2: f64,
    /// Smallest sample seen (`+inf` when empty).
    pub min: f64,
    /// Largest sample seen (`-inf` when empty).
    pub max: f64,
}

impl Summary {
    /// Empty summary.
    pub fn new() -> Self {
        Summary { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }
    /// Fold one sample in.
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }
    /// Sample mean.
    pub fn mean(&self) -> f64 {
        self.mean
    }
    /// Unbiased sample variance (0 for fewer than two samples).
    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }
    /// Sample standard deviation.
    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }
}

/// Exact quantile over a (small) sample; q in [0, 1], linear interpolation.
pub fn quantile(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    let pos = q.clamp(0.0, 1.0) * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        sorted[lo] + (sorted[hi] - sorted[lo]) * (pos - lo as f64)
    }
}

/// Log-bucketed latency histogram: ~4.6% relative resolution from 100ns to
/// ~100s in 456 buckets, constant-time record, mergeable. The serving
/// metrics path records nanoseconds.
#[derive(Clone, Debug)]
pub struct LatencyHistogram {
    buckets: Vec<u64>,
    count: u64,
    sum_ns: u128,
    max_ns: u64,
}

const HIST_BUCKETS: usize = 456;
const HIST_MIN_NS: f64 = 100.0;
const HIST_GROWTH: f64 = 1.0457; // 456 buckets * log(1.0457) covers ~9 decades

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHistogram {
    /// Empty histogram.
    pub fn new() -> Self {
        LatencyHistogram { buckets: vec![0; HIST_BUCKETS], count: 0, sum_ns: 0, max_ns: 0 }
    }

    #[inline]
    fn index(ns: u64) -> usize {
        if ns as f64 <= HIST_MIN_NS {
            return 0;
        }
        let idx = ((ns as f64 / HIST_MIN_NS).ln() / HIST_GROWTH.ln()) as usize;
        idx.min(HIST_BUCKETS - 1)
    }

    /// Record one latency in nanoseconds.
    pub fn record_ns(&mut self, ns: u64) {
        self.buckets[Self::index(ns)] += 1;
        self.count += 1;
        self.sum_ns += ns as u128;
        self.max_ns = self.max_ns.max(ns);
    }

    /// Record one latency from a [`std::time::Duration`].
    pub fn record(&mut self, d: std::time::Duration) {
        self.record_ns(d.as_nanos() as u64);
    }

    /// Number of recorded samples.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Mean latency in nanoseconds (0 when empty).
    pub fn mean_ns(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_ns as f64 / self.count as f64
        }
    }

    /// Largest recorded latency in nanoseconds.
    pub fn max_ns(&self) -> u64 {
        self.max_ns
    }

    /// Approximate quantile (upper edge of the containing bucket).
    pub fn quantile_ns(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let rank = (q.clamp(0.0, 1.0) * self.count as f64).ceil() as u64;
        let mut seen = 0;
        for (i, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= rank.max(1) {
                return HIST_MIN_NS * HIST_GROWTH.powi(i as i32 + 1);
            }
        }
        self.max_ns as f64
    }

    /// Add another histogram's samples into this one (bucket-wise).
    pub fn merge(&mut self, other: &LatencyHistogram) {
        for (a, b) in self.buckets.iter_mut().zip(&other.buckets) {
            *a += b;
        }
        self.count += other.count;
        self.sum_ns += other.sum_ns;
        self.max_ns = self.max_ns.max(other.max_ns);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn erf_known_values() {
        // Reference values (Abramowitz & Stegun tables).
        for (x, want) in [(0.0, 0.0), (0.5, 0.5204999), (1.0, 0.8427008), (2.0, 0.9953223)] {
            assert!((erf(x) - want).abs() < 2e-6, "erf({x})");
            assert!((erf(-x) + want).abs() < 2e-6, "erf(-{x})");
        }
    }

    #[test]
    fn phi_known_values() {
        assert!((phi(0.0) - 0.5).abs() < 1e-9);
        assert!((phi(1.96) - 0.9750021).abs() < 1e-5);
        assert!((phi(-1.0) - 0.1586553).abs() < 1e-5);
    }

    #[test]
    fn overlap_limits() {
        assert!((gaussian_overlap(0.0) - 1.0).abs() < 1e-6, "identical heads overlap 1");
        assert!(gaussian_overlap(10.0) < 1e-4, "far heads overlap ~0");
        // Monotone decreasing.
        let mut prev = 1.0;
        for i in 1..50 {
            let b = gaussian_overlap(i as f64 * 0.2);
            assert!(b < prev);
            prev = b;
        }
    }

    #[test]
    fn hoeffding_roundtrip() {
        let n = hoeffding_n(0.05, 0.05);
        assert!(hoeffding_eps(n, 0.05) <= 0.05 + 1e-9);
        assert!(hoeffding_eps(n - 1, 0.05) > 0.05 - 1e-3);
        // Paper's claim: "a modest number of held-out samples".
        assert!(n < 1000, "N for (5%, 95%) should be modest, got {n}");
    }

    #[test]
    fn summary_matches_direct() {
        let xs = [1.0, 2.0, 3.0, 4.0, 10.0];
        let mut s = Summary::new();
        for &x in &xs {
            s.push(x);
        }
        assert!((s.mean() - 4.0).abs() < 1e-12);
        let var = xs.iter().map(|x| (x - 4.0f64).powi(2)).sum::<f64>() / 4.0;
        assert!((s.var() - var).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 10.0);
    }

    #[test]
    fn quantile_interp() {
        let v = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(quantile(&v, 0.0), 1.0);
        assert_eq!(quantile(&v, 1.0), 4.0);
        assert!((quantile(&v, 0.5) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn histogram_quantiles_within_resolution() {
        let mut h = LatencyHistogram::new();
        for i in 1..=10_000u64 {
            h.record_ns(i * 1_000); // 1us .. 10ms uniform
        }
        let p50 = h.quantile_ns(0.5);
        assert!((p50 - 5e6).abs() / 5e6 < 0.10, "p50 {p50}");
        let p99 = h.quantile_ns(0.99);
        assert!((p99 - 9.9e6).abs() / 9.9e6 < 0.10, "p99 {p99}");
        assert_eq!(h.count(), 10_000);
    }

    #[test]
    fn histogram_merge() {
        let mut a = LatencyHistogram::new();
        let mut b = LatencyHistogram::new();
        a.record_ns(1_000);
        b.record_ns(1_000_000);
        a.merge(&b);
        assert_eq!(a.count(), 2);
        assert_eq!(a.max_ns(), 1_000_000);
    }
}
