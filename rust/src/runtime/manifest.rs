//! Artifact manifest (written by `python/compile/aot.py`): what models
//! exist, which HLO files implement them at which batch sizes, where the
//! weight blobs and golden vectors live.

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::nn::ModelDims;
use crate::util::json::Json;

/// One model described by the manifest (target or draft).
#[derive(Clone, Debug)]
pub struct ModelEntry {
    /// Model name as exported by the Python side.
    pub name: String,
    /// Transformer dimensions.
    pub dims: ModelDims,
    /// Total parameter count.
    pub param_count: usize,
    /// Path to the raw weight blob.
    pub weights_file: PathBuf,
    /// Raw tensor index (array of {name, shape, offset}) for Weights::load.
    pub tensor_index: Json,
}

/// One compiled HLO artifact on disk.
#[derive(Clone, Debug)]
pub struct ArtifactEntry {
    /// Path to the HLO-text file.
    pub file: PathBuf,
    /// "target" | "draft".
    pub model: String,
    /// Batch size the artifact was specialized for.
    pub batch: usize,
    /// Sequence length this artifact was specialized for (<= manifest
    /// n_ctx; short variants serve the decode hot path, see §Perf).
    pub n_ctx: usize,
    /// "fused" | "pallas".
    pub kernel: String,
}

/// The artifact-directory manifest (`manifest.json`).
#[derive(Clone, Debug)]
pub struct Manifest {
    /// The artifact directory the manifest was loaded from.
    pub dir: PathBuf,
    /// Patch size (values per patch token).
    pub patch: usize,
    /// Maximum model context in patches.
    pub n_ctx: usize,
    /// Batch sizes with compiled artifacts.
    pub batches: Vec<usize>,
    /// The large target model.
    pub target: ModelEntry,
    /// The small draft model.
    pub draft: ModelEntry,
    /// All compiled HLO artifacts.
    pub artifacts: Vec<ArtifactEntry>,
    /// Distillation noise σ the draft was trained with.
    pub distill_sigma: f64,
    /// Exported mean target-draft head gap (acceptance sanity anchor).
    pub mean_gap: f64,
    /// Whether the artifacts were built in quick (CI) mode.
    pub quick: bool,
}

fn model_entry(dir: &Path, j: &Json, patch: usize, n_ctx: usize) -> Result<ModelEntry> {
    let get = |k: &str| -> Result<usize> {
        j.get(k).and_then(Json::as_usize).with_context(|| format!("model field {k}"))
    };
    Ok(ModelEntry {
        name: j.get("name").and_then(Json::as_str).context("model name")?.to_string(),
        dims: ModelDims {
            patch,
            n_ctx,
            d_model: get("d_model")?,
            n_layers: get("n_layers")?,
            n_heads: get("n_heads")?,
            d_ff: get("d_ff")?,
        },
        param_count: get("param_count")?,
        weights_file: dir.join(j.get("weights").and_then(Json::as_str).context("weights")?),
        tensor_index: j.get("tensors").context("tensors")?.clone(),
    })
}

impl Manifest {
    /// Load and validate `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts`)", path.display()))?;
        let j = Json::parse(&text).context("parsing manifest.json")?;
        let patch = j.get("patch").and_then(Json::as_usize).context("patch")?;
        let n_ctx = j.get("n_ctx").and_then(Json::as_usize).context("n_ctx")?;
        let batches = j
            .get("batches")
            .and_then(Json::as_arr)
            .context("batches")?
            .iter()
            .filter_map(Json::as_usize)
            .collect();
        let artifacts = j
            .get("artifacts")
            .and_then(Json::as_arr)
            .context("artifacts")?
            .iter()
            .map(|a| {
                Ok(ArtifactEntry {
                    file: dir.join(a.get("file").and_then(Json::as_str).context("file")?),
                    model: a.get("model").and_then(Json::as_str).context("model")?.to_string(),
                    batch: a.get("batch").and_then(Json::as_usize).context("batch")?,
                    n_ctx: a.get("n_ctx").and_then(Json::as_usize).unwrap_or(n_ctx),
                    kernel: a.get("kernel").and_then(Json::as_str).context("kernel")?.to_string(),
                })
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(Manifest {
            dir: dir.to_path_buf(),
            patch,
            n_ctx,
            batches,
            target: model_entry(dir, j.path(&["models", "target"]).context("models.target")?, patch, n_ctx)?,
            draft: model_entry(dir, j.path(&["models", "draft"]).context("models.draft")?, patch, n_ctx)?,
            artifacts,
            distill_sigma: j.path(&["distill", "sigma"]).and_then(Json::as_f64).unwrap_or(0.5),
            mean_gap: j.path(&["distill", "mean_gap"]).and_then(Json::as_f64).unwrap_or(f64::NAN),
            quick: j.get("quick").and_then(Json::as_bool).unwrap_or(false),
        })
    }

    /// Find the cheapest HLO artifact for (model, kernel) that fits
    /// `min_batch` rows of `min_n` patches (cost ~ batch * n).
    pub fn artifact_for(&self, model: &str, kernel: &str, min_batch: usize) -> Option<&ArtifactEntry> {
        self.artifacts
            .iter()
            .filter(|a| a.model == model && a.kernel == kernel && a.batch >= min_batch)
            .min_by_key(|a| (a.batch * a.n_ctx, a.n_ctx))
    }

    /// All shape variants available for (model, kernel), ascending cost.
    pub fn batch_variants(&self, model: &str, kernel: &str) -> Vec<&ArtifactEntry> {
        let mut v: Vec<&ArtifactEntry> = self
            .artifacts
            .iter()
            .filter(|a| a.model == model && a.kernel == kernel)
            .collect();
        v.sort_by_key(|a| (a.batch, a.n_ctx));
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn loads_real_manifest_if_present() {
        let dir = crate::artifacts_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("SKIP: run `make artifacts`");
            return;
        }
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.patch, 24);
        assert_eq!(m.n_ctx, 32);
        assert!(m.target.param_count > m.draft.param_count * 3, "draft ~0.25x");
        assert!(m.artifact_for("target", "fused", 1).is_some());
        assert!(m.artifact_for("draft", "fused", 1).is_some());
        // Batch selection picks the smallest variant that fits.
        let a = m.artifact_for("target", "fused", 2).unwrap();
        assert!(a.batch >= 2);
        let variants = m.batch_variants("target", "fused");
        assert!(variants.windows(2).all(|w| (w[0].batch, w[0].n_ctx) < (w[1].batch, w[1].n_ctx)));
        // Short-sequence variants exist for the decode hot path.
        assert!(variants.iter().any(|a| a.n_ctx < m.n_ctx), "n-specialized variants");
    }

    #[test]
    fn artifact_for_none_when_too_big() {
        let dir = crate::artifacts_dir();
        if !dir.join("manifest.json").exists() {
            return;
        }
        let m = Manifest::load(&dir).unwrap();
        assert!(m.artifact_for("target", "fused", 100_000).is_none());
    }
}
