//! XLA/PJRT runtime layer: artifact manifest, compile cache, execution.

pub mod engine;
pub mod manifest;

pub use engine::{Engine, Executable};
pub use manifest::{ArtifactEntry, Manifest, ModelEntry};
