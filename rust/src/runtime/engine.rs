//! PJRT runtime: HLO-text loading, compile caching, execution, and per-
//! executable wall-clock accounting (the paper's measured `c` comes from
//! these timers).
//!
//! NOTE ON THREADING: the `xla` crate's `PjRtClient` is `Rc`-based and not
//! `Send`; the serving coordinator therefore owns one `Engine` on a
//! dedicated executor thread (see `server::engine_thread`), which is also
//! the natural continuous-batching design.

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::Path;
use std::time::Instant;

use anyhow::{Context, Result};

// The offline stub of the external `xla` crate (see `crate::xla`): same
// API, fails fast at client creation. Swap for the real dependency to
// restore PJRT execution.
use crate::util::stats::Summary;
use crate::xla;

/// A compiled, named executable with timing stats.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    /// Artifact file stem ("target_fwd_b1", ...).
    pub name: String,
    /// Input shape [b, n, p] this artifact was specialized for.
    pub shape: (usize, usize, usize),
    timings: RefCell<Summary>,
}

impl Executable {
    /// Execute on a flat row-major buffer of exactly b*n*p floats;
    /// returns the flat output (same shape).
    pub fn run(&self, input: &[f32]) -> Result<Vec<f32>> {
        let (b, n, p) = self.shape;
        anyhow::ensure!(
            input.len() == b * n * p,
            "{}: input len {} != {}x{}x{}",
            self.name,
            input.len(),
            b,
            n,
            p
        );
        let t0 = Instant::now();
        let lit = xla::Literal::vec1(input)
            .reshape(&[b as i64, n as i64, p as i64])
            .context("reshape literal")?;
        let result = self
            .exe
            .execute::<xla::Literal>(&[lit])
            .with_context(|| format!("executing {}", self.name))?[0][0]
            .to_literal_sync()
            .context("to_literal_sync")?;
        // aot.py lowers with return_tuple=True: unwrap the 1-tuple.
        let out = result.to_tuple1().context("to_tuple1")?;
        let v = out.to_vec::<f32>().context("to_vec")?;
        self.timings.borrow_mut().push(t0.elapsed().as_secs_f64());
        Ok(v)
    }

    /// Mean wall-clock seconds per call so far (NaN if never run).
    pub fn mean_secs(&self) -> f64 {
        let t = self.timings.borrow();
        if t.n == 0 {
            f64::NAN
        } else {
            t.mean()
        }
    }

    /// Number of completed `run` calls.
    pub fn calls(&self) -> u64 {
        self.timings.borrow().n
    }
}

/// PJRT CPU engine with a compile cache keyed by artifact path.
pub struct Engine {
    client: xla::PjRtClient,
    cache: HashMap<String, std::rc::Rc<Executable>>,
}

impl Engine {
    /// Create the PJRT CPU client (fails in stub builds — see
    /// `crate::xla`).
    pub fn cpu() -> Result<Engine> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Engine { client, cache: HashMap::new() })
    }

    /// PJRT platform name.
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO-text artifact (cached).
    pub fn load(
        &mut self,
        path: &Path,
        shape: (usize, usize, usize),
    ) -> Result<std::rc::Rc<Executable>> {
        let key = path.to_string_lossy().to_string();
        if let Some(exe) = self.cache.get(&key) {
            return Ok(exe.clone());
        }
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&key)
            .with_context(|| format!("parsing HLO text {key}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("PJRT compile of {key}"))?;
        log::info!("compiled {key} in {:.2}s", t0.elapsed().as_secs_f64());
        let name = path
            .file_stem()
            .map(|s| s.to_string_lossy().into_owned())
            .unwrap_or_else(|| key.clone());
        let entry = std::rc::Rc::new(Executable {
            exe,
            name,
            shape,
            timings: RefCell::new(Summary::new()),
        });
        self.cache.insert(key, entry.clone());
        Ok(entry)
    }

    /// Number of distinct compiled artifacts in the cache.
    pub fn cached_count(&self) -> usize {
        self.cache.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts() -> Option<std::path::PathBuf> {
        let dir = crate::artifacts_dir();
        if dir.join("manifest.json").exists() {
            Some(dir)
        } else {
            eprintln!("SKIP: run `make artifacts`");
            None
        }
    }

    #[test]
    fn load_run_and_cache() {
        let Some(dir) = artifacts() else { return };
        let Ok(mut eng) = Engine::cpu() else {
            eprintln!("SKIP: PJRT unavailable (offline xla stub?)");
            return;
        };
        let exe = eng.load(&dir.join("draft_fwd_b1.hlo.txt"), (1, 32, 24)).unwrap();
        let out = exe.run(&vec![0.1f32; 32 * 24]).unwrap();
        assert_eq!(out.len(), 32 * 24);
        assert!(out.iter().all(|v| v.is_finite()));
        assert_eq!(exe.calls(), 1);
        assert!(exe.mean_secs() > 0.0);
        // Second load hits the cache.
        let exe2 = eng.load(&dir.join("draft_fwd_b1.hlo.txt"), (1, 32, 24)).unwrap();
        assert_eq!(eng.cached_count(), 1);
        assert_eq!(exe2.calls(), 1);
    }

    #[test]
    fn wrong_input_len_rejected() {
        let Some(dir) = artifacts() else { return };
        let Ok(mut eng) = Engine::cpu() else {
            eprintln!("SKIP: PJRT unavailable (offline xla stub?)");
            return;
        };
        let exe = eng.load(&dir.join("draft_fwd_b1.hlo.txt"), (1, 32, 24)).unwrap();
        assert!(exe.run(&vec![0.0f32; 5]).is_err());
    }

    #[test]
    fn deterministic_outputs() {
        let Some(dir) = artifacts() else { return };
        let Ok(mut eng) = Engine::cpu() else {
            eprintln!("SKIP: PJRT unavailable (offline xla stub?)");
            return;
        };
        let exe = eng.load(&dir.join("draft_fwd_b1.hlo.txt"), (1, 32, 24)).unwrap();
        let input: Vec<f32> = (0..32 * 24).map(|i| (i as f32 * 0.01).sin()).collect();
        let a = exe.run(&input).unwrap();
        let b = exe.run(&input).unwrap();
        assert_eq!(a, b);
    }
}
