//! Blocking HTTP/1.1 client helper for the load generator, examples, and
//! integration tests. One request per call; no connection reuse needed at
//! the rates we generate (the server supports keep-alive, the loadgen
//! measures end-to-end latency including connect, which is what a web
//! client would see).
//!
//! Every request carries connect/read/write timeouts, so a stalled or
//! dying server cannot hang a caller. [`http_request_retry`] adds a
//! bounded, seeded-jitter exponential backoff that honors the server's
//! `Retry-After` hint on 429/503 — the client-side half of the serving
//! tier's shed/drain protocol — and gives up with a typed
//! [`RetryError`] instead of retrying forever.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpStream, ToSocketAddrs};
use std::time::Duration;

use crate::util::rng::uniform01;

/// Connect timeout for every request (a dead host fails fast).
const CONNECT_TIMEOUT: Duration = Duration::from_secs(10);
/// Read/write timeouts (a stalled server cannot hang the caller).
const IO_TIMEOUT: Duration = Duration::from_secs(60);

/// A received HTTP response.
#[derive(Clone, Debug)]
pub struct HttpResponse {
    /// Status code (200, 404, ...).
    pub status: u16,
    /// Response headers in arrival order.
    pub headers: Vec<(String, String)>,
    /// Raw response body.
    pub body: Vec<u8>,
}

impl HttpResponse {
    /// Body as UTF-8 (empty string when not valid UTF-8).
    pub fn body_str(&self) -> &str {
        std::str::from_utf8(&self.body).unwrap_or("")
    }
}

/// Issue a single HTTP/1.1 request to `addr` ("host:port").
pub fn http_request(
    addr: &str,
    method: &str,
    path: &str,
    body: Option<&[u8]>,
) -> std::io::Result<HttpResponse> {
    let sock = addr
        .to_socket_addrs()?
        .next()
        .ok_or_else(|| std::io::Error::new(std::io::ErrorKind::InvalidInput, "unresolvable addr"))?;
    let mut stream = TcpStream::connect_timeout(&sock, CONNECT_TIMEOUT)?;
    stream.set_read_timeout(Some(IO_TIMEOUT))?;
    stream.set_write_timeout(Some(IO_TIMEOUT))?;
    stream.set_nodelay(true)?;
    let body = body.unwrap_or(&[]);
    let head = format!(
        "{method} {path} HTTP/1.1\r\nHost: {addr}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    );
    stream.write_all(head.as_bytes())?;
    stream.write_all(body)?;
    stream.flush()?;

    let mut reader = BufReader::new(stream);
    let mut status_line = String::new();
    reader.read_line(&mut status_line)?;
    let status: u16 = status_line
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .ok_or_else(|| std::io::Error::new(std::io::ErrorKind::InvalidData, "bad status line"))?;
    let mut headers = Vec::new();
    let mut content_len: Option<usize> = None;
    loop {
        let mut h = String::new();
        if reader.read_line(&mut h)? == 0 {
            break;
        }
        let h = h.trim_end();
        if h.is_empty() {
            break;
        }
        if let Some((k, v)) = h.split_once(':') {
            let k = k.trim().to_string();
            let v = v.trim().to_string();
            if k.eq_ignore_ascii_case("content-length") {
                content_len = v.parse().ok();
            }
            headers.push((k, v));
        }
    }
    let body = match content_len {
        Some(n) => {
            let mut buf = vec![0u8; n];
            reader.read_exact(&mut buf)?;
            buf
        }
        None => {
            let mut buf = Vec::new();
            reader.read_to_end(&mut buf)?;
            buf
        }
    };
    Ok(HttpResponse { status, headers, body })
}

/// Backoff policy for [`http_request_retry`]: bounded attempts, capped
/// exponential backoff, seeded jitter (a fleet of clients retrying the
/// same shed does not stampede in lockstep, yet every run is
/// replayable from its seed).
#[derive(Clone, Copy, Debug)]
pub struct RetryPolicy {
    /// Total attempts, first try included (≥ 1).
    pub attempts: u32,
    /// Backoff before retry k is `base * 2^(k-1)`, jittered ±50%...
    pub base_backoff: Duration,
    /// ...and never more than this cap (which also caps an honored
    /// `Retry-After`, so a hostile hint cannot park the client).
    pub max_backoff: Duration,
    /// Jitter seed: the sleep before retry k is a pure function of
    /// `(seed, k)`.
    pub seed: u64,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy {
            attempts: 4,
            base_backoff: Duration::from_millis(50),
            max_backoff: Duration::from_secs(2),
            seed: 0x5E7_BAC0FF,
        }
    }
}

impl RetryPolicy {
    /// Sleep before retry `k` (1-based): capped exponential backoff
    /// with seeded multiplicative jitter in [0.5, 1.5), overridden by
    /// the server's `Retry-After` hint (seconds) when one was given —
    /// still jittered and still capped.
    fn backoff(&self, k: u32, retry_after_secs: Option<u64>) -> Duration {
        let base = match retry_after_secs {
            Some(s) => Duration::from_secs(s),
            None => self.base_backoff.saturating_mul(1u32 << (k - 1).min(16)),
        };
        let jitter = 0.5 + uniform01(self.seed, k as u64);
        base.min(self.max_backoff).mul_f64(jitter).min(self.max_backoff)
    }
}

/// Why [`http_request_retry`] gave up.
#[derive(Debug)]
pub enum RetryError {
    /// Every attempt was answered with a retryable status (429 or 503).
    /// The last such response is included — its body carries the typed
    /// `error_code` the server sent.
    Exhausted {
        /// Attempts made before giving up.
        attempts: u32,
        /// The final retryable response.
        last: HttpResponse,
    },
    /// Every attempt failed at the transport layer (connect/read/write).
    Io {
        /// Attempts made before giving up.
        attempts: u32,
        /// The final transport error.
        last: std::io::Error,
    },
}

impl std::fmt::Display for RetryError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RetryError::Exhausted { attempts, last } => write!(
                f,
                "gave up after {attempts} attempts; last response was HTTP {}",
                last.status
            ),
            RetryError::Io { attempts, last } => {
                write!(f, "gave up after {attempts} attempts; last transport error: {last}")
            }
        }
    }
}

impl std::error::Error for RetryError {}

/// `Retry-After` header of a response, parsed as whole seconds.
fn retry_after_secs(resp: &HttpResponse) -> Option<u64> {
    resp.headers
        .iter()
        .find(|(k, _)| k.eq_ignore_ascii_case("retry-after"))
        .and_then(|(_, v)| v.trim().parse().ok())
}

/// [`http_request`] with bounded retry: 429 (shed) and 503 (draining /
/// not ready) responses and transport errors are retried under
/// `policy`'s capped, seeded-jitter backoff — honoring the server's
/// `Retry-After` hint when present. Any other response (including 4xx
/// and 500) returns immediately: those are answers, not congestion.
pub fn http_request_retry(
    addr: &str,
    method: &str,
    path: &str,
    body: Option<&[u8]>,
    policy: &RetryPolicy,
) -> Result<HttpResponse, RetryError> {
    let attempts = policy.attempts.max(1);
    let mut k = 0u32;
    loop {
        k += 1;
        match http_request(addr, method, path, body) {
            Ok(resp) if resp.status == 429 || resp.status == 503 => {
                if k >= attempts {
                    return Err(RetryError::Exhausted { attempts: k, last: resp });
                }
                std::thread::sleep(policy.backoff(k, retry_after_secs(&resp)));
            }
            Ok(resp) => return Ok(resp),
            Err(e) => {
                if k >= attempts {
                    return Err(RetryError::Io { attempts: k, last: e });
                }
                std::thread::sleep(policy.backoff(k, None));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backoff_is_seeded_capped_and_honors_retry_after() {
        let p = RetryPolicy::default();
        // Deterministic: same (seed, attempt) -> same sleep.
        assert_eq!(p.backoff(1, None), p.backoff(1, None));
        // Jitter keeps the sleep within ±50% of the exponential base.
        let b1 = p.backoff(1, None).as_secs_f64();
        let base = p.base_backoff.as_secs_f64();
        assert!(b1 >= 0.5 * base && b1 < 1.5 * base, "b1 = {b1}");
        // Exponential growth saturates at the cap...
        let b30 = p.backoff(30, None);
        assert!(b30 <= p.max_backoff, "cap bounds the sleep, got {b30:?}");
        // ...and Retry-After overrides the exponential base but not the cap.
        let ra = p.backoff(1, Some(3600));
        assert!(ra <= p.max_backoff, "hostile hint capped, got {ra:?}");
        // Different seeds de-synchronize clients.
        let q = RetryPolicy { seed: p.seed ^ 1, ..p };
        assert_ne!(p.backoff(2, None), q.backoff(2, None));
    }

    #[test]
    fn transport_failures_exhaust_into_a_typed_error() {
        // Reserved port on localhost that nothing listens on; connect
        // fails instantly, so the retry loop spins through its budget.
        let p = RetryPolicy {
            attempts: 3,
            base_backoff: Duration::from_millis(1),
            max_backoff: Duration::from_millis(2),
            seed: 1,
        };
        match http_request_retry("127.0.0.1:9", "GET", "/healthz", None, &p) {
            Err(RetryError::Io { attempts, .. }) => assert_eq!(attempts, 3),
            other => panic!("expected Io give-up, got {other:?}"),
        }
    }
}
