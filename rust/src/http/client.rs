//! Blocking HTTP/1.1 client helper for the load generator, examples, and
//! integration tests. One request per call; no connection reuse needed at
//! the rates we generate (the server supports keep-alive, the loadgen
//! measures end-to-end latency including connect, which is what a web
//! client would see).

use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::time::Duration;

/// A received HTTP response.
#[derive(Clone, Debug)]
pub struct HttpResponse {
    /// Status code (200, 404, ...).
    pub status: u16,
    /// Response headers in arrival order.
    pub headers: Vec<(String, String)>,
    /// Raw response body.
    pub body: Vec<u8>,
}

impl HttpResponse {
    /// Body as UTF-8 (empty string when not valid UTF-8).
    pub fn body_str(&self) -> &str {
        std::str::from_utf8(&self.body).unwrap_or("")
    }
}

/// Issue a single HTTP/1.1 request to `addr` ("host:port").
pub fn http_request(
    addr: &str,
    method: &str,
    path: &str,
    body: Option<&[u8]>,
) -> std::io::Result<HttpResponse> {
    let mut stream = TcpStream::connect(addr)?;
    stream.set_read_timeout(Some(Duration::from_secs(60)))?;
    stream.set_nodelay(true)?;
    let body = body.unwrap_or(&[]);
    let head = format!(
        "{method} {path} HTTP/1.1\r\nHost: {addr}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    );
    stream.write_all(head.as_bytes())?;
    stream.write_all(body)?;
    stream.flush()?;

    let mut reader = BufReader::new(stream);
    let mut status_line = String::new();
    reader.read_line(&mut status_line)?;
    let status: u16 = status_line
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .ok_or_else(|| std::io::Error::new(std::io::ErrorKind::InvalidData, "bad status line"))?;
    let mut headers = Vec::new();
    let mut content_len: Option<usize> = None;
    loop {
        let mut h = String::new();
        if reader.read_line(&mut h)? == 0 {
            break;
        }
        let h = h.trim_end();
        if h.is_empty() {
            break;
        }
        if let Some((k, v)) = h.split_once(':') {
            let k = k.trim().to_string();
            let v = v.trim().to_string();
            if k.eq_ignore_ascii_case("content-length") {
                content_len = v.parse().ok();
            }
            headers.push((k, v));
        }
    }
    let body = match content_len {
        Some(n) => {
            let mut buf = vec![0u8; n];
            reader.read_exact(&mut buf)?;
            buf
        }
        None => {
            let mut buf = Vec::new();
            reader.read_to_end(&mut buf)?;
            buf
        }
    };
    Ok(HttpResponse { status, headers, body })
}
