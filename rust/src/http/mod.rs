//! Minimal HTTP/1.1 substrate over std::net (tokio is unavailable offline).
//!
//! Server: blocking accept loop + worker thread pool; enough of HTTP/1.1
//! for a JSON serving API (fixed-length bodies, keep-alive, chunked *not*
//! supported — the client we ship never sends it).
//! Client: blocking request helper used by the load generator and tests.

mod client;
mod server;

pub use client::{http_request, http_request_retry, HttpResponse, RetryError, RetryPolicy};
pub use server::{HttpServer, Request, Response, DEFAULT_MAX_BODY_BYTES};
