//! Blocking HTTP/1.1 server: accept loop on a std::net listener, requests
//! dispatched to a handler on a worker pool. Designed for the coordinator's
//! JSON API: small request bodies, keep-alive, graceful shutdown.

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use crate::util::threadpool::ThreadPool;

/// A parsed incoming HTTP request.
#[derive(Clone, Debug)]
pub struct Request {
    /// Upper-cased method ("GET", "POST", ...).
    pub method: String,
    /// Request path without the query string.
    pub path: String,
    /// Raw query string, if any.
    pub query: Option<String>,
    /// Headers in arrival order.
    pub headers: Vec<(String, String)>,
    /// Raw request body (Content-Length framed).
    pub body: Vec<u8>,
}

impl Request {
    /// Case-insensitive header lookup.
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers
            .iter()
            .find(|(k, _)| k.eq_ignore_ascii_case(name))
            .map(|(_, v)| v.as_str())
    }

    /// Body as UTF-8.
    pub fn body_str(&self) -> Result<&str, std::str::Utf8Error> {
        std::str::from_utf8(&self.body)
    }
}

/// An outgoing HTTP response.
#[derive(Clone, Debug)]
pub struct Response {
    /// Status code.
    pub status: u16,
    /// `Content-Type` header value.
    pub content_type: &'static str,
    /// Extra response headers (e.g. `Retry-After` on a shed response).
    pub headers: Vec<(String, String)>,
    /// Response body bytes.
    pub body: Vec<u8>,
}

impl Response {
    /// JSON response with the given status.
    pub fn json(status: u16, body: String) -> Response {
        Response {
            status,
            content_type: "application/json",
            headers: Vec::new(),
            body: body.into_bytes(),
        }
    }
    /// Plain-text response with the given status.
    pub fn text(status: u16, body: &str) -> Response {
        Response {
            status,
            content_type: "text/plain",
            headers: Vec::new(),
            body: body.as_bytes().to_vec(),
        }
    }
    /// 404 with a plain-text body.
    pub fn not_found() -> Response {
        Response::text(404, "not found")
    }
    /// 400 with the given plain-text message.
    pub fn bad_request(msg: &str) -> Response {
        Response::text(400, msg)
    }
    /// Attach an extra response header (builder style).
    pub fn with_header(mut self, name: &str, value: impl Into<String>) -> Response {
        self.headers.push((name.to_string(), value.into()));
        self
    }
}

fn status_text(code: u16) -> &'static str {
    match code {
        200 => "OK",
        201 => "Created",
        202 => "Accepted",
        400 => "Bad Request",
        404 => "Not Found",
        409 => "Conflict",
        413 => "Payload Too Large",
        422 => "Unprocessable Entity",
        429 => "Too Many Requests",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        504 => "Gateway Timeout",
        _ => "Unknown",
    }
}

/// Default request-body cap (64 MiB) when a server is started without an
/// explicit limit.
pub const DEFAULT_MAX_BODY_BYTES: usize = 64 << 20;

/// Shared request handler invoked on worker threads.
pub type Handler = Arc<dyn Fn(&Request) -> Response + Send + Sync + 'static>;

/// A bound, running HTTP server (accept loop + worker pool).
pub struct HttpServer {
    /// The actually-bound local address.
    pub addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
}

impl HttpServer {
    /// Bind `addr` (use port 0 for an ephemeral port) and serve `handler`
    /// on `workers` threads until `shutdown()`, with 30 s read *and*
    /// write socket timeouts.
    pub fn start(addr: &str, workers: usize, handler: Handler) -> std::io::Result<HttpServer> {
        Self::start_with_timeouts(
            addr,
            workers,
            handler,
            Duration::from_secs(30),
            Duration::from_secs(30),
        )
    }

    /// [`HttpServer::start`] with explicit socket timeouts. The write
    /// timeout matters as much as the read timeout: without it a client
    /// that stops *reading* (while the worker is mid-`write_all` on a
    /// response larger than the socket buffer) pins that worker thread
    /// forever — a handful of slow readers could brown out the whole
    /// pool.
    pub fn start_with_timeouts(
        addr: &str,
        workers: usize,
        handler: Handler,
        read_timeout: Duration,
        write_timeout: Duration,
    ) -> std::io::Result<HttpServer> {
        Self::start_with_limits(
            addr,
            workers,
            handler,
            read_timeout,
            write_timeout,
            DEFAULT_MAX_BODY_BYTES,
        )
    }

    /// [`HttpServer::start_with_timeouts`] with an explicit request-body
    /// cap. An over-cap `Content-Length` is answered with a typed HTTP
    /// 413 JSON body (`error_code: "body_too_large"`, echoing the cap)
    /// instead of silently dropping the connection — registry pushes are
    /// the first legitimate large-body traffic, so the client needs a
    /// deterministic signal to distinguish "too big" from "network flake".
    pub fn start_with_limits(
        addr: &str,
        workers: usize,
        handler: Handler,
        read_timeout: Duration,
        write_timeout: Duration,
        max_body_bytes: usize,
    ) -> std::io::Result<HttpServer> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        // Periodic accept timeout so the stop flag is observed promptly.
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let accept_thread = std::thread::Builder::new()
            .name("stride-accept".into())
            .spawn(move || {
                let pool = ThreadPool::new(workers);
                loop {
                    if stop2.load(Ordering::Relaxed) {
                        break;
                    }
                    match listener.accept() {
                        Ok((stream, _)) => {
                            let handler = Arc::clone(&handler);
                            pool.execute(move || {
                                handle_connection(
                                    stream,
                                    handler,
                                    read_timeout,
                                    write_timeout,
                                    max_body_bytes,
                                )
                            });
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(Duration::from_millis(2));
                        }
                        Err(_) => break,
                    }
                }
            })?;
        Ok(HttpServer { addr: local, stop, accept_thread: Some(accept_thread) })
    }

    /// Stop accepting and join the accept thread (idempotent).
    pub fn shutdown(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for HttpServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn handle_connection(
    stream: TcpStream,
    handler: Handler,
    read_timeout: Duration,
    write_timeout: Duration,
    max_body_bytes: usize,
) {
    let _ = stream.set_read_timeout(Some(read_timeout));
    // A reader that stalls mid-response must not pin this worker: when
    // the socket send buffer fills, `write_all` blocks until the timeout
    // fires and the connection is dropped.
    let _ = stream.set_write_timeout(Some(write_timeout));
    let _ = stream.set_nodelay(true);
    let mut reader = BufReader::new(match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    });
    let mut stream = stream;
    // Keep-alive loop.
    loop {
        let req = match read_request(&mut reader, max_body_bytes) {
            Ok(Some(ReadOutcome::Complete(r))) => r,
            Ok(Some(ReadOutcome::BodyTooLarge { content_len })) => {
                // The body was never read, so the connection cannot be
                // reused — answer with a typed 413 and close.
                let body = format!(
                    "{{\"error\":\"request body of {content_len} bytes exceeds the \
                     {max_body_bytes}-byte limit\",\"error_code\":\"body_too_large\",\
                     \"max_body_bytes\":{max_body_bytes}}}"
                );
                let _ = write_response(&mut stream, &Response::json(413, body), false);
                return;
            }
            _ => return,
        };
        let keep_alive = !matches!(req.header("connection"), Some(v) if v.eq_ignore_ascii_case("close"));
        let resp = handler(&req);
        if write_response(&mut stream, &resp, keep_alive).is_err() {
            return;
        }
        if !keep_alive {
            return;
        }
    }
}

/// What `read_request` produced for one wire request.
enum ReadOutcome {
    /// A fully-framed request, body included.
    Complete(Request),
    /// The declared `Content-Length` exceeds the server's cap; the body
    /// was not read.
    BodyTooLarge {
        /// The declared length.
        content_len: usize,
    },
}

fn read_request<R: BufRead>(
    reader: &mut R,
    max_body_bytes: usize,
) -> std::io::Result<Option<ReadOutcome>> {
    let mut line = String::new();
    if reader.read_line(&mut line)? == 0 {
        return Ok(None); // closed
    }
    let mut parts = line.split_whitespace();
    let method = parts.next().unwrap_or("").to_uppercase();
    let target = parts.next().unwrap_or("/").to_string();
    if method.is_empty() {
        return Ok(None);
    }
    let (path, query) = match target.split_once('?') {
        Some((p, q)) => (p.to_string(), Some(q.to_string())),
        None => (target, None),
    };
    let mut headers = Vec::new();
    let mut content_len = 0usize;
    loop {
        let mut h = String::new();
        if reader.read_line(&mut h)? == 0 {
            return Ok(None);
        }
        let h = h.trim_end();
        if h.is_empty() {
            break;
        }
        if let Some((k, v)) = h.split_once(':') {
            let k = k.trim().to_string();
            let v = v.trim().to_string();
            if k.eq_ignore_ascii_case("content-length") {
                content_len = v.parse().unwrap_or(0);
            }
            headers.push((k, v));
        }
    }
    if content_len > max_body_bytes {
        return Ok(Some(ReadOutcome::BodyTooLarge { content_len }));
    }
    let mut body = vec![0u8; content_len];
    reader.read_exact(&mut body)?;
    Ok(Some(ReadOutcome::Complete(Request { method, path, query, headers, body })))
}

fn write_response(stream: &mut TcpStream, resp: &Response, keep_alive: bool) -> std::io::Result<()> {
    let mut head = format!(
        "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nContent-Length: {}\r\nConnection: {}\r\n",
        resp.status,
        status_text(resp.status),
        resp.content_type,
        resp.body.len(),
        if keep_alive { "keep-alive" } else { "close" },
    );
    for (k, v) in &resp.headers {
        head.push_str(k);
        head.push_str(": ");
        head.push_str(v);
        head.push_str("\r\n");
    }
    head.push_str("\r\n");
    stream.write_all(head.as_bytes())?;
    stream.write_all(&resp.body)?;
    stream.flush()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::http::client::http_request;

    fn echo_server() -> HttpServer {
        HttpServer::start(
            "127.0.0.1:0",
            2,
            Arc::new(|req: &Request| match req.path.as_str() {
                "/healthz" => Response::text(200, "ok"),
                "/echo" => Response::json(200, String::from_utf8_lossy(&req.body).into_owned()),
                _ => Response::not_found(),
            }),
        )
        .unwrap()
    }

    #[test]
    fn serves_get_and_post() {
        let server = echo_server();
        let addr = server.addr;
        let r = http_request(&addr.to_string(), "GET", "/healthz", None).unwrap();
        assert_eq!(r.status, 200);
        assert_eq!(r.body_str(), "ok");
        let r = http_request(&addr.to_string(), "POST", "/echo", Some(b"{\"x\":1}")).unwrap();
        assert_eq!(r.status, 200);
        assert_eq!(r.body_str(), "{\"x\":1}");
        let r = http_request(&addr.to_string(), "GET", "/nope", None).unwrap();
        assert_eq!(r.status, 404);
    }

    #[test]
    fn concurrent_requests() {
        let server = echo_server();
        let addr = server.addr.to_string();
        let handles: Vec<_> = (0..8)
            .map(|i| {
                let addr = addr.clone();
                std::thread::spawn(move || {
                    let body = format!("{{\"i\":{i}}}");
                    let r =
                        http_request(&addr, "POST", "/echo", Some(body.as_bytes())).unwrap();
                    assert_eq!(r.body_str(), body);
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn extra_headers_are_written() {
        let server = HttpServer::start(
            "127.0.0.1:0",
            1,
            Arc::new(|_req: &Request| {
                Response::text(429, "slow down").with_header("Retry-After", "2")
            }),
        )
        .unwrap();
        let r = http_request(&server.addr.to_string(), "GET", "/", None).unwrap();
        assert_eq!(r.status, 429);
        let retry = r
            .headers
            .iter()
            .find(|(k, _)| k.eq_ignore_ascii_case("retry-after"))
            .map(|(_, v)| v.as_str());
        assert_eq!(retry, Some("2"));
    }

    /// A client that stops *reading* must not pin an HTTP worker: the
    /// write timeout drops the connection and frees the thread. With a
    /// single worker and a response far larger than any socket buffer,
    /// a follow-up request only succeeds if the stalled write timed out.
    #[test]
    fn write_timeout_frees_worker_from_slow_reader() {
        let big = vec![b'x'; 64 << 20]; // 64 MiB >> any default send buffer
        let server = HttpServer::start_with_timeouts(
            "127.0.0.1:0",
            1, // single worker: a pinned thread would block everyone
            Arc::new(move |req: &Request| match req.path.as_str() {
                "/big" => Response { status: 200, content_type: "text/plain", headers: Vec::new(), body: big.clone() },
                _ => Response::text(200, "ok"),
            }),
            Duration::from_secs(5),
            Duration::from_millis(200),
        )
        .unwrap();
        let addr = server.addr.to_string();
        // Request the huge body, then never read it.
        let mut stalled = TcpStream::connect(&addr).unwrap();
        stalled
            .write_all(b"GET /big HTTP/1.1\r\nHost: x\r\nContent-Length: 0\r\n\r\n")
            .unwrap();
        stalled.flush().unwrap();
        // Give the worker time to fill the socket buffers, block, and
        // hit the 200 ms write timeout.
        std::thread::sleep(Duration::from_millis(800));
        // The single worker must be free again for a normal request.
        let t0 = std::time::Instant::now();
        let r = http_request(&addr, "GET", "/ping", None).unwrap();
        assert_eq!(r.status, 200);
        assert!(
            t0.elapsed() < Duration::from_secs(3),
            "worker still pinned by the stalled reader after {:?}",
            t0.elapsed()
        );
        drop(stalled);
    }

    /// An over-cap `Content-Length` gets a typed 413 JSON answer, not a
    /// dropped connection, and the cap is configurable per server.
    #[test]
    fn over_cap_body_gets_typed_413() {
        let server = HttpServer::start_with_limits(
            "127.0.0.1:0",
            1,
            Arc::new(|_req: &Request| Response::text(200, "ok")),
            Duration::from_secs(5),
            Duration::from_secs(5),
            1024, // 1 KiB cap
        )
        .unwrap();
        let addr = server.addr.to_string();
        // Under the cap: served normally.
        let r = http_request(&addr, "POST", "/", Some(&vec![b'a'; 512])).unwrap();
        assert_eq!(r.status, 200);
        // Over the cap: typed 413 with the cap echoed back.
        let r = http_request(&addr, "POST", "/", Some(&vec![b'a'; 4096])).unwrap();
        assert_eq!(r.status, 413);
        let body = r.body_str();
        assert!(body.contains("\"error_code\":\"body_too_large\""), "body: {body}");
        assert!(body.contains("\"max_body_bytes\":1024"), "body: {body}");
    }

    /// A client that declares a body and then stops *writing* must not pin
    /// an HTTP worker: the read timeout drops the half-sent request and
    /// frees the thread (mirror of the slow-reader test above).
    #[test]
    fn read_timeout_frees_worker_from_slow_body_writer() {
        let server = HttpServer::start_with_timeouts(
            "127.0.0.1:0",
            1, // single worker: a pinned thread would block everyone
            Arc::new(|_req: &Request| Response::text(200, "ok")),
            Duration::from_millis(200),
            Duration::from_secs(5),
        )
        .unwrap();
        let addr = server.addr.to_string();
        // Declare a 1 MiB body, send 10 bytes of it, then stall.
        let mut stalled = TcpStream::connect(&addr).unwrap();
        stalled
            .write_all(b"POST /echo HTTP/1.1\r\nHost: x\r\nContent-Length: 1048576\r\n\r\n0123456789")
            .unwrap();
        stalled.flush().unwrap();
        // Give the worker time to hit the 200 ms read timeout.
        std::thread::sleep(Duration::from_millis(800));
        // The single worker must be free again for a normal request.
        let t0 = std::time::Instant::now();
        let r = http_request(&addr, "GET", "/ping", None).unwrap();
        assert_eq!(r.status, 200);
        assert!(
            t0.elapsed() < Duration::from_secs(3),
            "worker still pinned by the stalled writer after {:?}",
            t0.elapsed()
        );
        drop(stalled);
    }

    #[test]
    fn shutdown_is_clean() {
        let mut server = echo_server();
        let addr = server.addr.to_string();
        let _ = http_request(&addr, "GET", "/healthz", None).unwrap();
        server.shutdown();
        // Subsequent connections must fail (listener gone).
        std::thread::sleep(Duration::from_millis(20));
        assert!(http_request(&addr, "GET", "/healthz", None).is_err());
    }
}
