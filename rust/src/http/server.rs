//! Blocking HTTP/1.1 server: accept loop on a std::net listener, requests
//! dispatched to a handler on a worker pool. Designed for the coordinator's
//! JSON API: small request bodies, keep-alive, graceful shutdown.

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use crate::util::threadpool::ThreadPool;

/// A parsed incoming HTTP request.
#[derive(Clone, Debug)]
pub struct Request {
    /// Upper-cased method ("GET", "POST", ...).
    pub method: String,
    /// Request path without the query string.
    pub path: String,
    /// Raw query string, if any.
    pub query: Option<String>,
    /// Headers in arrival order.
    pub headers: Vec<(String, String)>,
    /// Raw request body (Content-Length framed).
    pub body: Vec<u8>,
}

impl Request {
    /// Case-insensitive header lookup.
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers
            .iter()
            .find(|(k, _)| k.eq_ignore_ascii_case(name))
            .map(|(_, v)| v.as_str())
    }

    /// Body as UTF-8.
    pub fn body_str(&self) -> Result<&str, std::str::Utf8Error> {
        std::str::from_utf8(&self.body)
    }
}

/// An outgoing HTTP response.
#[derive(Clone, Debug)]
pub struct Response {
    /// Status code.
    pub status: u16,
    /// `Content-Type` header value.
    pub content_type: &'static str,
    /// Response body bytes.
    pub body: Vec<u8>,
}

impl Response {
    /// JSON response with the given status.
    pub fn json(status: u16, body: String) -> Response {
        Response { status, content_type: "application/json", body: body.into_bytes() }
    }
    /// Plain-text response with the given status.
    pub fn text(status: u16, body: &str) -> Response {
        Response { status, content_type: "text/plain", body: body.as_bytes().to_vec() }
    }
    /// 404 with a plain-text body.
    pub fn not_found() -> Response {
        Response::text(404, "not found")
    }
    /// 400 with the given plain-text message.
    pub fn bad_request(msg: &str) -> Response {
        Response::text(400, msg)
    }
}

fn status_text(code: u16) -> &'static str {
    match code {
        200 => "OK",
        202 => "Accepted",
        400 => "Bad Request",
        404 => "Not Found",
        429 => "Too Many Requests",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        _ => "Unknown",
    }
}

/// Shared request handler invoked on worker threads.
pub type Handler = Arc<dyn Fn(&Request) -> Response + Send + Sync + 'static>;

/// A bound, running HTTP server (accept loop + worker pool).
pub struct HttpServer {
    /// The actually-bound local address.
    pub addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
}

impl HttpServer {
    /// Bind `addr` (use port 0 for an ephemeral port) and serve `handler`
    /// on `workers` threads until `shutdown()`.
    pub fn start(addr: &str, workers: usize, handler: Handler) -> std::io::Result<HttpServer> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        // Periodic accept timeout so the stop flag is observed promptly.
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let accept_thread = std::thread::Builder::new()
            .name("stride-accept".into())
            .spawn(move || {
                let pool = ThreadPool::new(workers);
                loop {
                    if stop2.load(Ordering::Relaxed) {
                        break;
                    }
                    match listener.accept() {
                        Ok((stream, _)) => {
                            let handler = Arc::clone(&handler);
                            pool.execute(move || handle_connection(stream, handler));
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(Duration::from_millis(2));
                        }
                        Err(_) => break,
                    }
                }
            })?;
        Ok(HttpServer { addr: local, stop, accept_thread: Some(accept_thread) })
    }

    /// Stop accepting and join the accept thread (idempotent).
    pub fn shutdown(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for HttpServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn handle_connection(stream: TcpStream, handler: Handler) {
    let _ = stream.set_read_timeout(Some(Duration::from_secs(30)));
    let _ = stream.set_nodelay(true);
    let mut reader = BufReader::new(match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    });
    let mut stream = stream;
    // Keep-alive loop.
    loop {
        let req = match read_request(&mut reader) {
            Ok(Some(r)) => r,
            _ => return,
        };
        let keep_alive = !matches!(req.header("connection"), Some(v) if v.eq_ignore_ascii_case("close"));
        let resp = handler(&req);
        if write_response(&mut stream, &resp, keep_alive).is_err() {
            return;
        }
        if !keep_alive {
            return;
        }
    }
}

fn read_request<R: BufRead>(reader: &mut R) -> std::io::Result<Option<Request>> {
    let mut line = String::new();
    if reader.read_line(&mut line)? == 0 {
        return Ok(None); // closed
    }
    let mut parts = line.split_whitespace();
    let method = parts.next().unwrap_or("").to_uppercase();
    let target = parts.next().unwrap_or("/").to_string();
    if method.is_empty() {
        return Ok(None);
    }
    let (path, query) = match target.split_once('?') {
        Some((p, q)) => (p.to_string(), Some(q.to_string())),
        None => (target, None),
    };
    let mut headers = Vec::new();
    let mut content_len = 0usize;
    loop {
        let mut h = String::new();
        if reader.read_line(&mut h)? == 0 {
            return Ok(None);
        }
        let h = h.trim_end();
        if h.is_empty() {
            break;
        }
        if let Some((k, v)) = h.split_once(':') {
            let k = k.trim().to_string();
            let v = v.trim().to_string();
            if k.eq_ignore_ascii_case("content-length") {
                content_len = v.parse().unwrap_or(0);
            }
            headers.push((k, v));
        }
    }
    const MAX_BODY: usize = 64 << 20;
    if content_len > MAX_BODY {
        return Err(std::io::Error::new(std::io::ErrorKind::InvalidData, "body too large"));
    }
    let mut body = vec![0u8; content_len];
    reader.read_exact(&mut body)?;
    Ok(Some(Request { method, path, query, headers, body }))
}

fn write_response(stream: &mut TcpStream, resp: &Response, keep_alive: bool) -> std::io::Result<()> {
    let head = format!(
        "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nContent-Length: {}\r\nConnection: {}\r\n\r\n",
        resp.status,
        status_text(resp.status),
        resp.content_type,
        resp.body.len(),
        if keep_alive { "keep-alive" } else { "close" },
    );
    stream.write_all(head.as_bytes())?;
    stream.write_all(&resp.body)?;
    stream.flush()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::http::client::http_request;

    fn echo_server() -> HttpServer {
        HttpServer::start(
            "127.0.0.1:0",
            2,
            Arc::new(|req: &Request| match req.path.as_str() {
                "/healthz" => Response::text(200, "ok"),
                "/echo" => Response::json(200, String::from_utf8_lossy(&req.body).into_owned()),
                _ => Response::not_found(),
            }),
        )
        .unwrap()
    }

    #[test]
    fn serves_get_and_post() {
        let server = echo_server();
        let addr = server.addr;
        let r = http_request(&addr.to_string(), "GET", "/healthz", None).unwrap();
        assert_eq!(r.status, 200);
        assert_eq!(r.body_str(), "ok");
        let r = http_request(&addr.to_string(), "POST", "/echo", Some(b"{\"x\":1}")).unwrap();
        assert_eq!(r.status, 200);
        assert_eq!(r.body_str(), "{\"x\":1}");
        let r = http_request(&addr.to_string(), "GET", "/nope", None).unwrap();
        assert_eq!(r.status, 404);
    }

    #[test]
    fn concurrent_requests() {
        let server = echo_server();
        let addr = server.addr.to_string();
        let handles: Vec<_> = (0..8)
            .map(|i| {
                let addr = addr.clone();
                std::thread::spawn(move || {
                    let body = format!("{{\"i\":{i}}}");
                    let r =
                        http_request(&addr, "POST", "/echo", Some(body.as_bytes())).unwrap();
                    assert_eq!(r.body_str(), body);
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn shutdown_is_clean() {
        let mut server = echo_server();
        let addr = server.addr.to_string();
        let _ = http_request(&addr, "GET", "/healthz", None).unwrap();
        server.shutdown();
        // Subsequent connections must fail (listener gone).
        std::thread::sleep(Duration::from_millis(20));
        assert!(http_request(&addr, "GET", "/healthz", None).is_err());
    }
}
