//! Adaptive speculation controller: online γ (and optionally σ) tuning
//! from live acceptance telemetry.
//!
//! The paper fixes the draft length γ and acceptance width σ offline, but
//! its own speedup model (Eq. 5 / Prop. 3, implemented in
//! [`crate::theory`]) makes the optimal γ a function of the mean
//! acceptance ᾱ and the draft/target cost ratio c — both of which drift
//! per-series and per-regime in real traffic. This module closes the loop
//! the repo already half-built: every decode measures per-proposal
//! acceptance probabilities ([`RoundStats::alphas`]); the controller
//! folds them into an EWMA estimate α̂, measures c from the round timers,
//! and re-evaluates the closed-form speedup curve online to pick the next
//! round's γ.
//!
//! Design constraints, in order:
//!
//! 1. **Adaptation never changes *what* is emitted, only *when* drafting
//!    happens.** Each speculative round is correct for any γ (the
//!    accept/reject math is per-proposal), so a γ change between rounds
//!    preserves both variants' guarantees — including Lossless exactness.
//!    `tests/statistical.rs` pins this by replaying an adaptive decode's
//!    per-round γ choices through [`super::sd_generate_scheduled`] and
//!    asserting bit-identical output.
//! 2. **No thrash.** γ changes are hysteresis-gated: the candidate γ* must
//!    beat the current γ's *predicted* speedup by a configurable relative
//!    margin, and changes are separated by a dwell period. Near the
//!    optimum the speedup curve is flat (Fig. 7 saturation), so the gate
//!    naturally pins γ once converged.
//! 3. **Rollback-aware estimation.** α̂ is updated from the per-proposal
//!    acceptance *probabilities*, which include the rejected proposal that
//!    ended a round — a Rao-Blackwellised estimate (the probability
//!    carries more information than the binary coin) that sees rejected
//!    work at exactly the weight the acceptance rule gave it.
//! 4. **Context-guarded.** The recommended γ is clamped so a round's
//!    γ+1 appended patches always fit the session window
//!    (`γ ≤ max_ctx − 2`), preserving the "gamma cannot fit in max_ctx"
//!    invariant introduced with the session layer.
//!
//! σ adaptation (off by default) widens the acceptance width when α̂ falls
//! below a target band and narrows it when acceptance saturates, bounded
//! by an MSE guard-rail: σ may never leave `[sigma_min, sigma_max]`
//! (defaulting to `[0.75·σ₀, 1.5·σ₀]`), which caps the accuracy cost the
//! paper's Tables 3–4 attribute to wider σ. It applies only to the
//! practical variant on the single-stream engine — Lossless exactness is
//! a statement about a *fixed* target law, so the engine rejects the
//! combination.
//!
//! The **speculation circuit breaker** (off by default) is the serving
//! tier's escape hatch: speculative decoding is an *optimization*, and
//! Leviathan et al.'s framework only stays safe in production if it can
//! be switched off mechanically when it misbehaves. Two trip conditions —
//! a sustained α̂ collapse below `breaker_alpha_floor` (speculation burns
//! draft compute for nothing) or a streak of numeric faults reported via
//! [`GammaController::note_numeric_fault`] (a backend is emitting
//! non-finite values) — move the breaker `Closed → Open`. Open pins
//! [`GammaController::gamma_for`] at 0 (the pure-AR round shape every
//! decode loop already supports) and [`GammaController::k`] at 1 for a
//! cool-down of `breaker_cooldown` rounds, then `Open → HalfOpen`:
//! `min_gamma` probe rounds judged on their *own* acceptance evidence
//! (the EWMA is still depressed from the collapse). `breaker_probes`
//! healthy probes re-close the breaker; one bad probe re-trips it.
//! A closed breaker changes nothing — `gamma_for`/`k` are byte-for-byte
//! the pre-breaker recommendations, so the k=1/lossless equivalence
//! walls hold verbatim whenever the breaker is not tripped.

use anyhow::Result;

use super::stats::RoundStats;
use crate::theory;

/// Tuning knobs of the adaptive controller. All fields are plain scalars
/// so the struct stays `Copy` and can live inside
/// [`super::SpecConfig`] without breaking its value semantics.
#[derive(Clone, Copy, Debug)]
pub struct AdaptiveConfig {
    /// Lower bound on the recommended γ (≥ 1).
    pub min_gamma: usize,
    /// Upper bound on the recommended γ; further clamped per round so
    /// γ + 1 proposals fit the joint context window.
    pub max_gamma: usize,
    /// EWMA half-life of the α̂ estimator, in observed *proposals*
    /// (the c estimator reuses it in *rounds*). Shorter tracks regime
    /// switches faster at the cost of noisier estimates.
    pub halflife: f64,
    /// Prior α̂ before any observation (the controller's opening belief).
    pub alpha0: f64,
    /// Rounds observed before the first γ change is allowed.
    pub warmup: usize,
    /// Minimum rounds between consecutive γ changes.
    pub dwell: usize,
    /// Relative predicted-speedup improvement a candidate γ must show
    /// before the controller switches (e.g. 0.02 = 2%). The anti-thrash
    /// gate: near-optimal neighbours never clear it.
    pub hysteresis: f64,
    /// Fixed draft/target wall-clock cost ratio. Finite values override
    /// the online measurement (deterministic tests, simulated-cost
    /// benches); `NAN` (the default) measures c from round timers.
    pub c_override: f64,
    /// Enable online σ adjustment (practical variant, single-stream
    /// engine only).
    pub sigma_adapt: bool,
    /// Lower σ bound; `NAN` resolves to `0.75 · σ₀` at controller
    /// construction.
    pub sigma_min: f64,
    /// Upper σ bound — the MSE guard-rail; `NAN` resolves to `1.5 · σ₀`.
    pub sigma_max: f64,
    /// Widen σ when α̂ drops below this.
    pub alpha_lo: f64,
    /// Narrow σ when α̂ rises above this (reclaiming accuracy once
    /// acceptance saturates).
    pub alpha_hi: f64,
    /// Multiplicative σ step per adjustment (> 1).
    pub sigma_step: f64,
    /// Upper bound on the tree branch count k the controller may choose.
    /// `1` (the default) disables the k axis entirely — the controller
    /// behaves exactly as the γ-only tuner and decodes stay on the
    /// classic single-trajectory path. `> 1` turns retuning into a joint
    /// (γ × k) scan over the tree speedup surface
    /// ([`crate::theory::tree_wall_speedup`]); requires
    /// [`super::Variant::Practical`] (the lossless guarantee is only
    /// proven for decodes bit-identical to k = 1).
    pub k_max: usize,
    /// Enable the speculation circuit breaker (see the module docs).
    /// Off by default: a disabled breaker is permanently `Closed` and
    /// the controller is byte-for-byte the pre-breaker tuner.
    pub breaker: bool,
    /// α̂ below this floor counts toward the collapse trip condition.
    pub breaker_alpha_floor: f64,
    /// Consecutive low-α̂ speculative rounds before the breaker opens.
    pub breaker_trip_rounds: usize,
    /// Consecutive numeric faults ([`GammaController::note_numeric_fault`])
    /// before the breaker opens. Faults and low-α̂ rounds trip
    /// independently; any healthy speculative round resets both streaks.
    pub breaker_nf_trip: usize,
    /// Pure-AR rounds the breaker stays `Open` before probing.
    pub breaker_cooldown: usize,
    /// Healthy `HalfOpen` probe rounds required to re-close.
    pub breaker_probes: usize,
}

impl Default for AdaptiveConfig {
    fn default() -> Self {
        AdaptiveConfig {
            min_gamma: 1,
            max_gamma: 16,
            halflife: 48.0,
            alpha0: 0.7,
            warmup: 4,
            dwell: 4,
            hysteresis: 0.02,
            c_override: f64::NAN,
            sigma_adapt: false,
            sigma_min: f64::NAN,
            sigma_max: f64::NAN,
            alpha_lo: 0.45,
            alpha_hi: 0.98,
            sigma_step: 1.1,
            k_max: 1,
            breaker: false,
            breaker_alpha_floor: 0.25,
            breaker_trip_rounds: 8,
            breaker_nf_trip: 2,
            breaker_cooldown: 64,
            breaker_probes: 4,
        }
    }
}

impl AdaptiveConfig {
    /// Check the knobs are internally consistent (bounds ordered, decay
    /// positive). Called by `ServeConfig::validate` and the engine entry
    /// points.
    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(self.min_gamma >= 1, "adaptive min_gamma must be >= 1");
        anyhow::ensure!(
            self.min_gamma <= self.max_gamma && self.max_gamma <= 64,
            "adaptive gamma bounds must satisfy 1 <= min <= max <= 64"
        );
        anyhow::ensure!(self.halflife > 0.0, "adaptive halflife must be positive");
        anyhow::ensure!((0.0..=1.0).contains(&self.alpha0), "alpha0 in [0,1]");
        anyhow::ensure!(self.hysteresis >= 0.0, "hysteresis must be >= 0");
        if self.c_override.is_finite() {
            anyhow::ensure!(self.c_override > 0.0, "c_override must be positive");
        }
        if self.sigma_min.is_finite() {
            anyhow::ensure!(self.sigma_min > 0.0, "sigma_min must be positive");
        }
        if self.sigma_max.is_finite() {
            anyhow::ensure!(self.sigma_max > 0.0, "sigma_max must be positive");
        }
        if self.sigma_min.is_finite() && self.sigma_max.is_finite() {
            anyhow::ensure!(
                self.sigma_min <= self.sigma_max,
                "sigma bounds must satisfy min <= max"
            );
        }
        if self.sigma_adapt {
            anyhow::ensure!(self.sigma_step > 1.0, "sigma_step must be > 1");
            anyhow::ensure!(
                self.alpha_lo < self.alpha_hi,
                "sigma target band needs alpha_lo < alpha_hi"
            );
        }
        anyhow::ensure!(
            (1..=super::tree::MAX_TREE_K).contains(&self.k_max),
            "adaptive k_max must be in [1, {}], got {}",
            super::tree::MAX_TREE_K,
            self.k_max
        );
        if self.breaker {
            anyhow::ensure!(
                self.breaker_alpha_floor > 0.0 && self.breaker_alpha_floor < 1.0,
                "breaker_alpha_floor must be in (0, 1)"
            );
            anyhow::ensure!(self.breaker_trip_rounds >= 1, "breaker_trip_rounds must be >= 1");
            anyhow::ensure!(self.breaker_nf_trip >= 1, "breaker_nf_trip must be >= 1");
            anyhow::ensure!(self.breaker_cooldown >= 1, "breaker_cooldown must be >= 1");
            anyhow::ensure!(self.breaker_probes >= 1, "breaker_probes must be >= 1");
        }
        Ok(())
    }

    /// Largest γ a context of `max_ctx` patches can host: a round appends
    /// γ proposals plus one bonus/fallback patch and must keep at least
    /// one context patch, so γ + 1 < max_ctx.
    pub fn ctx_gamma_cap(max_ctx: usize) -> usize {
        max_ctx.saturating_sub(2).max(1)
    }
}

/// State of the speculation circuit breaker. A disabled breaker is
/// permanently [`BreakerState::Closed`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BreakerState {
    /// Normal operation: speculation runs at the tuned (γ, k).
    Closed,
    /// Tripped: decodes run pure-AR (γ = 0, k = 1) for the cool-down.
    Open,
    /// Probing: `min_gamma` speculative rounds, judged individually;
    /// enough healthy probes re-close, one bad probe re-trips.
    HalfOpen,
}

impl BreakerState {
    /// Wire name (`"closed"` / `"open"` / `"half_open"`).
    pub fn as_str(&self) -> &'static str {
        match self {
            BreakerState::Closed => "closed",
            BreakerState::Open => "open",
            BreakerState::HalfOpen => "half_open",
        }
    }

    /// Gauge encoding for `stride_breaker_state` (0 closed, 1 open,
    /// 2 half-open) — monotone in "how far from normal".
    pub fn gauge(&self) -> f64 {
        match self {
            BreakerState::Closed => 0.0,
            BreakerState::Open => 1.0,
            BreakerState::HalfOpen => 2.0,
        }
    }
}

/// Read-only snapshot of a controller for metrics and the `/stats`
/// endpoint.
#[derive(Clone, Copy, Debug)]
pub struct ControllerState {
    /// Wire name of the draft source this controller's telemetry comes
    /// from (`"model"` unless tagged via
    /// [`GammaController::set_draft_kind`]) — serving observability for
    /// the pluggable-draft subsystem.
    pub draft: &'static str,
    /// Current recommended γ (before per-round context clamping).
    pub gamma: usize,
    /// Current acceptance width σ (equals σ₀ unless σ adaptation ran).
    pub sigma: f64,
    /// EWMA acceptance estimate α̂.
    pub alpha_hat: f64,
    /// Effective draft/target cost ratio (override or EWMA measurement;
    /// NaN before the first measured round).
    pub c: f64,
    /// Speculative rounds observed.
    pub rounds: usize,
    /// Proposals observed (α̂ sample count).
    pub proposals: usize,
    /// γ changes applied since construction.
    pub gamma_changes: usize,
    /// σ changes applied since construction.
    pub sigma_changes: usize,
    /// Current recommended tree branch count k (1 unless `k_max > 1`).
    pub k: usize,
    /// k changes applied since construction.
    pub k_changes: usize,
    /// Circuit-breaker state (`Closed` when the breaker is disabled).
    pub breaker: BreakerState,
    /// Times the breaker has tripped `-> Open` since construction.
    pub breaker_trips: usize,
    /// Numeric faults reported via
    /// [`GammaController::note_numeric_fault`] since construction.
    pub numeric_faults: usize,
}

/// Per-stream adaptive γ/σ controller.
///
/// Feed it every finished round via [`GammaController::observe_round`];
/// read the next round's γ via [`GammaController::gamma_for`] (context
/// clamped) and the current σ via [`GammaController::sigma`]. One
/// controller per decode stream: the engine creates one per call when
/// [`super::SpecConfig::adaptive`] is set, the batched engine one per
/// sequence, and the serving batcher keeps a long-lived one that seeds
/// each decode group (see `server::batcher`).
#[derive(Clone, Debug)]
pub struct GammaController {
    cfg: AdaptiveConfig,
    draft_kind: &'static str,
    gamma: usize,
    sigma: f64,
    sigma_min: f64,
    sigma_max: f64,
    alpha_hat: f64,
    c_meas: f64,
    rounds: usize,
    proposals: usize,
    since_change: usize,
    gamma_changes: usize,
    sigma_changes: usize,
    k: usize,
    k_changes: usize,
    breaker_state: BreakerState,
    /// Consecutive low-α̂ speculative rounds while `Closed`.
    low_streak: usize,
    /// Consecutive numeric faults while `Closed`.
    nf_streak: usize,
    /// Pure-AR rounds left before `Open -> HalfOpen`.
    cooldown_left: usize,
    /// Healthy probes accumulated while `HalfOpen`.
    probe_healthy: usize,
    breaker_trips: usize,
    numeric_faults: usize,
}

impl GammaController {
    /// Build a controller opening at `gamma0`/`sigma0` (typically the
    /// configured static values, so the first rounds behave exactly like
    /// the fixed setup the operator asked for).
    ///
    /// Construction never panics on degenerate configs (a half-specified
    /// σ band or inverted γ bounds collapse to their lower edge) —
    /// [`AdaptiveConfig::validate`] is where misconfiguration becomes an
    /// error, and every decode entry point calls it before building one
    /// of these.
    pub fn new(cfg: AdaptiveConfig, gamma0: usize, sigma0: f64) -> GammaController {
        let sigma_min = if cfg.sigma_min.is_finite() { cfg.sigma_min } else { 0.75 * sigma0 };
        let sigma_max = if cfg.sigma_max.is_finite() { cfg.sigma_max } else { 1.5 * sigma0 };
        // A half-specified band can come out inverted (finite min above
        // the defaulted max); collapse instead of panicking in clamp.
        let sigma_max = sigma_max.max(sigma_min);
        let gamma_max = cfg.max_gamma.max(cfg.min_gamma);
        GammaController {
            cfg,
            draft_kind: "model",
            gamma: gamma0.clamp(cfg.min_gamma, gamma_max),
            sigma: sigma0.clamp(sigma_min, sigma_max),
            sigma_min,
            sigma_max,
            alpha_hat: cfg.alpha0,
            c_meas: f64::NAN,
            rounds: 0,
            proposals: 0,
            since_change: 0,
            gamma_changes: 0,
            sigma_changes: 0,
            k: 1,
            k_changes: 0,
            breaker_state: BreakerState::Closed,
            low_streak: 0,
            nf_streak: 0,
            cooldown_left: 0,
            probe_healthy: 0,
            breaker_trips: 0,
            numeric_faults: 0,
        }
    }

    /// The configuration this controller runs with.
    pub fn config(&self) -> &AdaptiveConfig {
        &self.cfg
    }

    /// Tag the controller with the draft-source kind feeding its
    /// telemetry (serving observability; `"model"` by default). The c it
    /// measures — and therefore the γ it recommends — is per-source, so
    /// surfacing the source alongside the estimates keeps `/stats`
    /// interpretable when the server switches drafts.
    pub fn set_draft_kind(&mut self, kind: &'static str) {
        self.draft_kind = kind;
    }

    /// The tagged draft-source kind (see
    /// [`GammaController::set_draft_kind`]).
    pub fn draft_kind(&self) -> &'static str {
        self.draft_kind
    }

    /// Current recommended γ, unclamped (use [`GammaController::gamma_for`]
    /// inside a decode loop).
    pub fn gamma(&self) -> usize {
        self.gamma
    }

    /// γ for the next round on a backend with `max_ctx` context patches:
    /// the recommendation clamped so γ + 1 appended patches always fit
    /// (the session layer's invariant). An `Open` breaker pins γ = 0
    /// (the pure-AR round every decode loop supports as the horizon
    /// tail); `HalfOpen` probes at `min_gamma`; `Closed` is byte-for-byte
    /// the pre-breaker recommendation.
    pub fn gamma_for(&self, max_ctx: usize) -> usize {
        let cap = AdaptiveConfig::ctx_gamma_cap(max_ctx);
        match self.breaker_state {
            BreakerState::Open => 0,
            BreakerState::HalfOpen => self.cfg.min_gamma.min(cap).max(1),
            BreakerState::Closed => self.gamma.min(cap).max(1),
        }
    }

    /// Current acceptance width σ.
    pub fn sigma(&self) -> f64 {
        self.sigma
    }

    /// Current recommended tree branch count k (1 unless `k_max > 1`
    /// and the joint (γ × k) retune chose to branch). A non-`Closed`
    /// breaker pins k = 1 — branching is the most aggressive form of
    /// speculation and the first thing the escape hatch turns off.
    pub fn k(&self) -> usize {
        if self.breaker_state == BreakerState::Closed {
            self.k
        } else {
            1
        }
    }

    /// Seed the opening branch count without counting a k change
    /// (clamped into `[1, k_max]`) — how a static `SpecConfig::k` enters
    /// an adaptive tree decode.
    pub fn seed_k(&mut self, k: usize) {
        self.k = k.clamp(1, self.cfg.k_max.max(1));
    }

    /// EWMA acceptance estimate α̂ (the prior until proposals arrive).
    pub fn alpha_hat(&self) -> f64 {
        self.alpha_hat
    }

    /// Effective cost ratio: the override when finite, else the EWMA of
    /// per-round measurements (NaN before the first γ > 0 round).
    pub fn c(&self) -> f64 {
        if self.cfg.c_override.is_finite() {
            self.cfg.c_override
        } else {
            self.c_meas
        }
    }

    /// Snapshot for metrics / the stats endpoint.
    pub fn state(&self) -> ControllerState {
        ControllerState {
            draft: self.draft_kind,
            gamma: self.gamma,
            sigma: self.sigma,
            alpha_hat: self.alpha_hat,
            c: self.c(),
            rounds: self.rounds,
            proposals: self.proposals,
            gamma_changes: self.gamma_changes,
            sigma_changes: self.sigma_changes,
            k: self.k,
            k_changes: self.k_changes,
            breaker: self.breaker_state,
            breaker_trips: self.breaker_trips,
            numeric_faults: self.numeric_faults,
        }
    }

    /// Current circuit-breaker state (`Closed` when the breaker is
    /// disabled).
    pub fn breaker_state(&self) -> BreakerState {
        self.breaker_state
    }

    /// Report a numeric fault: a decode failed because a backend emitted
    /// non-finite mu/sigma (the session-boundary guards turned it into a
    /// typed error). Counted always; with the breaker enabled, a streak
    /// of `breaker_nf_trip` faults trips `Closed -> Open`, and any fault
    /// during a `HalfOpen` probe re-trips immediately.
    pub fn note_numeric_fault(&mut self) {
        self.numeric_faults += 1;
        if !self.cfg.breaker {
            return;
        }
        match self.breaker_state {
            BreakerState::Open => {}
            BreakerState::HalfOpen => self.trip(),
            BreakerState::Closed => {
                self.nf_streak += 1;
                if self.nf_streak >= self.cfg.breaker_nf_trip {
                    self.trip();
                }
            }
        }
    }

    /// Tick the open-breaker cool-down by `rounds` pure-AR rounds that
    /// did not flow through [`GammaController::observe_round`] (the
    /// serving AR-fallback path decodes whole horizons without round
    /// stats). No-op unless the breaker is `Open`.
    pub fn tick_fallback(&mut self, rounds: usize) {
        for _ in 0..rounds {
            if self.breaker_state != BreakerState::Open {
                break;
            }
            self.breaker_idle_tick();
        }
    }

    /// One γ = 0 round elapsed while `Open`: count down toward the
    /// `HalfOpen` probe phase.
    fn breaker_idle_tick(&mut self) {
        if !self.cfg.breaker || self.breaker_state != BreakerState::Open {
            return;
        }
        self.cooldown_left = self.cooldown_left.saturating_sub(1);
        if self.cooldown_left == 0 {
            self.breaker_state = BreakerState::HalfOpen;
            self.probe_healthy = 0;
        }
    }

    /// Judge one finished speculative round (γ > 0) against the trip /
    /// recovery conditions. Runs *after* the EWMA update: the `Closed`
    /// collapse test reads the smoothed α̂, while `HalfOpen` probes are
    /// judged on the round's own per-proposal evidence (the EWMA is
    /// still depressed from whatever tripped the breaker).
    fn breaker_observe(&mut self, r: &RoundStats) {
        if !self.cfg.breaker {
            return;
        }
        match self.breaker_state {
            BreakerState::Closed => {
                if self.alpha_hat >= self.cfg.breaker_alpha_floor {
                    self.low_streak = 0;
                    self.nf_streak = 0;
                } else {
                    self.low_streak += 1;
                    if self.low_streak >= self.cfg.breaker_trip_rounds {
                        self.trip();
                    }
                }
            }
            BreakerState::HalfOpen => {
                let n = r.alphas.len().max(1) as f64;
                let mean_a = r.alphas.iter().sum::<f64>() / n;
                if mean_a >= self.cfg.breaker_alpha_floor {
                    self.probe_healthy += 1;
                    if self.probe_healthy >= self.cfg.breaker_probes {
                        self.breaker_state = BreakerState::Closed;
                        self.low_streak = 0;
                        self.nf_streak = 0;
                    }
                } else {
                    self.trip();
                }
            }
            // gamma_for() pins 0 while Open, so speculative rounds should
            // not arrive here; a straggler (e.g. a round already in
            // flight when the breaker tripped) is simply ignored.
            BreakerState::Open => {}
        }
    }

    /// Open the breaker and arm the cool-down.
    fn trip(&mut self) {
        self.breaker_state = BreakerState::Open;
        self.cooldown_left = self.cfg.breaker_cooldown.max(1);
        self.breaker_trips += 1;
        self.low_streak = 0;
        self.nf_streak = 0;
        self.probe_healthy = 0;
    }

    /// Fold one finished round into the estimators, then re-evaluate the
    /// speedup curve and (hysteresis permitting) retune γ/σ for the next
    /// round.
    ///
    /// Rounds with γ = 0 (horizon tail) carry no acceptance information
    /// and are ignored. The α̂ update consumes `r.alphas` — which includes
    /// the rejected proposal when the round ended early, so rejected
    /// (rolled-back) work lowers α̂ exactly as it should.
    pub fn observe_round(&mut self, r: &RoundStats) {
        if r.gamma == 0 {
            // Pure-AR rounds carry no acceptance information — but they
            // are exactly what an open breaker decodes with, so they
            // tick its cool-down before the early return.
            self.breaker_idle_tick();
            return;
        }
        // Per-proposal EWMA: halflife h proposals => decay 2^(-1/h).
        let lam = 0.5f64.powf(1.0 / self.cfg.halflife);
        for &a in &r.alphas {
            self.alpha_hat = lam * self.alpha_hat + (1.0 - lam) * a.clamp(0.0, 1.0);
            self.proposals += 1;
        }
        // Per-round cost-ratio EWMA from the round's own timers: γ draft
        // extends against one target validation pass. A draft-free source
        // (closed-form extrapolation) can legitimately measure *zero*
        // draft time at clock resolution — that is a real observation of
        // c ≈ 0, the Eq. 5 best case, and must feed the estimator (the
        // old `dt > 0` guard would have frozen c at NaN and disabled
        // retuning exactly for the cheapest drafts).
        if !self.cfg.c_override.is_finite() {
            // Tree rounds draft γ proposals and run one verify extend
            // *per branch*: normalize both clocks by the branch count so
            // c stays per-proposal vs per-validation-pass at any k
            // (branches = 1 leaves the classic arithmetic untouched).
            let fan = r.branches.max(1) as f64;
            let dt = r.draft_time.as_secs_f64() / (r.gamma as f64 * fan);
            let tt = r.target_time.as_secs_f64() / fan;
            if tt > 0.0 {
                let c_round = dt / tt;
                self.c_meas = if self.c_meas.is_finite() {
                    lam * self.c_meas + (1.0 - lam) * c_round
                } else {
                    c_round
                };
            }
        }
        self.rounds += 1;
        self.since_change += 1;
        self.breaker_observe(r);
        self.retune();
    }

    /// Hysteresis-gated retuning: switch to the closed-form γ* only when
    /// its predicted speedup beats the current γ's by the configured
    /// margin, at most once per dwell period, never during warmup.
    fn retune(&mut self) {
        if self.rounds < self.cfg.warmup || self.since_change < self.cfg.dwell {
            return;
        }
        // c >= 0: a measured zero (free draft) is a legal operating point
        // — the curve then favors the γ cap; only "no measurement yet"
        // (NaN) blocks retuning.
        let c = self.c();
        if !(c.is_finite() && c >= 0.0) {
            return;
        }
        let a = self.alpha_hat.clamp(0.0, 1.0);
        let cap = self.cfg.max_gamma.max(self.cfg.min_gamma);
        if self.cfg.k_max <= 1 {
            // γ-only tuning: the pre-tree scan-up rule, byte-for-byte —
            // k_max = 1 controllers must be indistinguishable from the
            // controller that predated the k axis.
            let cand = theory::optimal_gamma(a, c, cap).clamp(self.cfg.min_gamma, cap);
            if cand != self.gamma {
                let s_cur = theory::wall_speedup(a, self.gamma, c);
                let s_cand = theory::wall_speedup(a, cand, c);
                if s_cand >= s_cur * (1.0 + self.cfg.hysteresis) {
                    self.gamma = cand;
                    self.gamma_changes += 1;
                    self.since_change = 0;
                }
            }
        } else {
            // Joint (γ × k) retune over the tree speedup surface, gated
            // by the same relative-improvement hysteresis so the pair
            // only moves when the predicted win is material.
            let (g_cand, k_cand) = theory::optimal_gamma_k(a, c, cap, self.cfg.k_max);
            let g_cand = g_cand.clamp(self.cfg.min_gamma, cap);
            if (g_cand, k_cand) != (self.gamma, self.k) {
                let s_cur = theory::tree_wall_speedup(a, self.gamma, self.k, c);
                let s_cand = theory::tree_wall_speedup(a, g_cand, k_cand, c);
                if s_cand >= s_cur * (1.0 + self.cfg.hysteresis) {
                    if g_cand != self.gamma {
                        self.gamma_changes += 1;
                    }
                    if k_cand != self.k {
                        self.k_changes += 1;
                    }
                    self.gamma = g_cand;
                    self.k = k_cand;
                    self.since_change = 0;
                }
            }
        }
        if self.cfg.sigma_adapt {
            self.retune_sigma(a);
        }
    }

    /// σ step toward the target acceptance band, inside the guard-rail.
    fn retune_sigma(&mut self, alpha: f64) {
        let next = if alpha < self.cfg.alpha_lo {
            (self.sigma * self.cfg.sigma_step).min(self.sigma_max)
        } else if alpha > self.cfg.alpha_hi {
            (self.sigma / self.cfg.sigma_step).max(self.sigma_min)
        } else {
            self.sigma
        };
        if (next - self.sigma).abs() > f64::EPSILON * self.sigma {
            self.sigma = next;
            self.sigma_changes += 1;
            self.since_change = 0;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    fn round(gamma: usize, accepted: usize, alphas: Vec<f64>) -> RoundStats {
        RoundStats {
            gamma,
            accepted,
            emitted: accepted + 1,
            alphas,
            residual_draws: 0,
            branches: 1,
            draft_time: Duration::from_micros(5 * gamma as u64),
            target_time: Duration::from_micros(50),
        }
    }

    fn fast_cfg() -> AdaptiveConfig {
        AdaptiveConfig {
            halflife: 8.0,
            warmup: 1,
            dwell: 1,
            c_override: 0.1,
            ..AdaptiveConfig::default()
        }
    }

    #[test]
    fn defaults_validate() {
        AdaptiveConfig::default().validate().unwrap();
        fast_cfg().validate().unwrap();
    }

    #[test]
    fn validate_rejects_bad_knobs() {
        let mut c = AdaptiveConfig::default();
        c.min_gamma = 0;
        assert!(c.validate().is_err());
        let mut c = AdaptiveConfig::default();
        c.min_gamma = 8;
        c.max_gamma = 4;
        assert!(c.validate().is_err());
        let mut c = AdaptiveConfig::default();
        c.halflife = 0.0;
        assert!(c.validate().is_err());
        let mut c = AdaptiveConfig::default();
        c.c_override = -1.0;
        assert!(c.validate().is_err());
        let mut c = AdaptiveConfig::default();
        c.sigma_adapt = true;
        c.sigma_step = 1.0;
        assert!(c.validate().is_err());
    }

    #[test]
    fn ewma_tracks_alpha_up_and_down() {
        let mut ctrl = GammaController::new(fast_cfg(), 3, 0.5);
        for _ in 0..50 {
            ctrl.observe_round(&round(3, 3, vec![0.95, 0.95, 0.95]));
        }
        assert!(ctrl.alpha_hat() > 0.9, "alpha_hat {}", ctrl.alpha_hat());
        for _ in 0..50 {
            ctrl.observe_round(&round(3, 0, vec![0.05]));
        }
        assert!(ctrl.alpha_hat() < 0.2, "alpha_hat {}", ctrl.alpha_hat());
    }

    #[test]
    fn rejected_rounds_lower_alpha_hat() {
        // Rollback-awareness: a round that ends in rejection contributes
        // its rejected proposal's low alpha to the estimate.
        let mut accept_only = GammaController::new(fast_cfg(), 3, 0.5);
        let mut with_rejects = GammaController::new(fast_cfg(), 3, 0.5);
        for _ in 0..30 {
            accept_only.observe_round(&round(3, 3, vec![0.9, 0.9, 0.9]));
            with_rejects.observe_round(&round(3, 1, vec![0.9, 0.1]));
        }
        assert!(with_rejects.alpha_hat() < accept_only.alpha_hat() - 0.2);
    }

    #[test]
    fn converges_to_optimal_gamma_high_alpha() {
        let cfg = fast_cfg();
        let mut ctrl = GammaController::new(cfg, 1, 0.5);
        for _ in 0..100 {
            let g = ctrl.gamma();
            ctrl.observe_round(&round(g, g, vec![0.95; g]));
        }
        // Hysteresis may legitimately stop a step short of the exact
        // argmax (the curve is flat there) — the contract is
        // near-optimality of the *predicted speedup*, not of gamma.
        let a = ctrl.alpha_hat();
        let g_star = theory::optimal_gamma(a, 0.1, cfg.max_gamma);
        let s_ctrl = theory::wall_speedup(a, ctrl.gamma(), 0.1);
        let s_star = theory::wall_speedup(a, g_star, 0.1);
        assert!(
            s_ctrl >= 0.95 * s_star,
            "controller gamma {} (S {:.3}) vs gamma* {} (S {:.3})",
            ctrl.gamma(),
            s_ctrl,
            g_star,
            s_star
        );
        assert!(ctrl.gamma() > 3, "high acceptance + cheap draft should push gamma up");
    }

    #[test]
    fn converges_down_under_hostile_draft() {
        let mut ctrl = GammaController::new(fast_cfg(), 8, 0.5);
        for _ in 0..100 {
            let g = ctrl.gamma();
            ctrl.observe_round(&round(g, 0, vec![0.02]));
        }
        assert_eq!(ctrl.gamma(), 1, "constant rejection should collapse gamma to 1");
    }

    #[test]
    fn hysteresis_prevents_thrash() {
        // Alternating alpha evidence around a boundary: with a dwell and a
        // relative-improvement gate, gamma must change far less often than
        // the evidence oscillates.
        let mut cfg = fast_cfg();
        cfg.dwell = 4;
        cfg.hysteresis = 0.05;
        let mut ctrl = GammaController::new(cfg, 3, 0.5);
        for i in 0..200 {
            let a = if i % 2 == 0 { 0.75 } else { 0.85 };
            let g = ctrl.gamma();
            ctrl.observe_round(&round(g, g, vec![a; g]));
        }
        let s = ctrl.state();
        assert!(
            s.gamma_changes <= 4,
            "gamma changed {} times under oscillating evidence",
            s.gamma_changes
        );
    }

    #[test]
    fn warmup_and_dwell_delay_changes() {
        let mut cfg = fast_cfg();
        cfg.warmup = 10;
        let mut ctrl = GammaController::new(cfg, 1, 0.5);
        for i in 0..9 {
            ctrl.observe_round(&round(1, 1, vec![0.99]));
            assert_eq!(ctrl.gamma(), 1, "no change during warmup (round {i})");
        }
        for _ in 0..20 {
            ctrl.observe_round(&round(1, 1, vec![0.99]));
        }
        assert!(ctrl.gamma() > 1, "post-warmup the controller must move");
    }

    #[test]
    fn gamma_for_respects_context_cap() {
        // PR 1's panic-fix guard: gamma + 1 appended patches must fit in
        // max_ctx with one context patch surviving, i.e. gamma <= ctx - 2.
        let mut ctrl = GammaController::new(fast_cfg(), 16, 0.5);
        for _ in 0..100 {
            ctrl.observe_round(&round(8, 8, vec![0.99; 8]));
        }
        assert!(ctrl.gamma() > 4, "unclamped gamma should be large");
        assert_eq!(ctrl.gamma_for(6), 4);
        assert_eq!(ctrl.gamma_for(3), 1);
        assert_eq!(ctrl.gamma_for(2), 1, "degenerate window still yields a legal gamma");
        assert_eq!(AdaptiveConfig::ctx_gamma_cap(480), 478);
    }

    #[test]
    fn c_measured_from_round_timers() {
        let mut cfg = fast_cfg();
        cfg.c_override = f64::NAN;
        let mut ctrl = GammaController::new(cfg, 3, 0.5);
        assert!(ctrl.c().is_nan(), "no measurement before the first round");
        for _ in 0..20 {
            // draft 5us/proposal vs target 50us => c = 0.1.
            ctrl.observe_round(&round(3, 3, vec![0.9, 0.9, 0.9]));
        }
        assert!((ctrl.c() - 0.1).abs() < 1e-9, "c {}", ctrl.c());
    }

    #[test]
    fn zero_cost_draft_measures_c_zero_and_maxes_gamma() {
        // A draft-free source can measure literally zero draft time per
        // round; that is a genuine observation of c = 0 (the Eq. 5 best
        // case) and must drive gamma to its cap, not freeze the
        // controller at "no measurement".
        let mut cfg = fast_cfg();
        cfg.c_override = f64::NAN;
        let mut ctrl = GammaController::new(cfg, 2, 0.5);
        for _ in 0..50 {
            let g = ctrl.gamma();
            ctrl.observe_round(&RoundStats {
                gamma: g,
                accepted: g,
                emitted: g + 1,
                alphas: vec![0.95; g],
                residual_draws: 0,
                branches: 1,
                draft_time: Duration::ZERO,
                target_time: Duration::from_micros(50),
            });
        }
        assert_eq!(ctrl.c(), 0.0, "zero draft time must measure c = 0");
        assert_eq!(ctrl.gamma(), ctrl.config().max_gamma, "free draft should max gamma");
    }

    #[test]
    fn draft_kind_tag_defaults_and_sets() {
        let mut ctrl = GammaController::new(fast_cfg(), 3, 0.5);
        assert_eq!(ctrl.state().draft, "model");
        ctrl.set_draft_kind("extrap");
        assert_eq!(ctrl.draft_kind(), "extrap");
        assert_eq!(ctrl.state().draft, "extrap");
    }

    #[test]
    fn gamma_zero_rounds_are_ignored() {
        let mut ctrl = GammaController::new(fast_cfg(), 3, 0.5);
        let before = ctrl.state();
        ctrl.observe_round(&RoundStats {
            gamma: 0,
            accepted: 0,
            emitted: 1,
            alphas: vec![],
            residual_draws: 0,
            branches: 1,
            draft_time: Duration::from_micros(1),
            target_time: Duration::from_micros(1),
        });
        let after = ctrl.state();
        assert_eq!(before.rounds, after.rounds);
        assert_eq!(before.proposals, after.proposals);
    }

    #[test]
    fn sigma_guard_rail_holds() {
        let mut cfg = fast_cfg();
        cfg.sigma_adapt = true;
        let mut ctrl = GammaController::new(cfg, 3, 0.5);
        // Persistent low acceptance: sigma widens but never past 1.5 x.
        for _ in 0..200 {
            let g = ctrl.gamma();
            ctrl.observe_round(&round(g, 0, vec![0.05]));
        }
        assert!(ctrl.sigma() <= 0.75 + 1e-12, "sigma {} escaped the guard", ctrl.sigma());
        assert!(ctrl.sigma() > 0.5, "low acceptance should widen sigma");
        // Persistent saturation: narrows back down, never below 0.75 x.
        for _ in 0..400 {
            let g = ctrl.gamma();
            ctrl.observe_round(&round(g, g, vec![1.0; g]));
        }
        assert!(ctrl.sigma() >= 0.375 - 1e-12);
        assert!(ctrl.sigma() < 0.5, "saturated acceptance should narrow sigma");
        assert!(ctrl.state().sigma_changes > 0);
    }

    #[test]
    fn degenerate_configs_construct_without_panicking() {
        // Half-specified sigma band: finite min above the defaulted max
        // (1.5 * 0.5 = 0.75) collapses instead of panicking in clamp.
        let mut cfg = fast_cfg();
        cfg.sigma_min = 1.0;
        let ctrl = GammaController::new(cfg, 3, 0.5);
        assert_eq!(ctrl.sigma(), 1.0, "sigma clamped into the collapsed band");
        // Inverted gamma bounds: invalid (validate() rejects them) but
        // construction must still not panic.
        let mut cfg = fast_cfg();
        cfg.min_gamma = 5;
        cfg.max_gamma = 2;
        assert!(cfg.validate().is_err());
        let ctrl = GammaController::new(cfg, 3, 0.5);
        assert!(ctrl.gamma() >= 1);
    }

    #[test]
    fn validate_checks_sigma_bounds_even_without_sigma_adapt() {
        let mut cfg = AdaptiveConfig::default();
        cfg.sigma_min = 2.0;
        cfg.sigma_max = 1.0;
        assert!(cfg.validate().is_err(), "inverted sigma bounds must be rejected");
        let mut cfg = AdaptiveConfig::default();
        cfg.sigma_min = -1.0;
        assert!(cfg.validate().is_err(), "negative sigma_min must be rejected");
    }

    #[test]
    fn k_stays_one_when_k_max_is_one() {
        // The default config must be indistinguishable from the
        // pre-tree controller: k pinned at 1, no k changes, ever.
        let mut ctrl = GammaController::new(fast_cfg(), 3, 0.5);
        for _ in 0..100 {
            let g = ctrl.gamma();
            ctrl.observe_round(&round(g, g, vec![0.95; g]));
        }
        assert_eq!(ctrl.k(), 1);
        assert_eq!(ctrl.state().k_changes, 0);
    }

    #[test]
    fn joint_retune_branches_when_draft_is_cheap() {
        // High acceptance + near-free draft: the tree surface favors
        // k > 1 (E[L_k] gain beats the tiny k·γ cost), so the joint
        // retune must move k off 1.
        let mut cfg = fast_cfg();
        cfg.k_max = 8;
        cfg.c_override = 0.002;
        let mut ctrl = GammaController::new(cfg, 3, 0.5);
        for _ in 0..100 {
            let g = ctrl.gamma();
            ctrl.observe_round(&round(g, g.min(2), vec![0.8; g]));
        }
        assert!(ctrl.k() > 1, "cheap draft never branched (k {})", ctrl.k());
        assert!(ctrl.state().k_changes >= 1);
        assert!(ctrl.k() <= 8, "k escaped k_max");
    }

    #[test]
    fn joint_retune_collapses_k_for_expensive_drafts() {
        // c large: every extra branch costs more than its E[L] gain, so
        // the joint optimum is the classic k = 1 even with k_max high.
        let mut cfg = fast_cfg();
        cfg.k_max = 8;
        cfg.c_override = 0.8;
        let mut ctrl = GammaController::new(cfg, 3, 0.5);
        ctrl.seed_k(4);
        assert_eq!(ctrl.k(), 4, "seed_k installs the opening k");
        for _ in 0..100 {
            let g = ctrl.gamma();
            ctrl.observe_round(&round(g, 1, vec![0.5; g.min(2)]));
        }
        assert_eq!(ctrl.k(), 1, "expensive draft should collapse to k = 1");
    }

    #[test]
    fn seed_k_clamps_to_k_max() {
        let mut ctrl = GammaController::new(fast_cfg(), 3, 0.5); // k_max 1
        ctrl.seed_k(6);
        assert_eq!(ctrl.k(), 1);
        let mut cfg = fast_cfg();
        cfg.k_max = 4;
        let mut ctrl = GammaController::new(cfg, 3, 0.5);
        ctrl.seed_k(6);
        assert_eq!(ctrl.k(), 4);
        assert_eq!(ctrl.state().k_changes, 0, "seeding is not a change");
    }

    #[test]
    fn validate_rejects_bad_k_max() {
        let mut cfg = AdaptiveConfig::default();
        cfg.k_max = 0;
        assert!(cfg.validate().is_err());
        let mut cfg = AdaptiveConfig::default();
        cfg.k_max = 17;
        assert!(cfg.validate().is_err());
        let mut cfg = AdaptiveConfig::default();
        cfg.k_max = 16;
        assert!(cfg.validate().is_ok());
    }

    #[test]
    fn tree_round_timers_normalized_by_branches() {
        // A k = 4 round reports 4x the draft clock and 4x the target
        // clock of its k = 1 twin; the per-proposal/per-pass c must come
        // out identical.
        let mut cfg = fast_cfg();
        cfg.c_override = f64::NAN;
        let mut flat = GammaController::new(cfg, 3, 0.5);
        let mut tree = GammaController::new(cfg, 3, 0.5);
        for _ in 0..20 {
            flat.observe_round(&round(3, 3, vec![0.9; 3]));
            let mut r = round(3, 3, vec![0.9; 12]);
            r.branches = 4;
            r.draft_time *= 4;
            r.target_time *= 4;
            tree.observe_round(&r);
        }
        assert!(
            (flat.c() - tree.c()).abs() < 1e-12,
            "c diverged: flat {} tree {}",
            flat.c(),
            tree.c()
        );
    }

    #[test]
    fn sigma_fixed_when_adaptation_off() {
        let mut ctrl = GammaController::new(fast_cfg(), 3, 0.5);
        for _ in 0..100 {
            ctrl.observe_round(&round(3, 0, vec![0.01]));
        }
        assert_eq!(ctrl.sigma(), 0.5);
        assert_eq!(ctrl.state().sigma_changes, 0);
    }

    fn breaker_cfg() -> AdaptiveConfig {
        AdaptiveConfig {
            breaker: true,
            breaker_alpha_floor: 0.25,
            breaker_trip_rounds: 4,
            breaker_nf_trip: 2,
            breaker_cooldown: 6,
            breaker_probes: 2,
            ..fast_cfg()
        }
    }

    fn ar_round() -> RoundStats {
        RoundStats {
            gamma: 0,
            accepted: 0,
            emitted: 1,
            alphas: vec![],
            residual_draws: 0,
            branches: 1,
            draft_time: Duration::ZERO,
            target_time: Duration::from_micros(50),
        }
    }

    #[test]
    fn breaker_disabled_never_leaves_closed() {
        let mut ctrl = GammaController::new(fast_cfg(), 3, 0.5);
        for _ in 0..100 {
            ctrl.observe_round(&round(3, 0, vec![0.01]));
            ctrl.note_numeric_fault();
        }
        assert_eq!(ctrl.breaker_state(), BreakerState::Closed);
        assert_eq!(ctrl.state().breaker_trips, 0);
        assert_eq!(ctrl.state().numeric_faults, 100, "faults still counted");
        assert!(ctrl.gamma_for(32) >= 1, "disabled breaker never pins gamma 0");
    }

    #[test]
    fn breaker_trips_on_alpha_collapse_then_recovers_via_probes() {
        let mut ctrl = GammaController::new(breaker_cfg(), 3, 0.5);
        // Sustained rejection: the EWMA sinks below the floor, and after
        // trip_rounds consecutive low rounds the breaker opens.
        for _ in 0..40 {
            ctrl.observe_round(&round(3, 0, vec![0.05]));
        }
        assert_eq!(ctrl.breaker_state(), BreakerState::Open);
        assert_eq!(ctrl.state().breaker_trips, 1);
        assert_eq!(ctrl.gamma_for(32), 0, "open breaker pins pure AR");
        assert_eq!(ctrl.k(), 1);
        // The pure-AR rounds the open breaker mandates tick the
        // cool-down down to the half-open probe phase.
        for _ in 0..6 {
            assert_eq!(ctrl.breaker_state(), BreakerState::Open);
            ctrl.observe_round(&ar_round());
        }
        assert_eq!(ctrl.breaker_state(), BreakerState::HalfOpen);
        let g_probe = ctrl.gamma_for(32);
        assert_eq!(g_probe, ctrl.config().min_gamma.max(1), "half-open probes at min_gamma");
        // Healthy probes (judged on their own alphas — the EWMA is still
        // depressed) re-close the breaker.
        ctrl.observe_round(&round(g_probe, g_probe, vec![0.9; g_probe]));
        assert_eq!(ctrl.breaker_state(), BreakerState::HalfOpen);
        ctrl.observe_round(&round(g_probe, g_probe, vec![0.9; g_probe]));
        assert_eq!(ctrl.breaker_state(), BreakerState::Closed);
        assert_eq!(ctrl.state().breaker_trips, 1, "recovery is not a trip");
    }

    #[test]
    fn bad_half_open_probe_retrips() {
        let mut ctrl = GammaController::new(breaker_cfg(), 3, 0.5);
        for _ in 0..40 {
            ctrl.observe_round(&round(3, 0, vec![0.05]));
        }
        for _ in 0..6 {
            ctrl.observe_round(&ar_round());
        }
        assert_eq!(ctrl.breaker_state(), BreakerState::HalfOpen);
        let g = ctrl.gamma_for(32);
        ctrl.observe_round(&round(g, 0, vec![0.02]));
        assert_eq!(ctrl.breaker_state(), BreakerState::Open, "one bad probe re-trips");
        assert_eq!(ctrl.state().breaker_trips, 2);
    }

    #[test]
    fn numeric_fault_streak_trips_and_healthy_rounds_reset_it() {
        let mut ctrl = GammaController::new(breaker_cfg(), 3, 0.5);
        // One fault, then a healthy round: streak resets, no trip.
        ctrl.note_numeric_fault();
        ctrl.observe_round(&round(3, 3, vec![0.9; 3]));
        ctrl.note_numeric_fault();
        assert_eq!(ctrl.breaker_state(), BreakerState::Closed);
        // A second consecutive fault trips.
        ctrl.note_numeric_fault();
        assert_eq!(ctrl.breaker_state(), BreakerState::Open);
        assert_eq!(ctrl.state().breaker_trips, 1);
        assert_eq!(ctrl.state().numeric_faults, 3);
        // A fault during half-open probing re-trips immediately.
        ctrl.tick_fallback(100);
        assert_eq!(ctrl.breaker_state(), BreakerState::HalfOpen);
        ctrl.note_numeric_fault();
        assert_eq!(ctrl.breaker_state(), BreakerState::Open);
        assert_eq!(ctrl.state().breaker_trips, 2);
    }

    #[test]
    fn tick_fallback_only_advances_an_open_breaker() {
        let mut ctrl = GammaController::new(breaker_cfg(), 3, 0.5);
        ctrl.tick_fallback(1000);
        assert_eq!(ctrl.breaker_state(), BreakerState::Closed, "closed breaker unaffected");
        ctrl.note_numeric_fault();
        ctrl.note_numeric_fault();
        assert_eq!(ctrl.breaker_state(), BreakerState::Open);
        ctrl.tick_fallback(5);
        assert_eq!(ctrl.breaker_state(), BreakerState::Open, "cooldown 6 not yet elapsed");
        ctrl.tick_fallback(1);
        assert_eq!(ctrl.breaker_state(), BreakerState::HalfOpen);
    }

    #[test]
    fn breaker_state_wire_encoding() {
        assert_eq!(BreakerState::Closed.as_str(), "closed");
        assert_eq!(BreakerState::Open.as_str(), "open");
        assert_eq!(BreakerState::HalfOpen.as_str(), "half_open");
        assert_eq!(BreakerState::Closed.gauge(), 0.0);
        assert_eq!(BreakerState::Open.gauge(), 1.0);
        assert_eq!(BreakerState::HalfOpen.gauge(), 2.0);
    }

    #[test]
    fn validate_rejects_bad_breaker_knobs() {
        for mutate in [
            (|c: &mut AdaptiveConfig| c.breaker_alpha_floor = 0.0) as fn(&mut AdaptiveConfig),
            |c| c.breaker_alpha_floor = 1.0,
            |c| c.breaker_trip_rounds = 0,
            |c| c.breaker_nf_trip = 0,
            |c| c.breaker_cooldown = 0,
            |c| c.breaker_probes = 0,
        ] {
            let mut cfg = breaker_cfg();
            mutate(&mut cfg);
            assert!(cfg.validate().is_err());
            // The same degenerate knobs are fine with the breaker off.
            cfg.breaker = false;
            assert!(cfg.validate().is_ok());
        }
        assert!(breaker_cfg().validate().is_ok());
    }
}
