//! The decode loop: Algorithm 1 (practical) and Algorithm 2 (lossless).
//!
//! Since the decode-session refactor the loop drives two
//! [`crate::models::DecodeSession`]s (target + draft) instead of stateless
//! re-forwards: a round is γ draft `extend`s, one target `extend` that
//! returns all γ+1 prefix-conditional means, an acceptance scan, and a
//! `rollback` of the rejected suffix — with [`CacheMode::On`] the rollback
//! rewinds KV caches instead of rebuilding context, turning a round's
//! target cost from O(n²·d) into O(γ·n·d). [`CacheMode::Off`] reproduces
//! the stateless cost model with identical outputs (the A/B baseline).

use std::time::Instant;

use anyhow::Result;

use super::controller::{AdaptiveConfig, GammaController};
use super::stats::{DecodeOutput, DecodeStats, RoundStats};
use crate::accept::AcceptancePolicy;
use crate::models::{begin_session, Backend, CacheMode};
use crate::util::rng::Rng;

/// Which SD variant to run on rejection (paper §3.2 vs §3.3).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Variant {
    /// Fallback-to-p (Algorithm 1) — the paper's deployed variant.
    Practical,
    /// Residual sampling via thinning from p (Algorithm 2 + §A.5.1) —
    /// exact target law, expensive in high-acceptance regimes (§B.6).
    Lossless,
}

/// What value a decode emits for each patch.
///
/// The acceptance *test* always uses a sampled x ~ q (that is what the
/// accept/reject math is defined over), but production forecasters report
/// point predictions:
/// * [`Emission::Mean`] — emit the draft mean for accepted positions and
///   the target mean for the fallback/bonus patch. This is the only
///   protocol consistent with the paper's reported MSep deltas (+5..24%
///   over sigma 0.3-0.7; emitting raw samples would add sigma^2 to MSE,
///   i.e. +50%+ at sigma 0.5 on z-scored data). Default for serving/benches.
/// * [`Emission::Sampled`] — emit the accepted samples themselves: the
///   true generative protocol, required for the lossless variant's
///   exactness guarantees (Theorems 1-2) and used by the statistical tests.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Emission {
    /// Emit head means (production protocol; the paper's MSep deltas).
    Mean,
    /// Emit the accepted samples (generative protocol; lossless exactness).
    Sampled,
}

/// One decode's full configuration (γ, acceptance policy, variant, seed,
/// emission, cache toggle, optional adaptive controller).
#[derive(Clone, Copy, Debug)]
pub struct SpecConfig {
    /// Draft block length γ (the opening value when `adaptive` is set).
    pub gamma: usize,
    /// Acceptance rule parameters (σ, bias λ).
    pub policy: AcceptancePolicy,
    /// Practical (fallback-to-p) or Lossless (residual thinning).
    pub variant: Variant,
    /// RNG stream seed; decodes are deterministic given the seed.
    pub seed: u64,
    /// Cap on thinning iterations per residual draw (safety valve; the
    /// expected count is 1/(1-beta) which explodes as beta -> 1).
    pub max_residual_draws: usize,
    /// Emission protocol; see [`Emission`].
    pub emission: Emission,
    /// KV-cache toggle: `On` uses incremental decode sessions where the
    /// backend supports them; `Off` forces the stateless re-forward cost
    /// model. Outputs are identical either way (pinned by
    /// `tests/cache_equivalence.rs`); only wall-clock differs.
    pub cache: CacheMode,
    /// Online γ/σ tuning from live acceptance telemetry. `None` (the
    /// default) keeps the static γ. When set, the engine runs a
    /// per-stream [`GammaController`] seeded at `gamma`/`policy.sigma`;
    /// adaptation changes *when* drafting happens, never *what* is
    /// emitted (see `specdec::controller`).
    pub adaptive: Option<AdaptiveConfig>,
}

impl Default for SpecConfig {
    fn default() -> Self {
        SpecConfig {
            gamma: 3,
            policy: AcceptancePolicy::default(),
            variant: Variant::Practical,
            seed: 0xC0FFEE,
            max_residual_draws: 10_000,
            emission: Emission::Mean,
            cache: CacheMode::On,
            adaptive: None,
        }
    }
}

/// Where each round's γ (and σ) comes from: the static config, a live
/// controller, or a recorded per-round schedule (replay).
pub(super) enum GammaPlan<'a> {
    /// Static `cfg.gamma` every round.
    Fixed,
    /// A live controller: γ from the speedup curve, observations fed back.
    Controller(&'a mut GammaController),
    /// Replay a recorded per-round γ sequence (`DecodeOutput::rounds`'
    /// `gamma` values); rounds beyond the schedule fall back to
    /// `cfg.gamma`. Used to prove adaptation changes only *when* drafting
    /// happens: replaying an adaptive decode's choices reproduces it
    /// bit-for-bit.
    Schedule(&'a [usize], usize),
}

impl GammaPlan<'_> {
    /// γ wanted for the next round, before horizon capping.
    fn desired(&mut self, cfg: &SpecConfig, max_ctx: usize) -> usize {
        match self {
            GammaPlan::Fixed => cfg.gamma,
            GammaPlan::Controller(c) => c.gamma_for(max_ctx),
            GammaPlan::Schedule(s, i) => {
                let g = s.get(*i).copied().unwrap_or(cfg.gamma);
                *i += 1;
                g
            }
        }
    }

    /// Acceptance policy for the next round (σ may drift under a
    /// controller with σ adaptation enabled).
    fn policy(&self, cfg: &SpecConfig) -> AcceptancePolicy {
        match self {
            GammaPlan::Controller(c) if c.config().sigma_adapt => {
                AcceptancePolicy { sigma: c.sigma(), bias: cfg.policy.bias }
            }
            _ => cfg.policy,
        }
    }

    /// Feed a finished round back (no-op for fixed/replay plans).
    fn observe(&mut self, r: &RoundStats) {
        if let GammaPlan::Controller(c) = self {
            c.observe_round(r);
        }
    }
}

/// Generate `horizon` patches following `history` (flat `[n_hist, patch]`).
///
/// The context is slid left if `n_hist + gamma + 1` would exceed the
/// backend's max context (long-horizon decodes, pred-len 336).
///
/// When [`SpecConfig::adaptive`] is set, a fresh per-stream
/// [`GammaController`] is created for this decode; to keep controller
/// state across decodes (a long-lived stream), use
/// [`sd_generate_with_controller`].
pub fn sd_generate(
    target: &dyn Backend,
    draft: &dyn Backend,
    history: &[f32],
    n_hist: usize,
    horizon: usize,
    cfg: &SpecConfig,
) -> Result<DecodeOutput> {
    match cfg.adaptive {
        Some(acfg) => {
            // Validate before construction: bad knobs must be a clean
            // error, never a clamp panic inside the controller.
            acfg.validate()?;
            let mut ctrl = GammaController::new(acfg, cfg.gamma, cfg.policy.sigma);
            sd_generate_with_controller(target, draft, history, n_hist, horizon, cfg, &mut ctrl)
        }
        None => sd_generate_impl(
            target,
            draft,
            history,
            n_hist,
            horizon,
            cfg,
            &mut GammaPlan::Fixed,
        ),
    }
}

/// [`sd_generate`] driven by a caller-owned [`GammaController`]: the
/// controller's α̂/c estimates and γ/σ choices persist across calls, which
/// is how a long-lived request stream (or the `adaptive_gamma` bench)
/// adapts across many forecast windows.
pub fn sd_generate_with_controller(
    target: &dyn Backend,
    draft: &dyn Backend,
    history: &[f32],
    n_hist: usize,
    horizon: usize,
    cfg: &SpecConfig,
    ctrl: &mut GammaController,
) -> Result<DecodeOutput> {
    ctrl.config().validate()?;
    if cfg.variant == Variant::Lossless {
        anyhow::ensure!(
            !ctrl.config().sigma_adapt,
            "sigma adaptation changes the emission law; the lossless variant \
             requires a fixed sigma (gamma adaptation alone is exact)"
        );
    }
    sd_generate_impl(
        target,
        draft,
        history,
        n_hist,
        horizon,
        cfg,
        &mut GammaPlan::Controller(ctrl),
    )
}

/// [`sd_generate`] with a recorded per-round γ schedule (entries beyond
/// the schedule fall back to `cfg.gamma`). Replaying the `gamma` values
/// from an adaptive decode's [`DecodeOutput`] rounds reproduces that
/// decode bit-for-bit — the test harness for "adaptation changes *when*
/// we draft, never *what* is emitted".
pub fn sd_generate_scheduled(
    target: &dyn Backend,
    draft: &dyn Backend,
    history: &[f32],
    n_hist: usize,
    horizon: usize,
    cfg: &SpecConfig,
    schedule: &[usize],
) -> Result<DecodeOutput> {
    sd_generate_impl(
        target,
        draft,
        history,
        n_hist,
        horizon,
        cfg,
        &mut GammaPlan::Schedule(schedule, 0),
    )
}

fn sd_generate_impl(
    target: &dyn Backend,
    draft: &dyn Backend,
    history: &[f32],
    n_hist: usize,
    horizon: usize,
    cfg: &SpecConfig,
    plan: &mut GammaPlan<'_>,
) -> Result<DecodeOutput> {
    let p = target.patch();
    anyhow::ensure!(p == draft.patch(), "patch mismatch");
    anyhow::ensure!(history.len() >= n_hist * p, "history too short");
    anyhow::ensure!(cfg.gamma >= 1, "gamma >= 1");
    if cfg.variant == Variant::Lossless {
        anyhow::ensure!(
            (cfg.policy.bias - 1.0).abs() < 1e-12,
            "lossless exactness requires canonical acceptance (bias = 1)"
        );
        anyhow::ensure!(
            cfg.emission == Emission::Sampled,
            "lossless exactness (Theorems 1-2) is a statement about the \
             sampled chain; use Emission::Sampled"
        );
    }

    let mut rng = Rng::new(cfg.seed);
    // Long-lived decode sessions: both models carry the full emitted
    // context; rejection rolls their state back instead of rebuilding it.
    let mut t_sess = begin_session(target, cfg.cache, history, n_hist)?;
    let mut d_sess = begin_session(draft, cfg.cache, history, n_hist)?;
    let max_ctx = target.max_ctx().min(draft.max_ctx());
    let mut emitted = 0usize;
    let mut out_patches: Vec<f32> = Vec::with_capacity(horizon * p);
    let mut rounds = Vec::new();
    let mut stats = DecodeStats::default();

    while emitted < horizon {
        let remaining = horizon - emitted;
        // A round emits up to gamma+1; don't overshoot the horizon. The
        // plan's desired gamma (static, controller, or replay) is already
        // context-clamped; the horizon cap composes on top.
        let gamma = plan.desired(cfg, max_ctx).min(remaining.saturating_sub(1));
        // Round policy: sigma may drift under an adapting controller.
        let policy = plan.policy(cfg);

        // Slide both windows in lockstep so validation fits in the joint
        // max_ctx (sessions also self-evict, but the shared rule keeps
        // target and draft contexts aligned patch-for-patch).
        let need = gamma + 1; // proposed patches appended before validation
        let n_ctx_now = t_sess.len();
        if n_ctx_now + need > max_ctx {
            anyhow::ensure!(need < max_ctx, "gamma {gamma} cannot fit in max_ctx {max_ctx}");
            let keep = max_ctx - need;
            t_sess.evict_to(keep)?;
            d_sess.evict_to(keep)?;
        }

        if gamma == 0 {
            // Horizon tail: plain target AR step off the session tip.
            let t0 = Instant::now();
            let mu_p = t_sess.tip_mean()?;
            let patch = emit_from_p(&mu_p, policy.sigma, cfg.emission, &mut rng);
            t_sess.append(&patch, 1)?;
            let tt = t0.elapsed();
            let t1 = Instant::now();
            d_sess.append(&patch, 1)?;
            let dt = t1.elapsed();
            out_patches.extend_from_slice(&patch);
            emitted += 1;
            let r = RoundStats {
                gamma: 0,
                accepted: 0,
                emitted: 1,
                alphas: vec![],
                residual_draws: 0,
                draft_time: dt,
                target_time: tt,
            };
            plan.observe(&r);
            stats.absorb(&r);
            rounds.push(r);
            continue;
        }

        // --- Draft proposes gamma patches autoregressively (Alg. 1 l.1-3).
        // The first mean comes off the session tip; each proposal i < γ-1
        // is pushed through `extend` to produce the next mean. Proposal
        // γ-1 is only needed by target validation, so it never enters the
        // draft context (nothing would read its successor mean).
        let t0 = Instant::now();
        let mut mu_q = d_sess.tip_mean()?;
        let mut draft_time = t0.elapsed();
        let mut proposals: Vec<Vec<f32>> = Vec::with_capacity(gamma);
        let mut mu_qs: Vec<Vec<f32>> = Vec::with_capacity(gamma);
        for i in 0..gamma {
            let mut x = vec![0.0f32; p];
            rng.fill_normal_around(&mu_q, policy.sigma as f32, &mut x);
            proposals.push(x);
            mu_qs.push(mu_q.clone());
            if i + 1 < gamma {
                let td = Instant::now();
                let rows = d_sess.extend(proposals.last().unwrap(), 1)?;
                draft_time += td.elapsed();
                mu_q = rows[p..].to_vec();
            }
        }

        // --- One target pass validates all gamma+1 prefix conditionals
        // (l.4): `extend` returns the means at positions n0-1 ..= n0+γ-1,
        // i.e. mu_p for every proposal plus the bonus patch.
        let mut flat = Vec::with_capacity(gamma * p);
        for x in &proposals {
            flat.extend_from_slice(x);
        }
        let t1 = Instant::now();
        let val_rows = t_sess.extend(&flat, gamma)?;
        let mut target_time = t1.elapsed();
        let mu_p_at = |i: usize| &val_rows[i * p..(i + 1) * p];

        // --- Acceptance scan (l.5-8).
        let mut alphas = Vec::with_capacity(gamma);
        let mut accepted = 0usize;
        let mut rejected_at: Option<usize> = None;
        for i in 0..gamma {
            let a = policy.alpha(&proposals[i], mu_p_at(i), &mu_qs[i]);
            alphas.push(a);
            if a >= 1.0 || rng.uniform() < a {
                accepted += 1;
            } else {
                rejected_at = Some(i);
                break;
            }
        }

        // --- Rewind to the accepted prefix (the KV-cache rollback that
        // replaces the old truncate-and-rebuild), then emit per protocol.
        // The draft session holds γ-1 proposals, the target session γ.
        let keep_d = accepted.min(gamma - 1);
        match cfg.emission {
            Emission::Sampled => {
                // Accepted proposals are already in both contexts.
                let t2 = Instant::now();
                t_sess.rollback(gamma - accepted)?;
                target_time += t2.elapsed();
                let t3 = Instant::now();
                d_sess.rollback((gamma - 1) - keep_d)?;
                if accepted > keep_d {
                    // All γ accepted: proposal γ-1 never entered the draft.
                    d_sess.append(proposals.last().unwrap(), 1)?;
                }
                draft_time += t3.elapsed();
                for x in &proposals[..accepted] {
                    out_patches.extend_from_slice(x);
                }
            }
            Emission::Mean => {
                // Contexts must carry the emitted draft means, not the
                // sampled proposals: rewind everything and re-append.
                let t2 = Instant::now();
                t_sess.rollback(gamma)?;
                target_time += t2.elapsed();
                let t3 = Instant::now();
                d_sess.rollback(gamma - 1)?;
                draft_time += t3.elapsed();
                let mut emit_flat = Vec::with_capacity(accepted * p);
                for m in &mu_qs[..accepted] {
                    emit_flat.extend_from_slice(m);
                }
                if accepted > 0 {
                    let t4 = Instant::now();
                    t_sess.append(&emit_flat, accepted)?;
                    target_time += t4.elapsed();
                    let t5 = Instant::now();
                    d_sess.append(&emit_flat, accepted)?;
                    draft_time += t5.elapsed();
                }
                out_patches.extend_from_slice(&emit_flat);
            }
        }

        let mut residual_draws = 0usize;
        let final_patch: Vec<f32> = match rejected_at {
            None => {
                // All accepted: bonus draw from p_{gamma+1} (l.9-10).
                let mu = mu_p_at(gamma);
                emit_from_p(mu, policy.sigma, cfg.emission, &mut rng)
            }
            Some(i) => {
                let mu_p = mu_p_at(i);
                match cfg.variant {
                    // Fallback-to-p (l.12).
                    Variant::Practical => emit_from_p(mu_p, policy.sigma, cfg.emission, &mut rng),
                    // Residual thinning (§A.5.1): draw Z ~ p, accept with
                    // prob (1 - q(Z)/p(Z))_+.
                    Variant::Lossless => {
                        let mu_q = &mu_qs[i];
                        let sigma = policy.sigma;
                        let mut z = vec![0.0f32; p];
                        loop {
                            residual_draws += 1;
                            rng.fill_normal_around(mu_p, sigma as f32, &mut z);
                            // pi(z) = (1 - q(z)/p(z))_+ = 1 - exp(min(0, log q - log p))
                            let lqp =
                                crate::gaussian::iso_log_ratio(&z, mu_q, mu_p, sigma);
                            let pi = 1.0 - lqp.min(0.0).exp();
                            if rng.uniform() < pi {
                                break;
                            }
                            if residual_draws >= cfg.max_residual_draws {
                                log::warn!(
                                    "residual thinning hit cap {}; emitting last draw",
                                    cfg.max_residual_draws
                                );
                                break;
                            }
                        }
                        z
                    }
                }
            }
        };
        out_patches.extend_from_slice(&final_patch);
        let t6 = Instant::now();
        t_sess.append(&final_patch, 1)?;
        target_time += t6.elapsed();
        let t7 = Instant::now();
        d_sess.append(&final_patch, 1)?;
        draft_time += t7.elapsed();
        // Residual thinning consumes no extra target *forwards* (it samples
        // from the already-computed head); `residual_draws` records the
        // draw count for the §B.6 cost analysis.
        emitted += accepted + 1;

        let r = RoundStats {
            gamma,
            accepted,
            emitted: accepted + 1,
            alphas,
            residual_draws,
            draft_time,
            target_time,
        };
        plan.observe(&r);
        stats.absorb(&r);
        rounds.push(r);
    }

    out_patches.truncate(horizon * p);
    Ok(DecodeOutput { patches: out_patches, rounds, stats })
}

/// Emit a patch given its target-head mean: a sample in the generative
/// protocol, the mean in production mode. Takes the *round* sigma so an
/// adapting controller's width applies consistently within a round.
fn emit_from_p(mu: &[f32], sigma: f64, emission: Emission, rng: &mut Rng) -> Vec<f32> {
    match emission {
        Emission::Sampled => {
            let mut buf = vec![0.0f32; mu.len()];
            rng.fill_normal_around(mu, sigma as f32, &mut buf);
            buf
        }
        Emission::Mean => mu.to_vec(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::AnalyticBackend;
    use crate::util::stats::Summary;

    fn cfg(gamma: usize, sigma: f64, variant: Variant, seed: u64) -> SpecConfig {
        SpecConfig {
            gamma,
            policy: AcceptancePolicy::new(sigma, 1.0),
            variant,
            seed,
            max_residual_draws: 10_000,
            emission: Emission::Sampled,
            cache: CacheMode::On,
            adaptive: None,
        }
    }

    /// Cache on/off must be RNG-stream and decision identical. On the
    /// native backend (the one with a real KV cache) incremental and
    /// stateless forwards share the exact op order, so whole decodes —
    /// including window slides past max_ctx — come out the same.
    #[test]
    fn cache_toggle_is_observationally_identical() {
        use crate::models::NativeBackend;
        use crate::nn::model::tiny_model;
        let t = NativeBackend::new(tiny_model(31));
        let d = NativeBackend::new(tiny_model(32));
        let hist = [0.4f32, -0.2, 0.1, 0.7, 0.0, 0.3, -0.5, 0.2]; // 2 patches
        for variant in [Variant::Practical, Variant::Lossless] {
            let mut on = cfg(3, 0.4, variant, 11);
            on.cache = CacheMode::On;
            let mut off = on;
            off.cache = CacheMode::Off;
            // horizon 17 with n_ctx 8 forces repeated eviction.
            let a = sd_generate(&t, &d, &hist, 2, 17, &on).unwrap();
            let b = sd_generate(&t, &d, &hist, 2, 17, &off).unwrap();
            assert_eq!(a.stats.accepted, b.stats.accepted, "{variant:?}");
            assert_eq!(a.stats.proposals, b.stats.proposals);
            assert_eq!(a.stats.rounds, b.stats.rounds);
            assert_eq!(a.patches.len(), b.patches.len());
            for (x, y) in a.patches.iter().zip(&b.patches) {
                assert!((x - y).abs() < 1e-5, "{variant:?}: {x} vs {y}");
            }
        }
    }

    #[test]
    fn emits_exact_horizon() {
        let t = AnalyticBackend::new("t", 2, 0.8, 0.1);
        let d = AnalyticBackend::new("d", 2, 0.75, 0.12);
        for horizon in [1, 2, 3, 4, 7, 13] {
            let out = sd_generate(&t, &d, &[0.5, -0.5], 1, horizon, &cfg(3, 0.5, Variant::Practical, 1))
                .unwrap();
            assert_eq!(out.patches.len(), horizon * 2, "horizon {horizon}");
            assert_eq!(out.stats.sum_block_len, horizon);
        }
    }

    #[test]
    fn identical_models_accept_everything() {
        let t = AnalyticBackend::new("t", 3, 0.8, 0.1);
        let d = AnalyticBackend::new("d", 3, 0.8, 0.1);
        let out =
            sd_generate(&t, &d, &[0.1, 0.2, 0.3], 1, 12, &cfg(3, 0.5, Variant::Practical, 2)).unwrap();
        assert_eq!(out.stats.accepted, out.stats.proposals);
        assert!((out.stats.alpha_hat() - 1.0).abs() < 1e-9);
        // E[L] = gamma + 1 when everything is accepted.
        assert!((out.stats.mean_block_len() - 4.0).abs() < 1e-9);
    }

    #[test]
    fn hostile_draft_rejects_mostly() {
        let t = AnalyticBackend::new("t", 2, 0.8, 0.0);
        let d = AnalyticBackend::new("d", 2, -0.8, 3.0); // wildly wrong draft
        let out =
            sd_generate(&t, &d, &[1.0, 1.0], 1, 20, &cfg(3, 0.3, Variant::Practical, 3)).unwrap();
        assert!(out.stats.accept_rate() < 0.3, "rate {}", out.stats.accept_rate());
        // Block length approaches 1 under constant rejection.
        assert!(out.stats.mean_block_len() < 2.0);
    }

    #[test]
    fn deterministic_given_seed() {
        let t = AnalyticBackend::new("t", 2, 0.8, 0.1);
        let d = AnalyticBackend::new("d", 2, 0.7, 0.1);
        let a = sd_generate(&t, &d, &[0.5, 0.5], 1, 8, &cfg(3, 0.4, Variant::Practical, 42)).unwrap();
        let b = sd_generate(&t, &d, &[0.5, 0.5], 1, 8, &cfg(3, 0.4, Variant::Practical, 42)).unwrap();
        assert_eq!(a.patches, b.patches);
        let c = sd_generate(&t, &d, &[0.5, 0.5], 1, 8, &cfg(3, 0.4, Variant::Practical, 43)).unwrap();
        assert_ne!(a.patches, c.patches);
    }

    #[test]
    fn lossless_requires_canonical_bias() {
        let t = AnalyticBackend::new("t", 1, 0.8, 0.0);
        let d = AnalyticBackend::new("d", 1, 0.7, 0.0);
        let mut c = cfg(2, 0.5, Variant::Lossless, 1);
        c.policy.bias = 1.5;
        assert!(sd_generate(&t, &d, &[0.0], 1, 4, &c).is_err());
    }

    /// Statistical test of single-step laws in 1-D (patch = 1):
    /// lossless must reproduce the target law; practical deviates by at
    /// most TV <= alpha-bar (here measured via mean/variance tolerance).
    #[test]
    fn lossless_first_step_matches_target_law() {
        let a_t = 0.6f32;
        let b_t = 0.2f32;
        let t = AnalyticBackend::new("t", 1, a_t, b_t);
        let d = AnalyticBackend::new("d", 1, 0.2, -0.1); // deliberately off
        let x0 = 1.0f32;
        let sigma = 0.5;
        // Target law for patch 1: N(a_t x0 + b_t, sigma^2).
        let want_mean = (a_t * x0 + b_t) as f64;
        let mut s = Summary::new();
        for seed in 0..4000 {
            let out =
                sd_generate(&t, &d, &[x0], 1, 1, &cfg(1, sigma, Variant::Lossless, seed)).unwrap();
            s.push(out.patches[0] as f64);
        }
        // 4000 samples: SE of mean ~ sigma/sqrt(4000) ~ 0.008.
        assert!(
            (s.mean() - want_mean).abs() < 0.03,
            "lossless mean {:.4} vs target {want_mean:.4}",
            s.mean()
        );
        assert!((s.std() - sigma).abs() < 0.03, "lossless std {:.4}", s.std());
    }

    #[test]
    fn practical_first_step_biased_but_bounded() {
        // With a biased draft, the practical variant's mean shifts toward
        // the draft, but stays within the TV bound's reach; we verify the
        // empirical mean sits between target and draft means.
        let t = AnalyticBackend::new("t", 1, 0.6, 0.2);
        let d = AnalyticBackend::new("d", 1, 0.6, -0.1);
        let x0 = 1.0f32;
        let sigma = 0.4;
        let mu_t = 0.6 * 1.0 + 0.2; // 0.8
        let mu_d = 0.6 * 1.0 - 0.1; // 0.5
        let mut s = Summary::new();
        for seed in 0..4000 {
            let out =
                sd_generate(&t, &d, &[x0], 1, 1, &cfg(1, sigma, Variant::Practical, seed)).unwrap();
            s.push(out.patches[0] as f64);
        }
        assert!(
            s.mean() > mu_d as f64 && s.mean() < mu_t as f64 + 0.05,
            "practical mean {:.4} should lie between draft {mu_d} and target {mu_t}",
            s.mean()
        );
    }

    #[test]
    fn lossless_costs_more_target_draws_at_high_overlap() {
        // Draft ~= target => beta ~ 1 => thinning needs many draws (§B.6).
        let t = AnalyticBackend::new("t", 1, 0.8, 0.100);
        let d = AnalyticBackend::new("d", 1, 0.8, 0.102); // tiny gap, huge overlap
        let mut total_residual = 0usize;
        let mut rejections = 0usize;
        for seed in 0..2000 {
            let out =
                sd_generate(&t, &d, &[1.0], 1, 2, &cfg(1, 0.5, Variant::Lossless, seed)).unwrap();
            total_residual += out.stats.residual_draws;
            rejections += out
                .rounds
                .iter()
                .filter(|r| r.accepted < r.gamma && r.gamma > 0)
                .count();
        }
        if rejections > 0 {
            let per_rejection = total_residual as f64 / rejections as f64;
            assert!(
                per_rejection > 5.0,
                "expected expensive residual sampling at high overlap, got {per_rejection:.1}"
            );
        }
    }

    #[test]
    fn long_horizon_slides_context() {
        // max_ctx is effectively unlimited for AnalyticBackend, so wrap it
        // with a tight-limit shim to exercise the sliding path.
        struct Limited(AnalyticBackend);
        impl crate::models::Backend for Limited {
            fn name(&self) -> &str {
                self.0.name()
            }
            fn patch(&self) -> usize {
                self.0.patch()
            }
            fn max_ctx(&self) -> usize {
                6
            }
            fn forward(&self, tokens: &[f32], n: usize) -> Result<Vec<f32>> {
                assert!(n <= 6, "context overflow: {n}");
                self.0.forward(tokens, n)
            }
            fn flops(&self, n: usize) -> f64 {
                self.0.flops(n)
            }
        }
        let t = Limited(AnalyticBackend::new("t", 2, 0.8, 0.1));
        let d = Limited(AnalyticBackend::new("d", 2, 0.75, 0.1));
        let out =
            sd_generate(&t, &d, &[0.5, -0.5], 1, 30, &cfg(3, 0.5, Variant::Practical, 7)).unwrap();
        assert_eq!(out.patches.len(), 30 * 2);
    }

    #[test]
    fn adaptive_emits_exact_horizon_and_adapts_gamma() {
        use super::super::controller::AdaptiveConfig;
        let t = AnalyticBackend::new("t", 2, 0.8, 0.1);
        let d = AnalyticBackend::new("d", 2, 0.8, 0.1); // identical => alpha ~ 1
        let mut c = cfg(2, 0.5, Variant::Practical, 9);
        c.adaptive = Some(AdaptiveConfig {
            warmup: 1,
            dwell: 1,
            halflife: 6.0,
            c_override: 0.05,
            ..AdaptiveConfig::default()
        });
        let out = sd_generate(&t, &d, &[0.5, -0.5], 1, 60, &c).unwrap();
        assert_eq!(out.patches.len(), 60 * 2);
        assert_eq!(out.stats.sum_block_len, 60);
        // Identical heads accept everything; the controller must have
        // raised gamma above its opening value within the decode.
        let max_gamma = out.rounds.iter().map(|r| r.gamma).max().unwrap();
        assert!(max_gamma > 2, "controller never adapted (max gamma {max_gamma})");
    }

    #[test]
    fn adaptive_respects_tight_context_window() {
        // A backend with max_ctx 6 can host at most gamma 4 per round
        // (gamma + 1 appended, >= 1 context patch kept). The controller
        // must clamp even when acceptance begs for more.
        struct Limited(AnalyticBackend);
        impl crate::models::Backend for Limited {
            fn name(&self) -> &str {
                self.0.name()
            }
            fn patch(&self) -> usize {
                self.0.patch()
            }
            fn max_ctx(&self) -> usize {
                6
            }
            fn forward(&self, tokens: &[f32], n: usize) -> Result<Vec<f32>> {
                assert!(n <= 6, "context overflow: {n}");
                self.0.forward(tokens, n)
            }
            fn flops(&self, n: usize) -> f64 {
                self.0.flops(n)
            }
        }
        let t = Limited(AnalyticBackend::new("t", 1, 0.9, 0.0));
        let d = Limited(AnalyticBackend::new("d", 1, 0.9, 0.0));
        let mut c = cfg(3, 0.5, Variant::Practical, 11);
        c.adaptive = Some(AdaptiveConfig {
            warmup: 1,
            dwell: 1,
            halflife: 4.0,
            c_override: 0.02, // begs for huge gamma
            ..AdaptiveConfig::default()
        });
        let out = sd_generate(&t, &d, &[0.4], 1, 50, &c).unwrap();
        assert_eq!(out.patches.len(), 50);
        assert!(out.rounds.iter().all(|r| r.gamma <= 4), "context clamp violated");
    }

    #[test]
    fn scheduled_replay_reproduces_adaptive_decode() {
        use super::super::controller::AdaptiveConfig;
        // The core lossless-compatibility property: replaying an adaptive
        // decode's per-round gamma choices yields the identical decode.
        let t = AnalyticBackend::new("t", 2, 0.7, 0.2);
        let d = AnalyticBackend::new("d", 2, 0.6, 0.1);
        let mut c = cfg(3, 0.5, Variant::Practical, 21);
        c.adaptive = Some(AdaptiveConfig {
            warmup: 1,
            dwell: 1,
            halflife: 4.0,
            c_override: 0.1,
            ..AdaptiveConfig::default()
        });
        let live = sd_generate(&t, &d, &[0.5, 0.5], 1, 40, &c).unwrap();
        let schedule: Vec<usize> = live.rounds.iter().map(|r| r.gamma).collect();
        assert!(schedule.iter().any(|&g| g != 3), "decode never adapted; test is vacuous");
        let mut replay_cfg = c;
        replay_cfg.adaptive = None;
        let replay =
            sd_generate_scheduled(&t, &d, &[0.5, 0.5], 1, 40, &replay_cfg, &schedule).unwrap();
        assert_eq!(live.patches, replay.patches, "replay drifted from the live decode");
        assert_eq!(live.stats.accepted, replay.stats.accepted);
        assert_eq!(live.stats.rounds, replay.stats.rounds);
    }

    #[test]
    fn adaptive_lossless_rejects_sigma_adaptation() {
        use super::super::controller::AdaptiveConfig;
        let t = AnalyticBackend::new("t", 1, 0.8, 0.0);
        let d = AnalyticBackend::new("d", 1, 0.7, 0.0);
        let mut c = cfg(2, 0.5, Variant::Lossless, 1);
        c.adaptive = Some(AdaptiveConfig { sigma_adapt: true, ..AdaptiveConfig::default() });
        assert!(sd_generate(&t, &d, &[0.0], 1, 4, &c).is_err());
        // Gamma-only adaptation is fine for lossless.
        c.adaptive = Some(AdaptiveConfig::default());
        assert!(sd_generate(&t, &d, &[0.0], 1, 4, &c).is_ok());
    }

    #[test]
    fn gamma_capped_near_horizon() {
        let t = AnalyticBackend::new("t", 1, 0.8, 0.1);
        let d = AnalyticBackend::new("d", 1, 0.8, 0.1);
        // horizon 2 with gamma 5: a single round should use gamma <= 1.
        let out = sd_generate(&t, &d, &[0.0], 1, 2, &cfg(5, 0.5, Variant::Practical, 1)).unwrap();
        assert!(out.rounds.iter().all(|r| r.gamma <= 1));
        assert_eq!(out.patches.len(), 2);
    }
}
