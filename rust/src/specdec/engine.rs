//! The decode loop: Algorithm 1 (practical) and Algorithm 2 (lossless).
//!
//! Since the decode-session refactor the loop drives a target
//! [`crate::models::DecodeSession`] plus a pluggable [`DraftSource`]
//! (which for the classic two-model setup wraps the draft's own decode
//! session — see [`super::draft`]): a round is one `propose` (γ draft
//! proposals), one target `extend` that returns all γ+1 prefix-conditional
//! means, an acceptance scan, a `rollback` of the rejected target suffix,
//! and one `finish_round` feeding the verification outcome back to the
//! source — with [`CacheMode::On`] the rollback rewinds KV caches instead
//! of rebuilding context, turning a round's target cost from O(n²·d) into
//! O(γ·n·d). [`CacheMode::Off`] reproduces the stateless cost model with
//! identical outputs (the A/B baseline). Decoding through the default
//! [`super::DraftKind::Model`] source is bit-identical to the
//! pre-refactor two-session engine (`tests/draft_equivalence.rs`).

use std::time::Instant;

use anyhow::Result;

use super::controller::{AdaptiveConfig, GammaController};
use super::draft::{make_source, DraftConfig, DraftSource, RoundFeedback};
use super::stats::{DecodeOutput, DecodeStats, RoundStats};
use crate::accept::AcceptancePolicy;
use crate::models::{begin_session, Backend, CacheMode};
use crate::util::rng::Rng;

/// Which SD variant to run on rejection (paper §3.2 vs §3.3).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Variant {
    /// Fallback-to-p (Algorithm 1) — the paper's deployed variant.
    Practical,
    /// Residual sampling via thinning from p (Algorithm 2 + §A.5.1) —
    /// exact target law, expensive in high-acceptance regimes (§B.6).
    Lossless,
}

/// What value a decode emits for each patch.
///
/// The acceptance *test* always uses a sampled x ~ q (that is what the
/// accept/reject math is defined over), but production forecasters report
/// point predictions:
/// * [`Emission::Mean`] — emit the draft mean for accepted positions and
///   the target mean for the fallback/bonus patch. This is the only
///   protocol consistent with the paper's reported MSep deltas (+5..24%
///   over sigma 0.3-0.7; emitting raw samples would add sigma^2 to MSE,
///   i.e. +50%+ at sigma 0.5 on z-scored data). Default for serving/benches.
/// * [`Emission::Sampled`] — emit the accepted samples themselves: the
///   true generative protocol, required for the lossless variant's
///   exactness guarantees (Theorems 1-2) and used by the statistical tests.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Emission {
    /// Emit head means (production protocol; the paper's MSep deltas).
    Mean,
    /// Emit the accepted samples (generative protocol; lossless exactness).
    Sampled,
}

/// One decode's full configuration (γ, acceptance policy, variant, seed,
/// emission, cache toggle, draft source, optional adaptive controller).
#[derive(Clone, Copy, Debug)]
pub struct SpecConfig {
    /// Draft block length γ (the opening value when `adaptive` is set).
    pub gamma: usize,
    /// Candidate branches per speculative round (tree speculation).
    /// `1` (the default) is the paper's single-trajectory algorithm;
    /// `k > 1` drafts k candidate continuations per round, verifies all
    /// of them against the shared committed prefix, and commits the
    /// longest accepted branch (see [`super::sd_generate_tree`]). The
    /// `k = 1` tree path is bit-identical to the classic engine
    /// (`tests/tree_equivalence.rs`); `k > 1` requires
    /// [`Variant::Practical`] — the lossless guarantee is only proven
    /// for configurations identical to k = 1.
    pub k: usize,
    /// Acceptance rule parameters (σ, bias λ).
    pub policy: AcceptancePolicy,
    /// Practical (fallback-to-p) or Lossless (residual thinning).
    pub variant: Variant,
    /// RNG stream seed; decodes are deterministic given the seed.
    pub seed: u64,
    /// Cap on thinning iterations per residual draw (safety valve; the
    /// expected count is 1/(1-beta) which explodes as beta -> 1).
    pub max_residual_draws: usize,
    /// Emission protocol; see [`Emission`].
    pub emission: Emission,
    /// KV-cache toggle: `On` uses incremental decode sessions where the
    /// backend supports them; `Off` forces the stateless re-forward cost
    /// model. Outputs are identical either way (pinned by
    /// `tests/cache_equivalence.rs`); only wall-clock differs.
    pub cache: CacheMode,
    /// Where draft proposals come from: the classic second model
    /// ([`super::DraftKind::Model`], the default — bit-identical to the
    /// pre-refactor engine), a draft-free closed-form continuation
    /// ([`super::DraftKind::Extrap`]), or an online-learned residual head
    /// ([`super::DraftKind::Adaptive`]). See [`super::draft`].
    pub draft: DraftConfig,
    /// Online γ/σ tuning from live acceptance telemetry. `None` (the
    /// default) keeps the static γ. When set, the engine runs a
    /// per-stream [`GammaController`] seeded at `gamma`/`policy.sigma`;
    /// adaptation changes *when* drafting happens, never *what* is
    /// emitted (see `specdec::controller`).
    pub adaptive: Option<AdaptiveConfig>,
}

impl Default for SpecConfig {
    fn default() -> Self {
        SpecConfig {
            gamma: 3,
            k: 1,
            policy: AcceptancePolicy::default(),
            variant: Variant::Practical,
            seed: 0xC0FFEE,
            max_residual_draws: 10_000,
            emission: Emission::Mean,
            cache: CacheMode::On,
            draft: DraftConfig::default(),
            adaptive: None,
        }
    }
}

/// Where each round's γ (and σ) comes from: the static config, a live
/// controller, or a recorded per-round schedule (replay).
pub(super) enum GammaPlan<'a> {
    /// Static `cfg.gamma` every round.
    Fixed,
    /// A live controller: γ from the speedup curve, observations fed back.
    Controller(&'a mut GammaController),
    /// Replay a recorded per-round γ sequence (`DecodeOutput::rounds`'
    /// `gamma` values); rounds beyond the schedule fall back to
    /// `cfg.gamma`. Used to prove adaptation changes only *when* drafting
    /// happens: replaying an adaptive decode's choices reproduces it
    /// bit-for-bit.
    Schedule(&'a [usize], usize),
}

impl GammaPlan<'_> {
    /// γ wanted for the next round, before horizon capping.
    pub(super) fn desired(&mut self, cfg: &SpecConfig, max_ctx: usize) -> usize {
        match self {
            GammaPlan::Fixed => cfg.gamma,
            GammaPlan::Controller(c) => c.gamma_for(max_ctx),
            GammaPlan::Schedule(s, i) => {
                let g = s.get(*i).copied().unwrap_or(cfg.gamma);
                *i += 1;
                g
            }
        }
    }

    /// Branch count k for the next round: the static config for fixed /
    /// replay plans, the controller's joint (γ × k) choice when tuned.
    pub(super) fn k_for(&self, cfg: &SpecConfig) -> usize {
        match self {
            GammaPlan::Controller(c) => c.k(),
            _ => cfg.k,
        }
    }

    /// Acceptance policy for the next round (σ may drift under a
    /// controller with σ adaptation enabled).
    pub(super) fn policy(&self, cfg: &SpecConfig) -> AcceptancePolicy {
        match self {
            GammaPlan::Controller(c) if c.config().sigma_adapt => {
                AcceptancePolicy { sigma: c.sigma(), bias: cfg.policy.bias }
            }
            _ => cfg.policy,
        }
    }

    /// Feed a finished round back (no-op for fixed/replay plans).
    pub(super) fn observe(&mut self, r: &RoundStats) {
        if let GammaPlan::Controller(c) = self {
            c.observe_round(r);
        }
    }
}

/// Generate `horizon` patches following `history` (flat `[n_hist, patch]`).
///
/// The draft source is built from [`SpecConfig::draft`]: the `draft`
/// backend is the proposal model for [`super::DraftKind::Model`] and
/// supplies only the patch size for the draft-free kinds. To keep a *learned*
/// source alive across decodes (e.g. an adapting residual head on a
/// long-lived stream), use [`sd_generate_from`].
///
/// The context is slid left if `n_hist + gamma + 1` would exceed the
/// joint max context (long-horizon decodes, pred-len 336).
///
/// When [`SpecConfig::adaptive`] is set, a fresh per-stream
/// [`GammaController`] is created for this decode; to keep controller
/// state across decodes (a long-lived stream), use
/// [`sd_generate_with_controller`].
pub fn sd_generate(
    target: &dyn Backend,
    draft: &dyn Backend,
    history: &[f32],
    n_hist: usize,
    horizon: usize,
    cfg: &SpecConfig,
) -> Result<DecodeOutput> {
    anyhow::ensure!(target.patch() == draft.patch(), "patch mismatch");
    let mut source = make_source(&cfg.draft, draft)?;
    sd_generate_from(target, source.as_mut(), history, n_hist, horizon, cfg)
}

/// [`sd_generate`] over a caller-owned [`DraftSource`]. The source is
/// re-anchored on `history` but keeps its learned state — this is how a
/// long-lived stream (or `benches/draft_sources.rs`) adapts its draft
/// across many forecast windows.
pub fn sd_generate_from(
    target: &dyn Backend,
    source: &mut dyn DraftSource,
    history: &[f32],
    n_hist: usize,
    horizon: usize,
    cfg: &SpecConfig,
) -> Result<DecodeOutput> {
    if cfg.k > 1 {
        // Tree speculation: k candidate branches per round, longest
        // accepted branch committed. k = 1 stays on this (classic) path
        // byte-for-byte — the equivalence wall the tree engine is tested
        // against.
        return super::tree::sd_generate_tree_from(target, source, history, n_hist, horizon, cfg);
    }
    match cfg.adaptive {
        Some(acfg) => {
            // Validate before construction: bad knobs must be a clean
            // error, never a clamp panic inside the controller.
            acfg.validate()?;
            let mut ctrl = GammaController::new(acfg, cfg.gamma, cfg.policy.sigma);
            sd_generate_from_with_controller(
                target, source, history, n_hist, horizon, cfg, &mut ctrl,
            )
        }
        None => sd_generate_impl(
            target,
            source,
            history,
            n_hist,
            horizon,
            cfg,
            &mut GammaPlan::Fixed,
        ),
    }
}

/// [`sd_generate`] driven by a caller-owned [`GammaController`]: the
/// controller's α̂/c estimates and γ/σ choices persist across calls, which
/// is how a long-lived request stream (or the `adaptive_gamma` bench)
/// adapts across many forecast windows.
pub fn sd_generate_with_controller(
    target: &dyn Backend,
    draft: &dyn Backend,
    history: &[f32],
    n_hist: usize,
    horizon: usize,
    cfg: &SpecConfig,
    ctrl: &mut GammaController,
) -> Result<DecodeOutput> {
    anyhow::ensure!(target.patch() == draft.patch(), "patch mismatch");
    let mut source = make_source(&cfg.draft, draft)?;
    sd_generate_from_with_controller(
        target,
        source.as_mut(),
        history,
        n_hist,
        horizon,
        cfg,
        ctrl,
    )
}

/// [`sd_generate_with_controller`] over a caller-owned [`DraftSource`]:
/// both the γ controller *and* the draft's learned state persist across
/// calls — the fully-adaptive long-lived stream (the controller tunes γ
/// to α, the source raises α itself).
pub fn sd_generate_from_with_controller(
    target: &dyn Backend,
    source: &mut dyn DraftSource,
    history: &[f32],
    n_hist: usize,
    horizon: usize,
    cfg: &SpecConfig,
    ctrl: &mut GammaController,
) -> Result<DecodeOutput> {
    ctrl.config().validate()?;
    if cfg.variant == Variant::Lossless {
        anyhow::ensure!(
            !ctrl.config().sigma_adapt,
            "sigma adaptation changes the emission law; the lossless variant \
             requires a fixed sigma (gamma adaptation alone is exact)"
        );
        anyhow::ensure!(
            cfg.k == 1 && ctrl.config().k_max == 1,
            "lossless exactness is only proven for decodes bit-identical \
             to k = 1; tree speculation (k > 1 or adaptive.k_max > 1) \
             requires Variant::Practical"
        );
    }
    if cfg.k > 1 || ctrl.config().k_max > 1 {
        // Any chance of a k > 1 round sends the whole decode through the
        // tree loop (which runs k = 1 rounds identically to this path).
        return super::tree::sd_generate_tree_ctrl(
            target, source, history, n_hist, horizon, cfg, ctrl,
        );
    }
    sd_generate_impl(
        target,
        source,
        history,
        n_hist,
        horizon,
        cfg,
        &mut GammaPlan::Controller(ctrl),
    )
}

/// [`sd_generate`] with a recorded per-round γ schedule (entries beyond
/// the schedule fall back to `cfg.gamma`). Replaying the `gamma` values
/// from an adaptive decode's [`DecodeOutput`] rounds reproduces that
/// decode bit-for-bit — the test harness for "adaptation changes *when*
/// we draft, never *what* is emitted".
pub fn sd_generate_scheduled(
    target: &dyn Backend,
    draft: &dyn Backend,
    history: &[f32],
    n_hist: usize,
    horizon: usize,
    cfg: &SpecConfig,
    schedule: &[usize],
) -> Result<DecodeOutput> {
    anyhow::ensure!(target.patch() == draft.patch(), "patch mismatch");
    anyhow::ensure!(
        cfg.k == 1,
        "scheduled replay records only the gamma axis; tree decodes \
         (k > 1) cannot be replayed through sd_generate_scheduled"
    );
    let mut source = make_source(&cfg.draft, draft)?;
    sd_generate_impl(
        target,
        source.as_mut(),
        history,
        n_hist,
        horizon,
        cfg,
        &mut GammaPlan::Schedule(schedule, 0),
    )
}

fn sd_generate_impl(
    target: &dyn Backend,
    source: &mut dyn DraftSource,
    history: &[f32],
    n_hist: usize,
    horizon: usize,
    cfg: &SpecConfig,
    plan: &mut GammaPlan<'_>,
) -> Result<DecodeOutput> {
    let p = target.patch();
    anyhow::ensure!(p == source.patch(), "patch mismatch");
    anyhow::ensure!(n_hist >= 1, "need at least one history patch");
    anyhow::ensure!(history.len() >= n_hist * p, "history too short");
    anyhow::ensure!(cfg.gamma >= 1, "gamma >= 1");
    anyhow::ensure!(cfg.k == 1, "classic decode loop requires k = 1 (tree decodes route via sd_generate_tree)");
    if cfg.variant == Variant::Lossless {
        anyhow::ensure!(
            (cfg.policy.bias - 1.0).abs() < 1e-12,
            "lossless exactness requires canonical acceptance (bias = 1)"
        );
        anyhow::ensure!(
            cfg.emission == Emission::Sampled,
            "lossless exactness (Theorems 1-2) is a statement about the \
             sampled chain; use Emission::Sampled"
        );
    }

    let max_ctx = target.max_ctx().min(source.max_ctx());
    // Config-vs-backend validation up front: the old engine only tripped
    // over an oversized γ when the first window slide discovered it,
    // mid-decode, with session state already diverging. A round appends
    // γ + 1 patches and must keep >= 1 context patch, so γ + 1 < max_ctx.
    anyhow::ensure!(
        cfg.gamma + 1 < max_ctx,
        "gamma {} cannot fit the joint context window: a round appends \
         gamma + 1 patches and must keep at least one context patch \
         (target max_ctx {}, draft max_ctx {}) — lower gamma or raise \
         the binding side's context",
        cfg.gamma,
        target.max_ctx(),
        source.max_ctx()
    );

    let mut rng = Rng::new(cfg.seed);
    // Clamp the opening history to the *joint* window before priming
    // either side, so target and draft contexts align patch-for-patch
    // even when their max_ctx differ (previously each session clamped to
    // its own window, silently conditioning the two models on different
    // histories when a small-context draft met a long history).
    let keep0 = n_hist.min(max_ctx);
    let hist = &history[(n_hist - keep0) * p..n_hist * p];
    // Long-lived decode state: the target session and the draft source
    // carry the full emitted context; rejection rolls state back instead
    // of rebuilding it.
    let mut t_sess = begin_session(target, cfg.cache, hist, keep0)?;
    source.begin(hist, keep0, cfg.cache)?;
    let upd0 = source.updates();
    let mut emitted = 0usize;
    let mut out_patches: Vec<f32> = Vec::with_capacity(horizon * p);
    let mut rounds = Vec::new();
    let mut stats = DecodeStats::default();

    while emitted < horizon {
        let remaining = horizon - emitted;
        // A round emits up to gamma+1; don't overshoot the horizon. The
        // plan's desired gamma (static, controller, or replay) is already
        // context-clamped; the horizon cap composes on top.
        let gamma = plan.desired(cfg, max_ctx).min(remaining.saturating_sub(1));
        // Round policy: sigma may drift under an adapting controller.
        let policy = plan.policy(cfg);

        // Slide both windows in lockstep so validation fits in the joint
        // max_ctx (sessions also self-evict, but the shared rule keeps
        // target and draft contexts aligned patch-for-patch).
        let need = gamma + 1; // proposed patches appended before validation
        let n_ctx_now = t_sess.len();
        if n_ctx_now + need > max_ctx {
            anyhow::ensure!(need < max_ctx, "gamma {gamma} cannot fit in max_ctx {max_ctx}");
            let keep = max_ctx - need;
            t_sess.evict_to(keep)?;
            source.evict_to(keep)?;
        }

        if gamma == 0 {
            // Horizon tail: plain target AR step off the session tip.
            let t0 = Instant::now();
            let mu_p = t_sess.tip_mean()?;
            ensure_finite(&mu_p, "target tip mean")?;
            let patch = emit_from_p(&mu_p, policy.sigma, cfg.emission, &mut rng);
            t_sess.append(&patch, 1)?;
            let tt = t0.elapsed();
            let t1 = Instant::now();
            source.append(&patch, 1)?;
            let dt = t1.elapsed();
            out_patches.extend_from_slice(&patch);
            emitted += 1;
            let r = RoundStats {
                gamma: 0,
                accepted: 0,
                emitted: 1,
                alphas: vec![],
                residual_draws: 0,
                branches: 1,
                draft_time: dt,
                target_time: tt,
            };
            plan.observe(&r);
            super::observer::notify_round(0, &r);
            stats.absorb(&r);
            rounds.push(r);
            continue;
        }

        // --- The source proposes gamma patches autoregressively
        // (Alg. 1 l.1-3): sampled x_i ~ N(mu_q_i, sigma^2) through this
        // decode's RNG stream, each mean conditioned on the committed
        // context plus the proposals so far.
        let t0 = Instant::now();
        let block = source.propose(gamma, policy.sigma, &mut rng)?;
        let mut draft_time = t0.elapsed();
        anyhow::ensure!(
            block.proposals.len() == gamma && block.mu_qs.len() == gamma,
            "draft source returned {} proposals for gamma {gamma}",
            block.proposals.len()
        );
        for (x, m) in block.proposals.iter().zip(&block.mu_qs) {
            ensure_finite(x, "draft proposal")?;
            ensure_finite(m, "draft mean")?;
        }
        let proposals = &block.proposals;
        let mu_qs = &block.mu_qs;

        // --- One target pass validates all gamma+1 prefix conditionals
        // (l.4): `extend` returns the means at positions n0-1 ..= n0+γ-1,
        // i.e. mu_p for every proposal plus the bonus patch.
        let mut flat = Vec::with_capacity(gamma * p);
        for x in proposals {
            flat.extend_from_slice(x);
        }
        let t1 = Instant::now();
        let val_rows = t_sess.extend(&flat, gamma)?;
        let mut target_time = t1.elapsed();
        ensure_finite(&val_rows, "target validation means")?;
        let mu_p_at = |i: usize| &val_rows[i * p..(i + 1) * p];

        // --- Acceptance scan (l.5-8).
        let mut alphas = Vec::with_capacity(gamma);
        let mut accepted = 0usize;
        let mut rejected_at: Option<usize> = None;
        for i in 0..gamma {
            let a = policy.alpha(&proposals[i], mu_p_at(i), &mu_qs[i]);
            alphas.push(a);
            if a >= 1.0 || rng.uniform() < a {
                accepted += 1;
            } else {
                rejected_at = Some(i);
                break;
            }
        }

        // --- Rewind the target to the accepted prefix (the KV-cache
        // rollback that replaces the old truncate-and-rebuild), then emit
        // per protocol. The draft side is rewound by `finish_round`.
        let mut emit_flat: Vec<f32> = Vec::with_capacity(accepted * p);
        match cfg.emission {
            Emission::Sampled => {
                // Accepted proposals are already in the target context.
                let t2 = Instant::now();
                t_sess.rollback(gamma - accepted)?;
                target_time += t2.elapsed();
                for x in &proposals[..accepted] {
                    emit_flat.extend_from_slice(x);
                }
            }
            Emission::Mean => {
                // The context must carry the emitted draft means, not the
                // sampled proposals: rewind everything and re-append.
                let t2 = Instant::now();
                t_sess.rollback(gamma)?;
                target_time += t2.elapsed();
                for m in &mu_qs[..accepted] {
                    emit_flat.extend_from_slice(m);
                }
                if accepted > 0 {
                    let t4 = Instant::now();
                    t_sess.append(&emit_flat, accepted)?;
                    target_time += t4.elapsed();
                }
            }
        }
        out_patches.extend_from_slice(&emit_flat);

        let mut residual_draws = 0usize;
        let final_patch: Vec<f32> = match rejected_at {
            None => {
                // All accepted: bonus draw from p_{gamma+1} (l.9-10).
                let mu = mu_p_at(gamma);
                emit_from_p(mu, policy.sigma, cfg.emission, &mut rng)
            }
            Some(i) => {
                let mu_p = mu_p_at(i);
                match cfg.variant {
                    // Fallback-to-p (l.12).
                    Variant::Practical => emit_from_p(mu_p, policy.sigma, cfg.emission, &mut rng),
                    // Residual thinning (§A.5.1), shared helper: draw
                    // Z ~ p, accept with prob (1 - q(Z)/p(Z))_+.
                    Variant::Lossless => {
                        let (z, draws) = residual_thin(
                            mu_p,
                            &mu_qs[i],
                            policy.sigma,
                            cfg.max_residual_draws,
                            &mut rng,
                        );
                        residual_draws = draws;
                        z
                    }
                }
            }
        };
        out_patches.extend_from_slice(&final_patch);
        let t6 = Instant::now();
        t_sess.append(&final_patch, 1)?;
        target_time += t6.elapsed();

        // --- Verification feedback: the source rewinds its rejected
        // suffix, commits what was emitted, and (for learning sources)
        // flushes its paused online update — all draft-side cost, so the
        // controller's measured c stays per-source honest.
        let t7 = Instant::now();
        source.finish_round(&RoundFeedback {
            gamma,
            accepted,
            alphas: &alphas,
            target_means: &val_rows,
            committed: &emit_flat,
            final_patch: &final_patch,
            sampled: cfg.emission == Emission::Sampled,
        })?;
        draft_time += t7.elapsed();

        // Residual thinning consumes no extra target *forwards* (it samples
        // from the already-computed head); `residual_draws` records the
        // draw count for the §B.6 cost analysis.
        emitted += accepted + 1;

        let r = RoundStats {
            gamma,
            accepted,
            emitted: accepted + 1,
            alphas,
            residual_draws,
            branches: 1,
            draft_time,
            target_time,
        };
        plan.observe(&r);
        super::observer::notify_round(0, &r);
        stats.absorb(&r);
        rounds.push(r);
    }

    out_patches.truncate(horizon * p);
    stats.draft_updates = source.updates().saturating_sub(upd0);
    Ok(DecodeOutput { patches: out_patches, rounds, stats })
}

/// Numeric guard at the session boundary: any non-finite value coming
/// out of a backend (draft proposals, target validation means, the AR
/// tip) becomes a typed error *before* the acceptance scan, so a model
/// emitting one NaN can never poison the acceptance math or be served to
/// a client. The message always contains the marker `non-finite` — the
/// serving tier greps the error chain for it to count numeric faults and
/// feed the controller's circuit breaker
/// ([`super::GammaController::note_numeric_fault`]).
pub(crate) fn ensure_finite(vals: &[f32], what: &str) -> Result<()> {
    if let Some(pos) = vals.iter().position(|v| !v.is_finite()) {
        anyhow::bail!(
            "non-finite model output: {what} has {} at flat index {pos}",
            if vals[pos].is_nan() { "NaN" } else { "inf" }
        );
    }
    Ok(())
}

/// Residual thinning at a rejection point (§A.5.1): draw `Z ~ p`,
/// accept with probability `(1 - q(Z)/p(Z))_+`, capped at
/// `max_residual_draws`. Returns the emitted patch and the draw count.
///
/// Shared by the single-stream loop and **both** batched decode loops —
/// the RNG consumption (one `fill_normal_around` block plus one
/// `uniform` per iteration, in that order) is part of the decode's
/// bit-exactness contract (`tests/draft_equivalence.rs`,
/// `seeded_batch_is_bitwise_identical_to_solo_decodes`); any change
/// here changes every path together, which is the point.
pub(crate) fn residual_thin(
    mu_p: &[f32],
    mu_q: &[f32],
    sigma: f64,
    max_residual_draws: usize,
    rng: &mut Rng,
) -> (Vec<f32>, usize) {
    let mut z = vec![0.0f32; mu_p.len()];
    let mut draws = 0usize;
    loop {
        draws += 1;
        rng.fill_normal_around(mu_p, sigma as f32, &mut z);
        // pi(z) = (1 - q(z)/p(z))_+ = 1 - exp(min(0, log q - log p))
        let lqp = crate::gaussian::iso_log_ratio(&z, mu_q, mu_p, sigma);
        let pi = 1.0 - lqp.min(0.0).exp();
        if rng.uniform() < pi {
            break;
        }
        if draws >= max_residual_draws {
            log::warn!("residual thinning hit cap {max_residual_draws}; emitting last draw");
            break;
        }
    }
    (z, draws)
}

/// Emit a patch given its target-head mean: a sample in the generative
/// protocol, the mean in production mode. Takes the *round* sigma so an
/// adapting controller's width applies consistently within a round.
pub(super) fn emit_from_p(mu: &[f32], sigma: f64, emission: Emission, rng: &mut Rng) -> Vec<f32> {
    match emission {
        Emission::Sampled => {
            let mut buf = vec![0.0f32; mu.len()];
            rng.fill_normal_around(mu, sigma as f32, &mut buf);
            buf
        }
        Emission::Mean => mu.to_vec(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::AnalyticBackend;
    use crate::util::stats::Summary;

    fn cfg(gamma: usize, sigma: f64, variant: Variant, seed: u64) -> SpecConfig {
        SpecConfig {
            gamma,
            k: 1,
            policy: AcceptancePolicy::new(sigma, 1.0),
            variant,
            seed,
            max_residual_draws: 10_000,
            emission: Emission::Sampled,
            cache: CacheMode::On,
            draft: DraftConfig::default(),
            adaptive: None,
        }
    }

    /// Cache on/off must be RNG-stream and decision identical. On the
    /// native backend (the one with a real KV cache) incremental and
    /// stateless forwards share the exact op order, so whole decodes —
    /// including window slides past max_ctx — come out the same.
    #[test]
    fn cache_toggle_is_observationally_identical() {
        use crate::models::NativeBackend;
        use crate::nn::model::tiny_model;
        let t = NativeBackend::new(tiny_model(31));
        let d = NativeBackend::new(tiny_model(32));
        let hist = [0.4f32, -0.2, 0.1, 0.7, 0.0, 0.3, -0.5, 0.2]; // 2 patches
        for variant in [Variant::Practical, Variant::Lossless] {
            let mut on = cfg(3, 0.4, variant, 11);
            on.cache = CacheMode::On;
            let mut off = on;
            off.cache = CacheMode::Off;
            // horizon 17 with n_ctx 8 forces repeated eviction.
            let a = sd_generate(&t, &d, &hist, 2, 17, &on).unwrap();
            let b = sd_generate(&t, &d, &hist, 2, 17, &off).unwrap();
            assert_eq!(a.stats.accepted, b.stats.accepted, "{variant:?}");
            assert_eq!(a.stats.proposals, b.stats.proposals);
            assert_eq!(a.stats.rounds, b.stats.rounds);
            assert_eq!(a.patches.len(), b.patches.len());
            for (x, y) in a.patches.iter().zip(&b.patches) {
                assert!((x - y).abs() < 1e-5, "{variant:?}: {x} vs {y}");
            }
        }
    }

    #[test]
    fn emits_exact_horizon() {
        let t = AnalyticBackend::new("t", 2, 0.8, 0.1);
        let d = AnalyticBackend::new("d", 2, 0.75, 0.12);
        for horizon in [1, 2, 3, 4, 7, 13] {
            let out = sd_generate(&t, &d, &[0.5, -0.5], 1, horizon, &cfg(3, 0.5, Variant::Practical, 1))
                .unwrap();
            assert_eq!(out.patches.len(), horizon * 2, "horizon {horizon}");
            assert_eq!(out.stats.sum_block_len, horizon);
        }
    }

    #[test]
    fn identical_models_accept_everything() {
        let t = AnalyticBackend::new("t", 3, 0.8, 0.1);
        let d = AnalyticBackend::new("d", 3, 0.8, 0.1);
        let out =
            sd_generate(&t, &d, &[0.1, 0.2, 0.3], 1, 12, &cfg(3, 0.5, Variant::Practical, 2)).unwrap();
        assert_eq!(out.stats.accepted, out.stats.proposals);
        assert!((out.stats.alpha_hat() - 1.0).abs() < 1e-9);
        // E[L] = gamma + 1 when everything is accepted.
        assert!((out.stats.mean_block_len() - 4.0).abs() < 1e-9);
    }

    #[test]
    fn hostile_draft_rejects_mostly() {
        let t = AnalyticBackend::new("t", 2, 0.8, 0.0);
        let d = AnalyticBackend::new("d", 2, -0.8, 3.0); // wildly wrong draft
        let out =
            sd_generate(&t, &d, &[1.0, 1.0], 1, 20, &cfg(3, 0.3, Variant::Practical, 3)).unwrap();
        assert!(out.stats.accept_rate() < 0.3, "rate {}", out.stats.accept_rate());
        // Block length approaches 1 under constant rejection.
        assert!(out.stats.mean_block_len() < 2.0);
    }

    #[test]
    fn deterministic_given_seed() {
        let t = AnalyticBackend::new("t", 2, 0.8, 0.1);
        let d = AnalyticBackend::new("d", 2, 0.7, 0.1);
        let a = sd_generate(&t, &d, &[0.5, 0.5], 1, 8, &cfg(3, 0.4, Variant::Practical, 42)).unwrap();
        let b = sd_generate(&t, &d, &[0.5, 0.5], 1, 8, &cfg(3, 0.4, Variant::Practical, 42)).unwrap();
        assert_eq!(a.patches, b.patches);
        let c = sd_generate(&t, &d, &[0.5, 0.5], 1, 8, &cfg(3, 0.4, Variant::Practical, 43)).unwrap();
        assert_ne!(a.patches, c.patches);
    }

    #[test]
    fn lossless_requires_canonical_bias() {
        let t = AnalyticBackend::new("t", 1, 0.8, 0.0);
        let d = AnalyticBackend::new("d", 1, 0.7, 0.0);
        let mut c = cfg(2, 0.5, Variant::Lossless, 1);
        c.policy.bias = 1.5;
        assert!(sd_generate(&t, &d, &[0.0], 1, 4, &c).is_err());
    }

    /// Statistical test of single-step laws in 1-D (patch = 1):
    /// lossless must reproduce the target law; practical deviates by at
    /// most TV <= alpha-bar (here measured via mean/variance tolerance).
    #[test]
    fn lossless_first_step_matches_target_law() {
        let a_t = 0.6f32;
        let b_t = 0.2f32;
        let t = AnalyticBackend::new("t", 1, a_t, b_t);
        let d = AnalyticBackend::new("d", 1, 0.2, -0.1); // deliberately off
        let x0 = 1.0f32;
        let sigma = 0.5;
        // Target law for patch 1: N(a_t x0 + b_t, sigma^2).
        let want_mean = (a_t * x0 + b_t) as f64;
        let mut s = Summary::new();
        for seed in 0..4000 {
            let out =
                sd_generate(&t, &d, &[x0], 1, 1, &cfg(1, sigma, Variant::Lossless, seed)).unwrap();
            s.push(out.patches[0] as f64);
        }
        // 4000 samples: SE of mean ~ sigma/sqrt(4000) ~ 0.008.
        assert!(
            (s.mean() - want_mean).abs() < 0.03,
            "lossless mean {:.4} vs target {want_mean:.4}",
            s.mean()
        );
        assert!((s.std() - sigma).abs() < 0.03, "lossless std {:.4}", s.std());
    }

    #[test]
    fn practical_first_step_biased_but_bounded() {
        // With a biased draft, the practical variant's mean shifts toward
        // the draft, but stays within the TV bound's reach; we verify the
        // empirical mean sits between target and draft means.
        let t = AnalyticBackend::new("t", 1, 0.6, 0.2);
        let d = AnalyticBackend::new("d", 1, 0.6, -0.1);
        let x0 = 1.0f32;
        let sigma = 0.4;
        let mu_t = 0.6 * 1.0 + 0.2; // 0.8
        let mu_d = 0.6 * 1.0 - 0.1; // 0.5
        let mut s = Summary::new();
        for seed in 0..4000 {
            let out =
                sd_generate(&t, &d, &[x0], 1, 1, &cfg(1, sigma, Variant::Practical, seed)).unwrap();
            s.push(out.patches[0] as f64);
        }
        assert!(
            s.mean() > mu_d as f64 && s.mean() < mu_t as f64 + 0.05,
            "practical mean {:.4} should lie between draft {mu_d} and target {mu_t}",
            s.mean()
        );
    }

    #[test]
    fn lossless_costs_more_target_draws_at_high_overlap() {
        // Draft ~= target => beta ~ 1 => thinning needs many draws (§B.6).
        let t = AnalyticBackend::new("t", 1, 0.8, 0.100);
        let d = AnalyticBackend::new("d", 1, 0.8, 0.102); // tiny gap, huge overlap
        let mut total_residual = 0usize;
        let mut rejections = 0usize;
        for seed in 0..2000 {
            let out =
                sd_generate(&t, &d, &[1.0], 1, 2, &cfg(1, 0.5, Variant::Lossless, seed)).unwrap();
            total_residual += out.stats.residual_draws;
            rejections += out
                .rounds
                .iter()
                .filter(|r| r.accepted < r.gamma && r.gamma > 0)
                .count();
        }
        if rejections > 0 {
            let per_rejection = total_residual as f64 / rejections as f64;
            assert!(
                per_rejection > 5.0,
                "expected expensive residual sampling at high overlap, got {per_rejection:.1}"
            );
        }
    }

    /// A tight-window shim over the analytic head, shared by the sliding
    /// and clamping tests below.
    struct Limited(AnalyticBackend, usize);
    impl crate::models::Backend for Limited {
        fn name(&self) -> &str {
            self.0.name()
        }
        fn patch(&self) -> usize {
            self.0.patch()
        }
        fn max_ctx(&self) -> usize {
            self.1
        }
        fn forward(&self, tokens: &[f32], n: usize) -> Result<Vec<f32>> {
            assert!(n <= self.1, "context overflow: {n}");
            self.0.forward(tokens, n)
        }
        fn flops(&self, n: usize) -> f64 {
            self.0.flops(n)
        }
    }

    #[test]
    fn long_horizon_slides_context() {
        let t = Limited(AnalyticBackend::new("t", 2, 0.8, 0.1), 6);
        let d = Limited(AnalyticBackend::new("d", 2, 0.75, 0.1), 6);
        let out =
            sd_generate(&t, &d, &[0.5, -0.5], 1, 30, &cfg(3, 0.5, Variant::Practical, 7)).unwrap();
        assert_eq!(out.patches.len(), 30 * 2);
    }

    /// The max_ctx footgun fix: an opening γ that can never fit the joint
    /// window — including when the *draft* is the binding constraint —
    /// must be a clear error at decode entry, not mid-decode weirdness.
    #[test]
    fn oversized_gamma_is_a_clear_upfront_error() {
        let t = AnalyticBackend::new("t", 1, 0.8, 0.1); // max_ctx unbounded
        let d = Limited(AnalyticBackend::new("d", 1, 0.8, 0.1), 4);
        let err = sd_generate(&t, &d, &[0.0, 0.1, 0.2], 3, 10, &cfg(5, 0.5, Variant::Practical, 1))
            .unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("cannot fit"), "unexpected error: {msg}");
        assert!(msg.contains("draft max_ctx 4"), "error must name the binding side: {msg}");
        // gamma 2 fits (2 + 1 < 4): same setup must decode fine.
        let out = sd_generate(&t, &d, &[0.0, 0.1, 0.2], 3, 10, &cfg(2, 0.5, Variant::Practical, 1))
            .unwrap();
        assert_eq!(out.patches.len(), 10);
    }

    /// A small-context draft meeting a long history: both sides must be
    /// clamped to the *joint* window (previously each clamped to its own,
    /// silently conditioning the two models on different histories).
    #[test]
    fn mismatched_max_ctx_aligns_on_joint_window() {
        let t = AnalyticBackend::new("t", 1, 0.9, 0.0);
        let d = Limited(AnalyticBackend::new("d", 1, 0.9, 0.0), 5);
        let hist: Vec<f32> = (0..12).map(|i| i as f32 * 0.1).collect();
        // Identical heads under the same window accept everything; a
        // desynced window would show up as rejections.
        let out = sd_generate(&t, &d, &hist, 12, 8, &cfg(2, 0.5, Variant::Practical, 9)).unwrap();
        assert_eq!(out.patches.len(), 8);
        assert_eq!(out.stats.accepted, out.stats.proposals, "window desync broke acceptance");
    }

    #[test]
    fn draft_free_sources_decode_exact_horizon() {
        use super::super::draft::DraftKind;
        let t = AnalyticBackend::new("t", 2, 0.8, 0.1);
        let d = AnalyticBackend::new("d", 2, 0.75, 0.1); // only supplies patch size
        for kind in [DraftKind::Extrap, DraftKind::Adaptive] {
            for (variant, emission) in [
                (Variant::Practical, Emission::Mean),
                (Variant::Practical, Emission::Sampled),
                (Variant::Lossless, Emission::Sampled),
            ] {
                let mut c = cfg(3, 0.5, variant, 13);
                c.emission = emission;
                c.draft.kind = kind;
                let out = sd_generate(&t, &d, &[0.5, -0.5, 0.2, 0.1], 2, 15, &c).unwrap();
                assert_eq!(out.patches.len(), 15 * 2, "{kind:?}/{variant:?}");
                assert!(out.patches.iter().all(|v| v.is_finite()));
                assert_eq!(out.stats.sum_block_len, 15);
            }
        }
    }

    #[test]
    fn adaptive_source_learns_the_target_online() {
        use super::super::draft::{AdaptiveResidualDraft, ModelDraft};
        // Frozen biased model draft vs the online residual head, same
        // target, same stream of windows: after enough feedback the
        // learned head's acceptance must overtake the frozen draft's.
        let t = AnalyticBackend::new("t", 2, 0.5, 0.8);
        let d_frozen = AnalyticBackend::new("d", 2, 0.5, 0.0); // stale bias
        let mut frozen = ModelDraft::new(&d_frozen);
        let mut learned = AdaptiveResidualDraft::new(2, 0.5);
        let c = cfg(3, 0.5, Variant::Practical, 17);
        let (mut a_frozen, mut a_learned) = (0.0, 0.0);
        for w in 0..30 {
            let hist = [0.3 + 0.01 * w as f32, -0.2];
            let mut cw = c;
            cw.seed = 1000 + w as u64;
            let of = sd_generate_from(&t, &mut frozen, &hist, 1, 10, &cw).unwrap();
            let ol = sd_generate_from(&t, &mut learned, &hist, 1, 10, &cw).unwrap();
            if w >= 20 {
                // Score only the tail, once the head has seen feedback.
                a_frozen += of.stats.alpha_hat();
                a_learned += ol.stats.alpha_hat();
            }
        }
        assert!(
            a_learned > a_frozen,
            "learned draft alpha {a_learned:.3} should beat frozen {a_frozen:.3}"
        );
        assert!(learned.updates() > 0, "head never updated");
    }

    #[test]
    fn adaptive_emits_exact_horizon_and_adapts_gamma() {
        use super::super::controller::AdaptiveConfig;
        let t = AnalyticBackend::new("t", 2, 0.8, 0.1);
        let d = AnalyticBackend::new("d", 2, 0.8, 0.1); // identical => alpha ~ 1
        let mut c = cfg(2, 0.5, Variant::Practical, 9);
        c.adaptive = Some(AdaptiveConfig {
            warmup: 1,
            dwell: 1,
            halflife: 6.0,
            c_override: 0.05,
            ..AdaptiveConfig::default()
        });
        let out = sd_generate(&t, &d, &[0.5, -0.5], 1, 60, &c).unwrap();
        assert_eq!(out.patches.len(), 60 * 2);
        assert_eq!(out.stats.sum_block_len, 60);
        // Identical heads accept everything; the controller must have
        // raised gamma above its opening value within the decode.
        let max_gamma = out.rounds.iter().map(|r| r.gamma).max().unwrap();
        assert!(max_gamma > 2, "controller never adapted (max gamma {max_gamma})");
    }

    #[test]
    fn adaptive_respects_tight_context_window() {
        // A backend with max_ctx 6 can host at most gamma 4 per round
        // (gamma + 1 appended, >= 1 context patch kept). The controller
        // must clamp even when acceptance begs for more.
        let t = Limited(AnalyticBackend::new("t", 1, 0.9, 0.0), 6);
        let d = Limited(AnalyticBackend::new("d", 1, 0.9, 0.0), 6);
        let mut c = cfg(3, 0.5, Variant::Practical, 11);
        c.adaptive = Some(AdaptiveConfig {
            warmup: 1,
            dwell: 1,
            halflife: 4.0,
            c_override: 0.02, // begs for huge gamma
            ..AdaptiveConfig::default()
        });
        let out = sd_generate(&t, &d, &[0.4], 1, 50, &c).unwrap();
        assert_eq!(out.patches.len(), 50);
        assert!(out.rounds.iter().all(|r| r.gamma <= 4), "context clamp violated");
    }

    #[test]
    fn scheduled_replay_reproduces_adaptive_decode() {
        use super::super::controller::AdaptiveConfig;
        // The core lossless-compatibility property: replaying an adaptive
        // decode's per-round gamma choices yields the identical decode.
        let t = AnalyticBackend::new("t", 2, 0.7, 0.2);
        let d = AnalyticBackend::new("d", 2, 0.6, 0.1);
        let mut c = cfg(3, 0.5, Variant::Practical, 21);
        c.adaptive = Some(AdaptiveConfig {
            warmup: 1,
            dwell: 1,
            halflife: 4.0,
            c_override: 0.1,
            ..AdaptiveConfig::default()
        });
        let live = sd_generate(&t, &d, &[0.5, 0.5], 1, 40, &c).unwrap();
        let schedule: Vec<usize> = live.rounds.iter().map(|r| r.gamma).collect();
        assert!(schedule.iter().any(|&g| g != 3), "decode never adapted; test is vacuous");
        let mut replay_cfg = c;
        replay_cfg.adaptive = None;
        let replay =
            sd_generate_scheduled(&t, &d, &[0.5, 0.5], 1, 40, &replay_cfg, &schedule).unwrap();
        assert_eq!(live.patches, replay.patches, "replay drifted from the live decode");
        assert_eq!(live.stats.accepted, replay.stats.accepted);
        assert_eq!(live.stats.rounds, replay.stats.rounds);
    }

    #[test]
    fn adaptive_lossless_rejects_sigma_adaptation() {
        use super::super::controller::AdaptiveConfig;
        let t = AnalyticBackend::new("t", 1, 0.8, 0.0);
        let d = AnalyticBackend::new("d", 1, 0.7, 0.0);
        let mut c = cfg(2, 0.5, Variant::Lossless, 1);
        c.adaptive = Some(AdaptiveConfig { sigma_adapt: true, ..AdaptiveConfig::default() });
        assert!(sd_generate(&t, &d, &[0.0], 1, 4, &c).is_err());
        // Gamma-only adaptation is fine for lossless.
        c.adaptive = Some(AdaptiveConfig::default());
        assert!(sd_generate(&t, &d, &[0.0], 1, 4, &c).is_ok());
    }

    /// A backend that emits NaN means after a set number of forwards —
    /// the minimal stand-in for a numerically-corrupt model.
    struct NanAfter(AnalyticBackend, std::cell::Cell<usize>);
    impl crate::models::Backend for NanAfter {
        fn name(&self) -> &str {
            self.0.name()
        }
        fn patch(&self) -> usize {
            self.0.patch()
        }
        fn max_ctx(&self) -> usize {
            self.0.max_ctx()
        }
        fn forward(&self, tokens: &[f32], n: usize) -> Result<Vec<f32>> {
            let mut out = self.0.forward(tokens, n)?;
            if self.1.get() == 0 {
                out[0] = f32::NAN;
            } else {
                self.1.set(self.1.get() - 1);
            }
            Ok(out)
        }
        fn flops(&self, n: usize) -> f64 {
            self.0.flops(n)
        }
    }

    #[test]
    fn non_finite_model_output_is_a_typed_error_not_a_served_nan() {
        let d = AnalyticBackend::new("d", 2, 0.75, 0.1);
        // Target goes NaN after 2 clean forwards: the decode must fail
        // with the greppable "non-finite" marker, never emit NaN patches.
        let t = NanAfter(AnalyticBackend::new("t", 2, 0.8, 0.1), std::cell::Cell::new(2));
        let err = sd_generate(&t, &d, &[0.5, -0.5], 1, 12, &cfg(3, 0.5, Variant::Practical, 5))
            .unwrap_err();
        assert!(format!("{err:#}").contains("non-finite"), "got: {err:#}");
        // Draft goes NaN: same contract, caught before the acceptance scan.
        let t = AnalyticBackend::new("t", 2, 0.8, 0.1);
        let d = NanAfter(AnalyticBackend::new("d", 2, 0.75, 0.1), std::cell::Cell::new(1));
        let err = sd_generate(&t, &d, &[0.5, -0.5], 1, 12, &cfg(3, 0.5, Variant::Practical, 5))
            .unwrap_err();
        assert!(format!("{err:#}").contains("non-finite"), "got: {err:#}");
    }

    #[test]
    fn gamma_capped_near_horizon() {
        let t = AnalyticBackend::new("t", 1, 0.8, 0.1);
        let d = AnalyticBackend::new("d", 1, 0.8, 0.1);
        // horizon 2 with gamma 5: a single round should use gamma <= 1.
        let out = sd_generate(&t, &d, &[0.0], 1, 2, &cfg(5, 0.5, Variant::Practical, 1)).unwrap();
        assert!(out.rounds.iter().all(|r| r.gamma <= 1));
        assert_eq!(out.patches.len(), 2);
    }
}
