//! Draft-free self-speculation: a closed-form continuation of the
//! committed context stands in for the draft model.
//!
//! Speculative Streaming showed drafting needs no auxiliary model; for
//! time series the cheapest competent "draft" is an extrapolation of the
//! context itself:
//!
//! * **Linear trend** (`period == 0`): continue the series at the slope
//!   of its last two points — `x̂[n+k] = x[n] + k·(x[n] − x[n−1])`. Flat
//!   or slowly-trending series (the bulk of z-scored traffic) yield
//!   proposal means close to any competent target's, so α stays useful.
//! * **Seasonal naive** (`period == s > 0`): repeat the patch one season
//!   back — `x̂_patch[i] = x_patch[i − s]` — the classic strong baseline
//!   on periodic telemetry.
//!
//! Cost: a handful of float ops per proposal — no forward pass, no
//! weights, no allocation beyond the returned block. Measured draft cost
//! c ≈ 0, which is the best case of the paper's Eq. 5 wall-clock speedup
//! (the denominator `c·γ + 1` collapses to 1): every accepted patch is
//! free. `benches/draft_sources.rs` pins this source as the lowest
//! measured c of the three.

use anyhow::Result;

use super::{DraftKind, DraftSource, ProposalBlock, RoundFeedback};
use crate::models::CacheMode;
use crate::util::rng::Rng;

/// Closed-form continuation draft (linear trend or seasonal naive). Holds
/// only the committed context window; proposals condition on the sampled
/// prefix recursively, mirroring a model draft's autoregression.
pub struct ExtrapolationDraft {
    patch: usize,
    /// `0` = linear trend; `s > 0` = seasonal naive with period `s`
    /// patches.
    period: usize,
    /// Committed context, flat `[len, patch]`.
    ctx: Vec<f32>,
}

impl ExtrapolationDraft {
    /// Continuation source over `patch`-sized tokens; `period == 0` for
    /// linear trend, else seasonal-naive with that many patches.
    pub fn new(patch: usize, period: usize) -> ExtrapolationDraft {
        assert!(patch >= 1, "patch must be >= 1");
        ExtrapolationDraft { patch, period, ctx: Vec::new() }
    }

    /// Closed-form mean of the next patch given the current (possibly
    /// speculatively extended) context tail.
    fn mean_next(&self) -> Vec<f32> {
        let p = self.patch;
        let n = self.ctx.len();
        debug_assert!(n >= p, "mean_next on an empty context");
        if self.period > 0 {
            let n_patches = n / p;
            if n_patches >= self.period {
                // Patch one season back.
                let start = (n_patches - self.period) * p;
                return self.ctx[start..start + p].to_vec();
            }
            // Not a full season yet: fall back to naive (repeat last).
            return self.ctx[n - p..].to_vec();
        }
        // Linear trend from the last two *points* of the flat series.
        let last = self.ctx[n - 1];
        let slope = if n >= 2 { last - self.ctx[n - 2] } else { 0.0 };
        (1..=p).map(|k| last + slope * k as f32).collect()
    }
}

impl DraftSource for ExtrapolationDraft {
    fn kind(&self) -> DraftKind {
        DraftKind::Extrap
    }
    fn patch(&self) -> usize {
        self.patch
    }
    fn begin(&mut self, history: &[f32], n_hist: usize, _cache: CacheMode) -> Result<()> {
        let p = self.patch;
        anyhow::ensure!(n_hist >= 1, "source needs at least one history patch");
        anyhow::ensure!(history.len() >= n_hist * p, "history too short");
        self.ctx.clear();
        self.ctx.extend_from_slice(&history[..n_hist * p]);
        Ok(())
    }
    fn len(&self) -> usize {
        self.ctx.len() / self.patch
    }
    fn max_ctx(&self) -> usize {
        usize::MAX
    }
    fn context(&self) -> &[f32] {
        &self.ctx
    }

    fn propose(&mut self, gamma: usize, sigma: f64, rng: &mut Rng) -> Result<ProposalBlock> {
        let p = self.patch;
        anyhow::ensure!(!self.ctx.is_empty(), "propose before begin()");
        // Speculative extension lives directly on the context buffer and
        // is truncated before returning — committed history is untouched
        // and nothing is cloned (this source must stay the cheapest).
        let base = self.ctx.len();
        let mut proposals = Vec::with_capacity(gamma);
        let mut mu_qs = Vec::with_capacity(gamma);
        for _ in 0..gamma {
            let mu = self.mean_next();
            let mut x = vec![0.0f32; p];
            rng.fill_normal_around(&mu, sigma as f32, &mut x);
            self.ctx.extend_from_slice(&x);
            proposals.push(x);
            mu_qs.push(mu);
        }
        self.ctx.truncate(base);
        Ok(ProposalBlock { proposals, mu_qs })
    }

    fn finish_round(&mut self, fb: &RoundFeedback<'_>) -> Result<()> {
        // Proposals were already unwound at the end of propose(): commit
        // exactly what the engine emitted.
        self.ctx.extend_from_slice(fb.committed);
        self.ctx.extend_from_slice(fb.final_patch);
        Ok(())
    }

    fn append(&mut self, patches: &[f32], k: usize) -> Result<()> {
        let p = self.patch;
        anyhow::ensure!(patches.len() >= k * p, "patch buffer too short");
        self.ctx.extend_from_slice(&patches[..k * p]);
        Ok(())
    }

    fn evict_to(&mut self, keep: usize) -> Result<()> {
        let p = self.patch;
        let n = self.len();
        anyhow::ensure!(keep >= 1 && keep <= n, "bad evict target {keep} for len {n}");
        self.ctx.drain(..(n - keep) * p);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn linear_trend_continues_slope() {
        let mut s = ExtrapolationDraft::new(2, 0);
        // Flat series 1,2,3,4 → slope 1 → next patch [5, 6].
        s.begin(&[1.0, 2.0, 3.0, 4.0], 2, CacheMode::Off).unwrap();
        assert_eq!(s.mean_next(), vec![5.0, 6.0]);
    }

    #[test]
    fn seasonal_naive_repeats_period() {
        let mut s = ExtrapolationDraft::new(2, 2);
        // Patches: [1,2], [9,9], [1,2], [9,9] with period 2 → next = [1,2].
        s.begin(&[1.0, 2.0, 9.0, 9.0, 1.0, 2.0, 9.0, 9.0], 4, CacheMode::Off).unwrap();
        assert_eq!(s.mean_next(), vec![1.0, 2.0]);
        // Short context falls back to naive-repeat.
        let mut s = ExtrapolationDraft::new(2, 8);
        s.begin(&[3.0, 4.0], 1, CacheMode::Off).unwrap();
        assert_eq!(s.mean_next(), vec![3.0, 4.0]);
    }

    #[test]
    fn propose_leaves_committed_context_untouched() {
        let mut s = ExtrapolationDraft::new(2, 0);
        s.begin(&[1.0, 2.0, 3.0, 4.0], 2, CacheMode::Off).unwrap();
        let before = s.context().to_vec();
        let mut rng = Rng::new(3);
        let block = s.propose(4, 0.5, &mut rng).unwrap();
        assert_eq!(block.proposals.len(), 4);
        assert_eq!(block.mu_qs.len(), 4);
        assert_eq!(s.context(), before.as_slice());
        // Later proposals condition on the sampled prefix: the second
        // mean continues from proposal 0's last points, not the context.
        let x0 = &block.proposals[0];
        let slope = x0[1] - x0[0];
        assert_eq!(block.mu_qs[1], vec![x0[1] + slope, x0[1] + 2.0 * slope]);
    }

    #[test]
    fn commit_and_evict_window() {
        let mut s = ExtrapolationDraft::new(1, 0);
        s.begin(&[1.0, 2.0], 2, CacheMode::Off).unwrap();
        let mut rng = Rng::new(4);
        let _ = s.propose(2, 0.5, &mut rng).unwrap();
        s.finish_round(&RoundFeedback {
            gamma: 2,
            accepted: 1,
            alphas: &[1.0, 0.0],
            target_means: &[0.0; 3],
            committed: &[7.0],
            final_patch: &[8.0],
            sampled: true,
        })
        .unwrap();
        assert_eq!(s.context(), &[1.0, 2.0, 7.0, 8.0]);
        s.evict_to(2).unwrap();
        assert_eq!(s.context(), &[7.0, 8.0]);
        assert!(s.evict_to(0).is_err());
    }
}
