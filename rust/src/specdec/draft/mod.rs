//! Pluggable draft sources: *where speculative proposals come from*.
//!
//! The paper (and this repo until now) hard-wires the draft as a second,
//! smaller forecasting model — a full [`crate::models::Backend`] driven
//! through its own decode session. But the speculative-decoding framework
//! only needs a *proposal distribution* q per position; anything that can
//! produce a mean patch given the committed history is a legal draft.
//! Opening this axis turns the acceptance rate α itself into a tunable:
//!
//! * [`ModelDraft`] — the classic two-model setup, wrapping any backend's
//!   [`crate::models::DecodeSession`]. This is the equivalence baseline:
//!   decoding through a `ModelDraft` is **bit-identical** to the
//!   pre-refactor engine (pinned by `tests/draft_equivalence.rs`).
//! * [`ExtrapolationDraft`] — *draft-free self-speculation* in the spirit
//!   of Speculative Streaming (Bhendawade et al.): a closed-form
//!   linear-trend or seasonal-naive continuation of the context. Draft
//!   cost c ≈ 0, which is the best case of the paper's Eq. 5 speedup
//!   curve — every accepted patch is nearly free.
//! * [`AdaptiveResidualDraft`] — an *online-learned* corrector in the
//!   spirit of Online Speculative Decoding (Liu et al.): a lightweight
//!   linear head over the last committed patch, NLMS-updated each round
//!   against the target means observed during verification. The target
//!   validation pass it learns from is already paid for, so α rises
//!   online with **zero extra target forwards** — exactly the lever the
//!   adaptive γ controller (PR 3) measures regime drift with but cannot
//!   itself pull.
//!
//! ## Contract
//!
//! A source is driven by the engine in strict phases per speculative
//! round: [`DraftSource::propose`] (γ proposals, sampled through the
//! engine's RNG stream), then — after target validation, acceptance
//! scanning, and the *target*-side rollback — one
//! [`DraftSource::finish_round`] carrying the verification feedback
//! ([`RoundFeedback`]): accepted count, per-proposal acceptance
//! probabilities, the target means at every validated prefix (including
//! the rejection point), and the patches actually committed. Between
//! rounds the source's state must equal "committed history only":
//! proposals never leak into the context of a later round unless they
//! were committed (`tests/draft_equivalence.rs`'s proptest invariants).
//! Learning updates therefore *pause* while speculation is in flight and
//! are *flushed* only in `finish_round`, after the rejected suffix has
//! been rolled back — a source can never train on patches that lost the
//! acceptance coin flip and left the sequence.
//!
//! Cost accounting: the engine times `propose`/`finish_round` as draft
//! work, so the [`super::GammaController`]'s measured cost ratio c is
//! per-source automatically — a near-zero-cost `ExtrapolationDraft`
//! measures c ≈ 0 and the speedup curve pushes γ toward its cap.
//!
//! ## Tree rounds
//!
//! The tree engine ([`super::sd_generate_tree`]) asks a source for *k*
//! candidate trajectories per round via [`DraftSource::propose_k`]. The
//! default implementation draws k independent blocks through the *same*
//! engine RNG stream — k σ-perturbed continuations for the closed-form
//! sources, k distinct sample paths for a model-backed source. At
//! `k = 1` the default delegates to [`DraftSource::propose`] verbatim,
//! which is the ground of the k=1 equivalence wall
//! (`tests/tree_equivalence.rs`). After verification the source gets a
//! single [`RoundFeedback`] for the *winning* branch; the between-rounds
//! contract is unchanged (state equals committed history only).
//! Stateful sources override `propose_k` to roll their sessions back
//! between branches ([`ModelDraft`]) or to pause learning on mismatched
//! features ([`AdaptiveResidualDraft`]).

mod adaptive;
mod extrap;
mod model;

pub use adaptive::AdaptiveResidualDraft;
pub use extrap::ExtrapolationDraft;
pub use model::{ModelBatchDraft, ModelDraft};

use anyhow::Result;

use crate::models::{Backend, CacheMode};
use crate::util::rng::Rng;

/// Which draft-source implementation a decode runs with (the config /
/// wire-level selector: `--draft`, JSON `"draft"`, per-request
/// `"draft"`).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum DraftKind {
    /// A second model's decode session (the paper's setup; the default).
    Model,
    /// Draft-free closed-form continuation (linear trend / seasonal
    /// naive) — near-zero draft cost.
    Extrap,
    /// Online-learned residual corrector fitted to verification feedback.
    Adaptive,
}

impl DraftKind {
    /// Wire/CLI name of the kind (`"model"` / `"extrap"` / `"adaptive"`).
    pub fn as_str(&self) -> &'static str {
        match self {
            DraftKind::Model => "model",
            DraftKind::Extrap => "extrap",
            DraftKind::Adaptive => "adaptive",
        }
    }

    /// Parse a wire/CLI name; `None` for unknown spellings.
    pub fn parse(s: &str) -> Option<DraftKind> {
        match s {
            "model" => Some(DraftKind::Model),
            "extrap" | "extrapolation" => Some(DraftKind::Extrap),
            "adaptive" => Some(DraftKind::Adaptive),
            _ => None,
        }
    }

    /// All kinds, in serving-metrics order.
    pub fn all() -> [DraftKind; 3] {
        [DraftKind::Model, DraftKind::Extrap, DraftKind::Adaptive]
    }
}

/// Draft-source configuration carried inside
/// [`super::SpecConfig`] (plain scalars so both stay `Copy`).
#[derive(Clone, Copy, Debug)]
pub struct DraftConfig {
    /// Which source to construct.
    pub kind: DraftKind,
    /// [`ExtrapolationDraft`] mode: `0` = linear-trend continuation,
    /// `k > 0` = seasonal-naive with a period of `k` patches.
    pub period: usize,
    /// [`AdaptiveResidualDraft`] NLMS learning rate, in `(0, 2)` for
    /// stability (normalized step — 2 is the classic divergence bound).
    pub eta: f64,
}

impl Default for DraftConfig {
    fn default() -> Self {
        DraftConfig { kind: DraftKind::Model, period: 0, eta: 0.5 }
    }
}

impl DraftConfig {
    /// Check the knobs are legal (η stability bound, sane period).
    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(
            self.eta > 0.0 && self.eta < 2.0,
            "draft.eta must be in (0, 2) for NLMS stability, got {}",
            self.eta
        );
        anyhow::ensure!(
            self.period <= 4096,
            "draft.period must be <= 4096 patches, got {}",
            self.period
        );
        Ok(())
    }
}

/// One round's proposal block from a source: γ sampled proposals and the
/// γ proposal means they were drawn around (the q-means the acceptance
/// rule needs).
#[derive(Clone, Debug)]
pub struct ProposalBlock {
    /// Sampled proposals `x_i ~ N(mu_q_i, σ²)`, one `[patch]` vector each.
    pub proposals: Vec<Vec<f32>>,
    /// The proposal means `mu_q_i`, aligned with `proposals`.
    pub mu_qs: Vec<Vec<f32>>,
}

/// Verification feedback for one finished speculative round — everything
/// a source may observe (and learn from) about what the target thought of
/// its proposals.
#[derive(Clone, Copy, Debug)]
pub struct RoundFeedback<'a> {
    /// Proposals produced this round (the block length γ fed to the
    /// target; in a lockstep batch this is the *round* γ, which may
    /// exceed the sequence's own scanned prefix).
    pub gamma: usize,
    /// Consecutive proposals accepted before rejection (run length).
    pub accepted: usize,
    /// Per-proposal acceptance probabilities evaluated by the scan
    /// (includes the rejected proposal's α when the round ended early).
    pub alphas: &'a [f64],
    /// Target means at every validated prefix, flat `[gamma+1, patch]`:
    /// row `i` is the target's prediction at position `i` given the
    /// committed context plus proposals `0..i` — row `accepted` is the
    /// mean *at the rejection point* (or the bonus mean when everything
    /// was accepted). This is the online-learning signal: it costs zero
    /// extra target forwards.
    pub target_means: &'a [f32],
    /// Patches committed to the sequence this round *before* the final
    /// patch, flat `[accepted, patch]` (the accepted samples under
    /// `Emission::Sampled`, the accepted draft means under
    /// `Emission::Mean`).
    pub committed: &'a [f32],
    /// The round's final bonus/fallback/residual patch, flat `[patch]`.
    pub final_patch: &'a [f32],
    /// True when `committed` is the accepted proposals verbatim
    /// (sampled emission) — lets [`ModelDraft`] keep its session's
    /// accepted prefix in place instead of rebuilding, preserving the
    /// pre-refactor session-op sequence exactly.
    pub sampled: bool,
}

/// A proposal source for speculative decoding (the "q side" of the
/// accept/reject rule). See the module docs for the phase contract.
pub trait DraftSource {
    /// Which implementation this is (metrics/group labels).
    fn kind(&self) -> DraftKind;
    /// Values per patch token.
    fn patch(&self) -> usize;
    /// (Re)anchor the source on a fresh committed history (flat
    /// `[n_hist, patch]`). Per-decode context state resets; *learned*
    /// state (e.g. the adaptive head) persists — that is how a
    /// long-lived source adapts across a request stream.
    fn begin(&mut self, history: &[f32], n_hist: usize, cache: CacheMode) -> Result<()>;
    /// Patches currently in the committed context.
    fn len(&self) -> usize;
    /// Whether the committed context holds no patches.
    fn is_empty(&self) -> bool {
        self.len() == 0
    }
    /// Context cap this source imposes on the joint decode window
    /// (`usize::MAX` for closed-form sources with no backend).
    fn max_ctx(&self) -> usize;
    /// The committed context tokens (flat `[len, patch]`) —
    /// introspection for tests and invariant checks.
    fn context(&self) -> &[f32];
    /// Produce γ proposals autoregressively: each mean conditions on the
    /// committed history plus the proposals sampled so far; each proposal
    /// is drawn `x_i ~ N(mu_q_i, σ²)` through `rng` (exactly one
    /// `fill_normal_around` per proposal, in order — the engine's RNG
    /// stream contract). Must leave the committed context untouched.
    fn propose(&mut self, gamma: usize, sigma: f64, rng: &mut Rng) -> Result<ProposalBlock>;
    /// Produce `k` candidate trajectories for one tree round, all drawn
    /// sequentially through the same `rng` (branch j consumes its normals
    /// after branch j-1's — the tree RNG stream contract). At `k = 1`
    /// this MUST be indistinguishable from one [`DraftSource::propose`]
    /// call: the default delegates, and overrides must preserve that
    /// (the k=1 equivalence wall). The committed context must be left
    /// untouched no matter how many branches were drafted; the winning
    /// branch arrives later through [`DraftSource::finish_round`].
    fn propose_k(
        &mut self,
        gamma: usize,
        k: usize,
        sigma: f64,
        rng: &mut Rng,
    ) -> Result<Vec<ProposalBlock>> {
        anyhow::ensure!(k >= 1, "propose_k needs k >= 1");
        (0..k).map(|_| self.propose(gamma, sigma, rng)).collect()
    }
    /// Absorb one round's verification outcome: commit
    /// `fb.committed + fb.final_patch` to the context and (for learning
    /// sources) fold the target means into the online update. Called
    /// exactly once per `propose`, after the engine's acceptance scan.
    fn finish_round(&mut self, fb: &RoundFeedback<'_>) -> Result<()>;
    /// Commit `k` patches outside a proposal round (the γ = 0 horizon
    /// tail, where the engine runs a plain target AR step).
    fn append(&mut self, patches: &[f32], k: usize) -> Result<()>;
    /// Slide the window from the front so exactly `keep` patches remain
    /// (kept in lockstep with the target session by the engine).
    fn evict_to(&mut self, keep: usize) -> Result<()>;
    /// Online parameter updates applied so far (0 for non-learning
    /// sources). Monotone; decode loops report per-decode deltas.
    fn updates(&self) -> usize {
        0
    }
    /// Snapshot of the source's learned parameters, if it has any
    /// (`None` for non-learning sources). The serving batcher exports
    /// after each decode group and re-imports into the next group's
    /// fresh sources, so online adaptation survives across requests.
    fn export_head(&self) -> Option<Vec<f32>> {
        None
    }
    /// Load a previously exported parameter snapshot. Non-learning
    /// sources ignore it; learning sources error on a wrong-sized head.
    fn import_head(&mut self, head: &[f32]) -> Result<()> {
        let _ = head;
        Ok(())
    }
}

/// Lockstep draft sources for the batched decoder: per-sequence state,
/// batched `propose` over an explicit index set (so a model-backed
/// implementation can share one batched extend across the active set),
/// per-sequence feedback/commit because acceptance lengths diverge.
pub trait BatchDraftSource {
    /// Which implementation this is (metrics/group labels).
    fn kind(&self) -> DraftKind;
    /// Values per patch token.
    fn patch(&self) -> usize;
    /// (Re)anchor on a fresh batch of `(history, n_hist)` tasks.
    fn begin(&mut self, tasks: &[(&[f32], usize)], cache: CacheMode) -> Result<()>;
    /// Sequences in the batch.
    fn batch(&self) -> usize;
    /// Committed context length (patches) of sequence `i`.
    fn len(&self, i: usize) -> usize;
    /// Context cap this source imposes on the joint decode window.
    fn max_ctx(&self) -> usize;
    /// Batched [`DraftSource::propose`]: one [`ProposalBlock`] per entry
    /// of `idx`, sampling sequence `i`'s proposals through `rngs[i]`
    /// (the full per-sequence RNG slab, indexed absolutely).
    fn propose(
        &mut self,
        idx: &[usize],
        gamma: usize,
        sigma: f64,
        rngs: &mut [Rng],
    ) -> Result<Vec<ProposalBlock>>;
    /// Per-sequence [`DraftSource::propose_k`]: `k` candidate blocks for
    /// sequence `i`, drawn branch-after-branch through `rngs[i]`. Same
    /// k=1-delegation contract as the single-stream trait. The lockstep
    /// decoder itself stays k = 1 (tree fan-out is a per-job affair in
    /// the serving batcher), but batch sources expose the capability so
    /// an adapter can host tree decodes without downcasting.
    fn propose_k(
        &mut self,
        i: usize,
        gamma: usize,
        k: usize,
        sigma: f64,
        rngs: &mut [Rng],
    ) -> Result<Vec<ProposalBlock>> {
        anyhow::ensure!(k >= 1, "propose_k needs k >= 1");
        (0..k).map(|_| Ok(self.propose(&[i], gamma, sigma, rngs)?.remove(0))).collect()
    }
    /// Per-sequence [`DraftSource::finish_round`].
    fn finish_round(&mut self, i: usize, fb: &RoundFeedback<'_>) -> Result<()>;
    /// Commit `k` patches to sequence `i` outside a proposal round.
    fn append(&mut self, i: usize, patches: &[f32], k: usize) -> Result<()>;
    /// Slide sequence `i`'s window so exactly `keep` patches remain.
    fn evict_to(&mut self, i: usize, keep: usize) -> Result<()>;
    /// Online updates applied so far by sequence `i`'s source.
    fn updates(&self, i: usize) -> usize {
        let _ = i;
        0
    }
    /// Merged snapshot of the batch's learned parameters (`None` when no
    /// sequence has any). See [`DraftSource::export_head`].
    fn export_head(&self) -> Option<Vec<f32>> {
        None
    }
    /// Seed every sequence's source (present and future — i.e. sources
    /// created by the next [`BatchDraftSource::begin`]) with an exported
    /// parameter snapshot.
    fn import_head(&mut self, head: &[f32]) -> Result<()> {
        let _ = head;
        Ok(())
    }
}

/// Build a single-stream source per `cfg`. The `draft` backend is the
/// proposal model for [`DraftKind::Model`]; draft-free kinds only take
/// its patch size (callers without a second model can use
/// [`make_free_source`]).
pub fn make_source<'a>(
    cfg: &DraftConfig,
    draft: &'a dyn Backend,
) -> Result<Box<dyn DraftSource + 'a>> {
    cfg.validate()?;
    Ok(match cfg.kind {
        DraftKind::Model => Box::new(ModelDraft::new(draft)),
        DraftKind::Extrap => Box::new(ExtrapolationDraft::new(draft.patch(), cfg.period)),
        DraftKind::Adaptive => {
            Box::new(AdaptiveResidualDraft::new(draft.patch(), cfg.eta as f32))
        }
    })
}

/// Build a draft-free source (no second model anywhere): errors on
/// [`DraftKind::Model`], which needs a backend.
pub fn make_free_source(cfg: &DraftConfig, patch: usize) -> Result<Box<dyn DraftSource>> {
    cfg.validate()?;
    Ok(match cfg.kind {
        DraftKind::Model => anyhow::bail!("draft kind 'model' requires a draft backend"),
        DraftKind::Extrap => Box::new(ExtrapolationDraft::new(patch, cfg.period)),
        DraftKind::Adaptive => Box::new(AdaptiveResidualDraft::new(patch, cfg.eta as f32)),
    })
}

/// Build a lockstep batch source per `cfg`: the model kind shares one
/// [`crate::models::BatchDecodeSession`] (keeping the pool-fanned batched
/// draft extends); draft-free kinds get one independent per-sequence
/// source each.
pub fn make_batch_source<'a>(
    cfg: &DraftConfig,
    draft: &'a dyn Backend,
) -> Result<Box<dyn BatchDraftSource + 'a>> {
    cfg.validate()?;
    Ok(match cfg.kind {
        DraftKind::Model => Box::new(ModelBatchDraft::new(draft)),
        _ => Box::new(PerSeqBatchDraft::new(*cfg, draft.patch())),
    })
}

/// [`BatchDraftSource`] adapter holding one independent
/// [`DraftSource`] per sequence — the lockstep flavor of the draft-free
/// kinds (no cross-sequence compute to share, so per-sequence loops are
/// already optimal).
pub struct PerSeqBatchDraft {
    cfg: DraftConfig,
    patch: usize,
    srcs: Vec<Box<dyn DraftSource>>,
    /// Pending parameter snapshot; applied to every source created by
    /// `begin` (cross-request persistence for learning kinds).
    seed_head: Option<Vec<f32>>,
}

impl PerSeqBatchDraft {
    /// Adapter for `cfg` over `patch`-sized tokens; sequences are created
    /// at [`BatchDraftSource::begin`].
    pub fn new(cfg: DraftConfig, patch: usize) -> PerSeqBatchDraft {
        PerSeqBatchDraft { cfg, patch, srcs: Vec::new(), seed_head: None }
    }
}

impl BatchDraftSource for PerSeqBatchDraft {
    fn kind(&self) -> DraftKind {
        self.cfg.kind
    }
    fn patch(&self) -> usize {
        self.patch
    }
    fn begin(&mut self, tasks: &[(&[f32], usize)], cache: CacheMode) -> Result<()> {
        self.srcs.clear();
        for (hist, n_hist) in tasks {
            let mut s = make_free_source(&self.cfg, self.patch)?;
            if let Some(h) = &self.seed_head {
                s.import_head(h)?;
            }
            s.begin(hist, *n_hist, cache)?;
            self.srcs.push(s);
        }
        Ok(())
    }
    fn batch(&self) -> usize {
        self.srcs.len()
    }
    fn len(&self, i: usize) -> usize {
        self.srcs[i].len()
    }
    fn max_ctx(&self) -> usize {
        usize::MAX
    }
    fn propose(
        &mut self,
        idx: &[usize],
        gamma: usize,
        sigma: f64,
        rngs: &mut [Rng],
    ) -> Result<Vec<ProposalBlock>> {
        idx.iter()
            .map(|&i| self.srcs[i].propose(gamma, sigma, &mut rngs[i]))
            .collect()
    }
    fn propose_k(
        &mut self,
        i: usize,
        gamma: usize,
        k: usize,
        sigma: f64,
        rngs: &mut [Rng],
    ) -> Result<Vec<ProposalBlock>> {
        self.srcs[i].propose_k(gamma, k, sigma, &mut rngs[i])
    }
    fn finish_round(&mut self, i: usize, fb: &RoundFeedback<'_>) -> Result<()> {
        self.srcs[i].finish_round(fb)
    }
    fn append(&mut self, i: usize, patches: &[f32], k: usize) -> Result<()> {
        self.srcs[i].append(patches, k)
    }
    fn evict_to(&mut self, i: usize, keep: usize) -> Result<()> {
        self.srcs[i].evict_to(keep)
    }
    fn updates(&self, i: usize) -> usize {
        self.srcs[i].updates()
    }
    /// Elementwise mean of the per-sequence heads — a deterministic
    /// merge (sequence order is fixed) that keeps every stream's
    /// adaptation represented in the snapshot the next group is seeded
    /// with.
    fn export_head(&self) -> Option<Vec<f32>> {
        let heads: Vec<Vec<f32>> =
            self.srcs.iter().filter_map(|s| s.export_head()).collect();
        let first_len = heads.first()?.len();
        let mut mean = vec![0.0f32; first_len];
        let mut n = 0usize;
        for h in &heads {
            if h.len() != first_len {
                continue;
            }
            for (m, v) in mean.iter_mut().zip(h) {
                *m += v;
            }
            n += 1;
        }
        for m in &mut mean {
            *m /= n as f32;
        }
        Some(mean)
    }
    fn import_head(&mut self, head: &[f32]) -> Result<()> {
        for s in &mut self.srcs {
            s.import_head(head)?;
        }
        self.seed_head = Some(head.to_vec());
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_roundtrips_through_names() {
        for k in DraftKind::all() {
            assert_eq!(DraftKind::parse(k.as_str()), Some(k));
        }
        assert_eq!(DraftKind::parse("warp"), None);
        assert_eq!(DraftKind::parse("extrapolation"), Some(DraftKind::Extrap));
    }

    #[test]
    fn config_validation() {
        DraftConfig::default().validate().unwrap();
        let mut c = DraftConfig::default();
        c.eta = 0.0;
        assert!(c.validate().is_err());
        c.eta = 2.0;
        assert!(c.validate().is_err());
        let mut c = DraftConfig::default();
        c.period = 5000;
        assert!(c.validate().is_err());
    }

    #[test]
    fn per_seq_batch_head_seeds_and_merges() {
        use crate::models::CacheMode;
        use crate::util::rng::Rng;
        let cfg = DraftConfig { kind: DraftKind::Adaptive, ..DraftConfig::default() };
        let mut batch = PerSeqBatchDraft::new(cfg, 1);
        let h1 = [0.5f32];
        let h2 = [0.2f32, 0.4];
        let tasks: Vec<(&[f32], usize)> = vec![(&h1, 1), (&h2, 2)];
        batch.begin(&tasks, CacheMode::Off).unwrap();
        // Drive one round on each sequence with different targets so the
        // per-sequence heads diverge.
        let mut rngs = vec![Rng::new(1), Rng::new(2)];
        let blocks = batch.propose(&[0, 1], 2, 0.5, &mut rngs).unwrap();
        for (i, tm) in [(0usize, [0.9f32; 3]), (1usize, [-0.9f32; 3])] {
            let committed: Vec<f32> =
                blocks[i].proposals.iter().flatten().copied().collect();
            batch
                .finish_round(
                    i,
                    &RoundFeedback {
                        gamma: 2,
                        accepted: 2,
                        alphas: &[1.0, 1.0],
                        target_means: &tm,
                        committed: &committed,
                        final_patch: &[0.0],
                        sampled: true,
                    },
                )
                .unwrap();
            assert!(batch.updates(i) > 0);
        }
        let head = batch.export_head().expect("adaptive batch exports a merged head");
        assert_eq!(head.len(), 1 * 2, "[patch, patch+1] head for patch 1");
        // Re-begin with the head imported: fresh sources are seeded.
        let mut next = PerSeqBatchDraft::new(cfg, 1);
        next.import_head(&head).unwrap();
        next.begin(&tasks, CacheMode::Off).unwrap();
        assert_eq!(next.srcs[0].export_head().unwrap(), head);
        assert_eq!(next.srcs[1].export_head().unwrap(), head);
        // Non-learning kinds export nothing.
        let ecfg = DraftConfig { kind: DraftKind::Extrap, ..DraftConfig::default() };
        let mut eb = PerSeqBatchDraft::new(ecfg, 1);
        eb.begin(&tasks, CacheMode::Off).unwrap();
        assert!(eb.export_head().is_none());
    }

    #[test]
    fn free_source_rejects_model_kind() {
        let cfg = DraftConfig::default(); // kind: Model
        assert!(make_free_source(&cfg, 4).is_err());
        let cfg = DraftConfig { kind: DraftKind::Extrap, ..DraftConfig::default() };
        assert!(make_free_source(&cfg, 4).is_ok());
    }
}
