//! Online-adapted draft: a lightweight residual head fitted to the
//! target's verification outputs, round by round.
//!
//! Online Speculative Decoding (Liu et al.) showed the draft should
//! *learn from verification*: every speculative round already pays for a
//! target pass over all γ+1 prefix conditionals, so the target's means at
//! those positions are free training signal. This source predicts
//!
//! ```text
//! mu_q(next) = x_last + R · [x_last; 1]
//! ```
//!
//! — naive persistence plus a learned linear residual `R ∈ R^{p×(p+1)}`,
//! updated by normalized LMS against the observed target means. `R`
//! opens at zero (a pure naive-persistence draft) and converges to the
//! target's local linear response; under regime drift it re-converges
//! within a handful of rounds, pulling the acceptance rate α back up with
//! **zero extra target forwards** — the knob the adaptive γ controller
//! measures drift with but cannot itself turn.
//!
//! Update discipline ("pause/flush on rollback"): features are captured
//! while proposals are in flight, but the NLMS step runs only in
//! [`DraftSource::finish_round`], *after* the engine has resolved the
//! acceptance scan and rolled the rejected suffix back — the head trains
//! exclusively on positions the target actually validated (accepted
//! prefix + the rejection point + the bonus position), never on patches
//! that silently left the sequence. Updates are deterministic: same
//! seed, same stream → the same head, bit for bit (pinned by the
//! proptest invariants in `tests/draft_equivalence.rs`).

use anyhow::Result;

use super::{DraftKind, DraftSource, ProposalBlock, RoundFeedback};
use crate::models::CacheMode;
use crate::util::rng::Rng;

/// Online-learned residual draft head (see module docs). Per-decode
/// context state resets at [`DraftSource::begin`]; the learned residual
/// head `R` persists — that is what makes a long-lived source adapt
/// across a request stream.
pub struct AdaptiveResidualDraft {
    patch: usize,
    /// NLMS step size in (0, 2).
    eta: f32,
    /// Residual head, row-major `[patch, patch + 1]` (last column is the
    /// bias term).
    r: Vec<f32>,
    /// Committed context, flat `[len, patch]`.
    ctx: Vec<f32>,
    /// Features captured during the in-flight round, one `[patch + 1]`
    /// vector per validated position `0 ..= γ` (position i's feature is
    /// the patch the target conditioned on last when predicting i).
    feats: Vec<Vec<f32>>,
    updates: usize,
}

impl AdaptiveResidualDraft {
    /// Fresh head (R = 0 → naive persistence) over `patch`-sized tokens
    /// with NLMS rate `eta`.
    pub fn new(patch: usize, eta: f32) -> AdaptiveResidualDraft {
        assert!(patch >= 1, "patch must be >= 1");
        assert!(eta > 0.0 && eta < 2.0, "eta must be in (0, 2)");
        AdaptiveResidualDraft {
            patch,
            eta,
            r: vec![0.0; patch * (patch + 1)],
            ctx: Vec::new(),
            feats: Vec::new(),
            updates: 0,
        }
    }

    /// The learned residual head, row-major `[patch, patch + 1]`
    /// (introspection for tests and determinism checks).
    pub fn head(&self) -> &[f32] {
        &self.r
    }

    /// Feature vector for predicting the patch after `last`: `[last; 1]`.
    fn features(last: &[f32]) -> Vec<f32> {
        let mut u = last.to_vec();
        u.push(1.0);
        u
    }

    /// Head prediction given a feature vector: persistence + residual.
    fn predict(&self, u: &[f32]) -> Vec<f32> {
        let p = self.patch;
        let f = p + 1;
        (0..p)
            .map(|j| {
                let row = &self.r[j * f..(j + 1) * f];
                let resid: f32 = row.iter().zip(u).map(|(w, v)| w * v).sum();
                u[j] + resid
            })
            .collect()
    }

    /// One NLMS step toward `target` on feature `u`.
    fn learn(&mut self, u: &[f32], target: &[f32]) {
        let p = self.patch;
        let f = p + 1;
        let pred = self.predict(u);
        let norm: f32 = u.iter().map(|v| v * v).sum::<f32>() + 1e-6;
        let g = self.eta / norm;
        for j in 0..p {
            let e = target[j] - pred[j];
            let row = &mut self.r[j * f..(j + 1) * f];
            for (w, v) in row.iter_mut().zip(u) {
                *w += g * e * v;
            }
        }
        self.updates += 1;
    }
}

impl DraftSource for AdaptiveResidualDraft {
    fn kind(&self) -> DraftKind {
        DraftKind::Adaptive
    }
    fn patch(&self) -> usize {
        self.patch
    }
    fn begin(&mut self, history: &[f32], n_hist: usize, _cache: CacheMode) -> Result<()> {
        let p = self.patch;
        anyhow::ensure!(n_hist >= 1, "source needs at least one history patch");
        anyhow::ensure!(history.len() >= n_hist * p, "history too short");
        self.ctx.clear();
        self.ctx.extend_from_slice(&history[..n_hist * p]);
        self.feats.clear();
        Ok(())
    }
    fn len(&self) -> usize {
        self.ctx.len() / self.patch
    }
    fn max_ctx(&self) -> usize {
        usize::MAX
    }
    fn context(&self) -> &[f32] {
        &self.ctx
    }

    fn propose(&mut self, gamma: usize, sigma: f64, rng: &mut Rng) -> Result<ProposalBlock> {
        let p = self.patch;
        anyhow::ensure!(!self.ctx.is_empty(), "propose before begin()");
        let mut proposals = Vec::with_capacity(gamma);
        let mut mu_qs = Vec::with_capacity(gamma);
        self.feats.clear();
        // Position i conditions on the previous patch: the context tip
        // for i = 0, proposal i-1 after. The same features feed the
        // eventual NLMS update — they are exactly what the target
        // conditioned on last during validation (the proposals *were*
        // extended into the target session).
        let mut last = self.ctx[self.ctx.len() - p..].to_vec();
        for _ in 0..gamma {
            let u = Self::features(&last);
            let mu = self.predict(&u);
            self.feats.push(u);
            let mut x = vec![0.0f32; p];
            rng.fill_normal_around(&mu, sigma as f32, &mut x);
            last = x.clone();
            proposals.push(x);
            mu_qs.push(mu);
        }
        // The bonus position γ conditions on proposal γ-1.
        self.feats.push(Self::features(&last));
        Ok(ProposalBlock { proposals, mu_qs })
    }

    fn propose_k(
        &mut self,
        gamma: usize,
        k: usize,
        sigma: f64,
        rng: &mut Rng,
    ) -> Result<Vec<ProposalBlock>> {
        anyhow::ensure!(k >= 1, "propose_k needs k >= 1");
        if k == 1 {
            // The k=1 equivalence wall: plain propose, features armed.
            return Ok(vec![self.propose(gamma, sigma, rng)?]);
        }
        // k σ-perturbed branches off the same committed tip (propose is
        // context-neutral here, so sequential calls fork naturally). The
        // captured features belong to the *last* branch only, which need
        // not be the winner — so learning pauses on tree rounds: clear
        // the feature buffer and let finish_round's `.min(feats.len())`
        // train on zero pairs. Commit bookkeeping still runs.
        let blocks = (0..k)
            .map(|_| self.propose(gamma, sigma, rng))
            .collect::<Result<Vec<_>>>()?;
        self.feats.clear();
        Ok(blocks)
    }

    fn finish_round(&mut self, fb: &RoundFeedback<'_>) -> Result<()> {
        let p = self.patch;
        anyhow::ensure!(
            fb.target_means.len() >= (fb.gamma + 1) * p,
            "target means shorter than gamma + 1 rows"
        );
        // Flush the paused updates: one NLMS step per *validated*
        // position — the accepted prefix, plus the rejection point (or
        // the bonus position when everything was accepted). Positions
        // past the rejection were conditioned on patches that are now
        // rolled back; their target rows are still well-defined function
        // samples, but only the surviving prefix reflects the sequence
        // the stream will actually continue from, so training stops at
        // the rejection boundary.
        let feats = std::mem::take(&mut self.feats);
        let n_pairs = (fb.accepted + 1).min(fb.gamma + 1).min(feats.len());
        for (i, u) in feats.iter().enumerate().take(n_pairs) {
            let y = fb.target_means[i * p..(i + 1) * p].to_vec();
            self.learn(u, &y);
        }
        self.ctx.extend_from_slice(fb.committed);
        self.ctx.extend_from_slice(fb.final_patch);
        Ok(())
    }

    fn append(&mut self, patches: &[f32], k: usize) -> Result<()> {
        let p = self.patch;
        anyhow::ensure!(patches.len() >= k * p, "patch buffer too short");
        self.ctx.extend_from_slice(&patches[..k * p]);
        Ok(())
    }

    fn evict_to(&mut self, keep: usize) -> Result<()> {
        let p = self.patch;
        let n = self.len();
        anyhow::ensure!(keep >= 1 && keep <= n, "bad evict target {keep} for len {n}");
        self.ctx.drain(..(n - keep) * p);
        Ok(())
    }

    fn updates(&self) -> usize {
        self.updates
    }

    fn export_head(&self) -> Option<Vec<f32>> {
        Some(self.r.clone())
    }

    fn import_head(&mut self, head: &[f32]) -> Result<()> {
        anyhow::ensure!(
            head.len() == self.r.len(),
            "residual head size {} != expected {} (patch {})",
            head.len(),
            self.r.len(),
            self.patch
        );
        self.r.copy_from_slice(head);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Drive rounds against a known linear target y = a·x + b and check
    /// the head's prediction error shrinks toward zero.
    #[test]
    fn nlms_converges_to_linear_target() {
        let p = 2;
        let (a, b) = (0.6f32, 0.4f32);
        let mut src = AdaptiveResidualDraft::new(p, 0.5);
        src.begin(&[0.3, -0.2], 1, CacheMode::Off).unwrap();
        let mut rng = Rng::new(11);
        let mut last_err = f32::INFINITY;
        for round in 0..60 {
            let gamma = 3;
            let block = src.propose(gamma, 0.5, &mut rng).unwrap();
            // Target means at each validated position: a·prev + b where
            // prev is the patch the position conditioned on.
            let mut prevs: Vec<Vec<f32>> =
                vec![src.context()[src.context().len() - p..].to_vec()];
            for x in &block.proposals {
                prevs.push(x.clone());
            }
            let mut tm = Vec::with_capacity((gamma + 1) * p);
            for prev in &prevs {
                tm.extend(prev.iter().map(|v| a * v + b));
            }
            // All accepted; commit the proposals + the bonus mean.
            let committed: Vec<f32> = block.proposals.iter().flatten().copied().collect();
            let fina = tm[gamma * p..(gamma + 1) * p].to_vec();
            src.finish_round(&RoundFeedback {
                gamma,
                accepted: gamma,
                alphas: &[1.0; 3],
                target_means: &tm,
                committed: &committed,
                final_patch: &fina,
                sampled: true,
            })
            .unwrap();
            if round == 59 {
                // Measure current prediction error on a probe feature.
                let u = AdaptiveResidualDraft::features(&[0.7, -0.1]);
                let pred = src.predict(&u);
                let want = [a * 0.7 + b, a * -0.1 + b];
                last_err = pred
                    .iter()
                    .zip(&want)
                    .map(|(x, y)| (x - y).abs())
                    .fold(0.0, f32::max);
            }
        }
        assert!(src.updates() >= 60, "updates {}", src.updates());
        assert!(last_err < 0.05, "head did not converge: err {last_err}");
    }

    #[test]
    fn updates_pause_during_speculation_and_stop_at_rejection() {
        let p = 1;
        let mut src = AdaptiveResidualDraft::new(p, 0.5);
        src.begin(&[1.0], 1, CacheMode::Off).unwrap();
        let mut rng = Rng::new(5);
        let _ = src.propose(4, 0.5, &mut rng).unwrap();
        assert_eq!(src.updates(), 0, "no updates while proposals are in flight");
        src.finish_round(&RoundFeedback {
            gamma: 4,
            accepted: 1, // rejected at position 1
            alphas: &[1.0, 0.0],
            target_means: &[0.5, 0.6, 0.7, 0.8, 0.9],
            committed: &[0.5],
            final_patch: &[0.6],
            sampled: true,
        })
        .unwrap();
        // accepted + 1 = 2 validated positions trained on, not gamma + 1.
        assert_eq!(src.updates(), 2);
        // Context = history + committed + final only.
        assert_eq!(src.context(), &[1.0, 0.5, 0.6]);
    }

    #[test]
    fn tree_rounds_pause_learning_but_commit() {
        let p = 1;
        let mut src = AdaptiveResidualDraft::new(p, 0.5);
        src.begin(&[1.0], 1, CacheMode::Off).unwrap();
        let mut rng = Rng::new(8);
        let blocks = src.propose_k(2, 3, 0.5, &mut rng).unwrap();
        assert_eq!(blocks.len(), 3);
        // All branches fork the same committed tip.
        assert_eq!(blocks[0].mu_qs[0], blocks[1].mu_qs[0]);
        assert_eq!(blocks[1].mu_qs[0], blocks[2].mu_qs[0]);
        let committed: Vec<f32> = blocks[1].proposals.iter().flatten().copied().collect();
        src.finish_round(&RoundFeedback {
            gamma: 2,
            accepted: 2,
            alphas: &[1.0, 1.0],
            target_means: &[0.3, 0.4, 0.5],
            committed: &committed,
            final_patch: &[0.5],
            sampled: true,
        })
        .unwrap();
        assert_eq!(src.updates(), 0, "tree rounds must not train on mismatched feats");
        assert_eq!(src.len(), 4, "context still commits winner + final");
        // A following k = 1 round learns again.
        let _ = src.propose_k(2, 1, 0.5, &mut rng).unwrap();
        src.finish_round(&RoundFeedback {
            gamma: 2,
            accepted: 0,
            alphas: &[0.0],
            target_means: &[0.3, 0.4, 0.5],
            committed: &[],
            final_patch: &[0.3],
            sampled: true,
        })
        .unwrap();
        assert_eq!(src.updates(), 1);
    }

    #[test]
    fn head_export_import_roundtrip() {
        let mut a = AdaptiveResidualDraft::new(2, 0.5);
        a.begin(&[0.1, 0.2], 1, CacheMode::Off).unwrap();
        let mut rng = Rng::new(3);
        let block = a.propose(2, 0.5, &mut rng).unwrap();
        let committed: Vec<f32> = block.proposals.iter().flatten().copied().collect();
        a.finish_round(&RoundFeedback {
            gamma: 2,
            accepted: 2,
            alphas: &[1.0, 1.0],
            target_means: &[0.4; 6],
            committed: &committed,
            final_patch: &[0.0, 0.0],
            sampled: true,
        })
        .unwrap();
        let head = a.export_head().expect("learning source exports");
        assert!(head.iter().any(|v| *v != 0.0), "trained head must be nonzero");
        // A fresh source seeded with the head predicts identically.
        let mut b = AdaptiveResidualDraft::new(2, 0.5);
        b.import_head(&head).unwrap();
        assert_eq!(b.head(), head.as_slice());
        // Wrong-sized head is rejected.
        assert!(b.import_head(&[0.0; 3]).is_err());
    }

    #[test]
    fn deterministic_under_fixed_seed() {
        let run = || {
            let mut src = AdaptiveResidualDraft::new(2, 0.5);
            src.begin(&[0.1, 0.2, 0.3, 0.4], 2, CacheMode::Off).unwrap();
            let mut rng = Rng::new(42);
            for _ in 0..10 {
                let block = src.propose(2, 0.4, &mut rng).unwrap();
                let committed: Vec<f32> =
                    block.proposals.iter().flatten().copied().collect();
                src.finish_round(&RoundFeedback {
                    gamma: 2,
                    accepted: 2,
                    alphas: &[1.0, 1.0],
                    target_means: &[0.1; 6],
                    committed: &committed,
                    final_patch: &[0.0, 0.0],
                    sampled: true,
                })
                .unwrap();
            }
            (src.head().to_vec(), src.context().to_vec(), src.updates())
        };
        let (h1, c1, u1) = run();
        let (h2, c2, u2) = run();
        assert_eq!(h1, h2, "head drifted under identical streams");
        assert_eq!(c1, c2);
        assert_eq!(u1, u2);
    }
}
