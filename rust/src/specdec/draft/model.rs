//! The classic two-model draft: a second backend's decode session,
//! wrapped as a [`DraftSource`]. This is the *equivalence baseline* of
//! the draft-source subsystem — driving a decode through [`ModelDraft`]
//! performs the exact session-operation sequence (and consumes the exact
//! RNG stream) of the pre-refactor engine, so fixed-draft decoding stays
//! bit-identical (pinned by `tests/draft_equivalence.rs`).

use anyhow::Result;

use super::{BatchDraftSource, DraftKind, DraftSource, ProposalBlock, RoundFeedback};
use crate::models::{
    begin_batch_session, begin_session, Backend, BatchDecodeSession, CacheMode, DecodeSession,
};
use crate::util::rng::Rng;

/// Draft source backed by a model's [`DecodeSession`] (KV-cached when the
/// backend supports it and the decode runs with [`CacheMode::On`]).
pub struct ModelDraft<'a> {
    backend: &'a dyn Backend,
    sess: Option<Box<dyn DecodeSession + 'a>>,
    /// The in-flight round's block length and final proposal (γ−1), the
    /// only proposal `finish_round` ever needs (the sampled-emission
    /// all-accepted path re-appends it — it never entered the session
    /// during drafting). One patch, not the whole block: this sits on
    /// the hot decode loop.
    last_gamma: usize,
    last_proposal: Vec<f32>,
    /// True while a k > 1 tree round is in flight: `propose_k` rolled
    /// the session back to the committed prefix after every branch, so
    /// `finish_round` rebuilds from the winner's feedback instead of
    /// trimming in-session proposals.
    tree_round: bool,
}

impl<'a> ModelDraft<'a> {
    /// Source proposing from `backend`'s decode sessions.
    pub fn new(backend: &'a dyn Backend) -> ModelDraft<'a> {
        ModelDraft {
            backend,
            sess: None,
            last_gamma: 0,
            last_proposal: Vec::new(),
            tree_round: false,
        }
    }

    fn sess(&mut self) -> Result<&mut Box<dyn DecodeSession + 'a>> {
        self.sess
            .as_mut()
            .ok_or_else(|| anyhow::anyhow!("ModelDraft used before begin()"))
    }
}

impl DraftSource for ModelDraft<'_> {
    fn kind(&self) -> DraftKind {
        DraftKind::Model
    }
    fn patch(&self) -> usize {
        self.backend.patch()
    }
    fn begin(&mut self, history: &[f32], n_hist: usize, cache: CacheMode) -> Result<()> {
        self.sess = Some(begin_session(self.backend, cache, history, n_hist)?);
        self.last_gamma = 0;
        self.last_proposal.clear();
        self.tree_round = false;
        Ok(())
    }
    fn len(&self) -> usize {
        self.sess.as_ref().map(|s| s.len()).unwrap_or(0)
    }
    fn max_ctx(&self) -> usize {
        self.backend.max_ctx()
    }
    fn context(&self) -> &[f32] {
        self.sess.as_ref().map(|s| s.context()).unwrap_or(&[])
    }

    fn propose(&mut self, gamma: usize, sigma: f64, rng: &mut Rng) -> Result<ProposalBlock> {
        let p = self.backend.patch();
        let sess = self.sess()?;
        // Verbatim pre-refactor drafting loop (Alg. 1 l.1-3): the first
        // mean comes off the session tip; each proposal i < γ-1 is pushed
        // through `extend` to produce the next mean. Proposal γ-1 is only
        // needed by target validation, so it never enters the draft
        // context (nothing would read its successor mean).
        let mut mu_q = sess.tip_mean()?;
        let mut proposals: Vec<Vec<f32>> = Vec::with_capacity(gamma);
        let mut mu_qs: Vec<Vec<f32>> = Vec::with_capacity(gamma);
        for i in 0..gamma {
            let mut x = vec![0.0f32; p];
            rng.fill_normal_around(&mu_q, sigma as f32, &mut x);
            proposals.push(x);
            mu_qs.push(mu_q.clone());
            if i + 1 < gamma {
                let rows = sess.extend(proposals.last().unwrap(), 1)?;
                mu_q = rows[p..].to_vec();
            }
        }
        self.last_gamma = gamma;
        self.last_proposal.clear();
        if let Some(x) = proposals.last() {
            self.last_proposal.extend_from_slice(x);
        }
        Ok(ProposalBlock { proposals, mu_qs })
    }

    fn propose_k(
        &mut self,
        gamma: usize,
        k: usize,
        sigma: f64,
        rng: &mut Rng,
    ) -> Result<Vec<ProposalBlock>> {
        anyhow::ensure!(k >= 1, "propose_k needs k >= 1");
        if k == 1 {
            // The k=1 equivalence wall: one plain propose, session left
            // holding its γ-1 proposals exactly as the classic path does.
            return Ok(vec![self.propose(gamma, sigma, rng)?]);
        }
        // Each branch is a fork of the committed prefix: draft it with
        // the verbatim propose loop, then roll the session back so the
        // next branch (and the winner commit) starts from the same KV
        // state. Branches consume the RNG stream in order, so branch 0's
        // samples are exactly the k=1 samples.
        let mut blocks = Vec::with_capacity(k);
        for _ in 0..k {
            blocks.push(self.propose(gamma, sigma, rng)?);
            self.sess()?.rollback(gamma - 1)?;
        }
        self.tree_round = true;
        Ok(blocks)
    }

    fn finish_round(&mut self, fb: &RoundFeedback<'_>) -> Result<()> {
        let gamma = fb.gamma;
        anyhow::ensure!(gamma >= 1, "finish_round on an empty proposal block");
        anyhow::ensure!(self.last_gamma == gamma, "feedback gamma mismatch");
        // Split the borrow: the retained final proposal is read while
        // the session is mutated.
        let last = std::mem::take(&mut self.last_proposal);
        self.last_gamma = 0;
        if self.tree_round {
            // Tree round: the session was rolled back to the committed
            // prefix after every branch, so the winner's patches are
            // rebuilt from feedback alone (sampled and mean emission
            // alike — `fb.committed` is whatever the engine emitted).
            self.tree_round = false;
            let sess = self.sess()?;
            if fb.accepted > 0 {
                sess.append(fb.committed, fb.accepted)?;
            }
            sess.append(fb.final_patch, 1)?;
            return Ok(());
        }
        let sess = self.sess()?;
        if fb.sampled {
            // The committed patches are the accepted proposals verbatim
            // and the session already holds proposals 0..γ-1: keep the
            // accepted prefix, re-append proposal γ-1 if everything was
            // accepted (it never entered the context during drafting).
            let keep_d = fb.accepted.min(gamma - 1);
            sess.rollback((gamma - 1) - keep_d)?;
            if fb.accepted > keep_d {
                sess.append(&last, 1)?;
            }
        } else {
            // Mean emission: the context must carry the emitted draft
            // means, not the sampled proposals — rewind everything and
            // re-append the committed means.
            sess.rollback(gamma - 1)?;
            if fb.accepted > 0 {
                sess.append(fb.committed, fb.accepted)?;
            }
        }
        sess.append(fb.final_patch, 1)?;
        Ok(())
    }

    fn append(&mut self, patches: &[f32], k: usize) -> Result<()> {
        self.sess()?.append(patches, k)
    }

    fn evict_to(&mut self, keep: usize) -> Result<()> {
        self.sess()?.evict_to(keep)
    }
}

/// Lockstep flavor of [`ModelDraft`]: one shared
/// [`BatchDecodeSession`], so the γ per-round draft extends stay batched
/// (and keep fanning across the worker pool on the native backend).
/// Performs the exact per-sequence session-op sequence of the
/// pre-refactor batched engine.
pub struct ModelBatchDraft<'a> {
    backend: &'a dyn Backend,
    sess: Option<Box<dyn BatchDecodeSession + 'a>>,
    /// Per-sequence in-flight round state: `(gamma, final proposal)` —
    /// the only proposal `finish_round` ever needs (see [`ModelDraft`]).
    last: Vec<(usize, Vec<f32>)>,
    /// Per-sequence tree-round flags (same contract as `ModelDraft`'s
    /// `tree_round`: branches were rolled back, rebuild from feedback).
    tree: Vec<bool>,
}

impl<'a> ModelBatchDraft<'a> {
    /// Lockstep source proposing from `backend`'s batched sessions.
    pub fn new(backend: &'a dyn Backend) -> ModelBatchDraft<'a> {
        ModelBatchDraft { backend, sess: None, last: Vec::new(), tree: Vec::new() }
    }

    fn sess(&mut self) -> Result<&mut Box<dyn BatchDecodeSession + 'a>> {
        self.sess
            .as_mut()
            .ok_or_else(|| anyhow::anyhow!("ModelBatchDraft used before begin()"))
    }
}

impl BatchDraftSource for ModelBatchDraft<'_> {
    fn kind(&self) -> DraftKind {
        DraftKind::Model
    }
    fn patch(&self) -> usize {
        self.backend.patch()
    }
    fn begin(&mut self, tasks: &[(&[f32], usize)], cache: CacheMode) -> Result<()> {
        self.sess = Some(begin_batch_session(self.backend, cache, tasks)?);
        self.last = vec![(0, Vec::new()); tasks.len()];
        self.tree = vec![false; tasks.len()];
        Ok(())
    }
    fn batch(&self) -> usize {
        self.last.len()
    }
    fn len(&self, i: usize) -> usize {
        self.sess.as_ref().map(|s| s.len(i)).unwrap_or(0)
    }
    fn max_ctx(&self) -> usize {
        self.backend.max_ctx()
    }

    fn propose(
        &mut self,
        idx: &[usize],
        gamma: usize,
        sigma: f64,
        rngs: &mut [Rng],
    ) -> Result<Vec<ProposalBlock>> {
        let p = self.backend.patch();
        let a = idx.len();
        let sess = self.sess()?;
        // Verbatim pre-refactor batched drafting: tip means, then γ-1
        // batched extends (the last proposal only feeds target
        // validation, never the draft context). Per-sequence RNG streams
        // are independent, so the per-step interleaving preserves each
        // sequence's exact sample order.
        let mut mu_q = sess.tip_means(idx)?; // [a, p]
        let mut blocks: Vec<ProposalBlock> = (0..a)
            .map(|_| ProposalBlock {
                proposals: Vec::with_capacity(gamma),
                mu_qs: Vec::with_capacity(gamma),
            })
            .collect();
        for step in 0..gamma {
            let mut xs = vec![0.0f32; a * p];
            for (ai, &i) in idx.iter().enumerate() {
                let mq = &mu_q[ai * p..(ai + 1) * p];
                rngs[i].fill_normal_around(mq, sigma as f32, &mut xs[ai * p..(ai + 1) * p]);
                blocks[ai].proposals.push(xs[ai * p..(ai + 1) * p].to_vec());
                blocks[ai].mu_qs.push(mq.to_vec());
            }
            if step + 1 < gamma {
                let rows = sess.extend(idx, &xs, 1)?; // [a, 2, p]
                for ai in 0..a {
                    mu_q[ai * p..(ai + 1) * p]
                        .copy_from_slice(&rows[ai * 2 * p + p..(ai + 1) * 2 * p]);
                }
            }
        }
        for (ai, &i) in idx.iter().enumerate() {
            let (g, buf) = &mut self.last[i];
            *g = gamma;
            buf.clear();
            if let Some(x) = blocks[ai].proposals.last() {
                buf.extend_from_slice(x);
            }
        }
        Ok(blocks)
    }

    fn propose_k(
        &mut self,
        i: usize,
        gamma: usize,
        k: usize,
        sigma: f64,
        rngs: &mut [Rng],
    ) -> Result<Vec<ProposalBlock>> {
        anyhow::ensure!(k >= 1, "propose_k needs k >= 1");
        if k == 1 {
            return Ok(self.propose(&[i], gamma, sigma, rngs)?);
        }
        let mut blocks = Vec::with_capacity(k);
        for _ in 0..k {
            blocks.push(self.propose(&[i], gamma, sigma, rngs)?.remove(0));
            self.sess()?.rollback(i, gamma - 1)?;
        }
        self.tree[i] = true;
        Ok(blocks)
    }

    fn finish_round(&mut self, i: usize, fb: &RoundFeedback<'_>) -> Result<()> {
        let gamma = fb.gamma;
        anyhow::ensure!(gamma >= 1, "finish_round on an empty proposal block");
        anyhow::ensure!(self.last[i].0 == gamma, "feedback gamma mismatch for seq {i}");
        let last = std::mem::take(&mut self.last[i].1);
        self.last[i].0 = 0;
        if self.tree[i] {
            self.tree[i] = false;
            let sess = self.sess()?;
            if fb.accepted > 0 {
                sess.append(i, fb.committed, fb.accepted)?;
            }
            sess.append(i, fb.final_patch, 1)?;
            return Ok(());
        }
        let sess = self.sess()?;
        if fb.sampled {
            let keep_d = fb.accepted.min(gamma - 1);
            sess.rollback(i, (gamma - 1) - keep_d)?;
            if fb.accepted > keep_d {
                sess.append(i, &last, 1)?;
            }
        } else {
            sess.rollback(i, gamma - 1)?;
            if fb.accepted > 0 {
                sess.append(i, fb.committed, fb.accepted)?;
            }
        }
        sess.append(i, fb.final_patch, 1)?;
        Ok(())
    }

    fn append(&mut self, i: usize, patches: &[f32], k: usize) -> Result<()> {
        self.sess()?.append(i, patches, k)
    }

    fn evict_to(&mut self, i: usize, keep: usize) -> Result<()> {
        self.sess()?.evict_to(i, keep)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::AnalyticBackend;

    #[test]
    fn propose_matches_session_semantics() {
        // Analytic head: mean(next) = 0.5 * last + 1.0 elementwise. The
        // first proposal mean must be the tip mean; the second must
        // condition on the sampled first proposal.
        let b = AnalyticBackend::new("d", 2, 0.5, 1.0);
        let mut src = ModelDraft::new(&b);
        src.begin(&[2.0, 4.0], 1, CacheMode::On).unwrap();
        let mut rng = Rng::new(7);
        let block = src.propose(2, 0.3, &mut rng).unwrap();
        assert_eq!(block.proposals.len(), 2);
        assert_eq!(block.mu_qs[0], vec![2.0, 3.0]);
        let x0 = &block.proposals[0];
        let want = vec![0.5 * x0[0] + 1.0, 0.5 * x0[1] + 1.0];
        assert_eq!(block.mu_qs[1], want);
        // Context must be committed history + the extended proposals
        // (γ-1 of them) until finish_round rewinds.
        assert_eq!(src.len(), 2);
    }

    #[test]
    fn finish_round_sampled_keeps_accepted_prefix() {
        let b = AnalyticBackend::new("d", 1, 1.0, 0.0);
        let mut src = ModelDraft::new(&b);
        src.begin(&[1.0], 1, CacheMode::On).unwrap();
        let mut rng = Rng::new(1);
        let block = src.propose(3, 0.5, &mut rng).unwrap();
        let committed: Vec<f32> = block.proposals[..2].iter().flatten().copied().collect();
        let fina = [9.0f32];
        src.finish_round(&RoundFeedback {
            gamma: 3,
            accepted: 2,
            alphas: &[1.0, 1.0, 0.1],
            target_means: &[0.0; 4],
            committed: &committed,
            final_patch: &fina,
            sampled: true,
        })
        .unwrap();
        // history(1) + 2 accepted + 1 final.
        assert_eq!(src.len(), 4);
        let ctx = src.context();
        assert_eq!(ctx[1], block.proposals[0][0]);
        assert_eq!(ctx[2], block.proposals[1][0]);
        assert_eq!(ctx[3], 9.0);
    }

    #[test]
    fn finish_round_mean_rebuilds_context() {
        let b = AnalyticBackend::new("d", 1, 1.0, 0.0);
        let mut src = ModelDraft::new(&b);
        src.begin(&[1.0], 1, CacheMode::On).unwrap();
        let mut rng = Rng::new(2);
        let block = src.propose(2, 0.5, &mut rng).unwrap();
        let committed = [block.mu_qs[0][0]];
        src.finish_round(&RoundFeedback {
            gamma: 2,
            accepted: 1,
            alphas: &[1.0, 0.0],
            target_means: &[0.0; 3],
            committed: &committed,
            final_patch: &[5.0],
            sampled: false,
        })
        .unwrap();
        assert_eq!(src.len(), 3);
        let ctx = src.context();
        assert_eq!(ctx[1], block.mu_qs[0][0], "mean emission commits mu_q, not the sample");
        assert_eq!(ctx[2], 5.0);
    }

    #[test]
    fn propose_k1_matches_propose_exactly() {
        let b = AnalyticBackend::new("d", 2, 0.6, 0.2);
        let mut a = ModelDraft::new(&b);
        let mut c = ModelDraft::new(&b);
        a.begin(&[1.0, 2.0], 1, CacheMode::On).unwrap();
        c.begin(&[1.0, 2.0], 1, CacheMode::On).unwrap();
        let mut r1 = Rng::new(42);
        let mut r2 = Rng::new(42);
        let lone = a.propose(3, 0.4, &mut r1).unwrap();
        let tree = c.propose_k(3, 1, 0.4, &mut r2).unwrap();
        assert_eq!(tree.len(), 1);
        assert_eq!(tree[0].proposals, lone.proposals);
        assert_eq!(tree[0].mu_qs, lone.mu_qs);
        // Session state identical too: γ-1 proposals left in place.
        assert_eq!(a.len(), c.len());
        assert_eq!(a.context(), c.context());
    }

    #[test]
    fn propose_k_forks_branches_from_committed_prefix() {
        let b = AnalyticBackend::new("d", 1, 0.5, 1.0);
        let mut src = ModelDraft::new(&b);
        src.begin(&[2.0], 1, CacheMode::On).unwrap();
        let mut rng = Rng::new(9);
        let blocks = src.propose_k(3, 3, 0.4, &mut rng).unwrap();
        assert_eq!(blocks.len(), 3);
        // Every branch conditions its first mean on the same committed
        // tip (branch forking, not chaining).
        let tip = 0.5 * 2.0 + 1.0;
        for bl in &blocks {
            assert_eq!(bl.mu_qs[0], vec![tip]);
            // ...and its second mean on its *own* first sample.
            assert_eq!(bl.mu_qs[1], vec![0.5 * bl.proposals[0][0] + 1.0]);
        }
        // Branches differ (distinct RNG draws).
        assert_ne!(blocks[0].proposals[0], blocks[1].proposals[0]);
        // Committed context untouched after drafting all branches.
        assert_eq!(src.len(), 1);
        assert_eq!(src.context(), &[2.0]);
        // finish_round rebuilds the winner (say branch 1, 2 accepted).
        let committed: Vec<f32> =
            blocks[1].proposals[..2].iter().flatten().copied().collect();
        src.finish_round(&RoundFeedback {
            gamma: 3,
            accepted: 2,
            alphas: &[1.0, 1.0, 0.0],
            target_means: &[0.0; 4],
            committed: &committed,
            final_patch: &[7.0],
            sampled: true,
        })
        .unwrap();
        assert_eq!(src.len(), 4);
        let ctx = src.context();
        assert_eq!(ctx[1], blocks[1].proposals[0][0]);
        assert_eq!(ctx[2], blocks[1].proposals[1][0]);
        assert_eq!(ctx[3], 7.0);
    }

    #[test]
    fn batch_propose_k_forks_one_sequence() {
        let b = AnalyticBackend::new("d", 1, 1.0, 0.0);
        let mut src = ModelBatchDraft::new(&b);
        let h0 = [1.0f32];
        let h1 = [3.0f32, 4.0];
        let tasks: Vec<(&[f32], usize)> = vec![(&h0, 1), (&h1, 2)];
        src.begin(&tasks, CacheMode::On).unwrap();
        let mut rngs = vec![Rng::new(5), Rng::new(6)];
        let blocks = src.propose_k(1, 2, 2, 0.3, &mut rngs).unwrap();
        assert_eq!(blocks.len(), 2);
        assert_eq!(blocks[0].mu_qs[0], vec![4.0]);
        assert_eq!(blocks[1].mu_qs[0], vec![4.0], "both branches fork the tip");
        assert_eq!(src.len(1), 2, "committed context untouched");
        assert_eq!(src.len(0), 1, "other sequence untouched");
        let committed: Vec<f32> = blocks[0].proposals[..1].to_vec().concat();
        src.finish_round(
            1,
            &RoundFeedback {
                gamma: 2,
                accepted: 1,
                alphas: &[1.0, 0.0],
                target_means: &[0.0; 3],
                committed: &committed,
                final_patch: &[8.0],
                sampled: true,
            },
        )
        .unwrap();
        assert_eq!(src.len(1), 4);
    }
}
